//! Quickstart: simulate a linear non-Gaussian SEM, discover its causal
//! DAG with DirectLiNGAM on the accelerated (XLA) engine, and compare
//! against the ground truth and the sequential reference.
//!
//!     cargo run --release --example quickstart
//!
//! Requires `make artifacts` (falls back to the pure-Rust vectorized
//! engine if the artifacts are missing).

use alingam::coordinator::{Engine, EngineChoice};
use alingam::metrics::graph_metrics;
use alingam::prelude::*;

fn main() -> alingam::util::Result<()> {
    // 1. simulate the paper's §3.1 workload: layered DAG, θ ~ N(0,1),
    //    ε ~ U(0,1), 10 variables × 10 000 samples
    let mut rng = Pcg64::seed_from_u64(2024);
    let spec = sim::SemSpec::layered(10, 2, 0.5);
    let ds = sim::simulate_sem(&spec, 10_000, &mut rng);
    println!("simulated: {} samples × {} vars, {} true edges",
        ds.data.rows(), ds.data.cols(),
        ds.adjacency.as_slice().iter().filter(|v| **v != 0.0).count());

    // 2. pick an engine: the AOT Pallas/XLA path if artifacts exist
    let engine = Engine::build(EngineChoice::Xla).unwrap_or_else(|e| {
        println!("(xla engine unavailable: {e}; using vectorized)");
        Engine::build(EngineChoice::Vectorized).expect("cpu engine")
    });
    println!("engine: {}", engine.as_ordering().name());

    // 3. fit
    let t0 = std::time::Instant::now();
    let fit = lingam::DirectLingam::new().fit(&ds.data, engine.as_ordering())?;
    println!("fit in {:.2?}; causal order {:?}", t0.elapsed(), fit.order);
    println!("ordering share of runtime: {:.1}%", 100.0 * fit.profile.fraction("ordering"));

    // 4. compare with truth
    let m = graph_metrics(&ds.adjacency, &fit.adjacency, 0.05);
    println!("recovery: F1 {:.3}  recall {:.3}  SHD {}", m.f1, m.recall, m.shd);
    assert!(
        alingam::graph::order_consistent(&ds.adjacency, &fit.order),
        "estimated order contradicts the true DAG"
    );

    // 5. cross-check against the sequential reference (the paper's
    //    headline validation: identical results)
    let seq = lingam::DirectLingam::new().fit(&ds.data, &lingam::SequentialEngine)?;
    println!(
        "sequential agreement: orders identical = {}, max |Δadj| = {:.2e}",
        seq.order == fit.order,
        metrics::adjacency_max_diff(&seq.adjacency, &fit.adjacency)
    );
    Ok(())
}
