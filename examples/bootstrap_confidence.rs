//! Bootstrap edge-confidence estimation — the companion workflow the
//! reference `lingam` package ships: resample → refit → per-edge
//! selection probabilities, fanned across coordinator workers.
//!
//!     cargo run --release --example bootstrap_confidence [-- --resamples 100]
//!
//! Also cross-checks DirectLiNGAM against ICA-LiNGAM (Shimizu et al.
//! 2006), the original estimator: two independent algorithms for the
//! same identifiable model class should agree on stable edges.

use alingam::coordinator::{bootstrap_direct, BootstrapOpts, Engine, EngineChoice};
use alingam::lingam::IcaLingam;
use alingam::prelude::*;
use alingam::util::cli::{opt, Args};
use alingam::util::table::{f, Table};

fn main() -> alingam::util::Result<()> {
    let args = Args::parse(
        "bootstrap confidence demo",
        &[
            opt("dims", "number of variables", Some("8")),
            opt("samples", "number of samples", Some("3000")),
            opt("resamples", "bootstrap resamples", Some("60")),
            opt("engine", "sequential|vectorized|xla", Some("vectorized")),
            opt("seed", "random seed", Some("2024")),
        ],
    );
    let d = args.usize("dims");
    let mut rng = Pcg64::seed_from_u64(args.usize("seed") as u64);
    let ds = sim::simulate_sem(&sim::SemSpec::layered(d, 2, 0.6), args.usize("samples"), &mut rng);
    let engine = Engine::build(EngineChoice::parse(&args.req("engine"))?)?;

    let opts = BootstrapOpts { resamples: args.usize("resamples"), workers: 2, ..Default::default() };
    let boot = bootstrap_direct(&ds.data, engine.as_ordering(), &opts)?;

    // ICA-LiNGAM as an independent cross-check
    let ica = IcaLingam::new().fit(&ds.data)?;

    let mut t = Table::new(
        "edges with bootstrap probability ≥ 0.5",
        &["edge", "boot prob", "mean weight", "true weight", "ICA-LiNGAM agrees"],
    );
    let mut agree = 0;
    let mut total = 0;
    for (from, to, p, w) in boot.stable_edges(0.5) {
        let truth = ds.adjacency[(to, from)];
        let ica_has = ica.adjacency[(to, from)].abs() > 0.05;
        if truth != 0.0 {
            total += 1;
            if ica_has {
                agree += 1;
            }
        }
        t.row(&[
            format!("x{from} → x{to}"),
            f(p, 2),
            f(w, 3),
            f(truth, 3),
            if ica_has { "yes" } else { "no" }.into(),
        ]);
    }
    t.print();
    println!(
        "\nstable true edges also found by ICA-LiNGAM: {agree}/{total} \
         (two independent estimators agreeing on the identifiable structure)"
    );
    println!("bootstrap resamples: {}", boot.resamples);
    Ok(())
}
