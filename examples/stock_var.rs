//! §4.2 / Figure 4 + Table 2: VarLiNGAM on S&P-500-style hourly data —
//! instantaneous-graph degree distributions and total-causal-influence
//! rankings.
//!
//!     cargo run --release --example stock_var [-- --dims 487 --engine vectorized]
//!
//! The synthetic market preserves the paper's pipeline end to end
//! (missing values → interpolation → differencing → VAR(1) → LiNGAM);
//! see DESIGN.md §Substitutions.

use alingam::apps::stocks::run_stocks;
use alingam::coordinator::{Engine, EngineChoice};
use alingam::sim::MarketSpec;
use alingam::util::cli::{opt, Args};
use alingam::util::table::{f, histogram, secs, Table};

fn main() -> alingam::util::Result<()> {
    let args = Args::parse(
        "Figure-4 / Table-2 stock pipeline",
        &[
            opt("dims", "number of tickers (487 = paper scale)", Some("60")),
            opt("samples", "hourly observations", Some("1500")),
            opt("engine", "sequential|vectorized|xla", Some("vectorized")),
            opt("seed", "random seed", Some("2024")),
        ],
    );
    let engine = Engine::build(EngineChoice::parse(&args.req("engine"))?)?;
    let dims = args.usize("dims");
    let spec = MarketSpec {
        dim: dims,
        t_len: args.usize("samples"),
        ..if dims >= 200 { MarketSpec::default() } else { MarketSpec::small() }
    };

    println!("market: {} tickers × {} hours, engine {}", spec.dim, spec.t_len, engine.as_ordering().name());
    let r = run_stocks(&spec, args.usize("seed") as u64, engine.as_ordering(), 5)?;

    let mut t = Table::new(
        "Table 2: top-5 total causal influence (exerting / receiving)",
        &["rank", "entity", "score", "role"],
    );
    for (k, (name, lag, score)) in r.top_exerting.iter().enumerate() {
        t.row(&[(k + 1).to_string(), format!("{name}_tau-{lag}"), f(*score, 3), "exerting".into()]);
    }
    for (k, (name, lag, score)) in r.top_receiving.iter().enumerate() {
        t.row(&[(k + 1).to_string(), format!("{name}_tau-{lag}"), f(*score, 3), "receiving".into()]);
    }
    t.print();

    print!("{}", histogram("Figure 4: in-degree distribution of θ0", &r.in_degrees, 12));
    print!("{}", histogram("Figure 4: out-degree distribution of θ0", &r.out_degrees, 12));
    println!("\nleaf tickers (influence nothing): {:?}", r.leaves);
    println!("designated exerters in top-5: {}/5   USB/FITB as leaves: {}/2", r.exerter_hits, r.leaf_hits);
    println!("fit: {} ({:.1}% in causal ordering)", secs(r.fit_secs), 100.0 * r.ordering_frac);
    println!(
        "\nPaper's qualitative findings to compare: in/out degrees roughly\n\
         symmetric with no dominant hubs; holding companies USB & FITB are leaves;\n\
         consumer-facing firms (NVR, AZO, CMG, BKNG, MTD) exert the most influence."
    );
    Ok(())
}
