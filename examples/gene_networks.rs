//! §4.1 / Table 1: gene-regulatory-network discovery from interventional
//! (Perturb-seq-style) expression data, with Stein-VI interventional
//! evaluation against a DCD-FG-like continuous-optimization baseline.
//!
//!     cargo run --release --example gene_networks [-- --scale medium --engine xla]
//!
//! The synthetic generator preserves the paper's experimental structure
//! (sparse GRN, targeted knockouts, three conditions, 20% held-out
//! interventions); see DESIGN.md §Substitutions.

use alingam::apps::genes::{run_table1, GeneScale, GenesConfig};
use alingam::baselines::SvgdOpts;
use alingam::coordinator::{Engine, EngineChoice};
use alingam::util::cli::{opt, Args};
use alingam::util::table::{f, secs, Table};

fn main() -> alingam::util::Result<()> {
    let args = Args::parse(
        "Table-1 gene pipeline",
        &[
            opt("scale", "small|medium|paper", Some("small")),
            opt("engine", "sequential|vectorized|xla", Some("vectorized")),
            opt("seed", "random seed", Some("2024")),
            opt("svgd-iters", "Stein VI iterations", Some("300")),
            opt("svgd-particles", "Stein VI particles", Some("50")),
        ],
    );
    let engine = Engine::build(EngineChoice::parse(&args.req("engine"))?)?;
    let cfg = GenesConfig {
        scale: GeneScale::parse(&args.req("scale")).expect("bad --scale"),
        seed: args.usize("seed") as u64,
        svgd: SvgdOpts {
            iters: args.usize("svgd-iters"),
            particles: args.usize("svgd-particles"),
            ..Default::default()
        },
        ..Default::default()
    };

    println!("engine: {}  scale: {:?}", engine.as_ordering().name(), cfg.scale);
    let rows = run_table1(&cfg, engine.as_ordering())?;

    let mut t = Table::new(
        "Table 1: I-NLL / I-MAE across held-out interventions (lower is better)",
        &["condition", "method", "I-NLL", "I-MAE", "leaves", "fit time"],
    );
    for r in &rows {
        t.row(&[
            r.condition.name().into(),
            r.method.into(),
            f(r.metrics.nll, 2),
            f(r.metrics.mae, 2),
            r.leaves.to_string(),
            secs(r.fit_secs),
        ]);
    }
    t.print();
    println!(
        "\nPaper's Table 1 (real Perturb-CITE-seq): DirectLiNGAM nll/mae = \n\
         co-culture 1.5/0.7, IFN 1.5/0.9, control 3/1.6; DCD-FG ≈ 1.1/0.7 each.\n\
         The shape to reproduce: comparable I-MAE, LiNGAM I-NLL slightly higher,\n\
         control the hardest condition for LiNGAM."
    );
    Ok(())
}
