//! Figure 1: the causal-asymmetry principle underpinning LiNGAM.
//!
//! For x → y with non-Gaussian noise, the regression residual is
//! independent of the regressor only in the correct direction; with
//! Gaussian noise the asymmetry vanishes and the direction is
//! unidentifiable.
//!
//!     cargo run --release --example causal_asymmetry

use alingam::apps::simbench::asymmetry_demo;
use alingam::sim::Noise;
use alingam::util::table::{f, Table};

fn main() -> alingam::util::Result<()> {
    let mut t = Table::new(
        "Figure 1: MI(regressor, residual) by direction and noise",
        &["noise", "theta", "MI forward (x->y)", "MI backward (y->x)", "identifiable"],
    );
    let n = 60_000;
    for (name, noise) in [
        ("Uniform(0,1)", Noise::Uniform01),
        ("Laplace(1)", Noise::Laplace(1.0)),
        ("Exponential(1)", Noise::Exponential(1.0)),
        ("Gaussian(1)", Noise::Gaussian(1.0)),
    ] {
        for theta in [0.8, 1.5] {
            let (fwd, bwd) = asymmetry_demo(noise, n, theta, 42)?;
            let identifiable = bwd > 5.0 * fwd.max(1e-3);
            t.row(&[
                name.into(),
                f(theta, 1),
                f(fwd, 4),
                f(bwd, 4),
                if identifiable { "yes".into() } else { "no (symmetric)".into() },
            ]);
        }
    }
    t.print();
    println!(
        "\nReading: non-Gaussian rows show MI ≈ 0 forward but > 0 backward — the\n\
         asymmetry DirectLiNGAM exploits. The Gaussian rows are symmetric: no\n\
         direction information exists (LiNGAM's non-Gaussianity assumption)."
    );
    Ok(())
}
