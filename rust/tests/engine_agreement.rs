//! Figure-3 integration tests: the accelerated engines must produce the
//! *same* causal orders and adjacencies as the sequential reference — the
//! paper's central validation ("Both implementations produce the exact
//! same result").
//!
//! The CPU-engine tests run in the tier-1 suite; everything touching the
//! XLA engine (which needs a live PJRT device plus `make artifacts`) is
//! gated behind the `xla` feature.

use alingam::apps::simbench::{agreement_sweep, fig3_spec};
use alingam::lingam::{
    DirectLingam, OrderingEngine, ParallelEngine, SequentialEngine, VectorizedEngine,
};
use alingam::sim::{simulate_sem, SemSpec};
use alingam::util::prop::props;
use alingam::util::rng::Pcg64;

#[test]
fn sequential_vs_vectorized_ten_seeds() {
    let seeds: Vec<u64> = (0..10).collect();
    let runs =
        agreement_sweep(&fig3_spec(), 2_000, &seeds, &SequentialEngine, &VectorizedEngine, 2);
    for r in &runs {
        assert!(r.orders_identical, "seed {}: orders diverged", r.seed);
        assert!(r.adj_max_diff < 1e-8, "seed {}: adjacency diff {}", r.seed, r.adj_max_diff);
        assert_eq!(r.metrics_a.f1, r.metrics_b.f1);
    }
}

#[test]
fn sequential_vs_parallel_ten_seeds() {
    // the paper's central validation, extended to the thread-pool engine:
    // identical orders and adjacencies vs the sequential reference
    let seeds: Vec<u64> = (0..10).collect();
    // force_parallel: the Fig-3 panel sits below the serial-fallback
    // cutoff, and the threaded path is what must agree here
    let runs = agreement_sweep(
        &fig3_spec(),
        2_000,
        &seeds,
        &SequentialEngine,
        &ParallelEngine::new(4).force_parallel(),
        2,
    );
    for r in &runs {
        assert!(r.orders_identical, "seed {}: orders diverged", r.seed);
        assert!(r.adj_max_diff < 1e-8, "seed {}: adjacency diff {}", r.seed, r.adj_max_diff);
        assert_eq!(r.metrics_a.f1, r.metrics_b.f1);
    }
}

#[test]
fn parallel_scores_match_vectorized_property() {
    // property: on random panels, random active masks and random worker
    // counts, the parallel engine's k_list agrees with the vectorized
    // engine to 1e-9 (they share the pair kernel; only the summation
    // association differs)
    props("parallel vs vectorized scores", 20, |g| {
        let d = g.usize_in(3, 12);
        let n = g.usize_in(64, 512);
        let seed = g.rng().next_u64();
        let mut rng = Pcg64::seed_from_u64(seed);
        let ds = simulate_sem(&SemSpec::layered(d, 2, 0.6), n, &mut rng);
        let mut active = vec![true; d];
        for slot in active.iter_mut() {
            if g.bool_p(0.2) {
                *slot = false;
            }
        }
        if active.iter().filter(|&&a| a).count() < 2 {
            active[0] = true;
            active[1] = true;
        }
        let workers = g.usize_in(1, 8);
        let kv = VectorizedEngine.scores(&ds.data, &active).unwrap();
        let kp = ParallelEngine::new(workers)
            .force_parallel()
            .scores(&ds.data, &active)
            .unwrap();
        for i in 0..d {
            if !active[i] {
                assert_eq!(kp[i], f64::NEG_INFINITY);
                continue;
            }
            assert!(
                (kv[i] - kp[i]).abs() < 1e-9 * (1.0 + kv[i].abs()),
                "d={d} n={n} workers={workers} i={i}: vec={} par={}",
                kv[i],
                kp[i]
            );
        }
    });
}

#[test]
fn session_scores_match_stateless_scores_at_every_step_all_cpu_engines() {
    // the session refactor's central contract: the incremental workspace
    // path (standardized-cache residualization + closed-form correlation
    // updates) reproduces the legacy from-scratch k_list at every
    // ordering step, ≤ 1e-9 relative, for every CPU engine
    let mut rng = Pcg64::seed_from_u64(99);
    let ds = simulate_sem(&SemSpec::layered(10, 2, 0.5), 2_500, &mut rng);
    let engines: Vec<Box<dyn OrderingEngine>> = vec![
        Box::new(SequentialEngine),
        Box::new(VectorizedEngine),
        Box::new(ParallelEngine::new(4).force_parallel()),
    ];
    for engine in &engines {
        let session_fit = DirectLingam::new().fit(&ds.data, engine.as_ref()).unwrap();
        let legacy_fit = DirectLingam::new().fit_stateless(&ds.data, engine.as_ref()).unwrap();
        assert_eq!(
            session_fit.order,
            legacy_fit.order,
            "{}: session order diverged from stateless",
            engine.name()
        );
        assert_eq!(session_fit.step_scores.len(), legacy_fit.step_scores.len());
        for (step, (s, l)) in session_fit
            .step_scores
            .iter()
            .zip(&legacy_fit.step_scores)
            .enumerate()
        {
            for i in 0..s.len() {
                if l[i] == f64::NEG_INFINITY {
                    assert_eq!(s[i], f64::NEG_INFINITY, "{}: step {step} var {i}", engine.name());
                    continue;
                }
                assert!(
                    (s[i] - l[i]).abs() <= 1e-9 * (1.0 + l[i].abs()),
                    "{}: step {step} var {i}: session={} stateless={}",
                    engine.name(),
                    s[i],
                    l[i]
                );
            }
        }
    }
}

#[test]
fn three_cpu_engines_identical_orders_on_one_fit() {
    let mut rng = Pcg64::seed_from_u64(17);
    let ds = simulate_sem(&SemSpec::layered(9, 2, 0.5), 3_000, &mut rng);
    let seq = DirectLingam::new().fit(&ds.data, &SequentialEngine).unwrap();
    let vec = DirectLingam::new().fit(&ds.data, &VectorizedEngine).unwrap();
    let par = DirectLingam::new()
        .fit(&ds.data, &ParallelEngine::new(3).force_parallel())
        .unwrap();
    assert_eq!(seq.order, vec.order);
    assert_eq!(vec.order, par.order);
    assert!(alingam::metrics::adjacency_max_diff(&vec.adjacency, &par.adjacency) < 1e-8);
}

#[cfg(feature = "xla")]
mod xla {
    use super::*;
    use alingam::lingam::{IncrementalSession, OrderingSession};
    use alingam::runtime::XlaEngine;

    fn xla_engine() -> XlaEngine {
        XlaEngine::from_default_artifacts()
            .expect("XLA engine unavailable — run `make artifacts` first")
    }

    #[test]
    fn sequential_vs_xla_orders_agree() {
        // the XLA path computes in f32; the validated property is the
        // paper's: identical causal orders and matching recovery metrics
        let engine = xla_engine();
        let seeds: Vec<u64> = (0..5).collect();
        let runs = agreement_sweep(&fig3_spec(), 4_000, &seeds, &SequentialEngine, &engine, 1);
        let identical = runs.iter().filter(|r| r.orders_identical).count();
        assert_eq!(
            identical,
            runs.len(),
            "xla orders diverged on seeds {:?}",
            runs.iter().filter(|r| !r.orders_identical).map(|r| r.seed).collect::<Vec<_>>()
        );
        for r in &runs {
            assert_eq!(r.metrics_a.shd, r.metrics_b.shd, "seed {}", r.seed);
            // adjacencies differ only by f32 rounding
            assert!(r.adj_max_diff < 1e-3, "seed {}: {}", r.seed, r.adj_max_diff);
        }
    }

    #[test]
    fn xla_scores_match_vectorized_scores() {
        let engine = xla_engine();
        let mut rng = Pcg64::seed_from_u64(42);
        let ds = simulate_sem(&SemSpec::layered(8, 2, 0.5), 1_000, &mut rng);
        let active = vec![true; 8];
        let k_vec = VectorizedEngine.scores(&ds.data, &active).unwrap();
        let k_xla = engine.scores(&ds.data, &active).unwrap();
        for i in 0..8 {
            let rel = (k_vec[i] - k_xla[i]).abs() / (1.0 + k_vec[i].abs());
            assert!(rel < 1e-3, "i={i}: vec {} xla {}", k_vec[i], k_xla[i]);
        }
    }

    #[test]
    fn xla_engine_respects_masking_and_padding() {
        let engine = xla_engine();
        // n=777, d=7 forces zero-padding into a larger bucket
        let mut rng = Pcg64::seed_from_u64(7);
        let ds = simulate_sem(&SemSpec::layered(7, 2, 0.6), 777, &mut rng);
        let mut active = vec![true; 7];
        active[3] = false;
        let k = engine.scores(&ds.data, &active).unwrap();
        assert_eq!(k[3], f64::NEG_INFINITY);
        let k_ref = VectorizedEngine.scores(&ds.data, &active).unwrap();
        for i in 0..7 {
            if i == 3 {
                continue;
            }
            let rel = (k[i] - k_ref[i]).abs() / (1.0 + k_ref[i].abs());
            assert!(rel < 1e-3, "i={i}: {} vs {}", k[i], k_ref[i]);
        }
    }

    #[test]
    fn full_fit_through_xla_recovers_truth() {
        let engine = xla_engine();
        let mut rng = Pcg64::seed_from_u64(3);
        let ds = simulate_sem(&fig3_spec(), 4_000, &mut rng);
        let fit = DirectLingam::new().fit(&ds.data, &engine).unwrap();
        assert!(
            alingam::graph::order_consistent(&ds.adjacency, &fit.order),
            "xla order {:?} inconsistent with truth",
            fit.order
        );
        let m = alingam::metrics::graph_metrics(&ds.adjacency, &fit.adjacency, 0.05);
        assert!(m.f1 > 0.8, "f1 = {}", m.f1);
    }

    #[test]
    fn device_stats_accumulate() {
        let engine = xla_engine();
        let mut rng = Pcg64::seed_from_u64(9);
        let ds = simulate_sem(&SemSpec::layered(6, 2, 0.6), 500, &mut rng);
        let before = engine.executor().stats.snapshot();
        let _ = DirectLingam::new().fit(&ds.data, &engine).unwrap();
        let after = engine.executor().stats.snapshot();
        assert!(after.0 > before.0, "no artifact calls recorded");
        assert!(after.1 > before.1, "no upload bytes recorded");
        assert!(after.3 > before.3, "no execute time recorded");
    }

    #[test]
    fn device_session_agrees_with_incremental_session_per_step() {
        // the device-resident XlaSession must make the same per-step
        // choices as the CPU IncrementalSession on the agreement panels,
        // with score rows equal to f32 precision — the accelerated
        // analogue of session_scores_match_stateless_scores
        let engine = xla_engine();
        for seed in [11u64, 12, 13] {
            let mut rng = Pcg64::seed_from_u64(seed);
            let ds = simulate_sem(&SemSpec::layered(8, 2, 0.5), 2_000, &mut rng);
            let mut dev = engine.session(&ds.data).unwrap();
            let mut cpu = IncrementalSession::new(&ds.data, 1, false).unwrap();
            for step in 0..7 {
                let a = dev.step().unwrap();
                let b = cpu.step().unwrap();
                assert_eq!(
                    a.chosen, b.chosen,
                    "seed {seed} step {step}: device chose {} vs cpu {}",
                    a.chosen, b.chosen
                );
                for i in 0..8 {
                    let (sa, sb) = (a.scores[i], b.scores[i]);
                    if sb == f64::NEG_INFINITY {
                        assert_eq!(sa, f64::NEG_INFINITY, "seed {seed} step {step} var {i}");
                        continue;
                    }
                    let rel = (sa - sb).abs() / (1.0 + sb.abs());
                    assert!(
                        rel < 1e-3,
                        "seed {seed} step {step} var {i}: device {sa} cpu {sb}"
                    );
                }
            }
        }
    }

    #[test]
    fn device_session_fit_matches_stateless_xla_fit() {
        // residency must not change the answer: the session fit and the
        // legacy stateless fused-step fit elect the same causal order
        let engine = xla_engine();
        let mut rng = Pcg64::seed_from_u64(21);
        let ds = simulate_sem(&SemSpec::layered(8, 2, 0.5), 2_000, &mut rng);
        let session_fit = DirectLingam::new().fit(&ds.data, &engine).unwrap();
        let stateless_fit = DirectLingam::new().fit_stateless(&ds.data, &engine).unwrap();
        assert_eq!(session_fit.order, stateless_fit.order, "residency changed the order");
        assert!(
            alingam::metrics::adjacency_max_diff(
                &session_fit.adjacency,
                &stateless_fit.adjacency
            ) < 1e-8
        );
    }
}
