//! Session-state correctness: the incremental ordering workspace
//! (`lingam::session::IncrementalSession`) must agree with a from-scratch
//! recompute at every step of the fit.
//!
//! Two families of checks:
//! - **per-step score agreement** — drive a session step by step while
//!   mirroring the legacy stateless path (engine `scores` on a panel that
//!   is residualized with `residualize_in_place`); every step's k_list
//!   must match to ≤ 1e-9 relative, for the sequential, vectorized and
//!   parallel engines;
//! - **workspace invariants** — a property test interleaves
//!   `advance_with` (residualize+update) steps with direct recomputation
//!   and checks that the cached correlation matrix stays within 1e-8 of the
//!   correlations computed from the cached columns by plain dots, and
//!   that the cached columns stay standardized.

use alingam::lingam::engine::{residualize_in_place, INACTIVE_SCORE};
use alingam::lingam::{
    DirectLingam, IncrementalSession, OrderingEngine, OrderingSession, ParallelEngine,
    SequentialEngine, VectorizedEngine,
};
use alingam::linalg::Mat;
use alingam::sim::{simulate_sem, SemSpec};
use alingam::util::prop::props;
use alingam::util::rng::Pcg64;

fn toy_panel(n: usize, d: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::seed_from_u64(seed);
    simulate_sem(&SemSpec::layered(d, 2, 0.6), n, &mut rng).data
}

/// Drive `engine.session(x)` to completion, asserting at every step that
/// the session's k_list matches what the engine's stateless `scores`
/// computes from scratch on the mirrored residual panel.
fn assert_per_step_agreement(engine: &dyn OrderingEngine, x: &Mat, tol: f64) {
    let d = x.cols();
    let mut session = engine.session(x).unwrap();
    let mut legacy_x = x.clone();
    let mut legacy_active = vec![true; d];
    for step_no in 0..(d - 1) {
        let from_scratch = engine.scores(&legacy_x, &legacy_active).unwrap();
        let step = session.step().unwrap();
        for i in 0..d {
            if !legacy_active[i] {
                assert_eq!(
                    step.scores[i],
                    INACTIVE_SCORE,
                    "{}: step {step_no} var {i}: inactive score leaked",
                    engine.name()
                );
                continue;
            }
            let (s, f) = (step.scores[i], from_scratch[i]);
            assert!(
                (s - f).abs() <= tol * (1.0 + f.abs()),
                "{}: step {step_no} var {i}: session={s} from-scratch={f}",
                engine.name()
            );
        }
        // both paths must choose the same root; mirror the legacy
        // residualization for the next round
        let legacy_best = alingam::lingam::engine::argmax_active(&from_scratch, &legacy_active)
            .unwrap();
        assert_eq!(
            step.chosen,
            legacy_best,
            "{}: step {step_no}: session chose a different root",
            engine.name()
        );
        residualize_in_place(&mut legacy_x, &legacy_active, step.chosen);
        legacy_active[step.chosen] = false;
    }
    assert_eq!(session.remaining(), 1);
}

#[test]
fn sequential_session_matches_from_scratch_per_step() {
    // the shim path: exact same code per step, so agreement is trivial —
    // this pins the shim's bookkeeping (active mask, panel mirroring)
    assert_per_step_agreement(&SequentialEngine, &toy_panel(1_200, 7, 1), 1e-12);
}

#[test]
fn vectorized_session_matches_from_scratch_per_step() {
    assert_per_step_agreement(&VectorizedEngine, &toy_panel(2_000, 9, 2), 1e-9);
}

#[test]
fn parallel_session_matches_from_scratch_per_step() {
    // force_parallel: the toy panel sits below the serial-fallback
    // cutoff and the pooled sweeps are what needs coverage
    let engine = ParallelEngine::new(4).force_parallel();
    assert_per_step_agreement(&engine, &toy_panel(1_500, 8, 3), 1e-9);
}

#[test]
fn per_step_agreement_over_seeds() {
    for seed in 10..15 {
        assert_per_step_agreement(&VectorizedEngine, &toy_panel(800, 6, seed), 1e-9);
    }
}

#[test]
fn prop_cached_corr_tracks_direct_recompute() {
    // interleaved residualize/update steps keep the cached correlation
    // matrix within 1e-8 of correlations recomputed from the cached
    // columns by plain dots, and the cache itself stays standardized
    props("session corr cache vs direct", 15, |g| {
        let d = g.usize_in(4, 10);
        let n = g.usize_in(128, 512);
        let seed = g.rng().next_u64();
        let mut rng = Pcg64::seed_from_u64(seed);
        let ds = simulate_sem(&SemSpec::layered(d, 2, 0.6), n, &mut rng);
        let workers = g.usize_in(1, 4);
        let mut s = IncrementalSession::new(&ds.data, workers, workers > 1).unwrap();
        let mut active: Vec<usize> = (0..d).collect();
        while active.len() > 1 {
            // remove a random active variable (not necessarily the
            // argmax: the invariants must hold for any removal order)
            let pick = g.usize_in(0, active.len() - 1);
            let m = active.swap_remove(pick);
            // residualize+update+deactivate in one committed step
            s.advance_with(m).unwrap();
            let corr = s.corr();
            for (ai, &ja) in active.iter().enumerate() {
                let ca = s.cached_column(ja);
                // unit variance / zero mean up to closed-form rounding
                let mean: f64 = ca.iter().sum::<f64>() / n as f64;
                let var: f64 = ca.iter().map(|v| v * v).sum::<f64>() / n as f64;
                assert!(mean.abs() < 1e-8, "col {ja}: cache mean drifted to {mean}");
                assert!((var - 1.0).abs() < 1e-6, "col {ja}: cache var drifted to {var}");
                for &jb in active.iter().skip(ai + 1) {
                    let cb = s.cached_column(jb);
                    let direct: f64 =
                        ca.iter().zip(cb).map(|(&x, &y)| x * y).sum::<f64>() / n as f64;
                    let cached = corr[(ja, jb)];
                    assert!(
                        (cached - direct).abs() < 1e-8,
                        "pair ({ja},{jb}): cached ρ {cached} vs direct {direct}"
                    );
                }
            }
        }
    });
}

#[test]
fn prop_session_scores_match_stateless_on_random_masks() {
    // a fresh session over a pre-residualized panel must agree with the
    // stateless engine on that panel: the incremental path's state after
    // k steps is equivalent to a stateless call on the k-times
    // residualized panel
    props("session vs stateless after random steps", 10, |g| {
        let d = g.usize_in(4, 9);
        let n = g.usize_in(256, 768);
        let seed = g.rng().next_u64();
        let mut rng = Pcg64::seed_from_u64(seed);
        let ds = simulate_sem(&SemSpec::layered(d, 2, 0.5), n, &mut rng);
        let steps = g.usize_in(1, d - 2);
        let mut session = IncrementalSession::new(&ds.data, 1, false).unwrap();
        let mut x = ds.data.clone();
        let mut active = vec![true; d];
        for _ in 0..steps {
            let scores = session.scores().unwrap();
            let chosen =
                alingam::lingam::engine::argmax_active(&scores, session.active()).unwrap();
            session.advance_with(chosen).unwrap();
            residualize_in_place(&mut x, &active, chosen);
            active[chosen] = false;
        }
        let incremental = session.scores().unwrap();
        let stateless = VectorizedEngine.scores(&x, &active).unwrap();
        for i in 0..d {
            if !active[i] {
                assert_eq!(incremental[i], INACTIVE_SCORE);
                continue;
            }
            assert!(
                (incremental[i] - stateless[i]).abs() <= 1e-9 * (1.0 + stateless[i].abs()),
                "var {i} after {steps} steps: incremental={} stateless={}",
                incremental[i],
                stateless[i]
            );
        }
    });
}

#[test]
fn session_reuse_across_resamples_matches_fresh_fits() {
    // the bootstrap's pool pattern: reset + fit_session must equal a
    // fresh fit on every resample
    let base = toy_panel(600, 6, 21);
    let mut rng = Pcg64::seed_from_u64(22);
    let engine = VectorizedEngine;
    let mut session = engine.session(&base).unwrap();
    for _ in 0..4 {
        let rows: Vec<usize> = (0..base.rows()).map(|_| rng.below(base.rows())).collect();
        let sample = base.select_rows(&rows);
        session.reset(&sample).unwrap();
        let reused = DirectLingam::new().fit_session(&sample, session.as_mut()).unwrap();
        let fresh = DirectLingam::new().fit(&sample, &VectorizedEngine).unwrap();
        assert_eq!(reused.order, fresh.order);
        assert_eq!(reused.step_scores, fresh.step_scores);
    }
}

// (Degenerate-panel session coverage — duplicated/collinear columns
// staying NaN-free through every engine's session — lives in
// tests/degenerate_panels.rs::sessions_stay_finite_on_degenerate_panels.)
