//! Runtime integration: the AOT HLO artifacts load, compile and execute
//! via PJRT, their numerics match the Rust reference implementations,
//! and the device-resident session keeps its transfer contract (one
//! panel upload per fit, O(d) per step).
//!
//! Requires `make artifacts`. Everything that needs a live PJRT device
//! is gated behind the `xla` feature (`cargo test --features xla`); the
//! manifest checks below run in the plain tier-1 suite too.

use alingam::runtime::{artifact_dir, ArtifactKind, ArtifactRegistry};

#[test]
fn manifest_loads_and_covers_default_shapes() {
    let reg = ArtifactRegistry::load(&artifact_dir()).expect("run `make artifacts`");
    assert!(!reg.is_empty());
    // the shapes the examples/benches rely on must be servable
    for (n, d) in [(200, 8), (1_000, 10), (4_000, 16), (4_000, 32)] {
        assert!(
            reg.best(ArtifactKind::OrderStep, n, d).is_ok(),
            "no order_step bucket for {n}x{d}"
        );
        assert!(reg.best(ArtifactKind::OrderScores, n, d).is_ok());
    }
    assert!(reg.best(ArtifactKind::VarFit, 500, 16).is_ok());
}

#[test]
fn manifest_session_triples_complete() {
    // every order bucket must carry the full session triple at the same
    // shape, or XlaSession would fall back to the stateless shim there
    let reg = ArtifactRegistry::load(&artifact_dir()).expect("run `make artifacts`");
    let inits = reg.of_kind(ArtifactKind::SessionInit);
    assert!(!inits.is_empty(), "no session_init artifacts in manifest");
    for b in inits {
        assert!(
            reg.exact(ArtifactKind::SessionScores, b.n, b.d).is_ok(),
            "no session_scores at {}x{}",
            b.n,
            b.d
        );
        assert!(
            reg.exact(ArtifactKind::SessionUpdate, b.n, b.d).is_ok(),
            "no session_update at {}x{}",
            b.n,
            b.d
        );
    }
}

#[test]
fn manifest_batch_triples_complete() {
    // every batched cell must carry all three batched kinds at the same
    // (n, d, b), and its (n, d) must also exist solo (the singleton
    // fallback when a fusion group collapses to one job)
    let reg = ArtifactRegistry::load(&artifact_dir()).expect("run `make artifacts`");
    let inits = reg.of_kind(ArtifactKind::SessionInitBatch);
    assert!(!inits.is_empty(), "no session_init_batch artifacts in manifest");
    for b in inits {
        assert!(b.b > 1, "batch bucket with b={}", b.b);
        assert!(
            reg.exact_batch(ArtifactKind::SessionScoresBatch, b.n, b.d, b.b).is_ok(),
            "no session_scores_batch at {}x{}b{}",
            b.n,
            b.d,
            b.b
        );
        assert!(
            reg.exact_batch(ArtifactKind::SessionUpdateBatch, b.n, b.d, b.b).is_ok(),
            "no session_update_batch at {}x{}b{}",
            b.n,
            b.d,
            b.b
        );
        assert!(
            reg.exact(ArtifactKind::SessionInit, b.n, b.d).is_ok(),
            "batch cell {}x{} has no solo session_init",
            b.n,
            b.d
        );
    }
}

#[cfg(feature = "xla")]
mod with_device {
    use alingam::lingam::var::var1_fit;
    use alingam::lingam::DirectLingam;
    use alingam::runtime::{
        artifact_dir, ArtifactKind, ArtifactRegistry, DeviceExecutor, HostArray, XlaEngine,
    };
    use alingam::sim::{simulate_sem, simulate_var, SemSpec, VarSpec};
    use alingam::util::rng::Pcg64;

    #[test]
    fn executor_reports_platform() {
        let exec = DeviceExecutor::start().unwrap();
        let p = exec.platform().unwrap();
        assert!(p.to_lowercase().contains("cpu") || p.contains("Host"), "platform = {p}");
    }

    #[test]
    fn var_fit_artifact_matches_rust_var_fit() {
        let reg = ArtifactRegistry::load(&artifact_dir()).expect("run `make artifacts`");
        let exec = DeviceExecutor::start().unwrap();

        let spec = VarSpec { dim: 12, ..Default::default() };
        let mut rng = Pcg64::seed_from_u64(5);
        let ds = simulate_var(&spec, 400, &mut rng);
        let (t, d) = (ds.data.rows(), ds.data.cols());

        // rust reference
        let (m1_ref, _) = var1_fit(&ds.data).unwrap();

        // artifact path: pad into the bucket
        let bucket = reg.best(ArtifactKind::VarFit, t, d).unwrap();
        let (tb, db) = (bucket.n, bucket.d);
        let mut series = vec![0.0f32; tb * db];
        for r in 0..t {
            for c in 0..d {
                series[r * db + c] = ds.data[(r, c)] as f32;
            }
        }
        let mut row_mask = vec![0.0f32; tb];
        for v in row_mask.iter_mut().take(t) {
            *v = 1.0;
        }
        let outs = exec
            .run(
                bucket.path.clone(),
                vec![
                    HostArray::new(vec![tb as i64, db as i64], series),
                    HostArray::vector(row_mask),
                ],
            )
            .unwrap();
        let m1_pad = outs[0].f32s().unwrap();
        for i in 0..d {
            for j in 0..d {
                let a = m1_ref[(i, j)];
                let b = m1_pad[i * db + j] as f64;
                assert!(
                    (a - b).abs() < 1e-3 * (1.0 + a.abs()),
                    "M1[{i},{j}]: rust {a} vs artifact {b}"
                );
            }
        }
    }

    #[test]
    fn executable_cache_compiles_once() {
        let reg = ArtifactRegistry::load(&artifact_dir()).expect("run `make artifacts`");
        let exec = DeviceExecutor::start().unwrap();
        let bucket = reg.best(ArtifactKind::OrderScores, 100, 8).unwrap();

        let run = |exec: &DeviceExecutor| {
            let x = vec![0.5f32; bucket.n * bucket.d];
            let mut rm = vec![0.0f32; bucket.n];
            rm[..50].iter_mut().for_each(|v| *v = 1.0);
            let cm = vec![1.0f32; bucket.d];
            exec.run(
                bucket.path.clone(),
                vec![
                    HostArray::new(vec![bucket.n as i64, bucket.d as i64], x),
                    HostArray::vector(rm),
                    HostArray::vector(cm),
                ],
            )
            .unwrap()
        };
        let t0 = std::time::Instant::now();
        let _ = run(&exec);
        let first = t0.elapsed();
        let t1 = std::time::Instant::now();
        let _ = run(&exec);
        let second = t1.elapsed();
        // second call skips XLA compilation: must be much faster
        assert!(
            second < first / 2,
            "no caching effect: first {first:?}, second {second:?}"
        );
    }

    #[test]
    fn constant_columns_do_not_crash_scores() {
        // degenerate input: zero-variance column (std clamped by STD_EPS)
        let reg = ArtifactRegistry::load(&artifact_dir()).expect("run `make artifacts`");
        let exec = DeviceExecutor::start().unwrap();
        let bucket = reg.best(ArtifactKind::OrderScores, 64, 4).unwrap();
        let mut x = vec![0.0f32; bucket.n * bucket.d];
        for r in 0..64 {
            x[r * bucket.d] = 1.0; // constant column 0
            x[r * bucket.d + 1] = r as f32; // ramp
            x[r * bucket.d + 2] = (r * r % 17) as f32;
            x[r * bucket.d + 3] = (r % 5) as f32;
        }
        let mut rm = vec![0.0f32; bucket.n];
        rm[..64].iter_mut().for_each(|v| *v = 1.0);
        let mut cm = vec![0.0f32; bucket.d];
        cm[..4].iter_mut().for_each(|v| *v = 1.0);
        let outs = exec
            .run(
                bucket.path.clone(),
                vec![
                    HostArray::new(vec![bucket.n as i64, bucket.d as i64], x),
                    HostArray::vector(rm),
                    HostArray::vector(cm),
                ],
            )
            .unwrap();
        let k = outs[0].f32s().unwrap();
        for i in 0..4 {
            assert!(k[i].is_finite(), "k[{i}] = {}", k[i]);
        }
    }

    #[test]
    fn executor_shared_across_threads() {
        use std::sync::Arc;
        let reg =
            Arc::new(ArtifactRegistry::load(&artifact_dir()).expect("run `make artifacts`"));
        let exec = DeviceExecutor::start().unwrap();
        let bucket = reg.best(ArtifactKind::OrderScores, 100, 8).unwrap().clone();
        std::thread::scope(|s| {
            for t in 0..3 {
                let exec = exec.clone();
                let path = bucket.path.clone();
                let (nb, db) = (bucket.n, bucket.d);
                s.spawn(move || {
                    let x = vec![(t as f32) * 0.1 + 0.3; nb * db];
                    let mut rm = vec![0.0f32; nb];
                    rm[..64].iter_mut().for_each(|v| *v = 1.0);
                    let cm = vec![1.0f32; db];
                    let outs = exec
                        .run(
                            path,
                            vec![
                                HostArray::new(vec![nb as i64, db as i64], x),
                                HostArray::vector(rm),
                                HostArray::vector(cm),
                            ],
                        )
                        .unwrap();
                    assert_eq!(outs[0].f32s().unwrap().len(), db);
                });
            }
        });
    }

    // -----------------------------------------------------------------
    // Device-resident session: the transfer contract.
    // -----------------------------------------------------------------

    #[test]
    fn session_fit_uploads_panel_exactly_once_and_steps_are_o_d() {
        // the tentpole's acceptance assertion: with the session path, a
        // fit performs exactly ONE panel upload (session_init) and every
        // step moves only the [db] score row down and the [db] one-hot
        // up — counted byte-exactly from the executor stats
        let engine = XlaEngine::from_default_artifacts().expect("run `make artifacts`");
        let mut rng = Pcg64::seed_from_u64(41);
        let (n, d) = (200usize, 6usize);
        let ds = simulate_sem(&SemSpec::layered(d, 2, 0.5), n, &mut rng);
        let bucket = engine
            .registry()
            .best(ArtifactKind::SessionInit, n, d)
            .expect("session bucket")
            .clone();
        let (nb, db) = (bucket.n, bucket.d);

        let before = engine.executor().stats.snapshot();
        let fit = DirectLingam::new().fit(&ds.data, &engine).unwrap();
        let after = engine.executor().stats.snapshot();
        assert_eq!(fit.order.len(), d);

        let steps = (d - 1) as u64;
        let calls = after.0 - before.0;
        let up = after.1 - before.1;
        let down = after.2 - before.2;
        // one init + (scores, update) per step
        assert_eq!(calls, 1 + 2 * steps, "unexpected device call count");
        // uploads: the padded panel + row/col masks once, then one [db]
        // one-hot per step — NOT one panel per step
        let init_bytes = 4 * (nb * db + nb + db) as u64;
        assert_eq!(up, init_bytes + steps * 4 * db as u64, "upload bytes");
        // downloads: one [db] score row per step — the residualized
        // panel never comes back to the host
        assert_eq!(down, steps * 4 * db as u64, "download bytes");
    }

    #[test]
    fn batched_session_uploads_once_and_steps_the_whole_group() {
        use alingam::lingam::XlaBatchSession;
        // the fusion acceptance assertion: B same-shape panels pay ONE
        // session_init upload and ONE scores dispatch per lock step for
        // the whole batch — counted byte-exactly — and every lane's
        // order is the solo XLA fit's order
        let engine = XlaEngine::from_default_artifacts().expect("run `make artifacts`");
        let mut rng = Pcg64::seed_from_u64(47);
        let (n, d) = (200usize, 6usize);
        let panels: Vec<_> = (0..3)
            .map(|_| simulate_sem(&SemSpec::layered(d, 2, 0.5), n, &mut rng).data)
            .collect();
        let solo_orders: Vec<_> = panels
            .iter()
            .map(|p| DirectLingam::new().fit(p, &engine).unwrap().order)
            .collect();
        let bucket = engine
            .registry()
            .best_batch(ArtifactKind::SessionInitBatch, n, d, panels.len())
            .expect("batch bucket")
            .clone();
        let (nb, db, bb) = (bucket.n, bucket.d, bucket.b);

        let before = engine.executor().stats.snapshot();
        let mut session =
            XlaBatchSession::new(engine.executor().clone(), engine.registry(), &panels).unwrap();
        while !session.finished() {
            session.step_live().unwrap();
        }
        let after = engine.executor().stats.snapshot();

        for (p, solo) in solo_orders.iter().enumerate() {
            assert!(session.live(p), "lane {p} died: {:?}", session.lane_error(p));
            assert_eq!(session.lane_order(p), &solo[..], "lane {p} diverged from solo");
        }
        let steps = (d - 1) as u64;
        let calls = after.0 - before.0;
        let up = after.1 - before.1;
        let down = after.2 - before.2;
        // one batched init + (scores, update) per lock step — NOT per job
        assert_eq!(calls, 1 + 2 * steps, "unexpected device call count");
        // uploads: the flattened [bb, nb, db] panel block + masks once,
        // then one [bb, db] one-hot block per step
        let init_bytes = 4 * (bb * nb * db + bb * nb + bb * db) as u64;
        assert_eq!(up, init_bytes + steps * 4 * (bb * db) as u64, "upload bytes");
        // downloads: one [bb, db] score block per step
        assert_eq!(down, steps * 4 * (bb * db) as u64, "download bytes");
        // buffer hygiene: the resident state is swapped, never duplicated
        drop(session);
        let _ = engine.executor().platform().unwrap();
        assert_eq!(engine.executor().stats.live_buffers(), 0, "batched state leaked");
    }

    #[test]
    fn session_state_buffers_do_not_leak() {
        let engine = XlaEngine::from_default_artifacts().expect("run `make artifacts`");
        let mut rng = Pcg64::seed_from_u64(43);
        let ds = simulate_sem(&SemSpec::layered(5, 2, 0.5), 300, &mut rng);
        for _ in 0..3 {
            let _ = DirectLingam::new().fit(&ds.data, &engine).unwrap();
        }
        // the Free messages are fire-and-forget; a synchronous platform
        // round-trip drains the FIFO queue behind them
        let _ = engine.executor().platform().unwrap();
        assert_eq!(
            engine.executor().stats.live_buffers(),
            0,
            "device-resident session state leaked"
        );
    }

    #[test]
    fn session_reset_reuses_workspace_across_panels() {
        use alingam::lingam::{OrderingEngine, OrderingSession};
        let engine = XlaEngine::from_default_artifacts().expect("run `make artifacts`");
        let mut rng = Pcg64::seed_from_u64(44);
        let a = simulate_sem(&SemSpec::layered(5, 2, 0.5), 300, &mut rng).data;
        let b = simulate_sem(&SemSpec::layered(5, 2, 0.5), 300, &mut rng).data;
        let mut session = engine.session(&a).unwrap();
        let fit_a = DirectLingam::new().fit_session(&a, session.as_mut()).unwrap();
        // pooled-reuse path (what the bootstrap does): reset re-seeds the
        // same workspace with one fresh panel upload
        session.reset(&b).unwrap();
        let fit_b = DirectLingam::new().fit_session(&b, session.as_mut()).unwrap();
        let fresh_b = DirectLingam::new().fit(&b, &engine).unwrap();
        assert_eq!(fit_b.order, fresh_b.order, "reset session diverged from fresh fit");
        assert_eq!(fit_a.order.len(), 5);
        // shape mismatch must be rejected
        let small = simulate_sem(&SemSpec::layered(4, 2, 0.5), 300, &mut rng).data;
        assert!(session.reset(&small).is_err());
    }

    #[test]
    fn resident_toggle_falls_back_to_stateless_shim() {
        // with_resident(false) must still fit correctly — it pins the
        // session API to the legacy fused order_step path
        let engine = XlaEngine::from_default_artifacts()
            .expect("run `make artifacts`")
            .with_resident(false);
        let mut rng = Pcg64::seed_from_u64(45);
        let ds = simulate_sem(&SemSpec::layered(5, 2, 0.5), 400, &mut rng);
        let before = engine.executor().stats.snapshot();
        let fit = DirectLingam::new().fit(&ds.data, &engine).unwrap();
        let after = engine.executor().stats.snapshot();
        assert_eq!(fit.order.len(), 5);
        // the shim pays one fused call per step, not 1 + 2·steps
        assert_eq!(after.0 - before.0, 4, "stateless shim call count");
    }
}
