//! Runtime integration: the AOT HLO artifacts load, compile and execute
//! via PJRT, and their numerics match the Rust reference implementations.
//!
//! Requires `make artifacts`.

use alingam::lingam::var::var1_fit;
use alingam::runtime::{artifact_dir, ArtifactKind, ArtifactRegistry, DeviceExecutor, HostArray};
use alingam::sim::{simulate_var, VarSpec};
use alingam::util::rng::Pcg64;

#[test]
fn manifest_loads_and_covers_default_shapes() {
    let reg = ArtifactRegistry::load(&artifact_dir()).expect("run `make artifacts`");
    assert!(!reg.is_empty());
    // the shapes the examples/benches rely on must be servable
    for (n, d) in [(200, 8), (1_000, 10), (4_000, 16), (4_000, 32)] {
        assert!(
            reg.best(ArtifactKind::OrderStep, n, d).is_ok(),
            "no order_step bucket for {n}x{d}"
        );
        assert!(reg.best(ArtifactKind::OrderScores, n, d).is_ok());
    }
    assert!(reg.best(ArtifactKind::VarFit, 500, 16).is_ok());
}

#[test]
fn executor_reports_platform() {
    let exec = DeviceExecutor::start().unwrap();
    let p = exec.platform().unwrap();
    assert!(p.to_lowercase().contains("cpu") || p.contains("Host"), "platform = {p}");
}

#[test]
fn var_fit_artifact_matches_rust_var_fit() {
    let reg = ArtifactRegistry::load(&artifact_dir()).expect("run `make artifacts`");
    let exec = DeviceExecutor::start().unwrap();

    let spec = VarSpec { dim: 12, ..Default::default() };
    let mut rng = Pcg64::seed_from_u64(5);
    let ds = simulate_var(&spec, 400, &mut rng);
    let (t, d) = (ds.data.rows(), ds.data.cols());

    // rust reference
    let (m1_ref, _) = var1_fit(&ds.data).unwrap();

    // artifact path: pad into the bucket
    let bucket = reg.best(ArtifactKind::VarFit, t, d).unwrap();
    let (tb, db) = (bucket.n, bucket.d);
    let mut series = vec![0.0f32; tb * db];
    for r in 0..t {
        for c in 0..d {
            series[r * db + c] = ds.data[(r, c)] as f32;
        }
    }
    let mut row_mask = vec![0.0f32; tb];
    for v in row_mask.iter_mut().take(t) {
        *v = 1.0;
    }
    let outs = exec
        .run(
            bucket.path.clone(),
            vec![
                HostArray::new(vec![tb as i64, db as i64], series),
                HostArray::vector(row_mask),
            ],
        )
        .unwrap();
    let m1_pad = outs[0].f32s().unwrap();
    for i in 0..d {
        for j in 0..d {
            let a = m1_ref[(i, j)];
            let b = m1_pad[i * db + j] as f64;
            assert!(
                (a - b).abs() < 1e-3 * (1.0 + a.abs()),
                "M1[{i},{j}]: rust {a} vs artifact {b}"
            );
        }
    }
}

#[test]
fn executable_cache_compiles_once() {
    let reg = ArtifactRegistry::load(&artifact_dir()).expect("run `make artifacts`");
    let exec = DeviceExecutor::start().unwrap();
    let bucket = reg.best(ArtifactKind::OrderScores, 100, 8).unwrap();

    let run = |exec: &DeviceExecutor| {
        let x = vec![0.5f32; bucket.n * bucket.d];
        let mut rm = vec![0.0f32; bucket.n];
        rm[..50].iter_mut().for_each(|v| *v = 1.0);
        let cm = vec![1.0f32; bucket.d];
        exec.run(
            bucket.path.clone(),
            vec![
                HostArray::new(vec![bucket.n as i64, bucket.d as i64], x),
                HostArray::vector(rm),
                HostArray::vector(cm),
            ],
        )
        .unwrap()
    };
    let t0 = std::time::Instant::now();
    let _ = run(&exec);
    let first = t0.elapsed();
    let t1 = std::time::Instant::now();
    let _ = run(&exec);
    let second = t1.elapsed();
    // second call skips XLA compilation: must be much faster
    assert!(
        second < first / 2,
        "no caching effect: first {first:?}, second {second:?}"
    );
}

#[test]
fn constant_columns_do_not_crash_scores() {
    // degenerate input: zero-variance column (std clamped by STD_EPS)
    let reg = ArtifactRegistry::load(&artifact_dir()).expect("run `make artifacts`");
    let exec = DeviceExecutor::start().unwrap();
    let bucket = reg.best(ArtifactKind::OrderScores, 64, 4).unwrap();
    let mut x = vec![0.0f32; bucket.n * bucket.d];
    for r in 0..64 {
        x[r * bucket.d] = 1.0; // constant column 0
        x[r * bucket.d + 1] = r as f32; // ramp
        x[r * bucket.d + 2] = (r * r % 17) as f32;
        x[r * bucket.d + 3] = (r % 5) as f32;
    }
    let mut rm = vec![0.0f32; bucket.n];
    rm[..64].iter_mut().for_each(|v| *v = 1.0);
    let mut cm = vec![0.0f32; bucket.d];
    cm[..4].iter_mut().for_each(|v| *v = 1.0);
    let outs = exec
        .run(
            bucket.path.clone(),
            vec![
                HostArray::new(vec![bucket.n as i64, bucket.d as i64], x),
                HostArray::vector(rm),
                HostArray::vector(cm),
            ],
        )
        .unwrap();
    let k = outs[0].f32s().unwrap();
    for i in 0..4 {
        assert!(k[i].is_finite(), "k[{i}] = {}", k[i]);
    }
}

#[test]
fn executor_shared_across_threads() {
    use std::sync::Arc;
    let reg = Arc::new(ArtifactRegistry::load(&artifact_dir()).expect("run `make artifacts`"));
    let exec = DeviceExecutor::start().unwrap();
    let bucket = reg.best(ArtifactKind::OrderScores, 100, 8).unwrap().clone();
    std::thread::scope(|s| {
        for t in 0..3 {
            let exec = exec.clone();
            let path = bucket.path.clone();
            let (nb, db) = (bucket.n, bucket.d);
            s.spawn(move || {
                let x = vec![(t as f32) * 0.1 + 0.3; nb * db];
                let mut rm = vec![0.0f32; nb];
                rm[..64].iter_mut().for_each(|v| *v = 1.0);
                let cm = vec![1.0f32; db];
                let outs = exec
                    .run(
                        path,
                        vec![
                            HostArray::new(vec![nb as i64, db as i64], x),
                            HostArray::vector(rm),
                            HostArray::vector(cm),
                        ],
                    )
                    .unwrap();
                assert_eq!(outs[0].f32s().unwrap().len(), db);
            });
        }
    });
}
