//! End-to-end serve integration over real loopback sockets: fit parity
//! with direct `DirectLingam::fit`, the panel-hash cache (submit-time
//! short-circuit and worker-side CSV path), streamed per-step and
//! per-resample progress, ≥ 4 concurrent clients with per-client FIFO
//! completion, cooperative cancellation, error recovery on one
//! connection, graceful drain on shutdown, and the fusion window —
//! concurrent same-shape fits batched through one session with the
//! metrics to prove it, and the worker-side cache short-circuit that
//! answers a tapped twin without leaving a ghost batch slot — the
//! acceptance criteria of the serve subsystem.

use alingam::lingam::{DirectLingam, VectorizedEngine};
use alingam::linalg::Mat;
use alingam::serve::protocol::{self, Json};
use alingam::serve::{ServeConfig, Server};
use alingam::sim::{sample_from_dag, simulate_sem, Noise, SemSpec};
use alingam::util::rng::Pcg64;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};

fn start(workers: usize, queue: usize, cache: usize) -> Server {
    // max_batch 1 disables the fusion window: these tests pin the
    // original one-job-per-session behavior
    start_fused(workers, queue, cache, 0, 1)
}

/// Like [`start`] but with the fusion window enabled.
fn start_fused(workers: usize, queue: usize, cache: usize, wait: u64, batch: usize) -> Server {
    Server::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers,
        queue_capacity: queue,
        cache_entries: cache,
        fuse_wait_ms: wait,
        max_batch: batch,
        ..ServeConfig::default()
    })
    .expect("server start")
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let writer = TcpStream::connect(addr).expect("connect");
        let reader = BufReader::new(writer.try_clone().expect("clone stream"));
        Client { reader, writer }
    }

    fn send(&mut self, line: &str) {
        self.writer.write_all(line.as_bytes()).expect("send");
        self.writer.write_all(b"\n").expect("send newline");
    }

    fn recv(&mut self) -> Json {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).expect("recv");
        assert!(n > 0, "connection closed mid-stream");
        protocol::parse_json(line.trim_end()).expect("server frames must be valid json")
    }

    /// Skip frames until the terminal frame (`result`/`error`/
    /// `canceled`) for `id`; returns `(event, frame)`.
    fn recv_terminal(&mut self, id: &str) -> (String, Json) {
        loop {
            let f = self.recv();
            if f.get("id").and_then(Json::as_str) != Some(id) {
                continue;
            }
            if let Some(ev @ ("result" | "error" | "canceled")) =
                f.get("event").and_then(Json::as_str)
            {
                let ev = ev.to_string();
                return (ev, f);
            }
        }
    }

    /// Skip frames until one whose `event` matches.
    fn recv_event(&mut self, event: &str) -> Json {
        loop {
            let f = self.recv();
            if f.get("event").and_then(Json::as_str) == Some(event) {
                return f;
            }
        }
    }
}

fn chain_panel(n: usize, d: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::seed_from_u64(seed);
    sample_from_dag(&alingam::graph::chain_dag(d, 1.0), Noise::Uniform01, n, &mut rng)
}

fn layered_panel(n: usize, d: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::seed_from_u64(seed);
    simulate_sem(&SemSpec::layered(d, 2, 0.6), n, &mut rng).data
}

fn order_of(frame: &Json) -> Vec<usize> {
    frame
        .get("data")
        .and_then(|d| d.get("order"))
        .and_then(Json::as_arr)
        .expect("result frame carries data.order")
        .iter()
        .map(|v| v.as_usize().expect("order entries are indices"))
        .collect()
}

fn jobs_counter(frame: &Json, key: &str) -> u64 {
    frame
        .get("jobs")
        .and_then(|j| j.get(key))
        .and_then(Json::as_u64)
        .unwrap_or_else(|| panic!("metrics frame missing jobs.{key}"))
}

fn batch_counter(frame: &Json, key: &str) -> u64 {
    frame
        .get("batch")
        .and_then(|b| b.get(key))
        .and_then(Json::as_u64)
        .unwrap_or_else(|| panic!("metrics frame missing batch.{key}"))
}

/// The acceptance criterion: a d=32 chain fit over the socket returns
/// the same causal order as a direct fit with the same engine spec, with
/// per-step progress streamed; a byte-identical second request is served
/// from cache without executing a new job.
#[test]
fn fit_matches_direct_fit_and_byte_identical_request_hits_cache() {
    let server = start(2, 16, 8);
    let panel = chain_panel(1_000, 32, 5);
    let direct = DirectLingam::new().fit(&panel, &VectorizedEngine).unwrap();

    let mut c = Client::connect(server.local_addr());
    let req = protocol::fit_request("f1", "vectorized", &panel);
    c.send(&req);
    let (mut accepted, mut progress) = (0usize, 0usize);
    let frame = loop {
        let f = c.recv();
        match f.get("event").and_then(Json::as_str) {
            Some("accepted") => accepted += 1,
            Some("progress") => {
                assert_eq!(f.get("stage").and_then(Json::as_str), Some("ordering"));
                assert_eq!(f.get("total").and_then(Json::as_usize), Some(31));
                progress += 1;
            }
            Some("result") => break f,
            other => panic!("unexpected event {other:?}"),
        }
    };
    assert_eq!(accepted, 1);
    assert_eq!(progress, 31, "one progress frame per ordering step");
    assert_eq!(frame.get("cached").and_then(Json::as_bool), Some(false));
    assert_eq!(order_of(&frame), direct.order, "serve order must match the direct fit");
    let adj = frame.get("data").and_then(|d| d.get("adjacency")).expect("adjacency");
    let adj = protocol::parse_mat(adj).unwrap();
    assert!(
        alingam::metrics::adjacency_max_diff(&adj, &direct.adjacency) < 1e-12,
        "serve adjacency must match the direct fit"
    );

    c.send(&protocol::control_request("metrics"));
    let m1 = c.recv_event("metrics");
    assert_eq!(jobs_counter(&m1, "completed"), 1);

    // byte-identical replay: served from cache, no new job executed
    c.send(&req);
    let (ev, frame2) = c.recv_terminal("f1");
    assert_eq!(ev, "result");
    assert_eq!(frame2.get("cached").and_then(Json::as_bool), Some(true));
    assert_eq!(order_of(&frame2), direct.order);
    let stats = server.cache_stats();
    assert_eq!(stats.hits, 1, "replay must hit the cache: {stats:?}");
    c.send(&protocol::control_request("metrics"));
    let m2 = c.recv_event("metrics");
    assert_eq!(jobs_counter(&m2, "completed"), 1, "no new job may execute on a cache hit");
    assert_eq!(jobs_counter(&m2, "cache_short_circuits"), 1);
    server.shutdown();
}

/// ≥ 4 concurrent clients with mixed fit/bootstrap traffic: every job
/// completes, and each client's results arrive in its submission order
/// (per-client FIFO), then the server shuts down cleanly.
#[test]
fn four_concurrent_clients_complete_fifo_and_server_drains() {
    let server = start(3, 8, 0);
    let addr = server.local_addr();
    let handles: Vec<_> = (0..4u64)
        .map(|k| {
            std::thread::spawn(move || {
                let mut c = Client::connect(addr);
                let ids: Vec<String> = (0..3).map(|i| format!("c{k}-j{i}")).collect();
                for (i, id) in ids.iter().enumerate() {
                    let seed = 100 + k * 10 + i as u64;
                    if k % 2 == 1 && i == 0 {
                        let panel = layered_panel(200, 4, seed);
                        let req =
                            protocol::bootstrap_request(id, "vectorized", &panel, 4, seed, 0.5);
                        c.send(&req);
                    } else {
                        let panel = layered_panel(250, 5, seed);
                        c.send(&protocol::fit_request(id, "vectorized", &panel));
                    }
                }
                // terminal frames must arrive in submission order
                let mut done = Vec::new();
                while done.len() < ids.len() {
                    let f = c.recv();
                    if let Some(ev @ ("result" | "error" | "canceled")) =
                        f.get("event").and_then(Json::as_str)
                    {
                        assert_eq!(ev, "result", "job failed: {}", f.render());
                        done.push(f.get("id").and_then(Json::as_str).unwrap().to_string());
                    }
                }
                assert_eq!(done, ids, "client {k}: results out of submission order");
            })
        })
        .collect();
    for h in handles {
        h.join().expect("client thread");
    }
    assert_eq!(server.queue_depth(), 0);
    server.shutdown();
}

/// Shutdown drains: jobs already accepted keep running and their
/// results still reach the client before the server exits.
#[test]
fn shutdown_drains_queued_jobs_before_exit() {
    let server = start(1, 8, 0);
    let mut c = Client::connect(server.local_addr());
    let ids = ["d1", "d2", "d3"];
    for (i, id) in ids.iter().enumerate() {
        let panel = layered_panel(250, 5, 40 + i as u64);
        c.send(&protocol::fit_request(id, "vectorized", &panel));
        loop {
            let f = c.recv();
            if f.get("event").and_then(Json::as_str) == Some("accepted")
                && f.get("id").and_then(Json::as_str) == Some(id)
            {
                break;
            }
        }
    }
    // the connection handler processes frames in order, so once the
    // status response arrives every earlier push has returned and all
    // three jobs are owned by the server — shutting down now must drain
    // them, not drop them
    c.send(&protocol::control_request("status"));
    let _ = c.recv_event("status");
    let drainer = std::thread::spawn(move || server.shutdown());
    for id in ids {
        let (ev, _) = c.recv_terminal(id);
        assert_eq!(ev, "result", "queued job {id} must complete during drain");
    }
    drainer.join().expect("shutdown thread");
}

/// Cooperative cancellation: a running bootstrap stops at a resample
/// boundary; a queued fit is dropped before it starts. Both report
/// `canceled`, not `error`.
#[test]
fn cancel_stops_running_and_queued_jobs() {
    let server = start(1, 8, 0);
    let mut c = Client::connect(server.local_addr());
    // heavy bootstrap occupies the single worker...
    let pa = layered_panel(400, 6, 50);
    c.send(&protocol::bootstrap_request("a", "vectorized", &pa, 500, 1, 0.5));
    // ...with a fit queued behind it
    let pb = layered_panel(300, 5, 51);
    c.send(&protocol::fit_request("b", "vectorized", &pb));
    c.send(&protocol::cancel_request("b"));
    c.send(&protocol::cancel_request("a"));
    let (ev_a, _) = c.recv_terminal("a");
    assert_eq!(ev_a, "canceled", "running bootstrap must cancel at a resample boundary");
    let (ev_b, _) = c.recv_terminal("b");
    assert_eq!(ev_b, "canceled", "queued fit must cancel before starting");
    c.send(&protocol::control_request("metrics"));
    let m = c.recv_event("metrics");
    assert_eq!(jobs_counter(&m, "canceled"), 2);
    assert_eq!(jobs_counter(&m, "completed"), 0);
    // canceling an unknown id acks with ok=false instead of erroring
    c.send(&protocol::cancel_request("nope"));
    let ack = c.recv_event("ack");
    assert_eq!(ack.get("ok").and_then(Json::as_bool), Some(false));
    server.shutdown();
}

/// Cancellation is server-wide by job id: a second connection (the
/// one-shot `alingam client cancel`) can cancel a job submitted on the
/// first.
#[test]
fn cancel_works_across_connections() {
    let server = start(1, 8, 0);
    let mut submitter = Client::connect(server.local_addr());
    let panel = layered_panel(400, 6, 60);
    submitter.send(&protocol::bootstrap_request("xc", "vectorized", &panel, 500, 2, 0.5));
    // `accepted` implies the cancel flag is registered server-wide
    let _ = submitter.recv_event("accepted");
    let mut other = Client::connect(server.local_addr());
    other.send(&protocol::cancel_request("xc"));
    let ack = other.recv_event("ack");
    assert_eq!(ack.get("ok").and_then(Json::as_bool), Some(true), "{}", ack.render());
    let (ev, _) = submitter.recv_terminal("xc");
    assert_eq!(ev, "canceled");
    server.shutdown();
}

/// Bootstrap jobs stream one progress frame per completed resample.
#[test]
fn bootstrap_streams_per_resample_progress() {
    let server = start(1, 4, 0);
    let mut c = Client::connect(server.local_addr());
    let panel = layered_panel(250, 4, 3);
    c.send(&protocol::bootstrap_request("bp", "vectorized", &panel, 6, 3, 0.5));
    let mut progress = 0usize;
    let frame = loop {
        let f = c.recv();
        if f.get("id").and_then(Json::as_str) != Some("bp") {
            continue;
        }
        match f.get("event").and_then(Json::as_str) {
            Some("progress") => {
                assert_eq!(f.get("stage").and_then(Json::as_str), Some("bootstrap"));
                progress += 1;
            }
            Some("accepted") => {}
            Some("result") => break f,
            other => panic!("unexpected event {other:?}"),
        }
    };
    assert_eq!(progress, 6, "one progress frame per resample");
    let data = frame.get("data").expect("bootstrap data");
    assert_eq!(data.get("kind").and_then(Json::as_str), Some("bootstrap"));
    assert_eq!(data.get("resamples").and_then(Json::as_usize), Some(6));
    server.shutdown();
}

/// Server-side CSV panels: loaded by the worker, fit matches a direct
/// fit of the same data, and the repeat request hits the worker-side
/// cache lookup (CSV keys are hashed after loading).
#[test]
fn csv_panel_fit_matches_direct_and_caches() {
    let server = start(1, 4, 4);
    let dir = std::env::temp_dir().join("alingam_serve_csv_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("panel.csv");
    let panel = layered_panel(300, 4, 9);
    let header: Vec<String> = (0..4).map(|c| format!("v{c}")).collect();
    alingam::data::write_csv(&path, &header, &panel).unwrap();
    let direct = DirectLingam::new().fit(&panel, &VectorizedEngine).unwrap();

    let mut c = Client::connect(server.local_addr());
    let req = protocol::csv_fit_request("csv1", "vectorized", path.to_str().unwrap());
    c.send(&req);
    let (ev, frame) = c.recv_terminal("csv1");
    assert_eq!(ev, "result", "csv fit failed: {}", frame.render());
    assert_eq!(order_of(&frame), direct.order);
    c.send(&req);
    let (ev2, frame2) = c.recv_terminal("csv1");
    assert_eq!(ev2, "result");
    assert_eq!(frame2.get("cached").and_then(Json::as_bool), Some(true));
    assert!(server.cache_stats().hits >= 1);
    // a missing file is an error frame, not a dead server
    c.send(&protocol::csv_fit_request("csv2", "vectorized", "/nonexistent/panel.csv"));
    let (ev3, _) = c.recv_terminal("csv2");
    assert_eq!(ev3, "error");
    server.shutdown();
}

/// Malformed and invalid frames produce `error` frames and leave the
/// connection (and server) fully serviceable.
#[test]
fn malformed_frames_error_without_killing_the_connection() {
    let server = start(1, 4, 0);
    let mut c = Client::connect(server.local_addr());
    c.send("this is not json");
    let e1 = c.recv_event("error");
    assert!(e1.get("message").and_then(Json::as_str).is_some());
    c.send("{\"cmd\":\"nope\"}");
    let _ = c.recv_event("error");
    // a degenerate panel is rejected by validation as a job error
    let mut bad = layered_panel(50, 3, 7);
    let constant = vec![0.25; 50];
    bad.set_col(1, &constant);
    c.send(&protocol::fit_request("bad1", "vectorized", &bad));
    let (ev, frame) = c.recv_terminal("bad1");
    assert_eq!(ev, "error");
    let msg = frame.get("message").and_then(Json::as_str).unwrap_or_default();
    assert!(msg.contains("constant"), "unexpected message {msg:?}");
    // the connection still answers real requests afterwards
    c.send(&protocol::control_request("status"));
    let s = c.recv_event("status");
    assert_eq!(s.get("workers").and_then(Json::as_usize), Some(1));
    assert_eq!(s.get("accepting").and_then(Json::as_bool), Some(true));
    server.shutdown();
}

/// Partition-engine requests route through the plan layer server-side:
/// the exact merge tier must return the same fit as a direct fit of the
/// same panel, and the blocks-formed / boundary-pair instrumentation
/// must surface in the metrics frame.
#[test]
fn partition_engine_requests_match_direct_and_report_block_metrics() {
    let server = start(1, 4, 0);
    // two independent chains side by side: at n=12_000 the cross-half
    // sample correlations (O(n^{-1/2}) ≈ 0.009) sit far below the 0.05
    // partition threshold, so the halves reliably form two blocks
    let half_a = chain_panel(12_000, 4, 23);
    let half_b = chain_panel(12_000, 4, 24);
    let panel = Mat::from_fn(12_000, 8, |r, c| {
        if c < 4 {
            half_a[(r, c)]
        } else {
            half_b[(r, c - 4)]
        }
    });
    let direct = DirectLingam::new().fit(&panel, &VectorizedEngine).unwrap();
    let mut c = Client::connect(server.local_addr());
    c.send(&protocol::fit_request("pt1", "partition", &panel));
    let (ev, frame) = c.recv_terminal("pt1");
    assert_eq!(ev, "result", "partition fit failed: {}", frame.render());
    assert_eq!(order_of(&frame), direct.order, "partitioned serve order diverged from direct");
    let engine = frame.get("data").and_then(|d| d.get("engine")).and_then(Json::as_str);
    assert_eq!(engine, Some("partition:0"), "result must echo the canonical engine spec");
    let adj = frame.get("data").and_then(|d| d.get("adjacency")).expect("adjacency");
    let adj = protocol::parse_mat(adj).unwrap();
    assert!(
        alingam::metrics::adjacency_max_diff(&adj, &direct.adjacency) < 1e-12,
        "partitioned serve adjacency must match the direct fit"
    );
    c.send(&protocol::control_request("metrics"));
    let m = c.recv_event("metrics");
    let partition = m.get("partition").expect("metrics frame must carry partition counters");
    assert_eq!(
        partition.get("blocks_formed").and_then(Json::as_u64),
        Some(2),
        "two independent chains must book two blocks: {}",
        m.render()
    );
    let boundary = partition.get("boundary_pairs").and_then(Json::as_u64).unwrap();
    assert!(boundary > 0, "exact merge must book the boundary pairs it visited");
    server.shutdown();
}

/// Pruned-engine requests run the bound-pruned sweep server-side and
/// report its counters, while matching the exact engine's order.
#[test]
fn pruned_engine_requests_match_exact_and_report_sweep_savings() {
    let server = start(1, 4, 0);
    let panel = chain_panel(1_500, 16, 21);
    let direct = DirectLingam::new().fit(&panel, &VectorizedEngine).unwrap();
    let mut c = Client::connect(server.local_addr());
    c.send(&protocol::fit_request("p1", "pruned:1", &panel));
    let (ev, frame) = c.recv_terminal("p1");
    assert_eq!(ev, "result", "pruned fit failed: {}", frame.render());
    assert_eq!(order_of(&frame), direct.order, "pruned serve order diverged from exact");
    let sweep = frame.get("data").and_then(|d| d.get("sweep")).expect("sweep counters");
    let total = sweep.get("pairs_total").and_then(Json::as_u64).unwrap();
    let visited = sweep.get("pairs_visited").and_then(Json::as_u64).unwrap();
    assert!(visited < total, "pruned sweep saved no kernel calls: {}", frame.render());
    server.shutdown();
}

/// The fusion window: two same-shape fits from different clients
/// arriving within the window run through one batched session — the
/// metrics frame books exactly one batch of two — while returning the
/// same orders as direct fits, streaming per-step progress, and never
/// reordering a client's own results.
#[test]
fn concurrent_same_shape_fits_fuse_into_one_batched_session() {
    let server = start_fused(1, 16, 0, 500, 4);
    let addr = server.local_addr();
    let p1 = layered_panel(300, 6, 70);
    let p2 = layered_panel(300, 6, 71);
    let p3 = layered_panel(250, 5, 72); // different shape: never fuses
    let d1 = DirectLingam::new().fit(&p1, &VectorizedEngine).unwrap();
    let d2 = DirectLingam::new().fit(&p2, &VectorizedEngine).unwrap();
    let d3 = DirectLingam::new().fit(&p3, &VectorizedEngine).unwrap();
    let mut c1 = Client::connect(addr);
    let mut c2 = Client::connect(addr);
    c1.send(&protocol::fit_request("f1", "vectorized", &p1));
    let _ = c1.recv_event("accepted");
    // the single worker holds f1 in its fusion window for up to 500 ms;
    // f2 lands well inside it, f3 (a different shape) must run alone
    c2.send(&protocol::fit_request("f2", "vectorized", &p2));
    c1.send(&protocol::fit_request("f3", "vectorized", &p3));

    // collect c1's terminal frames in arrival order: per-client FIFO
    // must survive fusion
    let mut order1 = Vec::new();
    let mut frames1 = Vec::new();
    let mut progress_f1 = 0usize;
    while frames1.len() < 2 {
        let f = c1.recv();
        match f.get("event").and_then(Json::as_str) {
            Some("result") => {
                order1.push(f.get("id").and_then(Json::as_str).unwrap().to_string());
                frames1.push(f);
            }
            Some("error" | "canceled") => panic!("job failed: {}", f.render()),
            Some("progress") if f.get("id").and_then(Json::as_str) == Some("f1") => {
                assert_eq!(f.get("stage").and_then(Json::as_str), Some("ordering"));
                progress_f1 += 1;
            }
            _ => {}
        }
    }
    assert_eq!(order1, ["f1", "f3"], "fusion reordered a client's results");
    assert_eq!(progress_f1, 5, "fused fits must stream one progress frame per step");
    assert_eq!(order_of(&frames1[0]), d1.order, "fused fit diverged from the direct fit");
    assert_eq!(frames1[0].get("cached").and_then(Json::as_bool), Some(false));
    assert_eq!(order_of(&frames1[1]), d3.order);
    let (ev2, f2) = c2.recv_terminal("f2");
    assert_eq!(ev2, "result");
    assert_eq!(order_of(&f2), d2.order, "fused fit diverged from the direct fit");

    c1.send(&protocol::control_request("metrics"));
    let m = c1.recv_event("metrics");
    assert_eq!(batch_counter(&m, "batches_dispatched"), 1, "{}", m.render());
    assert_eq!(batch_counter(&m, "jobs_fused"), 2, "{}", m.render());
    let occupancy = m.get("batch").and_then(|b| b.get("mean_occupancy")).and_then(Json::as_f64);
    assert_eq!(occupancy, Some(2.0), "{}", m.render());
    let _ = batch_counter(&m, "fuse_wait_ms_total"); // the window wait is booked
    assert_eq!(jobs_counter(&m, "completed"), 3);
    server.shutdown();
}

/// The worker-side cache short-circuit inside the fusion window: a
/// queued twin of a just-cached fit is answered from the cache the
/// moment the window taps it and leaves no ghost slot behind — the
/// leader proceeds alone and no batch is booked.
#[test]
fn cache_hit_peer_is_answered_in_the_window_without_a_ghost_slot() {
    let server = start_fused(1, 16, 8, 300, 2);
    let addr = server.local_addr();
    let px = chain_panel(4_000, 32, 80);
    let pz = chain_panel(4_000, 32, 81);
    let direct_x = DirectLingam::new().fit(&px, &VectorizedEngine).unwrap();
    let direct_z = DirectLingam::new().fit(&pz, &VectorizedEngine).unwrap();
    let mut c1 = Client::connect(addr);
    let mut c2 = Client::connect(addr);
    c1.send(&protocol::fit_request("warm", "vectorized", &px));
    // wait until the warmup is *executing* (first ordering step done):
    // nothing is cached yet, so the twin below must pass the submit-time
    // cache check and reach the queue
    loop {
        let f = c1.recv();
        if f.get("event").and_then(Json::as_str) == Some("progress") {
            break;
        }
    }
    // one lane, two jobs: the fresh leader first, its cached twin behind
    c2.send(&protocol::fit_request("lead", "vectorized", &pz));
    c2.send(&protocol::fit_request("twin", "vectorized", &px));
    let (ev_w, _) = c1.recv_terminal("warm");
    assert_eq!(ev_w, "result");
    // px is cached now; the worker pops `lead`, opens its window, taps
    // `twin`, and must answer it from the cache immediately instead of
    // letting it occupy a batch slot
    let (ev_t, twin) = c2.recv_terminal("twin");
    assert_eq!(ev_t, "result");
    assert_eq!(twin.get("cached").and_then(Json::as_bool), Some(true), "{}", twin.render());
    assert_eq!(order_of(&twin), direct_x.order);
    let (ev_l, lead) = c2.recv_terminal("lead");
    assert_eq!(ev_l, "result");
    assert_eq!(lead.get("cached").and_then(Json::as_bool), Some(false));
    assert_eq!(order_of(&lead), direct_z.order);
    c2.send(&protocol::control_request("metrics"));
    let m = c2.recv_event("metrics");
    // the twin reached the worker (no submit-time short-circuit), was
    // answered mid-window, and the leader ran alone: no batch booked
    assert_eq!(jobs_counter(&m, "cache_short_circuits"), 0, "{}", m.render());
    assert_eq!(jobs_counter(&m, "completed"), 3);
    assert_eq!(batch_counter(&m, "batches_dispatched"), 0, "{}", m.render());
    assert_eq!(batch_counter(&m, "jobs_fused"), 0);
    assert!(server.cache_stats().hits >= 1, "{:?}", server.cache_stats());
    server.shutdown();
}
