//! Observability integration over real sockets: a fit answered over
//! HTTP carries a `timing` object whose span durations sum to the job's
//! observed wall clock, `GET /trace/<t>` replays the same spans (by
//! trace id and by job id), the JSON metrics frame and the Prometheus
//! exposition are complete over mixed fit/cache-hit/bootstrap/watch/
//! cancel traffic, and a 2-shard fleet merges per-child histograms and
//! relays trace lookups through the front.

use alingam::linalg::Mat;
use alingam::serve::protocol::{self, Json};
use alingam::serve::{ServeConfig, Server};
use alingam::sim::{sample_from_dag, Noise};
use alingam::util::rng::Pcg64;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Instant;

fn start(workers: usize, cache: usize, http: bool) -> Server {
    Server::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers,
        queue_capacity: 16,
        cache_entries: cache,
        fuse_wait_ms: 0,
        max_batch: 1,
        http_addr: http.then(|| "127.0.0.1:0".to_string()),
        ..ServeConfig::default()
    })
    .expect("server start")
}

fn chain_panel(n: usize, d: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::seed_from_u64(seed);
    sample_from_dag(&alingam::graph::chain_dag(d, 1.0), Noise::Uniform01, n, &mut rng)
}

// ------------------------------------------------------ socket helpers

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let stream = TcpStream::connect(addr).expect("connect");
        let reader = BufReader::new(stream.try_clone().expect("clone stream"));
        Client { reader, writer: stream }
    }

    fn send(&mut self, line: &str) {
        self.writer.write_all(line.as_bytes()).expect("send");
        self.writer.write_all(b"\n").expect("send");
    }

    fn recv(&mut self) -> Json {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).expect("recv");
        assert!(n > 0, "connection closed mid-stream");
        protocol::parse_json(line.trim_end()).expect("server frames must be valid json")
    }

    fn recv_terminal(&mut self, id: &str) -> (String, Json) {
        loop {
            let f = self.recv();
            if f.get("id").and_then(Json::as_str) != Some(id) {
                continue;
            }
            if let Some(ev @ ("result" | "error" | "canceled")) =
                f.get("event").and_then(Json::as_str)
            {
                let ev = ev.to_string();
                return (ev, f);
            }
        }
    }

    fn recv_event(&mut self, event: &str) -> Json {
        loop {
            let f = self.recv();
            if f.get("event").and_then(Json::as_str) == Some(event) {
                return f;
            }
        }
    }
}

fn http_exchange(addr: SocketAddr, request: &str) -> String {
    let mut stream = TcpStream::connect(addr).expect("connect http");
    stream.write_all(request.as_bytes()).expect("send http request");
    let mut response = String::new();
    stream.read_to_string(&mut response).expect("read http response");
    response
}

fn http_get(addr: SocketAddr, path: &str) -> String {
    http_exchange(addr, &format!("GET {path} HTTP/1.1\r\nHost: test\r\n\r\n"))
}

fn http_post(addr: SocketAddr, path: &str, body: &str) -> String {
    http_exchange(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: test\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    )
}

fn status_line(response: &str) -> &str {
    response.lines().next().unwrap_or("")
}

fn response_body(response: &str) -> &str {
    response.split("\r\n\r\n").nth(1).unwrap_or("")
}

fn sse_frames(response: &str) -> Vec<Json> {
    response_body(response)
        .lines()
        .filter_map(|l| l.strip_prefix("data: "))
        .map(|l| protocol::parse_json(l).expect("sse events must be valid frames"))
        .collect()
}

fn event_of(frame: &Json) -> &str {
    frame.get("event").and_then(Json::as_str).unwrap_or("")
}

/// Sum of the `ms` fields across a timing/trace `spans` array.
fn span_ms_sum(spans: &Json) -> f64 {
    spans
        .as_arr()
        .expect("spans array")
        .iter()
        .map(|s| s.get("ms").and_then(Json::as_f64).expect("span ms"))
        .sum()
}

// ------------------------------------------------- timing + trace route

/// The tentpole acceptance criterion: a fit over HTTP returns a
/// `timing` object whose span durations sum (within 5%) to the job's
/// observed wall clock, and `GET /trace/<id>` replays the same spans —
/// addressable by trace id and by job id.
#[test]
fn http_fit_timing_sums_to_wall_clock_and_trace_route_replays_it() {
    let server = start(1, 8, true);
    let http = server.http_local_addr().expect("http listener");
    let body = protocol::fit_request("t1", "vectorized", &chain_panel(500, 8, 11));

    let wall_start = Instant::now();
    let resp = http_post(http, "/fit", &body);
    let wall_ms = wall_start.elapsed().as_secs_f64() * 1e3;
    assert!(status_line(&resp).starts_with("HTTP/1.1 200"), "got {}", status_line(&resp));

    let frames = sse_frames(&resp);
    let result = frames.last().expect("terminal frame");
    assert_eq!(event_of(result), "result");
    let timing = result.get("timing").expect("result frame must carry a timing object");
    let trace_hex = timing.get("trace").and_then(Json::as_str).expect("trace id").to_string();
    assert_eq!(trace_hex.len(), 32, "trace ids are 128-bit lowercase hex");
    let total_ms = timing.get("total_ms").and_then(Json::as_f64).expect("total_ms");
    assert!(total_ms > 0.0, "a real fit takes measurable time");
    // the job's wall clock (submit → terminal flush) is bounded by the
    // client-observed exchange, and the spans partition it: their sum
    // must land within 5% of the observed total
    assert!(
        total_ms <= wall_ms + 5.0,
        "job wall {total_ms}ms cannot exceed the client-observed {wall_ms}ms"
    );
    let sum_ms = span_ms_sum(timing.get("spans").expect("spans"));
    let drift = (sum_ms - total_ms).abs();
    assert!(
        drift <= 0.05 * total_ms + 0.1,
        "span sum {sum_ms}ms must be within 5% of the observed wall {total_ms}ms"
    );
    let names: Vec<&str> = timing
        .get("spans")
        .and_then(Json::as_arr)
        .expect("spans array")
        .iter()
        .map(|s| s.get("span").and_then(Json::as_str).unwrap_or(""))
        .collect();
    assert!(names.contains(&"order_step"), "fit timing must attribute ordering steps: {names:?}");
    assert!(names.contains(&"queue_wait"), "fit timing must attribute queue wait: {names:?}");

    // replay by trace id: the same spans come back from the trace ring
    let resp = http_get(http, &format!("/trace/{trace_hex}"));
    assert!(status_line(&resp).starts_with("HTTP/1.1 200"), "got {}", status_line(&resp));
    let replay = protocol::parse_json(response_body(&resp).trim()).expect("trace json");
    assert_eq!(event_of(&replay), "trace");
    assert_eq!(replay.get("found").and_then(Json::as_bool), Some(true));
    assert_eq!(replay.get("trace").and_then(Json::as_str), Some(trace_hex.as_str()));
    assert_eq!(replay.get("job").and_then(Json::as_str), Some("t1"));
    assert_eq!(
        replay.get("spans").expect("replayed spans").render(),
        timing.get("spans").expect("timing spans").render(),
        "the trace route must replay exactly the spans attached to the result frame"
    );

    // the job id is an alias for the latest trace under that id
    let resp = http_get(http, "/trace/t1");
    let by_job = protocol::parse_json(response_body(&resp).trim()).expect("trace json");
    assert_eq!(by_job.get("trace").and_then(Json::as_str), Some(trace_hex.as_str()));

    // unknown ids answer 404 with a found:false body
    let resp = http_get(http, "/trace/no-such-job");
    assert!(status_line(&resp).starts_with("HTTP/1.1 404"), "got {}", status_line(&resp));
    let miss = protocol::parse_json(response_body(&resp).trim()).expect("miss json");
    assert_eq!(miss.get("found").and_then(Json::as_bool), Some(false));
    server.shutdown();
}

/// The same trace is queryable over the TCP protocol (`trace` request),
/// and a cache-short-circuited job still gets a trace (no spans beyond
/// the probe, but a real record).
#[test]
fn tcp_trace_request_finds_jobs_and_cache_hits_get_traces_too() {
    let server = start(1, 8, false);
    let mut c = Client::connect(server.local_addr());
    let panel = chain_panel(400, 6, 12);
    c.send(&protocol::fit_request("q1", "vectorized", &panel));
    let (ev, first) = c.recv_terminal("q1");
    assert_eq!(ev, "result");
    let first_timing = first.get("timing").expect("timing");
    let first_trace = first_timing.get("trace").and_then(Json::as_str).unwrap().to_string();

    // byte-identical re-fit: answered from the cache, with its own trace
    c.send(&protocol::fit_request("q2", "vectorized", &panel));
    let (ev, second) = c.recv_terminal("q2");
    assert_eq!(ev, "result");
    assert_eq!(second.get("cached").and_then(Json::as_bool), Some(true));
    let second_timing = second.get("timing").expect("cache hits carry timing too");
    let second_trace = second_timing.get("trace").and_then(Json::as_str).unwrap().to_string();
    assert_ne!(first_trace, second_trace, "every submission mints a fresh trace");

    c.send(&protocol::trace_request(&first_trace));
    let t = c.recv_event("trace");
    assert_eq!(t.get("found").and_then(Json::as_bool), Some(true));
    assert_eq!(t.get("job").and_then(Json::as_str), Some("q1"));

    // by job id the ring answers the *latest* trace for that id
    c.send(&protocol::trace_request("q2"));
    let t = c.recv_event("trace");
    assert_eq!(t.get("trace").and_then(Json::as_str), Some(second_trace.as_str()));

    c.send(&protocol::trace_request("missing"));
    let t = c.recv_event("trace");
    assert_eq!(t.get("found").and_then(Json::as_bool), Some(false));
    assert_eq!(t.get("target").and_then(Json::as_str), Some("missing"));
    server.shutdown();
}

// ------------------------------------------------ metrics completeness

/// Drive fit / cache-hit / bootstrap / watch / cancel traffic, then
/// scrape both the JSON metrics frame and the Prometheus exposition and
/// assert every observability family is present and populated.
#[test]
fn metrics_and_prometheus_are_complete_over_mixed_traffic() {
    // cache_entries=1 forces a real eviction (satellite: the eviction
    // age total must make mean_eviction_age_ms computable)
    let server = start(1, 1, true);
    let http = server.http_local_addr().expect("http listener");
    let mut c = Client::connect(server.local_addr());

    let p1 = chain_panel(300, 5, 21);
    c.send(&protocol::fit_request("f1", "vectorized", &p1));
    assert_eq!(c.recv_terminal("f1").0, "result");
    c.send(&protocol::fit_request("f2", "vectorized", &p1)); // cache hit
    let (_, f2) = c.recv_terminal("f2");
    assert_eq!(f2.get("cached").and_then(Json::as_bool), Some(true));
    let p2 = chain_panel(300, 5, 22);
    c.send(&protocol::fit_request("f3", "vectorized", &p2)); // evicts p1
    assert_eq!(c.recv_terminal("f3").0, "result");
    c.send(&protocol::bootstrap_request("b1", "vectorized", &p2, 4, 7, 0.5));
    assert_eq!(c.recv_terminal("b1").0, "result");

    // cancel: a queued fit behind a running bootstrap is dropped
    c.send(&protocol::bootstrap_request("b2", "vectorized", &chain_panel(400, 6, 23), 500, 1, 0.5));
    c.send(&protocol::fit_request("c1", "vectorized", &chain_panel(300, 5, 24)));
    c.send(&protocol::cancel_request("c1"));
    c.send(&protocol::cancel_request("b2"));
    assert_eq!(c.recv_terminal("b2").0, "canceled");
    assert_eq!(c.recv_terminal("c1").0, "canceled");

    // watch: subscribe, stream a window's worth of rows, end
    let rows = chain_panel(12, 3, 25);
    let mut w = Client::connect(server.local_addr());
    w.send(&protocol::watch_request("w1", "vectorized", 3, 8, 0, 0, 1e-3, 0.05));
    let _ = w.recv_event("accepted");
    for i in 0..rows.rows() {
        let row: Vec<f64> = (0..3).map(|j| rows[(i, j)]).collect();
        w.send(&protocol::watch_frame_request("w1", &row));
    }
    w.send(&protocol::watch_end_request("w1"));
    let (ev, _) = w.recv_terminal("w1");
    assert_eq!(ev, "result", "a drained watch stream ends in a result summary");

    // ---- JSON metrics frame
    c.send(&protocol::control_request("metrics"));
    let m = c.recv_event("metrics");
    assert!(m.get("start_unix_ms").and_then(Json::as_u64).unwrap_or(0) > 0);
    assert!(m.get("uptime_ms").and_then(Json::as_u64).is_some());
    let jobs = m.get("jobs").expect("jobs object");
    assert!(jobs.get("completed").and_then(Json::as_u64).unwrap_or(0) >= 4);
    assert!(jobs.get("canceled").and_then(Json::as_u64).unwrap_or(0) >= 2);
    assert!(jobs.get("cache_short_circuits").and_then(Json::as_u64).unwrap_or(0) >= 1);
    let cache = m.get("cache").expect("cache object");
    assert!(cache.get("evictions").and_then(Json::as_u64).unwrap_or(0) >= 1);
    assert!(
        cache.get("mean_eviction_age_ms").and_then(Json::as_f64).is_some(),
        "mean eviction age must be computable: {}",
        cache.render()
    );
    let obs = m.get("obs").expect("obs histograms object");
    for hist in ["job_latency", "queue_wait", "step", "watch_frame"] {
        let h = obs.get(hist).unwrap_or_else(|| panic!("missing obs.{hist}"));
        assert!(
            h.get("count").and_then(Json::as_u64).unwrap_or(0) > 0,
            "obs.{hist} must have observations: {}",
            h.render()
        );
        assert!(h.get("p50_us").and_then(Json::as_u64).is_some(), "obs.{hist} p50");
        assert!(h.get("p99_us").and_then(Json::as_u64).is_some(), "obs.{hist} p99");
    }

    // status frame carries the uptime fields too (satellite b)
    c.send(&protocol::control_request("status"));
    let s = c.recv_event("status");
    assert!(s.get("start_unix_ms").and_then(Json::as_u64).unwrap_or(0) > 0);
    assert!(s.get("uptime_ms").and_then(Json::as_u64).is_some());

    // ---- Prometheus exposition
    let resp = http_get(http, "/metrics?format=prometheus");
    assert!(status_line(&resp).starts_with("HTTP/1.1 200"), "got {}", status_line(&resp));
    assert!(resp.contains("Content-Type: text/plain; version=0.0.4"));
    let text = response_body(&resp);
    for needle in [
        "# TYPE alingam_jobs_completed_total counter",
        "# TYPE alingam_job_latency_seconds summary",
        "alingam_job_latency_seconds{quantile=\"0.5\"}",
        "alingam_job_latency_seconds{quantile=\"0.95\"}",
        "alingam_job_latency_seconds{quantile=\"0.99\"}",
        "alingam_job_latency_seconds_count",
        "alingam_queue_wait_seconds{quantile=\"0.5\"}",
        "alingam_step_seconds{quantile=\"0.5\"}",
        "alingam_watch_frame_seconds{quantile=\"0.5\"}",
        "alingam_cache_evictions_total",
        "alingam_cache_eviction_age_seconds_total",
        "alingam_uptime_seconds",
        "alingam_start_time_seconds",
        "alingam_jobs_canceled_total",
    ] {
        assert!(text.contains(needle), "prometheus text missing {needle:?}:\n{text}");
    }
    // quantiles carry real observations, not zeros
    let count_line = text
        .lines()
        .find(|l| l.starts_with("alingam_job_latency_seconds_count"))
        .expect("job latency count sample");
    let count: f64 =
        count_line.split_whitespace().nth(1).expect("sample value").parse().expect("float");
    assert!(count >= 4.0, "job latency histogram must cover the completed jobs: {count_line}");

    // plain GET /metrics (no query) still answers the JSON frame
    let resp = http_get(http, "/metrics");
    assert!(resp.contains("Content-Type: application/json"));
    assert_eq!(
        protocol::parse_json(response_body(&resp).trim()).map(|f| event_of(&f).to_string()).ok(),
        Some("metrics".to_string())
    );
    server.shutdown();
}

// -------------------------------------------------------- fleet merge

/// Through a 2-shard fleet: the front's Prometheus exposition is the
/// snapshot-merge of per-child histograms (count covers every job run
/// anywhere in the fleet), fleet gauges are present, and `GET
/// /trace/<id>` relays the owning shard's trace verbatim.
#[cfg(unix)]
#[test]
fn fleet_front_merges_histograms_and_relays_traces() {
    use alingam::serve::shard::Supervisor;

    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 1,
        queue_capacity: 16,
        cache_entries: 8,
        fuse_wait_ms: 0,
        max_batch: 1,
        http_addr: Some("127.0.0.1:0".to_string()),
        ..ServeConfig::default()
    };
    let exe = std::path::PathBuf::from(env!("CARGO_BIN_EXE_alingam"));
    let sup = Supervisor::start(cfg, 2, Some(exe)).expect("fleet start");
    let http = sup.http_local_addr().expect("fleet http front");

    // several distinct panels so the panel-hash router exercises shards
    let mut traces = Vec::new();
    for (i, seed) in [31u64, 32, 33, 34].iter().enumerate() {
        let id = format!("fl{i}");
        let mut c = Client::connect(sup.local_addr());
        c.send(&protocol::fit_request(&id, "vectorized", &chain_panel(400, 6, *seed)));
        let (ev, frame) = c.recv_terminal(&id);
        assert_eq!(ev, "result", "fleet fit {id} failed: {}", frame.render());
        let timing = frame.get("timing").expect("fleet results relay timing");
        traces.push((
            id,
            timing.get("trace").and_then(Json::as_str).expect("trace id").to_string(),
        ));
    }

    // trace relay: the front fans the lookup out to the owning shard —
    // by trace id over HTTP, by job id over TCP
    let (job, trace_hex) = &traces[0];
    let resp = http_get(http, &format!("/trace/{trace_hex}"));
    assert!(status_line(&resp).starts_with("HTTP/1.1 200"), "got {}", status_line(&resp));
    let replay = protocol::parse_json(response_body(&resp).trim()).expect("trace json");
    assert_eq!(replay.get("found").and_then(Json::as_bool), Some(true));
    assert_eq!(replay.get("job").and_then(Json::as_str), Some(job.as_str()));
    assert!(replay.get("spans").and_then(Json::as_arr).is_some_and(|s| !s.is_empty()));

    let mut c = Client::connect(sup.local_addr());
    c.send(&protocol::trace_request(job));
    let t = c.recv_event("trace");
    assert_eq!(t.get("found").and_then(Json::as_bool), Some(true));
    c.send(&protocol::trace_request("nowhere"));
    let t = c.recv_event("trace");
    assert_eq!(t.get("found").and_then(Json::as_bool), Some(false));

    // merged Prometheus: job-latency count covers jobs run on *both*
    // shards (4 distinct panels over 2 shards), fleet gauges present
    let resp = http_get(http, "/metrics?format=prometheus");
    assert!(status_line(&resp).starts_with("HTTP/1.1 200"), "got {}", status_line(&resp));
    let text = response_body(&resp);
    for needle in [
        "alingam_job_latency_seconds{quantile=\"0.5\"}",
        "alingam_job_latency_seconds_count",
        "alingam_queue_wait_seconds_count",
        "alingam_step_seconds_count",
        "alingam_shards 2",
        "alingam_shards_live 2",
        "# TYPE alingam_shard_restarts_total counter",
        "alingam_start_time_seconds",
    ] {
        assert!(text.contains(needle), "fleet prometheus missing {needle:?}:\n{text}");
    }
    let count_line = text
        .lines()
        .find(|l| l.starts_with("alingam_job_latency_seconds_count"))
        .expect("merged job latency count");
    let count: f64 =
        count_line.split_whitespace().nth(1).expect("sample value").parse().expect("float");
    assert!(count >= 4.0, "merged histogram must cover all fleet jobs: {count_line}");
    assert!(sup.shutdown_within(std::time::Duration::from_secs(60)), "fleet drains cleanly");
}
