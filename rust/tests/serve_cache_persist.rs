//! Disk-persistent result cache integration: a byte-identical re-fit
//! after a full server restart is answered from the recovered disk
//! segment without executing a job; a torn segment tail is dropped
//! cleanly (no panic, intact prefix recovered); and the eviction-age
//! metric grows monotonically with real entry ages.

use alingam::linalg::Mat;
use alingam::serve::cache::{ResultCache, SEG_FILE};
use alingam::serve::protocol::{self, Json};
use alingam::serve::{ServeConfig, Server};
use alingam::sim::{sample_from_dag, Noise};
use alingam::util::rng::Pcg64;
use std::io::{BufRead, BufReader, Write};
use std::path::PathBuf;
use std::sync::Arc;
use std::time::Duration;

fn temp_dir(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!("alingam-cache-{}-{name}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).expect("create temp dir");
    dir
}

fn start_with_dir(dir: &PathBuf) -> Server {
    Server::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 1,
        queue_capacity: 16,
        cache_entries: 8,
        fuse_wait_ms: 0,
        max_batch: 1,
        cache_dir: Some(dir.clone()),
        ..ServeConfig::default()
    })
    .expect("server start")
}

fn chain_panel(n: usize, d: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::seed_from_u64(seed);
    sample_from_dag(&alingam::graph::chain_dag(d, 1.0), Noise::Uniform01, n, &mut rng)
}

/// Send one frame, read frames until the terminal one for `id`.
fn roundtrip(server: &Server, line: &str, id: &str) -> Json {
    let mut stream = std::net::TcpStream::connect(server.local_addr()).expect("connect");
    stream.write_all(line.as_bytes()).expect("send");
    stream.write_all(b"\n").expect("send");
    let mut reader = BufReader::new(stream);
    loop {
        let mut buf = String::new();
        assert!(reader.read_line(&mut buf).expect("recv") > 0, "closed mid-stream");
        let f = protocol::parse_json(buf.trim_end()).expect("frame json");
        if f.get("id").and_then(Json::as_str) != Some(id) {
            continue;
        }
        if matches!(
            f.get("event").and_then(Json::as_str),
            Some("result" | "error" | "canceled")
        ) {
            return f;
        }
    }
}

fn one_frame(server: &Server, line: &str) -> Json {
    let mut stream = std::net::TcpStream::connect(server.local_addr()).expect("connect");
    stream.write_all(line.as_bytes()).expect("send");
    stream.write_all(b"\n").expect("send");
    let mut reader = BufReader::new(stream);
    let mut buf = String::new();
    assert!(reader.read_line(&mut buf).expect("recv") > 0);
    protocol::parse_json(buf.trim_end()).expect("frame json")
}

/// The acceptance criterion: fit, restart the server on the same
/// `--cache-dir`, and the byte-identical re-fit is a disk hit — no job
/// executed, `cached:true`, and the recovery booked in metrics.
#[test]
fn byte_identical_refit_survives_a_server_restart() {
    let dir = temp_dir("restart");
    let panel = chain_panel(400, 6, 17);
    let req = protocol::fit_request("p1", "vectorized", &panel);

    let first = start_with_dir(&dir);
    let frame = roundtrip(&first, &req, "p1");
    assert_eq!(frame.get("event").and_then(Json::as_str), Some("result"));
    assert_eq!(frame.get("cached").and_then(Json::as_bool), Some(false));
    let data_before = frame.get("data").expect("data").render();
    first.shutdown();
    assert!(dir.join(SEG_FILE).exists(), "the segment file must be on disk after shutdown");

    let second = start_with_dir(&dir);
    let frame = roundtrip(&second, &req, "p1");
    assert_eq!(frame.get("event").and_then(Json::as_str), Some("result"));
    assert_eq!(
        frame.get("cached").and_then(Json::as_bool),
        Some(true),
        "the re-fit must be answered from the recovered cache"
    );
    assert_eq!(
        frame.get("data").expect("data").render(),
        data_before,
        "recovered payload must be byte-identical to the original"
    );

    let metrics = one_frame(&second, &protocol::control_request("metrics"));
    let jobs = metrics.get("jobs").expect("jobs object");
    assert_eq!(
        jobs.get("completed").and_then(Json::as_u64),
        Some(0),
        "no job may execute for a disk-recovered hit"
    );
    assert_eq!(jobs.get("cache_short_circuits").and_then(Json::as_u64), Some(1));
    let cache = metrics.get("cache").expect("cache object");
    assert!(cache.get("recovered").and_then(Json::as_u64).unwrap_or(0) >= 1);
    assert!(cache.get("disk_hits").and_then(Json::as_u64).unwrap_or(0) >= 1);
    second.shutdown();
    let _ = std::fs::remove_dir_all(&dir);
}

/// Crash tolerance: a torn (truncated) final record is dropped at open
/// — the intact prefix is recovered, nothing panics.
#[test]
fn truncated_segment_tail_recovers_the_intact_prefix() {
    let dir = temp_dir("torn");
    {
        let cache = ResultCache::with_dir(8, &dir).expect("open cache");
        cache.put(1, Arc::new("\"one\"".to_string()));
        cache.put(2, Arc::new("\"two\"".to_string()));
        cache.put(3, Arc::new("\"three\"".to_string()));
    }
    // simulate a crash mid-append: chop bytes off the last record
    let path = dir.join(SEG_FILE);
    let bytes = std::fs::read(&path).expect("read segment");
    assert!(bytes.len() > 5);
    std::fs::write(&path, &bytes[..bytes.len() - 5]).expect("truncate segment");

    let cache = ResultCache::with_dir(8, &dir).expect("reopen survives a torn tail");
    let stats = cache.stats();
    assert_eq!(stats.recovered, 2, "the two intact records are recovered");
    assert_eq!(cache.get(1).as_deref().map(String::as_str), Some("\"one\""));
    assert_eq!(cache.get(2).as_deref().map(String::as_str), Some("\"two\""));
    assert!(cache.get(3).is_none(), "the torn record is gone");
    assert_eq!(cache.stats().disk_hits, 2, "recovered-entry hits count as disk hits");

    // a fresh put after recovery persists alongside the compacted prefix
    cache.put(4, Arc::new("\"four\"".to_string()));
    drop(cache);
    let cache = ResultCache::with_dir(8, &dir).expect("reopen after recovery append");
    assert_eq!(cache.stats().recovered, 3);
    assert_eq!(cache.get(4).as_deref().map(String::as_str), Some("\"four\""));
    let _ = std::fs::remove_dir_all(&dir);
}

/// A corrupted byte inside the tail record (length intact, checksum
/// wrong) is also dropped — the digest catches it.
#[test]
fn corrupt_tail_record_fails_its_checksum_and_is_dropped() {
    let dir = temp_dir("corrupt");
    {
        let cache = ResultCache::with_dir(8, &dir).expect("open cache");
        cache.put(10, Arc::new("\"aa\"".to_string()));
        cache.put(11, Arc::new("\"bb\"".to_string()));
    }
    let path = dir.join(SEG_FILE);
    let mut bytes = std::fs::read(&path).expect("read segment");
    // flip a bit inside the final record's payload region
    let n = bytes.len();
    bytes[n - 20] ^= 0x40;
    std::fs::write(&path, &bytes).expect("corrupt segment");

    let cache = ResultCache::with_dir(8, &dir).expect("reopen survives corruption");
    assert_eq!(cache.stats().recovered, 1, "only the intact record survives");
    assert_eq!(cache.get(10).as_deref().map(String::as_str), Some("\"aa\""));
    assert!(cache.get(11).is_none());
    let _ = std::fs::remove_dir_all(&dir);
}

/// Eviction-age metric: grows monotonically, and by at least the time
/// an evicted entry demonstrably lived.
#[test]
fn eviction_age_metric_is_monotone_and_reflects_entry_age() {
    let cache = ResultCache::new(2);
    cache.put(1, Arc::new("a".to_string()));
    std::thread::sleep(Duration::from_millis(25));
    cache.put(2, Arc::new("b".to_string()));
    assert_eq!(cache.stats().eviction_age_ms_total, 0, "nothing evicted yet");

    cache.put(3, Arc::new("c".to_string())); // evicts key 1, aged ≥ 25ms
    let s1 = cache.stats();
    assert_eq!(s1.evictions, 1);
    assert!(
        s1.eviction_age_ms_total >= 20,
        "evicted entry lived ≥ 25ms, booked {}ms",
        s1.eviction_age_ms_total
    );

    std::thread::sleep(Duration::from_millis(10));
    cache.put(4, Arc::new("d".to_string())); // evicts key 2
    let s2 = cache.stats();
    assert_eq!(s2.evictions, 2);
    assert!(
        s2.eviction_age_ms_total >= s1.eviction_age_ms_total,
        "age total must be monotone: {} then {}",
        s1.eviction_age_ms_total,
        s2.eviction_age_ms_total
    );
}
