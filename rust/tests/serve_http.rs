//! HTTP front + shard fleet integration over real sockets: `POST /fit`
//! streams SSE frames whose `data` payload is byte-identical to the TCP
//! fit path, a repeat fit is a cache hit, control routes answer JSON,
//! malformed requests get real HTTP statuses, and a 2-shard fleet
//! (child processes of the real `alingam` binary) keeps serving after
//! one shard is killed — with the restart booked in `metrics`.

use alingam::lingam::{DirectLingam, VectorizedEngine};
use alingam::linalg::Mat;
use alingam::serve::protocol::{self, Json};
use alingam::serve::{ServeConfig, Server};
use alingam::sim::{sample_from_dag, Noise};
use alingam::util::rng::Pcg64;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::{Duration, Instant};

fn start_http(workers: usize) -> Server {
    Server::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers,
        queue_capacity: 16,
        cache_entries: 8,
        fuse_wait_ms: 0,
        max_batch: 1,
        http_addr: Some("127.0.0.1:0".to_string()),
        ..ServeConfig::default()
    })
    .expect("server start")
}

fn chain_panel(n: usize, d: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::seed_from_u64(seed);
    sample_from_dag(&alingam::graph::chain_dag(d, 1.0), Noise::Uniform01, n, &mut rng)
}

/// Send raw HTTP bytes, read the whole response (the server closes the
/// connection after one request, so EOF delimits it).
fn http_exchange(addr: SocketAddr, request: &str) -> String {
    let mut stream = TcpStream::connect(addr).expect("connect http");
    stream.write_all(request.as_bytes()).expect("send http request");
    let mut response = String::new();
    stream.read_to_string(&mut response).expect("read http response");
    response
}

fn http_get(addr: SocketAddr, path: &str) -> String {
    http_exchange(addr, &format!("GET {path} HTTP/1.1\r\nHost: test\r\n\r\n"))
}

fn http_post(addr: SocketAddr, path: &str, body: &str) -> String {
    http_exchange(
        addr,
        &format!(
            "POST {path} HTTP/1.1\r\nHost: test\r\nContent-Type: application/json\r\n\
             Content-Length: {}\r\n\r\n{body}",
            body.len()
        ),
    )
}

fn status_line(response: &str) -> &str {
    response.lines().next().unwrap_or("")
}

fn response_body(response: &str) -> &str {
    response.split("\r\n\r\n").nth(1).unwrap_or("")
}

/// Every `data:` event in an SSE response, parsed.
fn sse_frames(response: &str) -> Vec<Json> {
    response_body(response)
        .lines()
        .filter_map(|l| l.strip_prefix("data: "))
        .map(|l| protocol::parse_json(l).expect("sse events must be valid frames"))
        .collect()
}

fn event_of(frame: &Json) -> &str {
    frame.get("event").and_then(Json::as_str).unwrap_or("")
}

#[test]
fn get_status_and_metrics_answer_protocol_frames_as_json() {
    let server = start_http(1);
    let http = server.http_local_addr().expect("http listener");

    let resp = http_get(http, "/status");
    assert!(status_line(&resp).starts_with("HTTP/1.1 200"), "got {}", status_line(&resp));
    assert!(resp.contains("Content-Type: application/json"));
    let frame = protocol::parse_json(response_body(&resp).trim()).expect("status json");
    assert_eq!(event_of(&frame), "status");
    assert_eq!(frame.get("accepting").and_then(Json::as_bool), Some(true));

    let resp = http_get(http, "/metrics");
    let frame = protocol::parse_json(response_body(&resp).trim()).expect("metrics json");
    assert_eq!(event_of(&frame), "metrics");
    assert!(frame.get("cache").and_then(|c| c.get("disk_hits")).is_some());
    server.shutdown();
}

/// The tentpole acceptance criterion: the same panel fit over HTTP and
/// over TCP produces byte-identical `data` payloads, and the HTTP
/// stream carries the accepted → progress… → result frame sequence as
/// SSE events.
#[test]
fn post_fit_streams_sse_with_payload_byte_identical_to_tcp() {
    let panel = chain_panel(500, 8, 3);
    let direct = DirectLingam::new().fit(&panel, &VectorizedEngine).expect("direct fit");
    let body = protocol::fit_request("h1", "vectorized", &panel);

    // two fresh servers so neither path can be answered from a cache
    // warmed by the other
    let tcp_server = start_http(1);
    let http_server = start_http(1);

    // TCP path
    let mut stream = TcpStream::connect(tcp_server.local_addr()).expect("connect tcp");
    stream.write_all(body.as_bytes()).expect("send");
    stream.write_all(b"\n").expect("send");
    let mut reader = BufReader::new(stream);
    let tcp_frame = loop {
        let mut line = String::new();
        assert!(reader.read_line(&mut line).expect("recv") > 0, "tcp closed early");
        let f = protocol::parse_json(line.trim_end()).expect("tcp frame json");
        if event_of(&f) == "result" {
            break f;
        }
    };

    // HTTP path (the body is the TCP frame verbatim; its embedded cmd
    // is ignored in favor of the path)
    let http = http_server.http_local_addr().expect("http listener");
    let resp = http_post(http, "/fit", &body);
    assert!(status_line(&resp).starts_with("HTTP/1.1 200"), "got {}", status_line(&resp));
    assert!(resp.contains("Content-Type: text/event-stream"));
    let frames = sse_frames(&resp);
    assert!(frames.len() >= 3, "expected accepted + progress + result, got {}", frames.len());
    assert_eq!(event_of(&frames[0]), "accepted");
    assert!(frames.iter().any(|f| event_of(f) == "progress"), "progress must stream over SSE");
    let http_frame = frames.last().expect("terminal frame");
    assert_eq!(event_of(http_frame), "result");
    assert_eq!(http_frame.get("cached").and_then(Json::as_bool), Some(false));

    // payload equivalence, byte for byte (only timing fields differ
    // between the whole frames)
    let tcp_data = tcp_frame.get("data").expect("tcp data").render();
    let http_data = http_frame.get("data").expect("http data").render();
    assert_eq!(tcp_data, http_data, "HTTP and TCP result payloads must be byte-identical");

    // and both match the direct fit
    let order: Vec<usize> = http_frame
        .get("data")
        .and_then(|d| d.get("order"))
        .and_then(Json::as_arr)
        .expect("data.order")
        .iter()
        .map(|v| v.as_usize().expect("index"))
        .collect();
    assert_eq!(order, direct.order);

    tcp_server.shutdown();
    http_server.shutdown();
}

#[test]
fn repeat_post_fit_is_answered_from_cache() {
    let server = start_http(1);
    let http = server.http_local_addr().expect("http listener");
    let body = protocol::fit_request("c1", "vectorized", &chain_panel(400, 6, 9));

    let first = sse_frames(&http_post(http, "/fit", &body));
    assert_eq!(first.last().map(event_of), Some("result"));
    assert_eq!(first.last().and_then(|f| f.get("cached")).and_then(Json::as_bool), Some(false));

    let second = sse_frames(&http_post(http, "/fit", &body));
    let last = second.last().expect("terminal frame");
    assert_eq!(event_of(last), "result");
    assert_eq!(
        last.get("cached").and_then(Json::as_bool),
        Some(true),
        "byte-identical re-fit must be a cache hit"
    );
    server.shutdown();
}

#[test]
fn malformed_requests_get_real_http_statuses_and_error_frames() {
    let server = start_http(1);
    let http = server.http_local_addr().expect("http listener");

    let resp = http_post(http, "/fit", "this is not json");
    assert!(status_line(&resp).starts_with("HTTP/1.1 400"), "got {}", status_line(&resp));
    let frame = protocol::parse_json(response_body(&resp).trim()).expect("error frame json");
    assert_eq!(event_of(&frame), "error");

    // fit body missing its panel: still 400, still an error frame
    let resp = http_post(http, "/fit", "{\"id\":\"x\"}");
    assert!(status_line(&resp).starts_with("HTTP/1.1 400"), "got {}", status_line(&resp));

    let resp = http_get(http, "/no-such-route");
    assert!(status_line(&resp).starts_with("HTTP/1.1 404"), "got {}", status_line(&resp));

    let resp = http_get(http, "/fit");
    assert!(status_line(&resp).starts_with("HTTP/1.1 405"), "got {}", status_line(&resp));
    let resp = http_post(http, "/status", "");
    assert!(status_line(&resp).starts_with("HTTP/1.1 405"), "got {}", status_line(&resp));
    server.shutdown();
}

#[test]
fn post_cancel_answers_an_ack_frame() {
    let server = start_http(1);
    let http = server.http_local_addr().expect("http listener");
    let resp = http_post(http, "/cancel", "{\"target\":\"nope\"}");
    assert!(status_line(&resp).starts_with("HTTP/1.1 200"), "got {}", status_line(&resp));
    let frame = protocol::parse_json(response_body(&resp).trim()).expect("ack json");
    assert_eq!(event_of(&frame), "ack");
    assert_eq!(frame.get("ok").and_then(Json::as_bool), Some(false), "unknown job: ok=false");
    server.shutdown();
}

/// The fleet acceptance criterion: 2 shards of the real binary, kill
/// one with SIGKILL, the supervisor books the restart and traffic keeps
/// flowing.
#[cfg(unix)]
#[test]
fn two_shard_fleet_survives_a_kill_and_books_the_restart() {
    use alingam::serve::shard::Supervisor;
    use std::process::Command;

    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 1,
        queue_capacity: 16,
        cache_entries: 8,
        fuse_wait_ms: 0,
        max_batch: 1,
        ..ServeConfig::default()
    };
    // the test harness binary is not `alingam`; point the supervisor at
    // the real one Cargo built for this test run
    let exe = std::path::PathBuf::from(env!("CARGO_BIN_EXE_alingam"));
    let sup = Supervisor::start(cfg, 2, Some(exe)).expect("fleet start");
    let table = sup.shard_table();
    assert_eq!(table.len(), 2, "both shards announce an address");

    let fit = |id: &str, seed: u64| -> (String, Json) {
        let panel = chain_panel(400, 6, seed);
        let mut stream = TcpStream::connect(sup.local_addr()).expect("connect fleet");
        stream
            .write_all(protocol::fit_request(id, "vectorized", &panel).as_bytes())
            .expect("send");
        stream.write_all(b"\n").expect("send");
        let mut reader = BufReader::new(stream);
        loop {
            let mut line = String::new();
            assert!(reader.read_line(&mut line).expect("recv") > 0, "fleet closed early");
            let f = protocol::parse_json(line.trim_end()).expect("fleet frame json");
            if let ev @ ("result" | "error" | "canceled") = event_of(&f) {
                return (ev.to_string(), f);
            }
        }
    };

    let (ev, _) = fit("k1", 21);
    assert_eq!(ev, "result", "fit through the fleet front succeeds");

    // SIGKILL one shard — no drain, no goodbye
    let (_, pid, _) = table[0];
    let killed =
        Command::new("kill").args(["-9", &pid.to_string()]).status().expect("spawn kill");
    assert!(killed.success(), "kill -9 {pid}");

    // the monitor books the restart and brings the fleet back to 2 live
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let mut stream = TcpStream::connect(sup.local_addr()).expect("connect fleet");
        stream.write_all(protocol::control_request("metrics").as_bytes()).expect("send");
        stream.write_all(b"\n").expect("send");
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        assert!(reader.read_line(&mut line).expect("recv") > 0);
        let f = protocol::parse_json(line.trim_end()).expect("metrics json");
        let restarts = f.get("shard_restarts").and_then(Json::as_u64).unwrap_or(0);
        let live = f.get("shards_live").and_then(Json::as_u64).unwrap_or(0);
        if restarts >= 1 && live == 2 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "restart not booked within 30s (restarts={restarts}, live={live})"
        );
        std::thread::sleep(Duration::from_millis(200));
    }
    assert!(sup.restart_count() >= 1);

    // traffic still flows after the kill
    let (ev, _) = fit("k2", 22);
    assert_eq!(ev, "result", "fleet keeps serving after a shard kill");
    assert!(sup.shutdown_within(Duration::from_secs(60)), "fleet drains cleanly");
}

/// Total fleet loss: with every shard SIGKILLed at once, a submit must
/// come back as a prompt error frame (failover ring exhausted — not a
/// hang), the supervisor's backoff must revive both shards, and traffic
/// must flow again. Watch subscriptions are refused at the fleet front
/// outright: their follow-up frames need an in-process stream registry
/// a relay tier does not host.
#[cfg(unix)]
#[test]
fn all_shards_dead_errors_promptly_then_supervisor_recovers() {
    use alingam::serve::shard::Supervisor;
    use std::process::Command;

    let cfg = ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers: 1,
        queue_capacity: 16,
        cache_entries: 8,
        fuse_wait_ms: 0,
        max_batch: 1,
        ..ServeConfig::default()
    };
    let exe = std::path::PathBuf::from(env!("CARGO_BIN_EXE_alingam"));
    let sup = Supervisor::start(cfg, 2, Some(exe)).expect("fleet start");

    let terminal = |req: &str| -> (String, Json) {
        let mut stream = TcpStream::connect(sup.local_addr()).expect("connect fleet");
        stream.write_all(req.as_bytes()).expect("send");
        stream.write_all(b"\n").expect("send");
        let mut reader = BufReader::new(stream);
        loop {
            let mut line = String::new();
            assert!(reader.read_line(&mut line).expect("recv") > 0, "fleet closed early");
            let f = protocol::parse_json(line.trim_end()).expect("fleet frame json");
            if let ev @ ("result" | "error" | "canceled") = event_of(&f) {
                return (ev.to_string(), f);
            }
        }
    };

    let (ev, _) = terminal(&protocol::fit_request("d0", "vectorized", &chain_panel(400, 6, 31)));
    assert_eq!(ev, "result", "healthy fleet serves");

    // build the probe request *before* the kills so the submit races
    // only the monitors' 100 ms poll, not panel simulation too
    let probe = protocol::fit_request("d1", "vectorized", &chain_panel(400, 6, 32));

    // SIGKILL the whole fleet at once
    for (_, pid, _) in sup.shard_table() {
        let killed =
            Command::new("kill").args(["-9", &pid.to_string()]).status().expect("spawn kill");
        assert!(killed.success(), "kill -9 {pid}");
    }

    // with every shard down the failover ring exhausts into an error
    // frame — promptly, before the monitors can possibly respawn a child
    let t0 = Instant::now();
    let (ev, frame) = terminal(&probe);
    assert_eq!(ev, "error", "dead fleet must error, got {}", frame.render());
    let msg = frame.get("message").and_then(Json::as_str).unwrap_or_default();
    assert!(
        msg.contains("shard") || msg.contains("live"),
        "error must name the shard outage: {msg:?}"
    );
    assert!(
        t0.elapsed() < Duration::from_secs(10),
        "error frame took {:?}: a dead fleet must fail fast, not hang",
        t0.elapsed()
    );

    // the monitors' backoff revives both shards
    let metrics = || -> Json {
        let mut stream = TcpStream::connect(sup.local_addr()).expect("connect fleet");
        stream.write_all(protocol::control_request("metrics").as_bytes()).expect("send");
        stream.write_all(b"\n").expect("send");
        let mut reader = BufReader::new(stream);
        let mut line = String::new();
        assert!(reader.read_line(&mut line).expect("recv") > 0);
        protocol::parse_json(line.trim_end()).expect("metrics json")
    };
    let deadline = Instant::now() + Duration::from_secs(30);
    loop {
        let m = metrics();
        let restarts = m.get("shard_restarts").and_then(Json::as_u64).unwrap_or(0);
        let live = m.get("shards_live").and_then(Json::as_u64).unwrap_or(0);
        if restarts >= 2 && live == 2 {
            break;
        }
        assert!(
            Instant::now() < deadline,
            "fleet not revived within 30s (restarts={restarts}, live={live})"
        );
        std::thread::sleep(Duration::from_millis(200));
    }
    assert!(sup.restart_count() >= 2);
    let (ev, _) = terminal(&protocol::fit_request("d2", "vectorized", &chain_panel(400, 6, 33)));
    assert_eq!(ev, "result", "revived fleet serves again");

    // watch streams never relay: rejected at the front with a clear error
    let (ev, frame) =
        terminal(&protocol::watch_request("dw", "vectorized", 3, 16, 0, 0, 1e-3, 0.05));
    assert_eq!(ev, "error");
    let msg = frame.get("message").and_then(Json::as_str).unwrap_or_default();
    assert!(msg.contains("sharded fleet"), "unexpected rejection message {msg:?}");

    assert!(sup.shutdown_within(Duration::from_secs(60)), "fleet drains cleanly");
}
