//! End-to-end pipeline tests: the full experiment flows the examples and
//! benches drive, at test-friendly sizes.

use alingam::apps::{genes, simbench, stocks};
use alingam::baselines::SvgdOpts;
use alingam::coordinator::{profile_direct, Engine, EngineChoice};
use alingam::lingam::{SequentialEngine, VarLingam, VectorizedEngine};
use alingam::sim::{simulate_sem, simulate_var, Condition, MarketSpec, SemSpec, VarSpec};
use alingam::util::rng::Pcg64;

#[test]
fn gene_pipeline_table1_row_shape() {
    let cfg = genes::GenesConfig {
        scale: genes::GeneScale::Small,
        seed: 7,
        svgd: SvgdOpts { particles: 8, iters: 50, step: 0.1, seed: 0 },
        max_train_rows: 150,
        max_test_cells: 40,
        with_baseline: true,
    };
    let rows = genes::run_condition(&cfg, Condition::CoCulture, &VectorizedEngine).unwrap();
    assert_eq!(rows.len(), 2, "DirectLiNGAM + comparator");
    assert_eq!(rows[0].method, "DirectLiNGAM+VI");
    assert!(rows[1].method.contains("DCD-FG"));
    for r in &rows {
        assert!(r.metrics.nll.is_finite());
        assert!(r.metrics.mae > 0.0 && r.metrics.mae < 10.0);
    }
}

#[test]
fn stock_pipeline_full_flow_with_gaps() {
    // end-to-end through interpolation → differencing → VarLiNGAM
    let spec = MarketSpec { dim: 30, t_len: 900, ..MarketSpec::small() };
    let r = stocks::run_stocks(&spec, 11, &VectorizedEngine, 5).unwrap();
    assert_eq!(r.top_exerting.len(), 5);
    assert_eq!(r.top_receiving.len(), 5);
    // paper's qualitative finding: in/out degree distributions roughly
    // balanced (total mass equal by construction; compare maxima loosely)
    let max_in = *r.in_degrees.iter().max().unwrap();
    let max_out = *r.out_degrees.iter().max().unwrap();
    assert!(max_in > 0 && max_out > 0);
}

#[test]
fn xla_engine_through_full_gene_condition() {
    let engine = Engine::build(EngineChoice::Xla).expect("run `make artifacts`");
    let cfg = genes::GenesConfig {
        scale: genes::GeneScale::Small,
        seed: 3,
        svgd: SvgdOpts { particles: 6, iters: 30, step: 0.1, seed: 0 },
        max_train_rows: 100,
        max_test_cells: 25,
        with_baseline: false,
    };
    // Small scale is d=60: covered by the d=64 artifact bucket
    let rows = genes::run_condition(&cfg, Condition::Ifn, engine.as_ordering()).unwrap();
    assert!(rows[0].metrics.nll.is_finite());
}

#[test]
fn varlingam_sequential_equals_vectorized_end_to_end() {
    let spec = VarSpec { dim: 6, ..Default::default() };
    let mut rng = Pcg64::seed_from_u64(5);
    let ds = simulate_var(&spec, 3_000, &mut rng);
    let a = VarLingam::new().fit(&ds.data, &SequentialEngine).unwrap();
    let b = VarLingam::new().fit(&ds.data, &VectorizedEngine).unwrap();
    assert_eq!(a.order, b.order);
    assert!(a.b0.sub(&b.b0).max_abs() < 1e-8);
    assert!(a.b1().sub(b.b1()).max_abs() < 1e-8);
}

#[test]
fn profile_fraction_grows_with_dims() {
    // Figure-2 shape: the ordering share rises with d (the quadratic term)
    let mut rng = Pcg64::seed_from_u64(6);
    let small = simulate_sem(&SemSpec::layered(5, 2, 0.5), 2_000, &mut rng);
    let big = simulate_sem(&SemSpec::layered(14, 2, 0.5), 2_000, &mut rng);
    let f_small = profile_direct(&small.data, &SequentialEngine).unwrap().ordering_frac;
    let f_big = profile_direct(&big.data, &SequentialEngine).unwrap().ordering_frac;
    assert!(
        f_big > f_small,
        "ordering fraction should grow with d: {f_small} vs {f_big}"
    );
    assert!(f_big > 0.8, "at d=14 ordering should dominate: {f_big}");
}

#[test]
fn notears_comparison_runs_end_to_end() {
    let seeds: Vec<u64> = (0..2).collect();
    let ms = simbench::notears_sweep(&simbench::fig3_spec(), 800, &seeds, &[0.01], false, 2);
    // §3.1's point is qualitative: NOTEARS exists, runs, and is imperfect
    for m in &ms {
        assert!(m.f1 <= 1.0 && m.f1 > 0.0);
    }
}

#[test]
fn asymmetry_demo_directions() {
    use alingam::sim::Noise;
    let (fwd_u, bwd_u) = simbench::asymmetry_demo(Noise::Uniform01, 30_000, 1.5, 3).unwrap();
    let (fwd_g, bwd_g) = simbench::asymmetry_demo(Noise::Gaussian(1.0), 30_000, 1.5, 3).unwrap();
    assert!(bwd_u > 3.0 * fwd_u.max(1e-3), "uniform: {fwd_u} vs {bwd_u}");
    assert!(bwd_g < 0.02 && fwd_g < 0.02, "gaussian: {fwd_g} vs {bwd_g}");
}

#[test]
fn bootstrap_pipeline_stable_on_strong_graph() {
    use alingam::coordinator::{bootstrap_direct, BootstrapOpts};
    let mut rng = Pcg64::seed_from_u64(8);
    let ds = simulate_sem(&SemSpec::layered(6, 2, 0.7), 1_200, &mut rng);
    let opts = BootstrapOpts { resamples: 15, workers: 2, ..Default::default() };
    let boot = bootstrap_direct(&ds.data, &VectorizedEngine, &opts).unwrap();
    assert_eq!(boot.resamples, 15);
    // every very strong true edge should be stable
    for i in 0..6 {
        for j in 0..6 {
            if ds.adjacency[(i, j)].abs() > 1.2 {
                assert!(
                    boot.edge_probs[(i, j)] >= 0.8,
                    "edge {j}->{i} prob {}",
                    boot.edge_probs[(i, j)]
                );
            }
        }
    }
}

#[test]
fn ica_and_direct_agree_on_well_separated_data() {
    use alingam::lingam::{DirectLingam, IcaLingam};
    let mut rng = Pcg64::seed_from_u64(9);
    let ds = simulate_sem(&SemSpec::layered(6, 2, 0.7), 10_000, &mut rng);
    let direct = DirectLingam::new().fit(&ds.data, &VectorizedEngine).unwrap();
    let ica = IcaLingam::new().fit(&ds.data).unwrap();
    // both orders must be consistent with the truth (orders may differ
    // among equivalent permutations)
    assert!(alingam::graph::order_consistent(&ds.adjacency, &direct.order));
    assert!(alingam::graph::order_consistent(&ds.adjacency, &ica.order));
    let m_d = alingam::metrics::graph_metrics(&ds.adjacency, &direct.adjacency, 0.1);
    let m_i = alingam::metrics::graph_metrics(&ds.adjacency, &ica.adjacency, 0.1);
    assert!(m_d.f1 >= 0.75 && m_i.f1 >= 0.75, "direct {} ica {}", m_d.f1, m_i.f1);
}

#[test]
fn varlingam_lag2_pipeline() {
    use alingam::lingam::var::total_effects;
    let spec = VarSpec { dim: 5, ..Default::default() };
    let mut rng = Pcg64::seed_from_u64(10);
    let ds = simulate_var(&spec, 4_000, &mut rng);
    let fit = VarLingam::new().with_lags(2).fit(&ds.data, &VectorizedEngine).unwrap();
    assert_eq!(fit.m_tau.len(), 2);
    assert_eq!(fit.b_tau.len(), 2);
    let te = total_effects(&fit);
    assert_eq!(te.exerted.len(), 3); // tau = 0, 1, 2
    // data is VAR(1): the lag-2 coefficients should be comparatively small
    assert!(
        fit.m_tau[1].fro_norm() < fit.m_tau[0].fro_norm() + 1.0,
        "lag-2 mass should not dominate a VAR(1) process"
    );
}
