//! The batched-session parity contract, property-tested end to end:
//! a B-panel [`BatchedSession`] fit is **bitwise** identical — causal
//! orders, per-step score rows, adjacency matrices, and pruned-sweep
//! counters — to B independent [`IncrementalSession`] fits with the
//! same pool configuration, across randomized panels, shapes, sweep
//! strategies, and worker counts. Uses the hand-rolled `util::prop`
//! mini-framework (proptest is not in the offline crate set); failures
//! print a replay seed (`ALINGAM_PROP_SEED=...`).
//!
//! The one deliberate exception: multi-worker **pruned** sweeps
//! partition candidate rows across threads, so loser scores and skip
//! counters are execution-order-dependent even solo-vs-solo. For that
//! configuration the pinned surface is what the algorithm guarantees —
//! the chosen order and the adjacency regressed from it.

use alingam::lingam::prune::PruneMethod;
use alingam::lingam::{
    BatchedSession, DirectLingam, IncrementalSession, LingamFit, OrderingSession, SweepCounters,
    SweepStrategy,
};
use alingam::linalg::Mat;
use alingam::sim::{simulate_sem, SemSpec};
use alingam::util::prop::{props, Gen};
use alingam::util::rng::Pcg64;
use alingam::util::Error;

/// One solo reference fit with an explicit pool configuration.
fn solo(
    panel: &Mat,
    workers: usize,
    force: bool,
    strategy: SweepStrategy,
) -> (LingamFit, SweepCounters) {
    let mut session = IncrementalSession::with_strategy(panel, workers, force, strategy).unwrap();
    let fit = DirectLingam::new().fit_session(panel, &mut session).unwrap();
    let counters = session.sweep_counters();
    (fit, counters)
}

/// A random batch of same-shape SEM panels.
fn random_panels(g: &mut Gen, b: usize) -> Vec<Mat> {
    let d = g.usize_in(3, 7);
    let n = g.usize_in(60, 160);
    let p_edge = g.f64_in(0.4, 0.9);
    (0..b)
        .map(|_| simulate_sem(&SemSpec::layered(d, 2, p_edge), n, g.rng()).data)
        .collect()
}

/// Assert full bitwise parity of one batch outcome against its solo fit.
fn assert_bitwise(
    label: &str,
    p: usize,
    out: &alingam::lingam::BatchOutcome,
    fit: &LingamFit,
    counters: &SweepCounters,
) {
    let batch_fit = out.result.as_ref().unwrap();
    assert_eq!(batch_fit.order, fit.order, "{label}: panel {p} order");
    assert_eq!(batch_fit.step_scores, fit.step_scores, "{label}: panel {p} step scores");
    assert_eq!(batch_fit.adjacency, fit.adjacency, "{label}: panel {p} adjacency");
    assert_eq!(out.counters, *counters, "{label}: panel {p} sweep counters");
}

#[test]
fn prop_serial_exact_batch_is_bitwise_solo() {
    props("serial exact batch parity", 25, |g: &mut Gen| {
        let b = g.usize_in(2, 5);
        let panels = random_panels(g, b);
        let outs = BatchedSession::fit_batch(
            &panels,
            1,
            false,
            SweepStrategy::Exact,
            PruneMethod::default(),
        )
        .unwrap();
        for (p, out) in outs.iter().enumerate() {
            let (fit, counters) = solo(&panels[p], 1, false, SweepStrategy::Exact);
            assert_bitwise("serial exact", p, out, &fit, &counters);
        }
    });
}

#[test]
fn prop_serial_pruned_batch_is_bitwise_solo_with_counters() {
    props("serial pruned batch parity", 25, |g: &mut Gen| {
        let b = g.usize_in(2, 4);
        let panels = random_panels(g, b);
        let outs = BatchedSession::fit_batch(
            &panels,
            1,
            false,
            SweepStrategy::Pruned,
            PruneMethod::default(),
        )
        .unwrap();
        for (p, out) in outs.iter().enumerate() {
            // the bound-pruned sweep's skip/visit counters are part of
            // the contract: batching must not change which comparisons
            // the bound eliminates
            let (fit, counters) = solo(&panels[p], 1, false, SweepStrategy::Pruned);
            assert_bitwise("serial pruned", p, out, &fit, &counters);
        }
    });
}

#[test]
fn prop_pair_pooled_exact_batch_is_bitwise_solo() {
    // force_parallel drives the tiled pair sweep regardless of panel
    // size; the batch must make the identical pool-vs-serial decision
    // at every lock step and reuse the identical tiled kernel
    props("pair-pooled exact batch parity", 15, |g: &mut Gen| {
        let b = g.usize_in(2, 4);
        let workers = g.usize_in(2, 4);
        let panels = random_panels(g, b);
        let outs = BatchedSession::fit_batch(
            &panels,
            workers,
            true,
            SweepStrategy::Exact,
            PruneMethod::default(),
        )
        .unwrap();
        for (p, out) in outs.iter().enumerate() {
            let (fit, counters) = solo(&panels[p], workers, true, SweepStrategy::Exact);
            assert_bitwise("pooled exact", p, out, &fit, &counters);
        }
    });
}

#[test]
fn prop_pooled_pruned_batch_matches_orders_and_adjacency() {
    // multi-worker pruned sweeps are execution-order-dependent in loser
    // scores and counters (solo runs differ from each other too), so
    // the pinned surface is the order and the adjacency it implies
    props("pooled pruned batch order parity", 15, |g: &mut Gen| {
        let b = g.usize_in(2, 4);
        let workers = g.usize_in(2, 4);
        let panels = random_panels(g, b);
        let outs = BatchedSession::fit_batch(
            &panels,
            workers,
            true,
            SweepStrategy::Pruned,
            PruneMethod::default(),
        )
        .unwrap();
        for (p, out) in outs.iter().enumerate() {
            let (fit, _) = solo(&panels[p], workers, true, SweepStrategy::Pruned);
            let batch_fit = out.result.as_ref().unwrap();
            assert_eq!(batch_fit.order, fit.order, "panel {p} order");
            assert_eq!(batch_fit.adjacency, fit.adjacency, "panel {p} adjacency");
        }
    });
}

#[test]
fn prop_degenerate_panel_fails_alone() {
    // a constant-column panel dies with the solo path's validation
    // error while its batch peers stay bitwise-solo
    props("degenerate lane isolation", 15, |g: &mut Gen| {
        let mut panels = random_panels(g, 3);
        let bad = g.usize_in(0, 2);
        let col = g.usize_in(0, panels[bad].cols() - 1);
        for r in 0..panels[bad].rows() {
            panels[bad][(r, col)] = 4.25;
        }
        let outs = BatchedSession::fit_batch(
            &panels,
            1,
            false,
            SweepStrategy::Exact,
            PruneMethod::default(),
        )
        .unwrap();
        for (p, out) in outs.iter().enumerate() {
            if p == bad {
                let err = out.result.as_ref().unwrap_err();
                assert!(err.to_string().contains("constant"), "panel {p}: {err}");
            } else {
                let (fit, counters) = solo(&panels[p], 1, false, SweepStrategy::Exact);
                assert_bitwise("degenerate peer", p, out, &fit, &counters);
            }
        }
    });
}

#[test]
fn prop_dropped_lane_leaves_peers_bitwise_solo() {
    // cancel semantics: a lane dropped at a step boundary (the serve
    // worker's per-job cancel) reports its reason; peers are unaffected
    props("dropped lane isolation", 15, |g: &mut Gen| {
        let panels = random_panels(g, 3);
        let drop_at = g.usize_in(0, panels[0].cols() - 2);
        let dropped = g.usize_in(0, 2);
        let mut session =
            BatchedSession::with_strategy(&panels, 1, false, SweepStrategy::Exact).unwrap();
        while !session.finished() {
            if session.steps_done() == drop_at && session.live(dropped) {
                session.drop_lane(dropped, Error::Canceled("fit canceled".into()));
            }
            session.step_live();
        }
        let outs = session.into_fits(&panels, PruneMethod::default());
        for (p, out) in outs.iter().enumerate() {
            if p == dropped {
                assert!(
                    matches!(out.result, Err(Error::Canceled(_))),
                    "panel {p}: {:?}",
                    out.result
                );
            } else {
                let (fit, counters) = solo(&panels[p], 1, false, SweepStrategy::Exact);
                assert_bitwise("dropped-lane peer", p, out, &fit, &counters);
            }
        }
    });
}

#[test]
fn cross_panel_threading_is_bitwise_neutral() {
    // small panels route whole lanes across the pool (serial inner
    // kernels): scheduling must not move a single bit vs the serial walk
    let mut rng = Pcg64::seed_from_u64(404);
    let panels: Vec<Mat> = (0..4)
        .map(|_| simulate_sem(&SemSpec::layered(5, 2, 0.6), 90, &mut rng).data)
        .collect();
    let serial = BatchedSession::fit_batch(
        &panels,
        1,
        false,
        SweepStrategy::Exact,
        PruneMethod::default(),
    )
    .unwrap();
    let threaded = BatchedSession::fit_batch(
        &panels,
        4,
        false,
        SweepStrategy::Exact,
        PruneMethod::default(),
    )
    .unwrap();
    for (p, (a, b)) in serial.iter().zip(&threaded).enumerate() {
        let (fa, fb) = (a.result.as_ref().unwrap(), b.result.as_ref().unwrap());
        assert_eq!(fa.order, fb.order, "panel {p} order");
        assert_eq!(fa.step_scores, fb.step_scores, "panel {p} step scores");
        assert_eq!(fa.adjacency, fb.adjacency, "panel {p} adjacency");
        assert_eq!(a.counters, b.counters, "panel {p} counters");
    }
}
