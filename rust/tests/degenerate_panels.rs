//! Degenerate-panel hardening suite: production-shaped inputs — constant
//! columns (zero-variance probes in gene-expression panels), duplicated
//! columns, and near-collinear pairs — must surface as `Err` or finite
//! scores from every engine, never as a NaN panic.
//!
//! Regression coverage for the three historical crash paths:
//! - `argmax_active` asserting "no active variable" when every active
//!   score was NaN/−∞,
//! - the pair-kernel denominator collapsing on collinear columns
//!   (`sqrt(1−ρ²)` going NaN, floored to 1e-150 by `f64::max`, which
//!   overflowed the affected scores to −∞ and fed the panic above),
//! - `stats::quantile` panicking via `partial_cmp().unwrap()` on NaN
//!   (exercised in `stats`' own tests; it sits under `median_sq_dist`).
//!
//! The same hardening is mirrored on the Python/XLA side
//! (`python/compile/kernels/`): the Pallas HR kernel and the jnp oracle
//! clamp ρ² to ≤ 1 *before* forming `1 − ρ²` (the analogue of the Rust
//! pair-kernel clamp), and the AOT `order_step` graph routes its on-device
//! argmax through a NaN-safe rewrite (`ref.safe_argmax`) so a NaN-poisoned
//! k_list can never elect a variable — regenerate artifacts with
//! `make artifacts` to pick the guards up; `python/tests/test_kernel.py`
//! covers both. The incremental ordering session inherits the guards
//! through the shared closed forms (its ρ²-clamp matches `pair_diff`);
//! `sessions_stay_finite_on_degenerate_panels` below pins that.

use alingam::lingam::{
    DirectLingam, OrderingEngine, OrderingSession, ParallelEngine, SequentialEngine,
    VectorizedEngine,
};
use alingam::linalg::Mat;
use alingam::util::rng::Pcg64;
use alingam::util::Error;

fn engines() -> Vec<Box<dyn OrderingEngine>> {
    vec![
        Box::new(SequentialEngine),
        Box::new(VectorizedEngine),
        // force_parallel: these panels are tiny, and the threaded path —
        // the only code unique to ParallelEngine — is what needs coverage
        Box::new(ParallelEngine::new(2).force_parallel()),
    ]
}

/// Random non-degenerate base panel.
fn base_panel(n: usize, d: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::seed_from_u64(seed);
    Mat::from_fn(n, d, |_, _| rng.normal())
}

/// Scores must be Ok-and-never-NaN or a clean Err — in particular no
/// panic anywhere on the path.
fn assert_scores_err_or_finite(x: &Mat, label: &str) {
    let active = vec![true; x.cols()];
    for eng in engines() {
        // a clean Err is an accepted outcome for degenerate input; what
        // must never happen is a panic or a NaN-poisoned k_list
        if let Ok(k) = eng.scores(x, &active) {
            for (i, &v) in k.iter().enumerate() {
                assert!(
                    !v.is_nan(),
                    "{}: engine {} produced NaN score at {i}: {k:?}",
                    label,
                    eng.name()
                );
            }
        }
    }
}

/// `fit` must either succeed or return a clean Err — never panic.
fn assert_fit_err_or_ok(x: &Mat, label: &str) {
    for eng in engines() {
        if let Ok(fit) = DirectLingam::new().fit(x, eng.as_ref()) {
            let mut order = fit.order.clone();
            order.sort_unstable();
            assert_eq!(
                order,
                (0..x.cols()).collect::<Vec<_>>(),
                "{}: engine {} returned a non-permutation order",
                label,
                eng.name()
            );
        }
    }
}

#[test]
fn constant_column_panel() {
    let mut x = base_panel(300, 5, 1);
    // non-dyadic value: its float sums carry rounding variance ~1e-17,
    // so this also pins the scale-relative (not exact-zero) guard
    let constant = vec![0.1; 300];
    x.set_col(2, &constant);
    assert_scores_err_or_finite(&x, "constant column");
    // at the fit level a constant column is detected up front
    for eng in engines() {
        let res = DirectLingam::new().fit(&x, eng.as_ref());
        assert!(
            matches!(res, Err(Error::InvalidArgument(_))),
            "constant column: engine {} did not surface InvalidArgument",
            eng.name()
        );
    }
}

#[test]
fn duplicated_column_panel() {
    let mut x = base_panel(300, 5, 2);
    let dup = x.col(1);
    x.set_col(3, &dup);
    assert_scores_err_or_finite(&x, "duplicated column");
    assert_fit_err_or_ok(&x, "duplicated column");
}

#[test]
fn near_collinear_pair_panel() {
    let mut rng = Pcg64::seed_from_u64(3);
    let mut x = base_panel(300, 5, 3);
    // column 4 = column 0 plus vanishing noise: ρ² rounds to (or past) 1
    let near: Vec<f64> = x.col(0).iter().map(|&v| v + 1e-9 * rng.normal()).collect();
    x.set_col(4, &near);
    assert_scores_err_or_finite(&x, "near-collinear pair");
    assert_fit_err_or_ok(&x, "near-collinear pair");
}

#[test]
fn negatively_scaled_duplicate_panel() {
    // ρ → −1 exercises the other edge of the clamp
    let mut x = base_panel(300, 4, 4);
    let neg: Vec<f64> = x.col(0).iter().map(|&v| -2.5 * v).collect();
    x.set_col(3, &neg);
    assert_scores_err_or_finite(&x, "negative duplicate");
    assert_fit_err_or_ok(&x, "negative duplicate");
}

#[test]
fn all_constant_panel_never_panics() {
    // every column constant: nothing is estimable; engines must not panic
    // and fit must reject it cleanly
    let x = Mat::from_fn(64, 3, |_, c| c as f64);
    assert_scores_err_or_finite(&x, "all-constant panel");
    for eng in engines() {
        let res = DirectLingam::new().fit(&x, eng.as_ref());
        assert!(
            res.is_err(),
            "all-constant panel: engine {} should not produce a fit",
            eng.name()
        );
    }
}

#[test]
fn sessions_stay_finite_on_degenerate_panels() {
    // the stateful workspace path must uphold the same contract as the
    // stateless engines: every step either a clean Err or NaN-free scores
    let mut dup = base_panel(300, 5, 7);
    let col = dup.col(1);
    dup.set_col(3, &col);
    let mut neg = base_panel(300, 4, 8);
    let flipped: Vec<f64> = neg.col(0).iter().map(|&v| -2.5 * v).collect();
    neg.set_col(3, &flipped);
    for (label, x) in [("duplicated column", dup), ("negative duplicate", neg)] {
        for eng in engines() {
            let mut session = eng.session(&x).unwrap();
            while session.remaining() > 1 {
                match session.step() {
                    Ok(step) => {
                        for (i, &v) in step.scores.iter().enumerate() {
                            assert!(
                                !v.is_nan(),
                                "{label}: engine {} session produced NaN at {i}",
                                eng.name()
                            );
                        }
                    }
                    Err(_) => break, // a clean Err is an accepted outcome
                }
            }
        }
    }
}

#[test]
fn unusable_scores_surface_err_not_panic() {
    // the selection step order_step delegates to: every active score
    // NaN/−∞ must yield Err, not the old "no active variable" panic
    let scores = vec![f64::NAN, f64::NEG_INFINITY, f64::NAN];
    let active = vec![true; 3];
    assert!(alingam::lingam::engine::argmax_active(&scores, &active).is_err());
}
