//! Partition-exactness suite: the exact merge tier of the partitioned
//! plan (`lingam::partition`) must reproduce the unpartitioned
//! `DirectLingam::fit` — identical order, identical step scores
//! (bitwise), identical adjacency — on random panels, block-diagonal
//! panels, and degenerate panels, while the partition instrumentation
//! (blocks formed, boundary pairs) reports the work a lossy
//! decomposition would have skipped. The approx tier is held to the
//! honest-but-weaker contract the module essay states: a valid
//! permutation, truth-consistent recovery on separable panels, and a
//! boundary-pair count from its tournament merge.
//!
//! Why the exact tier can be pinned bitwise: it drives one global
//! session over the whole panel — the same session type, same serial
//! worker configuration, same step loop as the reference fit — so there
//! is no float reassociation anywhere on the path (the same argument
//! `pruning_exactness.rs` leans on, here by construction rather than by
//! bound).

use alingam::graph::chain_dag;
use alingam::lingam::{
    DirectLingam, MergeMode, PartitionSpec, PartitionedPlan, VectorizedEngine,
};
use alingam::linalg::Mat;
use alingam::metrics::{adjacency_max_diff, graph_metrics};
use alingam::sim::{sample_from_dag, simulate_sem, Noise, SemSpec};
use alingam::util::rng::Pcg64;

fn layered_panel(n: usize, d: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::seed_from_u64(seed);
    simulate_sem(&SemSpec::layered(d, 2, 0.5), n, &mut rng).data
}

/// Two independent chain SEMs side by side: columns `0..d1` form one
/// chain, `d1..d1+d2` the other, with no true edges across the halves —
/// the canonical separable panel. Returns the panel and the
/// block-diagonal ground-truth adjacency.
fn block_diagonal_panel(n: usize, d1: usize, d2: usize, seed: u64) -> (Mat, Mat) {
    let mut rng = Pcg64::seed_from_u64(seed);
    let dag_a = chain_dag(d1, 1.0);
    let dag_b = chain_dag(d2, 1.0);
    let xa = sample_from_dag(&dag_a, Noise::Uniform01, n, &mut rng);
    let xb = sample_from_dag(&dag_b, Noise::Uniform01, n, &mut rng);
    let d = d1 + d2;
    let mut x = Mat::zeros(n, d);
    for r in 0..n {
        for c in 0..d1 {
            x[(r, c)] = xa[(r, c)];
        }
        for c in 0..d2 {
            x[(r, d1 + c)] = xb[(r, c)];
        }
    }
    let mut truth = Mat::zeros(d, d);
    for i in 0..d1 {
        for j in 0..d1 {
            truth[(i, j)] = dag_a.adj[(i, j)];
        }
    }
    for i in 0..d2 {
        for j in 0..d2 {
            truth[(d1 + i, d1 + j)] = dag_b.adj[(i, j)];
        }
    }
    (x, truth)
}

/// Serial exact-merge spec: workers=1 matches the serial reference
/// session's float accumulation order, making bitwise pins legitimate.
fn exact_spec() -> PartitionSpec {
    PartitionSpec { workers: 1, ..PartitionSpec::default() }
}

/// The acceptance criterion: exact merge provably agrees with the
/// unpartitioned fit — order, adjacency, and per-step scores identical.
fn assert_exact_merge_matches_direct(x: &Mat, spec: &PartitionSpec, label: &str) {
    let direct = DirectLingam::new().fit(x, &VectorizedEngine).unwrap();
    let pf = DirectLingam::new().fit_plan(x, &PartitionedPlan::new(*spec)).unwrap();
    assert_eq!(pf.fit.order, direct.order, "{label}: exact merge changed the order");
    assert_eq!(
        pf.fit.step_scores, direct.step_scores,
        "{label}: step scores not bitwise-identical"
    );
    assert_eq!(
        adjacency_max_diff(&pf.fit.adjacency, &direct.adjacency),
        0.0,
        "{label}: identical orders must give identical regressions"
    );
}

#[test]
fn exact_merge_is_the_unpartitioned_fit_on_layered_panels() {
    for seed in [41, 42, 43] {
        let x = layered_panel(1_500, 10, seed);
        assert_exact_merge_matches_direct(&x, &exact_spec(), "layered");
    }
}

#[test]
fn exact_merge_matches_on_block_diagonal_and_counts_boundary_pairs() {
    // threshold 0.2: within each chain adjacent |ρ| ≈ 0.7 keeps the
    // block connected, while cross-half sample correlations are
    // O(n^{-1/2}) ≈ 0.016 at n=4000 — the halves reliably separate
    let (x, _truth) = block_diagonal_panel(4_000, 4, 4, 44);
    let spec = PartitionSpec { threshold: 0.2, ..exact_spec() };
    assert_exact_merge_matches_direct(&x, &spec, "block-diagonal");
    let pf = DirectLingam::new().fit_plan(&x, &PartitionedPlan::new(spec)).unwrap();
    assert_eq!(pf.blocks_formed, 2, "two independent chains must form two blocks");
    assert!(
        pf.boundary_pairs > 0,
        "exact tier must report the cross-block work it did not skip"
    );
    // first step: all 8 variables active, 4 per block → 16 of the 28
    // pairs straddle; later steps only shrink that, so the total is
    // bounded by step count × 16
    assert!(pf.boundary_pairs <= 7 * 16);
    // the whole-panel sweep visits everything: counters must say so
    assert_eq!(pf.counters.pairs_visited, pf.counters.pairs_total);
}

#[test]
fn exact_merge_survives_degenerate_panels_like_the_direct_fit() {
    // duplicated column: fit and fit_plan must agree on usability, and
    // on the fit itself when both succeed
    let mut dup = layered_panel(600, 6, 45);
    let col = dup.col(1);
    dup.set_col(4, &col);
    let direct = DirectLingam::new().fit(&dup, &VectorizedEngine);
    let planned = DirectLingam::new().fit_plan(&dup, &PartitionedPlan::new(exact_spec()));
    match (direct, planned) {
        (Ok(d), Ok(p)) => {
            assert_eq!(p.fit.order, d.order, "duplicated column: orders diverged");
            assert_eq!(adjacency_max_diff(&p.fit.adjacency, &d.adjacency), 0.0);
        }
        (Err(_), Err(_)) => {} // both reject the panel: fine
        (d, p) => panic!(
            "duplicated column: fit and fit_plan disagreed on usability: {:?} vs {:?}",
            d.map(|f| f.order),
            p.map(|f| f.fit.order)
        ),
    }

    // a connected panel is one block, zero boundary pairs — and still
    // the identical fit
    let mut rng = Pcg64::seed_from_u64(46);
    let chain = sample_from_dag(&chain_dag(6, 1.0), Noise::Uniform01, 2_000, &mut rng);
    let spec = PartitionSpec { threshold: 0.2, ..exact_spec() };
    assert_exact_merge_matches_direct(&chain, &spec, "connected chain");
    let pf = DirectLingam::new().fit_plan(&chain, &PartitionedPlan::new(spec)).unwrap();
    assert_eq!(pf.blocks_formed, 1, "a connected correlation graph is one block");
    assert_eq!(pf.boundary_pairs, 0, "one block has no boundary");
}

#[test]
fn partition_rejects_exactly_what_the_direct_fit_rejects() {
    // the hoisted-validation satellite: fit_plan runs the same panel
    // validation as fit, before the plan ever sees the data — identical
    // error strings, not merely identical error-ness
    let nan = {
        let mut m = layered_panel(300, 5, 47);
        m[(7, 2)] = f64::NAN;
        m
    };
    let constant = {
        let mut m = layered_panel(300, 5, 48);
        let c = vec![0.1; 300];
        m.set_col(2, &c);
        m
    };
    let single_col = Mat::from_fn(100, 1, |r, _| r as f64);
    let short = Mat::from_fn(4, 3, |r, c| (r * 3 + c) as f64);
    for (label, x) in
        [("NaN entry", nan), ("constant column", constant), ("d=1", single_col), ("n<8", short)]
    {
        let direct = DirectLingam::new().fit(&x, &VectorizedEngine);
        let planned = DirectLingam::new().fit_plan(&x, &PartitionedPlan::new(exact_spec()));
        let de = direct.err().unwrap_or_else(|| panic!("{label}: direct fit accepted the panel"));
        let pe = planned.err().unwrap_or_else(|| panic!("{label}: fit_plan accepted the panel"));
        assert_eq!(de.to_string(), pe.to_string(), "{label}: rejection messages diverged");
    }
}

#[test]
fn approx_merge_recovers_block_diagonal_structure() {
    let (x, truth) = block_diagonal_panel(4_000, 4, 4, 49);
    let spec = PartitionSpec {
        threshold: 0.2,
        merge: MergeMode::Approx,
        workers: 1,
        ..PartitionSpec::default()
    };
    let pf = DirectLingam::new().fit_plan(&x, &PartitionedPlan::new(spec)).unwrap();
    let mut sorted = pf.fit.order.clone();
    sorted.sort_unstable();
    assert_eq!(sorted, (0..8).collect::<Vec<_>>(), "approx order must be a permutation");
    assert_eq!(pf.blocks_formed, 2);
    assert!(pf.boundary_pairs > 0, "tournament merge must visit boundary pairs");
    assert!(
        pf.fit.step_scores.is_empty(),
        "block-local scores are not globally comparable; approx must not report them"
    );
    // on a truly separable panel the blockwise fit is two clean chain
    // fits: the merged order must be consistent with the truth and the
    // adjacency must recover the chains
    assert!(
        alingam::graph::order_consistent(&truth, &pf.fit.order),
        "approx order {:?} inconsistent with block-diagonal truth",
        pf.fit.order
    );
    let m = graph_metrics(&truth, &pf.fit.adjacency, 0.1);
    assert!(m.f1 >= 0.75, "approx F1 too low on a separable panel: {m:?}");
}

#[test]
fn approx_merge_on_one_block_is_the_blockwise_serial_fit() {
    // connected panel → one block → the approx tier is a single serial
    // whole-panel session with no tournament at all: exactly the direct
    // fit, with zero boundary pairs
    let mut rng = Pcg64::seed_from_u64(50);
    let x = sample_from_dag(&chain_dag(6, 1.0), Noise::Uniform01, 2_000, &mut rng);
    let spec = PartitionSpec {
        threshold: 0.2,
        merge: MergeMode::Approx,
        workers: 1,
        ..PartitionSpec::default()
    };
    let direct = DirectLingam::new().fit(&x, &VectorizedEngine).unwrap();
    let pf = DirectLingam::new().fit_plan(&x, &PartitionedPlan::new(spec)).unwrap();
    assert_eq!(pf.fit.order, direct.order, "single-block approx diverged from direct");
    assert_eq!(adjacency_max_diff(&pf.fit.adjacency, &direct.adjacency), 0.0);
    assert_eq!(pf.blocks_formed, 1);
    assert_eq!(pf.boundary_pairs, 0);
}

#[test]
fn block_cap_still_merges_exactly() {
    // partition:1 degenerates to the whole panel — the cap must not
    // change the exact tier's output, only its instrumentation
    let (x, _truth) = block_diagonal_panel(2_000, 3, 3, 51);
    let spec = PartitionSpec { max_blocks: 1, threshold: 0.2, ..exact_spec() };
    assert_exact_merge_matches_direct(&x, &spec, "capped");
    let pf = DirectLingam::new().fit_plan(&x, &PartitionedPlan::new(spec)).unwrap();
    assert_eq!(pf.blocks_formed, 1, "cap of 1 must merge everything");
    assert_eq!(pf.boundary_pairs, 0);
}
