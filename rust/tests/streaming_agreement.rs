//! Streaming-window acceptance: a full update/downdate slide of the
//! window reproduces the from-scratch fit within tolerance and is
//! bitwise identical immediately after a moment resync; the held-order
//! incremental refit is ≥ 5× faster than a from-scratch fit of the
//! identical window at d=64 / n=512; and a live `watch` stream over a
//! real loopback socket turns frames into adjacency updates, cancels
//! mid-stream, and books the streaming metrics counters.

use alingam::lingam::prune::{estimate_adjacency, PruneMethod};
use alingam::lingam::{
    DirectLingam, IncrementalSession, RefitKind, StreamingConfig, StreamingLingam,
};
use alingam::linalg::Mat;
use alingam::serve::protocol::{self, Json};
use alingam::serve::{ServeConfig, Server};
use alingam::sim::{simulate_sem, simulate_var, SemSpec, VarSpec};
use alingam::stats;
use alingam::util::rng::Pcg64;
use std::io::{BufRead, BufReader, Write};
use std::net::{SocketAddr, TcpStream};
use std::time::Instant;

fn no_resync() -> StreamingConfig {
    StreamingConfig { resync_every: 0, drift_tol: f64::INFINITY }
}

fn sem_rows(d: usize, n: usize, seed: u64) -> Vec<Vec<f64>> {
    let mut rng = Pcg64::seed_from_u64(seed);
    let ds = simulate_sem(&SemSpec::layered(d, 2, 0.7), n, &mut rng);
    (0..n).map(|r| ds.data.row(r).to_vec()).collect()
}

/// From-scratch reference on the identical window: a fresh session over
/// the materialized panel, matching [`StreamingLingam::new`] settings
/// (1 worker, exact sweep, OLS threshold 0.05).
fn from_scratch(panel: &Mat) -> alingam::lingam::LingamFit {
    let mut session = IncrementalSession::new(panel, 1, false).expect("reference session");
    DirectLingam::with_prune(PruneMethod::OlsThreshold(0.05))
        .fit_session(panel, &mut session)
        .expect("reference fit")
}

/// Acceptance (a), tolerance half: slide the window through a FULL
/// turnover — every seed sample enters and later leaves under rank-1
/// update/downdate, with resync disabled — and the maintained moments
/// and held-order adjacency must still match a from-scratch computation
/// on the surviving rows.
#[test]
fn full_window_slide_reproduces_from_scratch_fit_within_tolerance() {
    let (d, cap) = (8, 128);
    let rows = sem_rows(d, 2 * cap + 1, 7);
    let mut s = StreamingLingam::new(d, cap, no_resync()).unwrap();
    let mut last = None;
    for row in &rows {
        if let Some(out) = s.ingest(row).unwrap() {
            last = Some(out);
        }
    }
    // every original sample was downdated back out, never resynced
    assert_eq!(s.window().frames(), (2 * cap + 1) as u64);
    assert_eq!(s.window().resyncs(), 0, "slide must stay on the update/downdate path");
    let out = last.expect("full window produced no outcome");
    assert_eq!(out.refit, RefitKind::Incremental);

    // maintained moments vs direct computation on the surviving rows
    let panel = s.window().panel();
    for a in 0..d {
        let col_a = panel.col(a);
        assert!(
            (s.window().mean_of(a) - stats::mean(&col_a)).abs() < 1e-8,
            "mean[{a}] drifted after a full slide"
        );
        for b in 0..d {
            let direct = stats::cov(&col_a, &panel.col(b));
            assert!(
                (s.window().cov(a, b) - direct).abs() < 1e-8,
                "cov[{a},{b}]: maintained {} vs from-scratch {direct}",
                s.window().cov(a, b)
            );
        }
    }

    // held-order adjacency vs a from-scratch OLS on the raw window
    let reference =
        estimate_adjacency(&panel, &out.order, PruneMethod::OlsThreshold(0.05)).unwrap();
    let err = out.b0.sub(&reference).max_abs();
    assert!(err < 1e-6, "moment-space B0 off from-scratch OLS by {err}");

    // and when the from-scratch sweep lands on the same order, the full
    // fits agree too (the held order may legitimately lag a flip)
    let scratch = from_scratch(&panel);
    if scratch.order == out.order {
        let err = out.b0.sub(&scratch.adjacency).max_abs();
        assert!(err < 1e-6, "B0 off from-scratch fit by {err}");
    }
}

/// Acceptance (a), bitwise half: the frame on which the periodic resync
/// fires re-materializes raw columns and re-runs the full sweep from a
/// workspace bitwise identical to a fresh session's — so its fit must
/// equal the from-scratch fit bit for bit, not just within tolerance.
#[test]
fn resynced_frame_is_bitwise_identical_to_from_scratch_fit() {
    let (d, cap) = (6, 64);
    let cfg = StreamingConfig { resync_every: 96, drift_tol: f64::INFINITY };
    let rows = sem_rows(d, 100, 11);
    let mut s = StreamingLingam::new(d, cap, cfg).unwrap();
    let mut resynced = None;
    for row in &rows {
        if let Some(out) = s.ingest(row).unwrap() {
            if out.resynced && resynced.is_none() {
                resynced = Some(out);
                break;
            }
        }
    }
    let out = resynced.expect("resync cadence never fired within 100 frames");
    assert_eq!(out.refit, RefitKind::Full);
    let panel = s.window().panel();
    let scratch = from_scratch(&panel);
    assert_eq!(out.order, scratch.order, "resynced order must equal the from-scratch order");
    for i in 0..d {
        for j in 0..d {
            assert_eq!(
                out.b0[(i, j)].to_bits(),
                scratch.adjacency[(i, j)].to_bits(),
                "B0[{i},{j}] not bitwise after resync: {} vs {}",
                out.b0[(i, j)],
                scratch.adjacency[(i, j)]
            );
        }
    }
}

/// Acceptance (b): at d=64 over a 512-sample window, the held-order
/// incremental per-frame refit must be ≥ 5× faster than re-fitting the
/// identical window from scratch. (The real margin is orders of
/// magnitude — the incremental path never touches the raw panel.)
#[test]
fn incremental_refit_is_5x_faster_than_from_scratch_at_d64_n512() {
    let (d, cap) = (64, 512);
    let frames = 8usize;
    let rows = sem_rows(d, cap + frames, 13);
    let mut s = StreamingLingam::new(d, cap, no_resync()).unwrap();
    for row in rows.iter().take(cap) {
        s.ingest(row).unwrap();
    }
    assert_eq!(s.refits_full(), 1, "window fill must run exactly one full sweep");

    let t0 = Instant::now();
    for row in rows.iter().skip(cap) {
        let out = s.ingest(row).unwrap().expect("full window emits a frame");
        assert_eq!(out.refit, RefitKind::Incremental);
    }
    let incremental_ms = t0.elapsed().as_secs_f64() * 1e3 / frames as f64;

    let panel = s.window().panel();
    let t1 = Instant::now();
    let reps = 2usize;
    for _ in 0..reps {
        std::hint::black_box(from_scratch(&panel));
    }
    let scratch_ms = t1.elapsed().as_secs_f64() * 1e3 / reps as f64;

    assert!(
        scratch_ms >= 5.0 * incremental_ms,
        "incremental refit not ≥5× faster: {incremental_ms:.3} ms/frame incremental \
         vs {scratch_ms:.3} ms/frame from scratch"
    );
}

// ---------------------------------------------------------------------
// Socket-level watch stream (acceptance c)
// ---------------------------------------------------------------------

fn start(workers: usize, queue: usize) -> Server {
    Server::start(ServeConfig {
        addr: "127.0.0.1:0".to_string(),
        workers,
        queue_capacity: queue,
        cache_entries: 0,
        fuse_wait_ms: 0,
        max_batch: 1,
        ..ServeConfig::default()
    })
    .expect("server start")
}

struct Client {
    reader: BufReader<TcpStream>,
    writer: TcpStream,
}

impl Client {
    fn connect(addr: SocketAddr) -> Client {
        let writer = TcpStream::connect(addr).expect("connect");
        let reader = BufReader::new(writer.try_clone().expect("clone stream"));
        Client { reader, writer }
    }

    fn send(&mut self, line: &str) {
        self.writer.write_all(line.as_bytes()).expect("send");
        self.writer.write_all(b"\n").expect("send newline");
    }

    fn recv(&mut self) -> Json {
        let mut line = String::new();
        let n = self.reader.read_line(&mut line).expect("recv");
        assert!(n > 0, "connection closed mid-stream");
        protocol::parse_json(line.trim_end()).expect("server frames must be valid json")
    }

    fn recv_event(&mut self, event: &str) -> Json {
        loop {
            let f = self.recv();
            if f.get("event").and_then(Json::as_str) == Some(event) {
                return f;
            }
        }
    }

    fn recv_terminal(&mut self, id: &str) -> (String, Json) {
        loop {
            let f = self.recv();
            if f.get("id").and_then(Json::as_str) != Some(id) {
                continue;
            }
            if let Some(ev @ ("result" | "error" | "canceled")) =
                f.get("event").and_then(Json::as_str)
            {
                let ev = ev.to_string();
                return (ev, f);
            }
        }
    }
}

fn watch_counter(frame: &Json, key: &str) -> u64 {
    frame
        .get("watch")
        .and_then(|w| w.get(key))
        .and_then(Json::as_u64)
        .unwrap_or_else(|| panic!("metrics frame missing watch.{key}"))
}

/// Acceptance (c): subscribe, stream 26 frames into a 16-sample window,
/// read one adjacency frame per post-fill sample (the first a full
/// sweep, the rest held-order incremental), end the stream gracefully,
/// and find every streaming counter booked in `metrics`.
#[test]
fn watch_stream_turns_frames_into_adjacency_updates_over_the_socket() {
    let server = start(1, 8);
    let (d, window, total) = (3usize, 16usize, 26usize);
    let rows = sem_rows(d, total, 31);
    let mut c = Client::connect(server.local_addr());
    c.send(&protocol::watch_request("w1", "vectorized", d, window, 0, 0, 1e-3, 0.05));
    let _ = c.recv_event("accepted");
    for row in &rows {
        c.send(&protocol::watch_frame_request("w1", row));
    }
    // one adjacency frame per sample once the window filled
    let mut refits = Vec::new();
    for k in 0..=(total - window) {
        let f = c.recv_event("adjacency");
        assert_eq!(f.get("id").and_then(Json::as_str), Some("w1"));
        assert_eq!(f.get("frame").and_then(Json::as_u64), Some((window + k) as u64));
        assert_eq!(f.get("resynced").and_then(Json::as_bool), Some(false));
        let data = f.get("data").expect("adjacency frame carries data");
        assert_eq!(data.get("kind").and_then(Json::as_str), Some("watch"));
        let order = data.get("order").and_then(Json::as_arr).expect("data.order");
        assert_eq!(order.len(), d);
        let b0 = data.get("b0").and_then(|m| protocol::parse_mat(m).ok()).expect("data.b0");
        assert_eq!((b0.rows(), b0.cols()), (d, d));
        assert_eq!(
            data.get("b_tau").and_then(Json::as_arr).map(|a| a.len()),
            Some(0),
            "plain watch streams carry no lag matrices"
        );
        refits.push(f.get("refit").and_then(Json::as_str).unwrap_or("").to_string());
    }
    assert_eq!(refits[0], "full", "the fill frame must run the full sweep");
    assert!(
        refits[1..].iter().all(|r| r == "incremental"),
        "post-fill frames must take the held-order fast path: {refits:?}"
    );

    c.send(&protocol::watch_end_request("w1"));
    let (ev, frame) = c.recv_terminal("w1");
    assert_eq!(ev, "result", "graceful end must summarize: {}", frame.render());
    assert_eq!(frame.get("cached").and_then(Json::as_bool), Some(false));
    let data = frame.get("data").expect("summary data");
    assert_eq!(data.get("kind").and_then(Json::as_str), Some("watch_summary"));
    assert_eq!(data.get("frames").and_then(Json::as_u64), Some(total as u64));
    assert_eq!(data.get("refits_full").and_then(Json::as_u64), Some(1));
    assert_eq!(
        data.get("refits_incremental").and_then(Json::as_u64),
        Some((total - window) as u64)
    );

    c.send(&protocol::control_request("metrics"));
    let m = c.recv_event("metrics");
    assert_eq!(watch_counter(&m, "watch_streams"), 0, "gauge must drop after the end");
    assert_eq!(watch_counter(&m, "frames_ingested"), total as u64);
    assert_eq!(watch_counter(&m, "refits_full"), 1);
    assert_eq!(watch_counter(&m, "refits_incremental"), (total - window) as u64);
    let completed = m.get("jobs").and_then(|j| j.get("completed")).and_then(Json::as_u64);
    assert_eq!(completed, Some(1), "an ended stream books as completed");
    server.shutdown();
}

/// Acceptance (c), cancel half: `cancel` lands mid-stream and the
/// subscription answers `canceled` — booked as a canceled job, with the
/// live-stream gauge back at zero.
#[test]
fn watch_stream_cancels_mid_stream() {
    let server = start(1, 8);
    let (d, window) = (3usize, 16usize);
    let rows = sem_rows(d, 20, 37);
    let mut c = Client::connect(server.local_addr());
    c.send(&protocol::watch_request("w2", "vectorized", d, window, 0, 0, 1e-3, 0.05));
    let _ = c.recv_event("accepted");
    for row in &rows {
        c.send(&protocol::watch_frame_request("w2", row));
    }
    // the stream is live (adjacency flowing) when the cancel lands
    let _ = c.recv_event("adjacency");
    c.send(&protocol::cancel_request("w2"));
    let (ev, _) = c.recv_terminal("w2");
    assert_eq!(ev, "canceled");
    c.send(&protocol::control_request("metrics"));
    let m = c.recv_event("metrics");
    assert_eq!(watch_counter(&m, "watch_streams"), 0);
    let canceled = m.get("jobs").and_then(|j| j.get("canceled")).and_then(Json::as_u64);
    assert_eq!(canceled, Some(1), "a canceled stream books as canceled: {}", m.render());
    server.shutdown();
}

/// A `lags ≥ 1` subscription runs the streaming VAR-LiNGAM estimator:
/// adjacency frames carry one lag matrix per lag next to B̂₀.
#[test]
fn watch_stream_with_lags_streams_var_lag_matrices() {
    let server = start(1, 8);
    let (d, window, lags) = (2usize, 16usize, 1usize);
    let mut rng = Pcg64::seed_from_u64(41);
    let ds = simulate_var(&VarSpec { dim: d, ..VarSpec::default() }, 24, &mut rng);
    let mut c = Client::connect(server.local_addr());
    c.send(&protocol::watch_request("w3", "vectorized", d, window, lags, 0, 1e-3, 0.05));
    let _ = c.recv_event("accepted");
    for t in 0..24 {
        c.send(&protocol::watch_frame_request("w3", ds.data.row(t)));
    }
    // first outcome needs `lags` history rows plus `window` embedded
    let f = c.recv_event("adjacency");
    assert_eq!(f.get("frame").and_then(Json::as_u64), Some((window + lags) as u64));
    assert_eq!(f.get("refit").and_then(Json::as_str), Some("full"));
    let data = f.get("data").expect("adjacency data");
    let b_tau = data.get("b_tau").and_then(Json::as_arr).expect("data.b_tau");
    assert_eq!(b_tau.len(), lags, "one lag matrix per lag");
    let b1 = protocol::parse_mat(&b_tau[0]).expect("b_tau[0] parses");
    assert_eq!((b1.rows(), b1.cols()), (d, d));
    let next = c.recv_event("adjacency");
    assert_eq!(next.get("refit").and_then(Json::as_str), Some("incremental"));
    c.send(&protocol::watch_end_request("w3"));
    let (ev, frame) = c.recv_terminal("w3");
    assert_eq!(ev, "result");
    let data = frame.get("data").expect("summary data");
    assert_eq!(data.get("frames").and_then(Json::as_u64), Some(24));
    server.shutdown();
}
