//! Fuzz-ish property coverage for the serve wire protocol: the parser
//! is total — random garbage and mutated valid frames must produce
//! `Ok`/`Err`, never a panic (the server turns every `Err` into an
//! `error` frame and keeps the connection alive) — and well-formed
//! inline-panel requests round-trip exactly.

use alingam::linalg::Mat;
use alingam::serve::protocol::{self, Json, PanelSource, Request};
use alingam::util::prop::props;

#[test]
fn random_garbage_never_panics_the_parser() {
    props("garbage frames error cleanly", 200, |g| {
        let len = g.usize_in(0, 256);
        let bytes: Vec<u8> = (0..len).map(|_| g.rng().below(256) as u8).collect();
        let s = String::from_utf8_lossy(&bytes).into_owned();
        let _ = protocol::parse_json(&s);
        let _ = protocol::parse_request(&s);
    });
}

#[test]
fn structured_garbage_never_panics_the_parser() {
    // garbage drawn from JSON's own alphabet reaches much deeper into
    // the parser than uniform bytes do
    const ALPHABET: &[u8] = b"{}[]\",:.\\u0123456789eE+-truefalsn ";
    props("json-alphabet garbage errors cleanly", 300, |g| {
        let len = g.usize_in(0, 120);
        let bytes: Vec<u8> =
            (0..len).map(|_| ALPHABET[g.rng().below(ALPHABET.len())]).collect();
        let s = String::from_utf8_lossy(&bytes).into_owned();
        let _ = protocol::parse_json(&s);
        let _ = protocol::parse_request(&s);
    });
}

#[test]
fn mutated_valid_frames_never_panic() {
    props("mutated frames error cleanly", 150, |g| {
        let d = g.usize_in(2, 4);
        let n = g.usize_in(2, 5);
        let m = Mat::from_fn(n, d, |_, _| g.normal());
        let frame = match g.usize_in(0, 2) {
            0 => protocol::fit_request("id-1", "parallel:2", &m),
            1 => protocol::bootstrap_request("id-2", "pruned", &m, 10, 3, 0.5),
            _ => protocol::var_request("id-3", "vectorized", &m, 1),
        };
        let mut bytes = frame.into_bytes();
        for _ in 0..g.usize_in(1, 6) {
            let pos = g.rng().below(bytes.len());
            bytes[pos] = g.rng().below(256) as u8;
        }
        if g.bool_p(0.3) {
            let cut = g.rng().below(bytes.len() + 1);
            bytes.truncate(cut);
        }
        let s = String::from_utf8_lossy(&bytes).into_owned();
        let _ = protocol::parse_request(&s);
    });
}

#[test]
fn inline_panel_requests_roundtrip_exactly() {
    props("inline panels roundtrip", 40, |g| {
        let d = g.usize_in(2, 6);
        let n = g.usize_in(2, 8);
        let m = Mat::from_fn(n, d, |_, _| g.normal());
        let line = protocol::fit_request("rt", "pruned:3", &m);
        match protocol::parse_request(&line).expect("valid frame") {
            Request::Job(spec) => {
                assert_eq!(spec.id, "rt");
                assert_eq!(spec.engine, "pruned:3");
                match spec.panel {
                    PanelSource::Inline(p) => assert_eq!(p, m, "panel bits must survive"),
                    other => panic!("unexpected source {other:?}"),
                }
            }
            other => panic!("unexpected request {other:?}"),
        }
    });
}

#[test]
fn rendered_json_reparses_to_the_same_value() {
    props("render∘parse is the identity", 60, |g| {
        // build a random shallow value, render, reparse
        let mut kvs = Vec::new();
        for k in 0..g.usize_in(0, 5) {
            let v = match g.usize_in(0, 3) {
                0 => Json::Num((g.normal() * 100.0).round() / 8.0),
                1 => Json::Str(format!("s-{}\n\"{}\"", k, g.usize_in(0, 9))),
                2 => Json::Bool(g.bool_p(0.5)),
                _ => Json::Arr(vec![Json::Null, Json::Num(g.usize_in(0, 99) as f64)]),
            };
            kvs.push((format!("k{k}"), v));
        }
        let v = Json::Obj(kvs);
        assert_eq!(protocol::parse_json(&v.render()).expect("rendered json parses"), v);
    });
}
