//! Pruning-exactness suite: the bound-pruned sweep (`lingam::sweep`)
//! must select the **identical** root sequence — and carry the
//! **identical** (bitwise) winning score — as the exact sweep, on random
//! panels, degenerate panels, and through every wired path: the
//! stateless pruned engine, the serial and pooled pruned sessions, and
//! the CLI-facing `pruned[:N]` engine.
//!
//! Why bitwise identity is even possible: a completed candidate's
//! penalty is accumulated over ascending pair index, the same order as
//! the exact serial sweep, over the same kernel values (the canonical
//! (min, max) evaluation direction, negated exactly for the reverse);
//! pruned candidates report partial penalties strictly *above* the
//! winner's total, so they can never steal the argmax. The exact
//! reference below is therefore `VectorizedEngine`/the exact session
//! (serial accumulation) rather than the tiled sweep, whose merge
//! associates sums differently (1e-9-level slop the repo tolerates
//! elsewhere).

use alingam::lingam::engine::INACTIVE_SCORE;
use alingam::lingam::{
    DirectLingam, IncrementalSession, OrderingEngine, OrderingSession, ParallelEngine,
    SequentialEngine, SweepCounters, SweepStrategy, VectorizedEngine,
};
use alingam::linalg::Mat;
use alingam::sim::{sample_from_dag, simulate_sem, Noise, SemSpec};
use alingam::util::prop::props;
use alingam::util::rng::Pcg64;

fn toy_panel(n: usize, d: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::seed_from_u64(seed);
    simulate_sem(&SemSpec::layered(d, 2, 0.6), n, &mut rng).data
}

/// A d-variable chain 0 → 1 → … → d−1 with uniform noise: the panel the
/// acceptance criteria quote (clear root separation, so the bound
/// tightens immediately). Shares `graph::chain_dag` with the
/// `sweep_pruning` bench so both measure/pin the same panel.
fn chain_panel(n: usize, d: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::seed_from_u64(seed);
    sample_from_dag(&alingam::graph::chain_dag(d, 1.0), Noise::Uniform01, n, &mut rng)
}

/// Drive exact and pruned sessions side by side to completion, asserting
/// the identical choice and the bitwise-identical winning score at every
/// step.
fn assert_sessions_agree(mut exact: IncrementalSession, mut pruned: IncrementalSession) {
    let d = exact.active().len();
    for step_no in 0..(d - 1) {
        let e = exact.step().unwrap();
        let p = pruned.step().unwrap();
        assert_eq!(
            e.chosen, p.chosen,
            "step {step_no}: pruned chose {} but exact chose {}",
            p.chosen, e.chosen
        );
        assert_eq!(
            e.scores[e.chosen], p.scores[p.chosen],
            "step {step_no}: winning score not bitwise-identical"
        );
        // pruned candidates stop early, so their partial penalties are
        // *upper* bounds on the score: never below the exact score, and
        // never above the winner's
        for i in 0..d {
            let (se, sp) = (e.scores[i], p.scores[i]);
            if se == INACTIVE_SCORE {
                assert_eq!(sp, INACTIVE_SCORE, "step {step_no} var {i}");
                continue;
            }
            if se.is_nan() || sp.is_nan() {
                continue;
            }
            assert!(
                sp >= se,
                "step {step_no} var {i}: pruned partial score {sp} below exact {se}"
            );
            assert!(
                sp <= p.scores[p.chosen],
                "step {step_no} var {i}: pruned score {sp} above the winner's"
            );
        }
    }
}

#[test]
fn pruned_session_matches_exact_session_on_chain() {
    let x = chain_panel(3_000, 8, 1);
    let exact = IncrementalSession::new(&x, 1, false).unwrap();
    let pruned =
        IncrementalSession::with_strategy(&x, 1, false, SweepStrategy::Pruned).unwrap();
    assert_sessions_agree(exact, pruned);
}

#[test]
fn pooled_pruned_session_matches_exact_session() {
    // force_parallel: the toy panel is below the pool cutoff and the
    // shared-atomic-bound path is what needs coverage
    let x = chain_panel(2_000, 8, 2);
    let exact = IncrementalSession::new(&x, 1, false).unwrap();
    let pruned =
        IncrementalSession::with_strategy(&x, 4, true, SweepStrategy::Pruned).unwrap();
    assert_sessions_agree(exact, pruned);
}

#[test]
fn prop_pruned_sessions_match_exact_on_random_panels() {
    props("pruned session vs exact session", 15, |g| {
        let d = g.usize_in(4, 10);
        let n = g.usize_in(64, 400);
        let seed = g.rng().next_u64();
        let mut rng = Pcg64::seed_from_u64(seed);
        let ds = simulate_sem(&SemSpec::layered(d, 2, 0.6), n, &mut rng);
        let workers = g.usize_in(1, 4);
        let exact = IncrementalSession::new(&ds.data, 1, false).unwrap();
        let pruned = IncrementalSession::with_strategy(
            &ds.data,
            workers,
            workers > 1,
            SweepStrategy::Pruned,
        )
        .unwrap();
        assert_sessions_agree(exact, pruned);
    });
}

#[test]
fn pruned_fits_produce_identical_orders_across_engines() {
    // full-fit agreement for every pruned path against the exact CPU
    // engines (sequential reference included — the paper's validation,
    // extended to the pruned sweep). Same panel as engine_agreement's
    // three_cpu_engines_identical_orders_on_one_fit, which pins that
    // seq/vec agree here.
    let mut rng = Pcg64::seed_from_u64(17);
    let x = simulate_sem(&SemSpec::layered(9, 2, 0.5), 3_000, &mut rng).data;
    let seq = DirectLingam::new().fit(&x, &SequentialEngine).unwrap();
    let vec = DirectLingam::new().fit(&x, &VectorizedEngine).unwrap();
    let pruned_serial =
        DirectLingam::new().fit(&x, &ParallelEngine::new(1).with_pruning()).unwrap();
    let pruned_pooled = DirectLingam::new()
        .fit(&x, &ParallelEngine::new(4).with_pruning().force_parallel())
        .unwrap();
    assert_eq!(seq.order, vec.order);
    assert_eq!(vec.order, pruned_serial.order, "serial pruned fit diverged");
    assert_eq!(vec.order, pruned_pooled.order, "pooled pruned fit diverged");
    assert!(
        alingam::metrics::adjacency_max_diff(&vec.adjacency, &pruned_serial.adjacency) < 1e-10,
        "identical orders must give identical regressions"
    );
}

#[test]
fn prop_stateless_pruned_scores_pick_the_exact_argmax() {
    // the stateless pruned path (no session, no priority seed): same
    // argmax and bitwise winning score as the serial exact engine, on
    // random panels and random active masks
    props("stateless pruned vs exact scores", 15, |g| {
        let d = g.usize_in(3, 11);
        let n = g.usize_in(64, 384);
        let seed = g.rng().next_u64();
        let mut rng = Pcg64::seed_from_u64(seed);
        let ds = simulate_sem(&SemSpec::layered(d, 2, 0.6), n, &mut rng);
        let mut active = vec![true; d];
        for slot in active.iter_mut() {
            if g.bool_p(0.2) {
                *slot = false;
            }
        }
        if active.iter().filter(|&&a| a).count() < 2 {
            active[0] = true;
            active[1] = true;
        }
        let workers = g.usize_in(1, 4);
        let exact = VectorizedEngine.scores(&ds.data, &active).unwrap();
        let engine = if workers > 1 {
            ParallelEngine::new(workers).with_pruning().force_parallel()
        } else {
            ParallelEngine::new(1).with_pruning()
        };
        let pruned = engine.scores(&ds.data, &active).unwrap();
        let we = alingam::lingam::engine::argmax_active(&exact, &active).unwrap();
        let wp = alingam::lingam::engine::argmax_active(&pruned, &active).unwrap();
        assert_eq!(we, wp, "argmax diverged (d={d} n={n} workers={workers})");
        assert_eq!(exact[we], pruned[wp], "winning score not bitwise-identical");
        for i in 0..d {
            if !active[i] {
                assert_eq!(pruned[i], INACTIVE_SCORE);
            }
        }
    });
}

#[test]
fn pruned_sessions_track_exact_on_degenerate_panels() {
    // duplicated / negatively-scaled / near-collinear columns: the
    // pruned session must make the same choices as the exact one for as
    // long as both run, and fail together when the panel is unusable
    let dup = {
        let mut rng = Pcg64::seed_from_u64(7);
        let mut m = Mat::from_fn(300, 5, |_, _| rng.normal());
        let col = m.col(1);
        m.set_col(3, &col);
        m
    };
    let neg = {
        let mut rng = Pcg64::seed_from_u64(8);
        let mut m = Mat::from_fn(300, 4, |_, _| rng.normal());
        let flipped: Vec<f64> = m.col(0).iter().map(|&v| -2.5 * v).collect();
        m.set_col(3, &flipped);
        m
    };
    for (label, x) in [("duplicated column", dup), ("negative duplicate", neg)] {
        let mut exact = IncrementalSession::new(&x, 1, false).unwrap();
        let mut pruned =
            IncrementalSession::with_strategy(&x, 1, false, SweepStrategy::Pruned).unwrap();
        loop {
            match (exact.step(), pruned.step()) {
                (Ok(e), Ok(p)) => {
                    assert_eq!(e.chosen, p.chosen, "{label}: choices diverged");
                    for (i, &v) in p.scores.iter().enumerate() {
                        assert!(!v.is_nan(), "{label}: pruned NaN score at {i}");
                    }
                    if pruned.remaining() <= 1 {
                        break;
                    }
                }
                (Err(_), Err(_)) => break, // both reject the panel: fine
                (e, p) => panic!(
                    "{label}: exact and pruned disagreed on usability: {:?} vs {:?}",
                    e.map(|s| s.chosen),
                    p.map(|s| s.chosen)
                ),
            }
        }
    }
}

/// A chain whose root sits at the *last* index: natural-order scheduling
/// (the pre-seeding step-1 behavior) visits the root's candidate last,
/// while the kurtosis seed should move it to the front.
fn reversed_chain_panel(n: usize, d: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::seed_from_u64(seed);
    let x = sample_from_dag(&alingam::graph::chain_dag(d, 1.0), Noise::Uniform01, n, &mut rng);
    // reverse the columns: variable d−1 becomes the chain root
    let cols: Vec<usize> = (0..d).rev().collect();
    x.select_cols(&cols)
}

#[test]
fn first_step_seeding_keeps_identical_root_sequences() {
    // the satellite requirement: with the kurtosis/variance schedule
    // seed active on step 1, the pruned session must still walk the
    // identical root sequence (with bitwise-identical winning scores) as
    // the exact session — on the root-first chain, the root-last chain
    // (where the seed actually reorders step 1), and random panels
    for (label, x) in [
        ("chain", chain_panel(2_500, 10, 31)),
        ("reversed chain", reversed_chain_panel(2_500, 10, 32)),
        ("layered", toy_panel(1_200, 9, 33)),
    ] {
        let pruned =
            IncrementalSession::with_strategy(&x, 1, false, SweepStrategy::Pruned).unwrap();
        assert_eq!(
            pruned.seed_scores().len(),
            x.cols(),
            "{label}: pruned session must carry a step-1 schedule seed"
        );
        let exact = IncrementalSession::new(&x, 1, false).unwrap();
        assert_sessions_agree(exact, pruned);
    }
}

#[test]
fn first_step_seed_schedules_the_true_root_early_and_prunes() {
    // on the reversed chain the root (last index) is the most
    // non-Gaussian column, so the seed must rank it first and the bound
    // tightens immediately: every other candidate is dominated at step 1.
    // Kernel-call savings are panel-orientation-dependent (ascending-j
    // accumulation meets a root-last chain's penalties only at the end
    // of each row), so here the step-1 saving shows up as pruned
    // candidates and skipped comparisons — the root-first chain below
    // shows the kernel-call saving. Both cells were cross-validated
    // bit-for-bit against a numpy mirror (root seed |kurt| ≈ 1.21 vs
    // 0.63 runner-up; reversed: 15 candidates pruned, 105 comparisons
    // skipped; natural: 15/120 pairs visited at step 1).
    let x = reversed_chain_panel(4_000, 16, 34);
    let mut s = IncrementalSession::with_strategy(&x, 1, false, SweepStrategy::Pruned).unwrap();
    let seeds = s.seed_scores().to_vec();
    let top = (0..seeds.len())
        .max_by(|&a, &b| seeds[a].total_cmp(&seeds[b]))
        .unwrap();
    assert_eq!(top, 15, "kurtosis seed must rank the chain root first: {seeds:?}");
    let step = s.step().unwrap();
    assert_eq!(step.chosen, 15, "step 1 must still choose the true root");
    let c = s.sweep_counters();
    assert!(c.candidates_pruned > 0, "no candidate pruned at step 1: {c:?}");
    assert!(c.pairs_skipped > 0, "no comparison skipped at step 1: {c:?}");

    // root-first chain: the same seeded step-1 sweep saves kernel calls
    let y = chain_panel(4_000, 16, 34);
    let mut s = IncrementalSession::with_strategy(&y, 1, false, SweepStrategy::Pruned).unwrap();
    let step = s.step().unwrap();
    assert_eq!(step.chosen, 0, "step 1 must choose the chain root");
    let c = s.sweep_counters();
    assert!(
        c.pairs_visited < c.pairs_total,
        "seeded step-1 sweep on a root-first chain saved no kernel calls: {c:?}"
    );
}

#[test]
fn seeded_pruned_fits_match_exact_fits_on_reversed_chain() {
    let x = reversed_chain_panel(2_000, 12, 35);
    let exact = DirectLingam::new().fit(&x, &VectorizedEngine).unwrap();
    let pruned = DirectLingam::new().fit(&x, &ParallelEngine::new(1).with_pruning()).unwrap();
    let pooled = DirectLingam::new()
        .fit(&x, &ParallelEngine::new(4).with_pruning().force_parallel())
        .unwrap();
    assert_eq!(exact.order, pruned.order, "seeded serial pruned fit diverged");
    assert_eq!(exact.order, pooled.order, "seeded pooled pruned fit diverged");
}

#[test]
fn pruned_engine_rejects_constant_columns_like_exact() {
    let mut x = toy_panel(400, 5, 9);
    let constant = vec![0.1; 400];
    x.set_col(2, &constant);
    let res = DirectLingam::new().fit(&x, &ParallelEngine::new(1).with_pruning());
    assert!(res.is_err(), "constant column must be rejected up front");
}

#[test]
fn counters_report_pruning_on_chain_sem_d32() {
    // the acceptance criterion: on a d ≥ 32 chain SEM the pruned sweep
    // must actually skip work, and the counters must say so
    let x = chain_panel(2_000, 32, 11);
    let mut s = IncrementalSession::with_strategy(&x, 1, false, SweepStrategy::Pruned).unwrap();
    while s.remaining() > 1 {
        s.step().unwrap();
    }
    let c = s.sweep_counters();
    assert!(c.pairs_total > 0);
    assert!(c.pairs_skipped > 0, "no pair skipped on a chain SEM: {c:?}");
    assert!(c.candidates_pruned > 0, "no candidate pruned on a chain SEM: {c:?}");
    assert!(
        c.pairs_visited < c.pairs_total,
        "pruning saved no kernel calls: {c:?}"
    );
    assert_eq!(c.elements_touched, c.pairs_visited * 2_000);
    assert!(c.visited_fraction() < 1.0);
}

#[test]
fn exact_sessions_report_full_visits_and_reset_clears() {
    let x = toy_panel(500, 6, 12);
    let mut s = IncrementalSession::new(&x, 1, false).unwrap();
    assert_eq!(s.sweep_counters(), SweepCounters::default(), "fresh session must be zeroed");
    while s.remaining() > 1 {
        s.step().unwrap();
    }
    let c = s.sweep_counters();
    assert!(c.pairs_total > 0);
    assert_eq!(c.pairs_visited, c.pairs_total, "exact mode must visit everything");
    assert_eq!(c.pairs_skipped, 0);
    assert_eq!(c.candidates_pruned, 0);
    s.reset(&x).unwrap();
    assert_eq!(s.sweep_counters(), SweepCounters::default(), "reset must zero the counters");
}

#[test]
fn stateless_shim_reports_zero_counters() {
    // the OrderingSession surface default: sessions without an
    // instrumented sweep answer with zeros rather than lying
    let x = toy_panel(300, 4, 13);
    let session = SequentialEngine.session(&x).unwrap();
    assert_eq!(session.sweep_counters(), SweepCounters::default());
}

#[test]
fn pruned_session_reuse_across_resamples_matches_fresh_fits() {
    // the bootstrap pool pattern under the pruned strategy: reset +
    // fit_session must equal a fresh exact fit on every resample
    let base = toy_panel(600, 6, 21);
    let mut rng = Pcg64::seed_from_u64(22);
    let engine = ParallelEngine::new(1).with_pruning();
    let mut session = engine.session(&base).unwrap();
    for _ in 0..3 {
        let rows: Vec<usize> = (0..base.rows()).map(|_| rng.below(base.rows())).collect();
        let sample = base.select_rows(&rows);
        session.reset(&sample).unwrap();
        let reused = DirectLingam::new().fit_session(&sample, session.as_mut()).unwrap();
        let fresh = DirectLingam::new().fit(&sample, &VectorizedEngine).unwrap();
        assert_eq!(reused.order, fresh.order, "pruned pooled fit diverged from fresh exact");
    }
}
