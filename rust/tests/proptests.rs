//! Property-based tests over the library's invariants, using the
//! hand-rolled `util::prop` mini-framework (proptest is not in the
//! offline crate set). Each property runs dozens of randomized cases;
//! failures print a replay seed (`ALINGAM_PROP_SEED=...`).

use alingam::graph::{self, Dag};
use alingam::linalg::{cholesky, expm, lstsq, lu_inverse, lu_solve, Mat};
use alingam::lingam::engine::{argmax_active, residualize_in_place, OrderingEngine};
use alingam::lingam::{DirectLingam, VectorizedEngine};
use alingam::metrics::graph_metrics;
use alingam::sim::{simulate_sem, Noise, SemSpec};
use alingam::stats;
use alingam::util::prop::{props, Gen};
use alingam::util::rng::Pcg64;

// ------------------------------------------------------------- linalg

#[test]
fn prop_matmul_associative() {
    props("matmul associative", 40, |g: &mut Gen| {
        let (m, k, n, p) = (
            g.usize_in(1, 6),
            g.usize_in(1, 6),
            g.usize_in(1, 6),
            g.usize_in(1, 6),
        );
        let a = Mat::from_fn(m, k, |_, _| g.normal());
        let b = Mat::from_fn(k, n, |_, _| g.normal());
        let c = Mat::from_fn(n, p, |_, _| g.normal());
        let left = a.matmul(&b).matmul(&c);
        let right = a.matmul(&b.matmul(&c));
        assert!(left.sub(&right).max_abs() < 1e-9);
    });
}

#[test]
fn prop_lu_solve_solves() {
    props("lu solve residual", 40, |g: &mut Gen| {
        let n = g.usize_in(2, 8);
        // diagonally-dominant → nonsingular
        let mut a = Mat::from_fn(n, n, |_, _| g.normal());
        for i in 0..n {
            a[(i, i)] += n as f64 + 1.0;
        }
        let b = Mat::from_fn(n, 2, |_, _| g.normal());
        let x = lu_solve(&a, &b).unwrap();
        let resid = a.matmul(&x).sub(&b).max_abs();
        assert!(resid < 1e-8, "residual {resid}");
    });
}

#[test]
fn prop_inverse_roundtrip() {
    props("inverse roundtrip", 30, |g: &mut Gen| {
        let n = g.usize_in(2, 7);
        let mut a = Mat::from_fn(n, n, |_, _| g.normal());
        for i in 0..n {
            a[(i, i)] += n as f64 + 1.0;
        }
        let inv = lu_inverse(&a).unwrap();
        assert!(a.matmul(&inv).sub(&Mat::eye(n)).max_abs() < 1e-8);
    });
}

#[test]
fn prop_cholesky_reconstructs_spd() {
    props("cholesky spd", 30, |g: &mut Gen| {
        let n = g.usize_in(2, 6);
        let b = Mat::from_fn(n, n, |_, _| g.normal());
        let spd = b.t().matmul(&b).add(&Mat::eye(n).scale(0.5));
        let l = cholesky(&spd).unwrap();
        assert!(l.matmul(&l.t()).sub(&spd).max_abs() < 1e-9);
    });
}

#[test]
fn prop_lstsq_exact_for_consistent_systems() {
    props("lstsq consistent", 30, |g: &mut Gen| {
        let n = g.usize_in(8, 20);
        let p = g.usize_in(1, 4);
        let a = Mat::from_fn(n, p, |_, _| g.normal());
        let truth = Mat::from_fn(p, 1, |_, _| g.normal());
        let b = a.matmul(&truth);
        let x = lstsq(&a, &b).unwrap();
        assert!(x.sub(&truth).max_abs() < 1e-7);
    });
}

#[test]
fn prop_expm_of_strictly_triangular_has_unit_diagonal() {
    props("expm nilpotent diag", 30, |g: &mut Gen| {
        let n = g.usize_in(2, 6);
        let mut a = Mat::zeros(n, n);
        for i in 0..n {
            for j in (i + 1)..n {
                if g.bool_p(0.6) {
                    a[(i, j)] = g.f64_in(-2.0, 2.0);
                }
            }
        }
        let e = expm(&a).unwrap();
        for i in 0..n {
            assert!((e[(i, i)] - 1.0).abs() < 1e-10);
        }
        // trace == n ⟺ acyclic in the NOTEARS h-function sense
        assert!((e.trace() - n as f64).abs() < 1e-9);
    });
}

// ------------------------------------------------------------- graph/sim

#[test]
fn prop_generated_dags_are_acyclic_and_orderable() {
    props("dag generators acyclic", 40, |g: &mut Gen| {
        let d = g.usize_in(3, 20);
        let levels = g.usize_in(1, d.min(4));
        let p = g.f64_in(0.1, 0.9);
        let dag = graph::layered_dag(d, levels, p, g.rng());
        let order = dag.topological_order().expect("layered DAG acyclic");
        assert!(graph::order_consistent(&dag.adj, &order));

        let er = graph::erdos_renyi_dag(d, g.f64_in(0.5, 3.0), 0.3, 1.5, g.rng());
        assert!(er.topological_order().is_some());
    });
}

#[test]
fn prop_sem_data_respects_root_distribution() {
    props("sem roots uniform", 15, |g: &mut Gen| {
        let seed = g.rng().next_u64();
        let mut rng = Pcg64::seed_from_u64(seed);
        let ds = simulate_sem(&SemSpec::layered(6, 2, 0.5), 4_000, &mut rng);
        // all columns finite; roots have uniform kurtosis (< 0 excess)
        assert!(ds.data.is_finite());
        for i in 0..6 {
            if (0..6).all(|j| ds.adjacency[(i, j)] == 0.0) {
                let col = ds.data.col(i);
                assert!(
                    stats::excess_kurtosis(&col) < 0.0,
                    "root {i} kurtosis not uniform-like"
                );
            }
        }
    });
}

#[test]
fn prop_metrics_identity_and_bounds() {
    props("metrics identity", 40, |g: &mut Gen| {
        let d = g.usize_in(3, 10);
        let dag = graph::erdos_renyi_dag(d, g.f64_in(0.5, 2.0), 0.5, 1.5, g.rng());
        let m = graph_metrics(&dag.adj, &dag.adj, 0.01);
        assert_eq!(m.shd, 0);
        if m.true_edges > 0 {
            assert_eq!(m.f1, 1.0);
        }
        // against the empty graph: SHD = edge count
        let empty = Mat::zeros(d, d);
        let me = graph_metrics(&dag.adj, &empty, 0.01);
        assert_eq!(me.shd, m.true_edges);
        assert!(me.f1 >= 0.0 && me.f1 <= 1.0);
    });
}

// ------------------------------------------------------------- engines

#[test]
fn prop_residualize_kills_covariance() {
    props("residualize orthogonality", 30, |g: &mut Gen| {
        let n = g.usize_in(50, 300);
        let d = g.usize_in(3, 8);
        let mut x = Mat::from_fn(n, d, |_, _| g.normal());
        // inject correlation with column 0
        for r in 0..n {
            let base = x[(r, 0)];
            for c in 1..d {
                let v = x[(r, c)] + 0.7 * base;
                x[(r, c)] = v;
            }
        }
        let active = vec![true; d];
        residualize_in_place(&mut x, &active, 0);
        let x0 = x.col(0);
        for c in 1..d {
            let cv = stats::cov(&x.col(c), &x0);
            assert!(cv.abs() < 1e-8, "col {c} cov {cv}");
        }
    });
}

#[test]
fn prop_order_is_always_valid_permutation() {
    props("fit order permutation", 10, |g: &mut Gen| {
        let d = g.usize_in(3, 8);
        let seed = g.rng().next_u64();
        let mut rng = Pcg64::seed_from_u64(seed);
        let noise = if g.bool_p(0.5) { Noise::Uniform01 } else { Noise::Laplace(1.0) };
        let ds = simulate_sem(&SemSpec::layered(d, 2, 0.5).with_noise(noise), 400, &mut rng);
        let fit = DirectLingam::new().fit(&ds.data, &VectorizedEngine).unwrap();
        let mut o = fit.order.clone();
        o.sort_unstable();
        assert_eq!(o, (0..d).collect::<Vec<_>>());
        // estimated adjacency must be a DAG consistent with the order
        assert!(Dag::new(fit.adjacency.clone()).is_some());
    });
}

#[test]
fn prop_scores_invariant_to_affine_scaling() {
    // Algorithm 1 standardizes internally: scaling any column by a
    // positive constant and shifting must not change the k_list
    props("scores affine invariant", 15, |g: &mut Gen| {
        let seed = g.rng().next_u64();
        let mut rng = Pcg64::seed_from_u64(seed);
        let ds = simulate_sem(&SemSpec::layered(5, 2, 0.6), 600, &mut rng);
        let active = vec![true; 5];
        let k1 = VectorizedEngine.scores(&ds.data, &active).unwrap();
        let mut scaled = ds.data.clone();
        for c in 0..5 {
            let a = g.f64_in(0.1, 10.0);
            let b = g.f64_in(-5.0, 5.0);
            for r in 0..scaled.rows() {
                scaled[(r, c)] = a * scaled[(r, c)] + b;
            }
        }
        let k2 = VectorizedEngine.scores(&scaled, &active).unwrap();
        for i in 0..5 {
            assert!(
                (k1[i] - k2[i]).abs() < 1e-6 * (1.0 + k1[i].abs()),
                "i={i}: {} vs {}",
                k1[i],
                k2[i]
            );
        }
    });
}

#[test]
fn prop_argmax_matches_manual_max() {
    props("argmax consistent", 60, |g: &mut Gen| {
        let d = g.usize_in(1, 12);
        let scores: Vec<f64> = (0..d).map(|_| g.normal()).collect();
        let mut active = vec![false; d];
        let on = g.usize_in(1, d);
        for k in 0..on {
            active[k] = true;
        }
        let best = argmax_active(&scores, &active).unwrap();
        assert!(active[best]);
        for i in 0..d {
            if active[i] {
                assert!(scores[i] <= scores[best]);
            }
        }
    });
}

// ------------------------------------------------------------- data ops

#[test]
fn prop_interpolation_preserves_observed_values() {
    props("interp preserves observed", 30, |g: &mut Gen| {
        let n = g.usize_in(5, 40);
        let mut m = Mat::from_fn(n, 2, |_, _| g.normal());
        let observed = m.clone();
        // punch interior holes
        for r in 1..(n - 1) {
            if g.bool_p(0.3) {
                m[(r, 0)] = f64::NAN;
            }
        }
        let filled = alingam::data::interpolate_columns(&m);
        for r in 0..n {
            if !m[(r, 0)].is_nan() {
                assert_eq!(filled[(r, 0)], observed[(r, 0)]);
            } else {
                assert!(!filled[(r, 0)].is_nan(), "interior gap unfilled");
            }
            assert_eq!(filled[(r, 1)], observed[(r, 1)]);
        }
    });
}

#[test]
fn prop_interpolated_values_within_endpoints() {
    props("interp bounded", 30, |g: &mut Gen| {
        let n = g.usize_in(6, 30);
        let lo = g.f64_in(-10.0, 0.0);
        let hi = g.f64_in(1.0, 10.0);
        let mut m = Mat::zeros(n, 1);
        m[(0, 0)] = lo;
        m[(n - 1, 0)] = hi;
        for r in 1..(n - 1) {
            m[(r, 0)] = f64::NAN;
        }
        let filled = alingam::data::interpolate_columns(&m);
        for r in 0..n {
            let v = filled[(r, 0)];
            assert!(v >= lo.min(hi) - 1e-12 && v <= lo.max(hi) + 1e-12);
        }
        // monotone between endpoints
        for r in 1..n {
            assert!(filled[(r, 0)] >= filled[(r - 1, 0)] - 1e-12);
        }
    });
}

// --------------------------------------------------- obs histograms

#[test]
fn prop_hist_quantiles_track_exact_ranks() {
    use alingam::obs::hist::Histogram;
    props("hist quantile error", 30, |g: &mut Gen| {
        let n = g.usize_in(50, 400);
        // log-uniform latencies spanning µs to tens of seconds — the
        // regime the log-bucketed histogram is built for
        let mut values: Vec<u64> = (0..n)
            .map(|_| 10f64.powf(g.f64_in(0.0, 7.0)).round().max(1.0) as u64)
            .collect();
        let h = Histogram::new();
        for &v in &values {
            h.record_us(v);
        }
        let snap = h.snapshot();
        assert_eq!(snap.count(), n as u64);
        assert_eq!(snap.sum_us(), values.iter().sum::<u64>());
        assert_eq!(snap.max_us(), *values.iter().max().unwrap());
        values.sort_unstable();
        for q in [0.5, 0.95, 0.99] {
            let rank = (((n as f64) * q).ceil() as usize).clamp(1, n) - 1;
            let exact = values[rank] as f64;
            let est = snap.quantile_us(q);
            // bucket width is 2^(1/16) ≈ 4.4%; the midpoint readout
            // halves that, and adjacent ranks inside one bucket add no
            // error — 5% + 1µs covers rounding at the bottom bucket
            let tol = 0.05 * exact + 1.0;
            assert!(
                (est - exact).abs() <= tol,
                "q={q}: estimate {est} vs exact {exact} (n={n})"
            );
        }
    });
}

#[test]
fn prop_hist_merge_equals_single_histogram() {
    use alingam::obs::hist::Histogram;
    props("hist merge", 30, |g: &mut Gen| {
        let n = g.usize_in(2, 300);
        let split = g.usize_in(1, n - 1);
        let values: Vec<u64> =
            (0..n).map(|_| 10f64.powf(g.f64_in(0.0, 6.0)).round().max(1.0) as u64).collect();
        let (a, b) = (Histogram::new(), Histogram::new());
        let whole = Histogram::new();
        for (i, &v) in values.iter().enumerate() {
            if i < split {
                a.record_us(v);
            } else {
                b.record_us(v);
            }
            whole.record_us(v);
        }
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        let direct = whole.snapshot();
        assert_eq!(merged.count(), direct.count());
        assert_eq!(merged.sum_us(), direct.sum_us());
        assert_eq!(merged.max_us(), direct.max_us());
        // bucket-exact: the merged rendering is byte-identical, so the
        // fleet supervisor's re-render loses nothing
        assert_eq!(merged.to_json(), direct.to_json());
        for q in [0.5, 0.9, 0.99] {
            assert_eq!(merged.quantile_us(q), direct.quantile_us(q), "q={q}");
        }
    });
}
