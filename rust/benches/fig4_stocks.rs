//! Figure 4 + Table 2: VarLiNGAM on S&P-500-style hourly stock data.
//!
//! Paper: in/out-degree distributions of θ₀ are roughly symmetric with no
//! dominant hubs; USB and FITB (holding companies) are leaves; the top-5
//! exerting nodes are consumer-facing firms (NVR, AZO, CMG, BKNG, MTD)
//! and the top receivers include NWSA, CNP, FOXA, AMCR.
//!
//! Synthetic market per DESIGN.md §Substitutions (487 real+padded
//! tickers, sector-block VAR(1), heavy-tailed innovations, injected
//! gaps). Full scale (487 × 3500) runs with ALINGAM_BENCH_FULL=1.

mod common;

use alingam::apps::stocks::run_stocks_default;
use alingam::sim::MarketSpec;
use alingam::util::table::{f, histogram, secs, Table};

fn main() {
    common::header(
        "Figure 4 + Table 2 — VarLiNGAM on the stock panel",
        "balanced in/out degrees; USB+FITB leaves; consumer firms exert",
    );
    let spec = if common::full_scale() {
        MarketSpec::default() // 487 × 3500, the paper's dimensions
    } else {
        MarketSpec { dim: 80, t_len: 2_000, ..MarketSpec::small() }
    };
    // the apps' default CPU engine: the auto-sized ParallelEngine
    let r = run_stocks_default(&spec, 2024, 5).expect("stocks pipeline");

    let mut t = Table::new("Table 2 analogue: total causal influence", &["rank", "entity", "score", "role"]);
    for (k, (name, lag, score)) in r.top_exerting.iter().enumerate() {
        t.row(&[(k + 1).to_string(), format!("{name}_tau-{lag}"), f(*score, 3), "exerting".into()]);
    }
    for (k, (name, lag, score)) in r.top_receiving.iter().enumerate() {
        t.row(&[(k + 1).to_string(), format!("{name}_tau-{lag}"), f(*score, 3), "receiving".into()]);
    }
    t.print();

    print!("{}", histogram("Figure 4: in-degree distribution of θ0", &r.in_degrees, 12));
    print!("{}", histogram("Figure 4: out-degree distribution of θ0", &r.out_degrees, 12));

    let mean = |v: &[usize]| v.iter().sum::<usize>() as f64 / v.len() as f64;
    println!("\nshape checks:");
    println!(
        "  in/out mean degree (paper: similar): {:.2} vs {:.2}",
        mean(&r.in_degrees),
        mean(&r.out_degrees)
    );
    println!("  designated exerters (NVR/AZO/CMG/BKNG/MTD) in top-5: {}/5", r.exerter_hits);
    println!("  USB/FITB recovered as leaves: {}/2  (all leaves: {:?})", r.leaf_hits, r.leaves);
    println!("  fit {}  ({:.1}% in causal ordering)", secs(r.fit_secs), 100.0 * r.ordering_frac);
}
