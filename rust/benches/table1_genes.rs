//! Table 1: interventional evaluation on Perturb-seq-style gene data.
//!
//! Paper numbers (real Perturb-CITE-seq, d≈964):
//!     DirectLiNGAM+VI : co-culture 1.5/0.7, IFN 1.5/0.9, control 3/1.6
//!     DCD-FG          : ≈1.1/0.7 on all three            (I-NLL/I-MAE)
//!
//! The dataset here is the synthetic Perturb-seq generator (the real one
//! is access-controlled — DESIGN.md §Substitutions); the comparator is
//! NOTEARS-LR, DCD-FG's published low-rank ancestor. The shape to check:
//! comparable I-MAE between methods, DirectLiNGAM I-NLL slightly higher,
//! control the hardest condition.

mod common;

use alingam::apps::genes::{run_table1_default, GeneScale, GenesConfig};
use alingam::baselines::SvgdOpts;
use alingam::util::table::{f, secs, Table};

fn main() {
    common::header(
        "Table 1 — I-NLL / I-MAE on interventional gene expression",
        "DirectLiNGAM+VI competitive with DCD-FG; lower is better",
    );
    let full = common::full_scale();
    let cfg = GenesConfig {
        scale: if full { GeneScale::Medium } else { GeneScale::Small },
        seed: 2024,
        svgd: if full {
            SvgdOpts { particles: 200, iters: 1000, step: 0.05, seed: 0 }
        } else {
            SvgdOpts { particles: 24, iters: 150, step: 0.1, seed: 0 }
        },
        max_train_rows: if full { 1_000 } else { 300 },
        max_test_cells: if full { 400 } else { 120 },
        with_baseline: true,
    };

    // the apps' default CPU engine: the auto-sized ParallelEngine
    let (rows, dt) = common::time(|| run_table1_default(&cfg).expect("table1"));
    let mut t = Table::new(
        "Table 1 analogue (synthetic Perturb-seq)",
        &["condition", "method", "I-NLL", "I-MAE", "leaves", "fit"],
    );
    for r in &rows {
        t.row(&[
            r.condition.name().into(),
            r.method.into(),
            f(r.metrics.nll, 2),
            f(r.metrics.mae, 2),
            r.leaves.to_string(),
            secs(r.fit_secs),
        ]);
    }
    t.row(&["paper co-culture".into(), "DirectLiNGAM / DCD-FG".into(), "1.5 / 1.1".into(), "0.7 / 0.7".into(), "1".into(), String::new()]);
    t.row(&["paper IFN".into(), "DirectLiNGAM / DCD-FG".into(), "1.5 / 1.2".into(), "0.9 / 0.7".into(), "1".into(), String::new()]);
    t.row(&["paper control".into(), "DirectLiNGAM / DCD-FG".into(), "3.0 / 1.1".into(), "1.6 / 0.7".into(), "2".into(), String::new()]);
    t.print();

    // shape checks
    let get = |cond: &str, method_prefix: &str| {
        rows.iter()
            .find(|r| r.condition.name() == cond && r.method.starts_with(method_prefix))
            .expect("row")
    };
    let dl_control = get("control", "DirectLiNGAM");
    let dl_coc = get("co-culture", "DirectLiNGAM");
    println!("\nshape checks:");
    println!(
        "  control hardest for LiNGAM (paper: 3.0 vs 1.5): {} (nll {} vs {})",
        dl_control.metrics.nll > dl_coc.metrics.nll,
        f(dl_control.metrics.nll, 2),
        f(dl_coc.metrics.nll, 2)
    );
    let mae_gap: f64 = rows
        .iter()
        .filter(|r| r.method.starts_with("DirectLiNGAM"))
        .map(|r| r.metrics.mae)
        .sum::<f64>()
        - rows
            .iter()
            .filter(|r| r.method.starts_with("NOTEARS"))
            .map(|r| r.metrics.mae)
            .sum::<f64>();
    println!("  I-MAE comparable across methods (paper: ±0.2): total gap {:.2}", mae_gap / 3.0);
    println!("total bench time: {}", secs(dt));
}
