//! Figure 2 (top row): profile of the **sequential** DirectLiNGAM
//! implementation.
//!
//! Paper claims: (top-left) the causal-ordering sub-procedure accounts
//! for up to 96% of wall-clock; (top-right) 1e6 samples × 100 variables
//! takes ~7 hours on a server CPU.
//!
//! We measure a feasible grid, report the ordering fraction per cell,
//! then extrapolate the sequential cost to (1e6, 100) via the known
//! O(n·d²·iters) = O(n·d³) ordering complexity.

mod common;

use alingam::coordinator::{profile_direct, ProfileRow};
use alingam::lingam::SequentialEngine;
use alingam::sim::{simulate_sem, SemSpec};
use alingam::util::rng::Pcg64;
use alingam::util::table::{f, secs, Table};

fn main() {
    common::header(
        "Figure 2 (top) — sequential DirectLiNGAM profile + scaling",
        "ordering ≤ 96% of runtime; 1e6 × 100 ≈ 7 CPU-hours",
    );
    let grid: Vec<(usize, usize)> = if common::full_scale() {
        vec![(1_000, 10), (10_000, 10), (10_000, 20), (30_000, 20), (10_000, 40), (50_000, 30)]
    } else {
        vec![(1_000, 5), (1_000, 10), (4_000, 10), (4_000, 15), (10_000, 10), (10_000, 20)]
    };

    let mut rows: Vec<ProfileRow> = Vec::new();
    let mut t = Table::new(
        "sequential profile (Figure 2 top-left analogue)",
        &["samples", "dims", "total", "ordering", "ordering %", "other"],
    );
    for &(n, d) in &grid {
        let mut rng = Pcg64::seed_from_u64(17);
        let ds = simulate_sem(&SemSpec::layered(d, 2, 0.5), n, &mut rng);
        let row = profile_direct(&ds.data, &SequentialEngine).expect("profile");
        t.row(&[
            n.to_string(),
            d.to_string(),
            secs(row.total_secs),
            secs(row.ordering_secs),
            f(100.0 * row.ordering_frac, 1),
            secs(row.other_secs),
        ]);
        rows.push(row);
    }
    t.print();

    let max_frac = rows.iter().map(|r| r.ordering_frac).fold(0.0, f64::max);
    println!("\npeak ordering fraction on this grid: {:.1}% (paper: up to 96%)", 100.0 * max_frac);

    // Figure 2 top-right analogue: extrapolated full-scale cost
    let t_big = alingam::coordinator::profile::extrapolate_seconds(&rows, 1_000_000, 100);
    println!(
        "extrapolated sequential cost at 1e6 samples × 100 dims: {:.1} hours (paper: ~7 h on an \
         AMD EPYC server CPU; single-core sandbox numbers land in the same order of magnitude)",
        t_big / 3600.0
    );
}
