//! Ablation: the fused `order_step` artifact vs the two-phase path
//! (scores artifact + host-side argmax/residualize), and the cost of
//! shape-bucket padding.
//!
//! Design choices under test (DESIGN.md §Perf):
//!  1. fusing argmax+residualize into the artifact vs downloading only
//!     k_list and residualizing on the host (device-call count is the
//!     SAME — the honest measurement here is the work/transfer split);
//!  2. padding a panel into the next shape bucket trades wasted FLOPs
//!     for a bounded artifact inventory.

mod common;

use alingam::lingam::DirectLingam;
use alingam::runtime::XlaEngine;
use alingam::sim::{simulate_sem, SemSpec};
use alingam::util::rng::Pcg64;
use alingam::util::table::{f, secs, Table};

fn main() {
    common::header(
        "Ablation — order_step fusion + bucket padding",
        "(internal design choices; no direct paper analogue)",
    );

    // --- fusion ---
    let mut t = Table::new(
        "fused order_step vs two-phase (scores + host residualize)",
        &["samples", "dims", "fused", "two-phase", "speed-up", "device calls fused/unfused"],
    );
    for &(n, d) in &[(1_000usize, 8usize), (4_000, 16), (4_000, 32)] {
        let mut rng = Pcg64::seed_from_u64(31);
        let ds = simulate_sem(&SemSpec::layered(d, 2, 0.5), n, &mut rng);

        let fused = XlaEngine::from_default_artifacts().expect("artifacts").with_fused(true);
        let _ = DirectLingam::new().fit(&ds.data, &fused).unwrap(); // warm-up (compile)
        let calls0 = fused.executor().stats.snapshot().0;
        let (fit_f, t_fused) =
            common::time(|| DirectLingam::new().fit(&ds.data, &fused).unwrap());
        let calls_fused = fused.executor().stats.snapshot().0 - calls0;

        let unfused = XlaEngine::from_default_artifacts().expect("artifacts").with_fused(false);
        let _ = DirectLingam::new().fit(&ds.data, &unfused).unwrap();
        let calls0 = unfused.executor().stats.snapshot().0;
        let (fit_u, t_unfused) =
            common::time(|| DirectLingam::new().fit(&ds.data, &unfused).unwrap());
        let calls_unfused = unfused.executor().stats.snapshot().0 - calls0;

        assert_eq!(fit_f.order, fit_u.order, "fusion must not change results");
        t.row(&[
            n.to_string(),
            d.to_string(),
            secs(t_fused),
            secs(t_unfused),
            f(t_unfused / t_fused, 2),
            format!("{calls_fused} / {calls_unfused}"),
        ]);
    }
    t.print();

    // --- bucket padding ---
    let mut t = Table::new(
        "bucket-padding overhead (same data, increasingly oversized bucket)",
        &["true n×d", "bucket", "fit time", "overhead ×"],
    );
    let mut rng = Pcg64::seed_from_u64(37);
    let ds = simulate_sem(&SemSpec::layered(8, 2, 0.5), 1_000, &mut rng);
    let engine = XlaEngine::from_default_artifacts().expect("artifacts");
    let _ = DirectLingam::new().fit(&ds.data, &engine).unwrap(); // warm-up
    let (_, t_exact) = common::time(|| DirectLingam::new().fit(&ds.data, &engine).unwrap());
    t.row(&["1000×8".into(), "1024×8 (tight)".into(), secs(t_exact), f(1.0, 2)]);

    // 4× the rows (tiled copies keep the causal structure identical) so
    // the registry must choose the 4096×16 bucket instead of 1024×8
    let engine_big = XlaEngine::from_default_artifacts().expect("artifacts");
    let padded = alingam::linalg::Mat::from_fn(4_000, 8, |r, c| ds.data[(r % 1_000, c)]);
    let _ = DirectLingam::new().fit(&padded, &engine_big).unwrap();
    let (_, t_4x) = common::time(|| DirectLingam::new().fit(&padded, &engine_big).unwrap());
    t.row(&["4000×8 (4× rows)".into(), "4096×16".into(), secs(t_4x), f(t_4x / t_exact, 2)]);
    t.print();

    println!(
        "\nreading: both paths make one device call per iteration; fused trades a\n\
         panel download for skipping the host-side O(n·d) residualization — a\n\
         modest (~3-6%) win at CPU-PJRT bandwidth that grows with d, and the\n\
         prerequisite for a future device-resident panel (no download at all).\n\
         Padded FLOPs scale fit time ~linearly in bucket area, which is why the\n\
         registry picks the minimal-area bucket."
    );
}
