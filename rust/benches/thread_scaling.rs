//! Thread-scaling of the `ParallelEngine` ordering hot path.
//!
//! ParaLiNGAM (Shahbazinia et al.) reports the DirectLiNGAM pair loop
//! scaling near-linearly across CPU threads; this bench measures the same
//! axis for our implementation: `scores` wall-clock at 1/2/4/8 workers
//! against the single-threaded `VectorizedEngine` baseline, at
//! d ∈ {32, 64, 128}. Expected shape: ≥ 2× over vectorized at d ≥ 64
//! with 4+ workers on a ≥ 4-core machine (on a single exposed core the
//! pool degrades gracefully to ~1×).

mod common;

use alingam::lingam::{OrderingEngine, ParallelEngine, VectorizedEngine};
use alingam::sim::{simulate_sem, SemSpec};
use alingam::util::rng::Pcg64;
use alingam::util::table::{f, secs, Table};

fn main() {
    common::header(
        "Thread scaling — ParallelEngine pair-loop speed-up over VectorizedEngine",
        "ParaLiNGAM-style CPU parallelism: near-linear scaling of the O(d²) pair loop",
    );
    println!("machine reports {} available cores\n", alingam::lingam::parallel::default_workers());

    let n = 2_000;
    // CI smoke: the single d=32 cell (same cell ROADMAP's pending table
    // records); full scale: the ParaLiNGAM-style d sweep
    let dims: Vec<usize> = if common::smoke() {
        vec![32]
    } else if common::full_scale() {
        vec![32, 64, 128]
    } else {
        vec![32, 64]
    };
    let worker_grid = [1usize, 2, 4, 8];

    let mut t = Table::new(
        "scores() wall-clock per call",
        &["dims", "vectorized", "par:1", "par:2", "par:4", "par:8", "best ×"],
    );
    for &d in &dims {
        let mut rng = Pcg64::seed_from_u64(7);
        let ds = simulate_sem(&SemSpec::layered(d, 2, 0.5), n, &mut rng);
        let active = vec![true; d];
        // repeat small cells so timings are not noise-dominated
        let reps = (2_000_000 / (d * d * n / 64)).clamp(1, 16);

        let time_scores = |eng: &dyn OrderingEngine| -> f64 {
            let _ = eng.scores(&ds.data, &active).unwrap(); // warm-up
            let (_, dt) = common::time(|| {
                for _ in 0..reps {
                    let _ = eng.scores(&ds.data, &active).unwrap();
                }
            });
            dt / reps as f64
        };

        let t_vec = time_scores(&VectorizedEngine);
        let mut row = vec![d.to_string(), secs(t_vec)];
        let mut best = f64::INFINITY;
        for &w in &worker_grid {
            let t_par = time_scores(&ParallelEngine::new(w));
            best = best.min(t_par);
            row.push(secs(t_par));
        }
        row.push(f(t_vec / best, 2));
        t.row(&row);
    }
    t.print();
    common::emit_json("thread_scaling", &[&t]);
    println!(
        "\nshape check: the speed-up over vectorized should grow toward the\n\
         worker count as d grows (the pair loop is O(d²·n) while the merge\n\
         and standardize stages are O(d·n)); with one exposed core all\n\
         parallel cells collapse to ~1×."
    );
}
