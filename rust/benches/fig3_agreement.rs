//! Figure 3 (top): parallel vs sequential DirectLiNGAM on simulated data
//! — F1, recall and SHD over 50 seeds, plus the exact-agreement check.
//!
//! Paper claim: "Comparison of the sequential and parallel implementation
//! ... show that they produce the exact same result, and recover the true
//! causal graph accurately." Workload: linear FCM, 10 000 samples, 10
//! variables, 50 random seeds.

mod common;

use alingam::apps::simbench::{agreement_sweep, fig3_spec};
use alingam::coordinator::{Engine, EngineChoice};
use alingam::lingam::SequentialEngine;
use alingam::metrics::mean_std;
use alingam::util::table::Table;

fn main() {
    common::header(
        "Figure 3 (top) — parallel ≡ sequential over 50 seeds",
        "identical results; F1/recall ≈ 1, SHD ≈ 0 at n=10 000, d=10",
    );
    let (n_samples, n_seeds, xla_seeds) =
        if common::full_scale() { (10_000, 50, 50) } else { (10_000, 50, 8) };
    let seeds: Vec<u64> = (0..n_seeds as u64).collect();

    let vec_e = Engine::build(EngineChoice::Vectorized).unwrap();
    let runs = agreement_sweep(
        &fig3_spec(),
        n_samples,
        &seeds,
        &SequentialEngine,
        vec_e.as_ordering(),
        2,
    );

    let mut t = Table::new(
        "recovery metrics over seeds (sequential vs vectorized)",
        &["engine", "F1", "recall", "SHD", "identical orders", "max |Δadj|"],
    );
    let agg = |get: &dyn Fn(&alingam::apps::simbench::AgreementRun) -> f64| {
        mean_std(&runs.iter().map(get).collect::<Vec<_>>())
    };
    let max_diff = runs.iter().map(|r| r.adj_max_diff).fold(0.0, f64::max);
    let identical = runs.iter().filter(|r| r.orders_identical).count();
    t.row(&[
        "sequential".into(),
        agg(&|r| r.metrics_a.f1).to_string(),
        agg(&|r| r.metrics_a.recall).to_string(),
        agg(&|r| r.metrics_a.shd as f64).to_string(),
        String::new(),
        String::new(),
    ]);
    t.row(&[
        "vectorized".into(),
        agg(&|r| r.metrics_b.f1).to_string(),
        agg(&|r| r.metrics_b.recall).to_string(),
        agg(&|r| r.metrics_b.shd as f64).to_string(),
        format!("{identical}/{}", runs.len()),
        format!("{max_diff:.2e}"),
    ]);
    t.print();

    // XLA engine agreement (fewer seeds by default — each fit crosses the
    // PJRT boundary d−1 times)
    if let Ok(xla) = Engine::build(EngineChoice::Xla) {
        let seeds: Vec<u64> = (0..xla_seeds as u64).collect();
        let runs =
            agreement_sweep(&fig3_spec(), n_samples, &seeds, &SequentialEngine, xla.as_ordering(), 1);
        let identical = runs.iter().filter(|r| r.orders_identical).count();
        let same_shd = runs.iter().filter(|r| r.metrics_a.shd == r.metrics_b.shd).count();
        let f1 = mean_std(&runs.iter().map(|r| r.metrics_b.f1).collect::<Vec<_>>());
        println!(
            "\nXLA (AOT pallas artifact, f32) vs sequential (f64): identical orders \
             {identical}/{}, identical SHD {same_shd}/{}, F1 {}",
            runs.len(),
            runs.len(),
            f1
        );
    } else {
        println!("\n(xla engine unavailable — run `make artifacts`)");
    }
    println!(
        "\nshape check vs paper: all engines produce the same orders; F1/recall\n\
         near 1 and SHD near 0; the f32 XLA path may differ in adjacency weights\n\
         by ≤1e-3 (float width), never in the discovered structure. For\n\
         reference the paper reports {}",
        "F1 ≈ 1, recall ≈ 1, SHD ≈ 0 over its 50 simulations (Fig. 3)."
    );
    println!(
        "\ncontext (§3.1): NOTEARS on the same data achieves F1 0.79 ± 0.2,\n\
         recall 0.69 ± 0.2, SHD 2.52 ± 1.67 — run `cargo bench --bench sec31_notears`."
    );
}
