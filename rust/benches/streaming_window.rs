//! Streaming-window refit latency on the VAR simulator: per-frame cost
//! of the held-order incremental path ([`StreamingLingam`] /
//! [`StreamingVarLingam`] ingesting one sample under rank-1 moment
//! update/downdate) against a from-scratch full refit of the identical
//! window (seed session + complete ordering sweep). Reported per cell:
//! the from-scratch frame cost, the incremental ms/frame, the speed-up,
//! and the sustained frame rate — the numbers behind the serve tier's
//! `watch` streams.

mod common;

use alingam::lingam::{StreamingConfig, StreamingLingam, StreamingVarLingam};
use alingam::sim::{simulate_var, VarSpec};
use alingam::util::rng::Pcg64;
use alingam::util::table::{f, Table};

fn no_resync() -> StreamingConfig {
    StreamingConfig { resync_every: 0, drift_tol: f64::INFINITY }
}

/// One driver shape for both estimators (`lags = 0` is the plain
/// instantaneous stream, `lags ≥ 1` the embedded VAR design).
enum Driver {
    Plain(StreamingLingam),
    Var(StreamingVarLingam),
}

impl Driver {
    fn new(d: usize, lags: usize, window: usize) -> Driver {
        if lags == 0 {
            Driver::Plain(StreamingLingam::new(d, window, no_resync()).expect("driver"))
        } else {
            Driver::Var(StreamingVarLingam::new(d, lags, window, no_resync()).expect("driver"))
        }
    }

    fn warm(&mut self, row: &[f64]) {
        match self {
            Driver::Plain(s) => s.warm(row).expect("warm frame"),
            Driver::Var(s) => s.warm(row).expect("warm frame"),
        }
    }

    /// Ingest one sample; returns whether a frame was emitted.
    fn ingest(&mut self, row: &[f64]) -> bool {
        match self {
            Driver::Plain(s) => s.ingest(row).expect("ingest").is_some(),
            Driver::Var(s) => s.ingest(row).expect("ingest").is_some(),
        }
    }

    fn refits_incremental(&self) -> u64 {
        match self {
            Driver::Plain(s) => s.refits_incremental(),
            Driver::Var(s) => s.refits_incremental(),
        }
    }
}

fn main() {
    common::header(
        "Streaming window — held-order incremental refit vs from-scratch per frame",
        "a live stream re-estimates B̂₀/B̂_τ per sample from maintained moments \
         instead of re-running the ordering sweep, so per-frame latency drops by \
         orders of magnitude",
    );

    let window = 512usize;
    let dims: Vec<usize> = if common::full_scale() {
        vec![16, 64, 128]
    } else {
        vec![64]
    };
    let frames: usize = if common::smoke() { 24 } else { 64 };

    let mut t = Table::new(
        &format!("window n={window}, {frames} streamed frames per cell"),
        &["d", "lags", "scratch ms", "incr ms/frame", "speedup ×", "frames/s"],
    );
    for &d in &dims {
        for lags in [0usize, 1] {
            let mut rng = Pcg64::seed_from_u64(17 + d as u64 + lags as u64);
            let t_len = window + lags + frames + 8;
            let ds = simulate_var(&VarSpec { dim: d, ..VarSpec::default() }, t_len, &mut rng);

            let mut driver = Driver::new(d, lags, window);
            // fill all but the last warm-up row without fitting...
            let fill = window + lags - 1;
            for r in 0..fill {
                driver.warm(ds.data.row(r));
            }
            // ...so this single ingest pays the full from-scratch refit:
            // materialize the window, seed a session, run the sweep
            let (emitted, t_scratch) = common::time(|| driver.ingest(ds.data.row(fill)));
            assert!(emitted, "fill frame must emit");

            // now every further frame takes the held-order moment path
            let (_, t_incr) = common::time(|| {
                for r in fill + 1..fill + 1 + frames {
                    assert!(driver.ingest(ds.data.row(r)), "streamed frame must emit");
                }
            });
            assert_eq!(driver.refits_incremental(), frames as u64);
            let per_frame = t_incr / frames as f64;
            t.row(&[
                d.to_string(),
                lags.to_string(),
                f(t_scratch * 1e3, 3),
                f(per_frame * 1e3, 4),
                f(t_scratch / per_frame, 1),
                f(1.0 / per_frame, 0),
            ]);
        }
    }
    t.print();

    let refs: Vec<&Table> = vec![&t];
    common::emit_json("streaming_window", &refs);
    println!(
        "\nshape check: the scratch column grows with the full ordering sweep\n\
         (superlinear in d) while the incremental column is the O(d²) moment\n\
         fold plus per-node OLS — the speed-up should widen with d and sit\n\
         well past the 5× acceptance floor at d=64."
    );
}
