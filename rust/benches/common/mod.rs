//! Shared bench-harness plumbing (criterion is not in the offline crate
//! set; every bench is a `harness = false` binary that prints the same
//! rows/series its paper figure or table reports).

/// `ALINGAM_BENCH_FULL=1` switches benches to paper-scale workloads.
pub fn full_scale() -> bool {
    std::env::var("ALINGAM_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

/// `ALINGAM_BENCH_SMOKE=1` shrinks a bench to one CI-sized cell (the
/// workflow runs `fig2_speedup` this way so session-path perf
/// regressions show up in the log without paying for the full grid).
#[allow(dead_code)] // not every bench has a smoke cell
pub fn smoke() -> bool {
    std::env::var("ALINGAM_BENCH_SMOKE").map(|v| v == "1").unwrap_or(false)
}

/// Standard bench header.
pub fn header(id: &str, claim: &str) {
    println!("\n################################################################");
    println!("# {id}");
    println!("# paper claim: {claim}");
    println!("# full-scale: {} (set ALINGAM_BENCH_FULL=1 for paper sizes)", full_scale());
    println!("################################################################");
}

/// Wall-clock one closure.
#[allow(dead_code)] // not every bench uses it
pub fn time<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let t0 = std::time::Instant::now();
    let out = f();
    (out, t0.elapsed().as_secs_f64())
}

/// If `ALINGAM_BENCH_JSON` names a directory, also write the bench's
/// tables there as `BENCH_<name>.json` (machine-readable mirror of the
/// printed rows; the CI smoke steps upload these as workflow artifacts
/// and ROADMAP records the numbers from them).
#[allow(dead_code)] // not every bench emits tables
pub fn emit_json(name: &str, tables: &[&alingam::util::table::Table]) {
    let dir = match std::env::var("ALINGAM_BENCH_JSON") {
        Ok(d) if !d.is_empty() => d,
        _ => return,
    };
    let body: Vec<String> = tables.iter().map(|t| t.to_json()).collect();
    let json = format!("{{\"bench\":\"{name}\",\"tables\":[{}]}}\n", body.join(","));
    let path = std::path::Path::new(&dir).join(format!("BENCH_{name}.json"));
    match std::fs::write(&path, json) {
        Ok(()) => println!("(bench tables written to {})", path.display()),
        Err(e) => eprintln!("(bench json not written to {}: {e})", path.display()),
    }
}
