//! Figure 2 (bottom-right) + Figure 3 (bottom): VarLiNGAM — sequential
//! profile and accelerated speed-up.
//!
//! Paper claims: the DirectLiNGAM causal-ordering sub-procedure also
//! dominates VarLiNGAM's runtime (≈96%), and the GPU implementation
//! yields a ~30× speed-up.

mod common;

use alingam::coordinator::{profile_var, Engine, EngineChoice};
use alingam::lingam::VarLingam;
use alingam::sim::{simulate_var, VarSpec};
use alingam::util::rng::Pcg64;
use alingam::util::table::{f, secs, Table};

fn main() {
    common::header(
        "Figure 2 (bottom-right) / Figure 3 (bottom) — VarLiNGAM",
        "ordering dominates VarLiNGAM too; accelerated speed-up ≈ 30×",
    );
    let grid: Vec<(usize, usize)> = if common::full_scale() {
        vec![(2_000, 8), (2_000, 16), (4_000, 32), (4_000, 48)]
    } else {
        vec![(1_000, 8), (2_000, 12), (2_000, 16)]
    };

    let seq = Engine::build(EngineChoice::Sequential).unwrap();
    let vec_e = Engine::build(EngineChoice::Vectorized).unwrap();
    let xla = Engine::build(EngineChoice::Xla).ok();

    let mut t = Table::new(
        "VarLiNGAM: sequential profile + engine speed-ups",
        &["T", "dims", "seq total", "ordering %", "vectorized", "xla", "vec ×", "xla ×"],
    );
    for &(t_len, d) in &grid {
        let mut rng = Pcg64::seed_from_u64(29);
        let ds = simulate_var(&VarSpec { dim: d, ..Default::default() }, t_len, &mut rng);

        let prof = profile_var(&ds.data, seq.as_ordering()).expect("profile");
        let (fit_v, t_vec) =
            common::time(|| VarLingam::new().fit(&ds.data, vec_e.as_ordering()).unwrap());
        let t_xla = xla.as_ref().map(|x| {
            let _ = VarLingam::new().fit(&ds.data, x.as_ordering()).unwrap(); // compile warm-up
            let (fit_x, dt) =
                common::time(|| VarLingam::new().fit(&ds.data, x.as_ordering()).unwrap());
            assert_eq!(fit_x.order, fit_v.order, "engine disagreement at T={t_len} d={d}");
            dt
        });

        t.row(&[
            t_len.to_string(),
            d.to_string(),
            secs(prof.total_secs),
            f(100.0 * prof.ordering_frac, 1),
            secs(t_vec),
            t_xla.map(secs).unwrap_or_else(|| "—".into()),
            f(prof.total_secs / t_vec, 1),
            t_xla.map(|x| f(prof.total_secs / x, 1)).unwrap_or_else(|| "—".into()),
        ]);
    }
    t.print();
    println!(
        "\nshape check vs paper: the ordering fraction matches DirectLiNGAM's\n\
         (same inner algorithm — Figure 3 bottom), and the speed-up column\n\
         tracks the DirectLiNGAM one (paper: ~30× vs ~32×)."
    );
}
