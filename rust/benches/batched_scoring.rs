//! Queue-aware batched scoring: throughput of one lock-step
//! [`BatchedSession`] over B same-shape panels against B independent
//! `fit_session` runs with the same worker budget — the serve tier's
//! fusion window in isolation. Reported per cell: total wall-clock for
//! the B jobs both ways, the fused speed-up, fused jobs/sec, and the
//! per-lock-step kernel time. Under `--features xla` an extra cell
//! drives the device-resident `XlaBatchSession` (one upload, two
//! dispatches per step for the whole group) at the largest batched
//! artifact bucket.

mod common;

use alingam::lingam::prune::PruneMethod;
use alingam::lingam::{BatchedSession, DirectLingam, IncrementalSession, SweepStrategy};
use alingam::linalg::Mat;
use alingam::sim::{simulate_sem, SemSpec};
use alingam::util::rng::Pcg64;
use alingam::util::table::{f, secs, Table};

fn panels(b: usize, n: usize, d: usize, seed: u64) -> Vec<Mat> {
    let mut rng = Pcg64::seed_from_u64(seed);
    (0..b).map(|_| simulate_sem(&SemSpec::layered(d, 2, 0.5), n, &mut rng).data).collect()
}

/// The serve fallback path: B jobs run one after another, each through
/// its own incremental session with the full worker budget.
fn solo_fits(group: &[Mat], workers: usize) -> f64 {
    let (_, dt) = common::time(|| {
        for p in group {
            let mut s =
                IncrementalSession::with_strategy(p, workers, false, SweepStrategy::Exact).unwrap();
            let _ = DirectLingam::new().fit_session(p, &mut s).unwrap();
        }
    });
    dt
}

fn main() {
    common::header(
        "Batched scoring — lock-step multi-panel sessions vs independent fits",
        "fusing B same-shape jobs into one batched session turns idle-core \
         time into cross-panel work without moving a bit of any result",
    );
    let workers = alingam::lingam::parallel::default_workers();
    println!("machine reports {workers} available cores\n");

    // CI smoke: the d=32 cell with a short B grid; full scale adds the
    // d=128 column the ISSUE acceptance table records
    let dims: Vec<usize> = if common::full_scale() { vec![32, 128] } else { vec![32] };
    let batches: &[usize] = if common::smoke() { &[1, 2, 4, 8] } else { &[1, 2, 4, 8, 16] };
    let n = 1_000;

    let mut tables: Vec<Table> = Vec::new();
    for &d in &dims {
        let mut t = Table::new(
            &format!("d={d}, n={n} — B independent fits vs one batched session"),
            &["B", "solo", "batched", "speedup ×", "jobs/s", "step ms"],
        );
        let steps = (d - 1) as f64;
        for &b in batches {
            let group = panels(b, n, d, 11 + b as u64);
            // warm-up: populate thread pools and page in the panels
            let _ = BatchedSession::fit_batch(
                &group[..1],
                workers,
                false,
                SweepStrategy::Exact,
                PruneMethod::default(),
            )
            .unwrap();
            let t_solo = solo_fits(&group, workers);
            let (outs, t_batch) = common::time(|| {
                BatchedSession::fit_batch(
                    &group,
                    workers,
                    false,
                    SweepStrategy::Exact,
                    PruneMethod::default(),
                )
                .unwrap()
            });
            assert!(outs.iter().all(|o| o.result.is_ok()), "bench fit failed");
            t.row(&[
                b.to_string(),
                secs(t_solo),
                secs(t_batch),
                f(t_solo / t_batch, 2),
                f(b as f64 / t_batch, 1),
                f(t_batch / steps * 1e3, 3),
            ]);
        }
        t.print();
        tables.push(t);
    }

    #[cfg(feature = "xla")]
    xla_cell(&mut tables, n);

    let refs: Vec<&Table> = tables.iter().collect();
    common::emit_json("batched_scoring", &refs);
    println!(
        "\nshape check: small B pays the lock-step bookkeeping (~1×); the\n\
         speed-up should grow with B while per-step time grows sublinearly\n\
         in B — the pair sweeps of all live lanes share one worker pool\n\
         instead of leaving cores idle between jobs."
    );
}

/// Device-resident batched cell: one `session_init` upload for the whole
/// group, then two dispatches per lock step regardless of B. Degrades to
/// a printed note when no device or no batched artifacts are available.
#[cfg(feature = "xla")]
fn xla_cell(tables: &mut Vec<Table>, n: usize) {
    use alingam::lingam::XlaBatchSession;
    use alingam::runtime::XlaEngine;
    let engine = match XlaEngine::from_default_artifacts() {
        Ok(e) => e,
        Err(e) => {
            println!("\n(xla cell skipped: {e})");
            return;
        }
    };
    let d = 16; // the largest batched-artifact bucket (n=1024, d=16)
    let steps = (d - 1) as f64;
    let mut t = Table::new(
        &format!("xla batched session — d={d}, n={n}"),
        &["B", "total", "jobs/s", "step ms"],
    );
    for &b in &[1usize, 4, 8] {
        let group = panels(b, n, d, 29 + b as u64);
        let run = || -> alingam::util::Result<()> {
            let mut s = XlaBatchSession::new(engine.executor().clone(), engine.registry(), &group)?;
            while !s.finished() {
                s.step_live()?;
            }
            Ok(())
        };
        if let Err(e) = run() {
            println!("(xla B={b} skipped: {e})");
            continue;
        }
        let (res, dt) = common::time(run);
        res.expect("warmed xla cell");
        t.row(&[b.to_string(), secs(dt), f(b as f64 / dt, 1), f(dt / steps * 1e3, 3)]);
    }
    t.print();
    tables.push(t);
}
