//! Figure 1: the causal-asymmetry illustration.
//!
//! Paper: "the regression residual can only be independent of the
//! independent variable in the correct causal direction ... for any
//! distribution of the noise except Gaussian."
//!
//! Regenerates the figure as a table: MI(regressor, residual) in the
//! correct and reversed directions for non-Gaussian vs Gaussian noise.

mod common;

use alingam::apps::simbench::asymmetry_demo;
use alingam::sim::Noise;
use alingam::util::table::{f, Table};

fn main() {
    common::header(
        "Figure 1 — causal asymmetry of LiNGAM pairs",
        "MI ≈ 0 in the causal direction, > 0 reversed; symmetric for Gaussian",
    );
    let n = if common::full_scale() { 200_000 } else { 60_000 };
    let mut t = Table::new(
        "MI(regressor, residual) by direction",
        &["noise", "theta", "MI fwd", "MI bwd", "asymmetry", "direction identified"],
    );
    for (name, noise) in [
        ("uniform(0,1)", Noise::Uniform01),
        ("laplace(1)", Noise::Laplace(1.0)),
        ("exp(1)", Noise::Exponential(1.0)),
        ("gaussian(1)", Noise::Gaussian(1.0)),
    ] {
        for theta in [0.5, 1.0, 2.0] {
            let (fwd, bwd) = asymmetry_demo(noise, n, theta, 7).expect("demo");
            let asym = bwd - fwd;
            t.row(&[
                name.into(),
                f(theta, 1),
                f(fwd, 4),
                f(bwd, 4),
                f(asym, 4),
                if asym > 0.01 { "yes" } else { "no" }.into(),
            ]);
        }
    }
    t.print();
    println!(
        "\nshape check vs paper: every non-Gaussian row identifies the direction;\n\
         every Gaussian row does not."
    );
}
