//! Bound-pruned sweep vs exact sweep: wall-clock and work-avoidance of
//! the ParaLiNGAM-style early-termination path (`lingam::sweep`).
//!
//! The pruned sweep provably selects the identical root sequence, so the
//! only question is the work profile: on favorable panels (a chain SEM
//! with a clearly separated root) the bound tightens after the first
//! candidate and most of the O(d²·n) pair work is skipped; on
//! adversarial panels — tie-heavy i.i.d. columns where every candidate
//! scores the same, including the near-Gaussian worst case for the
//! max-ent measure — the bound never separates and the pruned sweep
//! degrades to the exact one plus bookkeeping noise. Both ends are
//! measured here, at d ∈ {32, 64, 128}, with the session counters
//! (visited % of the exact sweep's kernel calls, for the serial and the
//! pooled run separately) printed next to the timings and the
//! pruned/exact wall-clock ratio recorded in `BENCH_sweep_pruning.json`.

mod common;

use alingam::lingam::{IncrementalSession, OrderingSession, SweepStrategy};
use alingam::linalg::Mat;
use alingam::sim::{sample_from_dag, simulate_sem, Noise, SemSpec};
use alingam::util::rng::Pcg64;
use alingam::util::table::{f, secs, Table};

/// d-variable chain 0 → 1 → … → d−1 with uniform noise (shared
/// `graph::chain_dag`, the same panel `tests/pruning_exactness.rs` pins).
fn chain_panel(n: usize, d: usize, seed: u64) -> Mat {
    let mut rng = Pcg64::seed_from_u64(seed);
    sample_from_dag(&alingam::graph::chain_dag(d, 1.0), Noise::Uniform01, n, &mut rng)
}

fn panel(kind: &str, n: usize, d: usize) -> Mat {
    let mut rng = Pcg64::seed_from_u64(31);
    match kind {
        "chain" => chain_panel(n, d, 31),
        "layered" => simulate_sem(&SemSpec::layered(d, 2, 0.5), n, &mut rng).data,
        // adversarial: independent columns — every candidate is equally
        // exogenous, scores tie and the bound cannot separate; the
        // normal variant is additionally the max-ent measure's
        // near-Gaussian worst case (all entropies ≈ H_NU)
        "ties-gauss" => Mat::from_fn(n, d, |_, _| rng.normal()),
        "ties-unif" => Mat::from_fn(n, d, |_, _| rng.uniform(-1.0, 1.0)),
        other => panic!("unknown panel kind {other}"),
    }
}

/// Run the full d−1-step ordering loop on a fresh session (creation
/// included — it is identical work for both strategies) and return the
/// wall-clock plus the session's sweep counters.
fn time_ordering(
    x: &Mat,
    workers: usize,
    strategy: SweepStrategy,
) -> (f64, alingam::lingam::SweepCounters) {
    let run = || {
        let mut s = IncrementalSession::with_strategy(x, workers, false, strategy).unwrap();
        while s.remaining() > 1 {
            s.step().unwrap();
        }
        s.sweep_counters()
    };
    let _ = run(); // warm-up
    let (counters, dt) = common::time(run);
    (dt, counters)
}

fn main() {
    common::header(
        "Bound-pruned pair sweep vs exact sweep (session ordering path)",
        "ParaLiNGAM-style early termination: identical orders, skipped pair work",
    );

    // (panel kind, d) grid; n fixed per scale
    let (n, cells): (usize, Vec<(&str, usize)>) = if common::smoke() {
        (1_000, vec![("chain", 32), ("ties-gauss", 32)])
    } else if common::full_scale() {
        (
            2_000,
            vec![
                ("chain", 32),
                ("chain", 64),
                ("chain", 128),
                ("layered", 32),
                ("layered", 64),
                ("layered", 128),
                ("ties-gauss", 64),
                ("ties-unif", 64),
            ],
        )
    } else {
        (
            1_000,
            vec![
                ("chain", 32),
                ("chain", 64),
                ("layered", 32),
                ("layered", 64),
                ("ties-gauss", 32),
                ("ties-unif", 32),
            ],
        )
    };

    let workers = alingam::lingam::parallel::default_workers();
    let mut t = Table::new(
        "full ordering wall-clock, exact vs pruned (serial and pooled sessions)",
        &[
            "panel",
            "dims",
            "exact(1)",
            "pruned(1)",
            "×(1)",
            "exact(par)",
            "pruned(par)",
            "×(par)",
            "visited %(1)",
            "visited %(par)",
        ],
    );
    for &(kind, d) in &cells {
        let x = panel(kind, n, d);
        let (t_exact_1, _) = time_ordering(&x, 1, SweepStrategy::Exact);
        let (t_pruned_1, c1) = time_ordering(&x, 1, SweepStrategy::Pruned);
        let (t_exact_p, _) = time_ordering(&x, workers, SweepStrategy::Exact);
        let (t_pruned_p, cp) = time_ordering(&x, workers, SweepStrategy::Pruned);
        t.row(&[
            kind.to_string(),
            d.to_string(),
            secs(t_exact_1),
            secs(t_pruned_1),
            f(t_exact_1 / t_pruned_1, 2),
            secs(t_exact_p),
            secs(t_pruned_p),
            f(t_exact_p / t_pruned_p, 2),
            f(100.0 * c1.visited_fraction(), 1),
            f(100.0 * cp.visited_fraction(), 1),
        ]);
    }
    t.print();
    common::emit_json("sweep_pruning", &[&t]);
    println!(
        "\nshape check: on the chain panels the pruned column should be well\n\
         under the exact column (visited % far below 100 — the bound locks in\n\
         after the true root completes); on the ties-* panels the two columns\n\
         should be within noise of each other (visited % ≈ 100), bounding the\n\
         scheduling overhead. The ×(·) ratios are exact/pruned wall-clock —\n\
         ≥ 1.0 means pruning paid for itself."
    );

    #[cfg(feature = "fastmath")]
    {
        // the optional polynomial-exp kernel, measured on the same loop
        // (opt-in per session; never the default — agreement suites pin
        // the precise kernel bitwise)
        let x = panel("chain", n, 64);
        let run_fast = || {
            let mut s = IncrementalSession::with_strategy(&x, 1, false, SweepStrategy::Pruned)
                .unwrap()
                .with_fast_kernel();
            while s.remaining() > 1 {
                s.step().unwrap();
            }
        };
        let _ = run_fast();
        let (_, t_fast) = common::time(run_fast);
        let (t_precise, _) = time_ordering(&x, 1, SweepStrategy::Pruned);
        println!(
            "\nfastmath kernel (chain, d={}): precise {} vs fast {} ({}×)",
            x.cols(),
            secs(t_precise),
            secs(t_fast),
            f(t_precise / t_fast, 2)
        );
    }
}
