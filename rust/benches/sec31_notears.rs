//! §3.1: the NOTEARS negative result.
//!
//! Paper claim: "We evaluate NOTEARS on similarly simulated data selecting
//! the best performance across a grid {0.001, 0.005, 0.01, 0.05, 0.1} of
//! λ values. We obtain an F1 score of 0.79 ± 0.2, Recall of 0.69 ± 0.2
//! and SHD of 2.52 ± 1.67 ... even on data where the causal influences
//! are simple, NOTEARS does not perform well."

mod common;

use alingam::apps::simbench::{agreement_sweep, fig3_spec, notears_sweep};
use alingam::lingam::{SequentialEngine, VectorizedEngine};
use alingam::metrics::mean_std;
use alingam::util::table::Table;

fn main() {
    common::header(
        "§3.1 — NOTEARS vs DirectLiNGAM on layered-DAG LiNGAM data",
        "NOTEARS (best-of-λ): F1 0.79±0.2, recall 0.69±0.2, SHD 2.52±1.67",
    );
    let (n_samples, n_seeds) = if common::full_scale() { (10_000, 50) } else { (4_000, 20) };
    let lambdas = [0.001, 0.005, 0.01, 0.05, 0.1];
    let seeds: Vec<u64> = (0..n_seeds as u64).collect();

    // raw data = the paper's protocol (reference code; varsortability
    // helps); standardized = the Reisach-et-al-fair protocol
    let notears_raw = notears_sweep(&fig3_spec(), n_samples, &seeds, &lambdas, false, 2);
    let notears_std = notears_sweep(&fig3_spec(), n_samples, &seeds, &lambdas, true, 2);
    let lingam_runs = agreement_sweep(
        &fig3_spec(),
        n_samples,
        &seeds,
        &SequentialEngine,
        &VectorizedEngine,
        2,
    );

    let stat = |xs: Vec<f64>| mean_std(&xs).to_string();
    let mut t = Table::new(
        "structure recovery across seeds (best-of-λ for NOTEARS)",
        &["method", "F1", "recall", "SHD"],
    );
    t.row(&[
        "NOTEARS (raw data)".into(),
        stat(notears_raw.iter().map(|m| m.f1).collect()),
        stat(notears_raw.iter().map(|m| m.recall).collect()),
        stat(notears_raw.iter().map(|m| m.shd as f64).collect()),
    ]);
    t.row(&[
        "NOTEARS (standardized)".into(),
        stat(notears_std.iter().map(|m| m.f1).collect()),
        stat(notears_std.iter().map(|m| m.recall).collect()),
        stat(notears_std.iter().map(|m| m.shd as f64).collect()),
    ]);
    t.row(&[
        "DirectLiNGAM".into(),
        stat(lingam_runs.iter().map(|r| r.metrics_b.f1).collect()),
        stat(lingam_runs.iter().map(|r| r.metrics_b.recall).collect()),
        stat(lingam_runs.iter().map(|r| r.metrics_b.shd as f64).collect()),
    ]);
    t.row(&[
        "paper: NOTEARS".into(),
        "0.79 ± 0.20".into(),
        "0.69 ± 0.20".into(),
        "2.52 ± 1.67".into(),
    ]);
    t.print();
    println!(
        "\nshape check vs paper: DirectLiNGAM ≫ NOTEARS on this data — NOTEARS\n\
         misses/reverses edges even with the best λ, matching §3.1's negative\n\
         result (LiNGAM data is standardized ⇒ varsortability cannot help it)."
    );
}
