//! Figure 2 (bottom-left): accelerated DirectLiNGAM vs the sequential
//! implementation.
//!
//! Paper claim: up to **32×** speed-up of the parallel (GPU) version over
//! the sequential CPU version on an RTX 6000 Ada.
//!
//! This testbed substitutes the GPU with the XLA-CPU PJRT executable of
//! the same restructured computation (plus the pure-Rust vectorized
//! engine); the axis under test — restructured/fused/vectorized vs
//! scalar per-pair recomputation — is the paper's, the magnitude is
//! hardware-dependent (see DESIGN.md §Substitutions).

mod common;

use alingam::coordinator::{Engine, EngineChoice};
use alingam::lingam::{DirectLingam, ParallelEngine, VectorizedEngine};
use alingam::linalg::Mat;
use alingam::sim::{simulate_sem, SemSpec};
use alingam::util::rng::Pcg64;
use alingam::util::table::{f, secs, Table};

/// Session (stateful workspace) vs stateless ordering, per engine: the
/// incremental path must be no slower at d=32 and measurably faster
/// (target ≥ 1.3×) at d ≥ 128, where the avoided O(d²·n) correlation
/// dots dominate the per-step cost. The `xla` columns compare the
/// device-resident session (one `session_init` upload, O(d) per step)
/// against the legacy fused `order_step` loop (panel re-uploaded every
/// step) — "—" when the engine or its artifacts are unavailable.
fn session_vs_stateless(grid: &[(usize, usize)], xla: Option<&Engine>) -> Table {
    let vec_e = VectorizedEngine;
    let par_e = ParallelEngine::new(0);
    let cell = |t: f64| if t.is_nan() { "—".to_string() } else { secs(t) };
    let ratio = |a: f64, b: f64| {
        if a.is_nan() || b.is_nan() {
            "—".to_string()
        } else {
            f(a / b, 2)
        }
    };
    let mut t = Table::new(
        "stateful session vs legacy stateless ordering (full fit wall-clock)",
        &[
            "samples",
            "dims",
            "vec stateless",
            "vec session",
            "vec ×",
            "par stateless",
            "par session",
            "par ×",
            "xla stateless",
            "xla session",
            "xla ×",
        ],
    );
    for &(n, d) in grid {
        let mut rng = Pcg64::seed_from_u64(29);
        let ds = simulate_sem(&SemSpec::layered(d, 2, 0.5), n, &mut rng);
        let time_fit = |run: &dyn Fn(&Mat) -> alingam::lingam::LingamFit| -> f64 {
            let _ = run(&ds.data); // warm-up (XLA: compiles the bucket once)
            let (_, dt) = common::time(|| run(&ds.data));
            dt
        };
        let t_vec_sl = time_fit(&|x| DirectLingam::new().fit_stateless(x, &vec_e).unwrap());
        let t_vec_ss = time_fit(&|x| DirectLingam::new().fit(x, &vec_e).unwrap());
        let t_par_sl = time_fit(&|x| DirectLingam::new().fit_stateless(x, &par_e).unwrap());
        let t_par_ss = time_fit(&|x| DirectLingam::new().fit(x, &par_e).unwrap());
        // device rows: stateless = fused order_step with a panel upload
        // per step; session = device-resident XlaSession
        let (t_xla_sl, t_xla_ss) = match xla {
            Some(x) => (
                time_fit(&|p| DirectLingam::new().fit_stateless(p, x.as_ordering()).unwrap()),
                time_fit(&|p| DirectLingam::new().fit(p, x.as_ordering()).unwrap()),
            ),
            None => (f64::NAN, f64::NAN),
        };
        t.row(&[
            n.to_string(),
            d.to_string(),
            secs(t_vec_sl),
            secs(t_vec_ss),
            f(t_vec_sl / t_vec_ss, 2),
            secs(t_par_sl),
            secs(t_par_ss),
            f(t_par_sl / t_par_ss, 2),
            cell(t_xla_sl),
            cell(t_xla_ss),
            ratio(t_xla_sl, t_xla_ss),
        ]);
    }
    t.print();
    println!(
        "\nshape check: the session advantage grows with d — per step it trades\n\
         the stateless path's O(d·n) re-standardize + O(d²·n) correlation dots\n\
         for one O(d·n) fused cache update + an O(d²) closed-form matrix update;\n\
         the remaining per-step cost (entropy + pair-score sweeps) is shared.\n\
         On the xla rows the trade is host↔device traffic: O(steps) panel\n\
         uploads collapse to one session_init."
    );
    t
}

fn main() {
    common::header(
        "Figure 2 (bottom-left) — DirectLiNGAM engine speed-up",
        "parallel implementation up to 32× over sequential",
    );
    if common::smoke() {
        // CI smoke cell: one d=32 session-vs-stateless comparison,
        // including the device-session row when artifacts are present
        let xla = Engine::build(EngineChoice::Xla)
            .map_err(|e| println!("(xla engine unavailable: {e})"))
            .ok();
        let t = session_vs_stateless(&[(1_000, 32)], xla.as_ref());
        common::emit_json("fig2_speedup", &[&t]);
        return;
    }
    // (n, d, run_sequential): sequential is O(n d³) and becomes the
    // bottleneck of the bench itself at large d — cells where it is
    // skipped estimate seq time by the fitted n·d³ model.
    let grid: Vec<(usize, usize, bool)> = if common::full_scale() {
        vec![
            (1_000, 8, true),
            (4_000, 8, true),
            (4_000, 16, true),
            (4_000, 32, true),
            (16_384, 32, true),
            (16_384, 64, false),
        ]
    } else {
        vec![(1_000, 8, true), (4_000, 8, true), (4_000, 16, true), (4_000, 32, true)]
    };

    let seq = Engine::build(EngineChoice::Sequential).unwrap();
    let vec_e = Engine::build(EngineChoice::Vectorized).unwrap();
    let par = Engine::build(EngineChoice::Parallel { workers: 0 }).unwrap();
    let xla = Engine::build(EngineChoice::Xla)
        .map_err(|e| println!("(xla engine unavailable: {e})"))
        .ok();

    let mut t = Table::new(
        "wall-clock per engine + speed-up over sequential",
        &[
            "samples",
            "dims",
            "sequential",
            "vectorized",
            "parallel",
            "xla",
            "vec ×",
            "par ×",
            "xla ×",
        ],
    );
    // model constant for estimating skipped sequential cells
    let mut model_c: Option<f64> = None;
    for &(n, d, run_seq) in &grid {
        let mut rng = Pcg64::seed_from_u64(23);
        let ds = simulate_sem(&SemSpec::layered(d, 2, 0.5), n, &mut rng);

        let t_seq = if run_seq {
            let (_, dt) =
                common::time(|| DirectLingam::new().fit(&ds.data, seq.as_ordering()).unwrap());
            model_c = Some(dt / (n as f64 * (d as f64).powi(3)));
            dt
        } else {
            model_c.expect("measure a sequential cell first") * n as f64 * (d as f64).powi(3)
        };
        let (fit_v, t_vec) =
            common::time(|| DirectLingam::new().fit(&ds.data, vec_e.as_ordering()).unwrap());
        let (fit_p, t_par) =
            common::time(|| DirectLingam::new().fit(&ds.data, par.as_ordering()).unwrap());
        if fit_p.order != fit_v.order {
            // scores agree only to summation-association precision, so a
            // near-tie can legitimately flip the argmax — report, don't die
            println!(
                "(note: parallel/vectorized orders differ at n={n} d={d}: {:?} vs {:?})",
                fit_p.order, fit_v.order
            );
        }
        let (t_xla, xla_order_ok) = match &xla {
            Some(x) => {
                // warm-up: XLA compiles each shape bucket once; steady-state
                // timing is the quantity comparable to the paper's (their
                // CUDA kernels are also compiled ahead of time)
                let _ = DirectLingam::new().fit(&ds.data, x.as_ordering()).unwrap();
                let (fit_x, dt) =
                    common::time(|| DirectLingam::new().fit(&ds.data, x.as_ordering()).unwrap());
                (Some(dt), fit_x.order == fit_v.order)
            }
            None => (None, true),
        };
        assert!(xla_order_ok, "engines disagreed on the causal order at n={n} d={d}");

        t.row(&[
            n.to_string(),
            d.to_string(),
            if run_seq { secs(t_seq) } else { format!("~{} (est)", secs(t_seq)) },
            secs(t_vec),
            secs(t_par),
            t_xla.map(secs).unwrap_or_else(|| "—".into()),
            f(t_seq / t_vec, 1),
            f(t_seq / t_par, 1),
            t_xla.map(|x| f(t_seq / x, 1)).unwrap_or_else(|| "—".into()),
        ]);
    }
    t.print();
    println!(
        "\nshape check vs paper: the restructured engines beat sequential with a\n\
         margin that GROWS with d (the paper's 32× is at d ≈ 100 on 18 176 CUDA\n\
         cores; this sandbox exposes one CPU core, so magnitudes scale down)."
    );

    // the session refactor's own row: stateful workspace vs the legacy
    // stateless loop, on the same engines (d = 128 at full scale, where
    // the ≥ 1.3× target applies)
    let session_grid: Vec<(usize, usize)> = if common::full_scale() {
        vec![(4_000, 32), (4_000, 64), (2_000, 128)]
    } else {
        vec![(1_000, 32), (2_000, 48)]
    };
    let ts = session_vs_stateless(&session_grid, xla.as_ref());
    common::emit_json("fig2_speedup", &[&t, &ts]);
}
