//! Partitioned ordering vs whole-panel ordering: the d-sweep behind the
//! "scale past d≈1000" claim, on block-diagonal panels (B independent
//! chain SEMs side by side — the structure partitioning is built for).
//!
//! Three plans per cell: the unpartitioned baseline (single-block plan),
//! the exact merge tier (same fit by construction — its time column
//! bounds the instrumentation overhead, and its boundary-pair counter
//! reports the cross-block work a lossy decomposition would skip), and
//! the approx merge tier (independent per-block sessions plus the
//! boundary-pair tournament — the tier that actually changes the
//! asymptotics, whose SHD cost is measured here rather than promised
//! away). The SHD-vs-speed table is the deliverable: approx-vs-exact
//! SHD next to the wall-clock ratio, with the visited-boundary-pair
//! counters alongside. Exact columns are skipped (printed as `-`) past
//! the d where whole-panel ordering stops being measurable in bench
//! time — that cliff is the point of the plan layer.

mod common;

use alingam::lingam::{
    DirectLingam, MergeMode, OrderingPlan, PartitionSpec, PartitionedPlan, PlanFit,
    SingleBlockPlan,
};
use alingam::linalg::Mat;
use alingam::metrics::graph_metrics;
use alingam::sim::{sample_from_dag, Noise};
use alingam::util::rng::Pcg64;
use alingam::util::table::{f, secs, Table};

/// Correlation threshold for the bench panels: comfortably above the
/// O(n^{-1/2}) sampling noise of the cross-block correlations at every
/// n used here, and far below the ≈0.7 adjacent-pair correlation inside
/// each chain — so the partitioner recovers the true blocks.
const THRESHOLD: f64 = 0.2;

/// `blocks` independent chains of `d / blocks` variables side by side,
/// with the block-diagonal ground-truth adjacency.
fn block_diagonal(n: usize, d: usize, blocks: usize, seed: u64) -> (Mat, Mat) {
    let per = d / blocks;
    assert_eq!(per * blocks, d, "grid cells must divide evenly");
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut x = Mat::zeros(n, d);
    let mut truth = Mat::zeros(d, d);
    for b in 0..blocks {
        let base = b * per;
        let dag = alingam::graph::chain_dag(per, 1.0);
        let xb = sample_from_dag(&dag, Noise::Uniform01, n, &mut rng);
        for r in 0..n {
            for c in 0..per {
                x[(r, base + c)] = xb[(r, c)];
            }
        }
        for i in 0..per {
            for j in 0..per {
                truth[(base + i, base + j)] = dag.adj[(i, j)];
            }
        }
    }
    (x, truth)
}

/// Time one full `fit_plan` (ordering + regression); `warm` runs it once
/// beforehand so allocator effects do not dominate the small cells.
fn time_plan(x: &Mat, plan: &dyn OrderingPlan, warm: bool) -> (f64, PlanFit) {
    let run = || DirectLingam::new().fit_plan(x, plan).unwrap();
    if warm {
        let _ = run();
    }
    let (pf, dt) = common::time(run);
    (dt, pf)
}

fn main() {
    common::header(
        "Partitioned ordering d-sweep (plan layer, block-diagonal panels)",
        "exact merge reproduces the whole-panel fit; approx merge trades measured SHD for speed",
    );

    // (d, blocks) grid; exact plans run only up to `exact_max_d`
    let (n, exact_max_d, cells): (usize, usize, Vec<(usize, usize)>) = if common::smoke() {
        (500, 64, vec![(64, 8)])
    } else if common::full_scale() {
        (2_000, 256, vec![(64, 8), (128, 8), (256, 16), (512, 16), (1_024, 32)])
    } else {
        (1_000, 128, vec![(64, 8), (128, 8), (256, 16)])
    };

    let mut t = Table::new(
        "fit wall-clock and SHD, unpartitioned vs partition-exact vs partition-approx",
        &[
            "dims",
            "blocks",
            "exact(s)",
            "part-exact(s)",
            "part-approx(s)",
            "×(ex/ap)",
            "shd ap↔ex",
            "shd ex↔truth",
            "shd ap↔truth",
            "bnd visited",
            "bnd total",
        ],
    );
    for &(d, blocks) in &cells {
        let (x, truth) = block_diagonal(n, d, blocks, 61);
        let warm = d <= 128;
        let exact_spec = PartitionSpec { threshold: THRESHOLD, ..PartitionSpec::default() };
        let approx_spec = PartitionSpec { merge: MergeMode::Approx, ..exact_spec };
        let (t_ap, pf_ap) = time_plan(&x, &PartitionedPlan::new(approx_spec), warm);
        let m_ap = graph_metrics(&truth, &pf_ap.fit.adjacency, 0.1);
        if d <= exact_max_d {
            let (t_base, _) = time_plan(&x, &SingleBlockPlan::new(0), warm);
            let (t_ex, pf_ex) = time_plan(&x, &PartitionedPlan::new(exact_spec), warm);
            let m_ex = graph_metrics(&truth, &pf_ex.fit.adjacency, 0.1);
            let m_cross = graph_metrics(&pf_ex.fit.adjacency, &pf_ap.fit.adjacency, 0.1);
            t.row(&[
                d.to_string(),
                pf_ap.blocks_formed.to_string(),
                secs(t_base),
                secs(t_ex),
                secs(t_ap),
                f(t_ex / t_ap, 2),
                m_cross.shd.to_string(),
                m_ex.shd.to_string(),
                m_ap.shd.to_string(),
                pf_ap.boundary_pairs.to_string(),
                pf_ex.boundary_pairs.to_string(),
            ]);
        } else {
            t.row(&[
                d.to_string(),
                pf_ap.blocks_formed.to_string(),
                "-".to_string(),
                "-".to_string(),
                secs(t_ap),
                "-".to_string(),
                "-".to_string(),
                "-".to_string(),
                m_ap.shd.to_string(),
                pf_ap.boundary_pairs.to_string(),
                "-".to_string(),
            ]);
        }
    }
    t.print();
    common::emit_json("partition_scaling", &[&t]);
    println!(
        "\nshape check: part-exact(s) should track exact(s) (the exact tier is\n\
         the whole-panel fit plus counters) with shd ex↔truth == shd for the\n\
         unpartitioned fit by construction; part-approx(s) should fall away\n\
         from both as d grows — the per-step sweep drops from O(d²·n) to\n\
         O(Σ_b d_b²·n) — while shd ap↔ex stays small on these separable\n\
         panels. `bnd visited` is the tournament's pruned-sweep kernel-call\n\
         count; `bnd total` is every active cross-block pair the exact tier\n\
         evaluated — the gap is the work partitioning avoids."
    );
}
