//! Bootstrap confidence estimation for DirectLiNGAM — the reference
//! `lingam` package's companion feature: resample the rows with
//! replacement, refit, and report per-edge selection probabilities and
//! order stability. The coordinator fans the resamples across workers.
//!
//! Every resample has the same `[n, d]` shape, so the refits share a
//! pool of ordering sessions: a worker pops a parked workspace,
//! [`reset`](OrderingSession::reset)s it with its resample (reusing the
//! standardized-cache and correlation-matrix buffers) and parks it again
//! when the fit is done, instead of reallocating the workspace
//! `resamples` times. The pool is workspace-agnostic: the direct
//! bootstrap parks engine sessions, the partitioned bootstrap parks
//! [`PartitionWorkspace`]s (whose reset also re-partitions against the
//! resample's correlation graph) — one shared core drives both.
//!
//! Engines that publish an incremental workspace configuration
//! ([`OrderingEngine::incremental_config`]) skip the pool entirely:
//! their resamples share one [`BatchedSession`] per group of
//! [`BOOTSTRAP_BATCH`] seeds, paying one standardize pass and one sweep
//! dispatch per lock step for the whole group. The batched session is
//! bitwise-parity-pinned against solo fits, so the aggregates are the
//! same either way (pinned by a test below) — only the per-step
//! arithmetic intensity changes.

use super::sweep::parallel_map;
use crate::lingam::partition::{PartitionSpec, PartitionWorkspace};
use crate::lingam::prune::PruneMethod;
use crate::lingam::{
    BatchedSession, DirectLingam, LingamFit, OrderingEngine, OrderingSession, SweepStrategy,
};
use crate::linalg::Mat;
use crate::util::pool::parallel_indexed;
use crate::util::rng::Pcg64;
use crate::util::{Error, Result};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Mutex;

/// Resamples fused into one [`BatchedSession`] by the batched bootstrap
/// path. Eight panels keep the lock-step arithmetic dense without
/// making the group cancel boundary (a whole group finishes before the
/// flag is honored) noticeably coarser than the solo per-resample one.
const BOOTSTRAP_BATCH: usize = 8;

/// Bootstrap configuration.
#[derive(Clone, Debug)]
pub struct BootstrapOpts {
    /// Number of resamples.
    pub resamples: usize,
    /// Worker threads.
    pub workers: usize,
    /// |weight| threshold for counting an edge as selected.
    pub edge_threshold: f64,
    pub seed: u64,
}

impl Default for BootstrapOpts {
    fn default() -> Self {
        BootstrapOpts { resamples: 50, workers: 2, edge_threshold: 0.05, seed: 0 }
    }
}

/// Bootstrap output.
#[derive(Clone, Debug)]
pub struct BootstrapResult {
    /// `probs[(i, j)]` — fraction of resamples selecting edge j → i.
    pub edge_probs: Mat,
    /// Mean edge weight across resamples where the edge was selected.
    pub mean_weights: Mat,
    /// `precedence[(i, j)]` — fraction of resamples placing j before i in
    /// the causal order (directional stability).
    pub precedence: Mat,
    /// Resamples completed.
    pub resamples: usize,
}

impl BootstrapResult {
    /// Edges with selection probability ≥ `min_prob`, sorted descending.
    pub fn stable_edges(&self, min_prob: f64) -> Vec<(usize, usize, f64, f64)> {
        let d = self.edge_probs.rows();
        let mut out = Vec::new();
        for i in 0..d {
            for j in 0..d {
                let p = self.edge_probs[(i, j)];
                if p >= min_prob {
                    out.push((j, i, p, self.mean_weights[(i, j)])); // (from, to, prob, weight)
                }
            }
        }
        out.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
        out
    }
}

/// Run the bootstrap.
pub fn bootstrap_direct<'e>(
    data: &Mat,
    engine: &'e dyn OrderingEngine,
    opts: &BootstrapOpts,
) -> Result<BootstrapResult> {
    bootstrap_direct_observed(data, engine, opts, None, |_, _| {})
}

/// [`bootstrap_direct`] with per-resample observation and cooperative
/// cancellation — the entry point the serve layer drives so it can
/// stream `progress` events and honor `cancel` requests at resample
/// boundaries. `on_resample(done, total)` is called after every
/// completed refit (from worker threads, possibly concurrently — it must
/// be `Sync`); when `cancel` flips to `true`, workers stop picking up
/// new resamples and the whole run returns [`Error::Canceled`].
pub fn bootstrap_direct_observed<'e>(
    data: &Mat,
    engine: &'e dyn OrderingEngine,
    opts: &BootstrapOpts,
    cancel: Option<&AtomicBool>,
    on_resample: impl Fn(usize, usize) + Sync,
) -> Result<BootstrapResult> {
    if let Some(config) = engine.incremental_config() {
        return bootstrap_batched(data, config, opts, cancel, on_resample);
    }
    bootstrap_with_sessions(data, opts, cancel, on_resample, |sample| engine.session(sample))
}

/// The batched bootstrap core: resamples grouped [`BOOTSTRAP_BATCH`] at
/// a time, each group refit in lock step by one [`BatchedSession`]
/// configured exactly as the engine's own incremental workspace would
/// be — per-resample seeding, row sampling and fit bits identical to
/// the session-pool core, only the group cancel boundary is coarser.
fn bootstrap_batched(
    data: &Mat,
    (workers, force_parallel, strategy): (usize, bool, SweepStrategy),
    opts: &BootstrapOpts,
    cancel: Option<&AtomicBool>,
    on_resample: impl Fn(usize, usize) + Sync,
) -> Result<BootstrapResult> {
    let n = data.rows();
    if opts.resamples == 0 {
        return Err(Error::InvalidArgument("resamples must be ≥ 1".into()));
    }
    let seeds: Vec<u64> = (0..opts.resamples as u64).map(|k| opts.seed ^ (k + 1)).collect();
    let groups: Vec<&[u64]> = seeds.chunks(BOOTSTRAP_BATCH).collect();
    let completed = AtomicUsize::new(0);
    let group_fits = parallel_indexed(groups.len(), opts.workers, |g| -> Vec<Result<LingamFit>> {
        let group = groups[g];
        if cancel.is_some_and(|c| c.load(Ordering::Relaxed)) {
            let skipped = |_: &u64| Err(Error::Canceled("bootstrap resample skipped".into()));
            return group.iter().map(skipped).collect();
        }
        let samples: Vec<Mat> = group
            .iter()
            .map(|&seed| {
                let mut rng = Pcg64::seed_from_u64(seed);
                let rows: Vec<usize> = (0..n).map(|_| rng.below(n)).collect();
                data.select_rows(&rows)
            })
            .collect();
        let prune = PruneMethod::default();
        let fits: Vec<Result<LingamFit>> =
            match BatchedSession::fit_batch(&samples, workers, force_parallel, strategy, prune) {
                Ok(outs) => outs.into_iter().map(|o| o.result).collect(),
                // batch-level precondition failure (unreachable for
                // same-shape resamples of a validatable panel): charge
                // every member of the group with it
                Err(e) => {
                    let msg = e.to_string();
                    group.iter().map(|_| Err(Error::Numerical(msg.clone()))).collect()
                }
            };
        for fit in &fits {
            if fit.is_ok() {
                let done = completed.fetch_add(1, Ordering::Relaxed) + 1;
                on_resample(done, opts.resamples);
            }
        }
        fits
    });
    if cancel.is_some_and(|c| c.load(Ordering::Relaxed)) {
        return Err(Error::Canceled("bootstrap canceled".into()));
    }
    aggregate_fits(group_fits.into_iter().flatten(), data.cols(), opts)
}

/// Bootstrap through the partitioned plan's exact tier: every resample
/// is refit by a pooled [`PartitionWorkspace`], whose
/// [`reset`](OrderingSession::reset) both re-seeds the inner workspace
/// buffers *and* re-partitions against the resample's own correlation
/// graph. The exact tier's fit is the unpartitioned session fit bit for
/// bit, so at `spec.workers == 1` the aggregates are identical to
/// [`bootstrap_direct`] over the vectorized engine (pinned by a test
/// below) — what the partition run adds is the per-resample
/// boundary-pair instrumentation and, via the pool, block-label reuse.
pub fn bootstrap_partition(
    data: &Mat,
    spec: &PartitionSpec,
    opts: &BootstrapOpts,
) -> Result<BootstrapResult> {
    bootstrap_partition_observed(data, spec, opts, None, |_, _| {})
}

/// [`bootstrap_partition`] with per-resample observation and
/// cooperative cancellation — the serve layer's entry point, mirroring
/// [`bootstrap_direct_observed`].
pub fn bootstrap_partition_observed(
    data: &Mat,
    spec: &PartitionSpec,
    opts: &BootstrapOpts,
    cancel: Option<&AtomicBool>,
    on_resample: impl Fn(usize, usize) + Sync,
) -> Result<BootstrapResult> {
    bootstrap_with_sessions(data, opts, cancel, on_resample, |sample| {
        PartitionWorkspace::new(sample, spec).map(|w| Box::new(w) as Box<dyn OrderingSession>)
    })
}

/// The shared resample → pool → refit → aggregate core behind both
/// bootstrap flavors. `make_session` seeds a fresh workspace for a
/// resample when the pool is empty — the direct bootstrap passes an
/// engine's session factory, the partitioned bootstrap a
/// [`PartitionWorkspace`] constructor — and everything else (seeding,
/// row resampling, pooling, cancellation, aggregation) is written once.
fn bootstrap_with_sessions<'e>(
    data: &Mat,
    opts: &BootstrapOpts,
    cancel: Option<&AtomicBool>,
    on_resample: impl Fn(usize, usize) + Sync,
    make_session: impl Fn(&Mat) -> Result<Box<dyn OrderingSession + 'e>> + Sync,
) -> Result<BootstrapResult> {
    let (n, d) = (data.rows(), data.cols());
    if opts.resamples == 0 {
        return Err(Error::InvalidArgument("resamples must be ≥ 1".into()));
    }
    let seeds: Vec<u64> = (0..opts.resamples as u64).map(|k| opts.seed ^ (k + 1)).collect();
    // parked session workspaces, reused across resamples (shapes always
    // match: every resample is [n, d])
    let session_pool: Mutex<Vec<Box<dyn OrderingSession + 'e>>> = Mutex::new(Vec::new());
    let completed = AtomicUsize::new(0);
    let fits = parallel_map(&seeds, opts.workers, |seed| -> Result<LingamFit> {
        if cancel.is_some_and(|c| c.load(Ordering::Relaxed)) {
            return Err(Error::Canceled("bootstrap resample skipped".into()));
        }
        let mut rng = Pcg64::seed_from_u64(seed);
        let rows: Vec<usize> = (0..n).map(|_| rng.below(n)).collect();
        let sample = data.select_rows(&rows);
        let pooled = session_pool.lock().expect("session pool").pop();
        let mut session = match pooled {
            Some(mut s) => {
                s.reset(&sample)?;
                s
            }
            None => make_session(&sample)?,
        };
        let fit = DirectLingam::new().fit_session(&sample, session.as_mut());
        // park the workspace even after a failed refit: reset restores
        // its invariants before the next use
        session_pool.lock().expect("session pool").push(session);
        if fit.is_ok() {
            let done = completed.fetch_add(1, Ordering::Relaxed) + 1;
            on_resample(done, opts.resamples);
        }
        fit
    });
    if cancel.is_some_and(|c| c.load(Ordering::Relaxed)) {
        return Err(Error::Canceled("bootstrap canceled".into()));
    }
    aggregate_fits(fits, d, opts)
}

/// Fold per-resample fits into the bootstrap aggregates — written once
/// for the session-pool and batched cores (failed refits are skipped,
/// all-failed runs error).
fn aggregate_fits(
    fits: impl IntoIterator<Item = Result<LingamFit>>,
    d: usize,
    opts: &BootstrapOpts,
) -> Result<BootstrapResult> {
    let mut edge_probs = Mat::zeros(d, d);
    let mut weight_sums = Mat::zeros(d, d);
    let mut precedence = Mat::zeros(d, d);
    let mut ok = 0usize;
    for fit in fits.into_iter().flatten() {
        ok += 1;
        let mut pos = vec![0usize; d];
        for (p, &v) in fit.order.iter().enumerate() {
            pos[v] = p;
        }
        for i in 0..d {
            for j in 0..d {
                if i == j {
                    continue;
                }
                if fit.adjacency[(i, j)].abs() > opts.edge_threshold {
                    edge_probs[(i, j)] += 1.0;
                    weight_sums[(i, j)] += fit.adjacency[(i, j)];
                }
                if pos[j] < pos[i] {
                    precedence[(i, j)] += 1.0;
                }
            }
        }
    }
    if ok == 0 {
        return Err(Error::Numerical("every bootstrap refit failed".into()));
    }
    let inv = 1.0 / ok as f64;
    let mean_weights = Mat::from_fn(d, d, |i, j| {
        let c = edge_probs[(i, j)];
        if c > 0.0 {
            weight_sums[(i, j)] / c
        } else {
            0.0
        }
    });
    Ok(BootstrapResult {
        edge_probs: edge_probs.scale(inv),
        mean_weights,
        precedence: precedence.scale(inv),
        resamples: ok,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lingam::VectorizedEngine;
    use crate::sim::{simulate_sem, SemSpec};

    fn run(seed: u64, resamples: usize) -> (BootstrapResult, crate::sim::SemDataset) {
        let mut rng = Pcg64::seed_from_u64(seed);
        let ds = simulate_sem(&SemSpec::layered(5, 2, 0.7), 1_500, &mut rng);
        let opts = BootstrapOpts { resamples, workers: 2, ..Default::default() };
        let r = bootstrap_direct(&ds.data, &VectorizedEngine, &opts).unwrap();
        (r, ds)
    }

    #[test]
    fn strong_true_edges_are_stable() {
        let (r, ds) = run(1, 20);
        assert_eq!(r.resamples, 20);
        let d = ds.adjacency.rows();
        for i in 0..d {
            for j in 0..d {
                let w = ds.adjacency[(i, j)];
                if w.abs() > 1.0 {
                    assert!(
                        r.edge_probs[(i, j)] > 0.8,
                        "strong edge {j}→{i} (w={w}) prob {}",
                        r.edge_probs[(i, j)]
                    );
                    // mean weight should be near the truth
                    assert!(
                        (r.mean_weights[(i, j)] - w).abs() < 0.3,
                        "weight {} vs true {w}",
                        r.mean_weights[(i, j)]
                    );
                }
            }
        }
    }

    #[test]
    fn session_pool_reuse_is_deterministic() {
        // worker count changes which resamples share a pooled workspace;
        // reset must make that invisible in the aggregate
        let mut rng = Pcg64::seed_from_u64(9);
        let ds = simulate_sem(&SemSpec::layered(5, 2, 0.7), 1_000, &mut rng);
        let run = |workers: usize| {
            let opts = BootstrapOpts { resamples: 12, workers, ..Default::default() };
            bootstrap_direct(&ds.data, &VectorizedEngine, &opts).unwrap()
        };
        let (a, b) = (run(1), run(3));
        assert_eq!(a.edge_probs, b.edge_probs);
        assert_eq!(a.precedence, b.precedence);
        assert_eq!(a.resamples, b.resamples);
    }

    #[test]
    fn partition_bootstrap_matches_direct_and_pool_resets_cleanly() {
        let mut rng = Pcg64::seed_from_u64(9);
        let ds = simulate_sem(&SemSpec::layered(5, 2, 0.7), 1_000, &mut rng);
        let spec = PartitionSpec { workers: 1, ..PartitionSpec::default() };
        let run = |workers: usize| {
            let opts = BootstrapOpts { resamples: 12, workers, ..Default::default() };
            bootstrap_partition(&ds.data, &spec, &opts).unwrap()
        };
        // worker count changes which resamples share a pooled workspace;
        // reset (including the re-partition) must make that invisible
        let (a, b) = (run(1), run(3));
        assert_eq!(a.edge_probs, b.edge_probs);
        assert_eq!(a.precedence, b.precedence);
        assert_eq!(a.resamples, b.resamples);
        // the exact tier is the unpartitioned session fit bit for bit,
        // so the aggregates equal the direct bootstrap's exactly
        let opts = BootstrapOpts { resamples: 12, workers: 2, ..Default::default() };
        let direct = bootstrap_direct(&ds.data, &VectorizedEngine, &opts).unwrap();
        assert_eq!(a.edge_probs, direct.edge_probs);
        assert_eq!(a.precedence, direct.precedence);
        assert_eq!(a.resamples, direct.resamples);
    }

    #[test]
    fn observer_sees_every_resample_and_cancel_aborts() {
        let mut rng = Pcg64::seed_from_u64(31);
        let ds = simulate_sem(&SemSpec::layered(4, 2, 0.7), 600, &mut rng);
        let opts = BootstrapOpts { resamples: 8, workers: 2, ..Default::default() };
        // observer: every resample reported exactly once, monotone `done`
        let seen = std::sync::Mutex::new(Vec::new());
        let r = bootstrap_direct_observed(&ds.data, &VectorizedEngine, &opts, None, |done, total| {
            assert_eq!(total, 8);
            seen.lock().unwrap().push(done);
        })
        .unwrap();
        assert_eq!(r.resamples, 8);
        let mut seen = seen.into_inner().unwrap();
        seen.sort_unstable();
        assert_eq!(seen, (1..=8).collect::<Vec<_>>());
        // a pre-flipped cancel flag aborts before any refit
        let cancel = AtomicBool::new(true);
        let err = bootstrap_direct_observed(
            &ds.data,
            &VectorizedEngine,
            &opts,
            Some(&cancel),
            |_, _| panic!("canceled run must not report progress"),
        );
        assert!(matches!(err, Err(Error::Canceled(_))), "expected Canceled, got {err:?}");
    }

    #[test]
    fn batched_routing_matches_the_session_pool_core() {
        // engines with an incremental workspace route through
        // BatchedSession groups; the batched fits are bitwise the solo
        // fits, so every aggregate must equal the session-pool core's
        let mut rng = Pcg64::seed_from_u64(17);
        let ds = simulate_sem(&SemSpec::layered(5, 2, 0.7), 800, &mut rng);
        // 10 resamples = one full group of BOOTSTRAP_BATCH plus a stub
        let opts = BootstrapOpts { resamples: 10, workers: 2, ..Default::default() };
        let engine = VectorizedEngine;
        let batched = bootstrap_direct(&ds.data, &engine, &opts).unwrap();
        let pooled =
            bootstrap_with_sessions(&ds.data, &opts, None, |_, _| {}, |s| engine.session(s))
                .unwrap();
        assert_eq!(batched.edge_probs, pooled.edge_probs);
        assert_eq!(batched.mean_weights, pooled.mean_weights);
        assert_eq!(batched.precedence, pooled.precedence);
        assert_eq!(batched.resamples, pooled.resamples);
        // the multi-worker pruned engine routes batched too and stays
        // deterministic across coordinator worker counts
        let pruned = crate::lingam::ParallelEngine::new(1).with_pruning();
        let a = bootstrap_direct(&ds.data, &pruned, &opts).unwrap();
        let b = bootstrap_direct(
            &ds.data,
            &pruned,
            &BootstrapOpts { workers: 3, ..opts.clone() },
        )
        .unwrap();
        assert_eq!(a.edge_probs, b.edge_probs);
        assert_eq!(a.resamples, b.resamples);
    }

    #[test]
    fn probabilities_are_probabilities() {
        let (r, _) = run(2, 10);
        for &p in r.edge_probs.as_slice() {
            assert!((0.0..=1.0).contains(&p));
        }
        for &p in r.precedence.as_slice() {
            assert!((0.0..=1.0).contains(&p));
        }
    }

    #[test]
    fn precedence_antisymmetric() {
        let (r, _) = run(3, 10);
        let d = r.precedence.rows();
        for i in 0..d {
            for j in (i + 1)..d {
                let sum = r.precedence[(i, j)] + r.precedence[(j, i)];
                assert!((sum - 1.0).abs() < 1e-9, "precedence ({i},{j}) sums to {sum}");
            }
        }
    }

    #[test]
    fn stable_edges_sorted_and_thresholded() {
        let (r, _) = run(4, 10);
        let edges = r.stable_edges(0.5);
        for w in edges.windows(2) {
            assert!(w[0].2 >= w[1].2);
        }
        for (_, _, p, _) in &edges {
            assert!(*p >= 0.5);
        }
    }
}
