//! Multi-seed experiment sweeps over worker threads.
//!
//! Figure 3 and §3.1 aggregate 50 simulations with different seeds; the
//! sweep scheduler fans those jobs across a bounded worker pool (std
//! scoped threads — tokio is not in the offline crate set and the jobs
//! are pure compute anyway) and preserves seed order in the output.

use crate::util::pool::parallel_indexed;

/// Run `f(seed)` for every seed, `workers` at a time; results come back
/// in input order. `f` must be `Sync` (it is shared across workers).
/// Thin seed-indexed wrapper over [`parallel_indexed`], the crate's one
/// worker-pool implementation.
pub fn parallel_map<T, F>(seeds: &[u64], workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(u64) -> T + Sync,
{
    parallel_indexed(seeds.len(), workers, |i| f(seeds[i]))
}

/// Aggregate statistics of a metric across sweep runs.
#[derive(Debug, Clone, Copy)]
pub struct SweepStats {
    pub mean: f64,
    pub std: f64,
    pub min: f64,
    pub max: f64,
}

impl SweepStats {
    pub fn from(xs: &[f64]) -> SweepStats {
        let ms = crate::metrics::mean_std(xs);
        SweepStats {
            mean: ms.mean,
            std: ms.std,
            min: xs.iter().cloned().fold(f64::INFINITY, f64::min),
            max: xs.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
        }
    }
}

impl std::fmt::Display for SweepStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.2} ± {:.2}", self.mean, self.std)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_order() {
        let seeds: Vec<u64> = (0..37).collect();
        let out = parallel_map(&seeds, 4, |s| s * 2);
        assert_eq!(out, seeds.iter().map(|s| s * 2).collect::<Vec<_>>());
    }

    #[test]
    fn single_worker_works() {
        let out = parallel_map(&[5, 6], 1, |s| s + 1);
        assert_eq!(out, vec![6, 7]);
    }

    #[test]
    fn more_workers_than_jobs() {
        let out = parallel_map(&[1], 8, |s| s);
        assert_eq!(out, vec![1]);
    }

    #[test]
    fn stats_aggregate() {
        let s = SweepStats::from(&[1.0, 3.0]);
        assert_eq!(s.mean, 2.0);
        assert_eq!(s.min, 1.0);
        assert_eq!(s.max, 3.0);
    }

    #[test]
    fn real_work_distributes() {
        // run actual discovery jobs in parallel to catch Sync issues
        use crate::lingam::{DirectLingam, VectorizedEngine};
        use crate::sim::{simulate_sem, SemSpec};
        use crate::util::rng::Pcg64;
        let seeds: Vec<u64> = (0..6).collect();
        let orders = parallel_map(&seeds, 3, |seed| {
            let mut rng = Pcg64::seed_from_u64(seed);
            let ds = simulate_sem(&SemSpec::layered(5, 2, 0.6), 500, &mut rng);
            DirectLingam::new().fit(&ds.data, &VectorizedEngine).unwrap().order
        });
        assert_eq!(orders.len(), 6);
        // determinism: rerunning a seed gives the same answer
        let again = parallel_map(&seeds, 2, |seed| {
            let mut rng = Pcg64::seed_from_u64(seed);
            let ds = simulate_sem(&SemSpec::layered(5, 2, 0.6), 500, &mut rng);
            DirectLingam::new().fit(&ds.data, &VectorizedEngine).unwrap().order
        });
        assert_eq!(orders, again);
    }
}
