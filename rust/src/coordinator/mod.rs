//! The L3 coordinator: engine selection, multi-seed experiment sweeps and
//! stage profiling — the driver machinery around the discovery algorithms.
//!
//! The paper's contribution lives in the kernel (L1) and its restructured
//! computation (L2), so L3 is deliberately thin on the request path: a
//! discovery *job* is data in → (order, adjacency, profile) out. What L3
//! owns is everything around that: which engine serves a job, fanning 50
//! simulation seeds across workers (Figure 3), collecting stage timings
//! (Figure 2's 96% claim) and device statistics.

pub mod bootstrap;
pub mod profile;
pub mod sweep;

pub use bootstrap::{
    bootstrap_direct, bootstrap_direct_observed, bootstrap_partition,
    bootstrap_partition_observed, BootstrapOpts, BootstrapResult,
};
pub use profile::{profile_direct, profile_var, ProfileRow};
pub use sweep::{parallel_map, SweepStats};

use crate::lingam::{OrderingEngine, ParallelEngine, SequentialEngine, VectorizedEngine};
use crate::runtime::XlaEngine;
use crate::util::{Error, Result};
use std::sync::Arc;

/// Which ordering backend serves a job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineChoice {
    /// Scalar per-pair reference (the paper's CPU baseline).
    Sequential,
    /// Restructured pure-Rust path (GPU-shaped computation on CPU).
    Vectorized,
    /// Multi-threaded restructured path (`workers == 0` ⇒ one per core).
    Parallel { workers: usize },
    /// The parallel engine with the bound-pruned sweep
    /// ([`crate::lingam::sweep`]): identical causal orders, part of the
    /// O(d²·n) pair work skipped. `workers == 1` is the serial pruned
    /// path.
    Pruned { workers: usize },
    /// Partitioned ordering plan ([`crate::lingam::partition`]):
    /// correlation-graph blocks with a boundary-pair reconciliation
    /// merge. `blocks == 0` ⇒ uncapped (one block per connected
    /// component). Not a session engine — `Engine::build` rejects it;
    /// the CLI and serve layers route it through
    /// [`DirectLingam::fit_plan`](crate::lingam::DirectLingam::fit_plan).
    Partition { blocks: usize },
    /// AOT Pallas/JAX artifacts over PJRT (the accelerated path).
    Xla,
}

impl EngineChoice {
    /// Parse an engine spec. `parallel`/`par` and `pruned` take an
    /// optional worker count suffix: `parallel:4`, `pruned:4` (0 or
    /// absent ⇒ one worker per core).
    pub fn parse(s: &str) -> Result<EngineChoice> {
        if let Some(rest) = s.strip_prefix("parallel:").or_else(|| s.strip_prefix("par:")) {
            let workers: usize = rest.parse().map_err(|_| {
                Error::InvalidArgument(format!(
                    "bad worker count {rest:?} in engine spec {s:?} (want parallel:N)"
                ))
            })?;
            return Ok(EngineChoice::Parallel { workers });
        }
        if let Some(rest) = s.strip_prefix("pruned:") {
            let workers: usize = rest.parse().map_err(|_| {
                Error::InvalidArgument(format!(
                    "bad worker count {rest:?} in engine spec {s:?} (want pruned:N)"
                ))
            })?;
            return Ok(EngineChoice::Pruned { workers });
        }
        if let Some(rest) = s.strip_prefix("partition:") {
            let blocks: usize = rest.parse().map_err(|_| {
                Error::InvalidArgument(format!(
                    "bad block count {rest:?} in engine spec {s:?} (want partition:B)"
                ))
            })?;
            return Ok(EngineChoice::Partition { blocks });
        }
        match s {
            "sequential" | "seq" => Ok(EngineChoice::Sequential),
            "vectorized" | "vec" => Ok(EngineChoice::Vectorized),
            "parallel" | "par" => Ok(EngineChoice::Parallel { workers: 0 }),
            "pruned" => Ok(EngineChoice::Pruned { workers: 0 }),
            "partition" => Ok(EngineChoice::Partition { blocks: 0 }),
            "xla" => Ok(EngineChoice::Xla),
            other => Err(Error::InvalidArgument(format!(
                "unknown engine {other:?} \
                 (sequential|vectorized|parallel[:N]|pruned[:N]|partition[:B]|xla)"
            ))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            EngineChoice::Sequential => "sequential",
            EngineChoice::Vectorized => "vectorized",
            EngineChoice::Parallel { .. } => "parallel",
            EngineChoice::Pruned { .. } => "pruned",
            EngineChoice::Partition { .. } => "partition",
            EngineChoice::Xla => "xla",
        }
    }

    /// The per-job worker budget when `concurrent` sibling jobs share
    /// the machine — the one copy of the division
    /// [`resolve_workers`](EngineChoice::resolve_workers) applies to
    /// auto-sized pools, exposed so plan-driven paths (the partition
    /// plan's internal pool, which has no `workers` field in its spec)
    /// normalize identically in the CLI and the serve layer.
    pub fn per_job_workers(concurrent: usize) -> usize {
        (crate::lingam::parallel::default_workers() / concurrent.max(1)).max(1)
    }

    /// Resolve the `workers == 0` (auto) placeholder against a core
    /// budget shared by `concurrent` sibling jobs: one auto-sized
    /// parallel engine per job would oversubscribe every core
    /// `concurrent`-fold, so the machine's cores are divided instead.
    /// Explicit worker counts (`parallel:4`) are honored as given, and
    /// engines without a pool are untouched. This is the one copy of the
    /// worker-default normalization — the CLI sweep commands and the
    /// serve layer's per-request engine construction both go through it.
    pub fn resolve_workers(self, concurrent: usize) -> EngineChoice {
        match self {
            EngineChoice::Parallel { workers: 0 } => {
                EngineChoice::Parallel { workers: Self::per_job_workers(concurrent) }
            }
            EngineChoice::Pruned { workers: 0 } => {
                EngineChoice::Pruned { workers: Self::per_job_workers(concurrent) }
            }
            // `partition:B` counts blocks, not workers: its internal
            // pool is sized by the caller via `per_job_workers`
            other => other,
        }
    }

    /// Canonical spec string — the inverse of [`EngineChoice::parse`]
    /// (`parse(spec()) == self`). The serve layer keys its result cache
    /// on this, so two requests naming the same effective engine hash
    /// identically regardless of which alias (`par`, `parallel`) the
    /// client wrote.
    pub fn spec(self) -> String {
        match self {
            EngineChoice::Parallel { workers } => format!("parallel:{workers}"),
            EngineChoice::Pruned { workers } => format!("pruned:{workers}"),
            EngineChoice::Partition { blocks } => format!("partition:{blocks}"),
            other => other.name().to_string(),
        }
    }
}

/// A shareable engine handle (XLA engines are expensive to build — one
/// device thread + compile cache — so they are reference-counted).
#[derive(Clone)]
pub enum Engine {
    Sequential(SequentialEngine),
    Vectorized(VectorizedEngine),
    Parallel(ParallelEngine),
    Xla(Arc<XlaEngine>),
}

impl Engine {
    /// Construct an engine for a choice; `Xla` loads the default
    /// artifact directory and starts the device thread.
    pub fn build(choice: EngineChoice) -> Result<Engine> {
        Ok(match choice {
            EngineChoice::Sequential => Engine::Sequential(SequentialEngine),
            EngineChoice::Vectorized => Engine::Vectorized(VectorizedEngine),
            EngineChoice::Parallel { workers } => Engine::Parallel(ParallelEngine::new(workers)),
            EngineChoice::Pruned { workers } => {
                Engine::Parallel(ParallelEngine::new(workers).with_pruning())
            }
            EngineChoice::Partition { .. } => {
                return Err(Error::InvalidArgument(
                    "partition is an ordering plan, not a session engine — route it \
                     through DirectLingam::fit_plan (the discover/serve paths do)"
                        .into(),
                ))
            }
            EngineChoice::Xla => Engine::Xla(Arc::new(XlaEngine::from_default_artifacts()?)),
        })
    }

    /// Borrow as the trait object the algorithms take.
    pub fn as_ordering(&self) -> &dyn OrderingEngine {
        match self {
            Engine::Sequential(e) => e,
            Engine::Vectorized(e) => e,
            Engine::Parallel(e) => e,
            Engine::Xla(e) => e.as_ref(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choice_parsing() {
        assert_eq!(EngineChoice::parse("seq").unwrap(), EngineChoice::Sequential);
        assert_eq!(EngineChoice::parse("vectorized").unwrap(), EngineChoice::Vectorized);
        assert_eq!(EngineChoice::parse("xla").unwrap(), EngineChoice::Xla);
        assert!(EngineChoice::parse("cuda").is_err());
    }

    #[test]
    fn pruned_choice_parsing_and_build() {
        assert_eq!(EngineChoice::parse("pruned").unwrap(), EngineChoice::Pruned { workers: 0 });
        assert_eq!(
            EngineChoice::parse("pruned:3").unwrap(),
            EngineChoice::Pruned { workers: 3 }
        );
        assert!(EngineChoice::parse("pruned:x").is_err());
        let e = Engine::build(EngineChoice::Pruned { workers: 2 }).unwrap();
        assert_eq!(e.as_ordering().name(), "pruned");
        assert_eq!(
            e.as_ordering().sweep_strategy(),
            crate::lingam::SweepStrategy::Pruned
        );
    }

    #[test]
    fn parallel_choice_parsing() {
        assert_eq!(
            EngineChoice::parse("parallel").unwrap(),
            EngineChoice::Parallel { workers: 0 }
        );
        assert_eq!(EngineChoice::parse("par").unwrap(), EngineChoice::Parallel { workers: 0 });
        assert_eq!(
            EngineChoice::parse("parallel:4").unwrap(),
            EngineChoice::Parallel { workers: 4 }
        );
        assert_eq!(EngineChoice::parse("par:2").unwrap(), EngineChoice::Parallel { workers: 2 });
        assert!(EngineChoice::parse("parallel:x").is_err());
        assert!(EngineChoice::parse("par:").is_err());
    }

    #[test]
    fn partition_choice_parses_but_does_not_build() {
        assert_eq!(
            EngineChoice::parse("partition").unwrap(),
            EngineChoice::Partition { blocks: 0 }
        );
        assert_eq!(
            EngineChoice::parse("partition:8").unwrap(),
            EngineChoice::Partition { blocks: 8 }
        );
        assert!(EngineChoice::parse("partition:x").is_err());
        assert_eq!(EngineChoice::Partition { blocks: 3 }.name(), "partition");
        // a plan, not a session engine
        assert!(matches!(
            Engine::build(EngineChoice::Partition { blocks: 0 }),
            Err(Error::InvalidArgument(_))
        ));
        // blocks are not a worker count: resolve_workers passes through
        assert_eq!(
            EngineChoice::Partition { blocks: 0 }.resolve_workers(4),
            EngineChoice::Partition { blocks: 0 }
        );
        assert!(EngineChoice::per_job_workers(1) >= 1);
        assert_eq!(EngineChoice::per_job_workers(usize::MAX), 1);
    }

    #[test]
    fn resolve_workers_only_touches_auto_pools() {
        // explicit counts and pool-less engines pass through unchanged
        assert_eq!(
            EngineChoice::Parallel { workers: 3 }.resolve_workers(4),
            EngineChoice::Parallel { workers: 3 }
        );
        assert_eq!(EngineChoice::Sequential.resolve_workers(4), EngineChoice::Sequential);
        assert_eq!(EngineChoice::Xla.resolve_workers(4), EngineChoice::Xla);
        // auto resolves to at least one worker, however many siblings
        for concurrent in [0usize, 1, 2, 1024] {
            match EngineChoice::Parallel { workers: 0 }.resolve_workers(concurrent) {
                EngineChoice::Parallel { workers } => assert!(workers >= 1),
                other => panic!("unexpected {other:?}"),
            }
            match EngineChoice::Pruned { workers: 0 }.resolve_workers(concurrent) {
                EngineChoice::Pruned { workers } => assert!(workers >= 1),
                other => panic!("unexpected {other:?}"),
            }
        }
    }

    #[test]
    fn spec_roundtrips_through_parse() {
        for c in [
            EngineChoice::Sequential,
            EngineChoice::Vectorized,
            EngineChoice::Parallel { workers: 0 },
            EngineChoice::Parallel { workers: 5 },
            EngineChoice::Pruned { workers: 2 },
            EngineChoice::Partition { blocks: 0 },
            EngineChoice::Partition { blocks: 4 },
            EngineChoice::Xla,
        ] {
            assert_eq!(EngineChoice::parse(&c.spec()).unwrap(), c, "spec {}", c.spec());
        }
    }

    #[test]
    fn cpu_engines_build() {
        for c in [
            EngineChoice::Sequential,
            EngineChoice::Vectorized,
            EngineChoice::Parallel { workers: 2 },
        ] {
            let e = Engine::build(c).unwrap();
            assert_eq!(e.as_ordering().name(), c.name());
        }
    }
}
