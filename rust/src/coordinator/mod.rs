//! The L3 coordinator: engine selection, multi-seed experiment sweeps and
//! stage profiling — the driver machinery around the discovery algorithms.
//!
//! The paper's contribution lives in the kernel (L1) and its restructured
//! computation (L2), so L3 is deliberately thin on the request path: a
//! discovery *job* is data in → (order, adjacency, profile) out. What L3
//! owns is everything around that: which engine serves a job, fanning 50
//! simulation seeds across workers (Figure 3), collecting stage timings
//! (Figure 2's 96% claim) and device statistics.

pub mod bootstrap;
pub mod profile;
pub mod sweep;

pub use bootstrap::{bootstrap_direct, BootstrapOpts, BootstrapResult};
pub use profile::{profile_direct, profile_var, ProfileRow};
pub use sweep::{parallel_map, SweepStats};

use crate::lingam::{OrderingEngine, SequentialEngine, VectorizedEngine};
use crate::runtime::XlaEngine;
use crate::util::{Error, Result};
use std::sync::Arc;

/// Which ordering backend serves a job.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum EngineChoice {
    /// Scalar per-pair reference (the paper's CPU baseline).
    Sequential,
    /// Restructured pure-Rust path (GPU-shaped computation on CPU).
    Vectorized,
    /// AOT Pallas/JAX artifacts over PJRT (the accelerated path).
    Xla,
}

impl EngineChoice {
    pub fn parse(s: &str) -> Result<EngineChoice> {
        match s {
            "sequential" | "seq" => Ok(EngineChoice::Sequential),
            "vectorized" | "vec" => Ok(EngineChoice::Vectorized),
            "xla" => Ok(EngineChoice::Xla),
            other => Err(Error::InvalidArgument(format!(
                "unknown engine {other:?} (sequential|vectorized|xla)"
            ))),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            EngineChoice::Sequential => "sequential",
            EngineChoice::Vectorized => "vectorized",
            EngineChoice::Xla => "xla",
        }
    }
}

/// A shareable engine handle (XLA engines are expensive to build — one
/// device thread + compile cache — so they are reference-counted).
#[derive(Clone)]
pub enum Engine {
    Sequential(SequentialEngine),
    Vectorized(VectorizedEngine),
    Xla(Arc<XlaEngine>),
}

impl Engine {
    /// Construct an engine for a choice; `Xla` loads the default
    /// artifact directory and starts the device thread.
    pub fn build(choice: EngineChoice) -> Result<Engine> {
        Ok(match choice {
            EngineChoice::Sequential => Engine::Sequential(SequentialEngine),
            EngineChoice::Vectorized => Engine::Vectorized(VectorizedEngine),
            EngineChoice::Xla => Engine::Xla(Arc::new(XlaEngine::from_default_artifacts()?)),
        })
    }

    /// Borrow as the trait object the algorithms take.
    pub fn as_ordering(&self) -> &dyn OrderingEngine {
        match self {
            Engine::Sequential(e) => e,
            Engine::Vectorized(e) => e,
            Engine::Xla(e) => e.as_ref(),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn choice_parsing() {
        assert_eq!(EngineChoice::parse("seq").unwrap(), EngineChoice::Sequential);
        assert_eq!(EngineChoice::parse("vectorized").unwrap(), EngineChoice::Vectorized);
        assert_eq!(EngineChoice::parse("xla").unwrap(), EngineChoice::Xla);
        assert!(EngineChoice::parse("cuda").is_err());
    }

    #[test]
    fn cpu_engines_build() {
        for c in [EngineChoice::Sequential, EngineChoice::Vectorized] {
            let e = Engine::build(c).unwrap();
            assert_eq!(e.as_ordering().name(), c.name());
        }
    }
}
