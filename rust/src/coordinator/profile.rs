//! Stage profiling over workload grids — the measurement machinery behind
//! Figure 2 (runtime scaling + the "ordering is ≤96% of wall-clock"
//! claim) and Figure 3 bottom (the VarLiNGAM profile).

use crate::lingam::{DirectLingam, OrderingEngine, VarLingam};
use crate::linalg::Mat;
use crate::util::Result;

/// One grid point of a profiling run.
#[derive(Debug, Clone)]
pub struct ProfileRow {
    pub n: usize,
    pub d: usize,
    pub engine: &'static str,
    /// Total fit seconds.
    pub total_secs: f64,
    /// Seconds in the causal-ordering stage.
    pub ordering_secs: f64,
    /// Fraction of total spent ordering (the Figure-2 top-left number).
    pub ordering_frac: f64,
    /// Seconds in everything else (VAR fit and/or regression pruning).
    pub other_secs: f64,
}

/// Fit DirectLiNGAM once and report the stage split.
pub fn profile_direct(data: &Mat, engine: &dyn OrderingEngine) -> Result<ProfileRow> {
    let fit = DirectLingam::new().fit(data, engine)?;
    let total = fit.profile.total_secs();
    let ordering = fit.profile.secs("ordering");
    Ok(ProfileRow {
        n: data.rows(),
        d: data.cols(),
        engine: engine.name(),
        total_secs: total,
        ordering_secs: ordering,
        ordering_frac: fit.profile.fraction("ordering"),
        other_secs: total - ordering,
    })
}

/// Fit VarLiNGAM once and report the stage split (ordering fraction is
/// relative to the full pipeline including the VAR fit).
pub fn profile_var(series: &Mat, engine: &dyn OrderingEngine) -> Result<ProfileRow> {
    let fit = VarLingam::new().fit(series, engine)?;
    let total = fit.profile.total_secs();
    let ordering = fit.profile.secs("ordering");
    Ok(ProfileRow {
        n: series.rows(),
        d: series.cols(),
        engine: engine.name(),
        total_secs: total,
        ordering_secs: ordering,
        ordering_frac: if total > 0.0 { ordering / total } else { 0.0 },
        other_secs: total - ordering,
    })
}

/// Power-law extrapolation of sequential runtime to an (n, d) outside the
/// measured grid (Figure 2 top-right extends to 1e6 × 100, which took the
/// paper 7 CPU-hours; we measure a feasible grid and extrapolate with the
/// algorithm's known O(n · d²) ordering cost).
pub fn extrapolate_seconds(rows: &[ProfileRow], target_n: usize, target_d: usize) -> f64 {
    // fit c in t = c · n · d²  by least squares over the measured grid
    let mut num = 0.0;
    let mut den = 0.0;
    for r in rows {
        let w = (r.n as f64) * (r.d as f64).powi(2);
        num += w * r.total_secs;
        den += w * w;
    }
    let c = num / den.max(1e-300);
    c * (target_n as f64) * (target_d as f64).powi(2)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lingam::{SequentialEngine, VectorizedEngine};
    use crate::sim::{simulate_sem, simulate_var, SemSpec, VarSpec};
    use crate::util::rng::Pcg64;

    #[test]
    fn direct_profile_sums() {
        let mut rng = Pcg64::seed_from_u64(1);
        let ds = simulate_sem(&SemSpec::layered(8, 2, 0.5), 2_000, &mut rng);
        let row = profile_direct(&ds.data, &SequentialEngine).unwrap();
        assert!(row.total_secs > 0.0);
        assert!((row.ordering_secs + row.other_secs - row.total_secs).abs() < 1e-9);
        assert!(row.ordering_frac > 0.5);
    }

    #[test]
    fn var_profile_includes_var_fit() {
        let mut rng = Pcg64::seed_from_u64(2);
        let ds = simulate_var(&VarSpec { dim: 6, ..Default::default() }, 3_000, &mut rng);
        let row = profile_var(&ds.data, &VectorizedEngine).unwrap();
        assert!(row.other_secs > 0.0, "var_fit + regression time should be visible");
        assert!(row.ordering_frac > 0.0 && row.ordering_frac <= 1.0);
    }

    #[test]
    fn extrapolation_scales_cubically() {
        let rows = vec![
            ProfileRow {
                n: 1000,
                d: 10,
                engine: "sequential",
                total_secs: 1.0,
                ordering_secs: 0.96,
                ordering_frac: 0.96,
                other_secs: 0.04,
            },
        ];
        let t = extrapolate_seconds(&rows, 2000, 20);
        // n doubles (×2), d doubles (×4) → ×8
        assert!((t - 8.0).abs() < 1e-9, "t={t}");
    }
}
