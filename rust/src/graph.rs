//! Causal DAGs: a weighted-adjacency representation, acyclicity checks,
//! topological orders, degree statistics, and the random-DAG generators
//! the paper's simulations use.
//!
//! Convention (matches the `lingam` reference package): `B[(i, j)] ≠ 0`
//! means **j → i**, i.e. row `i` holds the coefficients of `x_i`'s
//! parents: `x_i = Σ_j B[i,j] x_j + ε_i`.

use crate::linalg::Mat;
use crate::util::rng::Pcg64;

/// A directed acyclic graph with edge weights (the SEM coefficients θ).
#[derive(Clone, Debug)]
pub struct Dag {
    /// Weighted adjacency, `adj[(i, j)] = θ_ij` meaning j → i.
    pub adj: Mat,
}

impl Dag {
    /// From a weighted adjacency matrix (validated for acyclicity).
    pub fn new(adj: Mat) -> Option<Dag> {
        let d = Dag { adj };
        if d.topological_order().is_some() {
            Some(d)
        } else {
            None
        }
    }

    /// Number of variables.
    pub fn dim(&self) -> usize {
        self.adj.rows()
    }

    /// Number of (directed) edges.
    pub fn num_edges(&self) -> usize {
        self.adj.as_slice().iter().filter(|&&w| w != 0.0).count()
    }

    /// Parents of node `i`.
    pub fn parents(&self, i: usize) -> Vec<usize> {
        (0..self.dim()).filter(|&j| self.adj[(i, j)] != 0.0).collect()
    }

    /// Children of node `j`.
    pub fn children(&self, j: usize) -> Vec<usize> {
        (0..self.dim()).filter(|&i| self.adj[(i, j)] != 0.0).collect()
    }

    /// In-degree of each node (number of parents).
    pub fn in_degrees(&self) -> Vec<usize> {
        (0..self.dim()).map(|i| self.parents(i).len()).collect()
    }

    /// Out-degree of each node (number of children).
    pub fn out_degrees(&self) -> Vec<usize> {
        (0..self.dim()).map(|j| self.children(j).len()).collect()
    }

    /// Leaf nodes: no outgoing edges (influence nothing) — the paper calls
    /// out USB/FITB as leaves of the stock graph in this sense.
    pub fn leaves(&self) -> Vec<usize> {
        self.out_degrees()
            .iter()
            .enumerate()
            .filter(|(_, &d)| d == 0)
            .map(|(i, _)| i)
            .collect()
    }

    /// Kahn topological order over causes-first; `None` if cyclic.
    pub fn topological_order(&self) -> Option<Vec<usize>> {
        topological_order(&self.adj)
    }
}

/// Kahn's algorithm on a weighted adjacency (j → i iff `adj[(i,j)] != 0`).
/// Returns a causes-first order, or `None` if the graph has a cycle.
pub fn topological_order(adj: &Mat) -> Option<Vec<usize>> {
    let d = adj.rows();
    assert_eq!(d, adj.cols());
    let mut indeg: Vec<usize> = (0..d)
        .map(|i| (0..d).filter(|&j| adj[(i, j)] != 0.0).count())
        .collect();
    let mut queue: Vec<usize> = (0..d).filter(|&i| indeg[i] == 0).collect();
    let mut order = Vec::with_capacity(d);
    while let Some(j) = queue.pop() {
        order.push(j);
        for i in 0..d {
            if adj[(i, j)] != 0.0 {
                indeg[i] -= 1;
                if indeg[i] == 0 {
                    queue.push(i);
                }
            }
        }
    }
    (order.len() == d).then_some(order)
}

/// Is the weighted adjacency acyclic?
pub fn is_acyclic(adj: &Mat) -> bool {
    topological_order(adj).is_some()
}

/// Check that `order` is consistent with `adj`: every edge j → i has j
/// earlier in the order than i. (The correctness criterion for a causal
/// ordering even when it is not unique.)
pub fn order_consistent(adj: &Mat, order: &[usize]) -> bool {
    let d = adj.rows();
    if order.len() != d {
        return false;
    }
    let mut pos = vec![usize::MAX; d];
    for (p, &v) in order.iter().enumerate() {
        if v >= d || pos[v] != usize::MAX {
            return false;
        }
        pos[v] = p;
    }
    for i in 0..d {
        for j in 0..d {
            if adj[(i, j)] != 0.0 && pos[j] > pos[i] {
                return false;
            }
        }
    }
    true
}

/// Layered random DAG per the paper's §3.1 simulation design: vertices
/// are arranged in levels; a vertex at level `l` may only have parents at
/// level `l − 1`. Edge weights θ ~ N(0, 1).
///
/// `dim` variables over `levels` levels, each potential (parent, child)
/// pair across adjacent levels included with probability `p_edge`.
pub fn layered_dag(dim: usize, levels: usize, p_edge: f64, rng: &mut Pcg64) -> Dag {
    assert!(levels >= 1 && dim >= levels);
    // assign variables to levels round-robin then shuffle for irregularity
    let mut level_of: Vec<usize> = (0..dim).map(|i| i % levels).collect();
    rng.shuffle(&mut level_of);
    let mut adj = Mat::zeros(dim, dim);
    for child in 0..dim {
        let lc = level_of[child];
        if lc == 0 {
            continue;
        }
        for parent in 0..dim {
            if level_of[parent] == lc - 1 && rng.bernoulli(p_edge) {
                adj[(child, parent)] = rng.normal(); // θ ~ N(0,1)
            }
        }
    }
    Dag::new(adj).expect("layered construction is acyclic by construction")
}

/// Deterministic chain DAG `0 → 1 → … → dim−1`, every edge with weight
/// `weight`. The canonical clearly-separated-root panel the pruning
/// exactness suite and the `sweep_pruning` bench both sample from (one
/// shared definition so the bench can never drift from what the tests
/// pin).
pub fn chain_dag(dim: usize, weight: f64) -> Dag {
    let mut adj = Mat::zeros(dim, dim);
    for i in 1..dim {
        adj[(i, i - 1)] = weight;
    }
    Dag::new(adj).expect("a chain is acyclic by construction")
}

/// Erdős–Rényi random DAG: sample a random permutation as the causal
/// order, include each forward edge with probability chosen to hit an
/// expected `edges_per_node` average degree; weights uniform in
/// ±[w_lo, w_hi] (the NOTEARS-literature convention).
pub fn erdos_renyi_dag(
    dim: usize,
    edges_per_node: f64,
    w_lo: f64,
    w_hi: f64,
    rng: &mut Pcg64,
) -> Dag {
    let order = rng.permutation(dim);
    let p = (edges_per_node * dim as f64 / (dim as f64 * (dim as f64 - 1.0) / 2.0)).min(1.0);
    let mut adj = Mat::zeros(dim, dim);
    for a in 0..dim {
        for b in (a + 1)..dim {
            if rng.bernoulli(p) {
                let (parent, child) = (order[a], order[b]);
                let mag = rng.uniform(w_lo, w_hi);
                let sign = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
                adj[(child, parent)] = sign * mag;
            }
        }
    }
    Dag::new(adj).expect("forward edges over a permutation are acyclic")
}

#[cfg(test)]
mod tests {
    use super::*;

    fn chain3() -> Mat {
        // 0 → 1 → 2
        let mut adj = Mat::zeros(3, 3);
        adj[(1, 0)] = 1.0;
        adj[(2, 1)] = 1.0;
        adj
    }

    #[test]
    fn topo_on_chain() {
        let order = topological_order(&chain3()).unwrap();
        assert!(order_consistent(&chain3(), &order));
        assert_eq!(order, vec![0, 1, 2]);
    }

    #[test]
    fn cycle_detected() {
        let mut adj = chain3();
        adj[(0, 2)] = 1.0; // close the loop
        assert!(!is_acyclic(&adj));
        assert!(Dag::new(adj).is_none());
    }

    #[test]
    fn order_consistency_rejects_bad_orders() {
        assert!(!order_consistent(&chain3(), &[2, 1, 0]));
        assert!(!order_consistent(&chain3(), &[0, 1])); // wrong length
        assert!(!order_consistent(&chain3(), &[0, 0, 1])); // not a permutation
    }

    #[test]
    fn degrees_and_leaves() {
        let d = Dag::new(chain3()).unwrap();
        assert_eq!(d.in_degrees(), vec![0, 1, 1]);
        assert_eq!(d.out_degrees(), vec![1, 1, 0]);
        assert_eq!(d.leaves(), vec![2]);
        assert_eq!(d.parents(1), vec![0]);
        assert_eq!(d.children(1), vec![2]);
    }

    #[test]
    fn layered_dag_respects_levels() {
        let mut rng = Pcg64::seed_from_u64(5);
        for _ in 0..10 {
            let g = layered_dag(12, 3, 0.6, &mut rng);
            assert!(g.topological_order().is_some());
            assert!(g.num_edges() > 0);
        }
    }

    #[test]
    fn er_dag_acyclic_and_weighted() {
        let mut rng = Pcg64::seed_from_u64(6);
        let g = erdos_renyi_dag(20, 2.0, 0.5, 2.0, &mut rng);
        assert!(g.topological_order().is_some());
        for &w in g.adj.as_slice() {
            assert!(w == 0.0 || (0.5..=2.0).contains(&w.abs()));
        }
    }
}
