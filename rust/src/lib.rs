//! # AcceleratedLiNGAM
//!
//! Reproduction of *AcceleratedLiNGAM: Learning Causal DAGs at the speed of
//! GPUs* (Akinwande & Kolter, 2024) as a three-layer Rust + JAX + Pallas
//! stack:
//!
//! - **L1** — the causal-ordering hot spot as a Pallas kernel
//!   (`python/compile/kernels/`), AOT-lowered to HLO text.
//! - **L2** — the JAX compute graph around it (`python/compile/model.py`).
//! - **L3** — this crate: the coordinator that drives DirectLiNGAM /
//!   VarLiNGAM, loads the AOT artifacts via PJRT, and hosts the
//!   substrates (linear algebra, simulation, metrics, baselines) the
//!   paper's evaluation needs.
//!
//! Python never runs on the request path: after `make artifacts` the
//! `alingam` binary is self-contained.
//!
//! ## The ordering pipeline: engines and sessions
//!
//! The hot path is organized around two abstractions in [`lingam`]:
//!
//! - an [`lingam::OrderingEngine`] names a *backend* (sequential
//!   baseline, vectorized, parallel, XLA) and doubles as a **session
//!   factory**;
//! - an [`lingam::OrderingSession`] is the per-fit *workspace* whose
//!   lifecycle `DirectLingam::fit` drives:
//!   **create → score → choose → residualize+update → … → finish**.
//!
//! The session is created once per fit and owns the standardized column
//! cache, a persistent correlation matrix and the per-column entropy
//! cache. Between steps it residualizes the cache in
//! place with the closed form `(c_j − ρ_jm·c_m)/√(1−ρ_jm²)` and updates
//! the correlation matrix analytically in O(d²) — so only the entropy
//! and pair-score sweeps still touch sample data, instead of the
//! re-standardize + O(d²·n) correlation dots the stateless path pays on
//! every step (ParaLiNGAM-style cross-iteration reuse). Engines without
//! an incremental workspace (the sequential baseline) run under a
//! stateless shim with their exact legacy per-step behavior, and
//! `DirectLingam::fit_stateless` keeps the legacy loop as the measured
//! baseline.
//!
//! On the accelerated path the same lifecycle is **device-resident**
//! ([`lingam::XlaSession`]): `session_init` uploads and standardizes
//! the panel once into a packed on-device state (column cache +
//! correlation matrix as one PJRT buffer), then each step downloads
//! only the `session_scores` row, picks the root on the host (NaN-safe,
//! same tie-breaking as the CPU engines) and uploads only the one-hot
//! choice to `session_update`, which residualizes the cache and updates
//! the correlations on the device. Artifact names:
//! `session_{init,scores,update}_n{N}_d{D}.hlo.txt` next to the legacy
//! `order_scores`/`order_step`/`var_fit` artifacts in `artifacts/`
//! (regenerate with `make artifacts`). The stateless fused `order_step`
//! remains as the measured baseline and as the fallback when a manifest
//! predates the session kinds.
//!
//! On machines without an accelerator the default CPU path is the
//! multi-threaded [`lingam::ParallelEngine`], which tiles the same
//! restructured pair kernel as the vectorized engine — and its session's
//! workspace sweeps — across a work-stealing worker pool
//! (ParaLiNGAM-style). Degenerate panels — constant or collinear columns
//! — surface as [`util::Error::InvalidArgument`] rather than NaN panics.
//!
//! Every CPU sweep runs on the [`lingam::sweep`] subsystem: a chunked,
//! autovectorizable fused pair kernel underneath, and on top either the
//! exact pair loops or the opt-in **bound-pruned scheduled sweep**
//! (`ParallelEngine::with_pruning()`, `pruned[:N]` on the CLI,
//! [`lingam::SweepStrategy::Pruned`] on a session). Because Algorithm
//! 1's per-candidate penalty only accumulates, a candidate whose running
//! penalty exceeds the best completed total can stop mid-sweep without
//! changing the chosen root — ParaLiNGAM-style work *avoidance* layered
//! under the same work *distribution*, provably order-identical, with
//! [`lingam::SweepCounters`] reporting pairs visited/skipped through
//! `OrderingSession::sweep_counters`. Pruned sweeps are scheduled
//! likely-roots-first: by the previous step's scores, and on the very
//! first step by cheap per-column non-Gaussianity proxies (|excess
//! kurtosis| of the standardized cache) — scheduling only, never
//! pruning semantics. The optional `fastmath` feature compiles an
//! accuracy-bounded polynomial-`exp` kernel (≤ 2e-7 relative error per
//! call) that sessions can opt into.
//!
//! ## The plan layer: partitioned ordering
//!
//! Above engines and sessions sits a third seam, the
//! [`lingam::OrderingPlan`]: a strategy that produces the *whole* causal
//! order, which `DirectLingam::fit_plan` validates and finishes with the
//! shared regression stage. The trivial plan
//! ([`lingam::SingleBlockPlan`]) is the whole-panel session fit; the
//! interesting one ([`lingam::PartitionedPlan`], `partition[:B]` on the
//! CLI and over the wire) decomposes the panel into connected components
//! of the thresholded correlation graph — read off the correlation
//! matrix the session has already computed — orders blocks
//! independently, and reconciles the block orders across boundary pairs.
//! Its merge tiers mirror the sweep strategies: the **exact** tier is
//! provably the unpartitioned fit (one global session; the partition
//! only counts the cross-block work a lossy split would skip), while the
//! **approx** tier actually drops the per-step sweep from O(d²·n) to
//! O(Σ_b d_b²·n) plus a bound-pruned boundary-pair tournament, trading
//! SHD that the `partition_scaling` bench measures rather than promises
//! away (see [`lingam::partition`] for the exactness argument). The
//! bootstrap pools [`lingam::PartitionWorkspace`]s across resamples like
//! any other session workspace.
//!
//! ## Batched scoring: one session, B panels
//!
//! The session lifecycle also scales *across* panels:
//! [`lingam::BatchedSession`] drives B same-shape panels in lock-step —
//! one shared worker pool sweeps every live lane at each step, with
//! per-panel roots, counters and failures (a degenerate or canceled
//! lane dies alone; its peers never notice). The batch replicates the
//! solo session's pool-vs-serial decision per lock step, so every lane
//! is **bitwise** the fit `fit_session` would have produced — orders,
//! step scores, adjacency and pruned-sweep counters alike
//! (`tests/batch_agreement.rs` property-pins this). Two callers ride
//! it: the serve tier's fusion window (below) and the bootstrap, which
//! refits resample groups through one batched session instead of one
//! session per resample. On the accelerated path
//! [`lingam::XlaBatchSession`] is the same lock-step over
//! `session_{init,scores,update}_batch_n{N}_d{D}_b{B}.hlo.txt`
//! artifacts (`jax.vmap` over the solo kernels, bitwise per lane): one
//! `session_init` upload for the whole group, then per step one `[B, d]`
//! scores fetch and one `[B, d]` one-hot dispatch regardless of B.
//!
//! ## The serving layer
//!
//! [`serve`] makes the repo a long-lived process instead of a batch
//! tool: a std-only JSON-lines-over-TCP service (`alingam serve` /
//! `alingam client`) with a bounded job queue (backpressure,
//! FIFO-per-client fairness), N workers holding parked
//! [`lingam::IncrementalSession`] workspaces hot across requests, a
//! panel-hash LRU result cache answering byte-identical requests
//! without recomputation, streamed per-step/per-resample progress over
//! the session lifecycle, cooperative cancellation, and graceful drain
//! on shutdown. With `--fuse-wait-ms`/`--max-batch` set, a worker that
//! pops a batchable fit opens a **fusion window**: it gathers queued
//! same-shape peers (prefix-only per client, so FIFO survives) and
//! drives the group through one [`lingam::BatchedSession`], with the
//! `batch` object of the metrics frame booking batches dispatched, jobs
//! fused, mean occupancy and window wait. The protocol and the CLI `--json` mode share one
//! serialization surface (`serve::protocol` over the same escaping
//! primitives as `util::table::Table::to_json`), so every JSON the repo
//! emits — bench artifacts, CLI results, service frames — parses the
//! same way.
//!
//! The production tier stacks three pieces on that core. An HTTP/1.1
//! front (`--http-addr`, [`serve::http`]) maps `POST /fit`,
//! `POST /bootstrap`, `GET /status` and `GET /metrics` onto the same
//! queue and streams job frames as Server-Sent Events — the SSE `data:`
//! payloads are byte-identical to the TCP lines because both fronts
//! share the protocol's frame builders. A shard supervisor
//! (`--shards N`, [`serve::shard`]) turns one process into a fleet: N
//! child servers on loopback ports, jobs routed by panel hash, crashed
//! shards restarted with backoff (only their in-flight jobs fail), and
//! fleet-wide `shards_live`/`shard_restarts`/per-shard metrics. A
//! disk-persistent result cache (`--cache-dir`, [`serve::cache`])
//! appends fsynced, checksummed records to a segment file and replays
//! the intact prefix on boot, so a byte-identical re-fit survives a
//! full restart — or a crash mid-append — without executing a job.
//!
//! ## Observability
//!
//! [`obs`] is the std-only telemetry substrate under the serve tier:
//! lock-free log-bucketed latency histograms ([`obs::hist`],
//! snapshot/merge-able across shard processes), per-job trace contexts
//! ([`obs::trace`]) that record typed span events — queue wait, fusion
//! wait, cache probe, session acquire, per-ordering-step, regression,
//! frame flush — from submit to terminal frame, and a leveled key=value
//! logger ([`obs::log`], `--log-level`/`--log-json`) whose records
//! carry the trace id. Every terminal `result` frame embeds a compact
//! `"timing"` breakdown, completed traces replay via the `trace`
//! request / `GET /trace/<id>`, and `GET /metrics?format=prometheus`
//! renders counters, gauges and latency quantiles in Prometheus text
//! format — merged fleet-wide by the shard supervisor. On the ordering
//! side, [`lingam::StepObserver`] is the seam sessions report per-step
//! timing through; the serve workers install observers that feed the
//! step histogram and the per-job traces. See [`serve`]'s module docs
//! for the full metric-name table.
//!
//! ## Quick example
//!
//! ```no_run
//! use alingam::prelude::*;
//!
//! let mut rng = Pcg64::seed_from_u64(7);
//! let spec = sim::SemSpec::layered(10, 2, 0.5);
//! let ds = sim::simulate_sem(&spec, 10_000, &mut rng);
//! // the default CPU engine: one worker per core; ParallelEngine::new(1)
//! // or VectorizedEngine give the single-threaded restructured path
//! let engine = lingam::ParallelEngine::default();
//! let fit = lingam::DirectLingam::new().fit(&ds.data, &engine).unwrap();
//! let m = metrics::graph_metrics(&ds.adjacency, &fit.adjacency, 0.05);
//! println!("order = {:?}  F1 = {:.3}", fit.order, m.f1);
//! ```

pub mod util;
pub mod linalg;
pub mod stats;
pub mod graph;
pub mod sim;
pub mod metrics;
pub mod data;
pub mod lingam;
pub mod obs;
pub mod runtime;
pub mod coordinator;
pub mod serve;
pub mod baselines;
pub mod apps;

/// Convenience re-exports for downstream users and the examples.
pub mod prelude {
    pub use crate::graph::Dag;
    pub use crate::linalg::Mat;
    pub use crate::lingam::{
        self, DirectLingam, OrderingEngine, OrderingSession, ParallelEngine, SequentialEngine,
        VarLingam, VectorizedEngine,
    };
    pub use crate::metrics;
    pub use crate::sim;
    pub use crate::util::rng::Pcg64;
    pub use crate::coordinator;
    pub use crate::runtime;
}
