//! Synthetic Perturb-CITE-seq-style interventional gene expression.
//!
//! Substitutes the proprietary Frangieh et al. (2021) melanoma dataset
//! used in the paper's Table 1 with a generator that preserves the
//! structure the experiment exercises (DESIGN.md §Substitutions):
//!
//! - a sparse gene-regulatory DAG over `n_genes` genes,
//! - non-Gaussian expression noise (log-normal-ish via Laplace on the
//!   latent scale),
//! - targeted genetic interventions (CRISPR-knockout semantics: a
//!   `do(x_g = low)` operator) on a subset of genes,
//! - three experimental conditions (co-culture / IFN / control analogues)
//!   that shift the global expression profile and noise level,
//! - a 20%-of-interventions held-out test split.

use crate::graph::{self, Dag};
use crate::linalg::Mat;
use crate::sim::sem::Noise;
use crate::util::rng::Pcg64;

/// Experimental condition analogue (paper: co-culture, IFN-γ, control).
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum Condition {
    CoCulture,
    Ifn,
    Control,
}

impl Condition {
    pub fn all() -> [Condition; 3] {
        [Condition::CoCulture, Condition::Ifn, Condition::Control]
    }

    pub fn name(self) -> &'static str {
        match self {
            Condition::CoCulture => "co-culture",
            Condition::Ifn => "IFN",
            Condition::Control => "control",
        }
    }

    /// (global shift, noise scale) — conditions differ in baseline
    /// expression and measurement dispersion, mirroring how the three
    /// Perturb-CITE-seq conditions differ.
    fn profile(self) -> (f64, f64) {
        match self {
            Condition::CoCulture => (0.0, 1.0),
            Condition::Ifn => (0.4, 1.1),
            Condition::Control => (-0.2, 1.35),
        }
    }
}

/// Generator configuration.
#[derive(Clone, Debug)]
pub struct PerturbSpec {
    /// Total genes measured (paper: ~964 after filtering).
    pub n_genes: usize,
    /// Genes with targeted interventions (paper: 249).
    pub n_targets: usize,
    /// Cells per intervention.
    pub cells_per_target: usize,
    /// Unperturbed (observational) cells.
    pub n_control_cells: usize,
    /// Fraction of interventions held out for evaluation (paper: 20%).
    pub heldout_frac: f64,
    /// GRN density.
    pub edges_per_gene: f64,
    pub condition: Condition,
}

impl PerturbSpec {
    /// A laptop-scale default preserving the paper's proportions.
    pub fn small(condition: Condition) -> PerturbSpec {
        PerturbSpec {
            n_genes: 60,
            n_targets: 16,
            cells_per_target: 80,
            n_control_cells: 400,
            heldout_frac: 0.2,
            edges_per_gene: 1.5,
            condition,
        }
    }

    /// Paper-scale dimensions (d ≈ 964, 249 targets). Heavy: only used by
    /// the full-scale bench flag.
    pub fn paper_scale(condition: Condition) -> PerturbSpec {
        PerturbSpec {
            n_genes: 964,
            n_targets: 249,
            cells_per_target: 260,
            n_control_cells: 10_000,
            heldout_frac: 0.2,
            edges_per_gene: 2.0,
            condition,
        }
    }
}

/// A simulated interventional expression dataset.
#[derive(Clone, Debug)]
pub struct PerturbDataset {
    /// Expression `[cells, genes]` (continuous, log-normalized analogue).
    pub data: Mat,
    /// Per-cell intervention target (`None` = observational cell).
    pub intervention: Vec<Option<usize>>,
    /// Ground-truth GRN adjacency (j → i).
    pub adjacency: Mat,
    /// Row indices of training cells (interventions seen during fitting).
    pub train_idx: Vec<usize>,
    /// Row indices of held-out-intervention cells.
    pub test_idx: Vec<usize>,
    /// The held-out target genes.
    pub heldout_targets: Vec<usize>,
    pub condition: Condition,
}

/// Knockout expression level on the latent scale.
pub const KNOCKOUT_LEVEL: f64 = -2.0;

/// Simulate a Perturb-seq-style dataset.
pub fn simulate_perturb(spec: &PerturbSpec, rng: &mut Pcg64) -> PerturbDataset {
    assert!(spec.n_targets <= spec.n_genes);
    let (shift, noise_scale) = spec.condition.profile();
    let grn = graph::erdos_renyi_dag(spec.n_genes, spec.edges_per_gene, 0.4, 1.2, rng);
    let order = grn.topological_order().expect("GRN is a DAG");
    let noise = Noise::Laplace(0.7 * noise_scale);

    let targets = rng.choose(spec.n_genes, spec.n_targets);
    let n_heldout = ((spec.n_targets as f64) * spec.heldout_frac).round() as usize;
    let heldout_targets: Vec<usize> = targets[..n_heldout].to_vec();

    let total_cells = spec.n_control_cells + spec.n_targets * spec.cells_per_target;
    let mut data = Mat::zeros(total_cells, spec.n_genes);
    let mut intervention: Vec<Option<usize>> = Vec::with_capacity(total_cells);

    let mut row = 0;
    // observational cells
    for _ in 0..spec.n_control_cells {
        sample_cell(&grn, &order, noise, shift, None, data.row_mut(row), rng);
        intervention.push(None);
        row += 1;
    }
    // interventional cells
    for &g in &targets {
        for _ in 0..spec.cells_per_target {
            sample_cell(&grn, &order, noise, shift, Some(g), data.row_mut(row), rng);
            intervention.push(Some(g));
            row += 1;
        }
    }
    debug_assert_eq!(row, total_cells);

    let is_heldout = |t: Option<usize>| t.map(|g| heldout_targets.contains(&g)).unwrap_or(false);
    let train_idx: Vec<usize> =
        (0..total_cells).filter(|&r| !is_heldout(intervention[r])).collect();
    let test_idx: Vec<usize> = (0..total_cells).filter(|&r| is_heldout(intervention[r])).collect();

    PerturbDataset {
        data,
        intervention,
        adjacency: grn.adj,
        train_idx,
        test_idx,
        heldout_targets,
        condition: spec.condition,
    }
}

/// Sample one cell: ancestral sampling with an optional do() operator.
fn sample_cell(
    grn: &Dag,
    order: &[usize],
    noise: Noise,
    shift: f64,
    target: Option<usize>,
    out: &mut [f64],
    rng: &mut Pcg64,
) {
    for &i in order {
        if target == Some(i) {
            // do(x_g = knockout): severs incoming edges
            out[i] = KNOCKOUT_LEVEL + 0.1 * rng.normal();
            continue;
        }
        let mut v = shift + noise.sample(rng);
        for j in grn.parents(i) {
            v += grn.adj[(i, j)] * out[j];
        }
        out[i] = v;
    }
}

impl PerturbDataset {
    /// Training matrix (rows = train cells).
    pub fn train_data(&self) -> Mat {
        self.data.select_rows(&self.train_idx)
    }

    /// Test matrix (rows = held-out-intervention cells).
    pub fn test_data(&self) -> Mat {
        self.data.select_rows(&self.test_idx)
    }

    pub fn n_cells(&self) -> usize {
        self.data.rows()
    }

    pub fn n_genes(&self) -> usize {
        self.data.cols()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats;

    fn small() -> (PerturbDataset, PerturbSpec) {
        let spec = PerturbSpec::small(Condition::CoCulture);
        let mut rng = Pcg64::seed_from_u64(11);
        (simulate_perturb(&spec, &mut rng), spec)
    }

    #[test]
    fn shapes_and_split() {
        let (ds, spec) = small();
        assert_eq!(ds.n_genes(), spec.n_genes);
        assert_eq!(
            ds.n_cells(),
            spec.n_control_cells + spec.n_targets * spec.cells_per_target
        );
        assert_eq!(ds.train_idx.len() + ds.test_idx.len(), ds.n_cells());
        // ~20% of interventions held out
        let expected = (spec.n_targets as f64 * spec.heldout_frac).round() as usize;
        assert_eq!(ds.heldout_targets.len(), expected);
        assert!(!ds.test_idx.is_empty());
    }

    #[test]
    fn heldout_cells_only_heldout_targets() {
        let (ds, _) = small();
        for &r in &ds.test_idx {
            let t = ds.intervention[r].expect("test cells are interventional");
            assert!(ds.heldout_targets.contains(&t));
        }
        for &r in &ds.train_idx {
            if let Some(t) = ds.intervention[r] {
                assert!(!ds.heldout_targets.contains(&t));
            }
        }
    }

    #[test]
    fn knockout_sets_target_low() {
        let (ds, _) = small();
        for (r, t) in ds.intervention.iter().enumerate() {
            if let Some(g) = t {
                let v = ds.data[(r, *g)];
                assert!((v - KNOCKOUT_LEVEL).abs() < 1.0, "target {g} at {v}");
            }
        }
    }

    #[test]
    fn intervention_propagates_to_children() {
        // mean expression of a direct child should differ between control
        // cells and cells where its parent was knocked out
        let (ds, _) = small();
        let d = ds.n_genes();
        // find a (parent, child) pair where parent is an intervention target
        let mut found = false;
        'outer: for (r, t) in ds.intervention.iter().enumerate() {
            if let Some(g) = t {
                for i in 0..d {
                    if ds.adjacency[(i, *g)].abs() > 0.8 {
                        // collect child values under do(g) vs observational
                        let under_do: Vec<f64> = ds
                            .intervention
                            .iter()
                            .enumerate()
                            .filter(|(_, tt)| **tt == Some(*g))
                            .map(|(rr, _)| ds.data[(rr, i)])
                            .collect();
                        let obs: Vec<f64> = ds
                            .intervention
                            .iter()
                            .enumerate()
                            .filter(|(_, tt)| tt.is_none())
                            .map(|(rr, _)| ds.data[(rr, i)])
                            .collect();
                        let diff = (stats::mean(&under_do) - stats::mean(&obs)).abs();
                        assert!(diff > 0.3, "child {i} of {g}: diff={diff} (r={r})");
                        found = true;
                        break 'outer;
                    }
                }
            }
        }
        assert!(found, "no strong parent-child pair among targets");
    }

    #[test]
    fn conditions_differ_in_profile() {
        let mut rng = Pcg64::seed_from_u64(12);
        let a = simulate_perturb(&PerturbSpec::small(Condition::Ifn), &mut rng);
        let mut rng = Pcg64::seed_from_u64(12);
        let b = simulate_perturb(&PerturbSpec::small(Condition::Control), &mut rng);
        let ma = stats::mean(a.data.as_slice());
        let mb = stats::mean(b.data.as_slice());
        assert!(ma > mb, "IFN shift should exceed control ({ma} vs {mb})");
    }
}
