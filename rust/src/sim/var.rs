//! Structural VAR(1) time-series generator for VarLiNGAM validation:
//!
//!   x(t) = B₀ x(t) + B₁ x(t−1) + ε(t),  ε non-Gaussian, B₀ acyclic
//!
//! equivalently the reduced form x(t) = (I−B₀)⁻¹ (B₁ x(t−1) + ε(t)).

use crate::graph;
use crate::linalg::{lu_inverse, Mat};
use crate::sim::sem::Noise;
use crate::util::rng::Pcg64;

/// VAR(1) generator configuration.
#[derive(Clone, Debug)]
pub struct VarSpec {
    pub dim: usize,
    /// Instantaneous DAG density (expected edges per node of B₀).
    pub instant_edges_per_node: f64,
    /// Magnitude of lagged effects (B₁ entries ~ ±U(0, lag_scale), scaled
    /// down for stability).
    pub lag_scale: f64,
    /// Density of B₁.
    pub lag_density: f64,
    /// Innovation distribution.
    pub noise: Noise,
}

impl Default for VarSpec {
    fn default() -> Self {
        VarSpec {
            dim: 10,
            instant_edges_per_node: 1.0,
            lag_scale: 0.3,
            lag_density: 0.2,
            noise: Noise::Laplace(1.0),
        }
    }
}

/// A simulated VAR dataset with ground truth.
#[derive(Clone, Debug)]
pub struct VarDataset {
    /// Time series `[T, dim]` (row t is x(t)).
    pub data: Mat,
    /// True instantaneous adjacency B₀ (acyclic).
    pub b0: Mat,
    /// True lag-1 coefficients B₁.
    pub b1: Mat,
}

/// Simulate `t_len` steps (after a burn-in) of the structural VAR.
pub fn simulate_var(spec: &VarSpec, t_len: usize, rng: &mut Pcg64) -> VarDataset {
    let d = spec.dim;
    // B0: acyclic instantaneous effects with moderate weights
    let b0 = graph::erdos_renyi_dag(d, spec.instant_edges_per_node, 0.3, 0.8, rng).adj;
    // B1: sparse lagged effects, scaled for stationarity
    let mut b1 = Mat::zeros(d, d);
    for i in 0..d {
        for j in 0..d {
            if rng.bernoulli(spec.lag_density) {
                let sign = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
                b1[(i, j)] = sign * rng.uniform(0.1, spec.lag_scale);
            }
        }
    }
    // normalize B1 spectral-ish via row-sum bound to keep the process stable
    let max_row: f64 = (0..d)
        .map(|i| b1.row(i).iter().map(|x| x.abs()).sum::<f64>())
        .fold(0.0, f64::max);
    if max_row > 0.9 {
        b1 = b1.scale(0.9 / max_row);
    }

    let inv = lu_inverse(&Mat::eye(d).sub(&b0)).expect("I - B0 invertible (B0 acyclic)");
    let burn = 200;
    let mut x_prev = vec![0.0; d];
    let mut data = Mat::zeros(t_len, d);
    for t in 0..(burn + t_len) {
        let mut rhs: Vec<f64> = b1.matvec(&x_prev);
        for v in rhs.iter_mut() {
            *v += spec.noise.sample(rng);
        }
        let x_t = inv.matvec(&rhs);
        if t >= burn {
            data.row_mut(t - burn).copy_from_slice(&x_t);
        }
        x_prev = x_t;
    }
    VarDataset { data, b0, b1 }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats;

    #[test]
    fn generates_stationary_series() {
        let mut rng = Pcg64::seed_from_u64(1);
        let ds = simulate_var(&VarSpec::default(), 2_000, &mut rng);
        assert_eq!(ds.data.rows(), 2_000);
        assert!(ds.data.is_finite());
        // variance of first and second half should be comparable (stationary)
        let col = ds.data.col(0);
        let v1 = stats::var(&col[..1000]);
        let v2 = stats::var(&col[1000..]);
        assert!(v1 / v2 < 5.0 && v2 / v1 < 5.0, "v1={v1} v2={v2}");
    }

    #[test]
    fn b0_is_acyclic() {
        let mut rng = Pcg64::seed_from_u64(2);
        let ds = simulate_var(&VarSpec::default(), 100, &mut rng);
        assert!(graph::is_acyclic(&ds.b0));
    }

    #[test]
    fn lagged_dependence_present() {
        // with strong lag coefficients, x(t) should correlate with x(t−1)
        let spec = VarSpec { lag_density: 0.8, lag_scale: 0.5, ..Default::default() };
        let mut rng = Pcg64::seed_from_u64(3);
        let ds = simulate_var(&spec, 4_000, &mut rng);
        let col = ds.data.col(0);
        let lagged: Vec<f64> = col[..col.len() - 1].to_vec();
        let lead: Vec<f64> = col[1..].to_vec();
        let rho = stats::cov(&lead, &lagged) / (stats::std(&lead) * stats::std(&lagged));
        assert!(rho.abs() > 0.05, "rho={rho}");
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = VarSpec::default();
        let a = simulate_var(&spec, 50, &mut Pcg64::seed_from_u64(7));
        let b = simulate_var(&spec, 50, &mut Pcg64::seed_from_u64(7));
        assert_eq!(a.data, b.data);
        assert_eq!(a.b0, b.b0);
        assert_eq!(a.b1, b.b1);
    }
}
