//! Linear non-Gaussian SEM data generation.
//!
//! Default configuration reproduces the paper's §3.1 design: a layered
//! DAG (each vertex's parents all sit one level up), causal strengths
//! θ ~ N(0, 1), noise ε ~ Uniform(0, 1).

use crate::graph::{self, Dag};
use crate::linalg::Mat;
use crate::util::rng::Pcg64;

/// Noise family for the SEM error terms. LiNGAM's identifiability needs a
/// non-Gaussian choice; `Gaussian` exists to demonstrate the failure mode
/// (Figure 1's caveat: asymmetry vanishes for Gaussian noise).
#[derive(Clone, Copy, Debug, PartialEq)]
pub enum Noise {
    /// ε ~ Uniform(0, 1) — the paper's §3.1 choice.
    Uniform01,
    /// ε ~ Laplace(0, b).
    Laplace(f64),
    /// ε ~ Exponential(rate), centered.
    Exponential(f64),
    /// ε ~ N(0, σ) — the *non-identifiable* case, for negative tests.
    Gaussian(f64),
}

impl Noise {
    /// Draw one noise sample.
    pub fn sample(self, rng: &mut Pcg64) -> f64 {
        match self {
            Noise::Uniform01 => rng.paper_noise(),
            Noise::Laplace(b) => rng.laplace(b),
            Noise::Exponential(r) => rng.exponential(r) - 1.0 / r,
            Noise::Gaussian(s) => rng.normal() * s,
        }
    }
}

/// SEM generator configuration.
#[derive(Clone, Debug)]
pub struct SemSpec {
    /// How to draw the DAG.
    pub dag: DagSpec,
    /// Noise family.
    pub noise: Noise,
}

/// DAG topology choices.
#[derive(Clone, Debug)]
pub enum DagSpec {
    /// Paper §3.1: `dim` nodes over `levels` levels, adjacent-level edges
    /// with probability `p_edge`, θ ~ N(0,1).
    Layered { dim: usize, levels: usize, p_edge: f64 },
    /// Erdős–Rényi with expected `edges_per_node`, |θ| ~ U(w_lo, w_hi).
    ErdosRenyi { dim: usize, edges_per_node: f64, w_lo: f64, w_hi: f64 },
    /// A fixed, caller-provided DAG.
    Fixed(Dag),
}

impl SemSpec {
    /// The paper's §3.1 configuration (layered DAG, uniform noise).
    pub fn layered(dim: usize, levels: usize, p_edge: f64) -> SemSpec {
        SemSpec { dag: DagSpec::Layered { dim, levels, p_edge }, noise: Noise::Uniform01 }
    }

    /// ER topology with uniform-magnitude weights.
    pub fn erdos_renyi(dim: usize, edges_per_node: f64) -> SemSpec {
        SemSpec {
            dag: DagSpec::ErdosRenyi { dim, edges_per_node, w_lo: 0.5, w_hi: 2.0 },
            noise: Noise::Uniform01,
        }
    }

    pub fn with_noise(mut self, noise: Noise) -> SemSpec {
        self.noise = noise;
        self
    }
}

/// A simulated SEM dataset with its ground truth.
#[derive(Clone, Debug)]
pub struct SemDataset {
    /// Observations `[n, dim]`.
    pub data: Mat,
    /// True weighted adjacency (`adj[(i,j)] = θ_ij`, j → i).
    pub adjacency: Mat,
    /// A true causal order (causes first).
    pub order: Vec<usize>,
}

/// Simulate `n` i.i.d. samples from the SEM described by `spec`.
pub fn simulate_sem(spec: &SemSpec, n: usize, rng: &mut Pcg64) -> SemDataset {
    let dag = match &spec.dag {
        DagSpec::Layered { dim, levels, p_edge } => graph::layered_dag(*dim, *levels, *p_edge, rng),
        DagSpec::ErdosRenyi { dim, edges_per_node, w_lo, w_hi } => {
            graph::erdos_renyi_dag(*dim, *edges_per_node, *w_lo, *w_hi, rng)
        }
        DagSpec::Fixed(d) => d.clone(),
    };
    let data = sample_from_dag(&dag, spec.noise, n, rng);
    let order = dag.topological_order().expect("generator DAGs are acyclic");
    SemDataset { data, adjacency: dag.adj, order }
}

/// Sample data from a fixed DAG: in topological order,
/// `x_i = Σ_j θ_ij x_j + ε_i`.
pub fn sample_from_dag(dag: &Dag, noise: Noise, n: usize, rng: &mut Pcg64) -> Mat {
    let d = dag.dim();
    let order = dag.topological_order().expect("acyclic");
    let mut x = Mat::zeros(n, d);
    for r in 0..n {
        for &i in &order {
            let mut v = noise.sample(rng);
            for j in dag.parents(i) {
                v += dag.adj[(i, j)] * x[(r, j)];
            }
            x[(r, i)] = v;
        }
    }
    x
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::stats;

    #[test]
    fn shapes_and_truth_consistent() {
        let mut rng = Pcg64::seed_from_u64(1);
        let ds = simulate_sem(&SemSpec::layered(10, 2, 0.5), 500, &mut rng);
        assert_eq!(ds.data.rows(), 500);
        assert_eq!(ds.data.cols(), 10);
        assert!(graph::order_consistent(&ds.adjacency, &ds.order));
        assert!(ds.data.is_finite());
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = SemSpec::layered(8, 2, 0.5);
        let a = simulate_sem(&spec, 100, &mut Pcg64::seed_from_u64(9));
        let b = simulate_sem(&spec, 100, &mut Pcg64::seed_from_u64(9));
        assert_eq!(a.data, b.data);
        assert_eq!(a.adjacency, b.adjacency);
    }

    #[test]
    fn root_variable_matches_noise_distribution() {
        // a root (no parents) should carry pure U(0,1) noise
        let mut rng = Pcg64::seed_from_u64(2);
        let ds = simulate_sem(&SemSpec::layered(6, 2, 0.8), 20_000, &mut rng);
        let roots: Vec<usize> = (0..6)
            .filter(|&i| (0..6).all(|j| ds.adjacency[(i, j)] == 0.0))
            .collect();
        assert!(!roots.is_empty());
        let col = ds.data.col(roots[0]);
        assert!((stats::mean(&col) - 0.5).abs() < 0.02);
        assert!((stats::var(&col) - 1.0 / 12.0).abs() < 0.005);
    }

    #[test]
    fn child_is_linear_in_parents() {
        // fixed chain 0 → 1 with θ = 2, zero-noise-ish via tiny uniform
        let mut adj = Mat::zeros(2, 2);
        adj[(1, 0)] = 2.0;
        let dag = Dag::new(adj).unwrap();
        let mut rng = Pcg64::seed_from_u64(3);
        let x = sample_from_dag(&dag, Noise::Uniform01, 5_000, &mut rng);
        // regression slope of x1 on x0 ≈ 2
        let c0 = x.col(0);
        let c1 = x.col(1);
        let slope = stats::cov(&c1, &c0) / stats::var(&c0);
        assert!((slope - 2.0).abs() < 0.1, "slope={slope}");
    }

    #[test]
    fn gaussian_noise_available_for_negative_tests() {
        let mut rng = Pcg64::seed_from_u64(4);
        let spec = SemSpec::layered(5, 2, 0.5).with_noise(Noise::Gaussian(1.0));
        let ds = simulate_sem(&spec, 10_000, &mut rng);
        let roots: Vec<usize> = (0..5)
            .filter(|&i| (0..5).all(|j| ds.adjacency[(i, j)] == 0.0))
            .collect();
        let col = ds.data.col(roots[0]);
        assert!(stats::excess_kurtosis(&col).abs() < 0.2);
    }
}
