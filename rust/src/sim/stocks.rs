//! Synthetic S&P-500-style hourly market generator (Figure 4 / Table 2
//! substitute for the paper's Yahoo Finance pull — see DESIGN.md).
//!
//! Log-returns follow a structural VAR(1):
//!   r(t) = B₀ r(t) + B₁ r(t−1) + ε(t),   ε heavy-tailed (Student-t),
//! with a sector-block instantaneous DAG. A handful of tickers are made
//! structural "exporters" of influence and a handful pure receivers /
//! leaves, mirroring the qualitative structure the paper reports (NVR,
//! AZO, ... exert; NWSA, CNP, ... receive; USB, FITB are leaves). Prices
//! are exp-cumulated returns with ~1% missing values injected to exercise
//! the interpolation pipeline.

use crate::linalg::{lu_inverse, Mat};
use crate::util::rng::Pcg64;

/// Real S&P constituents (subset), including every ticker named in the
/// paper's Table 2 / §4.2 discussion. The generator pads with synthetic
/// symbols up to `dim`.
pub const REAL_TICKERS: &[&str] = &[
    // named in the paper
    "NVR", "AZO", "CMG", "BKNG", "MTD", "NWSA", "CNP", "FOXA", "AMCR", "USB", "FITB",
    // large caps and a spread of sectors
    "AAPL", "MSFT", "AMZN", "GOOGL", "META", "NVDA", "TSLA", "BRK-B", "JPM", "V", "MA",
    "UNH", "HD", "PG", "XOM", "CVX", "LLY", "ABBV", "MRK", "PEP", "KO", "COST", "WMT",
    "BAC", "WFC", "C", "GS", "MS", "AXP", "BLK", "SCHW", "PNC", "TFC", "COF",
    "JNJ", "PFE", "TMO", "ABT", "DHR", "BMY", "AMGN", "GILD", "CVS", "CI", "HUM",
    "ORCL", "CRM", "ADBE", "INTC", "AMD", "QCOM", "TXN", "AVGO", "MU", "AMAT", "LRCX",
    "CSCO", "IBM", "ACN", "INTU", "NOW", "SNPS", "CDNS", "KLAC", "ADI", "NXPI",
    "T", "VZ", "TMUS", "CMCSA", "DIS", "NFLX", "PARA", "WBD", "FOX", "NWS",
    "BA", "CAT", "DE", "GE", "HON", "LMT", "RTX", "NOC", "GD", "MMM", "EMR", "ETN",
    "UPS", "FDX", "UNP", "CSX", "NSC", "DAL", "UAL", "AAL", "LUV",
    "NEE", "DUK", "SO", "D", "AEP", "EXC", "SRE", "XEL", "ED", "WEC", "ES", "PEG",
    "LIN", "APD", "SHW", "ECL", "NEM", "FCX", "DOW", "DD", "PPG", "ALB",
    "PLD", "AMT", "CCI", "EQIX", "SPG", "O", "PSA", "WELL", "AVB", "EQR",
    "MCD", "SBUX", "YUM", "DRI", "MAR", "HLT", "RCL", "CCL", "NCLH", "LVS", "MGM",
    "NKE", "TJX", "ROST", "LOW", "TGT", "DG", "DLTR", "ORLY", "AAP", "BBY", "EBAY",
    "F", "GM", "APTV", "LEA", "BWA", "PHM", "DHI", "LEN", "TOL", "MAS",
    "MDT", "SYK", "BSX", "EW", "ZBH", "BAX", "BDX", "ISRG", "RMD", "IDXX",
    "MO", "PM", "STZ", "TAP", "KHC", "GIS", "K", "HSY", "SJM", "CAG", "CPB",
    "CL", "KMB", "CHD", "CLX", "EL", "KDP", "MNST", "MDLZ", "HRL", "TSN",
];

/// Sector count used for the block structure (~GICS's 11).
const N_SECTORS: usize = 11;

/// Market generator configuration.
#[derive(Clone, Debug)]
pub struct MarketSpec {
    /// Number of tickers (paper: 487 after filtering).
    pub dim: usize,
    /// Hourly observations (paper: Jan 2022 – Dec 2023 ≈ 3500 trading hours).
    pub t_len: usize,
    /// Probability of an intra-sector instantaneous edge.
    pub p_intra: f64,
    /// Probability of a cross-sector instantaneous edge.
    pub p_cross: f64,
    /// Fraction of missing values to inject.
    pub missing_frac: f64,
    /// Student-t degrees of freedom for innovations.
    pub t_dof: f64,
}

impl Default for MarketSpec {
    fn default() -> Self {
        MarketSpec {
            dim: 487,
            t_len: 3_500,
            p_intra: 0.08,
            p_cross: 0.004,
            missing_frac: 0.01,
            t_dof: 4.0,
        }
    }
}

impl MarketSpec {
    /// A fast configuration for tests/examples.
    pub fn small() -> MarketSpec {
        MarketSpec { dim: 40, t_len: 1_200, p_intra: 0.25, p_cross: 0.02, ..Default::default() }
    }
}

/// A simulated market panel.
#[derive(Clone, Debug)]
pub struct MarketDataset {
    /// Prices `[T, dim]`, with injected NaN gaps.
    pub prices: Mat,
    /// Ticker symbols, length `dim`.
    pub tickers: Vec<String>,
    /// Ground-truth instantaneous adjacency over returns.
    pub b0: Mat,
    /// Ground-truth lag-1 matrix.
    pub b1: Mat,
    /// Designated exerting tickers (structural hubs).
    pub true_exerters: Vec<usize>,
    /// Designated receiving tickers.
    pub true_receivers: Vec<usize>,
}

/// Ticker list: real symbols first, synthetic padding after.
pub fn ticker_universe(dim: usize) -> Vec<String> {
    let mut out: Vec<String> = REAL_TICKERS.iter().take(dim).map(|s| s.to_string()).collect();
    let mut i = 0;
    while out.len() < dim {
        out.push(format!("SYN{:03}", i));
        i += 1;
    }
    out
}

/// Simulate the market.
pub fn simulate_market(spec: &MarketSpec, rng: &mut Pcg64) -> MarketDataset {
    let d = spec.dim;
    let tickers = ticker_universe(d);
    let idx_of = |sym: &str| tickers.iter().position(|t| t == sym);

    // causal order over tickers; exerters forced early, receivers late,
    // USB/FITB forced to be leaves (no outgoing edges at all).
    let mut order = rng.permutation(d);
    let exert_syms = ["NVR", "AZO", "CMG", "BKNG", "MTD"];
    let recv_syms = ["NWSA", "CNP", "FOXA", "AMCR"];
    let leaf_syms = ["USB", "FITB"];
    let mut pin_front: Vec<usize> = exert_syms.iter().filter_map(|s| idx_of(s)).collect();
    let mut pin_back: Vec<usize> = recv_syms
        .iter()
        .chain(leaf_syms.iter())
        .filter_map(|s| idx_of(s))
        .collect();
    order.retain(|i| !pin_front.contains(i) && !pin_back.contains(i));
    let mut full_order = Vec::with_capacity(d);
    full_order.append(&mut pin_front);
    full_order.extend(order);
    full_order.append(&mut pin_back);
    let order = full_order;
    let mut pos = vec![0usize; d];
    for (p, &v) in order.iter().enumerate() {
        pos[v] = p;
    }

    let sector: Vec<usize> = (0..d).map(|i| i % N_SECTORS).collect();
    let exerters: Vec<usize> = exert_syms.iter().filter_map(|s| idx_of(s)).collect();
    let receivers: Vec<usize> = recv_syms.iter().filter_map(|s| idx_of(s)).collect();
    let leaves: Vec<usize> = leaf_syms.iter().filter_map(|s| idx_of(s)).collect();

    // instantaneous DAG: edges only from earlier to later in `order`
    let mut b0 = Mat::zeros(d, d);
    for a in 0..d {
        for b in 0..d {
            if pos[a] >= pos[b] {
                continue; // a must precede b for edge a → b
            }
            if leaves.contains(&a) {
                continue; // leaves exert nothing
            }
            let mut p = if sector[a] == sector[b] { spec.p_intra } else { spec.p_cross };
            if exerters.contains(&a) {
                p = (p * 12.0).min(0.6); // structural hubs: many children
            }
            if receivers.contains(&b) {
                p = (p * 12.0).min(0.6); // structural sinks: many parents
            }
            if rng.bernoulli(p) {
                let sign = if rng.bernoulli(0.5) { 1.0 } else { -1.0 };
                b0[(b, a)] = sign * rng.uniform(0.05, 0.3);
            }
        }
    }

    // lag-1 effects: momentum/mean-reversion diagonal + sparse cross terms;
    // exerters also influence at lag 1 (paper's Table 2 ranks τ−1 terms).
    let mut b1 = Mat::zeros(d, d);
    for i in 0..d {
        b1[(i, i)] = rng.uniform(-0.15, 0.1);
    }
    for &e in &exerters {
        for i in 0..d {
            if i != e && rng.bernoulli(0.3) {
                b1[(i, e)] = rng.uniform(0.05, 0.2);
            }
        }
    }
    for _ in 0..(d * 2) {
        let i = rng.below(d);
        let j = rng.below(d);
        if i != j && !leaves.contains(&j) {
            b1[(i, j)] = rng.uniform(-0.1, 0.1);
        }
    }

    // reduced-form simulation of returns
    let inv = lu_inverse(&Mat::eye(d).sub(&b0)).expect("I - B0 invertible");
    let vol = 0.004; // hourly return scale
    let mut r_prev = vec![0.0; d];
    let burn = 100;
    let mut prices = Mat::zeros(spec.t_len, d);
    let mut log_p: Vec<f64> = (0..d).map(|_| rng.uniform(3.0, 6.0)).collect(); // ~$20-$400
    for t in 0..(burn + spec.t_len) {
        let mut rhs = b1.matvec(&r_prev);
        for v in rhs.iter_mut() {
            *v += vol * rng.student_t(spec.t_dof);
        }
        let r_t = inv.matvec(&rhs);
        if t >= burn {
            for i in 0..d {
                log_p[i] += r_t[i];
                prices[(t - burn, i)] = log_p[i].exp();
            }
        }
        r_prev = r_t;
    }

    // inject missing values (exchange halts / bad ticks)
    let n_missing = ((spec.t_len * d) as f64 * spec.missing_frac) as usize;
    for _ in 0..n_missing {
        let t = rng.below(spec.t_len);
        let i = rng.below(d);
        prices[(t, i)] = f64::NAN;
    }

    MarketDataset {
        prices,
        tickers,
        b0,
        b1,
        true_exerters: exerters,
        true_receivers: receivers,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph;

    #[test]
    fn universe_contains_paper_tickers() {
        let u = ticker_universe(487);
        assert_eq!(u.len(), 487);
        for s in ["NVR", "AZO", "CMG", "BKNG", "MTD", "NWSA", "CNP", "FOXA", "AMCR", "USB", "FITB"] {
            assert!(u.iter().any(|t| t == s), "missing {s}");
        }
        // no duplicates
        let mut v = u.clone();
        v.sort();
        v.dedup();
        assert_eq!(v.len(), u.len());
    }

    #[test]
    fn b0_acyclic_and_leaves_hold() {
        let mut rng = Pcg64::seed_from_u64(21);
        let ds = simulate_market(&MarketSpec::small(), &mut rng);
        assert!(graph::is_acyclic(&ds.b0));
        // USB and FITB have no outgoing instantaneous edges
        for sym in ["USB", "FITB"] {
            let j = ds.tickers.iter().position(|t| t == sym).unwrap();
            let outdeg = (0..ds.b0.rows()).filter(|&i| ds.b0[(i, j)] != 0.0).count();
            assert_eq!(outdeg, 0, "{sym} should be a leaf");
        }
    }

    #[test]
    fn prices_positive_and_gappy() {
        let mut rng = Pcg64::seed_from_u64(22);
        let spec = MarketSpec::small();
        let ds = simulate_market(&spec, &mut rng);
        let n_nan = ds.prices.as_slice().iter().filter(|v| v.is_nan()).count();
        assert!(n_nan > 0, "missing values should be injected");
        for &v in ds.prices.as_slice() {
            assert!(v.is_nan() || v > 0.0);
        }
    }

    #[test]
    fn exerters_have_high_out_degree() {
        let mut rng = Pcg64::seed_from_u64(23);
        let ds = simulate_market(&MarketSpec::small(), &mut rng);
        let d = ds.b0.rows();
        let out_deg =
            |j: usize| (0..d).filter(|&i| ds.b0[(i, j)] != 0.0).count();
        let mean_deg: f64 =
            (0..d).map(out_deg).sum::<usize>() as f64 / d as f64;
        for &e in &ds.true_exerters {
            assert!(
                out_deg(e) as f64 > mean_deg,
                "exerter {} deg {} <= mean {mean_deg}",
                ds.tickers[e],
                out_deg(e)
            );
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let spec = MarketSpec::small();
        let a = simulate_market(&spec, &mut Pcg64::seed_from_u64(5));
        let b = simulate_market(&spec, &mut Pcg64::seed_from_u64(5));
        assert_eq!(a.tickers, b.tickers);
        assert_eq!(a.b0, b.b0);
        // prices contain NaN: compare bit patterns
        let pa: Vec<u64> = a.prices.as_slice().iter().map(|v| v.to_bits()).collect();
        let pb: Vec<u64> = b.prices.as_slice().iter().map(|v| v.to_bits()).collect();
        assert_eq!(pa, pb);
    }
}
