//! Workload generators for every experiment in the paper:
//!
//! - [`sem`] — linear non-Gaussian SEM data over random DAGs (Figures 1-3,
//!   §3.1 NOTEARS comparison).
//! - [`var`] — structural VAR(1) time series (Figure 2 bottom-right,
//!   VarLiNGAM validation).
//! - [`genes`] — synthetic Perturb-CITE-seq-style interventional gene
//!   expression (Table 1). Substitutes the proprietary Frangieh et al.
//!   dataset; see DESIGN.md §Substitutions.
//! - [`stocks`] — synthetic S&P-500-style hourly market with VAR(1)
//!   dynamics (Figure 4, Table 2). Substitutes the Yahoo Finance pull.

pub mod sem;
pub mod var;
pub mod genes;
pub mod stocks;

pub use genes::{simulate_perturb, Condition, PerturbDataset, PerturbSpec};
pub use sem::{sample_from_dag, simulate_sem, Noise, SemDataset, SemSpec};
pub use stocks::{simulate_market, MarketDataset, MarketSpec};
pub use var::{simulate_var, VarDataset, VarSpec};
