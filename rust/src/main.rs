//! `alingam` — the AcceleratedLiNGAM command-line launcher.
//!
//! Subcommands:
//!   discover   DirectLiNGAM on simulated SEM data (choose an engine)
//!   var        VarLiNGAM on simulated VAR data
//!   genes      the Table-1 gene pipeline
//!   stocks     the Figure-4 / Table-2 stock pipeline
//!   agree      the Figure-3 parallel-vs-sequential agreement sweep
//!   bootstrap  bootstrap edge-confidence estimation
//!   ica        ICA-LiNGAM (the original estimator) on simulated data
//!   serve      resident JSON-lines-over-TCP discovery service, with an
//!              optional HTTP/1.1 + SSE front (--http-addr), a sharded
//!              multi-process fleet (--shards N), a disk-persistent
//!              result cache (--cache-dir), and structured stderr logs
//!              (--log-level error|warn|info|debug, --log-json)
//!   client     drive a running server (fit|bootstrap|varlingam|status|
//!              metrics|trace|cancel|shutdown as the second positional;
//!              for trace, --job-id is the job or trace id to look up);
//!              --timeout-ms bounds connect and every read/write
//!   watch      streaming discovery over stdin CSV rows: sliding-window
//!              moments, one `adjacency` frame per full-window sample,
//!              terminal summary frame (--lags 0 for plain DirectLiNGAM,
//!              k >= 1 for VAR; an explicit --addr relays the rows to a
//!              running server's live watch protocol instead)
//!   info       runtime/artifact inventory
//!
//! The fit paths (`discover`, `var`, `bootstrap`) accept a bare `--json`
//! flag to emit the result as one machine-readable line — the same
//! `result` frame the serve protocol streams, so both surfaces parse
//! identically.

use alingam::apps::{genes, simbench, stocks};
use alingam::coordinator::{Engine, EngineChoice};
use alingam::lingam::{
    DirectLingam, PartitionSpec, PartitionedPlan, StreamingConfig, StreamingLingam,
    StreamingVarLingam, SweepCounters, SweepStrategy, VarLingam,
};
use alingam::metrics::graph_metrics;
use alingam::prelude::*;
use alingam::runtime::{ArtifactKind, ArtifactRegistry};
use alingam::serve::protocol;
use alingam::sim::{MarketSpec, VarSpec};
use alingam::util::cli::{engine_opt, opt, serve_opts, Args, OptSpec};
use alingam::util::table::{f, secs, Table};

fn specs() -> Vec<OptSpec> {
    let mut specs = vec![
        engine_opt(),
        opt("dims", "number of variables", Some("10")),
        opt("samples", "number of samples / time steps", Some("4000")),
        opt("seed", "random seed", Some("2024")),
        opt("seeds", "number of sweep seeds (agree)", Some("10")),
        opt("workers", "sweep worker threads", Some("2")),
        opt("scale", "gene experiment scale: small|medium|paper", Some("small")),
        opt("top-k", "ranking size for stocks", Some("5")),
        opt("svgd-iters", "Stein VI iterations", Some("300")),
        opt("svgd-particles", "Stein VI particles", Some("50")),
        opt("resamples", "bootstrap resamples", Some("50")),
        opt("lags", "VAR order k", Some("1")),
    ];
    specs.extend(serve_opts());
    specs
}

fn main() {
    let args = Args::parse(
        "AcceleratedLiNGAM: LiNGAM causal discovery with an AOT JAX/Pallas hot path",
        &specs(),
    );
    let cmd = args.positional(0).unwrap_or("info").to_string();
    if let Err(e) = dispatch(&cmd, &args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(cmd: &str, args: &Args) -> alingam::util::Result<()> {
    match cmd {
        "discover" => discover(args),
        "var" => var(args),
        "genes" => genes_cmd(args),
        "stocks" => stocks_cmd(args),
        "agree" => agree(args),
        "bootstrap" => bootstrap_cmd(args),
        "ica" => ica_cmd(args),
        "serve" => serve_cmd(args),
        "client" => client_cmd(args),
        "watch" => watch_cmd(args),
        "info" => info(),
        other => {
            eprintln!(
                "unknown command {other:?} \
                 (discover|var|genes|stocks|agree|bootstrap|ica|serve|client|watch|info)"
            );
            std::process::exit(2);
        }
    }
}

fn build_engine(args: &Args) -> alingam::util::Result<Engine> {
    Engine::build(EngineChoice::parse(&args.req("engine"))?)
}

/// Engine for commands that fan jobs across `sweep_workers` threads of
/// their own (`agree`, `bootstrap`): an auto-sized parallel engine inside
/// such a sweep would oversubscribe every core `sweep_workers`-fold, so
/// the core budget is divided instead — the one normalization rule,
/// [`EngineChoice::resolve_workers`], shared with the serve layer. An
/// explicit `parallel:N` is honored as given.
fn build_engine_for_sweep(args: &Args, sweep_workers: usize) -> alingam::util::Result<Engine> {
    Engine::build(EngineChoice::parse(&args.req("engine"))?.resolve_workers(sweep_workers))
}

fn discover(args: &Args) -> alingam::util::Result<()> {
    let d = args.usize("dims");
    let n = args.usize("samples");
    let seed = args.usize("seed") as u64;
    let choice = EngineChoice::parse(&args.req("engine"))?;
    let mut rng = Pcg64::seed_from_u64(seed);
    let ds = sim::simulate_sem(&sim::SemSpec::layered(d, 2, 0.5), n, &mut rng);

    // `partition[:B]` is a plan, not a session engine: route it through
    // the plan layer before any Engine::build (which would reject it)
    if let EngineChoice::Partition { blocks } = choice {
        let plan = PartitionedPlan::with_blocks(blocks, EngineChoice::per_job_workers(1));
        let t0 = std::time::Instant::now();
        let pf = DirectLingam::new().fit_plan(&ds.data, &plan)?;
        let dt = t0.elapsed().as_secs_f64();
        if args.flag("json") {
            let data =
                protocol::fit_data(&choice.spec(), &pf.fit.order, &pf.fit.adjacency, &pf.counters);
            println!("{}", protocol::frame_result(None, false, dt * 1e3, &data));
            return Ok(());
        }
        let m = graph_metrics(&ds.adjacency, &pf.fit.adjacency, 0.05);
        println!("engine       : partition (exact merge)");
        println!("order        : {:?}", pf.fit.order);
        println!(
            "true order ok: {}",
            alingam::graph::order_consistent(&ds.adjacency, &pf.fit.order)
        );
        println!("F1 / recall  : {:.3} / {:.3}   SHD {}", m.f1, m.recall, m.shd);
        println!("blocks       : {}   boundary pairs {}", pf.blocks_formed, pf.boundary_pairs);
        println!(
            "wall         : {}   (ordering {:.1}%)",
            secs(dt),
            100.0 * pf.fit.profile.fraction("ordering")
        );
        return Ok(());
    }

    let engine = Engine::build(choice)?;
    let t0 = std::time::Instant::now();
    let fit = DirectLingam::new().fit(&ds.data, engine.as_ordering())?;
    let dt = t0.elapsed().as_secs_f64();
    if args.flag("json") {
        // the serve protocol's result frame (counters are zero here:
        // `DirectLingam::fit` does not surface its session's sweep
        // instrumentation, matching the shim's zeros convention)
        let counters = SweepCounters::default();
        let data = protocol::fit_data(&choice.spec(), &fit.order, &fit.adjacency, &counters);
        println!("{}", protocol::frame_result(None, false, dt * 1e3, &data));
        return Ok(());
    }
    let m = graph_metrics(&ds.adjacency, &fit.adjacency, 0.05);

    println!("engine       : {}", engine.as_ordering().name());
    println!("order        : {:?}", fit.order);
    println!("true order ok: {}", alingam::graph::order_consistent(&ds.adjacency, &fit.order));
    println!("F1 / recall  : {:.3} / {:.3}   SHD {}", m.f1, m.recall, m.shd);
    println!(
        "wall         : {}   (ordering {:.1}%)",
        secs(dt),
        100.0 * fit.profile.fraction("ordering")
    );
    Ok(())
}

fn var(args: &Args) -> alingam::util::Result<()> {
    let d = args.usize("dims");
    let n = args.usize("samples");
    let seed = args.usize("seed") as u64;
    let choice = EngineChoice::parse(&args.req("engine"))?;
    let engine = Engine::build(choice)?;
    let mut rng = Pcg64::seed_from_u64(seed);
    let ds = sim::simulate_var(&VarSpec { dim: d, ..Default::default() }, n, &mut rng);
    let t0 = std::time::Instant::now();
    let fit = VarLingam::new().with_lags(args.usize("lags")).fit(&ds.data, engine.as_ordering())?;
    let dt = t0.elapsed().as_secs_f64();
    if args.flag("json") {
        let data = protocol::var_data(&choice.spec(), &fit);
        println!("{}", protocol::frame_result(None, false, dt * 1e3, &data));
        return Ok(());
    }
    let m0 = graph_metrics(&ds.b0, &fit.b0, 0.05);
    println!("engine  : {}", engine.as_ordering().name());
    println!("B0 F1   : {:.3}  SHD {}", m0.f1, m0.shd);
    println!("B1 err  : {:.4} (max abs vs truth)", fit.b1().sub(&ds.b1).max_abs());
    println!("wall    : {}  (ordering {:.1}%)", secs(dt), 100.0 * fit.profile.fraction("ordering"));
    Ok(())
}

fn genes_cmd(args: &Args) -> alingam::util::Result<()> {
    let engine = build_engine(args)?;
    let cfg = genes::GenesConfig {
        scale: genes::GeneScale::parse(&args.req("scale"))
            .ok_or_else(|| alingam::util::Error::InvalidArgument("bad --scale".into()))?,
        seed: args.usize("seed") as u64,
        svgd: alingam::baselines::SvgdOpts {
            iters: args.usize("svgd-iters"),
            particles: args.usize("svgd-particles"),
            ..Default::default()
        },
        ..Default::default()
    };
    let rows = genes::run_table1(&cfg, engine.as_ordering())?;
    let mut t = Table::new(
        "Table 1: interventional NLL / MAE on Perturb-seq-style data",
        &["condition", "method", "I-NLL", "I-MAE", "leaves", "fit"],
    );
    for r in &rows {
        t.row(&[
            r.condition.name().into(),
            r.method.into(),
            f(r.metrics.nll, 2),
            f(r.metrics.mae, 2),
            r.leaves.to_string(),
            secs(r.fit_secs),
        ]);
    }
    t.print();
    Ok(())
}

fn stocks_cmd(args: &Args) -> alingam::util::Result<()> {
    let engine = build_engine(args)?;
    let d = args.usize("dims");
    let spec = if d >= 487 {
        MarketSpec::default()
    } else {
        MarketSpec { dim: d, ..MarketSpec::small() }
    };
    let report = stocks::run_stocks(
        &spec,
        args.usize("seed") as u64,
        engine.as_ordering(),
        args.usize("top-k"),
    )?;
    print_stocks_report(&report);
    Ok(())
}

fn print_stocks_report(r: &stocks::StocksReport) {
    let mut t = Table::new(
        "Table 2: total causal influence",
        &["rank", "ticker", "lag", "score", "role"],
    );
    for (k, (name, lag, score)) in r.top_exerting.iter().enumerate() {
        t.row(&[
            (k + 1).to_string(),
            format!("{name}_tau-{lag}"),
            lag.to_string(),
            f(*score, 3),
            "exerting".into(),
        ]);
    }
    for (k, (name, lag, score)) in r.top_receiving.iter().enumerate() {
        t.row(&[
            (k + 1).to_string(),
            format!("{name}_tau-{lag}"),
            lag.to_string(),
            f(*score, 3),
            "receiving".into(),
        ]);
    }
    t.print();
    println!(
        "{}",
        alingam::util::table::histogram("Figure 4: in-degree distribution", &r.in_degrees, 10)
    );
    println!(
        "{}",
        alingam::util::table::histogram("Figure 4: out-degree distribution", &r.out_degrees, 10)
    );
    println!("leaves: {:?}  (designated USB/FITB recovered: {}/2)", r.leaves, r.leaf_hits);
    println!("fit: {}  ordering {:.1}%", secs(r.fit_secs), 100.0 * r.ordering_frac);
}

fn agree(args: &Args) -> alingam::util::Result<()> {
    let n_seeds = args.usize("seeds");
    let seeds: Vec<u64> = (0..n_seeds as u64).collect();
    let engine_b = build_engine_for_sweep(args, args.usize("workers"))?;
    let runs = simbench::agreement_sweep(
        &simbench::fig3_spec(),
        args.usize("samples"),
        &seeds,
        &alingam::lingam::SequentialEngine,
        engine_b.as_ordering(),
        args.usize("workers"),
    );
    let identical = runs.iter().filter(|r| r.orders_identical).count();
    let f1: Vec<f64> = runs.iter().map(|r| r.metrics_b.f1).collect();
    let shd: Vec<f64> = runs.iter().map(|r| r.metrics_b.shd as f64).collect();
    println!("engine B      : {}", engine_b.as_ordering().name());
    println!("orders match  : {identical}/{}", runs.len());
    println!("F1            : {}", metrics::mean_std(&f1));
    println!("SHD           : {}", metrics::mean_std(&shd));
    Ok(())
}

fn bootstrap_cmd(args: &Args) -> alingam::util::Result<()> {
    use alingam::coordinator::{bootstrap_direct, bootstrap_partition, BootstrapOpts};
    let d = args.usize("dims");
    let n = args.usize("samples");
    let choice = EngineChoice::parse(&args.req("engine"))?.resolve_workers(args.usize("workers"));
    let mut rng = Pcg64::seed_from_u64(args.usize("seed") as u64);
    let ds = sim::simulate_sem(&sim::SemSpec::layered(d, 2, 0.5), n, &mut rng);
    let opts = BootstrapOpts {
        resamples: args.usize("resamples"),
        workers: args.usize("workers"),
        ..Default::default()
    };
    let t0 = std::time::Instant::now();
    let result = if let EngineChoice::Partition { blocks } = choice {
        // plan-layer route: pooled PartitionWorkspaces, sized like any
        // other per-job pool inside this sweep
        let spec = PartitionSpec {
            max_blocks: blocks,
            workers: EngineChoice::per_job_workers(opts.workers),
            ..PartitionSpec::default()
        };
        bootstrap_partition(&ds.data, &spec, &opts)?
    } else {
        let engine = Engine::build(choice)?;
        bootstrap_direct(&ds.data, engine.as_ordering(), &opts)?
    };
    let dt = t0.elapsed().as_secs_f64();
    if args.flag("json") {
        let data = protocol::bootstrap_data(&choice.spec(), &result, 0.5);
        println!("{}", protocol::frame_result(None, false, dt * 1e3, &data));
        return Ok(());
    }
    let mut t = Table::new(
        "bootstrap edge stability (prob ≥ 0.5)",
        &["edge", "probability", "mean weight", "true weight"],
    );
    for (from, to, p, w) in result.stable_edges(0.5) {
        t.row(&[
            format!("{from} → {to}"),
            f(p, 2),
            f(w, 3),
            f(ds.adjacency[(to, from)], 3),
        ]);
    }
    t.print();
    println!("resamples: {}", result.resamples);
    Ok(())
}

fn ica_cmd(args: &Args) -> alingam::util::Result<()> {
    use alingam::lingam::IcaLingam;
    let d = args.usize("dims");
    let n = args.usize("samples");
    let mut rng = Pcg64::seed_from_u64(args.usize("seed") as u64);
    let ds = sim::simulate_sem(&sim::SemSpec::layered(d, 2, 0.5), n, &mut rng);
    let t0 = std::time::Instant::now();
    let fit = IcaLingam::new().fit(&ds.data)?;
    let dt = t0.elapsed().as_secs_f64();
    let m = graph_metrics(&ds.adjacency, &fit.adjacency, 0.05);
    println!("method  : ICA-LiNGAM (Shimizu et al. 2006)");
    println!("order   : {:?}", fit.order);
    println!("order ok: {}", alingam::graph::order_consistent(&ds.adjacency, &fit.order));
    println!("F1 / SHD: {:.3} / {}   wall {}", m.f1, m.shd, secs(dt));
    Ok(())
}

/// Run the resident discovery service until some client sends a
/// `shutdown` frame, then drain (bounded) and exit. `--shards N` (N ≥ 2)
/// runs the multi-process fleet supervisor instead of an in-process
/// server; `--http-addr` adds the HTTP/1.1 + SSE front to either.
fn serve_cmd(args: &Args) -> alingam::util::Result<()> {
    use std::io::Write;
    let cfg = alingam::serve::ServeConfig {
        addr: args.req("addr"),
        workers: args.usize("serve-workers"),
        queue_capacity: args.usize("queue-cap"),
        cache_entries: args.usize("cache-entries"),
        fuse_wait_ms: args.usize("fuse-wait-ms") as u64,
        max_batch: args.usize("max-batch"),
        http_addr: args.get("http-addr"),
        cache_dir: args.get("cache-dir").map(std::path::PathBuf::from),
        log_level: args.req("log-level"),
        log_json: args.flag("log-json"),
    };
    // the fleet front logs too (shard lifecycle events); the in-process
    // server initializes the same way inside Server::start
    alingam::obs::log::init(
        alingam::obs::log::Level::parse(&cfg.log_level)
            .unwrap_or(alingam::obs::log::Level::Warn),
        cfg.log_json,
    );
    let shards: usize = args.get_as("shards").unwrap_or(0);
    // a wedged worker must not hang the process forever on exit: past
    // this the drain is abandoned and the exit code says so
    let drain_limit = std::time::Duration::from_secs(120);
    if shards >= 2 {
        let sup = alingam::serve::shard::Supervisor::start(cfg, shards, None)?;
        println!("serving on {}", sup.local_addr());
        if let Some(h) = sup.http_local_addr() {
            println!("http on {h}");
        }
        println!("{}", alingam::serve::shard::shard_banner(&sup.shard_table()));
        ready_signal(args)?;
        // flushed eagerly so scripted callers (the CI smoke) can read
        // the bound addresses even through a pipe
        std::io::stdout().flush()?;
        sup.wait_for_shutdown_request();
        println!("shutdown requested; draining shards");
        std::io::stdout().flush()?;
        if sup.shutdown_within(drain_limit) {
            println!("drained cleanly");
        } else {
            println!("drain timed out; exiting unclean");
            std::process::exit(3);
        }
        return Ok(());
    }
    let server = alingam::serve::Server::start(cfg)?;
    println!("serving on {}", server.local_addr());
    if let Some(h) = server.http_local_addr() {
        println!("http on {h}");
    }
    ready_signal(args)?;
    std::io::stdout().flush()?;
    server.wait_for_shutdown_request();
    println!("shutdown requested; draining queued jobs");
    std::io::stdout().flush()?;
    if server.shutdown_within(drain_limit) {
        println!("drained cleanly");
    } else {
        println!("drain timed out; exiting unclean");
        std::process::exit(3);
    }
    Ok(())
}

/// `--ready-fd N`: write `ready\n` to inherited fd N once every
/// listener is bound, then close it. Unlike scraping stdout for the
/// "serving on" line, this cannot race the bind — the fd write happens
/// strictly after every `bind()` returned (unix only; ignored
/// elsewhere).
fn ready_signal(args: &Args) -> alingam::util::Result<()> {
    let Some(fd) = args.get("ready-fd") else {
        return Ok(());
    };
    let fd: i32 = fd.parse().map_err(|_| {
        alingam::util::Error::InvalidArgument(format!("--ready-fd {fd:?} is not a descriptor"))
    })?;
    #[cfg(unix)]
    {
        use std::io::Write;
        use std::os::unix::io::FromRawFd;
        // SAFETY: the caller passed this inherited descriptor
        // explicitly; the File takes ownership and closing it on drop
        // gives the other end a clean EOF after the ready byte
        let mut f = unsafe { std::fs::File::from_raw_fd(fd) };
        let _ = f.write_all(b"ready\n");
    }
    #[cfg(not(unix))]
    let _ = fd;
    Ok(())
}

/// One-shot protocol client: build a request from the CLI options, send
/// it, echo every streamed frame, and exit on the terminal frame.
fn client_cmd(args: &Args) -> alingam::util::Result<()> {
    use alingam::serve::protocol::Json;
    use std::io::{BufRead, BufReader, Write};

    let action = args.positional(1).unwrap_or("fit").to_string();
    let addr = args.req("addr");
    let mut stream = connect_with_deadline(&addr, args.usize("timeout-ms") as u64)?;
    let reader = BufReader::new(stream.try_clone()?);
    let engine = args.req("engine");
    let id = args.req("job-id");

    let request = match action.as_str() {
        "status" | "metrics" | "shutdown" => protocol::control_request(&action),
        "cancel" => protocol::cancel_request(&id),
        // --job-id doubles as the lookup target: a job id or the 32-hex
        // trace id a result frame's "timing" object reported
        "trace" => protocol::trace_request(&id),
        "fit" | "bootstrap" | "varlingam" => {
            if let Some(path) = args.get("csv") {
                if action != "fit" {
                    return Err(alingam::util::Error::InvalidArgument(
                        "--csv panels are supported for the fit action only".into(),
                    ));
                }
                protocol::csv_fit_request(&id, &engine, &path)
            } else {
                // simulate the same layered SEM panel `discover` uses,
                // client-side, and ship it inline
                let d = args.usize("dims");
                let n = args.usize("samples");
                let seed = args.usize("seed") as u64;
                let mut rng = Pcg64::seed_from_u64(seed);
                let panel = sim::simulate_sem(&sim::SemSpec::layered(d, 2, 0.5), n, &mut rng).data;
                match action.as_str() {
                    "fit" => protocol::fit_request(&id, &engine, &panel),
                    "bootstrap" => protocol::bootstrap_request(
                        &id,
                        &engine,
                        &panel,
                        args.usize("resamples"),
                        seed,
                        args.f64("threshold"),
                    ),
                    _ => protocol::var_request(&id, &engine, &panel, args.usize("lags")),
                }
            }
        }
        other => {
            eprintln!(
                "unknown client action {other:?} \
                 (fit|bootstrap|varlingam|status|metrics|trace|cancel|shutdown)"
            );
            std::process::exit(2);
        }
    };
    stream.write_all(request.as_bytes())?;
    stream.write_all(b"\n")?;

    let one_shot =
        matches!(action.as_str(), "status" | "metrics" | "trace" | "shutdown" | "cancel");
    for line in reader.lines() {
        let line = line?;
        println!("{line}");
        let frame = protocol::parse_json(&line).unwrap_or(Json::Null);
        match frame.get("event").and_then(Json::as_str) {
            Some("result") => {
                let cached = frame.get("cached").and_then(Json::as_bool).unwrap_or(false);
                println!("# result received (cached: {cached})");
                return Ok(());
            }
            Some("canceled") => return Ok(()),
            Some("error") => {
                let msg = frame
                    .get("message")
                    .and_then(Json::as_str)
                    .unwrap_or("server error")
                    .to_string();
                return Err(alingam::util::Error::Runtime(msg));
            }
            _ => {}
        }
        if one_shot {
            return Ok(());
        }
    }
    Err(alingam::util::Error::Runtime(
        "connection closed before a terminal frame arrived".into(),
    ))
}

/// Connect with the `--timeout-ms` deadline: bounds the TCP connect per
/// resolved address and every subsequent read/write on the socket (a
/// stalled server surfaces as an io error instead of a hang). 0 keeps
/// the unbounded behavior.
fn connect_with_deadline(
    addr: &str,
    timeout_ms: u64,
) -> alingam::util::Result<std::net::TcpStream> {
    use std::net::{TcpStream, ToSocketAddrs};
    if timeout_ms == 0 {
        return Ok(TcpStream::connect(addr)?);
    }
    let limit = std::time::Duration::from_millis(timeout_ms);
    let mut last: Option<std::io::Error> = None;
    for sa in addr.to_socket_addrs()? {
        match TcpStream::connect_timeout(&sa, limit) {
            Ok(s) => {
                s.set_read_timeout(Some(limit))?;
                s.set_write_timeout(Some(limit))?;
                return Ok(s);
            }
            Err(e) => last = Some(e),
        }
    }
    Err(match last {
        Some(e) => e.into(),
        None => alingam::util::Error::InvalidArgument(format!("{addr:?} resolved to no addresses")),
    })
}

/// One CSV sample line → row of f64, `None` when any cell fails to
/// parse (the caller treats the first such line as a header).
fn parse_csv_row(line: &str) -> Option<Vec<f64>> {
    line.split(',').map(|c| c.trim().parse::<f64>().ok()).collect()
}

/// `(workers, strategy)` for the sliding-window refits. Streaming holds
/// its workspace in the window and re-seeds a session per full refit,
/// so only engines with an incremental workspace apply — the serve
/// worker enforces the identical rule on `watch` subscriptions.
fn incremental_engine(args: &Args) -> alingam::util::Result<(usize, SweepStrategy)> {
    let choice = EngineChoice::parse(&args.req("engine"))?.resolve_workers(1);
    match choice {
        EngineChoice::Vectorized => Ok((1, SweepStrategy::Exact)),
        EngineChoice::Parallel { workers } => Ok((workers.max(1), SweepStrategy::Exact)),
        EngineChoice::Pruned { workers } => Ok((workers.max(1), SweepStrategy::Pruned)),
        other => Err(alingam::util::Error::InvalidArgument(format!(
            "engine `{}` has no incremental workspace; watch needs \
             vectorized, parallel or pruned",
            other.spec()
        ))),
    }
}

/// The local streaming driver behind `watch`: `--lags 0` slides a plain
/// DirectLiNGAM window, k ≥ 1 the VAR variant.
enum StreamDriver {
    Plain(StreamingLingam),
    Var(StreamingVarLingam),
}

/// Streaming discovery over stdin: one CSV sample per line, one
/// protocol `adjacency` frame per full-window sample on stdout, one
/// terminal summary `result` frame at EOF — the offline twin of the
/// serve tier's `watch` streams (same frames, same sliding-window
/// engine). An explicit `--addr` switches to remote mode: the rows
/// relay to a running server over the live watch protocol and the
/// server's frames echo back.
fn watch_cmd(args: &Args) -> alingam::util::Result<()> {
    if args.provided("addr") {
        return watch_remote(args);
    }
    use std::io::BufRead;
    let lags = args.usize("lags");
    let window = args.usize("window");
    let cfg = StreamingConfig {
        resync_every: args.usize("resync-every"),
        drift_tol: args.f64("drift-tol"),
    };
    let threshold = args.f64("edge-threshold");
    let (workers, strategy) = incremental_engine(args)?;
    let engine_spec = EngineChoice::parse(&args.req("engine"))?.resolve_workers(1).spec();
    let id = args.req("job-id");
    let t_start = std::time::Instant::now();
    let stdin = std::io::stdin();
    let mut driver: Option<StreamDriver> = None;
    let mut ingested = 0u64;
    for line in stdin.lock().lines() {
        let line = line?;
        let text = line.trim();
        if text.is_empty() {
            continue;
        }
        let row = match parse_csv_row(text) {
            Some(r) => r,
            // the first unparseable line is a CSV header; later ones are
            // corrupt samples and fail the stream
            None if driver.is_none() => continue,
            None => {
                return Err(alingam::util::Error::Parse(format!(
                    "unparseable CSV sample: {text:?}"
                )))
            }
        };
        if driver.is_none() {
            // the first data row fixes the stream's dimensionality
            let d = row.len();
            driver = Some(if lags == 0 {
                StreamDriver::Plain(StreamingLingam::with_options(
                    d, window, cfg, workers, strategy, threshold,
                )?)
            } else {
                StreamDriver::Var(StreamingVarLingam::with_options(
                    d, lags, window, cfg, workers, strategy, threshold,
                )?)
            });
        }
        let drv = driver.as_mut().expect("driver installed above");
        ingested += 1;
        let t0 = std::time::Instant::now();
        let frame = match drv {
            StreamDriver::Plain(s) => s.ingest(&row)?.map(|o| {
                let data = protocol::watch_update_data(&o.order, &o.b0, &[]);
                (o.refit.as_str(), o.resynced, o.drift_bound, data)
            }),
            StreamDriver::Var(s) => s.ingest(&row)?.map(|o| {
                let data = protocol::watch_update_data(&o.order, &o.b0, &o.b_tau);
                (o.refit.as_str(), o.resynced, o.drift_bound, data)
            }),
        };
        if let Some((refit, resynced, drift, data)) = frame {
            let ms = t0.elapsed().as_secs_f64() * 1e3;
            println!(
                "{}",
                protocol::frame_adjacency(&id, ingested, refit, resynced, drift, ms, &data)
            );
        }
    }
    let (ri, rf, rs) = match &driver {
        Some(StreamDriver::Plain(s)) => {
            (s.refits_incremental(), s.refits_full(), s.window().resyncs())
        }
        Some(StreamDriver::Var(s)) => {
            (s.refits_incremental(), s.refits_full(), s.window().resyncs())
        }
        None => (0, 0, 0),
    };
    let summary = protocol::watch_summary_data(&engine_spec, ingested, ri, rf, rs);
    let ms = t_start.elapsed().as_secs_f64() * 1e3;
    println!("{}", protocol::frame_result(Some(&id), false, ms, &summary));
    Ok(())
}

/// Remote watch: subscribe on the server (dimensionality comes from the
/// first stdin row), relay every row as a `frame` request, send `end`
/// at EOF, and echo the server's frames until the terminal one.
fn watch_remote(args: &Args) -> alingam::util::Result<()> {
    use alingam::serve::protocol::Json;
    use std::io::{BufRead, BufReader, Write};
    let addr = args.req("addr");
    let stream = connect_with_deadline(&addr, args.usize("timeout-ms") as u64)?;
    let reader = BufReader::new(stream.try_clone()?);
    let mut writer = stream;
    let id = args.req("job-id");
    let echo = std::thread::spawn(move || -> alingam::util::Result<()> {
        for line in reader.lines() {
            let line = line?;
            println!("{line}");
            let event = protocol::parse_json(&line)
                .ok()
                .and_then(|j| j.get("event").and_then(Json::as_str).map(str::to_string));
            if matches!(event.as_deref(), Some("result" | "error" | "canceled")) {
                return Ok(());
            }
        }
        Err(alingam::util::Error::Runtime(
            "connection closed before a terminal frame arrived".into(),
        ))
    });
    let mut subscribed = false;
    let stdin = std::io::stdin();
    for line in stdin.lock().lines() {
        let line = line?;
        let text = line.trim();
        if text.is_empty() {
            continue;
        }
        let row = match parse_csv_row(text) {
            Some(r) => r,
            None if !subscribed => continue,
            None => {
                return Err(alingam::util::Error::Parse(format!(
                    "unparseable CSV sample: {text:?}"
                )))
            }
        };
        if !subscribed {
            let sub = protocol::watch_request(
                &id,
                &args.req("engine"),
                row.len(),
                args.usize("window"),
                args.usize("lags"),
                args.usize("resync-every"),
                args.f64("drift-tol"),
                args.f64("edge-threshold"),
            );
            writer.write_all(sub.as_bytes())?;
            writer.write_all(b"\n")?;
            subscribed = true;
        }
        writer.write_all(protocol::watch_frame_request(&id, &row).as_bytes())?;
        writer.write_all(b"\n")?;
    }
    if !subscribed {
        return Err(alingam::util::Error::InvalidArgument(
            "no samples on stdin to stream".into(),
        ));
    }
    writer.write_all(protocol::watch_end_request(&id).as_bytes())?;
    writer.write_all(b"\n")?;
    writer.flush()?;
    match echo.join() {
        Ok(result) => result,
        Err(_) => Err(alingam::util::Error::Runtime("frame reader thread panicked".into())),
    }
}

fn info() -> alingam::util::Result<()> {
    println!("alingam {} — AcceleratedLiNGAM reproduction", env!("CARGO_PKG_VERSION"));
    let dir = alingam::runtime::artifact_dir();
    match ArtifactRegistry::load(&dir) {
        Ok(reg) => {
            println!("artifacts: {} ({} entries)", dir.display(), reg.len());
            for kind in [ArtifactKind::OrderScores, ArtifactKind::OrderStep, ArtifactKind::VarFit] {
                let shapes: Vec<String> =
                    reg.of_kind(kind).iter().map(|b| format!("{}x{}", b.n, b.d)).collect();
                println!("  {:<13} {}", kind.as_str(), shapes.join(" "));
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    match alingam::runtime::DeviceExecutor::start() {
        Ok(exec) => println!("pjrt: {}", exec.platform()?),
        Err(e) => println!("pjrt: unavailable ({e})"),
    }
    Ok(())
}
