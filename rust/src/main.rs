//! `alingam` — the AcceleratedLiNGAM command-line launcher.
//!
//! Subcommands:
//!   discover   DirectLiNGAM on simulated SEM data (choose an engine)
//!   var        VarLiNGAM on simulated VAR data
//!   genes      the Table-1 gene pipeline
//!   stocks     the Figure-4 / Table-2 stock pipeline
//!   agree      the Figure-3 parallel-vs-sequential agreement sweep
//!   bootstrap  bootstrap edge-confidence estimation
//!   ica        ICA-LiNGAM (the original estimator) on simulated data
//!   info       runtime/artifact inventory

use alingam::apps::{genes, simbench, stocks};
use alingam::coordinator::{Engine, EngineChoice};
use alingam::lingam::{DirectLingam, VarLingam};
use alingam::metrics::graph_metrics;
use alingam::prelude::*;
use alingam::runtime::{ArtifactKind, ArtifactRegistry};
use alingam::sim::{MarketSpec, VarSpec};
use alingam::util::cli::{engine_opt, opt, Args, OptSpec};
use alingam::util::table::{f, secs, Table};

fn specs() -> Vec<OptSpec> {
    vec![
        engine_opt(),
        opt("dims", "number of variables", Some("10")),
        opt("samples", "number of samples / time steps", Some("4000")),
        opt("seed", "random seed", Some("2024")),
        opt("seeds", "number of sweep seeds (agree)", Some("10")),
        opt("workers", "sweep worker threads", Some("2")),
        opt("scale", "gene experiment scale: small|medium|paper", Some("small")),
        opt("top-k", "ranking size for stocks", Some("5")),
        opt("svgd-iters", "Stein VI iterations", Some("300")),
        opt("svgd-particles", "Stein VI particles", Some("50")),
        opt("resamples", "bootstrap resamples", Some("50")),
        opt("lags", "VAR order k", Some("1")),
    ]
}

fn main() {
    let args = Args::parse(
        "AcceleratedLiNGAM: LiNGAM causal discovery with an AOT JAX/Pallas hot path",
        &specs(),
    );
    let cmd = args.positional(0).unwrap_or("info").to_string();
    if let Err(e) = dispatch(&cmd, &args) {
        eprintln!("error: {e}");
        std::process::exit(1);
    }
}

fn dispatch(cmd: &str, args: &Args) -> alingam::util::Result<()> {
    match cmd {
        "discover" => discover(args),
        "var" => var(args),
        "genes" => genes_cmd(args),
        "stocks" => stocks_cmd(args),
        "agree" => agree(args),
        "bootstrap" => bootstrap_cmd(args),
        "ica" => ica_cmd(args),
        "info" => info(),
        other => {
            eprintln!(
                "unknown command {other:?} (discover|var|genes|stocks|agree|bootstrap|ica|info)"
            );
            std::process::exit(2);
        }
    }
}

fn build_engine(args: &Args) -> alingam::util::Result<Engine> {
    Engine::build(EngineChoice::parse(&args.req("engine"))?)
}

/// Engine for commands that fan jobs across `sweep_workers` threads of
/// their own (`agree`, `bootstrap`): an auto-sized parallel engine inside
/// such a sweep would oversubscribe every core `sweep_workers`-fold, so
/// divide the core budget instead. An explicit `parallel:N` is honored
/// as given.
fn build_engine_for_sweep(args: &Args, sweep_workers: usize) -> alingam::util::Result<Engine> {
    let mut choice = EngineChoice::parse(&args.req("engine"))?;
    let per_job =
        || (alingam::lingam::parallel::default_workers() / sweep_workers.max(1)).max(1);
    match choice {
        EngineChoice::Parallel { workers: 0 } => {
            choice = EngineChoice::Parallel { workers: per_job() };
        }
        EngineChoice::Pruned { workers: 0 } => {
            choice = EngineChoice::Pruned { workers: per_job() };
        }
        _ => {}
    }
    Engine::build(choice)
}

fn discover(args: &Args) -> alingam::util::Result<()> {
    let d = args.usize("dims");
    let n = args.usize("samples");
    let seed = args.usize("seed") as u64;
    let engine = build_engine(args)?;
    let mut rng = Pcg64::seed_from_u64(seed);
    let ds = sim::simulate_sem(&sim::SemSpec::layered(d, 2, 0.5), n, &mut rng);

    let t0 = std::time::Instant::now();
    let fit = DirectLingam::new().fit(&ds.data, engine.as_ordering())?;
    let dt = t0.elapsed().as_secs_f64();
    let m = graph_metrics(&ds.adjacency, &fit.adjacency, 0.05);

    println!("engine       : {}", engine.as_ordering().name());
    println!("order        : {:?}", fit.order);
    println!("true order ok: {}", alingam::graph::order_consistent(&ds.adjacency, &fit.order));
    println!("F1 / recall  : {:.3} / {:.3}   SHD {}", m.f1, m.recall, m.shd);
    println!(
        "wall         : {}   (ordering {:.1}%)",
        secs(dt),
        100.0 * fit.profile.fraction("ordering")
    );
    Ok(())
}

fn var(args: &Args) -> alingam::util::Result<()> {
    let d = args.usize("dims");
    let n = args.usize("samples");
    let seed = args.usize("seed") as u64;
    let engine = build_engine(args)?;
    let mut rng = Pcg64::seed_from_u64(seed);
    let ds = sim::simulate_var(&VarSpec { dim: d, ..Default::default() }, n, &mut rng);
    let t0 = std::time::Instant::now();
    let fit = VarLingam::new().with_lags(args.usize("lags")).fit(&ds.data, engine.as_ordering())?;
    let dt = t0.elapsed().as_secs_f64();
    let m0 = graph_metrics(&ds.b0, &fit.b0, 0.05);
    println!("engine  : {}", engine.as_ordering().name());
    println!("B0 F1   : {:.3}  SHD {}", m0.f1, m0.shd);
    println!("B1 err  : {:.4} (max abs vs truth)", fit.b1().sub(&ds.b1).max_abs());
    println!("wall    : {}  (ordering {:.1}%)", secs(dt), 100.0 * fit.profile.fraction("ordering"));
    Ok(())
}

fn genes_cmd(args: &Args) -> alingam::util::Result<()> {
    let engine = build_engine(args)?;
    let cfg = genes::GenesConfig {
        scale: genes::GeneScale::parse(&args.req("scale"))
            .ok_or_else(|| alingam::util::Error::InvalidArgument("bad --scale".into()))?,
        seed: args.usize("seed") as u64,
        svgd: alingam::baselines::SvgdOpts {
            iters: args.usize("svgd-iters"),
            particles: args.usize("svgd-particles"),
            ..Default::default()
        },
        ..Default::default()
    };
    let rows = genes::run_table1(&cfg, engine.as_ordering())?;
    let mut t = Table::new(
        "Table 1: interventional NLL / MAE on Perturb-seq-style data",
        &["condition", "method", "I-NLL", "I-MAE", "leaves", "fit"],
    );
    for r in &rows {
        t.row(&[
            r.condition.name().into(),
            r.method.into(),
            f(r.metrics.nll, 2),
            f(r.metrics.mae, 2),
            r.leaves.to_string(),
            secs(r.fit_secs),
        ]);
    }
    t.print();
    Ok(())
}

fn stocks_cmd(args: &Args) -> alingam::util::Result<()> {
    let engine = build_engine(args)?;
    let d = args.usize("dims");
    let spec = if d >= 487 {
        MarketSpec::default()
    } else {
        MarketSpec { dim: d, ..MarketSpec::small() }
    };
    let report = stocks::run_stocks(
        &spec,
        args.usize("seed") as u64,
        engine.as_ordering(),
        args.usize("top-k"),
    )?;
    print_stocks_report(&report);
    Ok(())
}

fn print_stocks_report(r: &stocks::StocksReport) {
    let mut t = Table::new(
        "Table 2: total causal influence",
        &["rank", "ticker", "lag", "score", "role"],
    );
    for (k, (name, lag, score)) in r.top_exerting.iter().enumerate() {
        t.row(&[
            (k + 1).to_string(),
            format!("{name}_tau-{lag}"),
            lag.to_string(),
            f(*score, 3),
            "exerting".into(),
        ]);
    }
    for (k, (name, lag, score)) in r.top_receiving.iter().enumerate() {
        t.row(&[
            (k + 1).to_string(),
            format!("{name}_tau-{lag}"),
            lag.to_string(),
            f(*score, 3),
            "receiving".into(),
        ]);
    }
    t.print();
    println!(
        "{}",
        alingam::util::table::histogram("Figure 4: in-degree distribution", &r.in_degrees, 10)
    );
    println!(
        "{}",
        alingam::util::table::histogram("Figure 4: out-degree distribution", &r.out_degrees, 10)
    );
    println!("leaves: {:?}  (designated USB/FITB recovered: {}/2)", r.leaves, r.leaf_hits);
    println!("fit: {}  ordering {:.1}%", secs(r.fit_secs), 100.0 * r.ordering_frac);
}

fn agree(args: &Args) -> alingam::util::Result<()> {
    let n_seeds = args.usize("seeds");
    let seeds: Vec<u64> = (0..n_seeds as u64).collect();
    let engine_b = build_engine_for_sweep(args, args.usize("workers"))?;
    let runs = simbench::agreement_sweep(
        &simbench::fig3_spec(),
        args.usize("samples"),
        &seeds,
        &alingam::lingam::SequentialEngine,
        engine_b.as_ordering(),
        args.usize("workers"),
    );
    let identical = runs.iter().filter(|r| r.orders_identical).count();
    let f1: Vec<f64> = runs.iter().map(|r| r.metrics_b.f1).collect();
    let shd: Vec<f64> = runs.iter().map(|r| r.metrics_b.shd as f64).collect();
    println!("engine B      : {}", engine_b.as_ordering().name());
    println!("orders match  : {identical}/{}", runs.len());
    println!("F1            : {}", metrics::mean_std(&f1));
    println!("SHD           : {}", metrics::mean_std(&shd));
    Ok(())
}

fn bootstrap_cmd(args: &Args) -> alingam::util::Result<()> {
    use alingam::coordinator::{bootstrap_direct, BootstrapOpts};
    let d = args.usize("dims");
    let n = args.usize("samples");
    let engine = build_engine_for_sweep(args, args.usize("workers"))?;
    let mut rng = Pcg64::seed_from_u64(args.usize("seed") as u64);
    let ds = sim::simulate_sem(&sim::SemSpec::layered(d, 2, 0.5), n, &mut rng);
    let opts = BootstrapOpts {
        resamples: args.usize("resamples"),
        workers: args.usize("workers"),
        ..Default::default()
    };
    let result = bootstrap_direct(&ds.data, engine.as_ordering(), &opts)?;
    let mut t = Table::new(
        "bootstrap edge stability (prob ≥ 0.5)",
        &["edge", "probability", "mean weight", "true weight"],
    );
    for (from, to, p, w) in result.stable_edges(0.5) {
        t.row(&[
            format!("{from} → {to}"),
            f(p, 2),
            f(w, 3),
            f(ds.adjacency[(to, from)], 3),
        ]);
    }
    t.print();
    println!("resamples: {}", result.resamples);
    Ok(())
}

fn ica_cmd(args: &Args) -> alingam::util::Result<()> {
    use alingam::lingam::IcaLingam;
    let d = args.usize("dims");
    let n = args.usize("samples");
    let mut rng = Pcg64::seed_from_u64(args.usize("seed") as u64);
    let ds = sim::simulate_sem(&sim::SemSpec::layered(d, 2, 0.5), n, &mut rng);
    let t0 = std::time::Instant::now();
    let fit = IcaLingam::new().fit(&ds.data)?;
    let dt = t0.elapsed().as_secs_f64();
    let m = graph_metrics(&ds.adjacency, &fit.adjacency, 0.05);
    println!("method  : ICA-LiNGAM (Shimizu et al. 2006)");
    println!("order   : {:?}", fit.order);
    println!("order ok: {}", alingam::graph::order_consistent(&ds.adjacency, &fit.order));
    println!("F1 / SHD: {:.3} / {}   wall {}", m.f1, m.shd, secs(dt));
    Ok(())
}

fn info() -> alingam::util::Result<()> {
    println!("alingam {} — AcceleratedLiNGAM reproduction", env!("CARGO_PKG_VERSION"));
    let dir = alingam::runtime::artifact_dir();
    match ArtifactRegistry::load(&dir) {
        Ok(reg) => {
            println!("artifacts: {} ({} entries)", dir.display(), reg.len());
            for kind in [ArtifactKind::OrderScores, ArtifactKind::OrderStep, ArtifactKind::VarFit] {
                let shapes: Vec<String> =
                    reg.of_kind(kind).iter().map(|b| format!("{}x{}", b.n, b.d)).collect();
                println!("  {:<13} {}", kind.as_str(), shapes.join(" "));
            }
        }
        Err(e) => println!("artifacts: unavailable ({e})"),
    }
    match alingam::runtime::DeviceExecutor::start() {
        Ok(exec) => println!("pjrt: {}", exec.platform()?),
        Err(e) => println!("pjrt: unavailable ({e})"),
    }
    Ok(())
}
