//! Experiment pipelines — one module per paper application:
//!
//! - [`genes`] — §4.1 / Table 1: DirectLiNGAM + Stein VI vs a factor-graph
//!   continuous-optimization baseline on Perturb-seq-style data.
//! - [`stocks`] — §4.2 / Figure 4 + Table 2: VarLiNGAM on an S&P-500-style
//!   hourly market panel.
//! - [`simbench`] — the simulation workloads behind Figures 1-3 and §3.1.

pub mod genes;
pub mod simbench;
pub mod stocks;
