//! §4.2 / Figure 4 + Table 2: VarLiNGAM on an S&P-500-style hourly
//! market panel.
//!
//! Pipeline (identical to the paper's): fill missing values by time-based
//! linear interpolation → drop tickers with remaining gaps → difference
//! to stationarity (log-returns) → VAR(1) + DirectLiNGAM on innovations →
//! degree distributions of θ₀ and total-effect rankings.

use crate::data;
use crate::lingam::var::{top_influence, total_effects, VarLingam};
use crate::lingam::{OrderingEngine, ParallelEngine};
use crate::linalg::Mat;
use crate::sim::{simulate_market, MarketDataset, MarketSpec};
use crate::util::rng::Pcg64;
use crate::util::Result;

/// Edge threshold applied to B̂₀ before degree counting.
pub const DEGREE_THRESHOLD: f64 = 0.02;

/// Output of the stock pipeline.
#[derive(Debug, Clone)]
pub struct StocksReport {
    /// Retained tickers (post gap-filtering).
    pub tickers: Vec<String>,
    /// In-degree of each retained ticker in θ̂₀.
    pub in_degrees: Vec<usize>,
    /// Out-degree of each retained ticker in θ̂₀.
    pub out_degrees: Vec<usize>,
    /// Tickers with zero out-degree (the paper: USB, FITB).
    pub leaves: Vec<String>,
    /// Top exerting (ticker, lag, total effect) — Table 2 upper half.
    pub top_exerting: Vec<(String, usize, f64)>,
    /// Top receiving — Table 2 lower half.
    pub top_receiving: Vec<(String, usize, f64)>,
    /// Ground-truth designated exerters recovered in the top-k set.
    pub exerter_hits: usize,
    /// Ground-truth designated leaves recovered as leaves.
    pub leaf_hits: usize,
    pub fit_secs: f64,
    pub ordering_frac: f64,
}

/// Run the full pipeline on a simulated market.
pub fn run_stocks(
    spec: &MarketSpec,
    seed: u64,
    engine: &dyn OrderingEngine,
    top_k: usize,
) -> Result<StocksReport> {
    let mut rng = Pcg64::seed_from_u64(seed);
    let market = simulate_market(spec, &mut rng);
    run_on_market(&market, engine, top_k)
}

/// Run the full pipeline with the default CPU engine: the multi-threaded
/// [`ParallelEngine`] (one worker per core). The paper-scale panel is
/// d ≈ 487 tickers — exactly the O(d²)-pair regime the thread pool is
/// for.
pub fn run_stocks_default(spec: &MarketSpec, seed: u64, top_k: usize) -> Result<StocksReport> {
    run_stocks(spec, seed, &ParallelEngine::default(), top_k)
}

/// Run on an existing market panel (separated for tests).
pub fn run_on_market(
    market: &MarketDataset,
    engine: &dyn OrderingEngine,
    top_k: usize,
) -> Result<StocksReport> {
    // 1) interpolation + gap filtering (paper's preprocessing)
    let filled = data::interpolate_columns(&market.prices);
    let (keep, prices) = data::drop_nan_columns(&filled);
    let tickers: Vec<String> = keep.iter().map(|&c| market.tickers[c].clone()).collect();

    // 2) difference to stationarity
    let returns = data::log_returns(&prices);

    // 3) VarLiNGAM
    let t0 = std::time::Instant::now();
    let fit = VarLingam::new().fit(&returns, engine)?;
    let fit_secs = t0.elapsed().as_secs_f64();

    // 4) degree distributions of the instantaneous graph
    let d = fit.b0.rows();
    let thresholded = Mat::from_fn(d, d, |i, j| {
        if fit.b0[(i, j)].abs() > DEGREE_THRESHOLD {
            fit.b0[(i, j)]
        } else {
            0.0
        }
    });
    let in_degrees: Vec<usize> =
        (0..d).map(|i| (0..d).filter(|&j| thresholded[(i, j)] != 0.0).count()).collect();
    let out_degrees: Vec<usize> =
        (0..d).map(|j| (0..d).filter(|&i| thresholded[(i, j)] != 0.0).count()).collect();
    let leaves: Vec<String> = (0..d)
        .filter(|&j| out_degrees[j] == 0)
        .map(|j| tickers[j].clone())
        .collect();

    // 5) total-effect rankings (Table 2)
    let te = total_effects(&fit);
    let name = |(node, lag, score): (usize, usize, f64)| (tickers[node].clone(), lag, score);
    let top_exerting: Vec<_> = top_influence(&te.exerted, top_k).into_iter().map(name).collect();
    let top_receiving: Vec<_> =
        top_influence(&te.received, top_k).into_iter().map(name).collect();

    // ground-truth recovery counters (for the agreement tests/bench notes)
    let truth_exert: Vec<&String> =
        market.true_exerters.iter().map(|&i| &market.tickers[i]).collect();
    let exerter_hits = top_exerting
        .iter()
        .filter(|(t, _, _)| truth_exert.iter().any(|s| *s == t))
        .count();
    let truth_leaves = ["USB", "FITB"];
    let leaf_hits =
        truth_leaves.iter().filter(|s| leaves.iter().any(|l| l == *s)).count();

    Ok(StocksReport {
        tickers,
        in_degrees,
        out_degrees,
        leaves,
        top_exerting,
        top_receiving,
        exerter_hits,
        leaf_hits,
        fit_secs,
        ordering_frac: fit.profile.fraction("ordering"),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    fn small_report(seed: u64) -> StocksReport {
        // exercise the default CPU engine (parallel) on the app path
        run_stocks(&MarketSpec::small(), seed, &ParallelEngine::new(2), 5).unwrap()
    }

    #[test]
    fn pipeline_runs_and_filters() {
        let r = small_report(1);
        assert!(!r.tickers.is_empty());
        assert_eq!(r.tickers.len(), r.in_degrees.len());
        assert_eq!(r.tickers.len(), r.out_degrees.len());
        assert_eq!(r.top_exerting.len(), 5);
        assert!(r.fit_secs > 0.0);
    }

    #[test]
    fn degree_conservation() {
        // Σ in-degrees == Σ out-degrees == edge count
        let r = small_report(2);
        let in_sum: usize = r.in_degrees.iter().sum();
        let out_sum: usize = r.out_degrees.iter().sum();
        assert_eq!(in_sum, out_sum);
        assert!(in_sum > 0, "no edges recovered");
    }

    #[test]
    fn designated_exerters_rank_high() {
        // the structural hubs should show up in the top-5 exerting list
        let r = small_report(3);
        assert!(
            r.exerter_hits >= 2,
            "only {} designated exerters in top-5: {:?}",
            r.exerter_hits,
            r.top_exerting
        );
    }

    #[test]
    fn structural_leaves_recovered() {
        let r = small_report(4);
        assert!(
            r.leaf_hits >= 1,
            "USB/FITB not recovered as leaves; leaves = {:?}",
            r.leaves
        );
    }
}
