//! §4.1 / Table 1: causal learning of gene-regulatory networks from
//! interventional expression data, scored by I-NLL / I-MAE on held-out
//! interventions.
//!
//! For each condition (co-culture / IFN / control analogues):
//!   1. simulate a Perturb-seq-style dataset ([`crate::sim::genes`]),
//!   2. fit DirectLiNGAM on the training cells, attach Stein-VI
//!      posterior samples, score held-out interventions,
//!   3. fit the factor-graph continuous-optimization comparator
//!      (NOTEARS-LR ≙ DCD-FG) and score it the same way.

use crate::baselines::{evaluate_interventions, evaluate_point, notears_lr, IntervMetrics, NotearsLrOpts, SvgdOpts};
use crate::lingam::{DirectLingam, OrderingEngine, ParallelEngine};
use crate::sim::{simulate_perturb, Condition, PerturbSpec};
use crate::util::rng::Pcg64;
use crate::util::Result;

/// Scale of the gene experiment.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum GeneScale {
    /// Laptop-scale (d=60): the default for tests and examples.
    Small,
    /// Mid-scale (d=200).
    Medium,
    /// Paper-scale (d≈964, 249 targets) — hours of compute.
    Paper,
}

impl GeneScale {
    pub fn spec(self, condition: Condition) -> PerturbSpec {
        match self {
            GeneScale::Small => PerturbSpec::small(condition),
            GeneScale::Medium => PerturbSpec {
                n_genes: 200,
                n_targets: 50,
                cells_per_target: 100,
                n_control_cells: 2_000,
                ..PerturbSpec::small(condition)
            },
            GeneScale::Paper => PerturbSpec::paper_scale(condition),
        }
    }

    pub fn parse(s: &str) -> Option<GeneScale> {
        match s {
            "small" => Some(GeneScale::Small),
            "medium" => Some(GeneScale::Medium),
            "paper" => Some(GeneScale::Paper),
            _ => None,
        }
    }
}

/// One Table-1 cell pair.
#[derive(Debug, Clone)]
pub struct GeneRow {
    pub condition: Condition,
    pub method: &'static str,
    pub metrics: IntervMetrics,
    pub fit_secs: f64,
    /// Leaf-variable count of the discovered graph (the paper remarks on
    /// these per condition).
    pub leaves: usize,
}

/// Configuration for the Table-1 run.
#[derive(Clone, Debug)]
pub struct GenesConfig {
    pub scale: GeneScale,
    pub seed: u64,
    pub svgd: SvgdOpts,
    /// Max training rows fed to the posterior / point evaluators.
    pub max_train_rows: usize,
    /// Max held-out cells scored.
    pub max_test_cells: usize,
    /// Also run the DCD-FG-like comparator.
    pub with_baseline: bool,
}

impl Default for GenesConfig {
    fn default() -> Self {
        GenesConfig {
            scale: GeneScale::Small,
            seed: 2024,
            svgd: SvgdOpts::default(),
            max_train_rows: 400,
            max_test_cells: 200,
            with_baseline: true,
        }
    }
}

/// Run one condition; returns the DirectLiNGAM row and (optionally) the
/// comparator row.
pub fn run_condition(
    cfg: &GenesConfig,
    condition: Condition,
    engine: &dyn OrderingEngine,
) -> Result<Vec<GeneRow>> {
    let mut rng = Pcg64::seed_from_u64(cfg.seed ^ condition as u64);
    let ds = simulate_perturb(&cfg.scale.spec(condition), &mut rng);
    let train = ds.train_data();
    let train_targets: Vec<Option<usize>> =
        ds.train_idx.iter().map(|&r| ds.intervention[r]).collect();
    let test = ds.test_data();
    let test_targets: Vec<usize> =
        ds.test_idx.iter().map(|&r| ds.intervention[r].expect("test cells intervened")).collect();

    let mut rows = Vec::new();

    // --- DirectLiNGAM + Stein VI ---
    let t0 = std::time::Instant::now();
    let fit = DirectLingam::new().fit(&train, engine)?;
    let fit_secs = t0.elapsed().as_secs_f64();
    let metrics = evaluate_interventions(
        &fit.adjacency,
        &train,
        &train_targets,
        &test,
        &test_targets,
        cfg.svgd.clone(),
        cfg.max_train_rows,
        cfg.max_test_cells,
    )?;
    let leaves = crate::graph::Dag::new(fit.adjacency.clone())
        .map(|g| g.leaves().len())
        .unwrap_or(0);
    rows.push(GeneRow { condition, method: "DirectLiNGAM+VI", metrics, fit_secs, leaves });

    // --- DCD-FG-like comparator (NOTEARS-LR + Gaussian predictive) ---
    if cfg.with_baseline {
        let t0 = std::time::Instant::now();
        let opts = NotearsLrOpts {
            rank: (train.cols() / 6).clamp(4, 20),
            max_outer: 8,
            max_inner: 80,
            seed: cfg.seed,
            ..Default::default()
        };
        let adj = notears_lr(&train, &opts)?;
        let fit_secs = t0.elapsed().as_secs_f64();
        let metrics = evaluate_point(
            &adj,
            &train,
            &train_targets,
            &test,
            &test_targets,
            cfg.max_train_rows,
            cfg.max_test_cells,
        )?;
        let leaves =
            crate::graph::Dag::new(adj).map(|g| g.leaves().len()).unwrap_or(0);
        rows.push(GeneRow { condition, method: "NOTEARS-LR (DCD-FG-like)", metrics, fit_secs, leaves });
    }
    Ok(rows)
}

/// Run all three conditions (the full Table 1).
pub fn run_table1(cfg: &GenesConfig, engine: &dyn OrderingEngine) -> Result<Vec<GeneRow>> {
    let mut rows = Vec::new();
    for condition in Condition::all() {
        rows.extend(run_condition(cfg, condition, engine)?);
    }
    Ok(rows)
}

/// Run the full Table 1 with the default CPU engine: the multi-threaded
/// [`ParallelEngine`] with one worker per core (gene panels are wide, so
/// the O(d²) pair loop is where the wall-clock goes).
pub fn run_table1_default(cfg: &GenesConfig) -> Result<Vec<GeneRow>> {
    run_table1(cfg, &ParallelEngine::default())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lingam::VectorizedEngine;

    fn fast_cfg() -> GenesConfig {
        GenesConfig {
            scale: GeneScale::Small,
            svgd: SvgdOpts { particles: 8, iters: 40, step: 0.1, seed: 0 },
            max_train_rows: 120,
            max_test_cells: 40,
            with_baseline: false,
            ..Default::default()
        }
    }

    #[test]
    fn condition_produces_finite_metrics() {
        let rows = run_condition(&fast_cfg(), Condition::CoCulture, &VectorizedEngine).unwrap();
        assert_eq!(rows.len(), 1);
        assert!(rows[0].metrics.nll.is_finite());
        assert!(rows[0].metrics.mae > 0.0);
        assert!(rows[0].fit_secs > 0.0);
    }

    #[test]
    fn default_engine_matches_vectorized() {
        // the default CPU engine (parallel) must reproduce the
        // vectorized engine's discovery on the same condition
        let cfg = fast_cfg();
        let vec_rows = run_condition(&cfg, Condition::Ifn, &VectorizedEngine).unwrap();
        let par_rows =
            run_condition(&cfg, Condition::Ifn, &ParallelEngine::new(2).force_parallel())
                .unwrap();
        assert_eq!(vec_rows[0].leaves, par_rows[0].leaves);
        assert!((vec_rows[0].metrics.nll - par_rows[0].metrics.nll).abs() < 1e-6);
    }

    #[test]
    fn scales_have_increasing_dims() {
        let s = GeneScale::Small.spec(Condition::Ifn);
        let m = GeneScale::Medium.spec(Condition::Ifn);
        let p = GeneScale::Paper.spec(Condition::Ifn);
        assert!(s.n_genes < m.n_genes && m.n_genes < p.n_genes);
        assert_eq!(p.n_genes, 964);
        assert_eq!(p.n_targets, 249);
    }

    #[test]
    fn scale_parsing() {
        assert_eq!(GeneScale::parse("small"), Some(GeneScale::Small));
        assert_eq!(GeneScale::parse("paper"), Some(GeneScale::Paper));
        assert_eq!(GeneScale::parse("huge"), None);
    }
}
