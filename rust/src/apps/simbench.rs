//! Simulation workloads behind Figures 1-3 and §3.1: the Figure-3
//! agreement sweep (parallel vs sequential over 50 seeds), the Figure-1
//! asymmetry demonstration, and the §3.1 NOTEARS comparison.

use crate::baselines::{notears, NotearsOpts};
use crate::coordinator::parallel_map;
use crate::lingam::{DirectLingam, OrderingEngine};
use crate::metrics::{graph_metrics, GraphMetrics};
use crate::sim::{sample_from_dag, simulate_sem, Noise, SemSpec};
use crate::stats;
use crate::util::rng::Pcg64;
use crate::util::Result;

/// The paper's Figure-3 workload: layered DAG, 10 000 samples, 10
/// variables, ε ~ U(0,1).
pub fn fig3_spec() -> SemSpec {
    SemSpec::layered(10, 2, 0.5)
}

/// Result of one seed of the agreement sweep.
#[derive(Debug, Clone)]
pub struct AgreementRun {
    pub seed: u64,
    pub metrics_a: GraphMetrics,
    pub metrics_b: GraphMetrics,
    /// Did both engines produce the identical causal order?
    pub orders_identical: bool,
    /// Max |Δ| between the two estimated adjacencies.
    pub adj_max_diff: f64,
}

/// Figure 3: run engine A and engine B on identical simulated datasets
/// across seeds; report recovery metrics for both plus exact-agreement
/// statistics.
pub fn agreement_sweep(
    spec: &SemSpec,
    n_samples: usize,
    seeds: &[u64],
    engine_a: &dyn OrderingEngine,
    engine_b: &dyn OrderingEngine,
    workers: usize,
) -> Vec<AgreementRun> {
    parallel_map(seeds, workers, |seed| {
        let mut rng = Pcg64::seed_from_u64(seed);
        let ds = simulate_sem(spec, n_samples, &mut rng);
        let fit_a = DirectLingam::new().fit(&ds.data, engine_a).expect("fit a");
        let fit_b = DirectLingam::new().fit(&ds.data, engine_b).expect("fit b");
        AgreementRun {
            seed,
            metrics_a: graph_metrics(&ds.adjacency, &fit_a.adjacency, 0.05),
            metrics_b: graph_metrics(&ds.adjacency, &fit_b.adjacency, 0.05),
            orders_identical: fit_a.order == fit_b.order,
            adj_max_diff: crate::metrics::adjacency_max_diff(&fit_a.adjacency, &fit_b.adjacency),
        }
    })
}

/// §3.1: NOTEARS on the same simulated data, best-of-λ-grid (the paper
/// searches {0.001, 0.005, 0.01, 0.05, 0.1} and reports the best).
pub fn notears_sweep(
    spec: &SemSpec,
    n_samples: usize,
    seeds: &[u64],
    lambdas: &[f64],
    standardize: bool,
    workers: usize,
) -> Vec<GraphMetrics> {
    parallel_map(seeds, workers, |seed| {
        let mut rng = Pcg64::seed_from_u64(seed);
        let ds = simulate_sem(spec, n_samples, &mut rng);
        let mut best: Option<GraphMetrics> = None;
        for &lambda in lambdas {
            let opts = NotearsOpts { lambda, standardize, ..Default::default() };
            if let Ok(adj) = notears(&ds.data, &opts) {
                let m = graph_metrics(&ds.adjacency, &adj, 0.0);
                if best.map(|b| m.f1 > b.f1).unwrap_or(true) {
                    best = Some(m);
                }
            }
        }
        best.expect("at least one lambda succeeded")
    })
}

/// Figure 1: the causal-asymmetry demonstration. Returns
/// (mi_forward, mi_backward) estimates for a 2-variable pair x → y:
/// the mutual information between the regressor and the residual in the
/// correct and reversed directions (correct ≈ 0, reversed > 0 for
/// non-Gaussian noise; both ≈ 0 for Gaussian).
pub fn asymmetry_demo(noise: Noise, n: usize, theta: f64, seed: u64) -> Result<(f64, f64)> {
    let mut rng = Pcg64::seed_from_u64(seed);
    let mut adj = crate::linalg::Mat::zeros(2, 2);
    adj[(1, 0)] = theta;
    let dag = crate::graph::Dag::new(adj).expect("2-node chain");
    let x = sample_from_dag(&dag, noise, n, &mut rng);

    let mut x0 = x.col(0);
    let mut x1 = x.col(1);
    stats::standardize(&mut x0);
    stats::standardize(&mut x1);
    let rho = stats::cov(&x0, &x1);
    let denom = (1.0 - rho * rho).sqrt().max(1e-12);

    // forward: residual of y on x must be independent of x
    let r_fwd: Vec<f64> = x1.iter().zip(&x0).map(|(&y, &a)| (y - rho * a) / denom).collect();
    // backward: residual of x on y against y
    let r_bwd: Vec<f64> = x0.iter().zip(&x1).map(|(&a, &y)| (a - rho * y) / denom).collect();

    Ok((pair_mi(&x0, &r_fwd), pair_mi(&x1, &r_bwd)))
}

/// Binned mutual-information estimate between two variables (equi-width
/// 2-D histogram over ±4σ). OLS residuals are *uncorrelated* with the
/// regressor in both directions by construction; what Figure 1
/// illustrates is the remaining *nonlinear* dependence in the wrong
/// direction, which a histogram MI captures and a correlation-based
/// proxy cannot.
pub fn pair_mi(a: &[f64], b: &[f64]) -> f64 {
    const BINS: usize = 24;
    const RANGE: f64 = 4.0; // standardized inputs: cover ±4σ
    let n = a.len().min(b.len());
    let bin = |v: f64| {
        (((v + RANGE) / (2.0 * RANGE) * BINS as f64) as isize).clamp(0, BINS as isize - 1)
            as usize
    };
    let mut joint = vec![0.0f64; BINS * BINS];
    let mut pa = vec![0.0f64; BINS];
    let mut pb = vec![0.0f64; BINS];
    for t in 0..n {
        let (ia, ib) = (bin(a[t]), bin(b[t]));
        joint[ia * BINS + ib] += 1.0;
        pa[ia] += 1.0;
        pb[ib] += 1.0;
    }
    let inv_n = 1.0 / n as f64;
    let mut mi = 0.0;
    for ia in 0..BINS {
        for ib in 0..BINS {
            let pj = joint[ia * BINS + ib] * inv_n;
            if pj > 0.0 {
                mi += pj * (pj / (pa[ia] * inv_n * pb[ib] * inv_n)).ln();
            }
        }
    }
    // small-sample bias correction (Miller–Madow)
    let occupied = joint.iter().filter(|&&c| c > 0.0).count() as f64;
    let occ_a = pa.iter().filter(|&&c| c > 0.0).count() as f64;
    let occ_b = pb.iter().filter(|&&c| c > 0.0).count() as f64;
    (mi - (occupied - occ_a - occ_b + 1.0).max(0.0) / (2.0 * n as f64)).max(0.0)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lingam::{SequentialEngine, VectorizedEngine};

    #[test]
    fn agreement_sweep_engines_match() {
        let seeds: Vec<u64> = (0..4).collect();
        let runs = agreement_sweep(
            &fig3_spec(),
            1_500,
            &seeds,
            &SequentialEngine,
            &VectorizedEngine,
            2,
        );
        assert_eq!(runs.len(), 4);
        for r in &runs {
            assert!(r.orders_identical, "seed {} orders diverged", r.seed);
            assert!(r.adj_max_diff < 1e-8);
            assert!(r.metrics_a.f1 > 0.5);
        }
    }

    #[test]
    fn asymmetry_uniform_noise() {
        let (fwd, bwd) = asymmetry_demo(Noise::Uniform01, 40_000, 1.5, 1).unwrap();
        assert!(fwd < bwd, "forward MI {fwd} should be < backward {bwd}");
        assert!(fwd < 0.02, "forward MI should be ~0, got {fwd}");
        assert!(bwd > 0.03, "backward MI should be clearly positive, got {bwd}");
    }

    #[test]
    fn asymmetry_vanishes_for_gaussian() {
        let (fwd, bwd) = asymmetry_demo(Noise::Gaussian(1.0), 40_000, 1.5, 2).unwrap();
        assert!(fwd < 0.02 && bwd < 0.02, "Gaussian case should be symmetric: {fwd} vs {bwd}");
    }

    #[test]
    fn notears_sweep_reports_imperfect_recovery() {
        let seeds: Vec<u64> = (0..2).collect();
        let ms = notears_sweep(&fig3_spec(), 1_000, &seeds, &[0.01, 0.1], false, 2);
        assert_eq!(ms.len(), 2);
        for m in &ms {
            assert!(m.f1 > 0.2 && m.f1 <= 1.0);
        }
    }
}
