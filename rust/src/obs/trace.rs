//! Per-job trace contexts: a 128-bit trace id minted at submit, typed
//! span aggregates accumulated as the job moves through the serve
//! pipeline, and a bounded ring buffer of finished traces served by the
//! `trace` request / `GET /trace/<id>` route.
//!
//! # Lifecycle
//!
//! 1. `submit` mints a [`TraceBuilder`] (id + start instant) and stamps
//!    the id onto the `JobSpec`.
//! 2. Pipeline stages record spans into it: one aggregate per
//!    [`SpanKind`] (first-start offset, total duration, event count) —
//!    compact by construction, so a d=1000 fit's 999 ordering steps are
//!    one `order_step` span with `count = 999`, and the ring buffer
//!    stays bounded regardless of job size.
//! 3. The terminal `result` frame carries
//!    [`TraceRecord::timing_json`] — spans plus an `other` filler for
//!    unattributed time, so the span durations always sum to the
//!    recorded wall clock.
//! 4. [`TraceStore::insert`] parks the finished record; `trace`
//!    requests replay it by trace id (or job id) until it ages out of
//!    the ring.

use crate::util::table::{json_escape, json_f64};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Mutex;
use std::time::{Duration, Instant, SystemTime};

/// Typed pipeline stages a span can attribute time to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum SpanKind {
    /// Submit → worker pop (or fusion-window tap).
    QueueWait,
    /// Time the fusion-window leader (or a tapped member) spent holding
    /// the window open for same-shape peers.
    FuseWait,
    /// Result-cache lookups (submit-time short-circuit and the
    /// worker-side re-check).
    CacheProbe,
    /// Session-pool acquire, or building a fresh session / engine.
    SessionAcquire,
    /// Ordering search steps (aggregated; `count` = steps run).
    OrderStep,
    /// The adjacency regression over the original panel.
    Regression,
    /// Writing progress/adjacency frames to the client sink.
    FrameFlush,
    /// Watch streams: ingesting rows between subscribe and terminal.
    Stream,
    /// Wall clock not covered by any recorded span (filler added at
    /// finish so spans sum to the total).
    Other,
}

impl SpanKind {
    pub fn as_str(self) -> &'static str {
        match self {
            SpanKind::QueueWait => "queue_wait",
            SpanKind::FuseWait => "fuse_wait",
            SpanKind::CacheProbe => "cache_probe",
            SpanKind::SessionAcquire => "session_acquire",
            SpanKind::OrderStep => "order_step",
            SpanKind::Regression => "regression",
            SpanKind::FrameFlush => "frame_flush",
            SpanKind::Stream => "stream",
            SpanKind::Other => "other",
        }
    }
}

/// One span aggregate inside a trace.
#[derive(Clone, Debug)]
pub struct Span {
    pub kind: SpanKind,
    /// Offset of the first event from the trace start, µs.
    pub start_us: u64,
    /// Total attributed duration, µs.
    pub dur_us: u64,
    /// Events aggregated into this span.
    pub count: u64,
}

/// Mutable trace context for one in-flight job. Cheap to share
/// (`Arc<TraceBuilder>`); recording locks a small per-job mutex, which
/// is uncontended in practice (one worker drives a job at a time).
pub struct TraceBuilder {
    id: u128,
    job: String,
    t0: Instant,
    spans: Mutex<Vec<Span>>,
}

/// Process-wide uniqueness counter for minted ids.
static MINT_SEQ: AtomicU64 = AtomicU64::new(0);

/// FNV-1a 128-bit, inlined so `obs` stays dependency-free.
fn fnv128(chunks: &[&[u8]]) -> u128 {
    const OFFSET: u128 = 0x6c62272e07bb014262b821756295c58d;
    const PRIME: u128 = 0x0000000001000000000000000000013b;
    let mut h = OFFSET;
    for chunk in chunks {
        for &b in *chunk {
            h ^= b as u128;
            h = h.wrapping_mul(PRIME);
        }
    }
    h
}

impl TraceBuilder {
    /// Mint a fresh trace for `job` at the current instant. The id
    /// hashes wall-clock nanos, a process-wide sequence number and the
    /// job id — unique across the fleet's processes without a shared
    /// randomness source.
    pub fn mint(job: &str) -> TraceBuilder {
        let nanos = SystemTime::now()
            .duration_since(SystemTime::UNIX_EPOCH)
            .map(|d| d.as_nanos())
            .unwrap_or(0);
        let seq = MINT_SEQ.fetch_add(1, Ordering::Relaxed);
        let pid = std::process::id();
        let id = fnv128(&[
            &nanos.to_le_bytes(),
            &seq.to_le_bytes(),
            &pid.to_le_bytes(),
            job.as_bytes(),
        ]);
        TraceBuilder { id, job: job.to_string(), t0: Instant::now(), spans: Mutex::new(Vec::new()) }
    }

    pub fn id(&self) -> u128 {
        self.id
    }

    /// The trace id as 32 lowercase hex chars (the wire form).
    pub fn id_hex(&self) -> String {
        format!("{:032x}", self.id)
    }

    /// The mint instant (= submit time; queue wait is measured from it).
    pub fn started(&self) -> Instant {
        self.t0
    }

    /// Record `dur` against `kind`, starting at `start`. Aggregates
    /// into the existing span of that kind if one exists.
    pub fn record_at(&self, kind: SpanKind, start: Instant, dur: Duration) {
        let start_us = start.saturating_duration_since(self.t0).as_micros() as u64;
        let dur_us = dur.as_micros() as u64;
        let mut spans = self.spans.lock().expect("trace spans");
        if let Some(s) = spans.iter_mut().find(|s| s.kind == kind) {
            s.dur_us += dur_us;
            s.count += 1;
            s.start_us = s.start_us.min(start_us);
        } else {
            spans.push(Span { kind, start_us, dur_us, count: 1 });
        }
    }

    /// Record a duration that ends now.
    pub fn record(&self, kind: SpanKind, dur: Duration) {
        let now = Instant::now();
        self.record_at(kind, now.checked_sub(dur).unwrap_or(now), dur);
    }

    /// Freeze into a [`TraceRecord`]: total = mint → now, with an
    /// `other` span filling whatever the recorded spans left
    /// unattributed (so span durations sum to the total exactly).
    pub fn finish(&self) -> TraceRecord {
        let total = self.t0.elapsed();
        let total_us = total.as_micros() as u64;
        let mut spans = self.spans.lock().expect("trace spans").clone();
        let attributed: u64 = spans.iter().map(|s| s.dur_us).sum();
        if total_us > attributed {
            spans.push(Span {
                kind: SpanKind::Other,
                start_us: 0,
                dur_us: total_us - attributed,
                count: 1,
            });
        }
        spans.sort_by_key(|s| s.start_us);
        TraceRecord { trace_hex: self.id_hex(), job: self.job.clone(), total_us, spans }
    }
}

/// A finished trace: what `trace` requests replay and what the terminal
/// `result` frame embeds as `"timing"`.
#[derive(Clone, Debug)]
pub struct TraceRecord {
    pub trace_hex: String,
    pub job: String,
    pub total_us: u64,
    pub spans: Vec<Span>,
}

impl TraceRecord {
    fn spans_json(&self) -> String {
        let spans: Vec<String> = self
            .spans
            .iter()
            .map(|s| {
                format!(
                    "{{\"span\":\"{}\",\"start_ms\":{},\"ms\":{},\"count\":{}}}",
                    s.kind.as_str(),
                    json_f64(s.start_us as f64 / 1e3),
                    json_f64(s.dur_us as f64 / 1e3),
                    s.count
                )
            })
            .collect();
        spans.join(",")
    }

    /// Brace-less body shared by the `trace` frame and `GET /trace/<id>`:
    /// `"trace":…,"job":…,"total_ms":…,"spans":[…]`.
    pub fn body_json(&self) -> String {
        format!(
            "\"trace\":\"{}\",\"job\":\"{}\",\"total_ms\":{},\"spans\":[{}]",
            self.trace_hex,
            json_escape(&self.job),
            json_f64(self.total_us as f64 / 1e3),
            self.spans_json()
        )
    }

    /// The compact object attached to terminal `result` frames.
    pub fn timing_json(&self) -> String {
        format!(
            "{{\"trace\":\"{}\",\"total_ms\":{},\"spans\":[{}]}}",
            self.trace_hex,
            json_f64(self.total_us as f64 / 1e3),
            self.spans_json()
        )
    }
}

/// Bounded ring of finished traces, queryable by trace id hex or job
/// id (latest job id match wins — job ids are client-chosen and may
/// repeat; trace ids are minted unique).
pub struct TraceStore {
    ring: Mutex<VecDeque<TraceRecord>>,
    capacity: usize,
}

impl TraceStore {
    pub fn new(capacity: usize) -> TraceStore {
        TraceStore { ring: Mutex::new(VecDeque::new()), capacity: capacity.max(1) }
    }

    pub fn insert(&self, record: TraceRecord) {
        let mut ring = self.ring.lock().expect("trace ring");
        if ring.len() == self.capacity {
            ring.pop_front();
        }
        ring.push_back(record);
    }

    pub fn get(&self, target: &str) -> Option<TraceRecord> {
        let ring = self.ring.lock().expect("trace ring");
        ring.iter().rev().find(|r| r.trace_hex == target || r.job == target).cloned()
    }

    pub fn len(&self) -> usize {
        self.ring.lock().expect("trace ring").len()
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn minted_ids_are_unique_and_hex_stable() {
        let a = TraceBuilder::mint("same-job");
        let b = TraceBuilder::mint("same-job");
        assert_ne!(a.id(), b.id(), "sequence number must split identical mint inputs");
        assert_eq!(a.id_hex().len(), 32);
        assert_eq!(a.id_hex(), format!("{:032x}", a.id()));
    }

    #[test]
    fn spans_aggregate_by_kind_and_other_fills_to_total() {
        let t = TraceBuilder::mint("j1");
        t.record(SpanKind::OrderStep, Duration::from_micros(300));
        t.record(SpanKind::OrderStep, Duration::from_micros(200));
        t.record(SpanKind::Regression, Duration::from_micros(100));
        std::thread::sleep(Duration::from_millis(2));
        let rec = t.finish();
        let steps = rec.spans.iter().find(|s| s.kind == SpanKind::OrderStep).unwrap();
        assert_eq!(steps.count, 2);
        assert_eq!(steps.dur_us, 500);
        let sum: u64 = rec.spans.iter().map(|s| s.dur_us).sum();
        assert_eq!(sum, rec.total_us, "other must fill spans to the total exactly");
        assert!(rec.spans.iter().any(|s| s.kind == SpanKind::Other));
    }

    #[test]
    fn timing_json_carries_trace_spans_and_totals() {
        let t = TraceBuilder::mint("j2");
        t.record(SpanKind::QueueWait, Duration::from_micros(1500));
        let rec = t.finish();
        let timing = rec.timing_json();
        assert!(timing.starts_with("{\"trace\":\""));
        assert!(timing.contains("\"span\":\"queue_wait\""));
        assert!(timing.contains("\"total_ms\":"));
        let body = rec.body_json();
        assert!(body.contains("\"job\":\"j2\""));
        assert!(!body.starts_with('{'), "body form is brace-less for frame embedding");
    }

    #[test]
    fn store_is_a_ring_queryable_by_trace_or_job_id() {
        let store = TraceStore::new(2);
        let mk = |job: &str| TraceBuilder::mint(job).finish();
        let a = mk("a");
        let a_hex = a.trace_hex.clone();
        store.insert(a);
        store.insert(mk("b"));
        assert!(store.get(&a_hex).is_some());
        assert!(store.get("b").is_some());
        store.insert(mk("c")); // evicts a
        assert_eq!(store.len(), 2);
        assert!(store.get(&a_hex).is_none(), "ring must evict the oldest");
        assert!(store.get("c").is_some());
        // duplicate job ids: latest wins
        store.insert(mk("c"));
        let latest = store.get("c").unwrap();
        assert_eq!(store.len(), 2);
        assert_eq!(latest.job, "c");
    }
}
