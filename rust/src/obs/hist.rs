//! Lock-free log-linear latency histogram.
//!
//! HdrHistogram-style bucketing: values (microseconds) below
//! 2·`SUB` land in exact unit buckets; above that, each power-of-two
//! octave splits into `SUB` = 16 linear sub-buckets, so the worst-case
//! relative error of a bucket's midpoint representative is
//! 1/(2·SUB) ≈ 3.1% — inside the ~4% budget the serve tier documents.
//! Recording is one relaxed `fetch_add` per bucket plus a CAS-max, so
//! the hot path (worker threads booking job/step latencies) never
//! contends on a lock; readout goes through an owned [`Snapshot`],
//! which also gives the shard supervisor its merge primitive: child
//! snapshots serialize sparsely into the JSON metrics frame and sum
//! bucket-wise at the front, and quantiles of the merged distribution
//! are exact at bucket resolution (bucketing is deterministic, so the
//! same value lands in the same bucket in every process).

use std::sync::atomic::{AtomicU64, Ordering};

/// Linear sub-buckets per octave (16 ⇒ ≤3.1% relative error).
const SUB: usize = 16;
const SUB_BITS: u32 = 4;
/// Total buckets: unit buckets + 44 octaves of SUB sub-buckets each.
/// The top bucket's low edge is ≈ 2^47 µs (≈ 4.5 years) — an effective
/// +Inf bucket for latencies.
pub const BUCKETS: usize = SUB * 45;

/// Bucket index for a microsecond value. Total (never panics), clamps
/// into the top bucket.
fn index_for(us: u64) -> usize {
    let v = us.max(1);
    let msb = 63 - v.leading_zeros(); // v >= 1, so well-defined
    if msb < SUB_BITS {
        return v as usize; // exact unit buckets 1..=15 (0 unused)
    }
    let shift = msb - SUB_BITS;
    let sub = (v >> shift) as usize - SUB; // linear position within the octave
    ((shift as usize + 1) * SUB + sub).min(BUCKETS - 1)
}

/// Inclusive low edge and exclusive high edge of bucket `i`, in µs.
fn bounds_for(i: usize) -> (u64, u64) {
    if i < SUB {
        return (i as u64, i as u64 + 1);
    }
    let block = (i / SUB) as u32; // >= 1
    let sub = (i % SUB) as u64;
    let low = (SUB as u64 + sub) << (block - 1);
    (low, low + (1u64 << (block - 1)))
}

/// Concurrent latency histogram; see the module docs for the bucketing
/// scheme. All methods take `&self`.
pub struct Histogram {
    buckets: Vec<AtomicU64>,
    count: AtomicU64,
    sum_us: AtomicU64,
    max_us: AtomicU64,
}

impl Default for Histogram {
    fn default() -> Histogram {
        Histogram::new()
    }
}

impl Histogram {
    pub fn new() -> Histogram {
        Histogram {
            buckets: (0..BUCKETS).map(|_| AtomicU64::new(0)).collect(),
            count: AtomicU64::new(0),
            sum_us: AtomicU64::new(0),
            max_us: AtomicU64::new(0),
        }
    }

    /// Record one duration.
    pub fn record(&self, d: std::time::Duration) {
        self.record_us(d.as_micros().min(u64::MAX as u128) as u64);
    }

    /// Record one microsecond value (0 is clamped to the 1 µs bucket so
    /// a sub-microsecond event still counts).
    pub fn record_us(&self, us: u64) {
        self.buckets[index_for(us)].fetch_add(1, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.sum_us.fetch_add(us, Ordering::Relaxed);
        self.max_us.fetch_max(us, Ordering::Relaxed);
    }

    /// Owned copy of the current state. Not a point-in-time atomic cut
    /// across buckets — concurrent records may straddle it — but every
    /// count lands in exactly one snapshot eventually, which is all a
    /// monotonic scrape needs.
    pub fn snapshot(&self) -> Snapshot {
        Snapshot {
            buckets: self.buckets.iter().map(|b| b.load(Ordering::Relaxed)).collect(),
            count: self.count.load(Ordering::Relaxed),
            sum_us: self.sum_us.load(Ordering::Relaxed),
            max_us: self.max_us.load(Ordering::Relaxed),
        }
    }
}

/// Owned histogram state: quantile readout, bucket-wise merge, and the
/// sparse JSON form the shard supervisor aggregates.
#[derive(Clone, Debug, Default)]
pub struct Snapshot {
    buckets: Vec<u64>,
    count: u64,
    sum_us: u64,
    max_us: u64,
}

impl Snapshot {
    /// Rebuild a snapshot from its serialized parts (the supervisor's
    /// deserialization path; pairs are `(bucket_index, count)`).
    /// Out-of-range indices are dropped rather than panicking — the
    /// frame came over a socket.
    pub fn from_parts(count: u64, sum_us: u64, max_us: u64, pairs: &[(usize, u64)]) -> Snapshot {
        let mut buckets = vec![0u64; BUCKETS];
        for &(i, c) in pairs {
            if i < BUCKETS {
                buckets[i] += c;
            }
        }
        Snapshot { buckets, count, sum_us, max_us }
    }

    pub fn count(&self) -> u64 {
        self.count
    }

    pub fn sum_us(&self) -> u64 {
        self.sum_us
    }

    pub fn max_us(&self) -> u64 {
        self.max_us
    }

    pub fn mean_us(&self) -> f64 {
        if self.count == 0 {
            0.0
        } else {
            self.sum_us as f64 / self.count as f64
        }
    }

    /// Bucket-wise sum (the shard supervisor's aggregation).
    pub fn merge(&mut self, other: &Snapshot) {
        if self.buckets.is_empty() {
            self.buckets = vec![0; BUCKETS];
        }
        for (a, b) in self.buckets.iter_mut().zip(&other.buckets) {
            *a += *b;
        }
        self.count += other.count;
        self.sum_us += other.sum_us;
        self.max_us = self.max_us.max(other.max_us);
    }

    /// The `q`-quantile (q ∈ [0, 1]) in µs: midpoint of the bucket
    /// holding the ⌈q·count⌉-th smallest recorded value, exact-rank at
    /// bucket resolution. 0.0 on an empty snapshot.
    pub fn quantile_us(&self, q: f64) -> f64 {
        if self.count == 0 {
            return 0.0;
        }
        let target = ((q.clamp(0.0, 1.0) * self.count as f64).ceil() as u64).max(1);
        let mut cum = 0u64;
        for (i, &c) in self.buckets.iter().enumerate() {
            cum += c;
            if cum >= target {
                let (lo, hi) = bounds_for(i);
                // midpoint, capped by the true max (the top recorded
                // value is known exactly, so never report past it)
                return ((lo + hi) as f64 / 2.0).min(self.max_us as f64).max(lo as f64);
            }
        }
        self.max_us as f64
    }

    /// Sparse JSON object: totals, convenience quantiles (ms), and the
    /// non-zero `[index, count]` bucket pairs a peer can
    /// [`from_parts`](Snapshot::from_parts) back.
    pub fn to_json(&self) -> String {
        let pairs: Vec<String> = self
            .buckets
            .iter()
            .enumerate()
            .filter(|(_, &c)| c > 0)
            .map(|(i, &c)| format!("[{i},{c}]"))
            .collect();
        format!(
            "{{\"count\":{},\"sum_us\":{},\"max_us\":{},\"p50_us\":{},\"p95_us\":{},\
             \"p99_us\":{},\"buckets\":[{}]}}",
            self.count,
            self.sum_us,
            self.max_us,
            crate::util::table::json_f64(self.quantile_us(0.5)),
            crate::util::table::json_f64(self.quantile_us(0.95)),
            crate::util::table::json_f64(self.quantile_us(0.99)),
            pairs.join(",")
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buckets_are_contiguous_and_monotone() {
        // every index maps into a bucket whose bounds contain it, and
        // bucket edges tile the line with no gaps
        let mut prev_hi = 1u64;
        for i in 1..BUCKETS {
            let (lo, hi) = bounds_for(i);
            assert_eq!(lo, prev_hi, "gap before bucket {i}");
            assert!(hi > lo);
            prev_hi = hi;
            assert_eq!(index_for(lo), i, "low edge of bucket {i} maps elsewhere");
            assert_eq!(index_for(hi - 1), i, "high edge of bucket {i} maps elsewhere");
        }
    }

    #[test]
    fn quantiles_hit_within_relative_error() {
        let h = Histogram::new();
        // geometric spread of values; exact-rank reference
        let mut vals: Vec<u64> = (0..2000u64).map(|k| 1 + (k * k) % 900_000).collect();
        for &v in &vals {
            h.record_us(v);
        }
        vals.sort_unstable();
        let s = h.snapshot();
        assert_eq!(s.count(), 2000);
        assert_eq!(s.max_us(), *vals.last().unwrap());
        for q in [0.5, 0.95, 0.99] {
            let rank = ((q * vals.len() as f64).ceil() as usize).max(1) - 1;
            let truth = vals[rank] as f64;
            let est = s.quantile_us(q);
            assert!(
                (est - truth).abs() <= 0.04 * truth + 1.0,
                "q={q}: est {est} vs truth {truth}"
            );
        }
    }

    #[test]
    fn merge_equals_single_histogram_and_json_roundtrips() {
        let a = Histogram::new();
        let b = Histogram::new();
        let all = Histogram::new();
        for v in 1..500u64 {
            let target = if v % 2 == 0 { &a } else { &b };
            target.record_us(v * 37);
            all.record_us(v * 37);
        }
        let mut merged = a.snapshot();
        merged.merge(&b.snapshot());
        let solo = all.snapshot();
        assert_eq!(merged.count(), solo.count());
        assert_eq!(merged.sum_us(), solo.sum_us());
        assert_eq!(merged.max_us(), solo.max_us());
        for q in [0.1, 0.5, 0.9, 0.99] {
            assert_eq!(merged.quantile_us(q), solo.quantile_us(q));
        }
        // sparse JSON carries every non-zero bucket
        let json = merged.to_json();
        assert!(json.contains("\"count\":499"));
        assert!(json.contains("\"buckets\":[["));
    }

    #[test]
    fn zero_and_huge_values_clamp_instead_of_panicking() {
        let h = Histogram::new();
        h.record_us(0);
        h.record_us(u64::MAX);
        let s = h.snapshot();
        assert_eq!(s.count(), 2);
        assert_eq!(s.max_us(), u64::MAX);
        assert!(s.quantile_us(0.0) >= 0.0);
        let empty = Histogram::new().snapshot();
        assert_eq!(empty.quantile_us(0.5), 0.0);
        assert_eq!(empty.mean_us(), 0.0);
    }
}
