//! Leveled structured logger for the serve stack: one record per line
//! on stderr, either `key=value` text or JSON (`--log-json`), every
//! record carrying an `event` name and — where one exists — the job's
//! trace id, so a log line joins against `GET /trace/<id>` and the
//! metrics it moved.
//!
//! # Record schema
//!
//! Text form:
//!
//! ```text
//! ts=1723111845123 level=info event=job_completed trace=3f2a… id=job-1 ms=41.8
//! ```
//!
//! JSON form (`--log-json`): the same fields as one object per line —
//! `{"ts":1723111845123,"level":"info","event":"job_completed",…}`.
//! `ts` is unix epoch milliseconds. Values containing spaces, quotes
//! or `=` are double-quoted (JSON-escaped) in the text form.
//!
//! # Initialization
//!
//! [`init`] is first-call-wins (`OnceLock`): the binary initializes
//! from `--log-level`/`--log-json`, library embedders may never call it
//! — the uninitialized default logs `warn` and `error` only, in text
//! form, so tests and embedders stay quiet.

use std::sync::OnceLock;
use std::time::SystemTime;

/// Log verbosity, ordered: `error` < `warn` < `info` < `debug`.
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Level {
    Error,
    Warn,
    Info,
    Debug,
}

impl Level {
    pub fn parse(s: &str) -> Option<Level> {
        match s.to_ascii_lowercase().as_str() {
            "error" => Some(Level::Error),
            "warn" | "warning" => Some(Level::Warn),
            "info" => Some(Level::Info),
            "debug" => Some(Level::Debug),
            _ => None,
        }
    }

    pub fn as_str(self) -> &'static str {
        match self {
            Level::Error => "error",
            Level::Warn => "warn",
            Level::Info => "info",
            Level::Debug => "debug",
        }
    }
}

struct Config {
    level: Level,
    json: bool,
}

static CONFIG: OnceLock<Config> = OnceLock::new();

/// Install the global logger configuration. First call wins; later
/// calls are no-ops (returns whether this call installed it).
pub fn init(level: Level, json: bool) -> bool {
    CONFIG.set(Config { level, json }).is_ok()
}

fn config() -> &'static Config {
    static DEFAULT: Config = Config { level: Level::Warn, json: false };
    CONFIG.get().unwrap_or(&DEFAULT)
}

/// Whether a record at `level` would be emitted.
pub fn enabled(level: Level) -> bool {
    level <= config().level
}

fn unix_ms() -> u128 {
    SystemTime::now()
        .duration_since(SystemTime::UNIX_EPOCH)
        .map(|d| d.as_millis())
        .unwrap_or(0)
}

/// Emit one record. `fields` are appended after `ts`/`level`/`event`
/// in the order given; by convention the trace id (when one exists)
/// comes first as `("trace", …)`.
pub fn log(level: Level, event: &str, fields: &[(&str, &str)]) {
    let cfg = config();
    if level > cfg.level {
        return;
    }
    let line = if cfg.json {
        let mut out = format!(
            "{{\"ts\":{},\"level\":\"{}\",\"event\":\"{}\"",
            unix_ms(),
            level.as_str(),
            crate::util::table::json_escape(event)
        );
        for (k, v) in fields {
            out.push_str(&format!(
                ",\"{}\":\"{}\"",
                crate::util::table::json_escape(k),
                crate::util::table::json_escape(v)
            ));
        }
        out.push('}');
        out
    } else {
        let mut out = format!("ts={} level={} event={}", unix_ms(), level.as_str(), event);
        for (k, v) in fields {
            if v.contains([' ', '"', '=']) || v.is_empty() {
                out.push_str(&format!(" {k}=\"{}\"", crate::util::table::json_escape(v)));
            } else {
                out.push_str(&format!(" {k}={v}"));
            }
        }
        out
    };
    // eprintln locks stderr per call, so records never interleave
    eprintln!("{line}");
}

pub fn error(event: &str, fields: &[(&str, &str)]) {
    log(Level::Error, event, fields);
}

pub fn warn(event: &str, fields: &[(&str, &str)]) {
    log(Level::Warn, event, fields);
}

pub fn info(event: &str, fields: &[(&str, &str)]) {
    log(Level::Info, event, fields);
}

pub fn debug(event: &str, fields: &[(&str, &str)]) {
    log(Level::Debug, event, fields);
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn levels_parse_and_order() {
        assert_eq!(Level::parse("INFO"), Some(Level::Info));
        assert_eq!(Level::parse("warning"), Some(Level::Warn));
        assert_eq!(Level::parse("nope"), None);
        assert!(Level::Error < Level::Warn);
        assert!(Level::Warn < Level::Info);
        assert!(Level::Info < Level::Debug);
    }

    #[test]
    fn default_config_logs_warn_and_error_only() {
        // tests share one process; this only asserts the *default*
        // when nothing initialized the logger (or whatever init chose
        // still honors the ordering contract)
        if CONFIG.get().is_none() {
            assert!(enabled(Level::Error));
            assert!(enabled(Level::Warn));
            assert!(!enabled(Level::Info));
            assert!(!enabled(Level::Debug));
        }
        // emitting below the threshold is a no-op, not a panic
        debug("never_emitted", &[("k", "v")]);
    }
}
