//! `obs` — observability primitives for the serve tier and the
//! ordering engine: lock-free latency histograms ([`hist`]), per-job
//! trace contexts with typed spans ([`trace`]), a leveled structured
//! logger ([`log`]), and a Prometheus text-exposition builder
//! ([`PromText`]). Std-only, like everything else in the crate.
//!
//! # Why a home-grown layer
//!
//! The paper's headline claim is a *measured* one — "up to a 32-fold
//! speed-up" rests on knowing where wall-clock goes (the Figure-2
//! "ordering is ≤96% of runtime" profile) — and the serve tier (queue,
//! fusion window, shard fleet, disk cache, watch streams) adds queueing
//! and batching stages the engine-side [`StageProfile`] never sees.
//! `tracing`/`metrics`/`prometheus` crates are not in the offline crate
//! set, so the three primitives they would provide are hand-rolled
//! here, sized for exactly what the serve tier needs:
//!
//! - [`hist::Histogram`] — log-linear bucketed latency distribution
//!   (`AtomicU64` buckets, ≈3% worst-case relative error) with
//!   p50/p95/p99/max readout and a snapshot/merge API the shard
//!   supervisor uses to aggregate per-child histograms.
//! - [`trace::TraceBuilder`] — a 128-bit trace id minted at submit and
//!   threaded through the job, accumulating typed span aggregates
//!   (queue wait, fusion-window wait, cache probe, session acquire,
//!   per-step ordering, regression, frame flush) that land on the
//!   terminal `result` frame as a compact `"timing"` object and in a
//!   bounded ring buffer served by `trace` requests / `GET /trace/<id>`.
//! - [`log`] — a leveled key=value (or JSON) logger on stderr carrying
//!   the trace id, replacing ad-hoc prints in the serve stack.
//!
//! [`StageProfile`]: crate::util::timer::StageProfile

pub mod hist;
pub mod log;
pub mod trace;

use crate::util::table::json_escape;

/// Prometheus text-exposition (version 0.0.4) builder: `# HELP`/`# TYPE`
/// headers, escaped label values, and summary rendering from a
/// histogram snapshot. The output parses under `tools/check_prom.py`
/// and any Prometheus scraper.
#[derive(Default)]
pub struct PromText {
    out: String,
}

impl PromText {
    pub fn new() -> PromText {
        PromText::default()
    }

    /// Start a metric family: `# HELP` and `# TYPE` lines.
    pub fn family(&mut self, name: &str, kind: &str, help: &str) -> &mut Self {
        self.out.push_str(&format!("# HELP {name} {help}\n# TYPE {name} {kind}\n"));
        self
    }

    /// One sample line with optional labels.
    pub fn sample(&mut self, name: &str, labels: &[(&str, &str)], value: f64) -> &mut Self {
        self.out.push_str(name);
        if !labels.is_empty() {
            self.out.push('{');
            for (i, (k, v)) in labels.iter().enumerate() {
                if i > 0 {
                    self.out.push(',');
                }
                // label values share JSON's escape set (backslash,
                // quote) plus escaped newlines — json_escape covers it
                self.out.push_str(&format!("{k}=\"{}\"", json_escape(v)));
            }
            self.out.push('}');
        }
        self.out.push_str(&format!(" {}\n", fmt_value(value)));
        self
    }

    /// Shorthand: family header plus one unlabeled sample.
    pub fn single(&mut self, name: &str, kind: &str, help: &str, value: f64) -> &mut Self {
        self.family(name, kind, help).sample(name, &[], value)
    }

    /// Render a histogram snapshot as a Prometheus `summary` in seconds:
    /// `name{quantile="0.5|0.95|0.99"}`, `name_sum`, `name_count`, plus
    /// a companion `name_max` gauge (summaries have no max series).
    pub fn summary_seconds(&mut self, name: &str, help: &str, snap: &hist::Snapshot) -> &mut Self {
        self.family(name, "summary", help);
        for (label, q) in [("0.5", 0.5), ("0.95", 0.95), ("0.99", 0.99)] {
            self.sample(name, &[("quantile", label)], snap.quantile_us(q) / 1e6);
        }
        self.sample(&format!("{name}_sum"), &[], snap.sum_us() as f64 / 1e6);
        self.sample(&format!("{name}_count"), &[], snap.count() as f64);
        self.single(
            &format!("{name}_max"),
            "gauge",
            "Largest value recorded into the companion summary, in seconds.",
            snap.max_us() as f64 / 1e6,
        )
    }

    pub fn render(self) -> String {
        self.out
    }
}

/// Prometheus float formatting: plain decimal (Rust's `Display` for
/// `f64` never emits exponents for the magnitudes booked here), with
/// non-finite values spelled the way the exposition format expects.
fn fmt_value(v: f64) -> String {
    if v.is_nan() {
        "NaN".to_string()
    } else if v.is_infinite() {
        if v > 0.0 { "+Inf" } else { "-Inf" }.to_string()
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn prom_text_renders_help_type_labels_and_values() {
        let mut p = PromText::new();
        p.single("alingam_up", "gauge", "Whether the server is up.", 1.0);
        p.family("alingam_jobs_total", "counter", "Jobs.")
            .sample("alingam_jobs_total", &[("kind", "fit")], 3.0)
            .sample("alingam_jobs_total", &[("kind", "boot\"strap")], 0.5);
        let text = p.render();
        assert!(text.contains("# HELP alingam_up Whether the server is up.\n"));
        assert!(text.contains("# TYPE alingam_up gauge\n"));
        assert!(text.contains("alingam_up 1\n"));
        assert!(text.contains("alingam_jobs_total{kind=\"fit\"} 3\n"));
        // escaped quote inside a label value
        assert!(text.contains("kind=\"boot\\\"strap\""));
        assert!(text.contains("alingam_jobs_total{kind=\"boot\\\"strap\"} 0.5\n"));
    }

    #[test]
    fn summary_renders_quantiles_sum_count_max() {
        let h = hist::Histogram::new();
        for us in [100u64, 200, 300, 400, 1000] {
            h.record_us(us);
        }
        let mut p = PromText::new();
        p.summary_seconds("alingam_job_latency_seconds", "Job latency.", &h.snapshot());
        let text = p.render();
        assert!(text.contains("# TYPE alingam_job_latency_seconds summary\n"));
        assert!(text.contains("alingam_job_latency_seconds{quantile=\"0.5\"}"));
        assert!(text.contains("alingam_job_latency_seconds{quantile=\"0.95\"}"));
        assert!(text.contains("alingam_job_latency_seconds{quantile=\"0.99\"}"));
        assert!(text.contains("alingam_job_latency_seconds_count 5\n"));
        assert!(text.contains("alingam_job_latency_seconds_sum 0.002\n"));
        assert!(text.contains("# TYPE alingam_job_latency_seconds_max gauge\n"));
    }

    #[test]
    fn fmt_value_spells_nonfinite_the_prometheus_way() {
        assert_eq!(fmt_value(f64::NAN), "NaN");
        assert_eq!(fmt_value(f64::INFINITY), "+Inf");
        assert_eq!(fmt_value(f64::NEG_INFINITY), "-Inf");
        assert_eq!(fmt_value(0.25), "0.25");
    }
}
