//! Dataset plumbing: CSV I/O, missing-value interpolation, differencing —
//! the light preprocessing the paper applies to the stock panel
//! ("filling missing values using time-based linear interpolation,
//! removing indices with any remaining missing values, and transforming
//! ... with first differencing").

use crate::linalg::Mat;
use crate::util::{Error, Result};
use std::io::{BufRead, BufReader, Write};
use std::path::Path;

/// Write a matrix as CSV with a header row.
pub fn write_csv(path: &Path, header: &[String], m: &Mat) -> Result<()> {
    let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
    writeln!(f, "{}", header.join(","))?;
    for r in 0..m.rows() {
        let row: Vec<String> = m.row(r).iter().map(|v| {
            if v.is_nan() { String::new() } else { format!("{v}") }
        }).collect();
        writeln!(f, "{}", row.join(","))?;
    }
    Ok(())
}

/// Read a CSV with a header row into (header, matrix). Empty cells parse
/// as NaN.
pub fn read_csv(path: &Path) -> Result<(Vec<String>, Mat)> {
    let f = BufReader::new(std::fs::File::open(path)?);
    let mut lines = f.lines();
    let header: Vec<String> = lines
        .next()
        .ok_or_else(|| Error::Parse("empty csv".into()))??
        .split(',')
        .map(|s| s.trim().to_string())
        .collect();
    let ncol = header.len();
    let mut data = Vec::new();
    let mut nrow = 0;
    for (lineno, line) in lines.enumerate() {
        let line = line?;
        if line.trim().is_empty() {
            continue;
        }
        let cells: Vec<&str> = line.split(',').collect();
        if cells.len() != ncol {
            return Err(Error::Parse(format!(
                "line {}: {} cells, expected {ncol}",
                lineno + 2,
                cells.len()
            )));
        }
        for c in cells {
            let c = c.trim();
            if c.is_empty() {
                data.push(f64::NAN);
            } else {
                data.push(c.parse::<f64>().map_err(|e| {
                    Error::Parse(format!("line {}: bad float {c:?}: {e}", lineno + 2))
                })?);
            }
        }
        nrow += 1;
    }
    Ok((header, Mat::from_vec(nrow, ncol, data)?))
}

/// Time-based linear interpolation of NaN runs in each column. Interior
/// gaps are linearly interpolated; leading/trailing gaps are left NaN
/// (the paper then drops such columns).
pub fn interpolate_columns(m: &Mat) -> Mat {
    let (n, d) = (m.rows(), m.cols());
    let mut out = m.clone();
    for c in 0..d {
        let mut r = 0;
        while r < n {
            if out[(r, c)].is_nan() {
                // find gap [r, e)
                let mut e = r;
                while e < n && out[(e, c)].is_nan() {
                    e += 1;
                }
                if r > 0 && e < n {
                    let lo = out[(r - 1, c)];
                    let hi = out[(e, c)];
                    let span = (e - r + 1) as f64;
                    for (k, rr) in (r..e).enumerate() {
                        out[(rr, c)] = lo + (hi - lo) * (k + 1) as f64 / span;
                    }
                }
                r = e;
            } else {
                r += 1;
            }
        }
    }
    out
}

/// Drop columns still containing NaN after interpolation (the paper's
/// "removing indices with any remaining missing values"). Returns the
/// retained column indices and the filtered matrix.
pub fn drop_nan_columns(m: &Mat) -> (Vec<usize>, Mat) {
    let keep: Vec<usize> = (0..m.cols())
        .filter(|&c| (0..m.rows()).all(|r| !m[(r, c)].is_nan()))
        .collect();
    let filtered = m.select_cols(&keep);
    (keep, filtered)
}

/// First differencing: out[t] = x[t+1] − x[t]; length shrinks by one.
pub fn first_difference(m: &Mat) -> Mat {
    let (n, d) = (m.rows(), m.cols());
    assert!(n >= 2);
    Mat::from_fn(n - 1, d, |t, c| m[(t + 1, c)] - m[(t, c)])
}

/// Log transform then first-difference (log-returns).
pub fn log_returns(prices: &Mat) -> Mat {
    first_difference(&prices.map(|p| p.ln()))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn csv_roundtrip() {
        let m = Mat::from_rows(&[&[1.0, 2.5], &[f64::NAN, -3.0]]);
        let dir = std::env::temp_dir().join("alingam_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("t.csv");
        write_csv(&p, &["a".into(), "b".into()], &m).unwrap();
        let (h, back) = read_csv(&p).unwrap();
        assert_eq!(h, vec!["a", "b"]);
        assert_eq!(back[(0, 1)], 2.5);
        assert!(back[(1, 0)].is_nan());
        assert_eq!(back[(1, 1)], -3.0);
    }

    #[test]
    fn interpolation_fills_interior_gaps() {
        let m = Mat::from_vec(5, 1, vec![1.0, f64::NAN, f64::NAN, 4.0, 5.0]).unwrap();
        let out = interpolate_columns(&m);
        assert!((out[(1, 0)] - 2.0).abs() < 1e-12);
        assert!((out[(2, 0)] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn interpolation_leaves_edge_gaps() {
        let m = Mat::from_vec(4, 1, vec![f64::NAN, 2.0, 3.0, f64::NAN]).unwrap();
        let out = interpolate_columns(&m);
        assert!(out[(0, 0)].is_nan());
        assert!(out[(3, 0)].is_nan());
    }

    #[test]
    fn drop_nan_cols_filters() {
        let m = Mat::from_rows(&[&[1.0, f64::NAN, 3.0], &[4.0, 5.0, 6.0]]);
        let (keep, f) = drop_nan_columns(&m);
        assert_eq!(keep, vec![0, 2]);
        assert_eq!(f.cols(), 2);
        assert_eq!(f[(0, 1)], 3.0);
    }

    #[test]
    fn differencing_makes_random_walk_stationary() {
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::seed_from_u64(1);
        let mut p = 0.0;
        let walk: Vec<f64> = (0..2000)
            .map(|_| {
                p += rng.normal();
                p
            })
            .collect();
        let m = Mat::from_vec(2000, 1, walk).unwrap();
        let d = first_difference(&m);
        assert_eq!(d.rows(), 1999);
        // differenced series ~ N(0,1): variance near 1
        let col = d.col(0);
        let v = crate::stats::var(&col);
        assert!((v - 1.0).abs() < 0.15, "var={v}");
    }

    #[test]
    fn log_returns_shape() {
        let m = Mat::from_rows(&[&[100.0], &[110.0], &[99.0]]);
        let r = log_returns(&m);
        assert_eq!(r.rows(), 2);
        assert!((r[(0, 0)] - (110.0f64 / 100.0).ln()).abs() < 1e-12);
    }
}
