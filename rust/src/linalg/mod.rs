//! Dense linear-algebra substrate, built from scratch (no ndarray/BLAS in
//! the offline crate set).
//!
//! Provides what the LiNGAM stack and its baselines need: matmul, LU
//! solves, Cholesky, least squares, matrix exponential (NOTEARS'
//! acyclicity function), and the usual element-wise operations.

mod mat;
mod decomp;
mod expm;
pub mod eigh;
pub mod assignment;

pub use decomp::{cholesky, lstsq, lu_inverse, lu_solve, ridge_solve};
pub use eigh::{eigh, whitening_matrix};
pub use expm::expm;
pub use mat::Mat;
