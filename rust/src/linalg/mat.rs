//! Row-major dense matrix.

use crate::util::{Error, Result};

/// Row-major dense `f64` matrix.
///
/// Datasets use the convention `[n_samples, n_vars]` (samples are rows),
/// matching the paper's `X[m, dim]`.
#[derive(Clone, Debug, PartialEq)]
pub struct Mat {
    rows: usize,
    cols: usize,
    data: Vec<f64>,
}

impl Mat {
    /// Zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Mat {
        Mat { rows, cols, data: vec![0.0; rows * cols] }
    }

    /// Identity.
    pub fn eye(n: usize) -> Mat {
        let mut m = Mat::zeros(n, n);
        for i in 0..n {
            m[(i, i)] = 1.0;
        }
        m
    }

    /// Build from a generator `f(row, col)`.
    pub fn from_fn(rows: usize, cols: usize, mut f: impl FnMut(usize, usize) -> f64) -> Mat {
        let mut data = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            for c in 0..cols {
                data.push(f(r, c));
            }
        }
        Mat { rows, cols, data }
    }

    /// Wrap an existing row-major buffer.
    pub fn from_vec(rows: usize, cols: usize, data: Vec<f64>) -> Result<Mat> {
        if data.len() != rows * cols {
            return Err(Error::Shape(format!(
                "buffer len {} != {}x{}",
                data.len(),
                rows,
                cols
            )));
        }
        Ok(Mat { rows, cols, data })
    }

    /// Build from rows of slices (for tests).
    pub fn from_rows(rows: &[&[f64]]) -> Mat {
        let r = rows.len();
        let c = rows.first().map(|x| x.len()).unwrap_or(0);
        let mut data = Vec::with_capacity(r * c);
        for row in rows {
            assert_eq!(row.len(), c, "ragged rows");
            data.extend_from_slice(row);
        }
        Mat { rows: r, cols: c, data }
    }

    pub fn rows(&self) -> usize {
        self.rows
    }

    pub fn cols(&self) -> usize {
        self.cols
    }

    /// Raw row-major buffer.
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Raw mutable buffer.
    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// Borrow row `r` as a slice.
    #[inline]
    pub fn row(&self, r: usize) -> &[f64] {
        &self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Mutable row slice.
    #[inline]
    pub fn row_mut(&mut self, r: usize) -> &mut [f64] {
        &mut self.data[r * self.cols..(r + 1) * self.cols]
    }

    /// Copy column `c` out.
    pub fn col(&self, c: usize) -> Vec<f64> {
        (0..self.rows).map(|r| self[(r, c)]).collect()
    }

    /// Overwrite column `c`.
    pub fn set_col(&mut self, c: usize, v: &[f64]) {
        assert_eq!(v.len(), self.rows);
        for r in 0..self.rows {
            self[(r, c)] = v[r];
        }
    }

    /// Transpose.
    pub fn t(&self) -> Mat {
        Mat::from_fn(self.cols, self.rows, |r, c| self[(c, r)])
    }

    /// Matrix product `self * rhs` (blocked i-k-j loop order: the inner
    /// loop runs along contiguous rows of both `rhs` and the output).
    pub fn matmul(&self, rhs: &Mat) -> Mat {
        assert_eq!(self.cols, rhs.rows, "matmul shape mismatch");
        let (m, k, n) = (self.rows, self.cols, rhs.cols);
        let mut out = Mat::zeros(m, n);
        for i in 0..m {
            let a_row = self.row(i);
            let out_row = &mut out.data[i * n..(i + 1) * n];
            for (kk, &a) in a_row.iter().enumerate().take(k) {
                if a == 0.0 {
                    continue;
                }
                let b_row = &rhs.data[kk * n..(kk + 1) * n];
                for j in 0..n {
                    out_row[j] += a * b_row[j];
                }
            }
        }
        out
    }

    /// Matrix-vector product.
    pub fn matvec(&self, v: &[f64]) -> Vec<f64> {
        assert_eq!(self.cols, v.len());
        (0..self.rows)
            .map(|i| self.row(i).iter().zip(v).map(|(a, b)| a * b).sum())
            .collect()
    }

    /// Element-wise map.
    pub fn map(&self, f: impl Fn(f64) -> f64) -> Mat {
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().map(|&x| f(x)).collect(),
        }
    }

    /// Element-wise combine.
    pub fn zip(&self, other: &Mat, f: impl Fn(f64, f64) -> f64) -> Mat {
        assert_eq!((self.rows, self.cols), (other.rows, other.cols));
        Mat {
            rows: self.rows,
            cols: self.cols,
            data: self.data.iter().zip(&other.data).map(|(&a, &b)| f(a, b)).collect(),
        }
    }

    /// `self + other`.
    pub fn add(&self, other: &Mat) -> Mat {
        self.zip(other, |a, b| a + b)
    }

    /// `self - other`.
    pub fn sub(&self, other: &Mat) -> Mat {
        self.zip(other, |a, b| a - b)
    }

    /// `self * s` (scalar).
    pub fn scale(&self, s: f64) -> Mat {
        self.map(|x| x * s)
    }

    /// Hadamard product.
    pub fn hadamard(&self, other: &Mat) -> Mat {
        self.zip(other, |a, b| a * b)
    }

    /// Frobenius norm.
    pub fn fro_norm(&self) -> f64 {
        self.data.iter().map(|x| x * x).sum::<f64>().sqrt()
    }

    /// Max |entry|.
    pub fn max_abs(&self) -> f64 {
        self.data.iter().fold(0.0_f64, |m, &x| m.max(x.abs()))
    }

    /// Trace (square only).
    pub fn trace(&self) -> f64 {
        assert_eq!(self.rows, self.cols);
        (0..self.rows).map(|i| self[(i, i)]).sum()
    }

    /// Sum of all entries.
    pub fn sum(&self) -> f64 {
        self.data.iter().sum()
    }

    /// All entries finite?
    pub fn is_finite(&self) -> bool {
        self.data.iter().all(|x| x.is_finite())
    }

    /// Select a subset of columns (in the given order).
    pub fn select_cols(&self, idx: &[usize]) -> Mat {
        Mat::from_fn(self.rows, idx.len(), |r, c| self[(r, idx[c])])
    }

    /// Select a subset of rows (in the given order).
    pub fn select_rows(&self, idx: &[usize]) -> Mat {
        Mat::from_fn(idx.len(), self.cols, |r, c| self[(idx[r], c)])
    }

    /// Convert to f32 (for PJRT transfer).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }
}

impl std::ops::Index<(usize, usize)> for Mat {
    type Output = f64;
    #[inline]
    fn index(&self, (r, c): (usize, usize)) -> &f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &self.data[r * self.cols + c]
    }
}

impl std::ops::IndexMut<(usize, usize)> for Mat {
    #[inline]
    fn index_mut(&mut self, (r, c): (usize, usize)) -> &mut f64 {
        debug_assert!(r < self.rows && c < self.cols);
        &mut self.data[r * self.cols + c]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn matmul_known() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[3.0, 4.0]]);
        let b = Mat::from_rows(&[&[5.0, 6.0], &[7.0, 8.0]]);
        let c = a.matmul(&b);
        assert_eq!(c, Mat::from_rows(&[&[19.0, 22.0], &[43.0, 50.0]]));
    }

    #[test]
    fn matmul_identity() {
        let a = Mat::from_fn(4, 4, |r, c| (r * 4 + c) as f64);
        assert_eq!(a.matmul(&Mat::eye(4)), a);
        assert_eq!(Mat::eye(4).matmul(&a), a);
    }

    #[test]
    fn transpose_involution() {
        let a = Mat::from_fn(3, 5, |r, c| (r + 7 * c) as f64);
        assert_eq!(a.t().t(), a);
    }

    #[test]
    fn col_roundtrip() {
        let mut a = Mat::zeros(3, 2);
        a.set_col(1, &[1.0, 2.0, 3.0]);
        assert_eq!(a.col(1), vec![1.0, 2.0, 3.0]);
        assert_eq!(a.col(0), vec![0.0; 3]);
    }

    #[test]
    fn matvec_matches_matmul() {
        let a = Mat::from_fn(3, 4, |r, c| (r * c + 1) as f64);
        let v = vec![1.0, -1.0, 0.5, 2.0];
        let via_mat = a.matmul(&Mat::from_vec(4, 1, v.clone()).unwrap());
        assert_eq!(a.matvec(&v), via_mat.col(0));
    }

    #[test]
    fn select_cols_order() {
        let a = Mat::from_rows(&[&[1.0, 2.0, 3.0], &[4.0, 5.0, 6.0]]);
        let s = a.select_cols(&[2, 0]);
        assert_eq!(s, Mat::from_rows(&[&[3.0, 1.0], &[6.0, 4.0]]));
    }

    #[test]
    fn fro_and_trace() {
        let a = Mat::from_rows(&[&[3.0, 0.0], &[4.0, 1.0]]);
        assert!((a.fro_norm() - (26.0_f64).sqrt()).abs() < 1e-12);
        assert_eq!(a.trace(), 4.0);
    }

    #[test]
    fn from_vec_shape_check() {
        assert!(Mat::from_vec(2, 2, vec![0.0; 3]).is_err());
    }
}
