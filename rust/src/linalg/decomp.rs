//! Factorizations and solvers: LU with partial pivoting, Cholesky, least
//! squares (normal equations with ridge fallback).

use super::Mat;
use crate::util::{Error, Result};

/// LU decomposition with partial pivoting, stored in-place.
struct Lu {
    lu: Mat,
    piv: Vec<usize>,
}

fn lu_factor(a: &Mat) -> Result<Lu> {
    let n = a.rows();
    if a.cols() != n {
        return Err(Error::Shape(format!("LU needs square, got {}x{}", a.rows(), a.cols())));
    }
    let mut lu = a.clone();
    let mut piv: Vec<usize> = (0..n).collect();
    for k in 0..n {
        // pivot search
        let mut p = k;
        let mut max = lu[(k, k)].abs();
        for i in (k + 1)..n {
            let v = lu[(i, k)].abs();
            if v > max {
                max = v;
                p = i;
            }
        }
        if max < 1e-300 {
            return Err(Error::Numerical(format!("singular matrix at pivot {k}")));
        }
        if p != k {
            piv.swap(p, k);
            for j in 0..n {
                let tmp = lu[(k, j)];
                lu[(k, j)] = lu[(p, j)];
                lu[(p, j)] = tmp;
            }
        }
        let pivot = lu[(k, k)];
        for i in (k + 1)..n {
            let m = lu[(i, k)] / pivot;
            lu[(i, k)] = m;
            for j in (k + 1)..n {
                let sub = m * lu[(k, j)];
                lu[(i, j)] -= sub;
            }
        }
    }
    Ok(Lu { lu, piv })
}

fn lu_solve_one(f: &Lu, b: &[f64]) -> Vec<f64> {
    let n = f.lu.rows();
    // apply permutation
    let mut y: Vec<f64> = f.piv.iter().map(|&p| b[p]).collect();
    // forward substitution (unit lower)
    for i in 1..n {
        for j in 0..i {
            y[i] -= f.lu[(i, j)] * y[j];
        }
    }
    // back substitution
    for i in (0..n).rev() {
        for j in (i + 1)..n {
            y[i] -= f.lu[(i, j)] * y[j];
        }
        y[i] /= f.lu[(i, i)];
    }
    y
}

/// Solve `A x = b` for one or more right-hand sides (columns of `b`).
pub fn lu_solve(a: &Mat, b: &Mat) -> Result<Mat> {
    if a.rows() != b.rows() {
        return Err(Error::Shape(format!("solve: A is {}x{}, b has {} rows", a.rows(), a.cols(), b.rows())));
    }
    let f = lu_factor(a)?;
    let mut out = Mat::zeros(b.rows(), b.cols());
    for c in 0..b.cols() {
        let x = lu_solve_one(&f, &b.col(c));
        out.set_col(c, &x);
    }
    Ok(out)
}

/// Matrix inverse via LU.
pub fn lu_inverse(a: &Mat) -> Result<Mat> {
    lu_solve(a, &Mat::eye(a.rows()))
}

/// Cholesky factor L (lower) of a symmetric positive-definite matrix.
pub fn cholesky(a: &Mat) -> Result<Mat> {
    let n = a.rows();
    if a.cols() != n {
        return Err(Error::Shape("cholesky needs square".into()));
    }
    let mut l = Mat::zeros(n, n);
    for i in 0..n {
        for j in 0..=i {
            let mut s = a[(i, j)];
            for k in 0..j {
                s -= l[(i, k)] * l[(j, k)];
            }
            if i == j {
                if s <= 0.0 {
                    return Err(Error::Numerical(format!("not positive definite at {i} (s={s})")));
                }
                l[(i, j)] = s.sqrt();
            } else {
                l[(i, j)] = s / l[(j, j)];
            }
        }
    }
    Ok(l)
}

/// Ordinary least squares: minimize ‖A x − b‖² via normal equations
/// `AᵀA x = Aᵀ b`, with a tiny ridge jitter retry if AᵀA is singular.
pub fn lstsq(a: &Mat, b: &Mat) -> Result<Mat> {
    ridge_solve(a, b, 0.0)
}

/// Ridge regression: `(AᵀA + λI) x = Aᵀ b`.
pub fn ridge_solve(a: &Mat, b: &Mat, lambda: f64) -> Result<Mat> {
    let at = a.t();
    let mut ata = at.matmul(a);
    let atb = at.matmul(b);
    if lambda > 0.0 {
        for i in 0..ata.rows() {
            ata[(i, i)] += lambda;
        }
    }
    match lu_solve(&ata, &atb) {
        Ok(x) => Ok(x),
        Err(_) if lambda == 0.0 => {
            // singular normal equations: retry with jitter proportional to scale
            let jitter = 1e-10 * (1.0 + ata.trace().abs() / ata.rows() as f64);
            for i in 0..ata.rows() {
                ata[(i, i)] += jitter;
            }
            lu_solve(&ata, &atb)
        }
        Err(e) => Err(e),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn assert_close(a: &Mat, b: &Mat, tol: f64) {
        assert_eq!((a.rows(), a.cols()), (b.rows(), b.cols()));
        let d = a.sub(b).max_abs();
        assert!(d < tol, "max abs diff {d}");
    }

    #[test]
    fn solve_known_system() {
        let a = Mat::from_rows(&[&[2.0, 1.0], &[1.0, 3.0]]);
        let b = Mat::from_vec(2, 1, vec![5.0, 10.0]).unwrap();
        let x = lu_solve(&a, &b).unwrap();
        // 2x + y = 5; x + 3y = 10 → x = 1, y = 3
        assert!((x[(0, 0)] - 1.0).abs() < 1e-12);
        assert!((x[(1, 0)] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn inverse_roundtrip() {
        let a = Mat::from_rows(&[&[4.0, 2.0, 0.5], &[2.0, 5.0, 1.0], &[0.5, 1.0, 3.0]]);
        let inv = lu_inverse(&a).unwrap();
        assert_close(&a.matmul(&inv), &Mat::eye(3), 1e-10);
    }

    #[test]
    fn singular_detected() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 4.0]]);
        assert!(lu_inverse(&a).is_err());
    }

    #[test]
    fn pivoting_handles_zero_diagonal() {
        let a = Mat::from_rows(&[&[0.0, 1.0], &[1.0, 0.0]]);
        let x = lu_solve(&a, &Mat::from_vec(2, 1, vec![3.0, 7.0]).unwrap()).unwrap();
        assert_eq!(x.col(0), vec![7.0, 3.0]);
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = Mat::from_rows(&[&[4.0, 2.0], &[2.0, 3.0]]);
        let l = cholesky(&a).unwrap();
        assert_close(&l.matmul(&l.t()), &a, 1e-12);
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[2.0, 1.0]]);
        assert!(cholesky(&a).is_err());
    }

    #[test]
    fn lstsq_recovers_coefficients() {
        // y = 2 x0 - 3 x1, overdetermined
        let a = Mat::from_fn(50, 2, |r, c| ((r * (c + 1) * 37 + 11) % 17) as f64 / 17.0);
        let truth = Mat::from_vec(2, 1, vec![2.0, -3.0]).unwrap();
        let b = a.matmul(&truth);
        let x = lstsq(&a, &b).unwrap();
        assert_close(&x, &truth, 1e-8);
    }

    #[test]
    fn ridge_shrinks() {
        let a = Mat::from_fn(30, 2, |r, c| ((r + c * 13) % 7) as f64);
        let b = Mat::from_fn(30, 1, |r, _| (r % 5) as f64);
        let x0 = lstsq(&a, &b).unwrap();
        let x1 = ridge_solve(&a, &b, 100.0).unwrap();
        assert!(x1.fro_norm() < x0.fro_norm());
    }
}
