//! Matrix exponential via scaling-and-squaring with a Padé(6,6)
//! approximant. Needed by the NOTEARS baseline's acyclicity function
//! `h(W) = tr(exp(W∘W)) − d` and its gradient `exp(W∘W)ᵀ ∘ 2W`.

use super::{lu_solve, Mat};
use crate::util::Result;

/// `exp(A)` for square `A`.
pub fn expm(a: &Mat) -> Result<Mat> {
    let n = a.rows();
    assert_eq!(n, a.cols(), "expm needs square");

    // Scale A down so ‖A/2^s‖∞ ≤ 0.5, apply Padé, square back up.
    let norm = inf_norm(a);
    let s = if norm > 0.5 { (norm / 0.5).log2().ceil() as i32 } else { 0 };
    let a_scaled = a.scale(0.5_f64.powi(s));

    // Padé(6,6): N = Σ c_k A^k, D = Σ (−1)^k c_k A^k, exp ≈ D⁻¹N.
    const C: [f64; 7] = [
        1.0,
        0.5,
        5.0 / 44.0,
        1.0 / 66.0,
        1.0 / 792.0,
        1.0 / 15840.0,
        1.0 / 665280.0,
    ];
    let mut term = Mat::eye(n); // A^0
    let mut num = Mat::eye(n); // c0 * I
    let mut den = Mat::eye(n);
    for (k, &c) in C.iter().enumerate().skip(1) {
        term = term.matmul(&a_scaled);
        num = num.add(&term.scale(c));
        let sign = if k % 2 == 0 { 1.0 } else { -1.0 };
        den = den.add(&term.scale(sign * c));
    }
    let mut e = lu_solve(&den, &num)?;
    for _ in 0..s {
        e = e.matmul(&e);
    }
    Ok(e)
}

fn inf_norm(a: &Mat) -> f64 {
    (0..a.rows())
        .map(|r| a.row(r).iter().map(|x| x.abs()).sum::<f64>())
        .fold(0.0, f64::max)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exp_zero_is_identity() {
        let e = expm(&Mat::zeros(4, 4)).unwrap();
        assert!(e.sub(&Mat::eye(4)).max_abs() < 1e-14);
    }

    #[test]
    fn exp_diagonal() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 1.0;
        a[(1, 1)] = -2.0;
        a[(2, 2)] = 0.5;
        let e = expm(&a).unwrap();
        assert!((e[(0, 0)] - 1.0_f64.exp()).abs() < 1e-10);
        assert!((e[(1, 1)] - (-2.0_f64).exp()).abs() < 1e-10);
        assert!((e[(2, 2)] - 0.5_f64.exp()).abs() < 1e-10);
        assert!(e[(0, 1)].abs() < 1e-12);
    }

    #[test]
    fn exp_nilpotent_exact() {
        // strictly upper triangular (DAG-like): series terminates.
        let mut a = Mat::zeros(3, 3);
        a[(0, 1)] = 2.0;
        a[(1, 2)] = 3.0;
        let e = expm(&a).unwrap();
        // exp = I + A + A²/2; A² has only (0,2)=6
        assert!((e[(0, 1)] - 2.0).abs() < 1e-12);
        assert!((e[(1, 2)] - 3.0).abs() < 1e-12);
        assert!((e[(0, 2)] - 3.0).abs() < 1e-12);
        assert!((e.trace() - 3.0).abs() < 1e-12);
    }

    #[test]
    fn exp_additivity_commuting() {
        // exp(A)·exp(A) = exp(2A)
        let a = Mat::from_rows(&[&[0.1, 0.3], &[-0.2, 0.05]]);
        let e1 = expm(&a).unwrap();
        let e2 = expm(&a.scale(2.0)).unwrap();
        assert!(e1.matmul(&e1).sub(&e2).max_abs() < 1e-10);
    }

    #[test]
    fn large_norm_scaled_correctly() {
        let a = Mat::from_rows(&[&[5.0, 1.0], &[0.0, 5.0]]);
        let e = expm(&a).unwrap();
        // analytic: exp([[5,1],[0,5]]) = e^5 [[1,1],[0,1]]
        let e5 = 5.0_f64.exp();
        assert!((e[(0, 0)] - e5).abs() / e5 < 1e-9);
        assert!((e[(0, 1)] - e5).abs() / e5 < 1e-9);
        assert!(e[(1, 0)].abs() < 1e-9);
    }
}
