//! Hungarian algorithm (Kuhn–Munkres, O(n³)) for the linear assignment
//! problem — ICA-LiNGAM permutes the unmixing matrix's rows to put the
//! dominant entries on the diagonal, which is exactly a min-cost
//! assignment on `1/|W_ij|` (the reference package uses munkres too).

use super::Mat;

/// Minimum-cost assignment: returns `perm` with `perm[row] = column`,
/// minimizing `Σ cost[(row, perm[row])]`. Costs may be any finite f64.
pub fn hungarian(cost: &Mat) -> Vec<usize> {
    let n = cost.rows();
    assert_eq!(n, cost.cols(), "assignment needs square cost");
    if n == 0 {
        return Vec::new();
    }
    // O(n³) shortest-augmenting-path formulation (1-indexed internals).
    let inf = f64::INFINITY;
    let mut u = vec![0.0; n + 1];
    let mut v = vec![0.0; n + 1];
    let mut p = vec![0usize; n + 1]; // p[col] = row matched to col
    let mut way = vec![0usize; n + 1];
    for i in 1..=n {
        p[0] = i;
        let mut j0 = 0usize;
        let mut minv = vec![inf; n + 1];
        let mut used = vec![false; n + 1];
        loop {
            used[j0] = true;
            let i0 = p[j0];
            let mut delta = inf;
            let mut j1 = 0usize;
            for j in 1..=n {
                if used[j] {
                    continue;
                }
                let cur = cost[(i0 - 1, j - 1)] - u[i0] - v[j];
                if cur < minv[j] {
                    minv[j] = cur;
                    way[j] = j0;
                }
                if minv[j] < delta {
                    delta = minv[j];
                    j1 = j;
                }
            }
            for j in 0..=n {
                if used[j] {
                    u[p[j]] += delta;
                    v[j] -= delta;
                } else {
                    minv[j] -= delta;
                }
            }
            j0 = j1;
            if p[j0] == 0 {
                break;
            }
        }
        loop {
            let j1 = way[j0];
            p[j0] = p[j1];
            j0 = j1;
            if j0 == 0 {
                break;
            }
        }
    }
    let mut perm = vec![0usize; n];
    for j in 1..=n {
        if p[j] > 0 {
            perm[p[j] - 1] = j - 1;
        }
    }
    perm
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn total(cost: &Mat, perm: &[usize]) -> f64 {
        perm.iter().enumerate().map(|(r, &c)| cost[(r, c)]).sum()
    }

    #[test]
    fn identity_when_diagonal_cheapest() {
        let cost = Mat::from_rows(&[&[0.0, 9.0, 9.0], &[9.0, 0.0, 9.0], &[9.0, 9.0, 0.0]]);
        assert_eq!(hungarian(&cost), vec![0, 1, 2]);
    }

    #[test]
    fn known_3x3() {
        // classic example: optimal = 1+2+2 = 5 via (0→1, 1→0, 2→2)? check
        let cost = Mat::from_rows(&[&[4.0, 1.0, 3.0], &[2.0, 0.0, 5.0], &[3.0, 2.0, 2.0]]);
        let perm = hungarian(&cost);
        assert_eq!(total(&cost, &perm), 5.0, "perm={perm:?}");
    }

    #[test]
    fn beats_or_matches_every_permutation_bruteforce() {
        let mut rng = Pcg64::seed_from_u64(3);
        for _case in 0..50 {
            let n = 2 + rng.below(4); // 2..=5
            let cost = Mat::from_fn(n, n, |_, _| rng.uniform(0.0, 10.0));
            let perm = hungarian(&cost);
            // validate it is a permutation
            let mut seen = vec![false; n];
            for &c in &perm {
                assert!(!seen[c]);
                seen[c] = true;
            }
            let best = brute_force_min(&cost);
            let got = total(&cost, &perm);
            assert!(got <= best + 1e-9, "hungarian {got} > brute {best}");
        }
    }

    fn brute_force_min(cost: &Mat) -> f64 {
        let n = cost.rows();
        let mut perm: Vec<usize> = (0..n).collect();
        let mut best = f64::INFINITY;
        permute(&mut perm, 0, &mut |p| {
            let t = p.iter().enumerate().map(|(r, &c)| cost[(r, c)]).sum::<f64>();
            if t < best {
                best = t;
            }
        });
        best
    }

    fn permute(xs: &mut Vec<usize>, k: usize, f: &mut impl FnMut(&[usize])) {
        if k == xs.len() {
            f(xs);
            return;
        }
        for i in k..xs.len() {
            xs.swap(k, i);
            permute(xs, k + 1, f);
            xs.swap(k, i);
        }
    }

    #[test]
    fn negative_costs_ok() {
        let cost = Mat::from_rows(&[&[-5.0, 0.0], &[0.0, -5.0]]);
        assert_eq!(hungarian(&cost), vec![0, 1]);
    }
}
