//! Symmetric eigendecomposition via the cyclic Jacobi method — the
//! whitening step of FastICA (ICA-LiNGAM) needs the eigensystem of the
//! covariance matrix.

use super::Mat;
use crate::util::{Error, Result};

/// Eigendecomposition of a symmetric matrix: `a = V diag(λ) Vᵀ`.
/// Returns (eigenvalues ascending, eigenvectors as columns of V).
pub fn eigh(a: &Mat) -> Result<(Vec<f64>, Mat)> {
    let n = a.rows();
    if a.cols() != n {
        return Err(Error::Shape("eigh needs square".into()));
    }
    let sym_err = (0..n)
        .flat_map(|i| (0..n).map(move |j| (i, j)))
        .map(|(i, j)| (a[(i, j)] - a[(j, i)]).abs())
        .fold(0.0, f64::max);
    if sym_err > 1e-8 * (1.0 + a.max_abs()) {
        return Err(Error::InvalidArgument(format!("matrix not symmetric (err {sym_err})")));
    }

    let mut m = a.clone();
    let mut v = Mat::eye(n);
    // cyclic Jacobi sweeps
    for _sweep in 0..100 {
        let mut off = 0.0;
        for p in 0..n {
            for q in (p + 1)..n {
                off += m[(p, q)] * m[(p, q)];
            }
        }
        if off < 1e-22 * (1.0 + m.max_abs()).powi(2) {
            break;
        }
        for p in 0..n {
            for q in (p + 1)..n {
                let apq = m[(p, q)];
                if apq.abs() < 1e-300 {
                    continue;
                }
                let app = m[(p, p)];
                let aqq = m[(q, q)];
                let theta = 0.5 * (aqq - app) / apq;
                let t = theta.signum() / (theta.abs() + (theta * theta + 1.0).sqrt());
                let c = 1.0 / (t * t + 1.0).sqrt();
                let s = t * c;
                // rotate rows/cols p, q of m
                for k in 0..n {
                    let mkp = m[(k, p)];
                    let mkq = m[(k, q)];
                    m[(k, p)] = c * mkp - s * mkq;
                    m[(k, q)] = s * mkp + c * mkq;
                }
                for k in 0..n {
                    let mpk = m[(p, k)];
                    let mqk = m[(q, k)];
                    m[(p, k)] = c * mpk - s * mqk;
                    m[(q, k)] = s * mpk + c * mqk;
                }
                // accumulate eigenvectors
                for k in 0..n {
                    let vkp = v[(k, p)];
                    let vkq = v[(k, q)];
                    v[(k, p)] = c * vkp - s * vkq;
                    v[(k, q)] = s * vkp + c * vkq;
                }
            }
        }
    }
    // sort ascending
    let mut idx: Vec<usize> = (0..n).collect();
    let evals: Vec<f64> = (0..n).map(|i| m[(i, i)]).collect();
    idx.sort_by(|&i, &j| evals[i].partial_cmp(&evals[j]).unwrap());
    let sorted: Vec<f64> = idx.iter().map(|&i| evals[i]).collect();
    let vs = Mat::from_fn(n, n, |r, c| v[(r, idx[c])]);
    Ok((sorted, vs))
}

/// Whitening transform `K` such that `K Σ Kᵀ = I`, from the covariance
/// eigensystem (drops directions with eigenvalue below `eps` — the
/// FastICA pre-processing step).
pub fn whitening_matrix(cov: &Mat, eps: f64) -> Result<Mat> {
    let (evals, v) = eigh(cov)?;
    let n = cov.rows();
    let kept: Vec<usize> = (0..n).filter(|&i| evals[i] > eps).collect();
    if kept.is_empty() {
        return Err(Error::Numerical("covariance has no positive eigenvalues".into()));
    }
    // K = Λ^{-1/2} Vᵀ (rows = kept components)
    Ok(Mat::from_fn(kept.len(), n, |r, c| {
        v[(c, kept[r])] / evals[kept[r]].sqrt()
    }))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn diagonal_matrix_eigenvalues() {
        let mut a = Mat::zeros(3, 3);
        a[(0, 0)] = 3.0;
        a[(1, 1)] = 1.0;
        a[(2, 2)] = 2.0;
        let (e, _) = eigh(&a).unwrap();
        assert!((e[0] - 1.0).abs() < 1e-12);
        assert!((e[1] - 2.0).abs() < 1e-12);
        assert!((e[2] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn reconstructs_symmetric() {
        let b = Mat::from_fn(4, 4, |r, c| ((r * 3 + c * 7) % 11) as f64 / 11.0);
        let a = b.add(&b.t()); // symmetric
        let (e, v) = eigh(&a).unwrap();
        // A = V diag(e) Vᵀ
        let lam = Mat::from_fn(4, 4, |r, c| if r == c { e[r] } else { 0.0 });
        let rec = v.matmul(&lam).matmul(&v.t());
        assert!(rec.sub(&a).max_abs() < 1e-9, "reconstruction error");
        // V orthogonal
        assert!(v.t().matmul(&v).sub(&Mat::eye(4)).max_abs() < 1e-9);
    }

    #[test]
    fn known_2x2() {
        // [[2,1],[1,2]] → eigenvalues 1, 3
        let a = Mat::from_rows(&[&[2.0, 1.0], &[1.0, 2.0]]);
        let (e, _) = eigh(&a).unwrap();
        assert!((e[0] - 1.0).abs() < 1e-12 && (e[1] - 3.0).abs() < 1e-12);
    }

    #[test]
    fn rejects_asymmetric() {
        let a = Mat::from_rows(&[&[1.0, 2.0], &[0.0, 1.0]]);
        assert!(eigh(&a).is_err());
    }

    #[test]
    fn whitening_whitens() {
        // random SPD covariance
        let b = Mat::from_fn(3, 5, |r, c| ((r * 5 + c * 3 + 1) % 7) as f64 - 3.0);
        let cov = b.matmul(&b.t()).scale(0.2).add(&Mat::eye(3).scale(0.1));
        let k = whitening_matrix(&cov, 1e-12).unwrap();
        let w = k.matmul(&cov).matmul(&k.t());
        assert!(w.sub(&Mat::eye(3)).max_abs() < 1e-9, "K Σ Kᵀ != I");
    }
}
