//! Interventional evaluation — Table 1's I-NLL / I-MAE metrics.
//!
//! Mirrors the paper's §4.1 protocol: the discovered weighted adjacency
//! defines a Bayesian linear SEM (edge weights and biases get N(0,1)
//! priors; variables with no outgoing edges are leaves, everything else
//! is a latent node); Stein VI draws posterior samples; held-out
//! interventions are scored by
//!
//! - **I-NLL**: negative log-likelihood of the held-out cells under the
//!   posterior-mixture predictive, with the intervened gene clamped
//!   (do-operator) and means propagated through the graph, and
//! - **I-MAE**: mean absolute error of the posterior-mean prediction.

use super::svgd::{LogDensity, Svgd, SvgdOpts};
use crate::linalg::{lstsq, lu_inverse, Mat};
use crate::util::{Error, Result};

/// Fixed noise-scale floor (avoids degenerate NLL when a gene is nearly
/// deterministic in the training set).
const SIGMA_FLOOR: f64 = 0.05;

/// Result of an interventional evaluation.
#[derive(Debug, Clone, Copy)]
pub struct IntervMetrics {
    /// Interventional negative log-likelihood (nats, per gene per cell).
    pub nll: f64,
    /// Interventional mean absolute error.
    pub mae: f64,
    /// Held-out cells scored.
    pub cells: usize,
}

/// Bayesian linear SEM with fixed structure, conditional-likelihood form:
/// θ = (edge weights, biases), x_i | parents ~ N(b_i + Σ θ_e x_par, σ_i²).
pub struct SemPosterior {
    /// (child, parent) per edge; θ[..edges.len()] are the edge weights.
    edges: Vec<(usize, usize)>,
    /// Genes (θ[edges.len()..] are per-gene biases).
    d: usize,
    /// Fixed per-gene noise scales (OLS residual std on training data).
    sigma: Vec<f64>,
    /// Training design (subsampled rows).
    train: Mat,
    /// Per-row intervention target (likelihood term of the target gene is
    /// dropped: the do-operator severs its structural equation).
    targets: Vec<Option<usize>>,
    /// Likelihood tempering 1/n (keeps the posterior from collapsing to a
    /// point at gene-data scale, matching VI-with-minibatch behaviour).
    like_scale: f64,
}

impl SemPosterior {
    /// Build from a discovered adjacency and training cells.
    ///
    /// `train_targets[r]` is the intervened gene of row r (None =
    /// observational). Rows are subsampled to at most `max_rows`.
    pub fn new(
        adjacency: &Mat,
        train: &Mat,
        train_targets: &[Option<usize>],
        max_rows: usize,
    ) -> Result<SemPosterior> {
        let d = adjacency.rows();
        if train.cols() != d || train.rows() != train_targets.len() {
            return Err(Error::Shape("train data vs adjacency mismatch".into()));
        }
        let mut edges = Vec::new();
        for i in 0..d {
            for j in 0..d {
                if adjacency[(i, j)] != 0.0 {
                    edges.push((i, j));
                }
            }
        }
        // deterministic stride subsample
        let n = train.rows();
        let keep: Vec<usize> = if n <= max_rows {
            (0..n).collect()
        } else {
            (0..max_rows).map(|k| k * n / max_rows).collect()
        };
        let sub = train.select_rows(&keep);
        let sub_targets: Vec<Option<usize>> = keep.iter().map(|&r| train_targets[r]).collect();

        let sigma = estimate_sigmas(adjacency, &sub, &sub_targets);
        let like_scale = 1.0 / sub.rows() as f64;
        Ok(SemPosterior { edges, d, sigma, train: sub, targets: sub_targets, like_scale })
    }

    pub fn n_edges(&self) -> usize {
        self.edges.len()
    }

    /// Predicted means for one cell under do(target = value): ancestral
    /// propagation in topological order with the target clamped.
    fn propagate(&self, theta: &[f64], target: usize, value: f64, order: &[usize]) -> Vec<f64> {
        let biases = &theta[self.edges.len()..];
        let mut mu = vec![0.0; self.d];
        // parent lookup per child
        for &i in order {
            if i == target {
                mu[i] = value;
                continue;
            }
            let mut v = biases[i];
            for (e, &(child, parent)) in self.edges.iter().enumerate() {
                if child == i {
                    v += theta[e] * mu[parent];
                }
            }
            mu[i] = v;
        }
        mu
    }
}

impl LogDensity for SemPosterior {
    fn dim(&self) -> usize {
        self.edges.len() + self.d
    }

    fn grad_log_prob(&self, theta: &[f64], grad: &mut [f64]) {
        // N(0,1) priors
        for (g, &t) in grad.iter_mut().zip(theta) {
            *g = -t;
        }
        let ne = self.edges.len();
        let biases = &theta[ne..];
        // conditional likelihood over training rows
        for (r, tgt) in self.targets.iter().enumerate() {
            let row = self.train.row(r);
            for i in 0..self.d {
                if *tgt == Some(i) {
                    continue; // do() severs this equation
                }
                // residual of gene i
                let mut pred = biases[i];
                for (e, &(child, parent)) in self.edges.iter().enumerate() {
                    if child == i {
                        pred += theta[e] * row[parent];
                    }
                }
                let w = self.like_scale / (self.sigma[i] * self.sigma[i]);
                let resid = (row[i] - pred) * w;
                grad[ne + i] += resid;
                for (e, &(child, parent)) in self.edges.iter().enumerate() {
                    if child == i {
                        grad[e] += resid * row[parent];
                    }
                }
            }
        }
    }
}

/// OLS residual stds per gene given the structure (observational +
/// non-target rows only).
fn estimate_sigmas(adjacency: &Mat, train: &Mat, targets: &[Option<usize>]) -> Vec<f64> {
    let d = adjacency.rows();
    let n = train.rows();
    (0..d)
        .map(|i| {
            let parents: Vec<usize> =
                (0..d).filter(|&j| adjacency[(i, j)] != 0.0).collect();
            let rows: Vec<usize> =
                (0..n).filter(|&r| targets[r] != Some(i)).collect();
            if rows.is_empty() {
                return 1.0;
            }
            let y: Vec<f64> = rows.iter().map(|&r| train[(r, i)]).collect();
            if parents.is_empty() {
                return crate::stats::std(&y).max(SIGMA_FLOOR);
            }
            // design with intercept
            let x = Mat::from_fn(rows.len(), parents.len() + 1, |r, c| {
                if c == 0 {
                    1.0
                } else {
                    train[(rows[r], parents[c - 1])]
                }
            });
            let ym = Mat::from_vec(rows.len(), 1, y.clone()).unwrap();
            match lstsq(&x, &ym) {
                Ok(beta) => {
                    let pred = x.matmul(&beta);
                    let resid: Vec<f64> =
                        (0..rows.len()).map(|r| y[r] - pred[(r, 0)]).collect();
                    crate::stats::std(&resid).max(SIGMA_FLOOR)
                }
                Err(_) => crate::stats::std(&y).max(SIGMA_FLOOR),
            }
        })
        .collect()
}

/// Score held-out interventional cells given posterior particles.
///
/// `test_targets[r]` is the intervened gene of test row r.
pub fn score_particles(
    posterior: &SemPosterior,
    particles: &Mat,
    adjacency: &Mat,
    test: &Mat,
    test_targets: &[usize],
    max_cells: usize,
) -> Result<IntervMetrics> {
    let d = adjacency.rows();
    let order = crate::graph::topological_order(adjacency)
        .ok_or_else(|| Error::InvalidArgument("adjacency must be a DAG".into()))?;
    let p = particles.rows();
    let n = test.rows().min(max_cells);

    // Predictive stds under do(g): ancestral mean propagation leaves the
    // *marginal* interventional variance Var_i = Σ_k M[i,k]² σ_k² with
    // M = (I − W_do)⁻¹ (W_do = W with row g severed) — using the
    // conditional σ_i alone would under-cover whenever parents are noisy.
    let mut pred_sigma_cache: std::collections::HashMap<usize, Vec<f64>> =
        std::collections::HashMap::new();
    let mut pred_sigma = |target: usize| -> Result<Vec<f64>> {
        if let Some(s) = pred_sigma_cache.get(&target) {
            return Ok(s.clone());
        }
        let mut w_do = adjacency.clone();
        for j in 0..d {
            w_do[(target, j)] = 0.0;
        }
        let m = lu_inverse(&Mat::eye(d).sub(&w_do))?;
        let s: Vec<f64> = (0..d)
            .map(|i| {
                (0..d)
                    .map(|k| (m[(i, k)] * posterior.sigma[k]).powi(2))
                    .sum::<f64>()
                    .sqrt()
                    .max(SIGMA_FLOOR)
            })
            .collect();
        pred_sigma_cache.insert(target, s.clone());
        Ok(s)
    };

    let mut nll_sum = 0.0;
    let mut mae_sum = 0.0;
    let mut terms = 0usize;
    for r in 0..n {
        let target = test_targets[r];
        let obs = test.row(r);
        let sigmas = pred_sigma(target)?;
        // per-particle predicted means
        let mus: Vec<Vec<f64>> = (0..p)
            .map(|pi| posterior.propagate(particles.row(pi), target, obs[target], &order))
            .collect();
        for i in 0..d {
            if i == target {
                continue;
            }
            let sigma = sigmas[i];
            // posterior-mixture NLL via log-sum-exp over particles
            let mut max_log = f64::NEG_INFINITY;
            let logs: Vec<f64> = mus
                .iter()
                .map(|mu| {
                    let z = (obs[i] - mu[i]) / sigma;
                    let l = -0.5 * z * z - sigma.ln() - 0.5 * (2.0 * std::f64::consts::PI).ln();
                    max_log = max_log.max(l);
                    l
                })
                .collect();
            let mix: f64 =
                logs.iter().map(|l| (l - max_log).exp()).sum::<f64>() / p as f64;
            nll_sum += -(max_log + mix.ln());
            let mean_mu: f64 = mus.iter().map(|mu| mu[i]).sum::<f64>() / p as f64;
            mae_sum += (obs[i] - mean_mu).abs();
            terms += 1;
        }
    }
    Ok(IntervMetrics {
        nll: nll_sum / terms.max(1) as f64,
        mae: mae_sum / terms.max(1) as f64,
        cells: n,
    })
}

/// OLS point estimate of θ = (edge weights, biases) per structural
/// equation — the warm start for SVGD and the point predictive.
fn ols_theta(posterior: &SemPosterior, adjacency: &Mat) -> Vec<f64> {
    let d = adjacency.rows();
    let mut theta = vec![0.0; posterior.dim()];
    let ne = posterior.n_edges();
    for i in 0..d {
        let parents: Vec<usize> = (0..d).filter(|&j| adjacency[(i, j)] != 0.0).collect();
        let rows: Vec<usize> = (0..posterior.train.rows())
            .filter(|&r| posterior.targets[r] != Some(i))
            .collect();
        if rows.is_empty() {
            continue;
        }
        let x = Mat::from_fn(rows.len(), parents.len() + 1, |r, c| {
            if c == 0 {
                1.0
            } else {
                posterior.train[(rows[r], parents[c - 1])]
            }
        });
        let y = Mat::from_fn(rows.len(), 1, |r, _| posterior.train[(rows[r], i)]);
        if let Ok(beta) = lstsq(&x, &y) {
            theta[ne + i] = beta[(0, 0)];
            for (c, &pj) in parents.iter().enumerate() {
                if let Some(e) =
                    posterior.edges.iter().position(|&(ch, pa)| ch == i && pa == pj)
                {
                    theta[e] = beta[(c + 1, 0)];
                }
            }
        }
    }
    theta
}

/// End-to-end: fit the posterior with SVGD (warm-started at the OLS
/// solution, the standard MAP-centered init) and score held-out
/// interventions — the DirectLiNGAM + VI column of Table 1.
#[allow(clippy::too_many_arguments)]
pub fn evaluate_interventions(
    adjacency: &Mat,
    train: &Mat,
    train_targets: &[Option<usize>],
    test: &Mat,
    test_targets: &[usize],
    svgd_opts: SvgdOpts,
    max_train_rows: usize,
    max_test_cells: usize,
) -> Result<IntervMetrics> {
    let posterior = SemPosterior::new(adjacency, train, train_targets, max_train_rows)?;
    let init = ols_theta(&posterior, adjacency);
    let particles = Svgd::new(svgd_opts).sample_from(&posterior, Some(&init));
    score_particles(&posterior, &particles, adjacency, test, test_targets, max_test_cells)
}

/// Point-estimate evaluation (one pseudo-particle at the OLS solution) —
/// the predictive used for the continuous-optimization comparator column.
pub fn evaluate_point(
    adjacency: &Mat,
    train: &Mat,
    train_targets: &[Option<usize>],
    test: &Mat,
    test_targets: &[usize],
    max_train_rows: usize,
    max_test_cells: usize,
) -> Result<IntervMetrics> {
    let posterior = SemPosterior::new(adjacency, train, train_targets, max_train_rows)?;
    let theta = ols_theta(&posterior, adjacency);
    let particles = Mat::from_vec(1, theta.len(), theta)?;
    score_particles(&posterior, &particles, adjacency, test, test_targets, max_test_cells)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate_perturb, Condition, PerturbSpec};
    use crate::util::rng::Pcg64;

    fn tiny_dataset() -> crate::sim::PerturbDataset {
        let spec = PerturbSpec {
            n_genes: 10,
            n_targets: 5,
            cells_per_target: 30,
            n_control_cells: 150,
            heldout_frac: 0.4,
            edges_per_gene: 1.2,
            condition: Condition::CoCulture,
        };
        let mut rng = Pcg64::seed_from_u64(7);
        simulate_perturb(&spec, &mut rng)
    }

    fn split(ds: &crate::sim::PerturbDataset) -> (Mat, Vec<Option<usize>>, Mat, Vec<usize>) {
        let train = ds.train_data();
        let train_t: Vec<Option<usize>> =
            ds.train_idx.iter().map(|&r| ds.intervention[r]).collect();
        let test = ds.test_data();
        let test_t: Vec<usize> =
            ds.test_idx.iter().map(|&r| ds.intervention[r].unwrap()).collect();
        (train, train_t, test, test_t)
    }

    #[test]
    fn true_graph_beats_empty_graph() {
        let ds = tiny_dataset();
        let (train, train_t, test, test_t) = split(&ds);
        let opts = SvgdOpts { particles: 12, iters: 120, step: 0.1, seed: 1 };
        let with_graph = evaluate_interventions(
            &ds.adjacency, &train, &train_t, &test, &test_t, opts.clone(), 150, 60,
        )
        .unwrap();
        let empty = Mat::zeros(10, 10);
        let without = evaluate_interventions(
            &empty, &train, &train_t, &test, &test_t, opts, 150, 60,
        )
        .unwrap();
        assert!(
            with_graph.mae < without.mae,
            "graph MAE {} !< empty MAE {}",
            with_graph.mae,
            without.mae
        );
        assert!(
            with_graph.nll < without.nll,
            "graph NLL {} !< empty NLL {}",
            with_graph.nll,
            without.nll
        );
    }

    #[test]
    fn point_evaluation_runs() {
        let ds = tiny_dataset();
        let (train, train_t, test, test_t) = split(&ds);
        let m =
            evaluate_point(&ds.adjacency, &train, &train_t, &test, &test_t, 200, 50).unwrap();
        assert!(m.nll.is_finite() && m.mae.is_finite());
        assert!(m.cells > 0);
    }

    #[test]
    fn posterior_dim_counts_edges_and_biases() {
        let ds = tiny_dataset();
        let (train, train_t, _, _) = split(&ds);
        let post = SemPosterior::new(&ds.adjacency, &train, &train_t, 100).unwrap();
        let edges = ds.adjacency.as_slice().iter().filter(|v| **v != 0.0).count();
        assert_eq!(post.dim(), edges + 10);
        assert_eq!(post.n_edges(), edges);
    }

    #[test]
    fn cyclic_adjacency_rejected() {
        let ds = tiny_dataset();
        let (train, train_t, test, test_t) = split(&ds);
        let mut cyc = Mat::zeros(10, 10);
        cyc[(0, 1)] = 1.0;
        cyc[(1, 0)] = 1.0;
        assert!(evaluate_point(&cyc, &train, &train_t, &test, &test_t, 50, 10).is_err());
    }
}
