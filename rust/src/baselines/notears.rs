//! NOTEARS (Zheng et al. 2018): score-based DAG learning by continuous
//! optimization with the trace-exponential acyclicity constraint
//!
//!   min_W  1/(2n) ‖X − XW‖²_F + λ‖W‖₁   s.t.  h(W) = tr(e^{W∘W}) − d = 0
//!
//! solved with the standard augmented-Lagrangian outer loop and proximal
//! gradient (ISTA) inner loop. §3.1 of the paper evaluates this on simple
//! layered-DAG LiNGAM data — where it underperforms DirectLiNGAM — so the
//! baseline must be a faithful implementation, not a strawman: we use the
//! reference hyper-parameters (ρ ×10 escalation, h-reduction 0.25,
//! threshold 0.3) from the authors' released code.
//!
//! NOTEARS' native convention is `X ≈ XW` with `W[i,j]` meaning i → j;
//! results are transposed on return to this crate's `adj[(i,j)] = j → i`.

use crate::linalg::{expm, Mat};
use crate::stats;
use crate::util::{Error, Result};

/// NOTEARS hyper-parameters (defaults follow the reference code).
#[derive(Clone, Debug)]
pub struct NotearsOpts {
    /// ℓ1 penalty λ.
    pub lambda: f64,
    /// Augmented-Lagrangian outer iterations.
    pub max_outer: usize,
    /// ISTA inner iterations per outer step.
    pub max_inner: usize,
    /// Stop when h(W) < h_tol.
    pub h_tol: f64,
    /// ρ escalation cap.
    pub rho_max: f64,
    /// Final edge threshold (reference uses 0.3).
    pub w_threshold: f64,
    /// Standardize columns first. The reference implementation (and the
    /// paper's §3.1 run of it) operates on *raw* data, where the layered
    /// SEM's growing marginal variances (varsortability — Reisach et al.
    /// 2021) help NOTEARS considerably; standardized data removes that
    /// crutch. Both protocols are exposed; the §3.1 bench reports both.
    pub standardize: bool,
}

impl Default for NotearsOpts {
    fn default() -> Self {
        NotearsOpts {
            lambda: 0.01,
            max_outer: 20,
            max_inner: 250,
            h_tol: 1e-8,
            rho_max: 1e16,
            w_threshold: 0.3,
            standardize: false,
        }
    }
}

/// Run NOTEARS; returns the weighted adjacency in this crate's
/// convention (`adj[(i,j)] ≠ 0` ⇔ j → i), thresholded.
pub fn notears(x: &Mat, opts: &NotearsOpts) -> Result<Mat> {
    let (n, d) = (x.rows(), x.cols());
    if n < 2 || d < 2 {
        return Err(Error::InvalidArgument("need n ≥ 2, d ≥ 2".into()));
    }
    // center always; standardize only if asked (see NotearsOpts docs)
    let xs = if opts.standardize {
        stats::standardize_cols(x)
    } else {
        let mut c = x.clone();
        for col in 0..d {
            let m = stats::mean(&x.col(col));
            for r in 0..n {
                c[(r, col)] -= m;
            }
        }
        c
    };
    let cov = xs.t().matmul(&xs).scale(1.0 / n as f64); // C = XᵀX/n

    let mut w = Mat::zeros(d, d);
    let mut rho = 1.0;
    let mut alpha = 0.0;
    let mut h = f64::INFINITY;

    for _outer in 0..opts.max_outer {
        // inner: minimize smooth part + λ‖·‖₁ at fixed (ρ, α) via ISTA
        let mut h_new = h;
        for _ in 0..1 {
            (w, h_new) = ista(&cov, w, rho, alpha, opts)?;
        }
        if h_new > 0.25 * h && rho < opts.rho_max {
            rho *= 10.0;
        }
        alpha += rho * h_new;
        h = h_new;
        if h < opts.h_tol || rho >= opts.rho_max {
            break;
        }
    }

    // threshold and transpose into this crate's convention
    let mut adj = Mat::zeros(d, d);
    for i in 0..d {
        for j in 0..d {
            let v = w[(i, j)]; // i → j in NOTEARS convention
            if v.abs() > opts.w_threshold {
                adj[(j, i)] = v;
            }
        }
    }
    // safety: thresholding almost always yields a DAG; if not, greedily
    // drop the weakest cycle-closing edges
    while crate::graph::topological_order(&adj).is_none() {
        let (mut bi, mut bj, mut bv) = (0, 0, f64::INFINITY);
        for i in 0..d {
            for j in 0..d {
                let v = adj[(i, j)].abs();
                if v > 0.0 && v < bv {
                    (bi, bj, bv) = (i, j, v);
                }
            }
        }
        adj[(bi, bj)] = 0.0;
    }
    Ok(adj)
}

/// Proximal-gradient (ISTA) minimization of
/// F(W) = ½/n‖X−XW‖² + α h(W) + ½ρ h(W)² at fixed (ρ, α), plus λ‖W‖₁.
fn ista(cov: &Mat, mut w: Mat, rho: f64, alpha: f64, opts: &NotearsOpts) -> Result<(Mat, f64)> {
    let mut step = 1.0;
    let (mut f_cur, mut h_cur, mut grad) = f_and_grad(cov, &w, rho, alpha)?;
    for _ in 0..opts.max_inner {
        // backtracking line search on the smooth part
        let mut improved = false;
        for _ in 0..30 {
            let w_try = prox_step(&w, &grad, step, opts.lambda);
            let (f_try, h_try, grad_try) = f_and_grad(cov, &w_try, rho, alpha)?;
            // sufficient decrease on the full objective (incl. ℓ1)
            let obj_cur = f_cur + opts.lambda * l1(&w);
            let obj_try = f_try + opts.lambda * l1(&w_try);
            if obj_try <= obj_cur - 1e-12 {
                let delta = w_try.sub(&w).max_abs();
                w = w_try;
                f_cur = f_try;
                h_cur = h_try;
                grad = grad_try;
                improved = true;
                step *= 1.25;
                if delta < 1e-7 {
                    return Ok((w, h_cur));
                }
                break;
            }
            step *= 0.5;
            if step < 1e-12 {
                return Ok((w, h_cur));
            }
        }
        if !improved {
            break;
        }
    }
    Ok((w, h_cur))
}

/// Smooth objective value, h(W), and smooth gradient.
fn f_and_grad(cov: &Mat, w: &Mat, rho: f64, alpha: f64) -> Result<(f64, f64, Mat)> {
    let d = cov.rows();
    // loss = ½ tr((I−W)ᵀ C (I−W));  grad = C(W − I)
    let i_minus_w = Mat::eye(d).sub(w);
    let c_imw = cov.matmul(&i_minus_w);
    let loss = 0.5 * i_minus_w.t().matmul(&c_imw).trace();
    let g_loss = c_imw.scale(-1.0);

    // h = tr(e^{W∘W}) − d;  ∇h = (e^{W∘W})ᵀ ∘ 2W
    let e = expm(&w.hadamard(w))?;
    let h = e.trace() - d as f64;
    let g_h = e.t().hadamard(&w.scale(2.0));

    let f = loss + alpha * h + 0.5 * rho * h * h;
    let g = g_loss.add(&g_h.scale(alpha + rho * h));
    Ok((f, h, g))
}

/// One proximal step: soft-threshold(W − step·∇, step·λ) with zero
/// diagonal (self-loops are never allowed).
fn prox_step(w: &Mat, grad: &Mat, step: f64, lambda: f64) -> Mat {
    let d = w.rows();
    let mut out = Mat::zeros(d, d);
    let t = step * lambda;
    for i in 0..d {
        for j in 0..d {
            if i == j {
                continue;
            }
            let v = w[(i, j)] - step * grad[(i, j)];
            out[(i, j)] = if v > t {
                v - t
            } else if v < -t {
                v + t
            } else {
                0.0
            };
        }
    }
    out
}

fn l1(w: &Mat) -> f64 {
    w.as_slice().iter().map(|v| v.abs()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::graph_metrics;
    use crate::sim::{simulate_sem, SemSpec};
    use crate::util::rng::Pcg64;

    #[test]
    fn returns_a_dag() {
        let mut rng = Pcg64::seed_from_u64(1);
        let ds = simulate_sem(&SemSpec::layered(6, 2, 0.5), 1_000, &mut rng);
        let adj = notears(&ds.data, &NotearsOpts::default()).unwrap();
        assert!(crate::graph::topological_order(&adj).is_some());
    }

    #[test]
    fn recovers_strong_two_node_edge() {
        // x0 → x1 with weight 2 and standardized data: NOTEARS should at
        // least find a single edge between them
        let mut rng = Pcg64::seed_from_u64(2);
        let mut adj = Mat::zeros(2, 2);
        adj[(1, 0)] = 2.0;
        let dag = crate::graph::Dag::new(adj).unwrap();
        let x = crate::sim::sem::sample_from_dag(&dag, crate::sim::Noise::Uniform01, 3_000, &mut rng);
        let est = notears(&x, &NotearsOpts::default()).unwrap();
        let edges = est.as_slice().iter().filter(|v| **v != 0.0).count();
        assert_eq!(edges, 1, "est = {est:?}");
    }

    #[test]
    fn imperfect_on_layered_lingam_data() {
        // §3.1's point: NOTEARS is *not* reliable on this data. We check
        // it runs and produces something plausible but do not demand
        // perfect recovery (it typically misses/reverses edges).
        let mut rng = Pcg64::seed_from_u64(3);
        let ds = simulate_sem(&SemSpec::layered(10, 2, 0.5), 3_000, &mut rng);
        let est = notears(&ds.data, &NotearsOpts { lambda: 0.05, ..Default::default() }).unwrap();
        let m = graph_metrics(&ds.adjacency, &est, 0.0);
        assert!(m.est_edges > 0, "degenerate empty graph");
        assert!(m.f1 > 0.2, "f1 collapsed: {}", m.f1);
    }

    #[test]
    fn h_decreases_to_tolerance() {
        let mut rng = Pcg64::seed_from_u64(4);
        let ds = simulate_sem(&SemSpec::layered(5, 2, 0.6), 800, &mut rng);
        let xs = stats::standardize_cols(&ds.data);
        let cov = xs.t().matmul(&xs).scale(1.0 / xs.rows() as f64);
        // run the full driver then verify acyclicity value at the solution
        let adj = notears(&ds.data, &NotearsOpts::default()).unwrap();
        let w = adj.t(); // back to notears convention
        let h = expm(&w.hadamard(&w)).unwrap().trace() - 5.0;
        assert!(h.abs() < 1e-4, "h={h}, cov trace {}", cov.trace());
    }

    #[test]
    fn lambda_controls_sparsity() {
        let mut rng = Pcg64::seed_from_u64(5);
        let ds = simulate_sem(&SemSpec::layered(8, 2, 0.6), 1_500, &mut rng);
        let nnz = |lam: f64| {
            let est = notears(
                &ds.data,
                &NotearsOpts { lambda: lam, w_threshold: 0.05, ..Default::default() },
            )
            .unwrap();
            est.as_slice().iter().filter(|v| **v != 0.0).count()
        };
        assert!(nnz(0.5) <= nnz(0.001));
    }
}
