//! Baselines and evaluation machinery the paper compares against:
//!
//! - [`notears`] — NOTEARS (Zheng et al. 2018), the continuous-
//!   optimization method §3.1 shows failing on simple LiNGAM data.
//! - [`notears_lr`] — a low-rank factor variant (W = UVᵀ) standing in for
//!   DCD-FG (Lopez et al. 2022) in Table 1; see DESIGN.md §Substitutions.
//! - [`svgd`] — Stein variational gradient descent (Liu & Wang 2016),
//!   replacing the paper's Pyro Stein VI.
//! - [`interv`] — interventional evaluation: I-NLL and I-MAE over
//!   held-out genetic interventions (Table 1's metrics).

pub mod interv;
pub mod notears;
pub mod notears_lr;
pub mod svgd;

pub use interv::{evaluate_interventions, evaluate_point, IntervMetrics, SemPosterior};
pub use notears::{notears, NotearsOpts};
pub use notears_lr::{notears_lr, NotearsLrOpts};
pub use svgd::{Svgd, SvgdOpts};
