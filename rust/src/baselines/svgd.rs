//! Stein variational gradient descent (Liu & Wang 2016) — the inference
//! engine behind the Table-1 evaluation (the paper uses Pyro's Stein VI;
//! same algorithm, see DESIGN.md §Substitutions).
//!
//! Particles θ¹..θᴾ approximate the posterior p(θ | data); each update
//! applies the perturbation-of-identity transform
//!
//!   θⁱ ← θⁱ + ε φ(θⁱ),
//!   φ(x) = 1/P Σ_j [ k(θʲ, x) ∇_θ log p(θʲ) + ∇_{θʲ} k(θʲ, x) ]
//!
//! with an RBF kernel whose bandwidth follows the median heuristic.

use crate::linalg::Mat;
use crate::stats;
use crate::util::rng::Pcg64;

/// A differentiable (unnormalized) log density over ℝᵖ.
pub trait LogDensity {
    /// Parameter dimension p.
    fn dim(&self) -> usize;
    /// ∇_θ log p(θ) written into `grad` (same length as `theta`).
    fn grad_log_prob(&self, theta: &[f64], grad: &mut [f64]);
}

/// SVGD options.
#[derive(Clone, Debug)]
pub struct SvgdOpts {
    /// Number of particles (paper: 200 posterior samples).
    pub particles: usize,
    /// Optimization iterations (paper: 5000; scale to budget).
    pub iters: usize,
    /// Step size (AdaGrad-scaled).
    pub step: f64,
    pub seed: u64,
}

impl Default for SvgdOpts {
    fn default() -> Self {
        SvgdOpts { particles: 50, iters: 300, step: 0.05, seed: 0 }
    }
}

/// The SVGD sampler.
pub struct Svgd {
    opts: SvgdOpts,
}

impl Svgd {
    pub fn new(opts: SvgdOpts) -> Svgd {
        Svgd { opts }
    }

    /// Run SVGD against `target`; returns the particle set as rows of a
    /// `[particles, dim]` matrix. Particles initialize from the N(0,1)
    /// prior.
    pub fn sample(&self, target: &dyn LogDensity) -> Mat {
        self.sample_from(target, None)
    }

    /// SVGD with a warm start: particles initialize at `init` plus prior
    /// noise (the standard MAP-centered initialization; cuts the
    /// iteration count dramatically for the gene-scale posteriors).
    pub fn sample_from(&self, target: &dyn LogDensity, init: Option<&[f64]>) -> Mat {
        let p = self.opts.particles;
        let dim = target.dim();
        let mut rng = Pcg64::seed_from_u64(self.opts.seed);
        let mut particles = match init {
            Some(center) => {
                assert_eq!(center.len(), dim, "init dim mismatch");
                Mat::from_fn(p, dim, |_, c| center[c] + 0.1 * rng.normal())
            }
            None => Mat::from_fn(p, dim, |_, _| rng.normal()),
        };
        let mut grads = Mat::zeros(p, dim);
        let mut adagrad = vec![1e-8; p * dim];
        let mut phi = vec![0.0; p * dim];

        for _it in 0..self.opts.iters {
            // per-particle target gradients
            for i in 0..p {
                let row = particles.row(i).to_vec();
                target.grad_log_prob(&row, grads.row_mut(i));
            }
            // RBF bandwidth via the median heuristic
            let med = stats::median_sq_dist(&particles).max(1e-12);
            let h = med / (2.0 * ((p as f64) + 1.0).ln()).max(1e-12);

            // φ(xᵢ) = 1/P Σⱼ k(xⱼ,xᵢ) g(xⱼ) + ∇_{xⱼ} k(xⱼ,xᵢ)
            phi.iter_mut().for_each(|v| *v = 0.0);
            for j in 0..p {
                let xj = particles.row(j).to_vec();
                let gj = grads.row(j).to_vec();
                for i in 0..p {
                    let xi = particles.row(i);
                    let mut sq = 0.0;
                    for k in 0..dim {
                        let dkk = xj[k] - xi[k];
                        sq += dkk * dkk;
                    }
                    let kji = (-sq / h).exp();
                    let out = &mut phi[i * dim..(i + 1) * dim];
                    for k in 0..dim {
                        // ∇_{xj} k = -2 (xj - xi)/h · k
                        out[k] += kji * gj[k] + kji * (-2.0 / h) * (xj[k] - xi[k]);
                    }
                }
            }
            // AdaGrad step
            let inv_p = 1.0 / p as f64;
            for i in 0..p {
                let row = particles.row_mut(i);
                for k in 0..dim {
                    let g = phi[i * dim + k] * inv_p;
                    let cell = &mut adagrad[i * dim + k];
                    *cell += g * g;
                    row[k] += self.opts.step * g / cell.sqrt();
                }
            }
        }
        particles
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Standard normal in p dims.
    struct StdNormal(usize);
    impl LogDensity for StdNormal {
        fn dim(&self) -> usize {
            self.0
        }
        fn grad_log_prob(&self, theta: &[f64], grad: &mut [f64]) {
            for (g, &t) in grad.iter_mut().zip(theta) {
                *g = -t;
            }
        }
    }

    /// N(mu, sigma²) univariate.
    struct Gaussian1 {
        mu: f64,
        sigma: f64,
    }
    impl LogDensity for Gaussian1 {
        fn dim(&self) -> usize {
            1
        }
        fn grad_log_prob(&self, theta: &[f64], grad: &mut [f64]) {
            grad[0] = -(theta[0] - self.mu) / (self.sigma * self.sigma);
        }
    }

    #[test]
    fn converges_to_shifted_gaussian() {
        let svgd = Svgd::new(SvgdOpts { particles: 40, iters: 1200, step: 0.2, seed: 1 });
        let particles = svgd.sample(&Gaussian1 { mu: 3.0, sigma: 0.5 });
        let vals = particles.col(0);
        let mean = crate::stats::mean(&vals);
        let std = crate::stats::std(&vals);
        assert!((mean - 3.0).abs() < 0.25, "mean={mean}");
        assert!((std - 0.5).abs() < 0.3, "std={std}");
    }

    #[test]
    fn particles_spread_not_collapsed() {
        // the repulsive kernel term must keep particle diversity
        let svgd = Svgd::new(SvgdOpts { particles: 30, iters: 200, step: 0.1, seed: 2 });
        let particles = svgd.sample(&StdNormal(2));
        let d = crate::stats::median_sq_dist(&particles);
        assert!(d > 0.05, "particles collapsed: median sq dist {d}");
    }

    #[test]
    fn deterministic_given_seed() {
        let opts = SvgdOpts { particles: 10, iters: 50, step: 0.1, seed: 3 };
        let a = Svgd::new(opts.clone()).sample(&StdNormal(3));
        let b = Svgd::new(opts).sample(&StdNormal(3));
        assert_eq!(a, b);
    }
}
