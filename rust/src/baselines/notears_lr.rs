//! Low-rank factor NOTEARS — the stand-in for DCD-FG (Lopez et al. 2022)
//! in the Table-1 comparison.
//!
//! DCD-FG parameterizes the graph as a *factor* DAG: genes interact
//! through a small number of latent factors, giving W a low-rank
//! structure. Its published ancestor is NOTEARS-LR; we implement that:
//!
//!   W = U Vᵀ,  U, V ∈ ℝ^{d×k},   min  1/(2n)‖X − XW‖² + λ(‖U‖₁+‖V‖₁)
//!                                s.t. h(UVᵀ) = 0
//!
//! optimized with the same augmented-Lagrangian scheme as [`super::notears`]
//! but with gradients pushed through the factors (∂/∂U = G V, ∂/∂V = GᵀU).
//! This preserves exactly what Table 1 needs from DCD-FG: a continuous-
//! optimization factor-graph learner of interventional gene data.

use crate::linalg::{expm, Mat};
use crate::stats;
use crate::util::{Error, Result};
use crate::util::rng::Pcg64;

/// Hyper-parameters.
#[derive(Clone, Debug)]
pub struct NotearsLrOpts {
    /// Number of latent factors k (DCD-FG uses ~10-20 for ~1000 genes).
    pub rank: usize,
    pub lambda: f64,
    pub max_outer: usize,
    pub max_inner: usize,
    pub h_tol: f64,
    pub rho_max: f64,
    pub w_threshold: f64,
    pub seed: u64,
}

impl Default for NotearsLrOpts {
    fn default() -> Self {
        NotearsLrOpts {
            rank: 10,
            lambda: 0.005,
            max_outer: 15,
            max_inner: 150,
            h_tol: 1e-6,
            rho_max: 1e14,
            w_threshold: 0.1,
            seed: 0,
        }
    }
}

/// Run NOTEARS-LR; returns the (thresholded, DAG-enforced) adjacency in
/// this crate's convention.
pub fn notears_lr(x: &Mat, opts: &NotearsLrOpts) -> Result<Mat> {
    let (n, d) = (x.rows(), x.cols());
    let k = opts.rank.min(d);
    if n < 2 || d < 2 {
        return Err(Error::InvalidArgument("need n ≥ 2, d ≥ 2".into()));
    }
    let xs = stats::standardize_cols(x);
    let cov = xs.t().matmul(&xs).scale(1.0 / n as f64);

    let mut rng = Pcg64::seed_from_u64(opts.seed);
    let scale = 0.1 / (k as f64).sqrt();
    let mut u = Mat::from_fn(d, k, |_, _| rng.normal() * scale);
    let mut v = Mat::from_fn(d, k, |_, _| rng.normal() * scale);

    let mut rho = 1.0;
    let mut alpha = 0.0;
    let mut h = f64::INFINITY;

    for _outer in 0..opts.max_outer {
        let h_new;
        (u, v, h_new) = inner_opt(&cov, u, v, rho, alpha, opts)?;
        if h_new > 0.25 * h && rho < opts.rho_max {
            rho *= 10.0;
        }
        alpha += rho * h_new;
        h = h_new;
        if h < opts.h_tol || rho >= opts.rho_max {
            break;
        }
    }

    let w = u.matmul(&v.t());
    let mut adj = Mat::zeros(d, d);
    for i in 0..d {
        for j in 0..d {
            if i != j && w[(i, j)].abs() > opts.w_threshold {
                adj[(j, i)] = w[(i, j)];
            }
        }
    }
    // enforce a DAG by dropping weakest cycle edges
    while crate::graph::topological_order(&adj).is_none() {
        let (mut bi, mut bj, mut bv) = (0, 0, f64::INFINITY);
        for i in 0..d {
            for j in 0..d {
                let a = adj[(i, j)].abs();
                if a > 0.0 && a < bv {
                    (bi, bj, bv) = (i, j, a);
                }
            }
        }
        adj[(bi, bj)] = 0.0;
    }
    Ok(adj)
}

/// Proximal gradient on (U, V) at fixed (ρ, α).
fn inner_opt(
    cov: &Mat,
    mut u: Mat,
    mut v: Mat,
    rho: f64,
    alpha: f64,
    opts: &NotearsLrOpts,
) -> Result<(Mat, Mat, f64)> {
    let mut step = 0.5;
    let (mut f_cur, mut h_cur, mut gu, mut gv) = f_and_grad(cov, &u, &v, rho, alpha)?;
    for _ in 0..opts.max_inner {
        let mut improved = false;
        for _ in 0..25 {
            let u_try = prox(&u, &gu, step, opts.lambda);
            let v_try = prox(&v, &gv, step, opts.lambda);
            let (f_try, h_try, gu_try, gv_try) = f_and_grad(cov, &u_try, &v_try, rho, alpha)?;
            let obj_cur = f_cur + opts.lambda * (l1(&u) + l1(&v));
            let obj_try = f_try + opts.lambda * (l1(&u_try) + l1(&v_try));
            if obj_try <= obj_cur - 1e-12 {
                let delta = u_try.sub(&u).max_abs().max(v_try.sub(&v).max_abs());
                u = u_try;
                v = v_try;
                f_cur = f_try;
                h_cur = h_try;
                gu = gu_try;
                gv = gv_try;
                improved = true;
                step *= 1.25;
                if delta < 1e-7 {
                    return Ok((u, v, h_cur));
                }
                break;
            }
            step *= 0.5;
            if step < 1e-12 {
                return Ok((u, v, h_cur));
            }
        }
        if !improved {
            break;
        }
    }
    Ok((u, v, h_cur))
}

/// Objective, h, and factor gradients. W = UVᵀ with zeroed diagonal.
fn f_and_grad(cov: &Mat, u: &Mat, v: &Mat, rho: f64, alpha: f64) -> Result<(f64, f64, Mat, Mat)> {
    let d = cov.rows();
    let mut w = u.matmul(&v.t());
    for i in 0..d {
        w[(i, i)] = 0.0;
    }
    let i_minus_w = Mat::eye(d).sub(&w);
    let c_imw = cov.matmul(&i_minus_w);
    let loss = 0.5 * i_minus_w.t().matmul(&c_imw).trace();
    let g_loss = c_imw.scale(-1.0);

    let e = expm(&w.hadamard(&w))?;
    let h = e.trace() - d as f64;
    let g_h = e.t().hadamard(&w.scale(2.0));

    let f = loss + alpha * h + 0.5 * rho * h * h;
    let mut g_w = g_loss.add(&g_h.scale(alpha + rho * h));
    for i in 0..d {
        g_w[(i, i)] = 0.0;
    }
    let gu = g_w.matmul(v);
    let gv = g_w.t().matmul(u);
    Ok((f, h, gu, gv))
}

fn prox(m: &Mat, g: &Mat, step: f64, lambda: f64) -> Mat {
    let t = step * lambda;
    m.zip(g, |a, b| {
        let v = a - step * b;
        if v > t {
            v - t
        } else if v < -t {
            v + t
        } else {
            0.0
        }
    })
}

fn l1(m: &Mat) -> f64 {
    m.as_slice().iter().map(|v| v.abs()).sum()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate_perturb, Condition, PerturbSpec};
    use crate::util::rng::Pcg64;

    #[test]
    fn returns_dag_on_gene_data() {
        let spec = PerturbSpec {
            n_genes: 20,
            n_targets: 6,
            cells_per_target: 40,
            n_control_cells: 200,
            ..PerturbSpec::small(Condition::CoCulture)
        };
        let mut rng = Pcg64::seed_from_u64(1);
        let ds = simulate_perturb(&spec, &mut rng);
        let adj = notears_lr(
            &ds.train_data(),
            &NotearsLrOpts { rank: 5, max_outer: 6, max_inner: 60, ..Default::default() },
        )
        .unwrap();
        assert!(crate::graph::topological_order(&adj).is_some());
        assert!(adj.is_finite());
    }

    #[test]
    fn rank_bounds_structure() {
        // with rank 1 the edge pattern is a (sparse) outer product —
        // verify the result has rank ≤ 1 before thresholding by checking
        // the learner still runs and returns a DAG
        let mut rng = Pcg64::seed_from_u64(2);
        let ds = crate::sim::simulate_sem(&crate::sim::SemSpec::erdos_renyi(8, 1.0), 800, &mut rng);
        let adj = notears_lr(
            &ds.data,
            &NotearsLrOpts { rank: 1, max_outer: 5, max_inner: 50, ..Default::default() },
        )
        .unwrap();
        assert!(crate::graph::topological_order(&adj).is_some());
    }

    #[test]
    fn deterministic_given_seed() {
        let mut rng = Pcg64::seed_from_u64(3);
        let ds = crate::sim::simulate_sem(&crate::sim::SemSpec::erdos_renyi(6, 1.0), 500, &mut rng);
        let o = NotearsLrOpts { rank: 3, max_outer: 4, max_inner: 40, ..Default::default() };
        let a = notears_lr(&ds.data, &o).unwrap();
        let b = notears_lr(&ds.data, &o).unwrap();
        assert_eq!(a, b);
    }
}
