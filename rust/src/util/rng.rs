//! PCG64 pseudo-random generator plus the sampling distributions the
//! paper's simulations need (Normal, Uniform, Laplace, Exponential,
//! Student-t) and permutation utilities.
//!
//! PCG-XSL-RR-128/64 (O'Neill 2014): 128-bit LCG state, 64-bit output via
//! xor-shift-low + random rotation. Deterministic across platforms, which
//! the Figure-3 agreement experiments rely on (same seed ⇒ same dataset on
//! every engine).

/// PCG-XSL-RR-128/64 generator.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Seed from a single u64 (stream constant fixed).
    pub fn seed_from_u64(seed: u64) -> Self {
        Self::new(seed as u128, 0xa02b_dbf7_bb3c_0a7a_c28f_a16a_64ab_f96d)
    }

    /// Full (state, stream) construction.
    pub fn new(init_state: u128, init_seq: u128) -> Self {
        let mut rng = Pcg64 { state: 0, inc: (init_seq << 1) | 1 };
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng.state = rng.state.wrapping_add(init_state);
        rng.state = rng.state.wrapping_mul(PCG_MULT).wrapping_add(rng.inc);
        rng
    }

    /// Derive an independent child generator (for per-job seeding in the
    /// coordinator's multi-seed sweeps).
    pub fn split(&mut self) -> Pcg64 {
        let s = self.next_u64() as u128 | ((self.next_u64() as u128) << 64);
        let q = self.next_u64() as u128 | ((self.next_u64() as u128) << 64);
        Pcg64::new(s, q)
    }

    /// Next raw 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xored = ((self.state >> 64) as u64) ^ (self.state as u64);
        xored.rotate_right(rot)
    }

    /// Uniform f64 in [0, 1).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        // 53 random mantissa bits.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in (0, 1] — safe as a log() argument.
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) as f64 + 1.0) * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in [lo, hi).
    #[inline]
    pub fn uniform(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in [0, n) via Lemire's method.
    pub fn below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        let n = n as u64;
        let mut x = self.next_u64();
        let mut m = (x as u128).wrapping_mul(n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128).wrapping_mul(n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as usize
    }

    /// Standard normal via Box-Muller (no cached spare: keeps the
    /// generator state a pure function of draw count).
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64_open();
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with given mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Laplace(0, b) — a non-Gaussian noise distribution used by the
    /// gene/stock simulators (LiNGAM requires non-Gaussian errors).
    pub fn laplace(&mut self, b: f64) -> f64 {
        let u = self.f64() - 0.5;
        -b * u.signum() * (1.0 - 2.0 * u.abs()).ln()
    }

    /// Exponential(rate).
    pub fn exponential(&mut self, rate: f64) -> f64 {
        -self.f64_open().ln() / rate
    }

    /// Student-t with `dof` degrees of freedom (heavy-tailed innovations
    /// for the stock simulator). Uses the ratio-of-normals/chi2 form.
    pub fn student_t(&mut self, dof: f64) -> f64 {
        let z = self.normal();
        // chi2(dof) as sum of gamma draws via Marsaglia-Tsang.
        let chi2 = 2.0 * self.gamma(dof / 2.0, 1.0);
        z / (chi2 / dof).sqrt()
    }

    /// Gamma(shape k, scale θ) via Marsaglia-Tsang (k ≥ 0 handled with the
    /// boost trick for k < 1).
    pub fn gamma(&mut self, k: f64, theta: f64) -> f64 {
        if k < 1.0 {
            let u = self.f64_open();
            return self.gamma(k + 1.0, theta) * u.powf(1.0 / k);
        }
        let d = k - 1.0 / 3.0;
        let c = 1.0 / (9.0 * d).sqrt();
        loop {
            let x = self.normal();
            let v = (1.0 + c * x).powi(3);
            if v <= 0.0 {
                continue;
            }
            let u = self.f64_open();
            if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
                return d * v * theta;
            }
        }
    }

    /// Uniform noise term per the paper's §3.1 simulation: ε ~ U(0, 1).
    #[inline]
    pub fn paper_noise(&mut self) -> f64 {
        self.f64()
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i + 1);
            xs.swap(i, j);
        }
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }

    /// Sample k distinct indices from 0..n (k ≤ n) — partial Fisher-Yates.
    pub fn choose(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut p: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below(n - i);
            p.swap(i, j);
        }
        p.truncate(k);
        p
    }

    /// Bernoulli(p).
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_clones() {
        let mut a = Pcg64::seed_from_u64(42);
        let mut b = Pcg64::seed_from_u64(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::seed_from_u64(1);
        let mut b = Pcg64::seed_from_u64(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn uniform_bounds_and_mean() {
        let mut r = Pcg64::seed_from_u64(3);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        let mean = sum / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Pcg64::seed_from_u64(4);
        let n = 50_000;
        let (mut s1, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.normal();
            s1 += x;
            s2 += x * x;
        }
        let mean = s1 / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn laplace_variance() {
        let mut r = Pcg64::seed_from_u64(5);
        let b = 0.7;
        let n = 50_000;
        let mut s2 = 0.0;
        for _ in 0..n {
            let x = r.laplace(b);
            s2 += x * x;
        }
        let var = s2 / n as f64;
        assert!((var - 2.0 * b * b).abs() < 0.05, "var={var}");
    }

    #[test]
    fn below_is_in_range_and_covers() {
        let mut r = Pcg64::seed_from_u64(6);
        let mut seen = [false; 7];
        for _ in 0..1000 {
            let v = r.below(7);
            assert!(v < 7);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn permutation_is_bijection() {
        let mut r = Pcg64::seed_from_u64(7);
        let p = r.permutation(50);
        let mut sorted = p.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
    }

    #[test]
    fn choose_distinct() {
        let mut r = Pcg64::seed_from_u64(8);
        let c = r.choose(100, 20);
        assert_eq!(c.len(), 20);
        let mut s = c.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 20);
    }

    #[test]
    fn student_t_heavy_tail() {
        let mut r = Pcg64::seed_from_u64(9);
        // t(5) kurtosis > normal: count |x|>3 exceedances vs normal draws.
        let n = 50_000;
        let t_exc = (0..n).filter(|_| r.student_t(5.0).abs() > 3.0).count();
        let z_exc = (0..n).filter(|_| r.normal().abs() > 3.0).count();
        assert!(t_exc > z_exc, "t_exc={t_exc} z_exc={z_exc}");
    }

    #[test]
    fn gamma_mean() {
        let mut r = Pcg64::seed_from_u64(10);
        let n = 30_000;
        let mean = (0..n).map(|_| r.gamma(2.5, 2.0)).sum::<f64>() / n as f64;
        assert!((mean - 5.0).abs() < 0.1, "mean={mean}");
    }

    #[test]
    fn split_independent() {
        let mut root = Pcg64::seed_from_u64(11);
        let mut a = root.split();
        let mut b = root.split();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}
