//! The crate's one scoped worker pool: work-stealing by atomic counter
//! over an index range, results returned in index order.
//!
//! Used by `coordinator::sweep::parallel_map` (multi-seed experiment
//! fan-out) and by `lingam::parallel::ParallelEngine` (pair-loop tiling
//! and parallel residualization), so there is a single pool
//! implementation to audit. Workers batch their `(index, value)` results
//! locally and hand them back through their join handles; the caller
//! places them by index, which makes the output — and any fold the
//! caller runs over it — deterministic regardless of which worker
//! claimed which index.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Run `f(i)` for every `i in 0..n` across `workers` scoped threads;
/// results come back in index order. `f` must be `Sync` (it is shared
/// across workers). A worker panic propagates to the caller.
pub fn parallel_indexed<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(workers >= 1);
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers.min(n))
            .map(|_| {
                scope.spawn(|| {
                    let mut done: Vec<(usize, T)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        done.push((i, f(i)));
                    }
                    done
                })
            })
            .collect();
        for handle in handles {
            for (i, value) in handle.join().expect("pool worker panicked") {
                out[i] = Some(value);
            }
        }
    });
    out.into_iter().map(|v| v.expect("every index claimed by a worker")).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_index_order() {
        let out = parallel_indexed(37, 4, |i| i * 2);
        assert_eq!(out, (0..37).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn zero_items_and_single_worker() {
        let empty: Vec<usize> = parallel_indexed(0, 3, |i| i);
        assert!(empty.is_empty());
        assert_eq!(parallel_indexed(3, 1, |i| i + 1), vec![1, 2, 3]);
    }

    #[test]
    fn more_workers_than_items() {
        assert_eq!(parallel_indexed(2, 16, |i| i), vec![0, 1]);
    }
}
