//! The crate's one scoped worker pool: work-stealing by atomic counter
//! over an index range, results returned in index order.
//!
//! Used by `coordinator::sweep::parallel_map` (multi-seed experiment
//! fan-out), by `lingam::parallel::ParallelEngine` (pair-loop tiling
//! and parallel residualization) and by the `lingam::session` workspace
//! sweeps (entropy refresh, correlation build, and — via
//! [`parallel_chunks_mut`] — the in-place cache residualization), so
//! there is a single pool implementation to audit. Workers batch their `(index, value)` results
//! locally and hand them back through their join handles; the caller
//! places them by index, which makes the output — and any fold the
//! caller runs over it — deterministic regardless of which worker
//! claimed which index.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Run `f(i)` for every `i in 0..n` across `workers` scoped threads;
/// results come back in index order. `f` must be `Sync` (it is shared
/// across workers). A worker panic propagates to the caller.
pub fn parallel_indexed<T, F>(n: usize, workers: usize, f: F) -> Vec<T>
where
    T: Send,
    F: Fn(usize) -> T + Sync,
{
    assert!(workers >= 1);
    let next = AtomicUsize::new(0);
    let mut out: Vec<Option<T>> = (0..n).map(|_| None).collect();
    std::thread::scope(|scope| {
        let handles: Vec<_> = (0..workers.min(n))
            .map(|_| {
                scope.spawn(|| {
                    let mut done: Vec<(usize, T)> = Vec::new();
                    loop {
                        let i = next.fetch_add(1, Ordering::Relaxed);
                        if i >= n {
                            break;
                        }
                        done.push((i, f(i)));
                    }
                    done
                })
            })
            .collect();
        for handle in handles {
            for (i, value) in handle.join().expect("pool worker panicked") {
                out[i] = Some(value);
            }
        }
    });
    out.into_iter().map(|v| v.expect("every index claimed by a worker")).collect()
}

/// Run `f(start_index, chunk)` over contiguous chunks of `items`, one
/// chunk per worker — the in-place mutation counterpart of
/// [`parallel_indexed`]. The partition is static (per-item cost should
/// be roughly uniform, as it is for the ordering session's equal-length
/// column updates), chunks are disjoint `&mut` slices so no locking is
/// needed, and the result is deterministic because each item is written
/// by exactly one worker. A worker panic propagates to the caller.
pub fn parallel_chunks_mut<T, F>(items: &mut [T], workers: usize, f: F)
where
    T: Send,
    F: Fn(usize, &mut [T]) + Sync,
{
    let n = items.len();
    if n == 0 {
        return;
    }
    let workers = workers.clamp(1, n);
    let chunk = n.div_ceil(workers);
    std::thread::scope(|scope| {
        let f = &f;
        for (w, slice) in items.chunks_mut(chunk).enumerate() {
            scope.spawn(move || f(w * chunk, slice));
        }
    });
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn preserves_index_order() {
        let out = parallel_indexed(37, 4, |i| i * 2);
        assert_eq!(out, (0..37).map(|i| i * 2).collect::<Vec<_>>());
    }

    #[test]
    fn zero_items_and_single_worker() {
        let empty: Vec<usize> = parallel_indexed(0, 3, |i| i);
        assert!(empty.is_empty());
        assert_eq!(parallel_indexed(3, 1, |i| i + 1), vec![1, 2, 3]);
    }

    #[test]
    fn more_workers_than_items() {
        assert_eq!(parallel_indexed(2, 16, |i| i), vec![0, 1]);
    }

    #[test]
    fn chunks_mut_covers_every_item_once() {
        for workers in [1, 2, 3, 8, 64] {
            let mut items: Vec<usize> = (0..37).collect();
            parallel_chunks_mut(&mut items, workers, |start, chunk| {
                for (off, v) in chunk.iter_mut().enumerate() {
                    assert_eq!(*v, start + off, "start index mismatch");
                    *v += 100;
                }
            });
            assert_eq!(items, (100..137).collect::<Vec<_>>());
        }
    }

    #[test]
    fn chunks_mut_empty_input() {
        let mut items: Vec<u8> = Vec::new();
        parallel_chunks_mut(&mut items, 4, |_, _| panic!("no chunks expected"));
    }
}
