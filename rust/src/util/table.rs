//! Aligned console tables for the bench harnesses — every bench prints the
//! same rows/series the paper's corresponding table or figure reports.

/// A simple column-aligned table.
pub struct Table {
    header: Vec<String>,
    rows: Vec<Vec<String>>,
    title: String,
}

impl Table {
    /// New table with a title (e.g. "Figure 2 (bottom-left): speed-up").
    pub fn new(title: &str, header: &[&str]) -> Table {
        Table {
            title: title.to_string(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    /// Append a row (stringified cells).
    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.header.len(), "row arity mismatch");
        self.rows.push(cells.to_vec());
    }

    /// Render to a string.
    pub fn render(&self) -> String {
        let ncol = self.header.len();
        let mut width = vec![0usize; ncol];
        for (i, h) in self.header.iter().enumerate() {
            width[i] = h.len();
        }
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                width[i] = width[i].max(c.len());
            }
        }
        let sep: String = width.iter().map(|w| "-".repeat(w + 2)).collect::<Vec<_>>().join("+");
        let fmt_row = |cells: &[String]| {
            cells
                .iter()
                .enumerate()
                .map(|(i, c)| format!(" {:<w$} ", c, w = width[i]))
                .collect::<Vec<_>>()
                .join("|")
        };
        let mut out = String::new();
        out.push_str(&format!("\n== {} ==\n", self.title));
        out.push_str(&fmt_row(&self.header));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out
    }

    /// Render and print to stdout.
    pub fn print(&self) {
        print!("{}", self.render());
    }

    /// Render as a small JSON object (no serde in the offline crate set):
    /// `{"title": ..., "header": [...], "rows": [[...]]}` — the machine
    /// half of the bench output; the CI smoke jobs upload these as
    /// `BENCH_*.json` workflow artifacts.
    pub fn to_json(&self) -> String {
        let arr = |cells: &[String]| -> String {
            let quoted: Vec<String> =
                cells.iter().map(|c| format!("\"{}\"", json_escape(c))).collect();
            format!("[{}]", quoted.join(","))
        };
        let rows: Vec<String> = self.rows.iter().map(|r| arr(r)).collect();
        format!(
            "{{\"title\":\"{}\",\"header\":{},\"rows\":[{}]}}",
            json_escape(&self.title),
            arr(&self.header),
            rows.join(",")
        )
    }
}

/// Minimal JSON string escaping (quotes, backslashes, control chars).
/// Public because it is the crate's one escaping routine: `Table::to_json`
/// (the bench artifacts), the CLI `--json` mode and the serve protocol
/// ([`crate::serve::protocol`]) all emit through it, so every JSON the
/// repo produces shares one serialization surface.
pub fn json_escape(s: &str) -> String {
    let mut out = String::with_capacity(s.len() + 2);
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Render an `f64` as a JSON number token. Rust's `Display` for finite
/// floats is the shortest round-trippable form, which is valid JSON;
/// non-finite values (which JSON cannot carry) become `null`.
pub fn json_f64(v: f64) -> String {
    if v.is_finite() {
        format!("{v}")
    } else {
        "null".to_string()
    }
}

/// Format a float with fixed decimals (bench output convention).
pub fn f(x: f64, decimals: usize) -> String {
    format!("{:.*}", decimals, x)
}

/// Format seconds human-readably (µs/ms/s).
pub fn secs(t: f64) -> String {
    if t < 1e-3 {
        format!("{:.1}µs", t * 1e6)
    } else if t < 1.0 {
        format!("{:.2}ms", t * 1e3)
    } else if t < 120.0 {
        format!("{:.2}s", t)
    } else {
        format!("{:.1}min", t / 60.0)
    }
}

/// Render a terminal histogram (for Figure 4's degree distributions).
pub fn histogram(title: &str, values: &[usize], bins: usize) -> String {
    let mut out = format!("\n== {title} ==\n");
    if values.is_empty() {
        out.push_str("(empty)\n");
        return out;
    }
    let max = *values.iter().max().unwrap();
    let lo = *values.iter().min().unwrap();
    let width = ((max - lo + 1) as f64 / bins as f64).ceil().max(1.0) as usize;
    let mut counts = vec![0usize; bins];
    for &v in values {
        let b = ((v - lo) / width).min(bins - 1);
        counts[b] += 1;
    }
    let peak = counts.iter().copied().max().unwrap().max(1);
    for (b, &c) in counts.iter().enumerate() {
        let bar = "#".repeat((c * 50 + peak - 1) / peak);
        let a = lo + b * width;
        let z = lo + (b + 1) * width - 1;
        out.push_str(&format!("{:>4}-{:<4} |{:<50}| {}\n", a, z, bar, c));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("demo", &["name", "value"]);
        t.row(&["a".into(), "1".into()]);
        t.row(&["longer".into(), "22".into()]);
        let s = t.render();
        assert!(s.contains("demo"));
        assert!(s.contains("longer"));
        // all data lines equal width
        let lines: Vec<&str> = s.lines().filter(|l| l.contains('|')).collect();
        assert!(lines.windows(2).all(|w| w[0].len() == w[1].len()));
    }

    #[test]
    #[should_panic]
    fn arity_checked() {
        let mut t = Table::new("demo", &["a", "b"]);
        t.row(&["only-one".into()]);
    }

    #[test]
    fn histogram_covers_all() {
        let h = histogram("deg", &[0, 1, 1, 2, 5, 9], 3);
        assert!(h.contains("deg"));
        // total count preserved
        let total: usize = h
            .lines()
            .filter_map(|l| l.rsplit('|').next().and_then(|c| c.trim().parse::<usize>().ok()))
            .sum();
        assert_eq!(total, 6);
    }

    #[test]
    fn json_shape_and_escaping() {
        let mut t = Table::new("ti\"tle", &["a", "b"]);
        t.row(&["x\\y".into(), "1".into()]);
        let j = t.to_json();
        assert!(j.starts_with('{') && j.ends_with('}'));
        assert!(j.contains("\"title\":\"ti\\\"tle\""), "{j}");
        assert!(j.contains("\"header\":[\"a\",\"b\"]"), "{j}");
        assert!(j.contains("\"rows\":[[\"x\\\\y\",\"1\"]]"), "{j}");
    }

    #[test]
    fn json_f64_tokens() {
        assert_eq!(json_f64(1.5), "1.5");
        assert_eq!(json_f64(-0.25), "-0.25");
        assert_eq!(json_f64(f64::NAN), "null");
        assert_eq!(json_f64(f64::INFINITY), "null");
        // shortest-roundtrip: parses back to the identical bits
        let v = 0.1f64 + 0.2f64;
        assert_eq!(json_f64(v).parse::<f64>().unwrap(), v);
    }

    #[test]
    fn secs_units() {
        assert!(secs(2e-5).ends_with("µs"));
        assert!(secs(0.02).ends_with("ms"));
        assert!(secs(2.0).ends_with('s'));
        assert!(secs(300.0).ends_with("min"));
    }
}
