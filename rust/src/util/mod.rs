//! Shared infrastructure: errors, PRNG, property-testing mini-framework,
//! CLI parsing, console tables, timing.
//!
//! Everything here is hand-rolled because the build is fully offline and
//! the vendored crate set does not include the usual suspects
//! (rand/clap/criterion/proptest) — see DESIGN.md §Toolchain constraints.

pub mod error;
pub mod rng;
pub mod pool;
pub mod prop;
pub mod cli;
pub mod table;
pub mod timer;

pub use error::{Error, Result};
