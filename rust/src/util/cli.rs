//! Minimal argv parser for the `alingam` binary, examples, and bench
//! harnesses (clap is not in the offline crate set).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional args, and
//! auto-generated `--help` from registered option descriptions.

use std::collections::BTreeMap;

/// Declarative description of one option (for --help).
#[derive(Clone)]
pub struct OptSpec {
    pub name: &'static str,
    pub help: &'static str,
    pub default: Option<String>,
}

/// Parsed command line.
pub struct Args {
    opts: BTreeMap<String, String>,
    flags: Vec<String>,
    positional: Vec<String>,
    specs: Vec<OptSpec>,
    prog: String,
    about: &'static str,
}

impl Args {
    /// Parse `std::env::args()` minus the program name.
    pub fn parse(about: &'static str, specs: &[OptSpec]) -> Args {
        let mut it = std::env::args();
        let prog = it.next().unwrap_or_else(|| "alingam".into());
        Self::parse_from(prog, it.collect(), about, specs)
    }

    /// Parse an explicit vector (testable).
    pub fn parse_from(
        prog: String,
        argv: Vec<String>,
        about: &'static str,
        specs: &[OptSpec],
    ) -> Args {
        let mut opts = BTreeMap::new();
        let mut flags = Vec::new();
        let mut positional = Vec::new();
        let takes_value = |name: &str| specs.iter().any(|s| s.name == name);
        let mut i = 0;
        while i < argv.len() {
            let a = &argv[i];
            if let Some(stripped) = a.strip_prefix("--") {
                if let Some(eq) = stripped.find('=') {
                    opts.insert(stripped[..eq].to_string(), stripped[eq + 1..].to_string());
                } else if takes_value(stripped)
                    && i + 1 < argv.len()
                    && !argv[i + 1].starts_with("--")
                {
                    // only options declared in the spec consume a value;
                    // unknown --names are flags (so `--verbose run` keeps
                    // `run` positional)
                    opts.insert(stripped.to_string(), argv[i + 1].clone());
                    i += 1;
                } else {
                    flags.push(stripped.to_string());
                }
            } else {
                positional.push(a.clone());
            }
            i += 1;
        }
        let args = Args { opts, flags, positional, specs: specs.to_vec(), prog, about };
        if args.flag("help") {
            args.print_help();
            std::process::exit(0);
        }
        args
    }

    /// Render --help text.
    pub fn print_help(&self) {
        println!("{} — {}\n", self.prog, self.about);
        println!("options:");
        for s in &self.specs {
            let def = s.default.as_deref().map(|d| format!(" [default: {d}]")).unwrap_or_default();
            println!("  --{:<18} {}{}", s.name, s.help, def);
        }
        println!("  --{:<18} {}", "help", "show this message");
    }

    /// Boolean flag presence.
    pub fn flag(&self, name: &str) -> bool {
        self.flags.iter().any(|f| f == name)
    }

    /// Was this option given explicitly on the command line (as opposed
    /// to falling back to its spec default)? Lets a subcommand switch
    /// behavior on an option that also has a default — e.g. `watch`
    /// goes remote only when `--addr` was actually typed.
    pub fn provided(&self, name: &str) -> bool {
        self.opts.contains_key(name)
    }

    /// String option (explicit or spec default).
    pub fn get(&self, name: &str) -> Option<String> {
        self.opts.get(name).cloned().or_else(|| {
            self.specs.iter().find(|s| s.name == name).and_then(|s| s.default.clone())
        })
    }

    /// Required string option.
    pub fn req(&self, name: &str) -> String {
        self.get(name).unwrap_or_else(|| {
            self.print_help();
            panic!("missing required option --{name}");
        })
    }

    /// Typed option with default handling via the spec.
    pub fn get_as<T: std::str::FromStr>(&self, name: &str) -> Option<T>
    where
        T::Err: std::fmt::Debug,
    {
        self.get(name).map(|v| {
            v.parse()
                .unwrap_or_else(|e| panic!("--{name}={v} is not a valid value: {e:?}"))
        })
    }

    /// usize option, panicking if absent and no default.
    pub fn usize(&self, name: &str) -> usize {
        self.get_as(name).unwrap_or_else(|| panic!("missing --{name}"))
    }

    /// f64 option, panicking if absent and no default.
    pub fn f64(&self, name: &str) -> f64 {
        self.get_as(name).unwrap_or_else(|| panic!("missing --{name}"))
    }

    /// First positional argument (subcommand).
    pub fn positional(&self, i: usize) -> Option<&str> {
        self.positional.get(i).map(|s| s.as_str())
    }
}

/// Shorthand spec constructor.
pub fn opt(name: &'static str, help: &'static str, default: Option<&str>) -> OptSpec {
    OptSpec { name, help, default: default.map(|s| s.to_string()) }
}

/// The shared `--engine` option: one spec so the binary, examples and
/// benches advertise the same engine grammar. The multi-threaded
/// `parallel` engine is the default CPU path (`parallel:N` pins the
/// worker count; bare `parallel` sizes the pool to the machine).
pub fn engine_opt() -> OptSpec {
    opt(
        "engine",
        "ordering engine: sequential|vectorized|parallel[:N]|pruned[:N]|partition[:B]|xla",
        Some("parallel"),
    )
}

/// Option specs for the `serve`/`client` subcommands — one shared list
/// so the binary and any future driver advertise the same grammar.
/// (`--json` and `--log-json`, being bare flags, are deliberately not
/// `OptSpec`s: specs consume a following value, which would swallow a
/// positional subcommand.)
pub fn serve_opts() -> Vec<OptSpec> {
    vec![
        opt("addr", "serve/client: TCP address (port 0 picks a free port)", Some("127.0.0.1:0")),
        opt("serve-workers", "serve: worker threads (0 = per-core, capped at 4)", Some("2")),
        opt("queue-cap", "serve: job-queue capacity (backpressure past it)", Some("64")),
        opt("cache-entries", "serve: result-cache capacity (0 disables)", Some("32")),
        opt("fuse-wait-ms", "serve: fusion-window wait for same-shape peers (0 = none)", Some("0")),
        opt("max-batch", "serve: most fits one batched session may fuse (1 disables)", Some("8")),
        opt("http-addr", "serve: optional HTTP/1.1 + SSE listener address", None),
        opt("shards", "serve: child server processes routed by panel hash (0/1 = in-process)", Some("0")),
        opt("cache-dir", "serve: directory for the disk-persistent result cache", None),
        opt("ready-fd", "serve: write 'ready' to this fd once all listeners are bound (unix)", None),
        opt("job-id", "client: job id echoed on response frames", Some("job-1")),
        opt("csv", "client: server-side CSV path instead of an inline panel", None),
        opt("threshold", "client bootstrap: stable-edge probability cutoff", Some("0.5")),
        opt("timeout-ms", "client/watch: connect and read deadline in ms (0 = none)", Some("0")),
        opt("window", "watch: sliding-window size in frames", Some("256")),
        opt("resync-every", "watch: full resync every K frames (0 = drift-only)", Some("64")),
        opt("drift-tol", "watch: relative moment-drift bound that forces a resync", Some("1e-8")),
        opt("edge-threshold", "watch: |beta| threshold for streamed adjacency edges", Some("0.05")),
        opt("log-level", "serve: stderr log level (error|warn|info|debug)", Some("warn")),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(argv: &[&str]) -> Args {
        Args::parse_from(
            "test".into(),
            argv.iter().map(|s| s.to_string()).collect(),
            "test tool",
            &[opt("dims", "number of variables", Some("10")), opt("out", "output path", None)],
        )
    }

    #[test]
    fn key_value_forms() {
        let a = parse(&["--dims", "32", "--out=/tmp/x", "--verbose", "run"]);
        assert_eq!(a.usize("dims"), 32);
        assert_eq!(a.req("out"), "/tmp/x");
        assert!(a.flag("verbose"));
        assert_eq!(a.positional(0), Some("run"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse(&[]);
        assert_eq!(a.usize("dims"), 10);
        assert_eq!(a.get("out"), None);
    }

    #[test]
    fn flags_do_not_eat_following_option() {
        let a = parse(&["--verbose", "--dims", "7"]);
        assert!(a.flag("verbose"));
        assert_eq!(a.usize("dims"), 7);
    }

    #[test]
    fn serve_opts_carry_defaults() {
        let specs = serve_opts();
        let a = Args::parse_from("test".into(), vec![], "t", &specs);
        assert_eq!(a.req("addr"), "127.0.0.1:0");
        assert_eq!(a.usize("serve-workers"), 2);
        assert_eq!(a.usize("queue-cap"), 64);
        assert_eq!(a.usize("cache-entries"), 32);
        assert_eq!(a.usize("fuse-wait-ms"), 0);
        assert_eq!(a.usize("max-batch"), 8);
        assert_eq!(a.usize("shards"), 0);
        assert_eq!(a.get("http-addr"), None);
        assert_eq!(a.get("cache-dir"), None);
        assert_eq!(a.get("ready-fd"), None);
        assert_eq!(a.get("csv"), None);
        assert_eq!(a.usize("timeout-ms"), 0);
        assert_eq!(a.usize("window"), 256);
        assert_eq!(a.usize("resync-every"), 64);
        assert!((a.f64("drift-tol") - 1e-8).abs() < 1e-20);
        assert!((a.f64("edge-threshold") - 0.05).abs() < 1e-12);
        assert_eq!(a.req("log-level"), "warn");
        assert!(!a.flag("log-json"), "log-json is a bare flag, absent by default");
    }

    #[test]
    fn provided_distinguishes_explicit_options_from_defaults() {
        let specs = serve_opts();
        let a = Args::parse_from(
            "test".into(),
            vec!["--addr".into(), "127.0.0.1:7777".into()],
            "t",
            &specs,
        );
        assert!(a.provided("addr"));
        assert!(!a.provided("window"));
        // defaults still resolve through get() either way
        assert_eq!(a.usize("window"), 256);
    }

    #[test]
    fn engine_opt_defaults_to_parallel() {
        let spec = engine_opt();
        assert_eq!(spec.name, "engine");
        assert_eq!(spec.default.as_deref(), Some("parallel"));
        let a = Args::parse_from("test".into(), vec![], "t", &[engine_opt()]);
        assert_eq!(a.req("engine"), "parallel");
    }
}
