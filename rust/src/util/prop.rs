//! Minimal property-testing framework (proptest is not in the offline
//! crate set). Supports seeded generators, configurable case counts, and
//! failure reporting with the offending seed so a case can be replayed.
//!
//! ```no_run
//! // (no_run: doctest binaries don't carry the xla_extension rpath)
//! use alingam::util::prop::{props, Gen};
//! props("addition commutes", 64, |g| {
//!     let a = g.f64_in(-1e3, 1e3);
//!     let b = g.f64_in(-1e3, 1e3);
//!     assert_eq!(a + b, b + a);
//! });
//! ```

use super::rng::Pcg64;

/// Source of random test inputs for one property case.
pub struct Gen {
    rng: Pcg64,
    /// Seed of this particular case (printed on failure).
    pub case_seed: u64,
}

impl Gen {
    /// Integer in [lo, hi] inclusive.
    pub fn usize_in(&mut self, lo: usize, hi: usize) -> usize {
        lo + self.rng.below(hi - lo + 1)
    }

    /// f64 uniform in [lo, hi).
    pub fn f64_in(&mut self, lo: f64, hi: f64) -> f64 {
        self.rng.uniform(lo, hi)
    }

    /// Standard normal draw.
    pub fn normal(&mut self) -> f64 {
        self.rng.normal()
    }

    /// Bernoulli(p).
    pub fn bool_p(&mut self, p: f64) -> bool {
        self.rng.bernoulli(p)
    }

    /// Vector of standard normals.
    pub fn normal_vec(&mut self, n: usize) -> Vec<f64> {
        (0..n).map(|_| self.rng.normal()).collect()
    }

    /// Vector of uniforms in [lo, hi).
    pub fn uniform_vec(&mut self, n: usize, lo: f64, hi: f64) -> Vec<f64> {
        (0..n).map(|_| self.rng.uniform(lo, hi)).collect()
    }

    /// Random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        self.rng.permutation(n)
    }

    /// Borrow the underlying generator for richer draws.
    pub fn rng(&mut self) -> &mut Pcg64 {
        &mut self.rng
    }
}

/// Run `cases` instances of a property. Panics (with the case seed) on the
/// first failing case. `ALINGAM_PROP_SEED` replays a specific case.
pub fn props<F: FnMut(&mut Gen)>(name: &str, cases: u32, mut f: F) {
    if let Ok(s) = std::env::var("ALINGAM_PROP_SEED") {
        let seed: u64 = s.parse().expect("ALINGAM_PROP_SEED must be a u64");
        let mut g = Gen { rng: Pcg64::seed_from_u64(seed), case_seed: seed };
        f(&mut g);
        return;
    }
    let mut meta = Pcg64::seed_from_u64(0x5eed ^ fnv1a(name));
    for case in 0..cases {
        let case_seed = meta.next_u64();
        let mut g = Gen { rng: Pcg64::seed_from_u64(case_seed), case_seed };
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| f(&mut g)));
        if let Err(e) = result {
            eprintln!(
                "property '{name}' failed at case {case}/{cases} \
                 (replay with ALINGAM_PROP_SEED={case_seed})"
            );
            std::panic::resume_unwind(e);
        }
    }
}

/// FNV-1a hash, used to derive a per-property meta-seed from its name so
/// different properties explore different input streams.
fn fnv1a(s: &str) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in s.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0;
        props("trivially true", 32, |_| count += 1);
        assert_eq!(count, 32);
    }

    #[test]
    #[should_panic]
    fn failing_property_panics() {
        props("always false", 8, |g| {
            let x = g.f64_in(0.0, 1.0);
            assert!(x < -1.0);
        });
    }

    #[test]
    fn gen_ranges_hold() {
        props("gen ranges", 64, |g| {
            let u = g.usize_in(3, 9);
            assert!((3..=9).contains(&u));
            let f = g.f64_in(-2.0, 5.0);
            assert!((-2.0..5.0).contains(&f));
        });
    }
}
