//! Library-wide error type.

use thiserror::Error;

/// Errors surfaced by the AcceleratedLiNGAM library.
#[derive(Error, Debug)]
pub enum Error {
    /// Shape or dimension mismatch in a linear-algebra or dataset op.
    #[error("shape mismatch: {0}")]
    Shape(String),

    /// Numerical failure (singular matrix, non-finite value, ...).
    #[error("numerical error: {0}")]
    Numerical(String),

    /// A caller violated an API precondition.
    #[error("invalid argument: {0}")]
    InvalidArgument(String),

    /// Problems loading/compiling/executing AOT artifacts via PJRT.
    #[error("runtime error: {0}")]
    Runtime(String),

    /// Artifact registry could not satisfy a shape request.
    #[error("no artifact bucket for shape n={n}, d={d} (available: {available})")]
    NoArtifact { n: usize, d: usize, available: String },

    /// Underlying XLA/PJRT failure.
    #[error("xla: {0}")]
    Xla(String),

    /// A job was cooperatively canceled (checked at step/resample
    /// boundaries by the long-running drivers; the serve layer maps this
    /// to a `canceled` protocol event rather than an error).
    #[error("canceled: {0}")]
    Canceled(String),

    /// I/O failure.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),

    /// Data parsing failure (CSV etc.).
    #[error("parse error: {0}")]
    Parse(String),
}

#[cfg(feature = "xla")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Library-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
