//! Timing utilities: a scoped stage profiler (used to reproduce the
//! paper's Figure-2 "96% of runtime is causal ordering" measurement) and a
//! small bench runner (criterion is not in the offline crate set).

use std::collections::BTreeMap;
use std::time::{Duration, Instant};

/// Accumulates wall-clock time per named stage.
#[derive(Default, Debug, Clone)]
pub struct StageProfile {
    totals: BTreeMap<String, Duration>,
    counts: BTreeMap<String, u64>,
}

impl StageProfile {
    pub fn new() -> Self {
        Self::default()
    }

    /// Time a closure under a stage name.
    pub fn time<T>(&mut self, stage: &str, f: impl FnOnce() -> T) -> T {
        let t0 = Instant::now();
        let out = f();
        self.add(stage, t0.elapsed());
        out
    }

    /// Record an externally-measured duration.
    pub fn add(&mut self, stage: &str, d: Duration) {
        *self.totals.entry(stage.to_string()).or_default() += d;
        *self.counts.entry(stage.to_string()).or_default() += 1;
    }

    /// Merge another profile into this one.
    pub fn merge(&mut self, other: &StageProfile) {
        for (k, v) in &other.totals {
            *self.totals.entry(k.clone()).or_default() += *v;
        }
        for (k, c) in &other.counts {
            *self.counts.entry(k.clone()).or_default() += *c;
        }
    }

    /// Total seconds across all stages.
    pub fn total_secs(&self) -> f64 {
        self.totals.values().map(|d| d.as_secs_f64()).sum()
    }

    /// Seconds spent in one stage.
    pub fn secs(&self, stage: &str) -> f64 {
        self.totals.get(stage).map(|d| d.as_secs_f64()).unwrap_or(0.0)
    }

    /// Fraction of total time spent in one stage (the Figure-2 number).
    ///
    /// Always finite, never NaN. When the profile has accumulated zero
    /// total duration but *has* recorded entries (stages timed below
    /// clock resolution — common for micro panels), the fraction falls
    /// back to the stage's share of recorded entries, so a stage that
    /// was genuinely exercised does not read as 0.0 just because it was
    /// fast. An empty profile (no entries anywhere) reports 0.0.
    pub fn fraction(&self, stage: &str) -> f64 {
        let t = self.total_secs();
        if t > 0.0 {
            return self.secs(stage) / t;
        }
        let entries: u64 = self.counts.values().sum();
        if entries == 0 {
            0.0
        } else {
            self.count(stage) as f64 / entries as f64
        }
    }

    /// Invocation count of one stage.
    pub fn count(&self, stage: &str) -> u64 {
        self.counts.get(stage).copied().unwrap_or(0)
    }

    /// (stage, seconds, fraction) rows sorted by time desc.
    pub fn rows(&self) -> Vec<(String, f64, f64)> {
        let total = self.total_secs().max(1e-12);
        let mut rows: Vec<_> = self
            .totals
            .iter()
            .map(|(k, d)| (k.clone(), d.as_secs_f64(), d.as_secs_f64() / total))
            .collect();
        rows.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
        rows
    }
}

/// Result of a [`bench`] run.
#[derive(Debug, Clone, Copy)]
pub struct BenchStats {
    pub iters: u32,
    pub mean_secs: f64,
    pub min_secs: f64,
    pub max_secs: f64,
}

/// Measure a closure: warm up once, then run up to `max_iters` iterations
/// or `budget` seconds, whichever first; report mean/min/max.
pub fn bench<T>(max_iters: u32, budget_secs: f64, mut f: impl FnMut() -> T) -> BenchStats {
    // warmup
    std::hint::black_box(f());
    let mut times = Vec::new();
    let start = Instant::now();
    for _ in 0..max_iters.max(1) {
        let t0 = Instant::now();
        std::hint::black_box(f());
        times.push(t0.elapsed().as_secs_f64());
        if start.elapsed().as_secs_f64() > budget_secs {
            break;
        }
    }
    let n = times.len() as f64;
    BenchStats {
        iters: times.len() as u32,
        mean_secs: times.iter().sum::<f64>() / n,
        min_secs: times.iter().cloned().fold(f64::INFINITY, f64::min),
        max_secs: times.iter().cloned().fold(0.0, f64::max),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn profile_accumulates() {
        let mut p = StageProfile::new();
        p.time("a", || std::thread::sleep(Duration::from_millis(2)));
        p.time("a", || std::thread::sleep(Duration::from_millis(2)));
        p.time("b", || ());
        assert_eq!(p.count("a"), 2);
        assert!(p.secs("a") >= 0.004);
        assert!(p.fraction("a") > 0.9);
        let rows = p.rows();
        assert_eq!(rows[0].0, "a");
    }

    #[test]
    fn bench_reports_sane_stats() {
        let s = bench(16, 0.2, || (0..1000).sum::<u64>());
        assert!(s.iters >= 1);
        assert!(s.min_secs <= s.mean_secs && s.mean_secs <= s.max_secs);
    }

    #[test]
    fn fraction_is_nan_free_on_zero_total() {
        // empty profile: nothing recorded anywhere → 0.0, not NaN
        let empty = StageProfile::new();
        assert_eq!(empty.fraction("ordering"), 0.0);
        // zero-duration entries: stages were exercised but the clock
        // read 0 — fraction falls back to the entry-count share
        let mut p = StageProfile::new();
        p.add("ordering", Duration::ZERO);
        p.add("ordering", Duration::ZERO);
        p.add("regression", Duration::ZERO);
        let f = p.fraction("ordering");
        assert!(f.is_finite());
        assert!((f - 2.0 / 3.0).abs() < 1e-12, "got {f}");
        assert_eq!(p.fraction("absent"), 0.0);
        // once real time lands, the time-weighted fraction takes over
        p.add("ordering", Duration::from_millis(3));
        assert!((p.fraction("ordering") - 1.0).abs() < 1e-12);
    }

    #[test]
    fn merge_sums() {
        let mut a = StageProfile::new();
        a.add("x", Duration::from_millis(5));
        let mut b = StageProfile::new();
        b.add("x", Duration::from_millis(7));
        a.merge(&b);
        assert!(a.secs("x") >= 0.012);
        assert_eq!(a.count("x"), 2);
    }
}
