//! PJRT device wrapper: one CPU client + a compile-once executable cache.
//!
//! Only the [`super::executor`] thread constructs this type; everything
//! else goes through the executor's channel API.

use crate::util::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A PJRT client plus compiled-executable cache keyed by artifact path.
pub struct Device {
    client: xla::PjRtClient,
    cache: HashMap<PathBuf, xla::PjRtLoadedExecutable>,
    /// Cumulative compile seconds (reported in bench output).
    pub compile_secs: f64,
}

impl Device {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Device> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Device { client, cache: HashMap::new(), compile_secs: 0.0 })
    }

    /// Human-readable platform string.
    pub fn platform(&self) -> String {
        format!("{} ({} devices)", self.client.platform_name(), self.client.device_count())
    }

    /// Compile (or fetch from cache) the HLO-text artifact at `path`.
    pub fn executable(&mut self, path: &Path) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(path) {
            let t0 = std::time::Instant::now();
            let proto = xla::HloModuleProto::from_text_file(path).map_err(|e| {
                Error::Runtime(format!("loading {}: {e}", path.display()))
            })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.compile_secs += t0.elapsed().as_secs_f64();
            self.cache.insert(path.to_path_buf(), exe);
        }
        Ok(&self.cache[path])
    }

    /// Execute an artifact on f32 input literals; returns the decomposed
    /// output tuple (jax artifacts are lowered with `return_tuple=True`).
    pub fn run(&mut self, path: &Path, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(path)?;
        let result = exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Number of cached executables.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }
}
