//! PJRT device wrapper: one CPU client + a compile-once executable cache.
//!
//! Only the [`super::executor`] thread constructs this type; everything
//! else goes through the executor's channel API.

use crate::util::{Error, Result};
use std::collections::HashMap;
use std::path::{Path, PathBuf};

/// A PJRT client plus compiled-executable cache keyed by artifact path.
pub struct Device {
    client: xla::PjRtClient,
    cache: HashMap<PathBuf, xla::PjRtLoadedExecutable>,
    /// Cumulative compile seconds (reported in bench output).
    pub compile_secs: f64,
}

impl Device {
    /// Create the CPU PJRT client.
    pub fn cpu() -> Result<Device> {
        let client = xla::PjRtClient::cpu()?;
        Ok(Device { client, cache: HashMap::new(), compile_secs: 0.0 })
    }

    /// Human-readable platform string.
    pub fn platform(&self) -> String {
        format!("{} ({} devices)", self.client.platform_name(), self.client.device_count())
    }

    /// Compile (or fetch from cache) the HLO-text artifact at `path`.
    pub fn executable(&mut self, path: &Path) -> Result<&xla::PjRtLoadedExecutable> {
        if !self.cache.contains_key(path) {
            let t0 = std::time::Instant::now();
            let proto = xla::HloModuleProto::from_text_file(path).map_err(|e| {
                Error::Runtime(format!("loading {}: {e}", path.display()))
            })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self.client.compile(&comp)?;
            self.compile_secs += t0.elapsed().as_secs_f64();
            self.cache.insert(path.to_path_buf(), exe);
        }
        Ok(&self.cache[path])
    }

    /// Execute an artifact on f32 input literals; returns the decomposed
    /// output tuple (jax artifacts are lowered with `return_tuple=True`).
    pub fn run(&mut self, path: &Path, inputs: &[xla::Literal]) -> Result<Vec<xla::Literal>> {
        let exe = self.executable(path)?;
        let result = exe.execute::<xla::Literal>(inputs)?;
        let lit = result[0][0].to_literal_sync()?;
        Ok(lit.to_tuple()?)
    }

    /// Transfer a host literal to the device (the session path's
    /// explicit-upload half; the output stays wherever the caller puts
    /// it).
    pub fn upload(&self, lit: &xla::Literal) -> Result<xla::PjRtBuffer> {
        Ok(self.client.buffer_from_host_literal(None, lit)?)
    }

    /// Execute a **single-output** artifact (non-tuple root — the
    /// `session_*` kinds) entirely over device buffers; the returned
    /// buffer is still resident and can feed the next execution.
    pub fn execute_buffers(
        &mut self,
        path: &Path,
        args: &[&xla::PjRtBuffer],
    ) -> Result<xla::PjRtBuffer> {
        let exe = self.executable(path)?;
        let mut per_device = exe.execute_b(args)?;
        if per_device.is_empty() || per_device[0].is_empty() {
            return Err(Error::Runtime(format!(
                "artifact {} produced no output buffer",
                path.display()
            )));
        }
        let mut outs = per_device.swap_remove(0);
        if outs.len() != 1 {
            return Err(Error::Runtime(format!(
                "artifact {} produced {} outputs (session artifacts must have a \
                 single non-tuple root)",
                path.display(),
                outs.len()
            )));
        }
        Ok(outs.swap_remove(0))
    }

    /// Number of cached executables.
    pub fn cached(&self) -> usize {
        self.cache.len()
    }
}
