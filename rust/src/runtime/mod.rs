//! The PJRT runtime: loads the AOT artifacts produced by
//! `python/compile/aot.py` (HLO text) and executes them from the L3 hot
//! path. Python never runs here.
//!
//! - [`registry`] — the artifact manifest and shape-bucket selection.
//! - [`device`] — a PJRT CPU client + executable cache (compile once per
//!   bucket, execute many).
//! - [`executor`] — a dedicated device thread with a job queue, the
//!   coordinator's stand-in for a CUDA stream. XLA handles are raw
//!   pointers (!Send), so all device interaction is confined to this
//!   thread; the rest of the system talks to it through channels, which
//!   also makes the engine shareable across coordinator workers. The
//!   thread also owns the **resident-buffer table**: single-output
//!   session artifacts can keep their output on the device (`BufferId`
//!   handles) and feed it back into later calls without any transfer.
//! - [`engine`] — `XlaEngine`: the `OrderingEngine` backed by the AOT
//!   artifacts — the device-resident session triple by default
//!   (`crate::lingam::XlaSession`), the fused `order_step` as the
//!   stateless baseline/fallback.

// The PJRT client wrapper is the only module that touches the `xla`
// crate; without the `xla` feature it is compiled out and
// `DeviceExecutor::start` reports the runtime as unavailable (every
// caller already degrades gracefully when artifacts/devices are absent).
#[cfg(feature = "xla")]
pub mod device;
pub mod engine;
pub mod executor;
pub mod registry;

pub use engine::XlaEngine;
pub use executor::{ArgValue, BufferId, DeviceExecutor, DeviceStats, HostArray, OutValue};
pub use registry::{ArtifactKind, ArtifactRegistry, Bucket};

/// Default artifact directory relative to the repo root.
pub const DEFAULT_ARTIFACT_DIR: &str = "artifacts";

/// Locate the artifact directory: `$ALINGAM_ARTIFACTS`, else `artifacts/`
/// relative to the current dir or the crate root (so tests work from
/// anywhere inside the repo).
pub fn artifact_dir() -> std::path::PathBuf {
    if let Ok(p) = std::env::var("ALINGAM_ARTIFACTS") {
        return p.into();
    }
    let cwd = std::path::PathBuf::from(DEFAULT_ARTIFACT_DIR);
    if cwd.join("manifest.txt").exists() {
        return cwd;
    }
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join(DEFAULT_ARTIFACT_DIR)
}
