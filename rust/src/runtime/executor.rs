//! The device executor: a dedicated thread that owns all XLA handles and
//! serializes artifact executions — the L3 analogue of a CUDA stream.
//!
//! XLA wrapper types hold raw pointers and are not `Send`; confining them
//! to one thread makes the rest of the system (coordinator workers,
//! engines, benches) free to share a cheap cloneable handle.
//!
//! Two execution surfaces:
//!
//! - [`DeviceExecutor::run`] — the legacy tuple-root artifacts
//!   (`order_scores`/`order_step`/`var_fit`): plain host arrays in, the
//!   whole decomposed output tuple downloaded back out.
//! - [`DeviceExecutor::run_resident`] / [`DeviceExecutor::run_fetch`] —
//!   the single-output session artifacts. Arguments mix host arrays
//!   (uploaded for this call) with [`BufferId`] handles to buffers
//!   already resident on the device; `run_resident` keeps the output on
//!   the device and returns a new handle, `run_fetch` downloads it. The
//!   device thread owns the handle table, so buffer lifetime is tied to
//!   the thread exactly like every other XLA object; callers free a
//!   handle with [`DeviceExecutor::free_buffer`] (the `XlaSession` drops
//!   its state this way).
//!
//! Transfer accounting ([`DeviceStats`]) counts only real host↔device
//! traffic: resident arguments and resident outputs move no bytes. The
//! runtime-roundtrip suite asserts the session contract on top of this —
//! one panel upload per fit, O(d) per step.

#[cfg(feature = "xla")]
use super::device::Device;
use crate::util::{Error, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

/// A host-side f32 tensor (inputs are always f32; jax artifacts are
/// compiled at f32, the TPU-native width).
#[derive(Clone, Debug)]
pub struct HostArray {
    pub dims: Vec<i64>,
    pub data: Vec<f32>,
}

impl HostArray {
    pub fn new(dims: Vec<i64>, data: Vec<f32>) -> HostArray {
        debug_assert_eq!(dims.iter().product::<i64>() as usize, data.len());
        HostArray { dims, data }
    }

    pub fn vector(data: Vec<f32>) -> HostArray {
        let n = data.len() as i64;
        HostArray { dims: vec![n], data }
    }
}

/// One output of an artifact execution.
#[derive(Clone, Debug)]
pub enum OutValue {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    I32 { dims: Vec<usize>, data: Vec<i32> },
}

impl OutValue {
    /// The f32 payload (errors if the output is integer).
    pub fn f32s(&self) -> Result<&[f32]> {
        match self {
            OutValue::F32 { data, .. } => Ok(data),
            OutValue::I32 { .. } => Err(Error::Runtime("expected f32 output".into())),
        }
    }

    /// A scalar i32 output (e.g. the chosen index of `order_step`).
    pub fn i32_scalar(&self) -> Result<i32> {
        match self {
            OutValue::I32 { data, .. } if data.len() == 1 => Ok(data[0]),
            other => Err(Error::Runtime(format!("expected i32 scalar, got {other:?}"))),
        }
    }
}

/// Opaque handle to a buffer resident on the device (e.g. the packed
/// ordering-session state). Owned by the device thread; obtained from
/// [`DeviceExecutor::run_resident`] and released with
/// [`DeviceExecutor::free_buffer`].
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub struct BufferId(u64);

/// One argument of a raw-root artifact execution: a host array uploaded
/// for this call, or a buffer already resident on the device (no
/// transfer).
#[derive(Clone, Debug)]
pub enum ArgValue {
    Host(HostArray),
    Device(BufferId),
}

/// Where a raw-root execution's single output went.
// without the xla feature the producing side (run_raw_job) is compiled
// out, so the variants are matched but never constructed
#[cfg_attr(not(feature = "xla"), allow(dead_code))]
enum RawOut {
    /// Kept on the device; handle into the device thread's table.
    Resident(BufferId),
    /// Downloaded to the host.
    Host(OutValue),
}

// without the xla feature the consuming side (device_loop) is compiled
// out, so the fields are written but never read
#[cfg_attr(not(feature = "xla"), allow(dead_code))]
struct Job {
    path: PathBuf,
    inputs: Vec<HostArray>,
    reply: mpsc::Sender<Result<Vec<OutValue>>>,
}

#[cfg_attr(not(feature = "xla"), allow(dead_code))]
struct RawJob {
    path: PathBuf,
    args: Vec<ArgValue>,
    /// `true` → keep the output resident; `false` → download it.
    keep: bool,
    reply: mpsc::Sender<Result<RawOut>>,
}

enum Msg {
    Run(Job),
    RunRaw(RawJob),
    Free(BufferId),
    Platform(mpsc::Sender<String>),
    Shutdown,
}

/// Cumulative executor statistics (for the perf pass and bench reports).
#[derive(Default, Debug)]
pub struct DeviceStats {
    /// Artifact executions.
    pub calls: AtomicU64,
    /// Bytes uploaded to the device (host arguments only — resident
    /// buffers passed by handle move nothing).
    pub bytes_up: AtomicU64,
    /// Bytes downloaded (fetched outputs only — resident outputs move
    /// nothing).
    pub bytes_down: AtomicU64,
    /// Nanoseconds spent inside execute (incl. transfers).
    pub exec_nanos: AtomicU64,
    /// Device-resident buffers currently alive (leak canary for the
    /// session tests).
    pub buffers_live: AtomicU64,
}

impl DeviceStats {
    pub fn snapshot(&self) -> (u64, u64, u64, f64) {
        (
            self.calls.load(Ordering::Relaxed),
            self.bytes_up.load(Ordering::Relaxed),
            self.bytes_down.load(Ordering::Relaxed),
            self.exec_nanos.load(Ordering::Relaxed) as f64 / 1e9,
        )
    }

    /// Number of device-resident buffers currently alive.
    pub fn live_buffers(&self) -> u64 {
        self.buffers_live.load(Ordering::Relaxed)
    }
}

/// Handle to the device thread. Clone freely; drop of the last handle
/// shuts the thread down.
pub struct DeviceExecutor {
    tx: Mutex<mpsc::Sender<Msg>>,
    pub stats: Arc<DeviceStats>,
    _thread: Option<std::thread::JoinHandle<()>>,
}

impl DeviceExecutor {
    /// Spawn the device thread (creates the PJRT CPU client on it).
    ///
    /// Without the `xla` crate feature there is no PJRT client to start;
    /// the error surfaces through the same graceful-degradation paths
    /// callers already use when artifacts or devices are missing.
    #[cfg(feature = "xla")]
    pub fn start() -> Result<Arc<DeviceExecutor>> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let stats = Arc::new(DeviceStats::default());
        let stats_thread = stats.clone();
        let thread = std::thread::Builder::new()
            .name("alingam-device".into())
            .spawn(move || device_loop(rx, ready_tx, stats_thread))
            .map_err(|e| Error::Runtime(format!("spawning device thread: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Runtime("device thread died during init".into()))??;
        Ok(Arc::new(DeviceExecutor { tx: Mutex::new(tx), stats, _thread: Some(thread) }))
    }

    /// See the `xla`-feature variant above.
    #[cfg(not(feature = "xla"))]
    pub fn start() -> Result<Arc<DeviceExecutor>> {
        Err(Error::Runtime(
            "alingam was built without the `xla` feature: the PJRT runtime is \
             unavailable (rebuild with `cargo build --features xla` to execute \
             AOT artifacts)"
                .into(),
        ))
    }

    /// Execute an artifact; blocks until the result is back on the host.
    pub fn run(&self, path: PathBuf, inputs: Vec<HostArray>) -> Result<Vec<OutValue>> {
        let (reply, rx) = mpsc::channel();
        {
            let tx = self.tx.lock().expect("executor mutex");
            tx.send(Msg::Run(Job { path, inputs, reply }))
                .map_err(|_| Error::Runtime("device thread gone".into()))?;
        }
        rx.recv().map_err(|_| Error::Runtime("device thread dropped reply".into()))?
    }

    fn run_raw(&self, path: PathBuf, args: Vec<ArgValue>, keep: bool) -> Result<RawOut> {
        let (reply, rx) = mpsc::channel();
        {
            let tx = self.tx.lock().expect("executor mutex");
            tx.send(Msg::RunRaw(RawJob { path, args, keep, reply }))
                .map_err(|_| Error::Runtime("device thread gone".into()))?;
        }
        rx.recv().map_err(|_| Error::Runtime("device thread dropped reply".into()))?
    }

    /// Execute a single-output ("raw root") artifact and keep its output
    /// resident on the device. Returns the handle to pass as
    /// [`ArgValue::Device`] in later calls.
    pub fn run_resident(&self, path: PathBuf, args: Vec<ArgValue>) -> Result<BufferId> {
        match self.run_raw(path, args, true)? {
            RawOut::Resident(id) => Ok(id),
            RawOut::Host(_) => Err(Error::Runtime("resident run returned host data".into())),
        }
    }

    /// Execute a single-output artifact and download its output.
    pub fn run_fetch(&self, path: PathBuf, args: Vec<ArgValue>) -> Result<OutValue> {
        match self.run_raw(path, args, false)? {
            RawOut::Host(v) => Ok(v),
            RawOut::Resident(_) => Err(Error::Runtime("fetch run kept data resident".into())),
        }
    }

    /// Release a device-resident buffer (fire-and-forget: the free is
    /// queued behind any in-flight executions that still use it, so a
    /// `Drop` impl can call this without blocking).
    pub fn free_buffer(&self, id: BufferId) {
        if let Ok(tx) = self.tx.lock() {
            let _ = tx.send(Msg::Free(id));
        }
    }

    /// Platform description string.
    pub fn platform(&self) -> Result<String> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .expect("executor mutex")
            .send(Msg::Platform(reply))
            .map_err(|_| Error::Runtime("device thread gone".into()))?;
        rx.recv().map_err(|_| Error::Runtime("device thread dropped reply".into()))
    }
}

impl Drop for DeviceExecutor {
    fn drop(&mut self) {
        if let Ok(tx) = self.tx.lock() {
            let _ = tx.send(Msg::Shutdown);
        }
        if let Some(t) = self._thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(feature = "xla")]
fn device_loop(
    rx: mpsc::Receiver<Msg>,
    ready: mpsc::Sender<Result<()>>,
    stats: Arc<DeviceStats>,
) {
    let mut device = match Device::cpu() {
        Ok(d) => {
            let _ = ready.send(Ok(()));
            d
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    // the device thread owns every resident buffer; dropping the map on
    // shutdown releases whatever sessions leaked
    let mut buffers: std::collections::HashMap<BufferId, xla::PjRtBuffer> =
        std::collections::HashMap::new();
    let mut next_id: u64 = 1;
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Shutdown => break,
            Msg::Platform(reply) => {
                let _ = reply.send(device.platform());
            }
            Msg::Free(id) => {
                if buffers.remove(&id).is_some() {
                    stats.buffers_live.fetch_sub(1, Ordering::Relaxed);
                }
            }
            Msg::Run(job) => {
                let t0 = std::time::Instant::now();
                let result = run_job(&mut device, &job, &stats);
                stats.exec_nanos.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                stats.calls.fetch_add(1, Ordering::Relaxed);
                let _ = job.reply.send(result);
            }
            Msg::RunRaw(job) => {
                let t0 = std::time::Instant::now();
                let result = run_raw_job(&mut device, &mut buffers, &mut next_id, &job, &stats);
                stats.exec_nanos.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                stats.calls.fetch_add(1, Ordering::Relaxed);
                let _ = job.reply.send(result);
            }
        }
    }
}

/// Reshape a host array into an input literal.
#[cfg(feature = "xla")]
fn literal_of(a: &HostArray) -> Result<xla::Literal> {
    let lit = xla::Literal::vec1(&a.data);
    Ok(if a.dims.len() == 1 { lit } else { lit.reshape(&a.dims)? })
}

/// Decode a downloaded (non-tuple) literal into a host value.
#[cfg(feature = "xla")]
fn decode_literal(lit: &xla::Literal) -> Result<OutValue> {
    let shape = lit.array_shape()?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    match shape.ty() {
        xla::ElementType::F32 => Ok(OutValue::F32 { dims, data: lit.to_vec::<f32>()? }),
        xla::ElementType::S32 => Ok(OutValue::I32 { dims, data: lit.to_vec::<i32>()? }),
        other => Err(Error::Runtime(format!("unsupported output type {other:?}"))),
    }
}

#[cfg(feature = "xla")]
fn run_job(device: &mut Device, job: &Job, stats: &DeviceStats) -> Result<Vec<OutValue>> {
    let mut literals = Vec::with_capacity(job.inputs.len());
    let mut up = 0usize;
    for a in &job.inputs {
        up += a.data.len() * 4;
        literals.push(literal_of(a)?);
    }
    stats.bytes_up.fetch_add(up as u64, Ordering::Relaxed);

    let outs = device.run(&job.path, &literals)?;
    let mut values = Vec::with_capacity(outs.len());
    let mut down = 0usize;
    for lit in outs {
        down += lit.size_bytes();
        values.push(decode_literal(&lit)?);
    }
    stats.bytes_down.fetch_add(down as u64, Ordering::Relaxed);
    Ok(values)
}

/// Execute a single-output session artifact over a mix of fresh host
/// uploads and already-resident buffers.
#[cfg(feature = "xla")]
fn run_raw_job(
    device: &mut Device,
    buffers: &mut std::collections::HashMap<BufferId, xla::PjRtBuffer>,
    next_id: &mut u64,
    job: &RawJob,
    stats: &DeviceStats,
) -> Result<RawOut> {
    // upload every host argument first so the argument slice below can
    // borrow the uploads and the resident table at the same time
    let mut uploads = Vec::new();
    let mut up = 0usize;
    for a in &job.args {
        if let ArgValue::Host(h) = a {
            up += h.data.len() * 4;
            uploads.push(device.upload(&literal_of(h)?)?);
        }
    }
    stats.bytes_up.fetch_add(up as u64, Ordering::Relaxed);

    let mut next_upload = uploads.iter();
    let mut args: Vec<&xla::PjRtBuffer> = Vec::with_capacity(job.args.len());
    for a in &job.args {
        match a {
            ArgValue::Host(_) => {
                args.push(next_upload.next().expect("one upload per host arg"));
            }
            ArgValue::Device(id) => args.push(buffers.get(id).ok_or_else(|| {
                Error::Runtime(format!("stale device buffer handle {id:?}"))
            })?),
        }
    }

    let out = device.execute_buffers(&job.path, &args)?;
    if job.keep {
        let id = BufferId(*next_id);
        *next_id += 1;
        buffers.insert(id, out);
        stats.buffers_live.fetch_add(1, Ordering::Relaxed);
        Ok(RawOut::Resident(id))
    } else {
        let lit = out.to_literal_sync()?;
        stats.bytes_down.fetch_add(lit.size_bytes() as u64, Ordering::Relaxed);
        Ok(RawOut::Host(decode_literal(&lit)?))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_array_shape_check() {
        let a = HostArray::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(a.dims, vec![2, 3]);
        let v = HostArray::vector(vec![1.0, 2.0]);
        assert_eq!(v.dims, vec![2]);
    }

    #[test]
    fn outvalue_accessors() {
        let f = OutValue::F32 { dims: vec![2], data: vec![1.0, 2.0] };
        assert_eq!(f.f32s().unwrap(), &[1.0, 2.0]);
        assert!(f.i32_scalar().is_err());
        let i = OutValue::I32 { dims: vec![], data: vec![7] };
        assert_eq!(i.i32_scalar().unwrap(), 7);
        assert!(i.f32s().is_err());
    }
}
