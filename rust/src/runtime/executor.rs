//! The device executor: a dedicated thread that owns all XLA handles and
//! serializes artifact executions — the L3 analogue of a CUDA stream.
//!
//! XLA wrapper types hold raw pointers and are not `Send`; confining them
//! to one thread makes the rest of the system (coordinator workers,
//! engines, benches) free to share a cheap cloneable handle. Jobs are
//! plain host arrays in, plain host arrays out.

#[cfg(feature = "xla")]
use super::device::Device;
use crate::util::{Error, Result};
use std::path::PathBuf;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex};

/// A host-side f32 tensor (inputs are always f32; jax artifacts are
/// compiled at f32, the TPU-native width).
#[derive(Clone, Debug)]
pub struct HostArray {
    pub dims: Vec<i64>,
    pub data: Vec<f32>,
}

impl HostArray {
    pub fn new(dims: Vec<i64>, data: Vec<f32>) -> HostArray {
        debug_assert_eq!(dims.iter().product::<i64>() as usize, data.len());
        HostArray { dims, data }
    }

    pub fn vector(data: Vec<f32>) -> HostArray {
        let n = data.len() as i64;
        HostArray { dims: vec![n], data }
    }
}

/// One output of an artifact execution.
#[derive(Clone, Debug)]
pub enum OutValue {
    F32 { dims: Vec<usize>, data: Vec<f32> },
    I32 { dims: Vec<usize>, data: Vec<i32> },
}

impl OutValue {
    /// The f32 payload (errors if the output is integer).
    pub fn f32s(&self) -> Result<&[f32]> {
        match self {
            OutValue::F32 { data, .. } => Ok(data),
            OutValue::I32 { .. } => Err(Error::Runtime("expected f32 output".into())),
        }
    }

    /// A scalar i32 output (e.g. the chosen index of `order_step`).
    pub fn i32_scalar(&self) -> Result<i32> {
        match self {
            OutValue::I32 { data, .. } if data.len() == 1 => Ok(data[0]),
            other => Err(Error::Runtime(format!("expected i32 scalar, got {other:?}"))),
        }
    }
}

// without the xla feature the consuming side (device_loop) is compiled
// out, so the fields are written but never read
#[cfg_attr(not(feature = "xla"), allow(dead_code))]
struct Job {
    path: PathBuf,
    inputs: Vec<HostArray>,
    reply: mpsc::Sender<Result<Vec<OutValue>>>,
}

enum Msg {
    Run(Job),
    Platform(mpsc::Sender<String>),
    Shutdown,
}

/// Cumulative executor statistics (for the perf pass and bench reports).
#[derive(Default, Debug)]
pub struct DeviceStats {
    /// Artifact executions.
    pub calls: AtomicU64,
    /// Bytes uploaded to the device.
    pub bytes_up: AtomicU64,
    /// Bytes downloaded.
    pub bytes_down: AtomicU64,
    /// Nanoseconds spent inside execute (incl. transfers).
    pub exec_nanos: AtomicU64,
}

impl DeviceStats {
    pub fn snapshot(&self) -> (u64, u64, u64, f64) {
        (
            self.calls.load(Ordering::Relaxed),
            self.bytes_up.load(Ordering::Relaxed),
            self.bytes_down.load(Ordering::Relaxed),
            self.exec_nanos.load(Ordering::Relaxed) as f64 / 1e9,
        )
    }
}

/// Handle to the device thread. Clone freely; drop of the last handle
/// shuts the thread down.
pub struct DeviceExecutor {
    tx: Mutex<mpsc::Sender<Msg>>,
    pub stats: Arc<DeviceStats>,
    _thread: Option<std::thread::JoinHandle<()>>,
}

impl DeviceExecutor {
    /// Spawn the device thread (creates the PJRT CPU client on it).
    ///
    /// Without the `xla` crate feature there is no PJRT client to start;
    /// the error surfaces through the same graceful-degradation paths
    /// callers already use when artifacts or devices are missing.
    #[cfg(feature = "xla")]
    pub fn start() -> Result<Arc<DeviceExecutor>> {
        let (tx, rx) = mpsc::channel::<Msg>();
        let (ready_tx, ready_rx) = mpsc::channel::<Result<()>>();
        let stats = Arc::new(DeviceStats::default());
        let stats_thread = stats.clone();
        let thread = std::thread::Builder::new()
            .name("alingam-device".into())
            .spawn(move || device_loop(rx, ready_tx, stats_thread))
            .map_err(|e| Error::Runtime(format!("spawning device thread: {e}")))?;
        ready_rx
            .recv()
            .map_err(|_| Error::Runtime("device thread died during init".into()))??;
        Ok(Arc::new(DeviceExecutor { tx: Mutex::new(tx), stats, _thread: Some(thread) }))
    }

    /// See the `xla`-feature variant above.
    #[cfg(not(feature = "xla"))]
    pub fn start() -> Result<Arc<DeviceExecutor>> {
        Err(Error::Runtime(
            "alingam was built without the `xla` feature: the PJRT runtime is \
             unavailable (rebuild with `cargo build --features xla` to execute \
             AOT artifacts)"
                .into(),
        ))
    }

    /// Execute an artifact; blocks until the result is back on the host.
    pub fn run(&self, path: PathBuf, inputs: Vec<HostArray>) -> Result<Vec<OutValue>> {
        let (reply, rx) = mpsc::channel();
        {
            let tx = self.tx.lock().expect("executor mutex");
            tx.send(Msg::Run(Job { path, inputs, reply }))
                .map_err(|_| Error::Runtime("device thread gone".into()))?;
        }
        rx.recv().map_err(|_| Error::Runtime("device thread dropped reply".into()))?
    }

    /// Platform description string.
    pub fn platform(&self) -> Result<String> {
        let (reply, rx) = mpsc::channel();
        self.tx
            .lock()
            .expect("executor mutex")
            .send(Msg::Platform(reply))
            .map_err(|_| Error::Runtime("device thread gone".into()))?;
        rx.recv().map_err(|_| Error::Runtime("device thread dropped reply".into()))
    }
}

impl Drop for DeviceExecutor {
    fn drop(&mut self) {
        if let Ok(tx) = self.tx.lock() {
            let _ = tx.send(Msg::Shutdown);
        }
        if let Some(t) = self._thread.take() {
            let _ = t.join();
        }
    }
}

#[cfg(feature = "xla")]
fn device_loop(
    rx: mpsc::Receiver<Msg>,
    ready: mpsc::Sender<Result<()>>,
    stats: Arc<DeviceStats>,
) {
    let mut device = match Device::cpu() {
        Ok(d) => {
            let _ = ready.send(Ok(()));
            d
        }
        Err(e) => {
            let _ = ready.send(Err(e));
            return;
        }
    };
    while let Ok(msg) = rx.recv() {
        match msg {
            Msg::Shutdown => break,
            Msg::Platform(reply) => {
                let _ = reply.send(device.platform());
            }
            Msg::Run(job) => {
                let t0 = std::time::Instant::now();
                let result = run_job(&mut device, &job, &stats);
                stats.exec_nanos.fetch_add(t0.elapsed().as_nanos() as u64, Ordering::Relaxed);
                stats.calls.fetch_add(1, Ordering::Relaxed);
                let _ = job.reply.send(result);
            }
        }
    }
}

#[cfg(feature = "xla")]
fn run_job(device: &mut Device, job: &Job, stats: &DeviceStats) -> Result<Vec<OutValue>> {
    let mut literals = Vec::with_capacity(job.inputs.len());
    let mut up = 0usize;
    for a in &job.inputs {
        up += a.data.len() * 4;
        let lit = xla::Literal::vec1(&a.data);
        let lit = if a.dims.len() == 1 { lit } else { lit.reshape(&a.dims)? };
        literals.push(lit);
    }
    stats.bytes_up.fetch_add(up as u64, Ordering::Relaxed);

    let outs = device.run(&job.path, &literals)?;
    let mut values = Vec::with_capacity(outs.len());
    let mut down = 0usize;
    for lit in outs {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        down += lit.size_bytes();
        let v = match shape.ty() {
            xla::ElementType::F32 => OutValue::F32 { dims, data: lit.to_vec::<f32>()? },
            xla::ElementType::S32 => OutValue::I32 { dims, data: lit.to_vec::<i32>()? },
            other => {
                return Err(Error::Runtime(format!("unsupported output type {other:?}")));
            }
        };
        values.push(v);
    }
    stats.bytes_down.fetch_add(down as u64, Ordering::Relaxed);
    Ok(values)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn host_array_shape_check() {
        let a = HostArray::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(a.dims, vec![2, 3]);
        let v = HostArray::vector(vec![1.0, 2.0]);
        assert_eq!(v.dims, vec![2]);
    }

    #[test]
    fn outvalue_accessors() {
        let f = OutValue::F32 { dims: vec![2], data: vec![1.0, 2.0] };
        assert_eq!(f.f32s().unwrap(), &[1.0, 2.0]);
        assert!(f.i32_scalar().is_err());
        let i = OutValue::I32 { dims: vec![], data: vec![7] };
        assert_eq!(i.i32_scalar().unwrap(), 7);
        assert!(i.f32s().is_err());
    }
}
