//! `XlaEngine` — the accelerated `OrderingEngine` backed by the AOT
//! Pallas/JAX artifacts, executed on PJRT through the device thread.
//!
//! Two per-step modes:
//!
//! - **Session (default)** — `session()` hands out the device-resident
//!   [`XlaSession`]: one `session_init` panel upload per fit, then per
//!   step only the score row comes down and the one-hot choice goes up
//!   while the standardized cache and correlation matrix stay on the
//!   device (`crate::lingam::xla_session`).
//! - **Stateless** — `order_step` makes one fused artifact call per
//!   iteration (scores → argmax → residualize), uploading the
//!   zero-padded panel + masks and downloading the residualized panel,
//!   the chosen index and the k_list. Padded buffers are preallocated
//!   once per fit and reused across iterations (see EXPERIMENTS.md
//!   §Perf). Kept as the measured baseline (`fit_stateless`), the
//!   residency ablation (`with_resident(false)`) and the fallback for
//!   manifests that predate the session kinds.

use super::executor::{DeviceExecutor, HostArray};
use super::registry::{ArtifactKind, ArtifactRegistry, Bucket};
use crate::lingam::engine::{OrderStep, OrderingEngine, INACTIVE_SCORE};
use crate::lingam::session::{OrderingSession, StatelessSession};
use crate::lingam::xla_session::XlaSession;
use crate::linalg::Mat;
use crate::util::{Error, Result};
use std::sync::{Arc, Mutex};

/// Scratch buffers reused across `order_step` calls of one fit.
#[derive(Default)]
struct Scratch {
    /// Which bucket the scratch is sized for.
    shape: (usize, usize),
    /// Valid (n, d) extent the padding regions are currently clean for.
    extent: (usize, usize),
    x_pad: Vec<f32>,
    row_mask: Vec<f32>,
}

/// OrderingEngine backed by AOT XLA artifacts.
pub struct XlaEngine {
    executor: Arc<DeviceExecutor>,
    registry: ArtifactRegistry,
    scratch: Mutex<Scratch>,
    /// Use the fused `order_step` artifact (one device call per
    /// iteration). `false` falls back to the two-phase path — `scores`
    /// artifact + host-side argmax/residualize — kept for the fusion
    /// ablation (`cargo bench --bench ablation_fusion`).
    fused: bool,
    /// Serve [`OrderingEngine::session`] with the device-resident
    /// [`XlaSession`] (panel uploaded once, state kept on device across
    /// steps). `false` forces the stateless shim — the legacy per-step
    /// path, kept as the measured baseline and the residency ablation.
    resident: bool,
}

impl XlaEngine {
    /// Build from an artifact directory (see [`super::artifact_dir`]).
    pub fn new(executor: Arc<DeviceExecutor>, artifact_dir: &std::path::Path) -> Result<XlaEngine> {
        let registry = ArtifactRegistry::load(artifact_dir)?;
        if registry.of_kind(ArtifactKind::OrderStep).is_empty() {
            return Err(Error::Runtime("no order_step artifacts in manifest".into()));
        }
        Ok(XlaEngine {
            executor,
            registry,
            scratch: Mutex::new(Scratch::default()),
            fused: true,
            resident: true,
        })
    }

    /// Toggle the fused order_step artifact (see field docs).
    pub fn with_fused(mut self, fused: bool) -> XlaEngine {
        self.fused = fused;
        self
    }

    /// Toggle the device-resident session (see field docs). `false`
    /// pins `session()` to the stateless shim.
    pub fn with_resident(mut self, resident: bool) -> XlaEngine {
        self.resident = resident;
        self
    }

    /// Convenience constructor: default artifact dir + fresh executor.
    pub fn from_default_artifacts() -> Result<XlaEngine> {
        let exec = DeviceExecutor::start()?;
        Self::new(exec, &super::artifact_dir())
    }

    /// The executor handle (for stats snapshots in benches).
    pub fn executor(&self) -> &Arc<DeviceExecutor> {
        &self.executor
    }

    /// The registry (for capacity introspection).
    pub fn registry(&self) -> &ArtifactRegistry {
        &self.registry
    }

    /// Largest (n, d) the order_step artifacts can serve.
    pub fn capacity(&self) -> (usize, usize) {
        let mut cap = (0, 0);
        for b in self.registry.of_kind(ArtifactKind::OrderStep) {
            cap.0 = cap.0.max(b.n);
            cap.1 = cap.1.max(b.d);
        }
        cap
    }

    /// Zero-pad `x` and the masks into the bucket shape; returns inputs
    /// for the artifact call.
    fn pack(&self, bucket: &Bucket, x: &Mat, active: &[bool]) -> Vec<HostArray> {
        let (n, d) = (x.rows(), x.cols());
        let (nb, db) = (bucket.n, bucket.d);
        let mut scratch = self.scratch.lock().expect("scratch mutex");
        if scratch.shape != (nb, db) {
            scratch.shape = (nb, db);
            scratch.extent = (0, 0);
            scratch.x_pad = vec![0.0; nb * db];
            scratch.row_mask = vec![0.0; nb];
        }
        if scratch.extent != (n, d) {
            // a different dataset extent was packed before: re-zero the
            // buffer once and refresh the row mask. Within one fit the
            // extent is constant, so the d−1 iterations skip this.
            scratch.x_pad.iter_mut().for_each(|v| *v = 0.0);
            for (r, v) in scratch.row_mask.iter_mut().enumerate() {
                *v = if r < n { 1.0 } else { 0.0 };
            }
            scratch.extent = (n, d);
        }
        // Row-major copy with zero column padding; inactive columns are
        // also zeroed (the kernel's masked-standardize handles the rest).
        // Padding regions (rows n.., cols d..) stay zero from allocation /
        // the extent refresh above, so no per-iteration full re-zeroing is
        // needed (§Perf: saves nb·db f32 stores per iteration).
        for r in 0..n {
            let src = x.row(r);
            let dst = &mut scratch.x_pad[r * db..r * db + d];
            for (c, out) in dst.iter_mut().enumerate() {
                *out = if active[c] { src[c] as f32 } else { 0.0 };
            }
        }
        let mut col_mask = vec![0.0f32; db];
        for (c, &a) in active.iter().enumerate() {
            col_mask[c] = if a { 1.0 } else { 0.0 };
        }
        vec![
            HostArray::new(vec![nb as i64, db as i64], scratch.x_pad.clone()),
            HostArray::vector(scratch.row_mask.clone()),
            HostArray::vector(col_mask),
        ]
    }

    /// Unpack a padded k_list into full-width f64 scores.
    fn unpack_scores(padded: &[f32], active: &[bool]) -> Vec<f64> {
        active
            .iter()
            .enumerate()
            .map(|(i, &a)| if a { padded[i] as f64 } else { INACTIVE_SCORE })
            .collect()
    }
}

impl OrderingEngine for XlaEngine {
    fn name(&self) -> &'static str {
        "xla"
    }

    fn scores(&self, x: &Mat, active: &[bool]) -> Result<Vec<f64>> {
        let (n, d) = (x.rows(), x.cols());
        let bucket = self.registry.best(ArtifactKind::OrderScores, n, d)?.clone();
        let inputs = self.pack(&bucket, x, active);
        let outs = self.executor.run(bucket.path.clone(), inputs)?;
        Ok(Self::unpack_scores(outs[0].f32s()?, active))
    }

    fn order_step(&self, x: &mut Mat, active: &mut [bool]) -> Result<OrderStep> {
        if !self.fused {
            // ablation path: scores artifact + host argmax/residualize
            let scores = self.scores(x, active)?;
            let chosen = crate::lingam::engine::argmax_active(&scores, active)?;
            crate::lingam::engine::residualize_in_place(x, active, chosen);
            active[chosen] = false;
            return Ok(OrderStep { chosen, scores });
        }
        let (n, d) = (x.rows(), x.cols());
        let bucket = self.registry.best(ArtifactKind::OrderStep, n, d)?.clone();
        let inputs = self.pack(&bucket, x, active);
        let outs = self.executor.run(bucket.path.clone(), inputs)?;
        // outputs: (x' [nb, db], m scalar i32, k_list [db])
        let chosen = outs[1].i32_scalar()? as usize;
        if chosen >= d || !active[chosen] {
            return Err(Error::Runtime(format!(
                "artifact chose invalid variable {chosen} (d={d})"
            )));
        }
        let scores = Self::unpack_scores(outs[2].f32s()?, active);
        // the artifact's argmax is NaN-safe (NaN rewrites to the INACTIVE
        // sentinel), but an all-NaN k_list ties every entry and elects
        // index 0; mirror the CPU engines' contract — degenerate panels
        // surface as Err, never as an arbitrary silent choice
        if scores[chosen].is_nan() {
            return Err(Error::Runtime(format!(
                "artifact chose variable {chosen} with a NaN score: degenerate panel"
            )));
        }
        let x_new = outs[0].f32s()?;
        let db = bucket.d;
        for r in 0..n {
            for c in 0..d {
                if active[c] && c != chosen {
                    x[(r, c)] = x_new[r * db + c] as f64;
                }
            }
        }
        active[chosen] = false;
        Ok(OrderStep { chosen, scores })
    }

    /// The device-resident [`XlaSession`]: the panel is uploaded once
    /// (`session_init`) and every step round-trips only the score row
    /// and the chosen index (see `lingam::xla_session`). Falls back to
    /// the stateless shim — one fused `order_step` artifact call per
    /// step, panel re-uploaded each time — when the manifest predates
    /// the session kinds or has no session bucket covering the shape
    /// (the host-mirror fallback: `fit` degrades, never fails, on a
    /// stale artifact dir).
    fn session<'a>(&'a self, data: &Mat) -> Result<Box<dyn OrderingSession + 'a>> {
        if self.resident {
            // any session-creation failure — no session bucket for this
            // shape, a manifest row whose HLO file is missing/corrupt, a
            // failed init compile — degrades to the shim rather than
            // failing the fit: the shim revalidates the order_step path,
            // so a genuinely broken device/artifact dir still surfaces
            // as an error there instead of being masked here
            if let Ok(s) = XlaSession::new(self.executor.clone(), &self.registry, data) {
                return Ok(Box::new(s));
            }
        }
        Ok(Box::new(StatelessSession::new(self, data)))
    }
}
