//! Artifact manifest parsing and shape-bucket selection.
//!
//! AOT artifacts are compiled for fixed shapes; a request for `(n, d)` is
//! served by the cheapest bucket with `n_b ≥ n` and `d_b ≥ d`, with the
//! data zero-padded and row/column masks carrying the true extents (the
//! masked semantics of `python/compile/kernels/ref.py`).

use crate::util::{Error, Result};
use std::path::{Path, PathBuf};

/// The computations the AOT pipeline exports.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum ArtifactKind {
    /// `order_scores(x, row_mask, col_mask) -> k_list`
    OrderScores,
    /// `order_step(x, row_mask, col_mask) -> (x', m, k_list)`
    OrderStep,
    /// `session_init(x, row_mask, col_mask) -> state` — the one panel
    /// upload of a device-resident ordering session (non-tuple root;
    /// the output buffer stays on the device).
    SessionInit,
    /// `session_scores(state) -> k_list` — the per-step score row, the
    /// only per-step download.
    SessionScores,
    /// `session_update(state, m_onehot) -> state` — commit the host's
    /// choice; the one-hot is the only per-step upload.
    SessionUpdate,
    /// `session_init_batch(x, row_mask, col_mask) -> state` — the
    /// batched session kinds: `jax.vmap` of the solo kinds over a
    /// leading `[B]` axis, bitwise the solo outputs slice for slice.
    /// One upload seeds B same-shape panels (short fusion groups pad
    /// with copies of panel 0).
    SessionInitBatch,
    /// `session_scores_batch(state) -> k_lists` — the per-step
    /// `[B, D]` score block, the only per-step download of a batch.
    SessionScoresBatch,
    /// `session_update_batch(state, m_onehots) -> state` — commit every
    /// lane's host-side choice at once; an all-zero one-hot row is a
    /// lane no-op (how finished/dropped lanes ride along).
    SessionUpdateBatch,
    /// `var_fit(series, row_mask) -> (m1, resid)`
    VarFit,
}

impl ArtifactKind {
    pub fn as_str(self) -> &'static str {
        match self {
            ArtifactKind::OrderScores => "order_scores",
            ArtifactKind::OrderStep => "order_step",
            ArtifactKind::SessionInit => "session_init",
            ArtifactKind::SessionScores => "session_scores",
            ArtifactKind::SessionUpdate => "session_update",
            ArtifactKind::SessionInitBatch => "session_init_batch",
            ArtifactKind::SessionScoresBatch => "session_scores_batch",
            ArtifactKind::SessionUpdateBatch => "session_update_batch",
            ArtifactKind::VarFit => "var_fit",
        }
    }

    /// Whether this kind carries a batch capacity (a 5-field manifest
    /// line) in addition to the `(n, d)` shape bucket.
    pub fn batched(self) -> bool {
        matches!(
            self,
            ArtifactKind::SessionInitBatch
                | ArtifactKind::SessionScoresBatch
                | ArtifactKind::SessionUpdateBatch
        )
    }

    fn parse(s: &str) -> Option<ArtifactKind> {
        match s {
            "order_scores" => Some(ArtifactKind::OrderScores),
            "order_step" => Some(ArtifactKind::OrderStep),
            "session_init" => Some(ArtifactKind::SessionInit),
            "session_scores" => Some(ArtifactKind::SessionScores),
            "session_update" => Some(ArtifactKind::SessionUpdate),
            "session_init_batch" => Some(ArtifactKind::SessionInitBatch),
            "session_scores_batch" => Some(ArtifactKind::SessionScoresBatch),
            "session_update_batch" => Some(ArtifactKind::SessionUpdateBatch),
            "var_fit" => Some(ArtifactKind::VarFit),
            _ => None,
        }
    }
}

/// One compiled shape bucket.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct Bucket {
    pub kind: ArtifactKind,
    /// Sample-count capacity (T for var_fit).
    pub n: usize,
    /// Variable-count capacity.
    pub d: usize,
    /// Batch capacity — how many panels the artifact drives at once.
    /// Always 1 for the unbatched kinds.
    pub b: usize,
    /// HLO text file.
    pub path: PathBuf,
}

/// The set of available artifacts.
#[derive(Clone, Debug, Default)]
pub struct ArtifactRegistry {
    buckets: Vec<Bucket>,
}

impl ArtifactRegistry {
    /// Load `manifest.txt` from an artifact directory. Lines:
    /// `kind n d filename`, or `kind n d b filename` for the batched
    /// session kinds.
    pub fn load(dir: &Path) -> Result<ArtifactRegistry> {
        let manifest = dir.join("manifest.txt");
        let text = std::fs::read_to_string(&manifest).map_err(|e| {
            Error::Runtime(format!(
                "cannot read {} (run `make artifacts` first): {e}",
                manifest.display()
            ))
        })?;
        Self::parse(&text, dir)
    }

    /// Parse manifest text (separated out for tests).
    pub fn parse(text: &str, dir: &Path) -> Result<ArtifactRegistry> {
        let mut buckets = Vec::new();
        for (lineno, line) in text.lines().enumerate() {
            let line = line.trim();
            if line.is_empty() || line.starts_with('#') {
                continue;
            }
            let parts: Vec<&str> = line.split_whitespace().collect();
            if parts.len() != 4 && parts.len() != 5 {
                return Err(Error::Parse(format!("manifest line {}: {line:?}", lineno + 1)));
            }
            let kind = ArtifactKind::parse(parts[0])
                .ok_or_else(|| Error::Parse(format!("unknown artifact kind {:?}", parts[0])))?;
            // the 5th (batch) field is present exactly for batched kinds
            if kind.batched() != (parts.len() == 5) {
                return Err(Error::Parse(format!(
                    "manifest line {}: {line:?} has the wrong field count for {:?}",
                    lineno + 1,
                    parts[0]
                )));
            }
            let n: usize = parts[1].parse().map_err(|_| Error::Parse(line.into()))?;
            let d: usize = parts[2].parse().map_err(|_| Error::Parse(line.into()))?;
            let b: usize = if parts.len() == 5 {
                parts[3].parse().map_err(|_| Error::Parse(line.into()))?
            } else {
                1
            };
            buckets.push(Bucket { kind, n, d, b, path: dir.join(parts[parts.len() - 1]) });
        }
        Ok(ArtifactRegistry { buckets })
    }

    /// All buckets of one kind.
    pub fn of_kind(&self, kind: ArtifactKind) -> Vec<&Bucket> {
        self.buckets.iter().filter(|b| b.kind == kind).collect()
    }

    /// Cheapest bucket covering `(n, d)`: minimal padded area `n_b · d_b`,
    /// ties broken toward smaller `n_b`.
    pub fn best(&self, kind: ArtifactKind, n: usize, d: usize) -> Result<&Bucket> {
        self.buckets
            .iter()
            .filter(|b| b.kind == kind && b.n >= n && b.d >= d)
            .min_by_key(|b| (b.n * b.d, b.n))
            .ok_or_else(|| Error::NoArtifact { n, d, available: self.inventory(kind) })
    }

    /// The bucket of `kind` at exactly `(n, d)`. The three session kinds
    /// must share one shape (the packed state threads between them), so
    /// after [`best`](Self::best) picks the init bucket the scores and
    /// update artifacts are resolved exactly, not re-bucketed.
    pub fn exact(&self, kind: ArtifactKind, n: usize, d: usize) -> Result<&Bucket> {
        self.buckets
            .iter()
            .find(|b| b.kind == kind && b.n == n && b.d == d)
            .ok_or_else(|| Error::NoArtifact { n, d, available: self.inventory(kind) })
    }

    /// Cheapest batched bucket covering `b` panels of `(n, d)`: minimal
    /// padded volume `n_b · d_b · b_b`, ties broken toward smaller
    /// `n_b`. Short groups pad the batch axis with copies of panel 0,
    /// so any `b_b ≥ b` serves.
    pub fn best_batch(&self, kind: ArtifactKind, n: usize, d: usize, b: usize) -> Result<&Bucket> {
        self.buckets
            .iter()
            .filter(|k| k.kind == kind && k.n >= n && k.d >= d && k.b >= b)
            .min_by_key(|k| (k.n * k.d * k.b, k.n))
            .ok_or_else(|| Error::NoArtifact { n, d, available: self.inventory(kind) })
    }

    /// The batched bucket of `kind` at exactly `(n, d, b)` — like
    /// [`exact`](Self::exact), the scores/update companions of a
    /// [`best_batch`](Self::best_batch)-chosen init bucket must resolve
    /// at the identical cell (the packed `[B, N+D+2, D]` state threads
    /// between them).
    pub fn exact_batch(&self, kind: ArtifactKind, n: usize, d: usize, b: usize) -> Result<&Bucket> {
        self.buckets
            .iter()
            .find(|k| k.kind == kind && k.n == n && k.d == d && k.b == b)
            .ok_or_else(|| Error::NoArtifact { n, d, available: self.inventory(kind) })
    }

    fn inventory(&self, kind: ArtifactKind) -> String {
        self.of_kind(kind)
            .iter()
            .map(|k| {
                if kind.batched() {
                    format!("{}x{}b{}", k.n, k.d, k.b)
                } else {
                    format!("{}x{}", k.n, k.d)
                }
            })
            .collect::<Vec<_>>()
            .join(",")
    }

    pub fn len(&self) -> usize {
        self.buckets.len()
    }

    pub fn is_empty(&self) -> bool {
        self.buckets.is_empty()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn reg() -> ArtifactRegistry {
        let text = "\
order_step 256 8 order_step_n256_d8.hlo.txt
order_step 1024 16 order_step_n1024_d16.hlo.txt
order_step 4096 16 order_step_n4096_d16.hlo.txt
order_step 4096 64 order_step_n4096_d64.hlo.txt
session_init 1024 16 session_init_n1024_d16.hlo.txt
session_scores 1024 16 session_scores_n1024_d16.hlo.txt
session_update 1024 16 session_update_n1024_d16.hlo.txt
var_fit 512 16 var_fit_t512_d16.hlo.txt
";
        ArtifactRegistry::parse(text, Path::new("/a")).unwrap()
    }

    #[test]
    fn picks_tightest_bucket() {
        let r = reg();
        let b = r.best(ArtifactKind::OrderStep, 200, 8).unwrap();
        assert_eq!((b.n, b.d), (256, 8));
        let b = r.best(ArtifactKind::OrderStep, 1000, 10).unwrap();
        assert_eq!((b.n, b.d), (1024, 16));
        // n=2000 forces the 4096 row bucket even though d fits 16
        let b = r.best(ArtifactKind::OrderStep, 2000, 12).unwrap();
        assert_eq!((b.n, b.d), (4096, 16));
    }

    #[test]
    fn no_bucket_errors_with_inventory() {
        let r = reg();
        let e = r.best(ArtifactKind::OrderStep, 100_000, 8).unwrap_err();
        let msg = e.to_string();
        assert!(msg.contains("100000"), "{msg}");
        assert!(msg.contains("4096x64"), "{msg}");
    }

    #[test]
    fn kinds_are_separate() {
        let r = reg();
        assert_eq!(r.of_kind(ArtifactKind::VarFit).len(), 1);
        assert!(r.best(ArtifactKind::VarFit, 400, 10).is_ok());
        assert!(r.best(ArtifactKind::OrderScores, 10, 2).is_err());
    }

    #[test]
    fn parse_rejects_malformed() {
        assert!(ArtifactRegistry::parse("order_step 1 2", Path::new("/")).is_err());
        assert!(ArtifactRegistry::parse("nope 1 2 f", Path::new("/")).is_err());
        // comments and blanks ok
        let ok =
            ArtifactRegistry::parse("# comment\n\norder_step 1 2 f\n", Path::new("/")).unwrap();
        assert_eq!(ok.len(), 1);
    }

    #[test]
    fn session_kinds_parse_and_resolve_exactly() {
        let r = reg();
        // best() buckets a request; the companion kinds must then be
        // looked up at the exact same shape
        let b = r.best(ArtifactKind::SessionInit, 800, 10).unwrap();
        assert_eq!((b.n, b.d), (1024, 16));
        assert!(r.exact(ArtifactKind::SessionScores, b.n, b.d).is_ok());
        assert!(r.exact(ArtifactKind::SessionUpdate, b.n, b.d).is_ok());
        // exact() does not re-bucket: a shape with no exact artifact errs
        assert!(r.exact(ArtifactKind::SessionScores, 800, 10).is_err());
    }

    #[test]
    fn batch_lines_parse_and_resolve() {
        let text = "\
session_init 256 8 session_init_n256_d8.hlo.txt
session_init_batch 256 8 4 session_init_batch_n256_d8_b4.hlo.txt
session_init_batch 256 8 8 session_init_batch_n256_d8_b8.hlo.txt
session_init_batch 1024 16 4 session_init_batch_n1024_d16_b4.hlo.txt
session_scores_batch 256 8 4 session_scores_batch_n256_d8_b4.hlo.txt
session_update_batch 256 8 4 session_update_batch_n256_d8_b4.hlo.txt
";
        let r = ArtifactRegistry::parse(text, Path::new("/a")).unwrap();
        // unbatched kinds default the batch capacity to 1
        assert_eq!(r.best(ArtifactKind::SessionInit, 200, 8).unwrap().b, 1);
        // tightest covering cell by padded volume n·d·b
        let b = r.best_batch(ArtifactKind::SessionInitBatch, 200, 8, 3).unwrap();
        assert_eq!((b.n, b.d, b.b), (256, 8, 4));
        let b = r.best_batch(ArtifactKind::SessionInitBatch, 200, 8, 6).unwrap();
        assert_eq!((b.n, b.d, b.b), (256, 8, 8));
        let b = r.best_batch(ArtifactKind::SessionInitBatch, 200, 12, 4).unwrap();
        assert_eq!((b.n, b.d, b.b), (1024, 16, 4));
        assert!(r.best_batch(ArtifactKind::SessionInitBatch, 200, 8, 9).is_err());
        // companion kinds resolve at the exact chosen cell, never re-bucketed
        assert!(r.exact_batch(ArtifactKind::SessionScoresBatch, 256, 8, 4).is_ok());
        assert!(r.exact_batch(ArtifactKind::SessionUpdateBatch, 256, 8, 8).is_err());
    }

    #[test]
    fn batch_field_count_is_enforced() {
        // a batched kind needs its 5th field…
        assert!(ArtifactRegistry::parse("session_init_batch 1 2 f", Path::new("/")).is_err());
        // …and an unbatched kind must not carry one
        assert!(ArtifactRegistry::parse("session_init 1 2 4 f", Path::new("/")).is_err());
    }

    #[test]
    fn path_joined_with_dir() {
        let r = reg();
        let b = r.best(ArtifactKind::VarFit, 1, 1).unwrap();
        assert_eq!(b.path, PathBuf::from("/a/var_fit_t512_d16.hlo.txt"));
    }
}
