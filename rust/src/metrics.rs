//! Structure-recovery metrics: F1 / precision / recall and the structural
//! Hamming distance (SHD) — the quantities Figure 3 and §3.1 report —
//! plus order-agreement utilities for the parallel-vs-sequential
//! equivalence claim.

use crate::linalg::Mat;

/// Precision/recall/F1/SHD of an estimated weighted adjacency against the
/// ground truth (both thresholded at `|w| > tol` to binary edges).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct GraphMetrics {
    pub precision: f64,
    pub recall: f64,
    pub f1: f64,
    /// Structural Hamming distance: missing + extra + reversed edges.
    pub shd: usize,
    pub true_edges: usize,
    pub est_edges: usize,
}

/// Compute metrics for directed-edge recovery.
///
/// SHD counts a reversed edge once (the standard convention): an edge
/// present in both graphs but with flipped orientation contributes 1, a
/// missing or spurious edge contributes 1.
pub fn graph_metrics(truth: &Mat, est: &Mat, tol: f64) -> GraphMetrics {
    let d = truth.rows();
    assert_eq!(d, truth.cols());
    assert_eq!((d, d), (est.rows(), est.cols()));
    let t = |m: &Mat, i: usize, j: usize| m[(i, j)].abs() > tol;

    let mut tp = 0usize;
    let mut fp = 0usize;
    let mut fnx = 0usize;
    let mut shd = 0usize;

    // Directed TP/FP/FN over all ordered pairs.
    for i in 0..d {
        for j in 0..d {
            if i == j {
                continue;
            }
            match (t(truth, i, j), t(est, i, j)) {
                (true, true) => tp += 1,
                (false, true) => fp += 1,
                (true, false) => fnx += 1,
                (false, false) => {}
            }
        }
    }

    // SHD over unordered pairs with reversal counted once.
    for i in 0..d {
        for j in (i + 1)..d {
            let t_ij = t(truth, i, j);
            let t_ji = t(truth, j, i);
            let e_ij = t(est, i, j);
            let e_ji = t(est, j, i);
            let truth_has = t_ij || t_ji;
            let est_has = e_ij || e_ji;
            if truth_has != est_has {
                shd += 1; // missing or extra
            } else if truth_has && est_has && (t_ij != e_ij || t_ji != e_ji) {
                shd += 1; // present both sides but orientation differs
            }
        }
    }

    let precision = if tp + fp == 0 { 0.0 } else { tp as f64 / (tp + fp) as f64 };
    let recall = if tp + fnx == 0 { 0.0 } else { tp as f64 / (tp + fnx) as f64 };
    let f1 = if precision + recall == 0.0 {
        0.0
    } else {
        2.0 * precision * recall / (precision + recall)
    };
    GraphMetrics {
        precision,
        recall,
        f1,
        shd,
        true_edges: tp + fnx,
        est_edges: tp + fp,
    }
}

/// Exact equality of two causal orders (the Figure-3 agreement check).
pub fn orders_identical(a: &[usize], b: &[usize]) -> bool {
    a == b
}

/// Exact equality of two weighted adjacencies to a tolerance (sequential
/// and accelerated paths should agree to float precision).
pub fn adjacency_max_diff(a: &Mat, b: &Mat) -> f64 {
    a.sub(b).max_abs()
}

/// Mean ± std summary over a set of runs (Figure 3 / §3.1 report style).
#[derive(Debug, Clone, Copy)]
pub struct MeanStd {
    pub mean: f64,
    pub std: f64,
}

/// Aggregate a metric across runs.
pub fn mean_std(xs: &[f64]) -> MeanStd {
    let n = xs.len().max(1) as f64;
    let mean = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
    MeanStd { mean, std: var.sqrt() }
}

impl std::fmt::Display for MeanStd {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "{:.2} ± {:.2}", self.mean, self.std)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn truth_chain() -> Mat {
        // 0 → 1 → 2
        let mut m = Mat::zeros(3, 3);
        m[(1, 0)] = 0.8;
        m[(2, 1)] = -1.1;
        m
    }

    #[test]
    fn perfect_recovery() {
        let m = graph_metrics(&truth_chain(), &truth_chain(), 0.01);
        assert_eq!(m.f1, 1.0);
        assert_eq!(m.shd, 0);
        assert_eq!(m.true_edges, 2);
    }

    #[test]
    fn empty_estimate() {
        let m = graph_metrics(&truth_chain(), &Mat::zeros(3, 3), 0.01);
        assert_eq!(m.recall, 0.0);
        assert_eq!(m.f1, 0.0);
        assert_eq!(m.shd, 2); // both edges missing
    }

    #[test]
    fn reversed_edge_counts_once_in_shd() {
        let mut est = Mat::zeros(3, 3);
        est[(0, 1)] = 0.8; // 1 → 0, reversed
        est[(2, 1)] = -1.1; // correct
        let m = graph_metrics(&truth_chain(), &est, 0.01);
        assert_eq!(m.shd, 1);
        assert_eq!(m.recall, 0.5); // one of two directed edges found
    }

    #[test]
    fn extra_edge_penalizes_precision() {
        let mut est = truth_chain();
        est[(2, 0)] = 0.5; // spurious 0 → 2
        let m = graph_metrics(&truth_chain(), &est, 0.01);
        assert!(m.precision < 1.0 && m.recall == 1.0);
        assert_eq!(m.shd, 1);
    }

    #[test]
    fn threshold_filters_small_weights() {
        let mut est = truth_chain();
        est[(2, 0)] = 1e-6;
        let m = graph_metrics(&truth_chain(), &est, 1e-3);
        assert_eq!(m.f1, 1.0);
    }

    #[test]
    fn mean_std_basic() {
        let s = mean_std(&[1.0, 2.0, 3.0]);
        assert!((s.mean - 2.0).abs() < 1e-12);
        assert!((s.std - (2.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }
}
