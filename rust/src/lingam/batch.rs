//! Batched lock-step ordering sessions: one workspace driving B
//! same-shape panels through DirectLiNGAM's search loop together.
//!
//! The serve tier scores one panel per worker: concurrent fits on
//! same-shape panels each pay their own standardize pass, their own
//! entropy sweeps and their own pair-kernel dispatches. ParaLiNGAM
//! parallelizes *within* one panel; [`BatchedSession`] parallelizes
//! *across* panels — the ROADMAP's queue-aware batched scoring tier.
//! B standardized column caches and B correlation matrices are held
//! contiguously (panel-major: column `j` of panel `p` starts at
//! `(p·d + j)·n`), and every lock step advances all live panels through
//! score → choose → residualize together, while each panel keeps its
//! **own** independently-chosen root (per-panel
//! [`argmax_active`], per-panel pruning schedule, per-panel
//! [`SweepCounters`]).
//!
//! Bitwise parity with the solo
//! [`IncrementalSession`](super::IncrementalSession) is a hard contract
//! (pinned by `tests/batch_agreement.rs`): fusing B jobs must never
//! change any job's answer. The only scheduling decision that can move
//! bits is the *pair-sweep* pooling choice — the tiled sweep merges row
//! contributions in a different summation association than the serial
//! sweep, and the parallel pruned sweep's losing partial scores depend
//! on thread interleaving — so each lock step replicates the solo
//! session's `use_pool(pair_work(m, n))` decision exactly (every live
//! lane shares the same active count `m`, so one decision covers the
//! batch) and then picks one of two modes:
//!
//! - **pair-pooled** (big panels): lanes step *sequentially*, each
//!   lane's entropy refresh / pair sweep / cache residualization tiled
//!   across the worker pool exactly as the solo session tiles them;
//! - **cross-panel** (small panels, where the solo pair sweep is
//!   serial): the pool distributes whole lanes instead, every lane
//!   running the identical serial kernels. Per-column entropy and
//!   residual updates are element-independent, so threading across
//!   panels is value-neutral exactly where threading across pairs is
//!   not.
//!
//! Panels that fail [`validate_panel`] enter the batch as dead lanes —
//! their error is reported alone, with the same message a solo fit
//! would produce — and a lane whose argmax degenerates mid-fit, or that
//! the serve worker cancels via [`BatchedSession::drop_lane`], drops
//! out at a step boundary without stalling the rest of the batch.

use super::direct::{validate_panel, LingamFit};
use super::engine::{accumulate_pair_diffs, argmax_active, scatter_scores};
use super::parallel::tiled_pair_sweep;
use super::prune::{estimate_adjacency, PruneMethod};
use super::session::StepObserver;
use super::sweep::{
    dot, entropy_fused_kernel, pair_diff_with_rho_kernel, pair_work, pruned_sweep,
    pruned_sweep_parallel, SweepCounters, SweepStrategy,
};
use crate::linalg::Mat;
use crate::stats;
use crate::util::pool::{parallel_chunks_mut, parallel_indexed};
use crate::util::timer::StageProfile;
use crate::util::{Error, Result};

/// Same small-problem cutoffs as the solo session — the pair-sweep
/// pooling decision must replicate `IncrementalSession`'s bit for bit.
const MIN_PARALLEL_PAIR_WORK: usize = 1 << 18;
/// Column-elements threshold below which per-column sweeps stay serial.
const MIN_PARALLEL_COL_WORK: usize = 1 << 16;

/// Per-panel state: everything the solo session keeps per fit except
/// the column cache and correlation matrix, which live panel-major in
/// the batch so kernels stream across panels without re-tiling.
struct Lane {
    /// Still stepping. False means failed validation, degenerated
    /// mid-fit, or dropped by the caller — `error` records which.
    live: bool,
    active: Vec<bool>,
    /// Per-column entropy cache, refreshed once per lock step.
    h: Vec<f64>,
    /// Packed active indices, rebuilt per step into the same buffer.
    idx: Vec<usize>,
    /// Previous step's scores: the pruned sweep's candidate schedule.
    prev_scores: Vec<f64>,
    /// First-step schedule seed (pruned strategy only): per-column
    /// |excess kurtosis| of the standardized cache.
    seed_scores: Vec<f64>,
    counters: SweepCounters,
    /// Roots chosen so far, in step order (the final forced variable is
    /// appended by `into_fits`).
    order: Vec<usize>,
    step_scores: Vec<Vec<f64>>,
    error: Option<Error>,
    /// Chosen-column copy for the in-place residualization. The solo
    /// session `mem::take`s the column instead; copying is bitwise
    /// identical and keeps the panel-major storage contiguous.
    scratch: Vec<f64>,
}

impl Lane {
    fn new(n: usize, d: usize) -> Lane {
        Lane {
            live: true,
            active: vec![true; d],
            h: vec![0.0; d],
            idx: Vec::with_capacity(d),
            prev_scores: Vec::new(),
            seed_scores: Vec::new(),
            counters: SweepCounters::default(),
            order: Vec::with_capacity(d),
            step_scores: Vec::with_capacity(d.saturating_sub(1)),
            error: None,
            scratch: vec![0.0; n],
        }
    }

    fn dead(n: usize, d: usize, error: Error) -> Lane {
        Lane { live: false, error: Some(error), ..Lane::new(n, d) }
    }
}

/// One lane's outcome from [`BatchedSession::into_fits`]: the fit (or
/// the lane's own failure) plus its sweep instrumentation — available
/// even for failed lanes, mirroring the solo serve path, which books
/// counters before surfacing the fit error.
#[derive(Debug)]
pub struct BatchOutcome {
    /// The fit, or this panel's own error (validation, degenerate
    /// argmax, cancellation) — batch peers are unaffected.
    pub result: Result<LingamFit>,
    /// Sweep work this lane performed before finishing or failing.
    pub counters: SweepCounters,
}

/// Per-step scheduling context shared by every lane of one lock step.
#[derive(Clone, Copy)]
struct StepCtx {
    n: usize,
    d: usize,
    /// Pool size for *within-lane* kernels: the batch's workers in
    /// pair-pooled mode, 1 in cross-panel mode (serial kernels).
    inner_workers: usize,
    force_parallel: bool,
    /// The solo session's pair-sweep pooling decision for this step's
    /// active count — identical for every live lane.
    pair_pooled: bool,
    strategy: SweepStrategy,
    fast: bool,
}

/// A multi-panel ordering workspace stepping B same-shape panels in
/// lock-step (see module docs). Build with
/// [`with_strategy`](BatchedSession::with_strategy), drive with
/// [`step_live`](BatchedSession::step_live) until
/// [`finished`](BatchedSession::finished), then consume with
/// [`into_fits`](BatchedSession::into_fits) — or use the one-call
/// [`fit_batch`](BatchedSession::fit_batch).
pub struct BatchedSession {
    n: usize,
    d: usize,
    workers: usize,
    force_parallel: bool,
    strategy: SweepStrategy,
    /// Route the transcendental pass through the `fastmath` polynomial
    /// `exp` (only settable when that feature is compiled in).
    fast_kernel: bool,
    /// B standardized panels, panel-major: column `j` of panel `p`
    /// occupies `[(p·d + j)·n, (p·d + j + 1)·n)`.
    cols: Vec<f64>,
    /// B correlation matrices, panel-major row-major: entry `(j, k)` of
    /// panel `p` at `p·d² + j·d + k`.
    corr: Vec<f64>,
    lanes: Vec<Lane>,
    steps_done: usize,
}

impl BatchedSession {
    /// Build a batch with exact sweeps. `workers == 1` keeps everything
    /// serial; `force_parallel` disables the small-problem serial
    /// fallback (tests and scaling benches), exactly like the solo
    /// session's flags.
    pub fn new(panels: &[Mat], workers: usize, force_parallel: bool) -> Result<BatchedSession> {
        BatchedSession::with_strategy(panels, workers, force_parallel, SweepStrategy::Exact)
    }

    /// [`new`](BatchedSession::new) with an explicit sweep strategy.
    ///
    /// Batch-level preconditions (empty batch, mixed shapes, degenerate
    /// shape) fail the whole construction; per-panel
    /// [`validate_panel`] failures only kill that panel's lane, whose
    /// [`BatchOutcome`] carries the same error a solo fit would return.
    pub fn with_strategy(
        panels: &[Mat],
        workers: usize,
        force_parallel: bool,
        strategy: SweepStrategy,
    ) -> Result<BatchedSession> {
        let b = panels.len();
        if b == 0 {
            return Err(Error::InvalidArgument("batched session needs ≥ 1 panel".into()));
        }
        let (n, d) = (panels[0].rows(), panels[0].cols());
        for (p, panel) in panels.iter().enumerate() {
            if (panel.rows(), panel.cols()) != (n, d) {
                return Err(Error::Shape(format!(
                    "batched session needs same-shape panels: panel 0 is {n}x{d}, \
                     panel {p} is {}x{}",
                    panel.rows(),
                    panel.cols()
                )));
            }
        }
        if d < 1 || n < 2 {
            return Err(Error::InvalidArgument(format!(
                "ordering session needs n ≥ 2 and d ≥ 1, got {n}x{d}"
            )));
        }
        let mut s = BatchedSession {
            n,
            d,
            workers: workers.max(1),
            force_parallel,
            strategy,
            fast_kernel: false,
            cols: vec![0.0; b * d * n],
            corr: vec![0.0; b * d * d],
            lanes: Vec::with_capacity(b),
            steps_done: 0,
        };
        for panel in panels {
            s.lanes.push(match validate_panel(panel) {
                Ok(()) => Lane::new(n, d),
                Err(e) => Lane::dead(n, d, e),
            });
        }
        s.rebuild(panels);
        Ok(s)
    }

    /// Swap the transcendental pass to the accuracy-bounded polynomial
    /// `exp` of [`super::sweep::fastmath`]. Never on by default: the
    /// agreement suites pin the precise kernel bitwise.
    #[cfg(feature = "fastmath")]
    pub fn with_fast_kernel(mut self) -> BatchedSession {
        self.fast_kernel = true;
        self
    }

    /// Number of panels in the batch (live or not).
    pub fn batch(&self) -> usize {
        self.lanes.len()
    }

    /// Sample count of every panel in the batch.
    pub fn rows(&self) -> usize {
        self.n
    }

    /// Variable count of every panel in the batch.
    pub fn dims(&self) -> usize {
        self.d
    }

    /// Lock steps completed so far.
    pub fn steps_done(&self) -> usize {
        self.steps_done
    }

    /// Search steps a full fit needs (d − 1; the last root is forced).
    pub fn steps_total(&self) -> usize {
        self.d.saturating_sub(1)
    }

    /// True once no further lock step can do work: every search step
    /// ran, or every lane is dead.
    pub fn finished(&self) -> bool {
        self.steps_done >= self.steps_total() || self.lanes.iter().all(|l| !l.live)
    }

    /// Whether panel `p` is still stepping.
    pub fn live(&self, p: usize) -> bool {
        self.lanes[p].live
    }

    /// Number of still-stepping lanes.
    pub fn live_count(&self) -> usize {
        self.lanes.iter().filter(|l| l.live).count()
    }

    /// Panel `p`'s accumulated sweep instrumentation.
    pub fn lane_counters(&self, p: usize) -> SweepCounters {
        self.lanes[p].counters
    }

    /// Roots panel `p` has chosen so far, in step order.
    pub fn lane_order(&self, p: usize) -> &[usize] {
        &self.lanes[p].order
    }

    /// Kill lane `p` with `reason` (e.g. per-job cancellation at a step
    /// boundary). No-op on an already-dead lane, so the original
    /// failure is never overwritten; the rest of the batch is
    /// unaffected.
    pub fn drop_lane(&mut self, p: usize, reason: Error) {
        let lane = &mut self.lanes[p];
        if lane.live {
            lane.live = false;
            lane.error = Some(reason);
        }
    }

    /// One lock step for every live lane: score → per-lane argmax →
    /// residualize+update → deactivate. A lane whose scores degenerate
    /// fails alone (its error is kept for
    /// [`into_fits`](BatchedSession::into_fits)); the rest keep
    /// stepping. Returns the number of lanes still live afterwards.
    pub fn step_live(&mut self) -> usize {
        if self.finished() {
            return self.live_count();
        }
        // every live lane has stepped in lock-step since construction,
        // so they all share the same active count — one solo-identical
        // pooling decision covers the batch
        let m = self.d - self.steps_done;
        let pair_pooled = m >= 2
            && use_pool(
                self.workers,
                self.force_parallel,
                pair_work(m, self.n),
                MIN_PARALLEL_PAIR_WORK,
            );
        let ctx = StepCtx {
            n: self.n,
            d: self.d,
            inner_workers: if pair_pooled { self.workers } else { 1 },
            force_parallel: self.force_parallel,
            pair_pooled,
            strategy: self.strategy,
            fast: self.fast_kernel,
        };
        let (n, d) = (self.n, self.d);
        let mut work: Vec<(&mut Lane, &mut [f64], &mut [f64])> = Vec::new();
        let (mut cols_rest, mut corr_rest) =
            (self.cols.as_mut_slice(), self.corr.as_mut_slice());
        for lane in self.lanes.iter_mut() {
            let (c, cols_tail) = cols_rest.split_at_mut(d * n);
            let (q, corr_tail) = corr_rest.split_at_mut(d * d);
            cols_rest = cols_tail;
            corr_rest = corr_tail;
            if lane.live {
                work.push((lane, c, q));
            }
        }
        if !pair_pooled && self.workers > 1 && work.len() > 1 {
            // cross-panel mode: distribute whole lanes, serial kernels
            parallel_chunks_mut(&mut work, self.workers, |_, chunk| {
                for (lane, cols, corr) in chunk.iter_mut() {
                    lane_step(lane, cols, corr, ctx);
                }
            });
        } else {
            // pair-pooled mode (or a single worker): lanes run
            // sequentially, inner kernels pooling exactly like solo
            for (lane, cols, corr) in work.iter_mut() {
                lane_step(lane, cols, corr, ctx);
            }
        }
        self.steps_done += 1;
        self.live_count()
    }

    /// [`step_live`](BatchedSession::step_live) with a [`StepObserver`]:
    /// the lock step is timed and reported as `step_done(steps_done,
    /// steps_total, elapsed)` — one observation per *lock step*, not per
    /// lane, since the lanes advance together and share the wall clock.
    /// An observer `Err` aborts (the serve layer's cancellation seam);
    /// the batch itself is left consistent and can keep stepping.
    pub fn step_live_observed(&mut self, observer: &mut dyn StepObserver) -> Result<usize> {
        let t0 = std::time::Instant::now();
        let live = self.step_live();
        observer.step_done(self.steps_done, self.steps_total(), t0.elapsed())?;
        Ok(live)
    }

    /// Consume the batch into per-panel outcomes. `panels` must be the
    /// slice the batch was built from (same contract as
    /// `DirectLingam::fit_session`: the adjacency is regressed on the
    /// original un-residualized data). Completed lanes append the final
    /// forced variable and run the shared regression stage; dead lanes
    /// return their recorded error. Counters are reported either way.
    pub fn into_fits(self, panels: &[Mat], prune: PruneMethod) -> Vec<BatchOutcome> {
        assert_eq!(
            panels.len(),
            self.lanes.len(),
            "into_fits needs the panels the batch was built from"
        );
        let (done, total) = (self.steps_done, self.d.saturating_sub(1));
        self.lanes
            .into_iter()
            .zip(panels)
            .map(|(lane, panel)| {
                let counters = lane.counters;
                let result = finish_lane(lane, panel, prune, done, total);
                BatchOutcome { result, counters }
            })
            .collect()
    }

    /// Build, drive to completion and finish a whole batch — the
    /// one-call path the bootstrap's resample groups use. Batch-level
    /// failures (empty batch, mixed shapes) fail every panel at once;
    /// per-panel failures come back in each panel's own outcome.
    pub fn fit_batch(
        panels: &[Mat],
        workers: usize,
        force_parallel: bool,
        strategy: SweepStrategy,
        prune: PruneMethod,
    ) -> Result<Vec<BatchOutcome>> {
        let mut s = BatchedSession::with_strategy(panels, workers, force_parallel, strategy)?;
        while !s.finished() {
            s.step_live();
        }
        Ok(s.into_fits(panels, prune))
    }

    /// Standardize every live panel into the panel-major cache and
    /// build its correlation matrix — the solo `rebuild`, fanned across
    /// lanes. Per-column and per-dot work only, so cross-panel
    /// threading is bitwise value-neutral.
    fn rebuild(&mut self, panels: &[Mat]) {
        let (n, d) = (self.n, self.d);
        let strategy = self.strategy;
        let mut work: Vec<(&mut Lane, &mut [f64], &mut [f64], &Mat)> = Vec::new();
        let (mut cols_rest, mut corr_rest) =
            (self.cols.as_mut_slice(), self.corr.as_mut_slice());
        for (lane, panel) in self.lanes.iter_mut().zip(panels) {
            let (c, cols_tail) = cols_rest.split_at_mut(d * n);
            let (q, corr_tail) = corr_rest.split_at_mut(d * d);
            cols_rest = cols_tail;
            corr_rest = corr_tail;
            if lane.live {
                work.push((lane, c, q, panel));
            }
        }
        if self.workers > 1 && work.len() > 1 {
            parallel_chunks_mut(&mut work, self.workers, |_, chunk| {
                for (lane, cols, corr, panel) in chunk.iter_mut() {
                    rebuild_lane(lane, cols, corr, panel, n, strategy);
                }
            });
        } else {
            for (lane, cols, corr, panel) in work.iter_mut() {
                rebuild_lane(lane, cols, corr, panel, n, strategy);
            }
        }
    }
}

/// Column `j` of a panel-major column slice.
fn col(cols: &[f64], n: usize, j: usize) -> &[f64] {
    &cols[j * n..(j + 1) * n]
}

/// The solo session's pooling predicate, parameterized so cross-panel
/// mode can pass `workers == 1` and force every inner kernel serial.
fn use_pool(workers: usize, force_parallel: bool, work: usize, cutoff: usize) -> bool {
    workers > 1 && (force_parallel || work >= cutoff)
}

/// The solo `rebuild` for one lane: standardize every column into the
/// cache, recompute the correlation matrix (`dot / n`, exactly as the
/// solo session divides), seed the pruned schedule.
fn rebuild_lane(
    lane: &mut Lane,
    cols: &mut [f64],
    corr: &mut [f64],
    panel: &Mat,
    n: usize,
    strategy: SweepStrategy,
) {
    let d = panel.cols();
    for (c, column) in cols.chunks_exact_mut(n).enumerate() {
        for (r, v) in column.iter_mut().enumerate() {
            *v = panel[(r, c)];
        }
        stats::standardize(column);
    }
    for a in 0..d {
        corr[a * d + a] = 1.0;
        for b in (a + 1)..d {
            let v = dot(col(cols, n, a), col(cols, n, b)) / n as f64;
            corr[a * d + b] = v;
            corr[b * d + a] = v;
        }
    }
    lane.active.fill(true);
    lane.prev_scores.clear();
    lane.counters = SweepCounters::default();
    lane.seed_scores.clear();
    if strategy == SweepStrategy::Pruned {
        let inv_n = 1.0 / n as f64;
        lane.seed_scores.extend(cols.chunks_exact(n).map(|column| {
            let m4 = column.iter().map(|&v| (v * v) * (v * v)).sum::<f64>() * inv_n;
            (m4 - 3.0).abs()
        }));
    }
}

/// One solo-session step for one lane against its panel-major slices:
/// the `IncrementalSession::scores` body, the argmax, and
/// `residualize_and_update`, with the lane's own schedule and counters.
fn lane_step(lane: &mut Lane, cols: &mut [f64], corr: &mut [f64], ctx: StepCtx) {
    let (n, d) = (ctx.n, ctx.d);
    lane.idx.clear();
    let active = &lane.active;
    lane.idx.extend((0..d).filter(|&i| active[i]));
    let m = lane.idx.len();
    debug_assert!(m >= 2, "stepping an exhausted lane");
    let fast = ctx.fast;
    // entropy refresh: per-column independent, so pooled vs serial is
    // bitwise value-neutral — pool it exactly when solo would
    if use_pool(
        ctx.inner_workers,
        ctx.force_parallel,
        m.saturating_mul(n),
        MIN_PARALLEL_COL_WORK,
    ) {
        let (cols_ro, idx) = (&*cols, &lane.idx);
        let hs = parallel_indexed(m, ctx.inner_workers.min(m), |t| {
            entropy_fused_kernel(fast, col(cols_ro, n, idx[t]))
        });
        for (t, hv) in hs.into_iter().enumerate() {
            lane.h[lane.idx[t]] = hv;
        }
    } else {
        for t in 0..m {
            let i = lane.idx[t];
            lane.h[i] = entropy_fused_kernel(fast, col(cols, n, i));
        }
    }
    // pruned-sweep schedule: previous step's scores, else the kurtosis
    // seed, else unscheduled — the solo priority chain
    let priority: Option<Vec<f64>> = if ctx.strategy == SweepStrategy::Pruned {
        if lane.prev_scores.len() == d {
            Some(lane.idx.iter().map(|&i| lane.prev_scores[i]).collect())
        } else if lane.seed_scores.len() == d {
            Some(lane.idx.iter().map(|&i| lane.seed_scores[i]).collect())
        } else {
            None
        }
    } else {
        None
    };
    let mut call = SweepCounters::default();
    let k = {
        let (cols_ro, corr_ro, h, idx) = (&*cols, &*corr, &lane.h, &lane.idx);
        let diff = |a: usize, b: usize| {
            let (ia, ib) = (idx[a], idx[b]);
            pair_diff_with_rho_kernel(
                fast,
                col(cols_ro, n, ia),
                col(cols_ro, n, ib),
                corr_ro[ia * d + ib],
                h[ia],
                h[ib],
            )
        };
        match ctx.strategy {
            SweepStrategy::Exact => {
                call.record_exact(m, n);
                if ctx.pair_pooled {
                    tiled_pair_sweep(m, ctx.inner_workers, &diff)
                } else {
                    accumulate_pair_diffs(m, &diff)
                }
            }
            SweepStrategy::Pruned => {
                if ctx.pair_pooled {
                    pruned_sweep_parallel(
                        m,
                        ctx.inner_workers,
                        &diff,
                        priority.as_deref(),
                        n,
                        &mut call,
                    )
                } else {
                    pruned_sweep(m, &diff, priority.as_deref(), n, &mut call)
                }
            }
        }
    };
    lane.counters.merge(&call);
    let scores = scatter_scores(d, &lane.idx, &k);
    if ctx.strategy == SweepStrategy::Pruned {
        lane.prev_scores.clear();
        lane.prev_scores.extend_from_slice(&scores);
    }
    let chosen = match argmax_active(&scores, &lane.active) {
        Ok(c) => c,
        Err(e) => {
            // this lane's panel degenerated (all NaN/−∞ scores): it
            // fails alone, with the same error a solo fit raises
            lane.error = Some(e);
            lane.live = false;
            return;
        }
    };
    residualize_lane(lane, cols, corr, chosen, ctx);
    lane.active[chosen] = false;
    lane.order.push(chosen);
    lane.step_scores.push(scores);
}

/// The solo `residualize_and_update` against panel-major slices: one
/// fused pass per remaining column (`(c_j − ρ_jm·c_m)/√(1−ρ_jm²)`, same
/// ρ²-clamp), then the closed-form O(d²) correlation update.
fn residualize_lane(lane: &mut Lane, cols: &mut [f64], corr: &mut [f64], m: usize, ctx: StepCtx) {
    let (n, d) = (ctx.n, ctx.d);
    let targets: Vec<usize> = (0..d).filter(|&j| j != m && lane.active[j]).collect();
    if targets.is_empty() {
        return;
    }
    let dinv: Vec<f64> = targets
        .iter()
        .map(|&j| {
            let r = corr[j * d + m];
            1.0 / (1.0 - (r * r).min(1.0)).sqrt().max(1e-12)
        })
        .collect();
    lane.scratch.copy_from_slice(col(cols, n, m));
    let cm = &lane.scratch;
    if use_pool(
        ctx.inner_workers,
        ctx.force_parallel,
        targets.len().saturating_mul(n),
        MIN_PARALLEL_COL_WORK,
    ) {
        // the panel-major layout hands out disjoint column views, so
        // workers update their chunk in place (the solo session takes
        // columns out of its Vec-of-Vecs instead; same math, same bits)
        let corr_ro = &*corr;
        let mut views: Vec<(usize, &mut [f64])> = cols
            .chunks_exact_mut(n)
            .enumerate()
            .filter(|(j, _)| targets.binary_search(j).is_ok())
            .collect();
        parallel_chunks_mut(&mut views, ctx.inner_workers, |start, chunk| {
            for (off, (j, column)) in chunk.iter_mut().enumerate() {
                let r = corr_ro[*j * d + m];
                let s = dinv[start + off];
                for (v, &cmv) in column.iter_mut().zip(cm) {
                    *v = (*v - r * cmv) * s;
                }
            }
        });
    } else {
        for (t, &j) in targets.iter().enumerate() {
            let r = corr[j * d + m];
            let s = dinv[t];
            let column = &mut cols[j * n..(j + 1) * n];
            for (v, &cmv) in column.iter_mut().zip(cm) {
                *v = (*v - r * cmv) * s;
            }
        }
    }
    for (ta, &ja) in targets.iter().enumerate() {
        let ra = corr[ja * d + m];
        for (tb, &jb) in targets.iter().enumerate().skip(ta + 1) {
            let rb = corr[jb * d + m];
            let v = ((corr[ja * d + jb] - ra * rb) * dinv[ta] * dinv[tb]).clamp(-1.0, 1.0);
            corr[ja * d + jb] = v;
            corr[jb * d + ja] = v;
        }
    }
}

/// Turn one finished (or failed) lane into its outcome: append the
/// final forced variable and run the shared regression stage, exactly
/// like `DirectLingam::drive` finishing a solo session.
fn finish_lane(
    lane: Lane,
    panel: &Mat,
    prune: PruneMethod,
    steps_done: usize,
    steps_total: usize,
) -> Result<LingamFit> {
    if let Some(e) = lane.error {
        return Err(e);
    }
    if steps_done < steps_total {
        return Err(Error::InvalidArgument(format!(
            "batched fit consumed before completion: {steps_done}/{steps_total} steps"
        )));
    }
    let mut order = lane.order;
    let last = lane.active.iter().position(|&a| a).expect("exactly one variable remains");
    order.push(last);
    let mut profile = StageProfile::new();
    let adjacency = profile.time("regression", || estimate_adjacency(panel, &order, prune))?;
    Ok(LingamFit { order, adjacency, step_scores: lane.step_scores, profile })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lingam::{DirectLingam, IncrementalSession, OrderingSession};
    use crate::sim::{simulate_sem, SemSpec};
    use crate::util::rng::Pcg64;

    fn toy_panel(n: usize, d: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::seed_from_u64(seed);
        simulate_sem(&SemSpec::layered(d, 2, 0.6), n, &mut rng).data
    }

    fn solo_fit(
        panel: &Mat,
        workers: usize,
        force_parallel: bool,
        strategy: SweepStrategy,
    ) -> (LingamFit, SweepCounters) {
        let mut session =
            IncrementalSession::with_strategy(panel, workers, force_parallel, strategy).unwrap();
        let fit = DirectLingam::new().fit_session(panel, &mut session).unwrap();
        (fit, session.sweep_counters())
    }

    #[test]
    fn batched_serial_exact_matches_solo_bitwise() {
        let panels: Vec<Mat> = (0..4).map(|s| toy_panel(300, 6, 40 + s)).collect();
        let outcomes = BatchedSession::fit_batch(
            &panels,
            1,
            false,
            SweepStrategy::Exact,
            PruneMethod::default(),
        )
        .unwrap();
        for (panel, out) in panels.iter().zip(&outcomes) {
            let (solo, counters) = solo_fit(panel, 1, false, SweepStrategy::Exact);
            let fit = out.result.as_ref().expect("batched fit failed");
            assert_eq!(fit.order, solo.order);
            assert_eq!(fit.step_scores, solo.step_scores, "step scores must be bitwise equal");
            assert_eq!(fit.adjacency, solo.adjacency, "adjacency must be bitwise equal");
            assert_eq!(out.counters, counters);
        }
    }

    #[test]
    fn degenerate_panel_fails_alone() {
        let good = toy_panel(200, 5, 50);
        let mut bad = toy_panel(200, 5, 51);
        let constant = vec![0.25; 200];
        bad.set_col(2, &constant);
        let panels = vec![good.clone(), bad, toy_panel(200, 5, 52)];
        let outcomes = BatchedSession::fit_batch(
            &panels,
            1,
            false,
            SweepStrategy::Exact,
            PruneMethod::default(),
        )
        .unwrap();
        let msg = outcomes[1].result.as_ref().unwrap_err().to_string();
        assert!(msg.contains("constant"), "unexpected error: {msg}");
        let (solo, _) = solo_fit(&good, 1, false, SweepStrategy::Exact);
        assert_eq!(outcomes[0].result.as_ref().unwrap().order, solo.order);
        assert!(outcomes[2].result.is_ok());
    }

    #[test]
    fn mixed_shapes_are_a_batch_level_error() {
        let panels = vec![toy_panel(200, 5, 1), toy_panel(200, 4, 2)];
        assert!(BatchedSession::new(&panels, 1, false).is_err());
        assert!(BatchedSession::new(&[], 1, false).is_err());
    }

    #[test]
    fn dropped_lane_reports_its_reason_and_peers_finish() {
        let panels: Vec<Mat> = (0..3).map(|s| toy_panel(200, 5, 60 + s)).collect();
        let mut s = BatchedSession::new(&panels, 1, false).unwrap();
        s.step_live();
        s.drop_lane(1, Error::Canceled("fit canceled at step 1/4".into()));
        assert_eq!(s.live_count(), 2);
        while !s.finished() {
            s.step_live();
        }
        let outcomes = s.into_fits(&panels, PruneMethod::default());
        assert!(matches!(outcomes[1].result, Err(Error::Canceled(_))));
        let (solo, _) = solo_fit(&panels[0], 1, false, SweepStrategy::Exact);
        assert_eq!(outcomes[0].result.as_ref().unwrap().order, solo.order);
        assert!(outcomes[2].result.is_ok());
    }

    #[test]
    fn pooled_exact_batch_matches_pooled_solo_bitwise() {
        // force_parallel drives both the solo session and the batch
        // through the tiled pair sweep, whose summation association is
        // scheduling-independent — bitwise comparable
        let panels: Vec<Mat> = (0..3).map(|s| toy_panel(400, 6, 70 + s)).collect();
        let outcomes = BatchedSession::fit_batch(
            &panels,
            3,
            true,
            SweepStrategy::Exact,
            PruneMethod::default(),
        )
        .unwrap();
        for (panel, out) in panels.iter().zip(&outcomes) {
            let (solo, counters) = solo_fit(panel, 3, true, SweepStrategy::Exact);
            let fit = out.result.as_ref().expect("batched fit failed");
            assert_eq!(fit.order, solo.order);
            assert_eq!(fit.step_scores, solo.step_scores);
            assert_eq!(fit.adjacency, solo.adjacency);
            assert_eq!(out.counters, counters);
        }
    }

    #[test]
    fn serial_pruned_batch_matches_solo_with_counters() {
        let panels: Vec<Mat> = (0..3).map(|s| toy_panel(350, 7, 80 + s)).collect();
        let outcomes = BatchedSession::fit_batch(
            &panels,
            1,
            false,
            SweepStrategy::Pruned,
            PruneMethod::default(),
        )
        .unwrap();
        for (panel, out) in panels.iter().zip(&outcomes) {
            let (solo, counters) = solo_fit(panel, 1, false, SweepStrategy::Pruned);
            let fit = out.result.as_ref().expect("batched fit failed");
            assert_eq!(fit.order, solo.order);
            assert_eq!(fit.step_scores, solo.step_scores);
            assert_eq!(out.counters, counters, "pruned counters must match the solo sweep");
        }
    }

    #[test]
    fn cross_panel_threading_is_bitwise_neutral() {
        // small panels keep the solo pair sweep serial, so the batch
        // distributes lanes instead — still bitwise equal to solo
        let panels: Vec<Mat> = (0..5).map(|s| toy_panel(250, 5, 90 + s)).collect();
        let outcomes = BatchedSession::fit_batch(
            &panels,
            4,
            false,
            SweepStrategy::Exact,
            PruneMethod::default(),
        )
        .unwrap();
        for (panel, out) in panels.iter().zip(&outcomes) {
            let (solo, _) = solo_fit(panel, 4, false, SweepStrategy::Exact);
            let fit = out.result.as_ref().expect("batched fit failed");
            assert_eq!(fit.order, solo.order);
            assert_eq!(fit.step_scores, solo.step_scores);
            assert_eq!(fit.adjacency, solo.adjacency);
        }
    }
}
