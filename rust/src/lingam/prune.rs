//! Adjacency estimation given a causal order: each variable is regressed
//! on its predecessors. Coefficients are estimated by OLS and optionally
//! pruned with an adaptive lasso (the reference `lingam` package's
//! default), implemented as coordinate descent with weights from the OLS
//! solution.

use crate::linalg::{lstsq, Mat};
use crate::util::Result;

/// How to estimate/prune the adjacency over a causal order.
#[derive(Clone, Copy, Debug)]
pub enum PruneMethod {
    /// Plain OLS; entries with |β| below the threshold are zeroed.
    OlsThreshold(f64),
    /// Adaptive lasso: coordinate descent on weighted-ℓ1 penalized OLS,
    /// weights 1/|β_ols|. `lambda` is the penalty scale.
    AdaptiveLasso { lambda: f64 },
}

impl Default for PruneMethod {
    fn default() -> Self {
        // small-but-nonzero threshold: same role as the reference's lasso
        PruneMethod::AdaptiveLasso { lambda: 0.01 }
    }
}

/// Estimate the weighted adjacency (`adj[(i,j)] = β_ij`, j → i) of data
/// `x` under the causal order `order` (causes first).
pub fn estimate_adjacency(x: &Mat, order: &[usize], method: PruneMethod) -> Result<Mat> {
    let d = x.cols();
    assert_eq!(order.len(), d);
    let mut adj = Mat::zeros(d, d);
    for (pos, &i) in order.iter().enumerate() {
        if pos == 0 {
            continue;
        }
        let preds = &order[..pos];
        let xi = Mat::from_vec(x.rows(), 1, x.col(i))?;
        let xp = x.select_cols(preds);
        let beta = match method {
            PruneMethod::OlsThreshold(_) => lstsq_centered(&xp, &xi)?,
            PruneMethod::AdaptiveLasso { lambda } => adaptive_lasso(&xp, &xi, lambda)?,
        };
        for (k, &j) in preds.iter().enumerate() {
            let b = beta[k];
            let keep = match method {
                PruneMethod::OlsThreshold(t) => b.abs() > t,
                PruneMethod::AdaptiveLasso { .. } => b != 0.0,
            };
            if keep {
                adj[(i, j)] = b;
            }
        }
    }
    Ok(adj)
}

/// OLS with column centering (an implicit intercept, as the reference's
/// `LinearRegression` has).
fn lstsq_centered(a: &Mat, b: &Mat) -> Result<Vec<f64>> {
    let (ac, bc) = center(a, b);
    Ok(lstsq(&ac, &bc)?.col(0))
}

fn center(a: &Mat, b: &Mat) -> (Mat, Mat) {
    let n = a.rows();
    let mut ac = a.clone();
    for c in 0..a.cols() {
        let m = crate::stats::mean(&a.col(c));
        for r in 0..n {
            ac[(r, c)] -= m;
        }
    }
    let mb = crate::stats::mean(&b.col(0));
    let bc = b.map(|v| v - mb);
    (ac, bc)
}

/// Adaptive lasso via cyclic coordinate descent.
///
/// Solves min_β ½‖y − Xβ‖²/n + λ Σ w_k |β_k| with w_k = 1/|β_ols,k|.
/// Variables the OLS already puts near zero get an enormous penalty and
/// are removed; strong edges are barely shrunk — the oracle property the
/// reference package relies on for pruning.
///
/// The problem is solved on *standardized* variables and the
/// coefficients are rescaled back, making `lambda` scale-invariant
/// (stock returns live at 1e-3 scale, gene expression at 1e0 — the same
/// λ must prune sensibly for both).
pub fn adaptive_lasso(a: &Mat, b: &Mat, lambda: f64) -> Result<Vec<f64>> {
    let sd = |col: &[f64]| crate::stats::std(col).max(1e-12);
    let sd_y = sd(&b.col(0));
    let sd_x: Vec<f64> = (0..a.cols()).map(|c| sd(&a.col(c))).collect();
    let a_std = Mat::from_fn(a.rows(), a.cols(), |r, c| a[(r, c)] / sd_x[c]);
    let b_std = b.map(|v| v / sd_y);
    let beta_std = adaptive_lasso_raw(&a_std, &b_std, lambda)?;
    Ok(beta_std.iter().zip(&sd_x).map(|(&bb, &sx)| bb * sd_y / sx).collect())
}

fn adaptive_lasso_raw(a: &Mat, b: &Mat, lambda: f64) -> Result<Vec<f64>> {
    let (ac, bc) = center(a, b);
    let (n, p) = (ac.rows(), ac.cols());
    let beta_ols = lstsq(&ac, &bc)?.col(0);
    let weights: Vec<f64> = beta_ols.iter().map(|&b| 1.0 / b.abs().max(1e-8)).collect();

    // precompute column norms and gram-lite quantities
    let cols: Vec<Vec<f64>> = (0..p).map(|c| ac.col(c)).collect();
    let col_sq: Vec<f64> = cols.iter().map(|c| c.iter().map(|v| v * v).sum::<f64>() / n as f64).collect();
    let y = bc.col(0);

    let mut beta = beta_ols.clone();
    let mut resid: Vec<f64> = (0..n)
        .map(|r| {
            let mut v = y[r];
            for k in 0..p {
                v -= beta[k] * cols[k][r];
            }
            v
        })
        .collect();

    for _sweep in 0..200 {
        let mut max_delta = 0.0_f64;
        for k in 0..p {
            if col_sq[k] < 1e-300 {
                continue;
            }
            // partial residual correlation
            let mut rho = 0.0;
            for r in 0..n {
                rho += cols[k][r] * resid[r];
            }
            rho = rho / n as f64 + col_sq[k] * beta[k];
            let new_b = soft_threshold(rho, lambda * weights[k]) / col_sq[k];
            let delta = new_b - beta[k];
            if delta != 0.0 {
                for r in 0..n {
                    resid[r] -= delta * cols[k][r];
                }
                beta[k] = new_b;
                max_delta = max_delta.max(delta.abs());
            }
        }
        if max_delta < 1e-10 {
            break;
        }
    }
    Ok(beta)
}

#[inline]
fn soft_threshold(x: f64, t: f64) -> f64 {
    if x > t {
        x - t
    } else if x < -t {
        x + t
    } else {
        0.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate_sem, SemSpec};
    use crate::util::rng::Pcg64;

    #[test]
    fn ols_recovers_chain_weights() {
        let mut rng = Pcg64::seed_from_u64(1);
        let mut adj = Mat::zeros(3, 3);
        adj[(1, 0)] = 1.5;
        adj[(2, 1)] = -0.8;
        let dag = crate::graph::Dag::new(adj.clone()).unwrap();
        let x = crate::sim::sem::sample_from_dag(&dag, crate::sim::Noise::Uniform01, 20_000, &mut rng);
        let est = estimate_adjacency(&x, &[0, 1, 2], PruneMethod::OlsThreshold(0.05)).unwrap();
        assert!((est[(1, 0)] - 1.5).abs() < 0.05, "{}", est[(1, 0)]);
        assert!((est[(2, 1)] + 0.8).abs() < 0.05, "{}", est[(2, 1)]);
        // non-edge 0 → 2 should be ~0 after conditioning on 1
        assert!(est[(2, 0)].abs() < 0.06, "{}", est[(2, 0)]);
    }

    #[test]
    fn adaptive_lasso_zeroes_nuisance() {
        let mut rng = Pcg64::seed_from_u64(2);
        let ds = simulate_sem(&SemSpec::layered(8, 2, 0.4), 5_000, &mut rng);
        let order = ds.order.clone();
        let est =
            estimate_adjacency(&ds.data, &order, PruneMethod::AdaptiveLasso { lambda: 0.01 })
                .unwrap();
        // every true zero stays (near) zero, every strong edge survives
        for i in 0..8 {
            for j in 0..8 {
                let t = ds.adjacency[(i, j)];
                if t == 0.0 {
                    assert!(est[(i, j)].abs() < 0.1, "({i},{j}) = {}", est[(i, j)]);
                } else if t.abs() > 0.5 {
                    assert!(
                        (est[(i, j)] - t).abs() < 0.2,
                        "({i},{j}): est {} vs true {t}",
                        est[(i, j)]
                    );
                }
            }
        }
    }

    #[test]
    fn adjacency_lower_triangular_under_order() {
        // entries only from predecessors: with order = identity this
        // means strictly lower-triangular
        let mut rng = Pcg64::seed_from_u64(3);
        let ds = simulate_sem(&SemSpec::layered(6, 3, 0.5), 2_000, &mut rng);
        let order: Vec<usize> = (0..6).collect();
        let est = estimate_adjacency(&ds.data, &order, PruneMethod::OlsThreshold(0.0)).unwrap();
        for i in 0..6 {
            for j in i..6 {
                assert_eq!(est[(i, j)], 0.0, "upper entry ({i},{j}) set");
            }
        }
    }

    #[test]
    fn soft_threshold_props() {
        assert_eq!(soft_threshold(3.0, 1.0), 2.0);
        assert_eq!(soft_threshold(-3.0, 1.0), -2.0);
        assert_eq!(soft_threshold(0.5, 1.0), 0.0);
    }

    #[test]
    fn lasso_heavier_penalty_sparser() {
        let mut rng = Pcg64::seed_from_u64(4);
        let ds = simulate_sem(&SemSpec::layered(8, 2, 0.6), 3_000, &mut rng);
        let nnz = |lam: f64| {
            let est = estimate_adjacency(
                &ds.data,
                &ds.order,
                PruneMethod::AdaptiveLasso { lambda: lam },
            )
            .unwrap();
            est.as_slice().iter().filter(|v| **v != 0.0).count()
        };
        assert!(nnz(0.5) <= nnz(0.001));
    }
}
