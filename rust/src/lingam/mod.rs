//! The LiNGAM family — the paper's core algorithms.
//!
//! - [`entropy`] — the maximum-entropy differential-entropy approximation
//!   and the mutual-information difference measure (Algorithm 1's
//!   `_diff_mutual_info`).
//! - [`engine`] — the `OrderingEngine` abstraction over the causal-order
//!   scoring hot spot, with the sequential (paper's CPU baseline) and
//!   vectorized (restructured, GPU-shaped) implementations. The
//!   XLA-backed engine lives in [`crate::runtime`]. Engines also act as
//!   *session factories*.
//! - [`sweep`] — the pair-sweep subsystem every CPU ordering path runs
//!   on: the chunked fused pair kernel, the exact serial/tiled sweeps,
//!   and the **bound-pruned scheduled sweep** (ParaLiNGAM-style early
//!   termination — provably the identical root sequence with part of
//!   the O(d²·n) work skipped), plus the [`SweepCounters`]
//!   instrumentation and the optional `fastmath` polynomial-`exp`
//!   kernel.
//! - [`session`] — stateful ordering sessions: the per-fit workspace
//!   (standardized column cache, persistent correlation matrix, entropy
//!   cache) with in-place incremental residualization and closed-form
//!   O(d²) correlation updates between steps (ParaLiNGAM-style reuse),
//!   plus the stateless compatibility shim.
//! - [`xla_session`] — the device-resident counterpart: the same
//!   workspace packed into one resident PJRT buffer, driven by the
//!   `session_init`/`session_scores`/`session_update` artifacts; one
//!   panel upload per fit, O(d) transfers per step.
//! - [`parallel`] — the multi-threaded CPU engine: the restructured pair
//!   kernel tiled across a work-stealing worker pool (ParaLiNGAM-style);
//!   the default CPU engine for the apps. Its sessions tile the shared
//!   workspace sweeps across the same pool.
//! - [`batch`] — the cross-panel counterpart: one [`BatchedSession`]
//!   drives B same-shape panels in lock-step (panel-major caches,
//!   per-panel roots/counters, bitwise solo parity), the workspace the
//!   serve tier's fusion window and the bootstrap's resample groups
//!   share.
//! - [`direct`] — DirectLiNGAM (Shimizu et al. 2011): iterative exogenous
//!   search + residualization, then adjacency estimation over the order.
//!   Also the [`OrderingPlan`] seam, which generalizes the fit driver
//!   from "drive one session" to "execute a plan of sessions".
//! - [`partition`] — partitioned ordering plans: thresholded
//!   correlation-graph blocks, independent per-block sessions, and a
//!   boundary-pair reconciliation merge, with an exact tier (provably
//!   the unpartitioned fit, instrumented) and a measured approx tier —
//!   the d≈1000+ scaling path.
//! - [`streaming`] — online discovery over a sliding window: rank-1
//!   update/downdate of the window's moments (Welford-style, with a
//!   drift-bounded resync policy), seeded sessions for the full refits,
//!   and held-order moment-space coefficient re-estimation for the
//!   per-frame fast path — both the plain and the lag-k VAR drivers.
//!   The workspace behind the serve tier's `watch` streams.
//! - [`prune`] — adjacency estimation: OLS over predecessors + adaptive
//!   lasso pruning.
//! - [`var`] — VarLiNGAM (Hyvärinen et al. 2010): VAR(k) fit, DirectLiNGAM
//!   on innovations, lag-matrix transformation, total-effect rankings.
//! - [`fastica`] / [`ica`] — ICA-LiNGAM (Shimizu et al. 2006), the
//!   original estimator (§2.2), as an independent cross-check.

pub mod batch;
pub mod entropy;
pub mod engine;
pub mod session;
pub mod sweep;
pub mod xla_session;
pub mod direct;
pub mod fastica;
pub mod ica;
pub mod parallel;
pub mod partition;
pub mod prune;
pub mod streaming;
pub mod var;

pub use batch::{BatchOutcome, BatchedSession};
pub use direct::{DirectLingam, LingamFit, OrderingPlan, PlanFit, PlanOrdering};
pub use partition::{
    partition_columns, MergeMode, PartitionSpec, PartitionWorkspace, PartitionedPlan,
    SingleBlockPlan,
};
pub use engine::{OrderingEngine, SequentialEngine, VectorizedEngine};
pub use parallel::ParallelEngine;
pub use session::{
    FnObserver, IncrementalSession, NullObserver, OrderingSession, StatelessSession, StepObserver,
};
pub use streaming::{
    ols_from_cov, FrameOutcome, RefitKind, StreamingConfig, StreamingLingam, StreamingVarLingam,
    StreamingWindow, VarFrameOutcome,
};
pub use sweep::{SweepCounters, SweepStrategy};
pub use xla_session::{XlaBatchSession, XlaSession};
pub use ica::{IcaLingam, IcaLingamFit};
pub use var::{VarLingam, VarLingamFit};
