//! The causal-ordering hot spot behind an engine abstraction.
//!
//! An [`OrderingEngine`] is two things:
//!
//! 1. **A stateless scorer** — `scores` is Algorithm 1
//!    (`search_causal_order`): given the residual panel and the set of
//!    still-active variables, produce `k_list` where
//!    `k_list[i] = −Σ_{j≠i} min(0, diff_mi(i,j))²`; the next exogenous
//!    variable is the argmax. This path re-derives every statistic per
//!    call and is kept as the compatibility shim the agreement tests and
//!    the `fig2_speedup` stateless baseline run through.
//! 2. **A session factory** — [`OrderingEngine::session`] opens a
//!    stateful [`OrderingSession`](super::session::OrderingSession) over
//!    a panel. The session owns the per-fit workspace (standardized
//!    column cache, persistent correlation matrix, entropy cache) and
//!    `DirectLingam::fit` drives its lifecycle:
//!    **create → score → choose → residualize+update → … → finish**,
//!    with the residualize+update half done incrementally in place (see
//!    [`super::session`]). Engines without an incremental path hand out
//!    the [`StatelessSession`](super::session::StatelessSession) shim,
//!    which preserves their exact per-step behavior.
//!
//! Four implementations:
//! - [`SequentialEngine`] — faithful port of the numpy reference: per-pair
//!   re-standardization, scalar loops. This is the paper's CPU baseline
//!   whose profile (Figure 2, ~96% in ordering) and runtime the speedup is
//!   measured against. Sessions: the stateless shim (the baseline must
//!   stay deliberately unoptimized).
//! - [`VectorizedEngine`] — the restructured computation the GPU kernel
//!   performs (standardize once per iteration, correlation precompute,
//!   per-`i` residual panel reduction), in pure Rust, single-threaded.
//!   Sessions: the incremental workspace with serial sweeps.
//! - [`super::parallel::ParallelEngine`] — the same restructured pair
//!   kernel tiled across a bounded CPU worker pool (ParaLiNGAM-style).
//!   Sessions: the incremental workspace with pooled sweeps.
//! - `runtime::XlaEngine` — the same restructuring AOT-compiled from
//!   JAX/Pallas and executed via PJRT (the repo's "GPU" path). Sessions:
//!   the stateless shim around its fused on-device `order_step`.
//!
//! The restructured math itself — standardize-once column cache, ρ
//! precompute, fused log-cosh/gauss-score pair reduction — lives in
//! [`super::sweep`] (the chunked pair kernel plus the exact and
//! bound-pruned sweep schedulers) and is re-exported here, so the
//! stateless CPU engines and the incremental session share every numeric
//! detail and their scores agree to float precision. The pruned mode
//! ([`super::sweep::SweepStrategy::Pruned`]) is opt-in per engine
//! ([`super::parallel::ParallelEngine::with_pruning`]) or per session.

use super::entropy::{diff_mi, order_penalty};
use super::session::{IncrementalSession, OrderingSession, StatelessSession};
use super::sweep::SweepStrategy;
use crate::linalg::Mat;
use crate::stats;
use crate::util::{Error, Result};

pub use super::sweep::{accumulate_pair_diffs, entropy_fused, pair_diff, pair_diff_with_rho};
pub(crate) use super::sweep::dot;

/// Score assigned to inactive variables so argmax never selects them.
pub const INACTIVE_SCORE: f64 = f64::NEG_INFINITY;

/// Result of one exogenous-search step.
#[derive(Clone, Debug)]
pub struct OrderStep {
    /// Index of the variable chosen as exogenous at this step.
    pub chosen: usize,
    /// The full k_list (inactive entries = `INACTIVE_SCORE`).
    pub scores: Vec<f64>,
}

/// A backend for the causal-ordering subprocedure.
///
/// `Send + Sync` so the coordinator can share one engine across sweep
/// workers (the XLA engine serializes device access internally).
pub trait OrderingEngine: Send + Sync {
    /// Engine name for logs/benches.
    fn name(&self) -> &'static str;

    /// Algorithm 1: `k_list` over active variables of the panel `x`.
    fn scores(&self, x: &Mat, active: &[bool]) -> Result<Vec<f64>>;

    /// One full search step: score, pick the argmax, residualize the
    /// remaining active columns against the chosen variable in place.
    ///
    /// Engines with a fused path (the XLA artifact) override this.
    fn order_step(&self, x: &mut Mat, active: &mut [bool]) -> Result<OrderStep> {
        let scores = self.scores(x, active)?;
        let chosen = argmax_active(&scores, active)?;
        residualize_in_place(x, active, chosen);
        active[chosen] = false;
        Ok(OrderStep { chosen, scores })
    }

    /// Open a stateful ordering session over a panel — the workspace
    /// `DirectLingam::fit` drives for the whole d−1-step loop (see
    /// [`super::session`] for the lifecycle). Engines without an
    /// incremental workspace return the
    /// [`StatelessSession`](super::session::StatelessSession) shim, which
    /// keeps their exact per-step semantics.
    fn session<'a>(&'a self, data: &Mat) -> Result<Box<dyn OrderingSession + 'a>>;

    /// How this engine's sweeps visit the pair space (reported in logs
    /// and benches; [`SweepStrategy::Exact`] unless the engine was
    /// explicitly configured for the bound-pruned sweep).
    fn sweep_strategy(&self) -> SweepStrategy {
        SweepStrategy::Exact
    }

    /// The `(workers, force_parallel, strategy)` an incremental CPU
    /// workspace for this engine would run with, or `None` if the engine
    /// has no such workspace (the sequential baseline, the XLA engine).
    ///
    /// `Some` is the batching contract: it promises that
    /// [`super::batch::BatchedSession::with_strategy`] built from these
    /// parameters produces bitwise the same fit as this engine's solo
    /// session, so the serve fusion window and the bootstrap's resample
    /// groups may batch same-shape fits for this engine.
    fn incremental_config(&self) -> Option<(usize, bool, SweepStrategy)> {
        None
    }
}

/// Argmax of scores over active entries (ties → lowest index, matching
/// `np.argmax`). NaN scores are skipped rather than compared; if every
/// active score is NaN or −∞ (a degenerate panel — constant or collinear
/// columns) no variable is selectable and an `InvalidArgument` error is
/// returned instead of panicking.
pub fn argmax_active(scores: &[f64], active: &[bool]) -> Result<usize> {
    let mut best: Option<usize> = None;
    let mut best_v = f64::NEG_INFINITY;
    for (i, (&s, &a)) in scores.iter().zip(active).enumerate() {
        if a && !s.is_nan() && s > best_v {
            best_v = s;
            best = Some(i);
        }
    }
    best.ok_or_else(|| {
        Error::InvalidArgument(
            "no active variable has a usable ordering score (all NaN or −∞): \
             degenerate panel"
                .into(),
        )
    })
}

/// Least-squares removal of variable `m`'s effect from every other active
/// column: `x_j ← x_j − (cov(x_j, x_m)/var(x_m)) x_m` (Shimizu et al.
/// 2011, Lemma 1: the residuals again follow a LiNGAM).
pub fn residualize_in_place(x: &mut Mat, active: &[bool], m: usize) {
    let xm = x.col(m);
    let var_m = stats::var(&xm).max(1e-300);
    let mean_m = stats::mean(&xm);
    let n = x.rows();
    for j in 0..x.cols() {
        if j == m || !active[j] {
            continue;
        }
        let xj = x.col(j);
        let cov_jm = stats::cov(&xj, &xm);
        let beta = cov_jm / var_m;
        let mean_j = stats::mean(&xj);
        for r in 0..n {
            // residual of centered regression (keeps residual mean ~0)
            let v = (xj[r] - mean_j) - beta * (xm[r] - mean_m);
            x[(r, j)] = v;
        }
    }
}

// ---------------------------------------------------------------------
// Sequential engine — the numpy-reference port (paper's CPU baseline).
// ---------------------------------------------------------------------

/// Faithful port of the reference `search_causal_order`: for every pair
/// (i, j) it re-standardizes both columns, computes both regression
/// residuals and the MI difference, exactly as the paper's Algorithm 1
/// pseudo-implementation does. Deliberately unoptimized: this is the
/// baseline whose cost profile Figure 2 reports.
#[derive(Default, Clone)]
pub struct SequentialEngine;

impl OrderingEngine for SequentialEngine {
    fn name(&self) -> &'static str {
        "sequential"
    }

    /// The baseline stays deliberately unoptimized: its session is the
    /// stateless shim, re-deriving everything per step like the
    /// reference implementation does.
    fn session<'a>(&'a self, data: &Mat) -> Result<Box<dyn OrderingSession + 'a>> {
        Ok(Box::new(StatelessSession::new(self, data)))
    }

    fn scores(&self, x: &Mat, active: &[bool]) -> Result<Vec<f64>> {
        let d = x.cols();
        let mut k_list = vec![INACTIVE_SCORE; d];
        for i in 0..d {
            if !active[i] {
                continue;
            }
            let mut k = 0.0;
            for j in 0..d {
                if j == i || !active[j] {
                    continue;
                }
                // per-pair standardization (the reference recomputes this
                // for every pair — part of what the GPU version hoists)
                let mut xi = x.col(i);
                let mut xj = x.col(j);
                stats::standardize(&mut xi);
                stats::standardize(&mut xj);
                let rho = stats::cov(&xi, &xj);
                // residuals of each direction, then standardized
                let ri_j: Vec<f64> =
                    xi.iter().zip(&xj).map(|(&a, &b)| a - rho * b).collect();
                let rj_i: Vec<f64> =
                    xj.iter().zip(&xi).map(|(&a, &b)| a - rho * b).collect();
                let h_xi = super::entropy::entropy(&xi);
                let h_xj = super::entropy::entropy(&xj);
                let mut ri = ri_j;
                let mut rj = rj_i;
                stats::standardize(&mut ri);
                stats::standardize(&mut rj);
                let h_ri = super::entropy::entropy(&ri);
                let h_rj = super::entropy::entropy(&rj);
                let diff = diff_mi(h_xi, h_xj, h_ri, h_rj);
                k += order_penalty(diff);
            }
            k_list[i] = -k;
        }
        Ok(k_list)
    }
}

// ---------------------------------------------------------------------
// Vectorized engine — the GPU-kernel restructuring, in Rust.
// ---------------------------------------------------------------------

/// The computation reorganized the way the CUDA/Pallas kernel organizes
/// it: standardize every active column **once**, compute all pairwise
/// correlations, then for each candidate root `i` sweep the full residual
/// panel with fused log-cosh / gauss-score reductions. Entropies of the
/// standardized columns are also hoisted (the reference recomputes them
/// per pair).
#[derive(Default, Clone)]
pub struct VectorizedEngine;

impl OrderingEngine for VectorizedEngine {
    fn name(&self) -> &'static str {
        "vectorized"
    }

    fn scores(&self, x: &Mat, active: &[bool]) -> Result<Vec<f64>> {
        let (idx, cols) = standardized_active_columns(x, active);
        let h = column_entropies(&cols);
        let k = accumulate_pairs(&cols, &h);
        Ok(scatter_scores(x.cols(), &idx, &k))
    }

    /// Incremental workspace with serial sweeps: the single-threaded
    /// restructured path plus cross-step reuse.
    fn session<'a>(&'a self, data: &Mat) -> Result<Box<dyn OrderingSession + 'a>> {
        Ok(Box::new(IncrementalSession::new(data, 1, false)?))
    }

    /// Serial exact workspace — batchable.
    fn incremental_config(&self) -> Option<(usize, bool, SweepStrategy)> {
        Some((1, false, SweepStrategy::Exact))
    }
}

// ---------------------------------------------------------------------
// Shared restructured-computation kernel (vectorized + parallel engines).
// ---------------------------------------------------------------------

/// Standardize every active column **once** (column-major cache); returns
/// the active indices alongside the cache. This is step 1 of the
/// restructured computation both CPU engines and the Pallas kernel hoist
/// out of the pair loop.
pub fn standardized_active_columns(x: &Mat, active: &[bool]) -> (Vec<usize>, Vec<Vec<f64>>) {
    let idx: Vec<usize> = (0..x.cols()).filter(|&i| active[i]).collect();
    let mut cols: Vec<Vec<f64>> = Vec::with_capacity(idx.len());
    for &c in &idx {
        let mut v = x.col(c);
        stats::standardize(&mut v);
        cols.push(v);
    }
    (idx, cols)
}

/// Per-column entropies of the standardized cache (hoisted out of the
/// pair loop; the reference recomputes them per pair).
pub fn column_entropies(cols: &[Vec<f64>]) -> Vec<f64> {
    cols.iter().map(|c| entropy_fused(c)).collect()
}

/// [`accumulate_pair_diffs`] over freshly standardized columns. This is
/// the loop `VectorizedEngine` runs — and `ParallelEngine`'s
/// small-problem fallback, where spawning threads would cost more than
/// the pair work itself.
pub fn accumulate_pairs(cols: &[Vec<f64>], h: &[f64]) -> Vec<f64> {
    accumulate_pair_diffs(cols.len(), |a, b| pair_diff(&cols[a], &cols[b], h[a], h[b]))
}

/// Scatter packed per-active accumulators into a full-width k_list
/// (`k_list[i] = −k[pos]`, inactive entries = [`INACTIVE_SCORE`]).
pub fn scatter_scores(d: usize, idx: &[usize], k: &[f64]) -> Vec<f64> {
    let mut k_list = vec![INACTIVE_SCORE; d];
    for (pos, &i) in idx.iter().enumerate() {
        k_list[i] = -k[pos];
    }
    k_list
}

/// On standardized data, the residual of the centered regression equals
/// `(x_i − ρ x_j)`; its std is `√(1−ρ²)`. The sequential engine
/// standardizes residuals empirically; the closed form agrees to float
/// precision, which the `engines_agree` tests pin down.
#[cfg(test)]
mod tests {
    use super::*;
    use crate::sim::{simulate_sem, SemSpec};
    use crate::util::rng::Pcg64;

    fn toy_panel(n: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::seed_from_u64(seed);
        let ds = simulate_sem(&SemSpec::layered(6, 2, 0.6), n, &mut rng);
        ds.data
    }

    #[test]
    fn sequential_and_vectorized_scores_match() {
        let x = toy_panel(2_000, 1);
        let active = vec![true; 6];
        let s = SequentialEngine.scores(&x, &active).unwrap();
        let v = VectorizedEngine.scores(&x, &active).unwrap();
        for i in 0..6 {
            assert!(
                (s[i] - v[i]).abs() < 1e-9 * (1.0 + s[i].abs()),
                "i={i}: seq={} vec={}",
                s[i],
                v[i]
            );
        }
    }

    #[test]
    fn scores_respect_active_mask() {
        let x = toy_panel(500, 2);
        let mut active = vec![true; 6];
        active[2] = false;
        active[4] = false;
        for eng in [&SequentialEngine as &dyn OrderingEngine, &VectorizedEngine] {
            let s = eng.scores(&x, &active).unwrap();
            assert_eq!(s[2], INACTIVE_SCORE);
            assert_eq!(s[4], INACTIVE_SCORE);
            assert!(s[0].is_finite());
        }
    }

    #[test]
    fn root_scores_highest_on_simple_chain() {
        // 0 → 1 → 2 with uniform noise: variable 0 should win step 1
        let mut rng = Pcg64::seed_from_u64(3);
        let mut adj = Mat::zeros(3, 3);
        adj[(1, 0)] = 1.2;
        adj[(2, 1)] = -1.0;
        let dag = crate::graph::Dag::new(adj).unwrap();
        let x = crate::sim::sem::sample_from_dag(
            &dag,
            crate::sim::Noise::Uniform01,
            20_000,
            &mut rng,
        );
        let active = vec![true; 3];
        for eng in [&SequentialEngine as &dyn OrderingEngine, &VectorizedEngine] {
            let s = eng.scores(&x, &active).unwrap();
            let best = argmax_active(&s, &active).unwrap();
            assert_eq!(best, 0, "{}: scores={s:?}", eng.name());
        }
    }

    #[test]
    fn order_step_deactivates_and_residualizes() {
        let mut x = toy_panel(1_000, 4);
        let mut active = vec![true; 6];
        let step = VectorizedEngine.order_step(&mut x, &mut active).unwrap();
        assert!(!active[step.chosen]);
        assert_eq!(active.iter().filter(|&&a| a).count(), 5);
        // every remaining active column is now uncorrelated with chosen
        let xm = x.col(step.chosen);
        for j in 0..6 {
            if j != step.chosen && active[j] {
                let c = stats::cov(&x.col(j), &xm);
                assert!(c.abs() < 1e-8, "cov after residualize = {c}");
            }
        }
    }

    #[test]
    fn argmax_matches_numpy_tie_breaking() {
        let scores = vec![1.0, 5.0, 5.0, 2.0];
        let active = vec![true; 4];
        assert_eq!(argmax_active(&scores, &active).unwrap(), 1); // first max
        let active2 = vec![false, false, true, true];
        assert_eq!(argmax_active(&scores, &active2).unwrap(), 2);
    }

    #[test]
    fn argmax_skips_nan_scores() {
        let scores = vec![f64::NAN, 1.0, f64::NAN, 0.5];
        let active = vec![true; 4];
        assert_eq!(argmax_active(&scores, &active).unwrap(), 1);
    }

    #[test]
    fn argmax_errors_on_degenerate_scores() {
        // every active score NaN or −∞ → Err, not panic
        let scores = vec![f64::NAN, f64::NEG_INFINITY, f64::NAN];
        let active = vec![true; 3];
        assert!(argmax_active(&scores, &active).is_err());
        // no active variable at all → Err
        assert!(argmax_active(&[1.0, 2.0], &[false, false]).is_err());
    }

    #[test]
    fn pair_diff_finite_on_duplicated_columns() {
        // an exactly-duplicated standardized column drives ρ² to (or past)
        // 1; the clamped kernel must stay finite instead of going NaN
        let mut rng = Pcg64::seed_from_u64(11);
        let mut c: Vec<f64> = (0..500).map(|_| rng.normal()).collect();
        stats::standardize(&mut c);
        let h = entropy_fused(&c);
        let d = pair_diff(&c, &c, h, h);
        assert!(!d.is_nan(), "duplicated pair produced NaN diff");
    }
}
