//! Stateful ordering sessions: the reusable workspace behind
//! [`DirectLingam::fit`](super::direct::DirectLingam::fit).
//!
//! The stateless `OrderingEngine::scores` path re-derives everything on
//! every search step: it re-standardizes all active columns, reallocates
//! the column cache, and recomputes all pairwise correlations with
//! O(d²·n) dots — even though the residualized panel's statistics are a
//! closed-form function of the previous step's. ParaLiNGAM (Shahbazinia
//! et al. 2023) identifies exactly this reuse as the next speedup after
//! parallelizing the pair loop, and this module is that reuse:
//!
//! - [`OrderingSession`] — the lifecycle trait `DirectLingam::fit`
//!   drives: create (once per fit) → [`step`](OrderingSession::step)
//!   (score → choose → residualize+update) × (d−1) → finish. Sessions can
//!   be [`reset`](OrderingSession::reset) with a fresh same-shape panel
//!   so bootstrap resamples reuse one workspace allocation.
//! - [`IncrementalSession`] — the workspace the CPU engines hand out: it
//!   owns the standardized column cache, a persistent correlation
//!   matrix, the per-column entropy cache and the packed active-index
//!   scratch, all reused across steps (and across whole fits via
//!   `reset`).
//!   After each step it residualizes the *standardized cache in place*
//!   (closed form `(c_j − ρ_jm·c_m)/√(1−ρ_jm²)`, with the shared
//!   ρ²-clamp) and updates the correlation matrix analytically,
//!   `ρ'_jk = (ρ_jk − ρ_jm·ρ_km)/√((1−ρ_jm²)(1−ρ_km²))`, in O(d²)
//!   instead of O(d²·n) dots. Only the entropy and pair-score sweeps
//!   still touch sample data.
//! - [`StatelessSession`] — the compatibility shim: owns a panel clone
//!   and delegates every step to `OrderingEngine::order_step`, so
//!   engines with a fused per-step path (the XLA artifact) or a
//!   deliberately unoptimized one (the sequential baseline) keep their
//!   exact per-step semantics under the session API.
//!
//! The per-step pair sweep itself runs under a [`SweepStrategy`]: exact
//! (every pair, the default) or bound-pruned ([`super::sweep`]) — where
//! the persistent correlation matrix makes per-pair setup free, so every
//! comparison the bound prunes is pure saving. What each sweep touched
//! is accumulated into [`SweepCounters`] and surfaced through
//! [`OrderingSession::sweep_counters`].
//!
//! Why the closed forms are exact: the cached columns are standardized,
//! so the residual `c_j − ρ_jm·c_m` has mean 0 and variance `1 − ρ_jm²`;
//! dividing by `√(1−ρ_jm²)` re-standardizes it without another pass over
//! the data, and the correlation of two such residuals expands to the
//! analytic update above (using `ρ_mm = 1`). The incremental path
//! therefore agrees with a from-scratch recompute to float precision —
//! pinned per step by `tests/session_state.rs`.

use super::engine::{
    accumulate_pair_diffs, argmax_active, dot, scatter_scores, OrderStep, OrderingEngine,
    INACTIVE_SCORE,
};
use super::parallel::tiled_pair_sweep;
use super::sweep::{
    entropy_fused_kernel, pair_diff_with_rho_kernel, pair_work, pruned_sweep,
    pruned_sweep_parallel, SweepCounters, SweepStrategy,
};
use crate::linalg::Mat;
use crate::stats;
use crate::util::pool::{parallel_chunks_mut, parallel_indexed};
use crate::util::{Error, Result};

/// Same small-problem cutoffs as `ParallelEngine`: below ~1 ms of fused
/// pair work (pairs × n elements) the scoped spawn/join overhead
/// outweighs the work itself.
const MIN_PARALLEL_PAIR_WORK: usize = 1 << 18;
/// Column-elements threshold below which per-column sweeps stay serial.
const MIN_PARALLEL_COL_WORK: usize = 1 << 16;

/// One causal-ordering run over one panel: the stateful counterpart of
/// the `OrderingEngine` trait (engines act as session factories via
/// [`OrderingEngine::session`]).
///
/// `Send` so a bootstrap worker can park a finished session in a shared
/// pool for another worker to [`reset`](OrderingSession::reset) and
/// reuse.
pub trait OrderingSession: Send {
    /// Number of still-active variables.
    fn remaining(&self) -> usize;

    /// Sample count of the panel the workspace was seeded with.
    fn rows(&self) -> usize;

    /// Active mask over the original variable indices.
    fn active(&self) -> &[bool];

    /// One full search step: score the active set, pick the argmax,
    /// residualize the workspace against the choice and deactivate it.
    fn step(&mut self) -> Result<OrderStep>;

    /// Re-seed the workspace with a fresh panel of the same `[n, d]`
    /// shape, reusing every buffer (the bootstrap's session pool calls
    /// this once per resample). Errors on a shape mismatch.
    fn reset(&mut self, data: &Mat) -> Result<()>;

    /// Instrumentation counters accumulated over this fit's sweeps
    /// (pairs visited / skipped, elements touched — see
    /// [`SweepCounters`]). Sessions without an instrumented sweep (the
    /// stateless shim, the device session) report zeros.
    fn sweep_counters(&self) -> SweepCounters {
        SweepCounters::default()
    }
}

// ---------------------------------------------------------------------
// Per-step instrumentation.
// ---------------------------------------------------------------------

/// Per-step instrumentation seam: every step loop — the solo drive in
/// [`DirectLingam`](super::direct::DirectLingam), the lock-step batch
/// ([`BatchedSession::step_live_observed`](super::batch::BatchedSession::step_live_observed))
/// and the streaming full refit
/// ([`StreamingLingam::ingest_stepped`](super::streaming::StreamingLingam::ingest_stepped))
/// — reports through this one trait, unifying what used to be ad-hoc
/// `FnMut(step, total)` progress closures with the
/// [`StageProfile`](crate::util::timer::StageProfile)/[`SweepCounters`]
/// plumbing. The serve worker installs an implementation that books the
/// step-time histogram, trace spans, progress frames and cancellation;
/// returning `Err` aborts the fit at the step boundary.
pub trait StepObserver {
    /// One search step finished: `step` of `total` (1-based), measured
    /// at `elapsed` wall clock.
    fn step_done(&mut self, step: usize, total: usize, elapsed: std::time::Duration)
        -> Result<()>;

    /// The step loop completed (not called on abort): final sweep
    /// counters for the fit.
    fn sweep_done(&mut self, _counters: &SweepCounters) {}
}

/// The no-op observer (uninstrumented fits).
pub struct NullObserver;

impl StepObserver for NullObserver {
    fn step_done(&mut self, _: usize, _: usize, _: std::time::Duration) -> Result<()> {
        Ok(())
    }
}

/// Adapter: any legacy `FnMut(step, total) -> Result<()>` progress
/// closure observes steps (ignoring timing), so the pre-existing
/// `*_observed` entry points keep their signatures.
pub struct FnObserver<'a>(pub &'a mut dyn FnMut(usize, usize) -> Result<()>);

impl StepObserver for FnObserver<'_> {
    fn step_done(&mut self, step: usize, total: usize, _: std::time::Duration) -> Result<()> {
        (self.0)(step, total)
    }
}

// ---------------------------------------------------------------------
// Stateless compatibility shim.
// ---------------------------------------------------------------------

/// Adapter that runs any [`OrderingEngine`] under the session API by
/// owning a panel clone and delegating each step to
/// `OrderingEngine::order_step` — the exact legacy per-step semantics
/// (the sequential baseline's per-pair recomputation, the XLA engine's
/// fused on-device step).
pub struct StatelessSession<'e> {
    engine: &'e dyn OrderingEngine,
    x: Mat,
    active: Vec<bool>,
}

impl<'e> StatelessSession<'e> {
    /// Clone the panel into the shim's private working copy.
    pub fn new(engine: &'e dyn OrderingEngine, data: &Mat) -> StatelessSession<'e> {
        StatelessSession { engine, x: data.clone(), active: vec![true; data.cols()] }
    }
}

impl OrderingSession for StatelessSession<'_> {
    fn remaining(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    fn rows(&self) -> usize {
        self.x.rows()
    }

    fn active(&self) -> &[bool] {
        &self.active
    }

    fn step(&mut self) -> Result<OrderStep> {
        self.engine.order_step(&mut self.x, &mut self.active)
    }

    fn reset(&mut self, data: &Mat) -> Result<()> {
        if (data.rows(), data.cols()) != (self.x.rows(), self.x.cols()) {
            return Err(Error::Shape(format!(
                "session reset: panel is {}x{}, workspace is {}x{}",
                data.rows(),
                data.cols(),
                self.x.rows(),
                self.x.cols()
            )));
        }
        self.x.as_mut_slice().copy_from_slice(data.as_slice());
        self.active.fill(true);
        Ok(())
    }
}

// ---------------------------------------------------------------------
// Incremental workspace session.
// ---------------------------------------------------------------------

/// The reusable ordering workspace (see module docs): standardized
/// column cache + persistent correlation matrix + entropy cache +
/// packed-index scratch, updated in place after every step.
///
/// `workers == 1` gives the single-threaded restructured path
/// (`VectorizedEngine`'s session); `workers > 1` tiles the entropy and
/// pair sweeps, the cache residualization and the initial correlation
/// build across the crate's worker pool (`ParallelEngine`'s session).
pub struct IncrementalSession {
    n: usize,
    d: usize,
    active: Vec<bool>,
    /// Standardized column cache (entries of removed columns are stale).
    cols: Vec<Vec<f64>>,
    /// Persistent correlation matrix; rows/columns of removed variables
    /// are stale, the active block is maintained by the closed-form
    /// update.
    corr: Mat,
    /// Per-column entropy cache, refreshed once per step (the stateless
    /// path recomputes entropies per engine call; the sequential
    /// reference recomputes them per *pair*).
    h: Vec<f64>,
    /// Packed active indices, rebuilt per step into the same buffer.
    idx: Vec<usize>,
    workers: usize,
    force_parallel: bool,
    /// Exact or bound-pruned pair sweeps ([`super::sweep`]).
    strategy: SweepStrategy,
    /// Previous step's full-width scores: the pruned sweep's candidate
    /// schedule (likely roots first, so the bound tightens early).
    /// Empty before the first step and after a reset.
    prev_scores: Vec<f64>,
    /// First-step schedule seed (pruned strategy only): per-column
    /// |excess kurtosis| of the standardized cache, computed once per
    /// rebuild. The very first sweep has no previous scores, and
    /// ParaLiNGAM reports most of the residual pruning headroom is
    /// exactly there — an exogenous variable keeps its noise
    /// distribution's full non-Gaussianity while downstream mixtures are
    /// driven toward Gaussian by the CLT, so scheduling the most
    /// non-Gaussian columns first tends to complete the true root early
    /// and tighten the bound immediately. Scheduling only: a bad proxy
    /// costs pruning efficiency, never correctness (see `sweep`'s
    /// exactness argument, which is schedule-independent).
    seed_scores: Vec<f64>,
    /// Sweep instrumentation, accumulated across the fit's steps.
    counters: SweepCounters,
    /// Route the transcendental pass through the `fastmath` polynomial
    /// `exp` (only settable when that feature is compiled in; always
    /// false otherwise).
    fast_kernel: bool,
}

impl IncrementalSession {
    /// Build the workspace: standardize every column once and compute
    /// the full correlation matrix once. `workers == 1` keeps every
    /// sweep serial; `force_parallel` disables the small-problem serial
    /// fallback (tests and scaling benches). Sweeps are exact; use
    /// [`with_strategy`](IncrementalSession::with_strategy) for the
    /// bound-pruned mode.
    pub fn new(data: &Mat, workers: usize, force_parallel: bool) -> Result<IncrementalSession> {
        IncrementalSession::with_strategy(data, workers, force_parallel, SweepStrategy::Exact)
    }

    /// [`new`](IncrementalSession::new) with an explicit sweep strategy.
    /// Under [`SweepStrategy::Pruned`] every step's pair sweep carries a
    /// running penalty per candidate, schedules candidates by the
    /// previous step's scores, and drops dominated candidates early —
    /// choosing the identical root sequence as the exact sweep while
    /// skipping part of the O(d²·n) pair work (the cached correlation
    /// matrix already makes per-pair setup free here, so the skipped
    /// kernel sweeps are pure saving).
    pub fn with_strategy(
        data: &Mat,
        workers: usize,
        force_parallel: bool,
        strategy: SweepStrategy,
    ) -> Result<IncrementalSession> {
        let (n, d) = (data.rows(), data.cols());
        if d < 1 || n < 2 {
            return Err(Error::InvalidArgument(format!(
                "ordering session needs n ≥ 2 and d ≥ 1, got {n}x{d}"
            )));
        }
        let mut s = IncrementalSession {
            n,
            d,
            active: vec![true; d],
            cols: vec![Vec::new(); d],
            corr: Mat::zeros(d, d),
            h: vec![0.0; d],
            idx: Vec::with_capacity(d),
            workers: workers.max(1),
            force_parallel,
            strategy,
            prev_scores: Vec::new(),
            seed_scores: Vec::new(),
            counters: SweepCounters::default(),
            fast_kernel: false,
        };
        s.rebuild(data);
        Ok(s)
    }

    /// Seed the workspace directly from a precomputed standardized
    /// column cache and its correlation matrix, skipping
    /// [`rebuild`](IncrementalSession::with_strategy)'s O(n·d²)
    /// standardize-and-correlate pass entirely — the entry point of the
    /// streaming window ([`super::streaming`]), which maintains exactly
    /// these statistics under rank-1 update/downdate as samples enter
    /// and leave, so each frame's ordering starts from the
    /// already-current statistics in O(n·d) (materializing the cache)
    /// instead of O(n·d²).
    ///
    /// The caller's contract: `cols` are the panel's columns
    /// standardized to zero mean / unit population std (the
    /// [`stats::standardize`] convention, including its 1e-12 std
    /// floor) and `corr[(a,b)] = dot(cols[a], cols[b]) / n` — what
    /// `rebuild` would have computed. Shapes are checked here; the
    /// statistical contract cannot be and is pinned instead by
    /// `tests/streaming_agreement.rs` against from-scratch fits.
    pub fn from_statistics(
        cols: Vec<Vec<f64>>,
        corr: Mat,
        workers: usize,
        strategy: SweepStrategy,
    ) -> Result<IncrementalSession> {
        let d = cols.len();
        let n = cols.first().map_or(0, Vec::len);
        if d < 1 || n < 2 {
            return Err(Error::InvalidArgument(format!(
                "ordering session needs n ≥ 2 and d ≥ 1, got {n}x{d}"
            )));
        }
        if cols.iter().any(|c| c.len() != n) {
            return Err(Error::Shape(
                "seeded session: column cache is ragged (columns differ in length)".into(),
            ));
        }
        if (corr.rows(), corr.cols()) != (d, d) {
            return Err(Error::Shape(format!(
                "seeded session: correlation is {}x{}, cache is {n}x{d}",
                corr.rows(),
                corr.cols()
            )));
        }
        let mut s = IncrementalSession {
            n,
            d,
            active: vec![true; d],
            cols,
            corr,
            h: vec![0.0; d],
            idx: Vec::with_capacity(d),
            workers: workers.max(1),
            force_parallel: false,
            strategy,
            prev_scores: Vec::new(),
            seed_scores: Vec::new(),
            counters: SweepCounters::default(),
            fast_kernel: false,
        };
        // replicate `rebuild`'s fresh-fit tail: pruned mode seeds the
        // first-step schedule from the cache's |excess kurtosis|
        if s.strategy == SweepStrategy::Pruned {
            let inv_n = 1.0 / s.n as f64;
            s.seed_scores.extend(s.cols.iter().map(|col| {
                let m4 = col.iter().map(|&v| (v * v) * (v * v)).sum::<f64>() * inv_n;
                (m4 - 3.0).abs()
            }));
        }
        Ok(s)
    }

    /// Take the workspace's large buffers back (column cache +
    /// correlation matrix) so a per-frame caller can refill them instead
    /// of reallocating — the streaming window's churn-avoidance loop:
    /// seed → fit → reclaim → refill → seed. The contents are stale
    /// (residualized in place by the fit); only the allocations matter.
    pub fn into_workspace(self) -> (Vec<Vec<f64>>, Mat) {
        (self.cols, self.corr)
    }

    /// Resolved worker count of the session's sweeps.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// The session's sweep strategy.
    pub fn strategy(&self) -> SweepStrategy {
        self.strategy
    }

    /// Counters accumulated over this fit's sweeps (zeroed by `reset`).
    pub fn counters(&self) -> SweepCounters {
        self.counters
    }

    /// The first-step schedule seed: per-column |excess kurtosis| of the
    /// standardized cache (non-empty only under the pruned strategy).
    /// Exposed for the pruning-exactness test suite.
    pub fn seed_scores(&self) -> &[f64] {
        &self.seed_scores
    }

    /// Swap the transcendental pass to the accuracy-bounded polynomial
    /// `exp` of [`super::sweep::fastmath`] (relative error ≤ 2e-7 per
    /// `exp` call). Never on by default: the agreement suites pin the
    /// precise kernel bitwise.
    #[cfg(feature = "fastmath")]
    pub fn with_fast_kernel(mut self) -> IncrementalSession {
        self.fast_kernel = true;
        self
    }

    /// The cached correlation matrix (active block is live; rows and
    /// columns of removed variables are stale). Exposed for the
    /// session-state test suite.
    pub fn corr(&self) -> &Mat {
        &self.corr
    }

    /// The cached standardized column `i` (stale once `i` is removed).
    /// Exposed for the session-state test suite.
    pub fn cached_column(&self, i: usize) -> &[f64] {
        &self.cols[i]
    }

    /// Score the active set from the workspace: refresh the entropy
    /// cache (one fused pass per active column), then run the pair sweep
    /// with the *cached* correlations — no per-pair dot. Under the
    /// pruned strategy the sweep is scheduled by the previous step's
    /// scores and dominated candidates stop early (identical argmax,
    /// partial losing scores); either way the sweep's work is booked
    /// into [`counters`](IncrementalSession::counters).
    pub fn scores(&mut self) -> Result<Vec<f64>> {
        self.idx.clear();
        self.idx.extend((0..self.d).filter(|&i| self.active[i]));
        let m = self.idx.len();
        if m == 0 {
            return Ok(vec![INACTIVE_SCORE; self.d]);
        }
        let fast = self.fast_kernel;
        if self.use_pool(m.saturating_mul(self.n), MIN_PARALLEL_COL_WORK) {
            let (cols, idx) = (&self.cols, &self.idx);
            let hs = parallel_indexed(m, self.workers.min(m), |t| {
                entropy_fused_kernel(fast, &cols[idx[t]])
            });
            for (t, hv) in hs.into_iter().enumerate() {
                self.h[self.idx[t]] = hv;
            }
        } else {
            for t in 0..m {
                let i = self.idx[t];
                self.h[i] = entropy_fused_kernel(fast, &self.cols[i]);
            }
        }
        let (cols, corr, h, idx) = (&self.cols, &self.corr, &self.h, &self.idx);
        let diff = |a: usize, b: usize| {
            let (ia, ib) = (idx[a], idx[b]);
            pair_diff_with_rho_kernel(fast, &cols[ia], &cols[ib], corr[(ia, ib)], h[ia], h[ib])
        };
        let pooled = m >= 2 && self.use_pool(pair_work(m, self.n), MIN_PARALLEL_PAIR_WORK);
        let mut call = SweepCounters::default();
        let k = match self.strategy {
            SweepStrategy::Exact => {
                call.record_exact(m, self.n);
                if pooled {
                    tiled_pair_sweep(m, self.workers, &diff)
                } else {
                    accumulate_pair_diffs(m, &diff)
                }
            }
            SweepStrategy::Pruned => {
                // schedule by the previous step's scores over the still
                // active variables (likely roots first); the first step
                // has none, so it falls back to the per-column
                // non-Gaussianity proxies computed at rebuild
                let priority: Option<Vec<f64>> = if self.prev_scores.len() == self.d {
                    Some(idx.iter().map(|&i| self.prev_scores[i]).collect())
                } else if self.seed_scores.len() == self.d {
                    Some(idx.iter().map(|&i| self.seed_scores[i]).collect())
                } else {
                    None
                };
                if pooled {
                    pruned_sweep_parallel(
                        m,
                        self.workers,
                        &diff,
                        priority.as_deref(),
                        self.n,
                        &mut call,
                    )
                } else {
                    pruned_sweep(m, &diff, priority.as_deref(), self.n, &mut call)
                }
            }
        };
        self.counters.merge(&call);
        let out = scatter_scores(self.d, &self.idx, &k);
        if self.strategy == SweepStrategy::Pruned {
            self.prev_scores.clear();
            self.prev_scores.extend_from_slice(&out);
        }
        Ok(out)
    }

    /// Commit a choice: residualize the cache against `chosen`, update
    /// the correlation matrix, deactivate it. The one public entry point
    /// for callers that pick the root themselves (tests, external
    /// selection policies) — it enforces the "root must still be active"
    /// precondition the raw update relies on.
    pub fn advance_with(&mut self, chosen: usize) -> Result<()> {
        if chosen >= self.d || !self.active[chosen] {
            return Err(Error::InvalidArgument(format!(
                "cannot advance the session on inactive variable {chosen}"
            )));
        }
        self.residualize_and_update(chosen);
        self.active[chosen] = false;
        Ok(())
    }

    /// Residualize the standardized cache in place against root `m` —
    /// closed form `(c_j − ρ_jm·c_m)/√(1−ρ_jm²)` with the shared
    /// ρ²-clamp — and update the cached correlation matrix analytically:
    /// `ρ'_jk = (ρ_jk − ρ_jm·ρ_km)/√((1−ρ_jm²)(1−ρ_km²))`. One fused
    /// O(n) pass per column plus an O(d²) matrix update, versus the
    /// stateless path's per-step O(d·n) re-standardization and O(d²·n)
    /// correlation dots.
    ///
    /// Private: calling it twice for the same root would rewrite the
    /// workspace from its own stale row; [`advance_with`] is the checked
    /// public entry point.
    ///
    /// [`advance_with`]: IncrementalSession::advance_with
    fn residualize_and_update(&mut self, m: usize) {
        debug_assert!(self.active[m], "residualizing against an inactive root");
        let targets: Vec<usize> =
            (0..self.d).filter(|&j| j != m && self.active[j]).collect();
        if targets.is_empty() {
            return;
        }
        // inverse denominators from the cached correlation row of m; the
        // clamp matches `pair_diff` so collinear columns stay finite
        let dinv: Vec<f64> = targets
            .iter()
            .map(|&j| {
                let r = self.corr[(j, m)];
                1.0 / (1.0 - (r * r).min(1.0)).sqrt().max(1e-12)
            })
            .collect();

        // 1) cache update: one fused pass per column (standardized by
        // construction — no mean/std sweeps)
        let cm = std::mem::take(&mut self.cols[m]);
        if self.use_pool(targets.len().saturating_mul(self.n), MIN_PARALLEL_COL_WORK) {
            // take the target columns out so workers own disjoint buffers
            let mut taken: Vec<(usize, Vec<f64>)> = targets
                .iter()
                .map(|&j| (j, std::mem::take(&mut self.cols[j])))
                .collect();
            let corr = &self.corr;
            parallel_chunks_mut(&mut taken, self.workers, |start, chunk| {
                for (off, (j, col)) in chunk.iter_mut().enumerate() {
                    let r = corr[(*j, m)];
                    let s = dinv[start + off];
                    for (v, &cmv) in col.iter_mut().zip(&cm) {
                        *v = (*v - r * cmv) * s;
                    }
                }
            });
            for (j, col) in taken {
                self.cols[j] = col;
            }
        } else {
            for (t, &j) in targets.iter().enumerate() {
                let r = self.corr[(j, m)];
                let s = dinv[t];
                let col = &mut self.cols[j];
                for (v, &cmv) in col.iter_mut().zip(&cm) {
                    *v = (*v - r * cmv) * s;
                }
            }
        }
        self.cols[m] = cm;

        // 2) closed-form correlation update over the remaining active
        // block (row/column m is left stale on purpose). The clamp keeps
        // later denominators well-defined when a pair collapses to
        // collinearity.
        for (ta, &ja) in targets.iter().enumerate() {
            let ra = self.corr[(ja, m)];
            for (tb, &jb) in targets.iter().enumerate().skip(ta + 1) {
                let rb = self.corr[(jb, m)];
                let v = ((self.corr[(ja, jb)] - ra * rb) * dinv[ta] * dinv[tb]).clamp(-1.0, 1.0);
                self.corr[(ja, jb)] = v;
                self.corr[(jb, ja)] = v;
            }
        }
    }

    /// Standardize every column into the cache and recompute the full
    /// correlation matrix (once per fit; shared by `new` and `reset`).
    fn rebuild(&mut self, data: &Mat) {
        for c in 0..self.d {
            let col = &mut self.cols[c];
            col.clear();
            col.extend((0..self.n).map(|r| data[(r, c)]));
            stats::standardize(col);
        }
        if self.d >= 2 && self.use_pool(pair_work(self.d, self.n), MIN_PARALLEL_PAIR_WORK) {
            let n = self.n;
            let rows = {
                let cols = &self.cols;
                parallel_indexed(self.d, self.workers.min(self.d), |a| {
                    ((a + 1)..self.d)
                        .map(|b| dot(&cols[a], &cols[b]) / n as f64)
                        .collect::<Vec<f64>>()
                })
            };
            for (a, row) in rows.into_iter().enumerate() {
                for (off, v) in row.into_iter().enumerate() {
                    let b = a + 1 + off;
                    self.corr[(a, b)] = v;
                    self.corr[(b, a)] = v;
                }
            }
        } else {
            for a in 0..self.d {
                for b in (a + 1)..self.d {
                    let v = dot(&self.cols[a], &self.cols[b]) / self.n as f64;
                    self.corr[(a, b)] = v;
                    self.corr[(b, a)] = v;
                }
            }
        }
        for i in 0..self.d {
            self.corr[(i, i)] = 1.0;
        }
        self.active.fill(true);
        // a rebuilt workspace is a fresh fit: no previous-step schedule,
        // fresh instrumentation
        self.prev_scores.clear();
        self.counters = SweepCounters::default();
        // first-step schedule seed (pruned mode only): |excess kurtosis|
        // per standardized column. One O(d·n) pass — cheaper than a
        // single candidate's pair row — and the cache is standardized,
        // so the fourth moment alone gives m4/σ⁴ − 3 = m4 − 3.
        self.seed_scores.clear();
        if self.strategy == SweepStrategy::Pruned {
            let inv_n = 1.0 / self.n as f64;
            self.seed_scores.extend(self.cols.iter().map(|col| {
                let m4 = col.iter().map(|&v| (v * v) * (v * v)).sum::<f64>() * inv_n;
                (m4 - 3.0).abs()
            }));
        }
    }

    fn use_pool(&self, work: usize, cutoff: usize) -> bool {
        self.workers > 1 && (self.force_parallel || work >= cutoff)
    }
}

impl OrderingSession for IncrementalSession {
    fn remaining(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    fn rows(&self) -> usize {
        self.n
    }

    fn active(&self) -> &[bool] {
        &self.active
    }

    fn step(&mut self) -> Result<OrderStep> {
        let scores = self.scores()?;
        let chosen = argmax_active(&scores, &self.active)?;
        self.advance_with(chosen)?;
        Ok(OrderStep { chosen, scores })
    }

    fn reset(&mut self, data: &Mat) -> Result<()> {
        if (data.rows(), data.cols()) != (self.n, self.d) {
            return Err(Error::Shape(format!(
                "session reset: panel is {}x{}, workspace is {}x{}",
                data.rows(),
                data.cols(),
                self.n,
                self.d
            )));
        }
        self.rebuild(data);
        Ok(())
    }

    fn sweep_counters(&self) -> SweepCounters {
        self.counters
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lingam::engine::VectorizedEngine;
    use crate::sim::{simulate_sem, SemSpec};
    use crate::util::rng::Pcg64;

    fn toy_panel(n: usize, d: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::seed_from_u64(seed);
        simulate_sem(&SemSpec::layered(d, 2, 0.6), n, &mut rng).data
    }

    #[test]
    fn first_step_scores_match_stateless_exactly() {
        // before any residualization the session runs the same dots and
        // sweeps as the stateless engine, in the same order: bitwise equal
        let x = toy_panel(800, 7, 1);
        let active = vec![true; 7];
        let stateless = VectorizedEngine.scores(&x, &active).unwrap();
        let mut session = IncrementalSession::new(&x, 1, false).unwrap();
        let first = session.scores().unwrap();
        assert_eq!(stateless, first);
    }

    #[test]
    fn step_deactivates_and_reports_choice() {
        let x = toy_panel(400, 5, 2);
        let mut s = IncrementalSession::new(&x, 1, false).unwrap();
        assert_eq!(s.remaining(), 5);
        let step = s.step().unwrap();
        assert!(!s.active()[step.chosen]);
        assert_eq!(s.remaining(), 4);
        assert_eq!(step.scores.len(), 5);
    }

    #[test]
    fn advance_with_rejects_inactive() {
        let x = toy_panel(100, 4, 3);
        let mut s = IncrementalSession::new(&x, 1, false).unwrap();
        s.advance_with(2).unwrap();
        assert!(s.advance_with(2).is_err());
        assert!(s.advance_with(9).is_err());
    }

    #[test]
    fn reset_restores_a_fresh_workspace() {
        let x = toy_panel(300, 5, 4);
        let y = toy_panel(300, 5, 5);
        let mut fresh = IncrementalSession::new(&y, 1, false).unwrap();
        let mut reused = IncrementalSession::new(&x, 1, false).unwrap();
        let _ = reused.step().unwrap();
        let _ = reused.step().unwrap();
        reused.reset(&y).unwrap();
        assert_eq!(reused.remaining(), 5);
        assert_eq!(fresh.scores().unwrap(), reused.scores().unwrap());
    }

    #[test]
    fn reset_rejects_shape_mismatch() {
        let x = toy_panel(300, 5, 6);
        let mut s = IncrementalSession::new(&x, 1, false).unwrap();
        assert!(s.reset(&toy_panel(300, 4, 6)).is_err());
        assert!(s.reset(&toy_panel(200, 5, 6)).is_err());
    }

    #[test]
    fn parallel_session_matches_serial_session() {
        let x = toy_panel(600, 8, 7);
        let mut serial = IncrementalSession::new(&x, 1, false).unwrap();
        let mut par = IncrementalSession::new(&x, 4, true).unwrap();
        for _ in 0..7 {
            let a = serial.step().unwrap();
            let b = par.step().unwrap();
            assert_eq!(a.chosen, b.chosen);
            for i in 0..8 {
                let (sa, sb) = (a.scores[i], b.scores[i]);
                if sa == INACTIVE_SCORE {
                    assert_eq!(sb, INACTIVE_SCORE);
                } else {
                    assert!(
                        (sa - sb).abs() < 1e-9 * (1.0 + sa.abs()),
                        "i={i}: serial={sa} parallel={sb}"
                    );
                }
            }
        }
    }

    #[test]
    fn seed_scores_rank_non_gaussian_columns_first() {
        // a raw uniform column (excess kurtosis ≈ −1.2) must out-rank a
        // sum of 8 uniforms (CLT-washed, ≈ −0.15) in the schedule seed
        let mut rng = Pcg64::seed_from_u64(40);
        let n = 4_000;
        let x = Mat::from_fn(n, 2, |_, c| {
            if c == 0 {
                rng.f64()
            } else {
                (0..8).map(|_| rng.f64()).sum::<f64>()
            }
        });
        let exact = IncrementalSession::new(&x, 1, false).unwrap();
        assert!(exact.seed_scores().is_empty(), "exact mode must not pay for the seed pass");
        let pruned =
            IncrementalSession::with_strategy(&x, 1, false, SweepStrategy::Pruned).unwrap();
        let seeds = pruned.seed_scores();
        assert_eq!(seeds.len(), 2);
        assert!(
            seeds[0] > seeds[1],
            "uniform column must rank first: {seeds:?}"
        );
        assert!((seeds[0] - 1.2).abs() < 0.2, "uniform |kurtosis| ≈ 1.2, got {}", seeds[0]);
    }

    #[test]
    fn seeded_session_is_bitwise_the_rebuilt_session() {
        // from_statistics with the exact statistics rebuild() would have
        // computed must reproduce the whole fit bitwise — step choices
        // AND step scores — in both sweep strategies
        let x = toy_panel(500, 6, 9);
        let (n, d) = (x.rows(), x.cols());
        for strategy in [SweepStrategy::Exact, SweepStrategy::Pruned] {
            let cols: Vec<Vec<f64>> = (0..d)
                .map(|c| {
                    let mut col = x.col(c);
                    stats::standardize(&mut col);
                    col
                })
                .collect();
            let mut corr = Mat::zeros(d, d);
            for a in 0..d {
                corr[(a, a)] = 1.0;
                for b in (a + 1)..d {
                    let v = dot(&cols[a], &cols[b]) / n as f64;
                    corr[(a, b)] = v;
                    corr[(b, a)] = v;
                }
            }
            let mut seeded =
                IncrementalSession::from_statistics(cols, corr, 1, strategy).unwrap();
            let mut scratch =
                IncrementalSession::with_strategy(&x, 1, false, strategy).unwrap();
            assert_eq!(seeded.seed_scores(), scratch.seed_scores());
            for _ in 0..(d - 1) {
                let a = scratch.step().unwrap();
                let b = seeded.step().unwrap();
                assert_eq!(a.chosen, b.chosen);
                assert_eq!(a.scores, b.scores, "seeded session diverged ({strategy:?})");
            }
        }
    }

    #[test]
    fn from_statistics_rejects_bad_shapes() {
        let cols = vec![vec![0.0; 16], vec![0.0; 16]];
        // correlation shape must match the cache
        assert!(IncrementalSession::from_statistics(
            cols.clone(),
            Mat::zeros(3, 3),
            1,
            SweepStrategy::Exact
        )
        .is_err());
        // ragged cache
        assert!(IncrementalSession::from_statistics(
            vec![vec![0.0; 16], vec![0.0; 8]],
            Mat::zeros(2, 2),
            1,
            SweepStrategy::Exact
        )
        .is_err());
        // empty / too-short
        assert!(IncrementalSession::from_statistics(
            Vec::new(),
            Mat::zeros(0, 0),
            1,
            SweepStrategy::Exact
        )
        .is_err());
        assert!(IncrementalSession::from_statistics(
            vec![vec![0.0; 1]],
            Mat::zeros(1, 1),
            1,
            SweepStrategy::Exact
        )
        .is_err());
    }

    #[test]
    fn exhausted_session_scores_are_inactive() {
        let x = toy_panel(100, 3, 8);
        let mut s = IncrementalSession::new(&x, 1, false).unwrap();
        for _ in 0..3 {
            let _ = s.step().unwrap();
        }
        assert_eq!(s.remaining(), 0);
        assert!(s.scores().unwrap().iter().all(|&v| v == INACTIVE_SCORE));
        assert!(s.step().is_err());
    }
}
