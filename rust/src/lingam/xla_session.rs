//! `XlaSession` — the device-resident ordering session behind
//! `XlaEngine`: the accelerated analogue of [`IncrementalSession`].
//!
//! The stateless XLA path re-uploads the zero-padded panel and
//! re-derives its statistics on every `order_step` call — O(steps) panel
//! transfers per fit. This session instead keeps the whole workspace
//! *on the device* as one packed PJRT buffer
//! (`python/compile/kernels/session.py` #state-layout) and drives it
//! through three single-output artifacts:
//!
//! 1. `session_init` — the **one panel upload of the fit**: masked
//!    standardize + correlation matmul, packed into the resident state.
//! 2. `session_scores` — per step, the [d] score row is the **only
//!    download**; the NaN-safe argmax then runs on the host
//!    ([`argmax_active`]), which keeps tie-breaking and degenerate-panel
//!    rejection bit-identical to the CPU engines.
//! 3. `session_update` — per step, the [d] one-hot choice is the **only
//!    upload**; on the device the standardized cache is residualized in
//!    place via the shared ρ²-clamped closed form and the correlation
//!    matrix updated analytically in O(d²), exactly the
//!    `IncrementalSession` math in f32.
//!
//! Buffer lifetime: the state handle is owned by the executor's device
//! thread; each `session_update` swaps the handle (old state freed, new
//! state kept resident) and `Drop`/`reset` release it, so a bootstrap
//! worker can park and reuse the session like any CPU workspace — a
//! `reset` costs one fresh `session_init` upload for the new resample
//! and nothing else.
//!
//! [`IncrementalSession`]: super::session::IncrementalSession
//! [`argmax_active`]: super::engine::argmax_active

use super::engine::{argmax_active, OrderStep, INACTIVE_SCORE};
use super::session::OrderingSession;
use crate::linalg::Mat;
use crate::runtime::{
    ArgValue, ArtifactKind, ArtifactRegistry, Bucket, BufferId, DeviceExecutor, HostArray,
};
use crate::util::{Error, Result};
use std::path::PathBuf;
use std::sync::Arc;

/// Resolve the session artifact triple for a panel shape: `best` buckets
/// the init request, then the scores/update kinds must exist at exactly
/// that shape (the packed state threads between them, so re-bucketing
/// any one of them would desynchronize the layout).
pub(crate) fn resolve_session_buckets(
    registry: &ArtifactRegistry,
    n: usize,
    d: usize,
) -> Result<(Bucket, Bucket, Bucket)> {
    let init = registry.best(ArtifactKind::SessionInit, n, d)?.clone();
    let scores = registry.exact(ArtifactKind::SessionScores, init.n, init.d)?.clone();
    let update = registry.exact(ArtifactKind::SessionUpdate, init.n, init.d)?.clone();
    Ok((init, scores, update))
}

/// A device-resident ordering session (see module docs).
pub struct XlaSession {
    executor: Arc<DeviceExecutor>,
    init_path: PathBuf,
    scores_path: PathBuf,
    update_path: PathBuf,
    /// Bucket (padded) shape.
    nb: usize,
    db: usize,
    /// True panel extents.
    n: usize,
    d: usize,
    active: Vec<bool>,
    /// Handle to the packed on-device state (cache + correlations +
    /// masks); swapped on every step.
    state: Option<BufferId>,
}

impl XlaSession {
    /// Open a session over a panel: resolve the artifact triple and
    /// perform the fit's single panel upload (`session_init`).
    pub fn new(
        executor: Arc<DeviceExecutor>,
        registry: &ArtifactRegistry,
        data: &Mat,
    ) -> Result<XlaSession> {
        let (n, d) = (data.rows(), data.cols());
        let (init, scores, update) = resolve_session_buckets(registry, n, d)?;
        let (nb, db) = (init.n, init.d);
        let mut session = XlaSession {
            executor,
            init_path: init.path,
            scores_path: scores.path,
            update_path: update.path,
            nb,
            db,
            n,
            d,
            active: vec![true; d],
            state: None,
        };
        session.upload_panel(data)?;
        Ok(session)
    }

    /// The one host→device panel transfer: pad into the bucket shape and
    /// run `session_init`, keeping the packed state resident. Also the
    /// whole cost of a [`reset`](OrderingSession::reset).
    fn upload_panel(&mut self, data: &Mat) -> Result<()> {
        let mut x_pad = vec![0.0f32; self.nb * self.db];
        for r in 0..self.n {
            let src = data.row(r);
            let dst = &mut x_pad[r * self.db..r * self.db + self.d];
            for (c, out) in dst.iter_mut().enumerate() {
                *out = src[c] as f32;
            }
        }
        let mut row_mask = vec![0.0f32; self.nb];
        for v in row_mask.iter_mut().take(self.n) {
            *v = 1.0;
        }
        let mut col_mask = vec![0.0f32; self.db];
        for v in col_mask.iter_mut().take(self.d) {
            *v = 1.0;
        }
        let args = vec![
            ArgValue::Host(HostArray::new(vec![self.nb as i64, self.db as i64], x_pad)),
            ArgValue::Host(HostArray::vector(row_mask)),
            ArgValue::Host(HostArray::vector(col_mask)),
        ];
        let fresh = self.executor.run_resident(self.init_path.clone(), args)?;
        if let Some(old) = self.state.take() {
            self.executor.free_buffer(old);
        }
        self.state = Some(fresh);
        Ok(())
    }
}

impl OrderingSession for XlaSession {
    fn remaining(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    fn rows(&self) -> usize {
        self.n
    }

    fn active(&self) -> &[bool] {
        &self.active
    }

    fn step(&mut self) -> Result<OrderStep> {
        let state = self
            .state
            .ok_or_else(|| Error::Runtime("session has no device state".into()))?;
        // download half: the [db] score row (O(d) bytes)
        let out = self
            .executor
            .run_fetch(self.scores_path.clone(), vec![ArgValue::Device(state)])?;
        let padded = out.f32s()?;
        if padded.len() < self.d {
            return Err(Error::Runtime(format!(
                "session_scores returned {} entries for d={}",
                padded.len(),
                self.d
            )));
        }
        let scores: Vec<f64> = (0..self.d)
            .map(|i| if self.active[i] { padded[i] as f64 } else { INACTIVE_SCORE })
            .collect();
        // host argmax: NaN-skip + lowest-index tie-break, and the
        // degenerate-panel Err the CPU engines raise (an all-NaN/−∞ row
        // never silently elects a variable)
        let chosen = argmax_active(&scores, &self.active)?;
        // upload half: the [db] one-hot choice (O(d) bytes); the state
        // swap happens entirely on the device
        let mut onehot = vec![0.0f32; self.db];
        onehot[chosen] = 1.0;
        let args = vec![ArgValue::Device(state), ArgValue::Host(HostArray::vector(onehot))];
        let next = self.executor.run_resident(self.update_path.clone(), args)?;
        self.executor.free_buffer(state);
        self.state = Some(next);
        self.active[chosen] = false;
        Ok(OrderStep { chosen, scores })
    }

    fn reset(&mut self, data: &Mat) -> Result<()> {
        if (data.rows(), data.cols()) != (self.n, self.d) {
            return Err(Error::Shape(format!(
                "session reset: panel is {}x{}, workspace is {}x{}",
                data.rows(),
                data.cols(),
                self.n,
                self.d
            )));
        }
        self.upload_panel(data)?;
        self.active.fill(true);
        Ok(())
    }
}

impl Drop for XlaSession {
    fn drop(&mut self) {
        if let Some(id) = self.state.take() {
            self.executor.free_buffer(id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn reg() -> ArtifactRegistry {
        let text = "\
session_init 1024 16 session_init_n1024_d16.hlo.txt
session_scores 1024 16 session_scores_n1024_d16.hlo.txt
session_update 1024 16 session_update_n1024_d16.hlo.txt
session_init 4096 32 session_init_n4096_d32.hlo.txt
session_scores 4096 32 session_scores_n4096_d32.hlo.txt
";
        ArtifactRegistry::parse(text, Path::new("/a")).unwrap()
    }

    #[test]
    fn bucket_triple_resolves_at_one_shape() {
        let (init, scores, update) = resolve_session_buckets(&reg(), 800, 10).unwrap();
        assert_eq!((init.n, init.d), (1024, 16));
        assert_eq!((scores.n, scores.d), (1024, 16));
        assert_eq!((update.n, update.d), (1024, 16));
    }

    #[test]
    fn incomplete_triple_is_rejected() {
        // the 4096x32 bucket has no session_update artifact: the triple
        // must fail rather than mix shapes
        assert!(resolve_session_buckets(&reg(), 2000, 20).is_err());
    }

    #[test]
    fn missing_kinds_error_with_inventory() {
        let empty = ArtifactRegistry::parse("", Path::new("/a")).unwrap();
        let e = resolve_session_buckets(&empty, 100, 8).unwrap_err();
        assert!(matches!(e, Error::NoArtifact { .. }), "{e}");
    }
}
