//! `XlaSession` — the device-resident ordering session behind
//! `XlaEngine`: the accelerated analogue of [`IncrementalSession`].
//!
//! The stateless XLA path re-uploads the zero-padded panel and
//! re-derives its statistics on every `order_step` call — O(steps) panel
//! transfers per fit. This session instead keeps the whole workspace
//! *on the device* as one packed PJRT buffer
//! (`python/compile/kernels/session.py` #state-layout) and drives it
//! through three single-output artifacts:
//!
//! 1. `session_init` — the **one panel upload of the fit**: masked
//!    standardize + correlation matmul, packed into the resident state.
//! 2. `session_scores` — per step, the [d] score row is the **only
//!    download**; the NaN-safe argmax then runs on the host
//!    ([`argmax_active`]), which keeps tie-breaking and degenerate-panel
//!    rejection bit-identical to the CPU engines.
//! 3. `session_update` — per step, the [d] one-hot choice is the **only
//!    upload**; on the device the standardized cache is residualized in
//!    place via the shared ρ²-clamped closed form and the correlation
//!    matrix updated analytically in O(d²), exactly the
//!    `IncrementalSession` math in f32.
//!
//! Buffer lifetime: the state handle is owned by the executor's device
//! thread; each `session_update` swaps the handle (old state freed, new
//! state kept resident) and `Drop`/`reset` release it, so a bootstrap
//! worker can park and reuse the session like any CPU workspace — a
//! `reset` costs one fresh `session_init` upload for the new resample
//! and nothing else.
//!
//! [`IncrementalSession`]: super::session::IncrementalSession
//! [`argmax_active`]: super::engine::argmax_active

use super::engine::{argmax_active, OrderStep, INACTIVE_SCORE};
use super::session::OrderingSession;
use crate::linalg::Mat;
use crate::runtime::{
    ArgValue, ArtifactKind, ArtifactRegistry, Bucket, BufferId, DeviceExecutor, HostArray,
};
use crate::util::{Error, Result};
use std::path::PathBuf;
use std::sync::Arc;

/// Resolve the session artifact triple for a panel shape: `best` buckets
/// the init request, then the scores/update kinds must exist at exactly
/// that shape (the packed state threads between them, so re-bucketing
/// any one of them would desynchronize the layout).
pub(crate) fn resolve_session_buckets(
    registry: &ArtifactRegistry,
    n: usize,
    d: usize,
) -> Result<(Bucket, Bucket, Bucket)> {
    let init = registry.best(ArtifactKind::SessionInit, n, d)?.clone();
    let scores = registry.exact(ArtifactKind::SessionScores, init.n, init.d)?.clone();
    let update = registry.exact(ArtifactKind::SessionUpdate, init.n, init.d)?.clone();
    Ok((init, scores, update))
}

/// A device-resident ordering session (see module docs).
pub struct XlaSession {
    executor: Arc<DeviceExecutor>,
    init_path: PathBuf,
    scores_path: PathBuf,
    update_path: PathBuf,
    /// Bucket (padded) shape.
    nb: usize,
    db: usize,
    /// True panel extents.
    n: usize,
    d: usize,
    active: Vec<bool>,
    /// Handle to the packed on-device state (cache + correlations +
    /// masks); swapped on every step.
    state: Option<BufferId>,
}

impl XlaSession {
    /// Open a session over a panel: resolve the artifact triple and
    /// perform the fit's single panel upload (`session_init`).
    pub fn new(
        executor: Arc<DeviceExecutor>,
        registry: &ArtifactRegistry,
        data: &Mat,
    ) -> Result<XlaSession> {
        let (n, d) = (data.rows(), data.cols());
        let (init, scores, update) = resolve_session_buckets(registry, n, d)?;
        let (nb, db) = (init.n, init.d);
        let mut session = XlaSession {
            executor,
            init_path: init.path,
            scores_path: scores.path,
            update_path: update.path,
            nb,
            db,
            n,
            d,
            active: vec![true; d],
            state: None,
        };
        session.upload_panel(data)?;
        Ok(session)
    }

    /// The one host→device panel transfer: pad into the bucket shape and
    /// run `session_init`, keeping the packed state resident. Also the
    /// whole cost of a [`reset`](OrderingSession::reset).
    fn upload_panel(&mut self, data: &Mat) -> Result<()> {
        let mut x_pad = vec![0.0f32; self.nb * self.db];
        for r in 0..self.n {
            let src = data.row(r);
            let dst = &mut x_pad[r * self.db..r * self.db + self.d];
            for (c, out) in dst.iter_mut().enumerate() {
                *out = src[c] as f32;
            }
        }
        let mut row_mask = vec![0.0f32; self.nb];
        for v in row_mask.iter_mut().take(self.n) {
            *v = 1.0;
        }
        let mut col_mask = vec![0.0f32; self.db];
        for v in col_mask.iter_mut().take(self.d) {
            *v = 1.0;
        }
        let args = vec![
            ArgValue::Host(HostArray::new(vec![self.nb as i64, self.db as i64], x_pad)),
            ArgValue::Host(HostArray::vector(row_mask)),
            ArgValue::Host(HostArray::vector(col_mask)),
        ];
        let fresh = self.executor.run_resident(self.init_path.clone(), args)?;
        if let Some(old) = self.state.take() {
            self.executor.free_buffer(old);
        }
        self.state = Some(fresh);
        Ok(())
    }
}

impl OrderingSession for XlaSession {
    fn remaining(&self) -> usize {
        self.active.iter().filter(|&&a| a).count()
    }

    fn rows(&self) -> usize {
        self.n
    }

    fn active(&self) -> &[bool] {
        &self.active
    }

    fn step(&mut self) -> Result<OrderStep> {
        let state = self
            .state
            .ok_or_else(|| Error::Runtime("session has no device state".into()))?;
        // download half: the [db] score row (O(d) bytes)
        let out = self
            .executor
            .run_fetch(self.scores_path.clone(), vec![ArgValue::Device(state)])?;
        let padded = out.f32s()?;
        if padded.len() < self.d {
            return Err(Error::Runtime(format!(
                "session_scores returned {} entries for d={}",
                padded.len(),
                self.d
            )));
        }
        let scores: Vec<f64> = (0..self.d)
            .map(|i| if self.active[i] { padded[i] as f64 } else { INACTIVE_SCORE })
            .collect();
        // host argmax: NaN-skip + lowest-index tie-break, and the
        // degenerate-panel Err the CPU engines raise (an all-NaN/−∞ row
        // never silently elects a variable)
        let chosen = argmax_active(&scores, &self.active)?;
        // upload half: the [db] one-hot choice (O(d) bytes); the state
        // swap happens entirely on the device
        let mut onehot = vec![0.0f32; self.db];
        onehot[chosen] = 1.0;
        let args = vec![ArgValue::Device(state), ArgValue::Host(HostArray::vector(onehot))];
        let next = self.executor.run_resident(self.update_path.clone(), args)?;
        self.executor.free_buffer(state);
        self.state = Some(next);
        self.active[chosen] = false;
        Ok(OrderStep { chosen, scores })
    }

    fn reset(&mut self, data: &Mat) -> Result<()> {
        if (data.rows(), data.cols()) != (self.n, self.d) {
            return Err(Error::Shape(format!(
                "session reset: panel is {}x{}, workspace is {}x{}",
                data.rows(),
                data.cols(),
                self.n,
                self.d
            )));
        }
        self.upload_panel(data)?;
        self.active.fill(true);
        Ok(())
    }
}

impl Drop for XlaSession {
    fn drop(&mut self) {
        if let Some(id) = self.state.take() {
            self.executor.free_buffer(id);
        }
    }
}

/// Resolve the batched artifact triple for `b` panels of `(n, d)`:
/// `best_batch` buckets the init request, then the scores/update kinds
/// must exist at exactly that `(n, d, b)` cell (the packed
/// `[B, N+D+2, D]` state threads between them).
pub(crate) fn resolve_batch_buckets(
    registry: &ArtifactRegistry,
    n: usize,
    d: usize,
    b: usize,
) -> Result<(Bucket, Bucket, Bucket)> {
    let init = registry.best_batch(ArtifactKind::SessionInitBatch, n, d, b)?.clone();
    let scores = registry
        .exact_batch(ArtifactKind::SessionScoresBatch, init.n, init.d, init.b)?
        .clone();
    let update = registry
        .exact_batch(ArtifactKind::SessionUpdateBatch, init.n, init.d, init.b)?
        .clone();
    Ok((init, scores, update))
}

/// The device-resident **multi-panel** ordering session — the XLA
/// analogue of [`BatchedSession`](super::batch::BatchedSession): B
/// same-shape panels uploaded in **one** `session_init_batch` call and
/// stepped in lock step, one `[B, D]` score fetch down and one
/// `[B, D]` one-hot block up per step for the whole group.
///
/// Per-panel semantics are untouched: each lane's argmax runs on the
/// host with the CPU engines' NaN-skip / lowest-index tie-break, a lane
/// whose scores degenerate dies alone (its one-hot row stays all-zero —
/// a device-side no-op — while peers keep stepping), and every batch
/// slice of the vmapped artifacts is bitwise the solo artifact's
/// output. Fusion groups shorter than the bucket's batch capacity pad
/// the trailing slots with copies of panel 0; padded lanes are stepped
/// but never read back.
pub struct XlaBatchSession {
    executor: Arc<DeviceExecutor>,
    scores_path: PathBuf,
    update_path: PathBuf,
    /// Bucket (padded) capacities.
    nb: usize,
    db: usize,
    bb: usize,
    /// True panel extents and batch size.
    n: usize,
    d: usize,
    b: usize,
    /// Per-lane active masks, orders, and terminal errors.
    active: Vec<Vec<bool>>,
    orders: Vec<Vec<usize>>,
    errors: Vec<Option<Error>>,
    steps_done: usize,
    state: Option<BufferId>,
}

impl XlaBatchSession {
    /// Open a batched session: resolve the `(n, d, b)` artifact triple
    /// and perform the group's **single** panel upload.
    pub fn new(
        executor: Arc<DeviceExecutor>,
        registry: &ArtifactRegistry,
        panels: &[Mat],
    ) -> Result<XlaBatchSession> {
        let b = panels.len();
        if b == 0 {
            return Err(Error::InvalidArgument("batched session needs ≥ 1 panel".into()));
        }
        let (n, d) = (panels[0].rows(), panels[0].cols());
        for (p, panel) in panels.iter().enumerate().skip(1) {
            if (panel.rows(), panel.cols()) != (n, d) {
                return Err(Error::Shape(format!(
                    "batched session needs same-shape panels: panel 0 is {n}x{d}, \
                     panel {p} is {}x{}",
                    panel.rows(),
                    panel.cols()
                )));
            }
        }
        let (init, scores, update) = resolve_batch_buckets(registry, n, d, b)?;
        let (nb, db, bb) = (init.n, init.d, init.b);
        let mut session = XlaBatchSession {
            executor,
            scores_path: scores.path,
            update_path: update.path,
            nb,
            db,
            bb,
            n,
            d,
            b,
            active: vec![vec![true; d]; b],
            orders: vec![Vec::with_capacity(d); b],
            errors: (0..b).map(|_| None).collect(),
            steps_done: 0,
            state: None,
        };
        session.upload_panels(&init.path, panels)?;
        Ok(session)
    }

    /// The one host→device transfer of the whole group: every panel
    /// padded into its `[nb, db]` slot of a flattened `[bb, nb, db]`
    /// block (trailing slots copy panel 0), one `session_init_batch`
    /// call, packed state kept resident.
    fn upload_panels(&mut self, init_path: &std::path::Path, panels: &[Mat]) -> Result<()> {
        let slot = self.nb * self.db;
        let mut x_pad = vec![0.0f32; self.bb * slot];
        for p in 0..self.bb {
            let panel = &panels[if p < self.b { p } else { 0 }];
            for r in 0..self.n {
                let src = panel.row(r);
                let base = p * slot + r * self.db;
                for (c, out) in x_pad[base..base + self.d].iter_mut().enumerate() {
                    *out = src[c] as f32;
                }
            }
        }
        let mut row_mask = vec![0.0f32; self.bb * self.nb];
        let mut col_mask = vec![0.0f32; self.bb * self.db];
        for p in 0..self.bb {
            for v in row_mask[p * self.nb..p * self.nb + self.n].iter_mut() {
                *v = 1.0;
            }
            for v in col_mask[p * self.db..p * self.db + self.d].iter_mut() {
                *v = 1.0;
            }
        }
        let args = vec![
            ArgValue::Host(HostArray::new(
                vec![self.bb as i64, self.nb as i64, self.db as i64],
                x_pad,
            )),
            ArgValue::Host(HostArray::new(vec![self.bb as i64, self.nb as i64], row_mask)),
            ArgValue::Host(HostArray::new(vec![self.bb as i64, self.db as i64], col_mask)),
        ];
        let fresh = self.executor.run_resident(init_path.to_path_buf(), args)?;
        if let Some(old) = self.state.take() {
            self.executor.free_buffer(old);
        }
        self.state = Some(fresh);
        Ok(())
    }

    /// True batch size (lanes, not the padded bucket capacity).
    pub fn batch(&self) -> usize {
        self.b
    }

    /// Lock steps completed; a full drive takes `d − 1`.
    pub fn steps_done(&self) -> usize {
        self.steps_done
    }

    /// Whether lane `p` is still stepping.
    pub fn live(&self, p: usize) -> bool {
        self.errors[p].is_none()
    }

    /// Lane `p`'s causal order so far (complete after the final step).
    pub fn lane_order(&self, p: usize) -> &[usize] {
        &self.orders[p]
    }

    /// Lane `p`'s terminal error, if it died.
    pub fn lane_error(&self, p: usize) -> Option<&Error> {
        self.errors[p].as_ref()
    }

    /// All `d − 1` steps done (or every lane dead).
    pub fn finished(&self) -> bool {
        self.steps_done >= self.d.saturating_sub(1) || self.errors.iter().all(|e| e.is_some())
    }

    /// One lock step for the whole group: one `[bb, db]` score fetch,
    /// per-lane host argmax, one `[bb, db]` one-hot upload. Lanes whose
    /// argmax fails die alone (all-zero one-hot row = device no-op).
    /// The final step appends each surviving lane's last variable.
    pub fn step_live(&mut self) -> Result<()> {
        let state = self
            .state
            .ok_or_else(|| Error::Runtime("session has no device state".into()))?;
        let out = self
            .executor
            .run_fetch(self.scores_path.clone(), vec![ArgValue::Device(state)])?;
        let padded = out.f32s()?;
        if padded.len() < self.bb * self.db {
            return Err(Error::Runtime(format!(
                "session_scores_batch returned {} entries for b={} d={}",
                padded.len(),
                self.bb,
                self.db
            )));
        }
        let mut onehot = vec![0.0f32; self.bb * self.db];
        for p in 0..self.b {
            if self.errors[p].is_some() {
                continue;
            }
            let row = &padded[p * self.db..p * self.db + self.d];
            let scores: Vec<f64> = (0..self.d)
                .map(|i| if self.active[p][i] { row[i] as f64 } else { INACTIVE_SCORE })
                .collect();
            match argmax_active(&scores, &self.active[p]) {
                Ok(chosen) => {
                    onehot[p * self.db + chosen] = 1.0;
                    self.active[p][chosen] = false;
                    self.orders[p].push(chosen);
                }
                Err(e) => self.errors[p] = Some(e),
            }
        }
        let args = vec![
            ArgValue::Device(state),
            ArgValue::Host(HostArray::new(vec![self.bb as i64, self.db as i64], onehot)),
        ];
        let next = self.executor.run_resident(self.update_path.clone(), args)?;
        self.executor.free_buffer(state);
        self.state = Some(next);
        self.steps_done += 1;
        if self.steps_done >= self.d.saturating_sub(1) {
            for p in 0..self.b {
                if self.errors[p].is_none() {
                    let last = self.active[p]
                        .iter()
                        .position(|&a| a)
                        .expect("exactly one variable remains");
                    self.orders[p].push(last);
                }
            }
        }
        Ok(())
    }
}

impl Drop for XlaBatchSession {
    fn drop(&mut self) {
        if let Some(id) = self.state.take() {
            self.executor.free_buffer(id);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::path::Path;

    fn reg() -> ArtifactRegistry {
        let text = "\
session_init 1024 16 session_init_n1024_d16.hlo.txt
session_scores 1024 16 session_scores_n1024_d16.hlo.txt
session_update 1024 16 session_update_n1024_d16.hlo.txt
session_init 4096 32 session_init_n4096_d32.hlo.txt
session_scores 4096 32 session_scores_n4096_d32.hlo.txt
";
        ArtifactRegistry::parse(text, Path::new("/a")).unwrap()
    }

    #[test]
    fn bucket_triple_resolves_at_one_shape() {
        let (init, scores, update) = resolve_session_buckets(&reg(), 800, 10).unwrap();
        assert_eq!((init.n, init.d), (1024, 16));
        assert_eq!((scores.n, scores.d), (1024, 16));
        assert_eq!((update.n, update.d), (1024, 16));
    }

    #[test]
    fn incomplete_triple_is_rejected() {
        // the 4096x32 bucket has no session_update artifact: the triple
        // must fail rather than mix shapes
        assert!(resolve_session_buckets(&reg(), 2000, 20).is_err());
    }

    #[test]
    fn missing_kinds_error_with_inventory() {
        let empty = ArtifactRegistry::parse("", Path::new("/a")).unwrap();
        let e = resolve_session_buckets(&empty, 100, 8).unwrap_err();
        assert!(matches!(e, Error::NoArtifact { .. }), "{e}");
    }

    fn batch_reg() -> ArtifactRegistry {
        let text = "\
session_init_batch 256 8 4 session_init_batch_n256_d8_b4.hlo.txt
session_scores_batch 256 8 4 session_scores_batch_n256_d8_b4.hlo.txt
session_update_batch 256 8 4 session_update_batch_n256_d8_b4.hlo.txt
session_init_batch 256 8 8 session_init_batch_n256_d8_b8.hlo.txt
session_scores_batch 256 8 8 session_scores_batch_n256_d8_b8.hlo.txt
";
        ArtifactRegistry::parse(text, Path::new("/a")).unwrap()
    }

    #[test]
    fn batch_triple_resolves_at_one_cell() {
        // a 3-panel group rounds up to the b=4 cell, all three kinds
        let (init, scores, update) = resolve_batch_buckets(&batch_reg(), 200, 8, 3).unwrap();
        assert_eq!((init.n, init.d, init.b), (256, 8, 4));
        assert_eq!((scores.n, scores.d, scores.b), (256, 8, 4));
        assert_eq!((update.n, update.d, update.b), (256, 8, 4));
    }

    #[test]
    fn incomplete_batch_triple_is_rejected() {
        // the b=8 cell lacks session_update_batch: the triple must fail
        // rather than mix cells
        assert!(resolve_batch_buckets(&batch_reg(), 200, 8, 6).is_err());
    }
}
