//! Streaming LiNGAM — online causal discovery over a sliding window.
//!
//! The stocks app (and every batch front in `serve`) refits each panel
//! from scratch, but the workload that motivates Var-LiNGAM — live
//! market or tick data — watches a *moving window*: per frame one sample
//! enters, one leaves, and n−1 of the n rows are the ones the previous
//! fit already standardized and correlated. This module amortizes that
//! work across time, the same way the PR 2 sessions amortize it across
//! ordering steps:
//!
//! - [`StreamingWindow`] maintains the window's running per-column mean
//!   and the d×d centered co-moment matrix under **rank-1 update**
//!   (sample enters) and **rank-1 downdate** (sample leaves), Welford
//!   style, in O(d²) per frame instead of the O(n·d²) full pass.
//! - The window **materializes** an ordering workspace — standardized
//!   column cache + correlation matrix — straight from those moments and
//!   seeds an [`IncrementalSession`] through
//!   [`IncrementalSession::from_statistics`], skipping `rebuild`'s
//!   standardize-and-correlate pass.
//! - [`StreamingLingam`] and [`StreamingVarLingam`] drive the per-frame
//!   policy: **full refits** re-run the complete ordering sweep (first
//!   fit and every resync); **incremental refits** hold the causal order
//!   from the last full refit and re-estimate every coefficient directly
//!   from the maintained moments ([`ols_from_cov`]) — no per-sample work
//!   at all, which is where the measured ≥ 5× per-frame win of
//!   `benches/streaming_window.rs` comes from.
//!
//! # Exactness and drift
//!
//! The **update** is Welford's: with `old = x − μ_n` and
//! `new = x − μ_{n+1}`, the co-moment gains exactly `old ⊗ new`
//! (`new = old·n/(n+1)`, so the increment is symmetric up to rounding;
//! we accumulate the upper triangle and mirror it, keeping the matrix
//! *exactly* symmetric). The **downdate** is the inverse step:
//! `μ_{n−1} = μ_n − old/(n−1)` and the co-moment loses `old ⊗ new` with
//! `new = x − μ_{n−1}`. Updates are backward-stable; downdates are not —
//! cancellation can eat the co-moment's low bits, and the error is
//! *cumulative* across frames. The window therefore carries a running
//! drift estimate (`Σ ε·max|old|·max|new|` over every rank-1 op, a
//! cheap proxy for the accumulated absolute rounding error) and
//! triggers a **full resync** — recompute the moments from the ring
//! buffer — every `resync_every` frames or whenever
//! `drift / min_j C_jj` exceeds `drift_tol`. Immediately after a resync
//! the materialized workspace takes the *raw-column* path
//! (`stats::standardize` + `dot/n`), which is bit-for-bit what
//! `IncrementalSession`'s `rebuild` computes on the same panel — pinned
//! by `tests/streaming_agreement.rs`. Between resyncs the workspace is
//! derived from the maintained moments and agrees within the drift
//! tolerance.
//!
//! # Why incremental frames hold the order
//!
//! The ordering pair sweep costs ~d²/2 transcendental kernel passes over
//! n samples per step — it dwarfs the O(n·d²) statistics rebuild the
//! seeded constructor saves, and it is identical work whether the
//! statistics were maintained or recomputed. Re-running it every frame
//! would cap the streaming speedup near 1×. But the order is a
//! *discrete* object: one new sample in a window of hundreds almost
//! never flips it, and when the data does shift, the resync cadence
//! bounds how stale a held order can get (every resync forces a full
//! re-ordering). So incremental frames re-estimate only the
//! *coefficients*, which is pure cheap linear algebra on the maintained
//! moments: `β = Σ_PP⁻¹ Σ_Pi` per ordered variable — algebraically the
//! same centered OLS as [`super::prune::estimate_adjacency`]'s
//! `OlsThreshold`, just computed from Σ instead of the data.
//!
//! [`StreamingVarLingam`] extends this to the lag-k model by embedding
//! `z(t) = [x(t), x(t−1), …, x(t−k)]` and maintaining the *joint*
//! moments of z. Per incremental frame: `M̂ = Σ_pp⁻¹ Σ_pf` (the
//! reduced-form VAR, same stacked-Mᵀ layout as [`super::var::var_fit`]),
//! the innovation covariance by the exact identity
//! `Σ_rr = Σ_ff − Σ_fp M̂`, then `B̂₀ = ols_from_cov(Σ_rr)` under the
//! held innovation order and `B̂_τ = (I − B̂₀) M̂_τ` — the paper's lag
//! transformation, per frame, without touching a single sample.

use std::collections::VecDeque;

use super::direct::DirectLingam;
use super::engine::dot;
use super::prune::PruneMethod;
use super::session::{FnObserver, IncrementalSession, NullObserver, StepObserver};
use super::sweep::{SweepCounters, SweepStrategy};
use super::var::var_fit;
use crate::linalg::{lu_solve, Mat};
use crate::stats;
use crate::util::{Error, Result};

/// Resync policy of a [`StreamingWindow`].
#[derive(Clone, Copy, Debug)]
pub struct StreamingConfig {
    /// Force a full moment recomputation every this many frames
    /// (`0` disables the periodic trigger; the drift trigger remains).
    pub resync_every: usize,
    /// Resync when the accumulated rounding-drift estimate exceeds this
    /// fraction of the smallest co-moment diagonal.
    pub drift_tol: f64,
}

impl Default for StreamingConfig {
    fn default() -> Self {
        StreamingConfig { resync_every: 64, drift_tol: 1e-8 }
    }
}

/// Which refit produced a frame's outcome.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum RefitKind {
    /// Coefficients re-estimated from the maintained moments under the
    /// held causal order — the O(d³) fast path.
    Incremental,
    /// Complete ordering sweep re-run on the current window.
    Full,
}

impl RefitKind {
    /// Wire name used by the serve `watch` frames and the CLI.
    pub fn as_str(self) -> &'static str {
        match self {
            RefitKind::Incremental => "incremental",
            RefitKind::Full => "full",
        }
    }
}

/// A sliding window over d-variate samples with rank-1 maintained
/// moments. See the module docs for the update/downdate formulas and
/// the drift/resync contract.
pub struct StreamingWindow {
    d: usize,
    capacity: usize,
    /// Ring buffer, `capacity × d` row-major; `head` is the oldest row.
    ring: Vec<f64>,
    head: usize,
    len: usize,
    /// Running per-column mean of the live window.
    mean: Vec<f64>,
    /// Centered co-moment `C[(a,b)] = Σ_r (x_ra − μ_a)(x_rb − μ_b)`
    /// (not divided by n), maintained exactly symmetric.
    comoment: Mat,
    /// Accumulated rounding-drift estimate (absolute, co-moment units).
    drift: f64,
    frames_since_resync: usize,
    /// True iff the moments were last set by [`resync`](Self::resync)
    /// and no rank-1 op has touched them since — gates the bitwise
    /// raw-column materialization path.
    fresh: bool,
    cfg: StreamingConfig,
    frames: u64,
    resyncs: u64,
    /// Reclaimed ordering-workspace buffers (column cache + correlation)
    /// so steady-state frames never reallocate.
    pool: Option<(Vec<Vec<f64>>, Mat)>,
    // rank-1 scratch (kept to avoid per-frame allocation)
    evict: Vec<f64>,
    delta_old: Vec<f64>,
    delta_new: Vec<f64>,
}

impl StreamingWindow {
    /// A window of `capacity` samples over `d` variables. Mirrors the
    /// batch panel validation: `d ≥ 2`, `capacity ≥ 8`.
    pub fn new(d: usize, capacity: usize, cfg: StreamingConfig) -> Result<StreamingWindow> {
        if d < 2 {
            return Err(Error::InvalidArgument(format!("need ≥ 2 variables, got {d}")));
        }
        if capacity < 8 {
            return Err(Error::InvalidArgument(format!(
                "streaming window needs capacity ≥ 8, got {capacity}"
            )));
        }
        Ok(StreamingWindow {
            d,
            capacity,
            ring: vec![0.0; capacity * d],
            head: 0,
            len: 0,
            mean: vec![0.0; d],
            comoment: Mat::zeros(d, d),
            drift: 0.0,
            frames_since_resync: 0,
            fresh: false,
            cfg,
            frames: 0,
            resyncs: 0,
            pool: None,
            evict: Vec::with_capacity(d),
            delta_old: Vec::with_capacity(d),
            delta_new: Vec::with_capacity(d),
        })
    }

    /// Variable count.
    pub fn dim(&self) -> usize {
        self.d
    }

    /// Window capacity (the steady-state sample count).
    pub fn capacity(&self) -> usize {
        self.capacity
    }

    /// Live sample count (`< capacity` only during warm-up).
    pub fn len(&self) -> usize {
        self.len
    }

    /// True once the window has reached capacity.
    pub fn is_full(&self) -> bool {
        self.len == self.capacity
    }

    /// True before any sample has been pushed.
    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Total samples ever pushed.
    pub fn frames(&self) -> u64 {
        self.frames
    }

    /// Full moment recomputations performed so far.
    pub fn resyncs(&self) -> u64 {
        self.resyncs
    }

    /// Running mean of column `j`.
    pub fn mean_of(&self, j: usize) -> f64 {
        self.mean[j]
    }

    /// Population covariance of columns `a`, `b` from the maintained
    /// co-moment.
    pub fn cov(&self, a: usize, b: usize) -> f64 {
        self.comoment[(a, b)] / self.len.max(1) as f64
    }

    /// Relative drift estimate: accumulated rank-1 rounding error over
    /// the smallest co-moment diagonal. `0` right after a resync.
    pub fn drift_bound(&self) -> f64 {
        if self.len < 2 {
            return 0.0;
        }
        let mut min_diag = f64::INFINITY;
        for j in 0..self.d {
            min_diag = min_diag.min(self.comoment[(j, j)].abs());
        }
        self.drift / min_diag.max(1e-300)
    }

    /// True when the resync policy fires: the periodic cadence is due or
    /// the drift bound exceeded tolerance.
    pub fn needs_resync(&self) -> bool {
        (self.cfg.resync_every > 0 && self.frames_since_resync >= self.cfg.resync_every)
            || self.drift_bound() > self.cfg.drift_tol
    }

    /// Push one sample. At capacity the oldest sample is retired first
    /// (rank-1 downdate) and the new one accumulated (rank-1 update) —
    /// O(d²) total. Rejects wrong-width and non-finite rows so the
    /// moments can never be poisoned.
    pub fn push(&mut self, row: &[f64]) -> Result<()> {
        if row.len() != self.d {
            return Err(Error::Shape(format!(
                "streaming frame has {} values, window is {}-variate",
                row.len(),
                self.d
            )));
        }
        if row.iter().any(|v| !v.is_finite()) {
            return Err(Error::InvalidArgument(
                "streaming frame contains a non-finite value".into(),
            ));
        }
        if self.len == self.capacity {
            let mut evict = std::mem::take(&mut self.evict);
            evict.clear();
            let base = self.head * self.d;
            evict.extend_from_slice(&self.ring[base..base + self.d]);
            self.retire(&evict);
            self.evict = evict;
            self.head = (self.head + 1) % self.capacity;
            self.len -= 1;
        }
        let slot = (self.head + self.len) % self.capacity;
        self.ring[slot * self.d..(slot + 1) * self.d].copy_from_slice(row);
        self.accumulate(row);
        self.len += 1;
        self.frames += 1;
        self.frames_since_resync += 1;
        self.fresh = false;
        Ok(())
    }

    /// Welford rank-1 update: `μ ← μ + old/(n+1)`, `C ← C + old ⊗ new`.
    fn accumulate(&mut self, row: &[f64]) {
        let n_new = (self.len + 1) as f64;
        let mut old = std::mem::take(&mut self.delta_old);
        let mut new = std::mem::take(&mut self.delta_new);
        old.clear();
        new.clear();
        let (mut max_old, mut max_new) = (0.0f64, 0.0f64);
        for j in 0..self.d {
            let o = row[j] - self.mean[j];
            self.mean[j] += o / n_new;
            let nv = row[j] - self.mean[j];
            max_old = max_old.max(o.abs());
            max_new = max_new.max(nv.abs());
            old.push(o);
            new.push(nv);
        }
        for a in 0..self.d {
            for b in a..self.d {
                self.comoment[(a, b)] += old[a] * new[b];
                if a != b {
                    self.comoment[(b, a)] = self.comoment[(a, b)];
                }
            }
        }
        self.drift += f64::EPSILON * max_old * max_new;
        self.delta_old = old;
        self.delta_new = new;
    }

    /// Rank-1 downdate (the inverse of [`accumulate`](Self::accumulate)):
    /// `μ ← μ − old/(n−1)`, `C ← C − old ⊗ new`. Only called while the
    /// window is at capacity, so `n − 1 ≥ 7`.
    fn retire(&mut self, row: &[f64]) {
        let n_new = (self.len - 1) as f64;
        let mut old = std::mem::take(&mut self.delta_old);
        let mut new = std::mem::take(&mut self.delta_new);
        old.clear();
        new.clear();
        let (mut max_old, mut max_new) = (0.0f64, 0.0f64);
        for j in 0..self.d {
            let o = row[j] - self.mean[j];
            self.mean[j] -= o / n_new;
            let nv = row[j] - self.mean[j];
            max_old = max_old.max(o.abs());
            max_new = max_new.max(nv.abs());
            old.push(o);
            new.push(nv);
        }
        for a in 0..self.d {
            for b in a..self.d {
                self.comoment[(a, b)] -= old[a] * new[b];
                if a != b {
                    self.comoment[(b, a)] = self.comoment[(a, b)];
                }
            }
        }
        self.drift += f64::EPSILON * max_old * max_new;
        self.delta_old = old;
        self.delta_new = new;
    }

    /// Recompute the moments from the ring buffer (two passes), zeroing
    /// the drift. The next [`materialize`](Self::materialize) takes the
    /// bitwise raw-column path.
    pub fn resync(&mut self) {
        let n = self.len.max(1) as f64;
        for j in 0..self.d {
            let mut s = 0.0;
            for r in 0..self.len {
                s += self.ring[((self.head + r) % self.capacity) * self.d + j];
            }
            self.mean[j] = s / n;
        }
        for a in 0..self.d {
            for b in a..self.d {
                let mut s = 0.0;
                for r in 0..self.len {
                    let base = ((self.head + r) % self.capacity) * self.d;
                    s += (self.ring[base + a] - self.mean[a])
                        * (self.ring[base + b] - self.mean[b]);
                }
                self.comoment[(a, b)] = s;
                self.comoment[(b, a)] = s;
            }
        }
        self.drift = 0.0;
        self.frames_since_resync = 0;
        self.fresh = true;
        self.resyncs += 1;
    }

    /// The live window as a panel `[len, d]`, oldest row first — the
    /// layout every from-scratch agreement fit uses.
    pub fn panel(&self) -> Mat {
        Mat::from_fn(self.len, self.d, |r, c| {
            self.ring[((self.head + r) % self.capacity) * self.d + c]
        })
    }

    /// Materialize the ordering workspace (standardized column cache +
    /// correlation matrix) for [`IncrementalSession::from_statistics`].
    ///
    /// Right after a [`resync`](Self::resync) this takes the raw-column
    /// path — `stats::standardize` per column, `dot/n` per pair — which
    /// is bit-for-bit the workspace `IncrementalSession`'s rebuild
    /// computes on [`panel`](Self::panel). Otherwise the cache is derived
    /// from the maintained moments in one O(n·d) + O(d²) pass: columns
    /// scaled by the running mean/std, correlations read straight off
    /// the co-moment (clamped to [−1, 1]; the std floor matches
    /// `stats::standardize`'s 1e-12).
    pub fn materialize(&mut self) -> (Vec<Vec<f64>>, Mat) {
        let n = self.len;
        let (mut cols, mut corr) = match self.pool.take() {
            Some((c, m)) if c.len() == self.d && m.rows() == self.d && m.cols() == self.d => (c, m),
            _ => (vec![Vec::with_capacity(n); self.d], Mat::zeros(self.d, self.d)),
        };
        if self.fresh {
            for (j, col) in cols.iter_mut().enumerate() {
                col.clear();
                col.extend(
                    (0..n).map(|r| self.ring[((self.head + r) % self.capacity) * self.d + j]),
                );
                stats::standardize(col);
            }
            for a in 0..self.d {
                for b in (a + 1)..self.d {
                    let v = dot(&cols[a], &cols[b]) / n as f64;
                    corr[(a, b)] = v;
                    corr[(b, a)] = v;
                }
            }
        } else {
            let inv_n = 1.0 / n.max(1) as f64;
            let stds: Vec<f64> = (0..self.d)
                .map(|j| (self.comoment[(j, j)] * inv_n).max(0.0).sqrt().max(1e-12))
                .collect();
            for (j, col) in cols.iter_mut().enumerate() {
                col.clear();
                let (mu, inv_s) = (self.mean[j], 1.0 / stds[j]);
                col.extend((0..n).map(|r| {
                    (self.ring[((self.head + r) % self.capacity) * self.d + j] - mu) * inv_s
                }));
            }
            for a in 0..self.d {
                for b in (a + 1)..self.d {
                    let v = (self.comoment[(a, b)] * inv_n / (stds[a] * stds[b])).clamp(-1.0, 1.0);
                    corr[(a, b)] = v;
                    corr[(b, a)] = v;
                }
            }
        }
        for j in 0..self.d {
            corr[(j, j)] = 1.0;
        }
        (cols, corr)
    }

    /// Open a seeded ordering session on the current window.
    pub fn session(
        &mut self,
        workers: usize,
        strategy: SweepStrategy,
    ) -> Result<IncrementalSession> {
        let (cols, corr) = self.materialize();
        IncrementalSession::from_statistics(cols, corr, workers, strategy)
    }

    /// Return a finished session's buffers to the pool so the next
    /// [`materialize`](Self::materialize) refills instead of allocating.
    pub fn reclaim(&mut self, workspace: (Vec<Vec<f64>>, Mat)) {
        self.pool = Some(workspace);
    }
}

/// One frame's re-estimate from [`StreamingLingam`].
#[derive(Clone, Debug)]
pub struct FrameOutcome {
    /// Causal order in effect (held under incremental refits).
    pub order: Vec<usize>,
    /// Instantaneous adjacency B̂₀ (`b0[(i,j)] = β_ij`, j → i).
    pub b0: Mat,
    /// Which path produced this estimate.
    pub refit: RefitKind,
    /// True when this frame ran a moment resync first.
    pub resynced: bool,
    /// The window's relative drift estimate after the frame.
    pub drift_bound: f64,
    /// Ordering sweep instrumentation (zero for incremental frames —
    /// they run no sweep).
    pub counters: SweepCounters,
}

/// Sliding-window DirectLiNGAM: full ordering on first fill and on
/// every resync, held-order coefficient re-estimation in between. See
/// the module docs for the policy argument.
pub struct StreamingLingam {
    window: StreamingWindow,
    workers: usize,
    strategy: SweepStrategy,
    prune: PruneMethod,
    threshold: f64,
    order: Option<Vec<usize>>,
    refits_incremental: u64,
    refits_full: u64,
}

impl StreamingLingam {
    /// Serial exact-sweep instance with the default |β| > 0.05 edge
    /// threshold.
    pub fn new(d: usize, window: usize, cfg: StreamingConfig) -> Result<StreamingLingam> {
        StreamingLingam::with_options(d, window, cfg, 1, SweepStrategy::Exact, 0.05)
    }

    /// Full control: sweep workers/strategy for the full refits and the
    /// OLS edge threshold shared by both refit paths (the full path uses
    /// [`PruneMethod::OlsThreshold`] so the two estimates agree).
    pub fn with_options(
        d: usize,
        window: usize,
        cfg: StreamingConfig,
        workers: usize,
        strategy: SweepStrategy,
        threshold: f64,
    ) -> Result<StreamingLingam> {
        Ok(StreamingLingam {
            window: StreamingWindow::new(d, window, cfg)?,
            workers: workers.max(1),
            strategy,
            prune: PruneMethod::OlsThreshold(threshold),
            threshold,
            order: None,
            refits_incremental: 0,
            refits_full: 0,
        })
    }

    /// The underlying window (len/frames/resyncs/drift accessors).
    pub fn window(&self) -> &StreamingWindow {
        &self.window
    }

    /// Causal order currently held (None until the first full refit).
    pub fn order(&self) -> Option<&[usize]> {
        self.order.as_deref()
    }

    /// Held-order coefficient re-estimates performed.
    pub fn refits_incremental(&self) -> u64 {
        self.refits_incremental
    }

    /// Complete ordering sweeps performed.
    pub fn refits_full(&self) -> u64 {
        self.refits_full
    }

    /// Push a warm-up sample without fitting (used to pre-fill the
    /// window from a seed panel before the stream starts).
    pub fn warm(&mut self, row: &[f64]) -> Result<()> {
        self.window.push(row)
    }

    /// Ingest one sample. Returns `None` until the window is full, then
    /// one [`FrameOutcome`] per frame.
    pub fn ingest(&mut self, row: &[f64]) -> Result<Option<FrameOutcome>> {
        self.ingest_stepped(row, &mut NullObserver)
    }

    /// [`ingest`](Self::ingest) with a full-refit step observer closure
    /// — the ergonomic form over
    /// [`ingest_stepped`](Self::ingest_stepped).
    pub fn ingest_observed(
        &mut self,
        row: &[f64],
        observer: &mut dyn FnMut(usize, usize) -> Result<()>,
    ) -> Result<Option<FrameOutcome>> {
        self.ingest_stepped(row, &mut FnObserver(observer))
    }

    /// [`ingest`](Self::ingest) with a typed [`StepObserver`] — the
    /// serve worker's cancel/progress/timing hook, called per ordering
    /// step of any full refit exactly as in
    /// [`DirectLingam::fit_session_stepped`]. Incremental frames run no
    /// ordering steps and report nothing.
    pub fn ingest_stepped(
        &mut self,
        row: &[f64],
        observer: &mut dyn StepObserver,
    ) -> Result<Option<FrameOutcome>> {
        self.window.push(row)?;
        if !self.window.is_full() {
            return Ok(None);
        }
        let resynced = if self.window.needs_resync() {
            self.window.resync();
            true
        } else {
            false
        };
        if resynced || self.order.is_none() {
            return self.refit_full_observed(resynced, observer).map(Some);
        }
        match self.refit_incremental() {
            Ok(out) => Ok(Some(out)),
            // Degenerate moments (singular predecessor block): resync and
            // fall back to the full sweep, which re-derives the order.
            Err(_) => {
                self.window.resync();
                self.refit_full_observed(true, observer).map(Some)
            }
        }
    }

    fn refit_full_observed(
        &mut self,
        resynced: bool,
        observer: &mut dyn StepObserver,
    ) -> Result<FrameOutcome> {
        let panel = self.window.panel();
        let mut session = self.window.session(self.workers, self.strategy)?;
        let fit = DirectLingam::with_prune(self.prune)
            .fit_session_stepped(&panel, &mut session, observer);
        let counters = session.counters();
        self.window.reclaim(session.into_workspace());
        let fit = fit?;
        self.order = Some(fit.order.clone());
        self.refits_full += 1;
        Ok(FrameOutcome {
            order: fit.order,
            b0: fit.adjacency,
            refit: RefitKind::Full,
            resynced,
            drift_bound: self.window.drift_bound(),
            counters,
        })
    }

    fn refit_incremental(&mut self) -> Result<FrameOutcome> {
        let order = self.order.as_ref().expect("incremental refit without a held order");
        let d = self.window.dim();
        let cov = Mat::from_fn(d, d, |a, b| self.window.cov(a, b));
        let b0 = ols_from_cov(&cov, order, self.threshold)?;
        self.refits_incremental += 1;
        Ok(FrameOutcome {
            order: order.clone(),
            b0,
            refit: RefitKind::Incremental,
            resynced: false,
            drift_bound: self.window.drift_bound(),
            counters: SweepCounters::default(),
        })
    }
}

/// One frame's re-estimate from [`StreamingVarLingam`].
#[derive(Clone, Debug)]
pub struct VarFrameOutcome {
    /// Innovation causal order in effect.
    pub order: Vec<usize>,
    /// Instantaneous adjacency B̂₀.
    pub b0: Mat,
    /// Reduced-form VAR matrices M̂_τ, τ = 1..=k.
    pub m_tau: Vec<Mat>,
    /// Causal lag matrices B̂_τ = (I − B̂₀) M̂_τ.
    pub b_tau: Vec<Mat>,
    /// Which path produced this estimate.
    pub refit: RefitKind,
    /// True when this frame ran a moment resync first.
    pub resynced: bool,
    /// The embedded window's relative drift estimate after the frame.
    pub drift_bound: f64,
}

/// Sliding-window VarLiNGAM over the lag-k embedded design
/// `z(t) = [x(t), x(t−1), …, x(t−k)]`: the joint (k+1)d-variate moments
/// are rank-1 maintained, full refits run `var_fit` + DirectLiNGAM on
/// the raw tail, incremental frames solve the reduced form and the
/// innovation regression straight from the moments (see module docs).
pub struct StreamingVarLingam {
    d: usize,
    lags: usize,
    /// Window over the embedded z-rows (dimension `(lags+1)·d`).
    window: StreamingWindow,
    /// Raw sample tail, newest last; holds `capacity + lags` rows so the
    /// full refit can rebuild the exact series the window embeds.
    series: VecDeque<Vec<f64>>,
    workers: usize,
    strategy: SweepStrategy,
    prune: PruneMethod,
    threshold: f64,
    order: Option<Vec<usize>>,
    refits_incremental: u64,
    refits_full: u64,
}

impl StreamingVarLingam {
    /// Serial exact-sweep instance (threshold 0.05), lag-k embedded
    /// window of `window` frames. Requires `window + lags ≥ lags·d + 2`
    /// (the [`super::var::var_fit`] solvability bound) and `window ≥ 8`.
    pub fn new(
        d: usize,
        lags: usize,
        window: usize,
        cfg: StreamingConfig,
    ) -> Result<StreamingVarLingam> {
        StreamingVarLingam::with_options(d, lags, window, cfg, 1, SweepStrategy::Exact, 0.05)
    }

    /// Full control, mirroring [`StreamingLingam::with_options`].
    pub fn with_options(
        d: usize,
        lags: usize,
        window: usize,
        cfg: StreamingConfig,
        workers: usize,
        strategy: SweepStrategy,
        threshold: f64,
    ) -> Result<StreamingVarLingam> {
        if d < 2 {
            return Err(Error::InvalidArgument(format!("need ≥ 2 variables, got {d}")));
        }
        if lags < 1 {
            return Err(Error::InvalidArgument("VAR needs lags ≥ 1".into()));
        }
        if window < 8 || window + lags < lags * d + 2 {
            return Err(Error::InvalidArgument(format!(
                "streaming VAR window too short: {window} frames for d={d}, k={lags}"
            )));
        }
        Ok(StreamingVarLingam {
            d,
            lags,
            window: StreamingWindow::new((lags + 1) * d, window, cfg)?,
            series: VecDeque::with_capacity(window + lags + 1),
            workers: workers.max(1),
            strategy,
            prune: PruneMethod::OlsThreshold(threshold),
            threshold,
            order: None,
            refits_incremental: 0,
            refits_full: 0,
        })
    }

    /// The embedded window (len/frames/resyncs/drift accessors).
    pub fn window(&self) -> &StreamingWindow {
        &self.window
    }

    /// Innovation causal order currently held.
    pub fn order(&self) -> Option<&[usize]> {
        self.order.as_deref()
    }

    /// Held-order re-estimates performed.
    pub fn refits_incremental(&self) -> u64 {
        self.refits_incremental
    }

    /// Complete refits (var_fit + ordering sweep) performed.
    pub fn refits_full(&self) -> u64 {
        self.refits_full
    }

    /// Push a warm-up sample without fitting.
    pub fn warm(&mut self, row: &[f64]) -> Result<()> {
        self.feed(row).map(|_| ())
    }

    /// Ingest one raw sample x(t). Returns `None` until the embedded
    /// window is full (the first `lags` samples only build history).
    pub fn ingest(&mut self, row: &[f64]) -> Result<Option<VarFrameOutcome>> {
        self.ingest_stepped(row, &mut NullObserver)
    }

    /// [`ingest`](Self::ingest) with a full-refit step observer closure.
    pub fn ingest_observed(
        &mut self,
        row: &[f64],
        observer: &mut dyn FnMut(usize, usize) -> Result<()>,
    ) -> Result<Option<VarFrameOutcome>> {
        self.ingest_stepped(row, &mut FnObserver(observer))
    }

    /// [`ingest`](Self::ingest) with a typed [`StepObserver`] — see
    /// [`StreamingLingam::ingest_stepped`].
    pub fn ingest_stepped(
        &mut self,
        row: &[f64],
        observer: &mut dyn StepObserver,
    ) -> Result<Option<VarFrameOutcome>> {
        if !self.feed(row)? || !self.window.is_full() {
            return Ok(None);
        }
        let resynced = if self.window.needs_resync() {
            self.window.resync();
            true
        } else {
            false
        };
        if resynced || self.order.is_none() {
            return self.refit_full_observed(resynced, observer).map(Some);
        }
        match self.refit_incremental() {
            Ok(out) => Ok(Some(out)),
            Err(_) => {
                self.window.resync();
                self.refit_full_observed(true, observer).map(Some)
            }
        }
    }

    /// Append x(t) to the raw tail and, once `lags` of history exist,
    /// push the embedded row `z(t)` into the moment window. Returns
    /// whether an embedded row was produced.
    fn feed(&mut self, row: &[f64]) -> Result<bool> {
        if row.len() != self.d {
            return Err(Error::Shape(format!(
                "streaming frame has {} values, series is {}-variate",
                row.len(),
                self.d
            )));
        }
        if row.iter().any(|v| !v.is_finite()) {
            return Err(Error::InvalidArgument(
                "streaming frame contains a non-finite value".into(),
            ));
        }
        self.series.push_back(row.to_vec());
        while self.series.len() > self.window.capacity() + self.lags {
            self.series.pop_front();
        }
        if self.series.len() < self.lags + 1 {
            return Ok(false);
        }
        // z(t) = [x(t), x(t−1), …, x(t−k)] — past blocks in var_fit's
        // design layout (lag 1 first, var-major within a lag)
        let mut z = Vec::with_capacity((self.lags + 1) * self.d);
        let newest = self.series.len() - 1;
        for tau in 0..=self.lags {
            z.extend_from_slice(&self.series[newest - tau]);
        }
        self.window.push(&z)?;
        Ok(true)
    }

    fn refit_full_observed(
        &mut self,
        resynced: bool,
        observer: &mut dyn StepObserver,
    ) -> Result<VarFrameOutcome> {
        // Rebuild the exact series the embedded window covers: its
        // `len` newest z-rows span the last `len + lags` raw samples.
        let t_len = self.window.len() + self.lags;
        let start = self.series.len() - t_len;
        let series = Mat::from_fn(t_len, self.d, |r, c| self.series[start + r][c]);
        let (m_tau, resid) = var_fit(&series, self.lags)?;
        let mut session =
            IncrementalSession::with_strategy(&resid, self.workers, false, self.strategy)?;
        let fit = DirectLingam::with_prune(self.prune)
            .fit_session_stepped(&resid, &mut session, observer)?;
        let b0 = fit.adjacency;
        let eye_minus = Mat::eye(self.d).sub(&b0);
        let b_tau: Vec<Mat> = m_tau.iter().map(|m| eye_minus.matmul(m)).collect();
        self.order = Some(fit.order.clone());
        self.refits_full += 1;
        Ok(VarFrameOutcome {
            order: fit.order,
            b0,
            m_tau,
            b_tau,
            refit: RefitKind::Full,
            resynced,
            drift_bound: self.window.drift_bound(),
        })
    }

    /// Data-free re-estimate from the embedded moments: reduced form
    /// `M̂ = Σ_pp⁻¹ Σ_pf`, innovation covariance `Σ_rr = Σ_ff − Σ_fp M̂`,
    /// then OLS under the held innovation order and the lag transform.
    fn refit_incremental(&mut self) -> Result<VarFrameOutcome> {
        let order = self.order.as_ref().expect("incremental refit without a held order");
        let (d, k) = (self.d, self.lags);
        // embedded layout: future block = 0..d, past blocks = d..(k+1)d
        let spp = Mat::from_fn(k * d, k * d, |a, b| self.window.cov(d + a, d + b));
        let spf = Mat::from_fn(k * d, d, |a, i| self.window.cov(d + a, i));
        let coef = lu_solve(&spp, &spf)?; // [k·d, d] — stacked M_τᵀ
        let m_tau: Vec<Mat> = (0..k)
            .map(|tau| Mat::from_fn(d, d, |i, j| coef[(tau * d + j, i)]))
            .collect();
        let sff = Mat::from_fn(d, d, |a, b| self.window.cov(a, b));
        let srr_raw = sff.sub(&spf.t().matmul(&coef));
        // exact identity up to rounding; symmetrize for the OLS solves
        let srr = Mat::from_fn(d, d, |a, b| 0.5 * (srr_raw[(a, b)] + srr_raw[(b, a)]));
        let b0 = ols_from_cov(&srr, order, self.threshold)?;
        let eye_minus = Mat::eye(d).sub(&b0);
        let b_tau: Vec<Mat> = m_tau.iter().map(|m| eye_minus.matmul(m)).collect();
        self.refits_incremental += 1;
        Ok(VarFrameOutcome {
            order: order.clone(),
            b0,
            m_tau,
            b_tau,
            refit: RefitKind::Incremental,
            resynced: false,
            drift_bound: self.window.drift_bound(),
        })
    }
}

/// Adjacency estimation from a covariance matrix under a fixed causal
/// order: for each variable `i` at position `pos ≥ 1`,
/// `β = Σ_PP⁻¹ Σ_Pi` over the predecessors `P = order[..pos]`, keeping
/// entries with `|β| > threshold` — algebraically the centered OLS of
/// [`super::prune::estimate_adjacency`]'s [`PruneMethod::OlsThreshold`]
/// (the intercept is implicit in the centering), computed from the
/// moments instead of the data. O(d⁴/4) flops worst case, no samples.
pub fn ols_from_cov(cov: &Mat, order: &[usize], threshold: f64) -> Result<Mat> {
    let d = cov.rows();
    if cov.cols() != d {
        return Err(Error::Shape(format!(
            "covariance must be square, got {}x{}",
            cov.rows(),
            cov.cols()
        )));
    }
    if order.len() != d {
        return Err(Error::InvalidArgument(format!(
            "order has {} entries for {d} variables",
            order.len()
        )));
    }
    let mut adj = Mat::zeros(d, d);
    for (pos, &i) in order.iter().enumerate() {
        if pos == 0 {
            continue;
        }
        let preds = &order[..pos];
        let spp = Mat::from_fn(pos, pos, |a, b| cov[(preds[a], preds[b])]);
        let spi = Mat::from_fn(pos, 1, |a, _| cov[(preds[a], i)]);
        let beta = lu_solve(&spp, &spi)?;
        for (a, &p) in preds.iter().enumerate() {
            let b = beta[(a, 0)];
            if b.abs() > threshold {
                adj[(i, p)] = b;
            }
        }
    }
    Ok(adj)
}

// ---------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lingam::prune::estimate_adjacency;
    use crate::sim::sem::{simulate_sem, SemSpec};
    use crate::sim::var::{simulate_var, VarSpec};
    use crate::util::rng::Pcg64;

    fn no_resync() -> StreamingConfig {
        StreamingConfig { resync_every: 0, drift_tol: f64::INFINITY }
    }

    fn sem_rows(d: usize, n: usize, seed: u64) -> Vec<Vec<f64>> {
        let mut rng = Pcg64::seed_from_u64(seed);
        let ds = simulate_sem(&SemSpec::layered(d, 2, 0.7), n, &mut rng);
        (0..n).map(|r| (0..d).map(|c| ds.data[(r, c)]).collect()).collect()
    }

    #[test]
    fn window_moments_match_direct_computation_after_slides() {
        let (d, cap) = (5, 32);
        let rows = sem_rows(d, 200, 41);
        let mut w = StreamingWindow::new(d, cap, no_resync()).unwrap();
        for row in &rows {
            w.push(row).unwrap();
        }
        assert!(w.is_full());
        assert_eq!(w.frames(), 200);
        assert_eq!(w.resyncs(), 0);
        let panel = w.panel();
        // the panel must be the last `cap` rows, oldest first
        for r in 0..cap {
            for c in 0..d {
                assert_eq!(panel[(r, c)], rows[200 - cap + r][c]);
            }
        }
        for j in 0..d {
            let col = panel.col(j);
            assert!(
                (w.mean_of(j) - stats::mean(&col)).abs() < 1e-10,
                "mean[{j}] drifted"
            );
        }
        for a in 0..d {
            for b in 0..d {
                let direct = stats::cov(&panel.col(a), &panel.col(b));
                assert!(
                    (w.cov(a, b) - direct).abs() < 1e-9,
                    "cov[{a},{b}]: incremental {} vs direct {direct}",
                    w.cov(a, b)
                );
            }
        }
        assert!(w.drift_bound() > 0.0 && w.drift_bound() < 1e-8);
    }

    #[test]
    fn materialized_workspace_is_bitwise_rebuild_after_resync() {
        let (d, cap) = (4, 24);
        let rows = sem_rows(d, 120, 42);
        let mut w = StreamingWindow::new(d, cap, no_resync()).unwrap();
        for row in &rows {
            w.push(row).unwrap();
        }
        w.resync();
        let panel = w.panel();
        let (cols, corr) = w.materialize();
        // reference: exactly what IncrementalSession's rebuild computes
        let reference = IncrementalSession::new(&panel, 1, false).unwrap();
        for j in 0..d {
            let mut re = panel.col(j);
            stats::standardize(&mut re);
            assert_eq!(cols[j], re, "column {j} not bitwise");
            assert_eq!(cols[j], reference.cached_column(j), "cache[{j}] != rebuild");
        }
        for a in 0..d {
            for b in 0..d {
                assert_eq!(corr[(a, b)], reference.corr()[(a, b)], "corr[{a},{b}] not bitwise");
            }
        }
    }

    #[test]
    fn incremental_workspace_agrees_with_exact_within_tolerance() {
        let (d, cap) = (5, 40);
        let rows = sem_rows(d, 300, 43);
        let mut w = StreamingWindow::new(d, cap, no_resync()).unwrap();
        for row in &rows {
            w.push(row).unwrap();
        }
        assert!(!w.needs_resync());
        let panel = w.panel();
        let (cols, corr) = w.materialize();
        let reference = IncrementalSession::new(&panel, 1, false).unwrap();
        for a in 0..d {
            let mut re = panel.col(a);
            stats::standardize(&mut re);
            for r in 0..cap {
                assert!((cols[a][r] - re[r]).abs() < 1e-8, "col[{a}][{r}]");
            }
            for b in 0..d {
                assert!(
                    (corr[(a, b)] - reference.corr()[(a, b)]).abs() < 1e-8,
                    "corr[{a},{b}]"
                );
            }
        }
    }

    #[test]
    fn ols_from_cov_matches_estimate_adjacency() {
        let d = 5;
        let mut rng = Pcg64::seed_from_u64(44);
        let ds = simulate_sem(&SemSpec::layered(d, 2, 0.8), 600, &mut rng);
        let order: Vec<usize> = (0..d).collect();
        let cov = Mat::from_fn(d, d, |a, b| stats::cov(&ds.data.col(a), &ds.data.col(b)));
        let from_cov = ols_from_cov(&cov, &order, 0.05).unwrap();
        let from_data =
            estimate_adjacency(&ds.data, &order, PruneMethod::OlsThreshold(0.05)).unwrap();
        for i in 0..d {
            for j in 0..d {
                assert!(
                    (from_cov[(i, j)] - from_data[(i, j)]).abs() < 1e-6,
                    "adj[{i},{j}]: cov {} vs data {}",
                    from_cov[(i, j)],
                    from_data[(i, j)]
                );
            }
        }
    }

    #[test]
    fn streaming_lifecycle_full_then_incremental_then_resync() {
        let (d, cap) = (4, 32);
        let rows = sem_rows(d, cap + 20, 45);
        let cfg = StreamingConfig { resync_every: 8, drift_tol: 1e-8 };
        let mut s = StreamingLingam::new(d, cap, cfg).unwrap();
        let mut outcomes = Vec::new();
        for (i, row) in rows.iter().enumerate() {
            let out = s.ingest(row).unwrap();
            if i + 1 < cap {
                assert!(out.is_none(), "outcome before the window filled");
            } else {
                outcomes.push(out.expect("no outcome on a full window"));
            }
        }
        // warm-up pushes count toward the cadence, so the first fit (at
        // frame `cap` ≥ resync_every) both resyncs and runs fully
        assert_eq!(outcomes[0].refit, RefitKind::Full);
        let incremental =
            outcomes.iter().filter(|o| o.refit == RefitKind::Incremental).count();
        let full = outcomes.iter().filter(|o| o.refit == RefitKind::Full).count();
        assert!(full >= 2, "resync cadence never re-ran the sweep ({full} full)");
        assert!(incremental > full, "incremental path never dominated");
        assert_eq!(full as u64, s.refits_full());
        assert_eq!(incremental as u64, s.refits_incremental());
        assert!(s.window().resyncs() >= 2);
        // every resynced frame is a full refit with zero drift... until
        // the frame's own push lands, so just require it is Full
        for o in &outcomes {
            if o.resynced {
                assert_eq!(o.refit, RefitKind::Full);
            }
            assert_eq!(o.order.len(), d);
            assert_eq!((o.b0.rows(), o.b0.cols()), (d, d));
        }
    }

    #[test]
    fn incremental_b0_agrees_with_from_scratch_fit() {
        let (d, cap) = (4, 200);
        let rows = sem_rows(d, cap + 12, 46);
        let mut s = StreamingLingam::new(d, cap, no_resync()).unwrap();
        for row in rows.iter().take(cap) {
            s.ingest(row).unwrap();
        }
        for row in rows.iter().skip(cap) {
            let out = s.ingest(row).unwrap().unwrap();
            if out.refit != RefitKind::Incremental {
                continue;
            }
            // from-scratch on the identical window
            let panel = s.window().panel();
            let mut session = IncrementalSession::new(&panel, 1, false).unwrap();
            let reference = DirectLingam::with_prune(PruneMethod::OlsThreshold(0.05))
                .fit_session(&panel, &mut session)
                .unwrap();
            if reference.order != out.order {
                continue; // order flip: the held order is allowed to lag
            }
            let err = out.b0.sub(&reference.adjacency).max_abs();
            assert!(err < 1e-6, "incremental B0 off by {err}");
        }
        assert!(s.refits_incremental() >= 10);
    }

    #[test]
    fn drift_tolerance_triggers_resync() {
        let (d, cap) = (4, 16);
        let rows = sem_rows(d, cap + 10, 47);
        let cfg = StreamingConfig { resync_every: 0, drift_tol: 0.0 };
        let mut s = StreamingLingam::new(d, cap, cfg).unwrap();
        for row in &rows {
            s.ingest(row).unwrap();
        }
        // any accumulated drift (> 0 after the first slide) exceeds 0.0
        assert!(s.window().resyncs() >= 5, "drift trigger never fired");
        assert_eq!(s.refits_incremental(), 0);
    }

    #[test]
    fn streaming_var_agrees_with_from_scratch_var_fit() {
        let spec = VarSpec { dim: 4, ..VarSpec::default() };
        let mut rng = Pcg64::seed_from_u64(48);
        let t_total = 400;
        let ds = simulate_var(&spec, t_total, &mut rng);
        let (d, cap, lags) = (4, 240, 1);
        let mut s = StreamingVarLingam::new(d, lags, cap, no_resync()).unwrap();
        let mut last = None;
        for t in 0..t_total {
            let row: Vec<f64> = (0..d).map(|c| ds.data[(t, c)]).collect();
            if let Some(out) = s.ingest(&row).unwrap() {
                last = Some(out);
            }
        }
        let out = last.expect("stream never produced a frame");
        assert_eq!(out.refit, RefitKind::Incremental);
        assert!(s.refits_incremental() > 100);
        assert_eq!(s.refits_full(), 1);
        // from-scratch reference on the identical tail
        let start = t_total - (cap + lags);
        let tail = Mat::from_fn(cap + lags, d, |r, c| ds.data[(start + r, c)]);
        let (m_ref, resid) = var_fit(&tail, lags).unwrap();
        let mut session = IncrementalSession::new(&resid, 1, false).unwrap();
        let fit_ref = DirectLingam::with_prune(PruneMethod::OlsThreshold(0.05))
            .fit_session(&resid, &mut session)
            .unwrap();
        let m_err = out.m_tau[0].sub(&m_ref[0]).max_abs();
        assert!(m_err < 1e-6, "reduced-form M1 off by {m_err}");
        if fit_ref.order == out.order {
            let b_err = out.b0.sub(&fit_ref.adjacency).max_abs();
            assert!(b_err < 1e-5, "incremental B0 off by {b_err}");
        }
        assert_eq!(out.b_tau.len(), lags);
        // and the lag transform is consistent: B1 = (I − B0) M1
        let want_b1 = Mat::eye(d).sub(&out.b0).matmul(&out.m_tau[0]);
        assert!(out.b_tau[0].sub(&want_b1).max_abs() < 1e-12);
    }

    #[test]
    fn streaming_var_warms_up_and_books_counts() {
        let spec = VarSpec { dim: 3, ..VarSpec::default() };
        let mut rng = Pcg64::seed_from_u64(49);
        let ds = simulate_var(&spec, 60, &mut rng);
        let (d, cap, lags) = (3, 16, 2);
        let mut s = StreamingVarLingam::new(d, lags, cap, no_resync()).unwrap();
        let mut first_at = None;
        for t in 0..60 {
            let row: Vec<f64> = (0..d).map(|c| ds.data[(t, c)]).collect();
            if s.ingest(&row).unwrap().is_some() && first_at.is_none() {
                first_at = Some(t);
            }
        }
        // the first outcome needs `lags` history rows plus `cap` embedded
        assert_eq!(first_at, Some(cap + lags - 1));
        assert_eq!(s.refits_full(), 1);
        assert_eq!(s.refits_incremental() as usize, 60 - (cap + lags));
    }

    #[test]
    fn window_rejects_bad_frames_and_shapes() {
        assert!(StreamingWindow::new(1, 32, StreamingConfig::default()).is_err());
        assert!(StreamingWindow::new(4, 4, StreamingConfig::default()).is_err());
        let mut w = StreamingWindow::new(3, 8, StreamingConfig::default()).unwrap();
        assert!(w.push(&[1.0, 2.0]).is_err());
        assert!(w.push(&[1.0, 2.0, f64::NAN]).is_err());
        assert!(w.is_empty());
        assert!(StreamingVarLingam::new(2, 1, 4, StreamingConfig::default()).is_err());
        let mut v = StreamingVarLingam::new(2, 1, 8, StreamingConfig::default()).unwrap();
        assert!(v.ingest(&[1.0]).is_err());
        assert!(v.ingest(&[f64::INFINITY, 0.0]).is_err());
    }
}
