//! DirectLiNGAM (Shimizu et al. 2011).
//!
//! Repeats: find the most-exogenous variable (Algorithm 1, delegated to
//! an [`OrderingEngine`]) → append it to the causal order → remove its
//! effect from the remaining variables by least-squares residualization.
//! After the full order is known, the weighted adjacency is estimated by
//! regressing each variable on its predecessors ([`prune`]).
//!
//! The per-stage timing profile this driver collects is what the
//! Figure-2 reproduction reports (ordering is ~96% of total runtime).

use super::engine::{OrderingEngine, OrderStep};
use super::prune::{estimate_adjacency, PruneMethod};
use crate::linalg::Mat;
use crate::util::timer::StageProfile;
use crate::util::{Error, Result};

/// DirectLiNGAM configuration.
#[derive(Clone, Debug, Default)]
pub struct DirectLingam {
    /// Adjacency pruning method (default: adaptive lasso).
    pub prune: PruneMethod,
}

/// A fitted model.
#[derive(Clone, Debug)]
pub struct LingamFit {
    /// Estimated causal order, causes first.
    pub order: Vec<usize>,
    /// Estimated weighted adjacency (`adj[(i,j)] = β_ij`, j → i).
    pub adjacency: Mat,
    /// k_list of every search step (step s has scores over the variables
    /// still active at step s) — kept for the engine-agreement tests.
    pub step_scores: Vec<Vec<f64>>,
    /// Wall-clock per stage: "ordering" vs "regression".
    pub profile: StageProfile,
}

impl DirectLingam {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_prune(prune: PruneMethod) -> Self {
        DirectLingam { prune }
    }

    /// Fit on a data panel `[n, d]` using the given ordering engine.
    pub fn fit(&self, data: &Mat, engine: &dyn OrderingEngine) -> Result<LingamFit> {
        let (n, d) = (data.rows(), data.cols());
        if d < 2 {
            return Err(Error::InvalidArgument(format!("need ≥ 2 variables, got {d}")));
        }
        if n < 8 {
            return Err(Error::InvalidArgument(format!("need ≥ 8 samples, got {n}")));
        }
        if !data.is_finite() {
            return Err(Error::InvalidArgument("data contains NaN/inf".into()));
        }
        // a (near-)constant column has no causal direction to estimate
        // (its correlation with everything is 0/0); reject it up front
        // instead of letting degenerate scores reach the engines. The
        // threshold is relative to the column's scale: an exact-zero test
        // would miss constants like 0.1 whose float sums leave ~1e-17 of
        // rounding variance, and std below the standardize() floor means
        // the column is constant to working precision anyway
        for c in 0..d {
            let col = data.col(c);
            if crate::stats::std(&col) <= 1e-12 * (1.0 + crate::stats::mean(&col).abs()) {
                return Err(Error::InvalidArgument(format!(
                    "column {c} is constant (zero variance): causal order undefined"
                )));
            }
        }

        let mut profile = StageProfile::new();
        let mut x = data.clone();
        let mut active = vec![true; d];
        let mut order = Vec::with_capacity(d);
        let mut step_scores = Vec::with_capacity(d);

        // causal ordering: d−1 search steps; the last variable is forced
        for _ in 0..(d - 1) {
            let step: OrderStep =
                profile.time("ordering", || engine.order_step(&mut x, &mut active))?;
            order.push(step.chosen);
            step_scores.push(step.scores);
        }
        let last = active
            .iter()
            .position(|&a| a)
            .expect("exactly one variable remains");
        order.push(last);

        // adjacency over the original (un-residualized) data
        let adjacency =
            profile.time("regression", || estimate_adjacency(data, &order, self.prune))?;

        Ok(LingamFit { order, adjacency, step_scores, profile })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph;
    use crate::lingam::{ParallelEngine, SequentialEngine, VectorizedEngine};
    use crate::metrics::graph_metrics;
    use crate::sim::{simulate_sem, SemSpec};
    use crate::util::rng::Pcg64;

    #[test]
    fn recovers_chain() {
        let mut rng = Pcg64::seed_from_u64(1);
        let mut adj = Mat::zeros(4, 4);
        adj[(1, 0)] = 1.0;
        adj[(2, 1)] = 1.3;
        adj[(3, 2)] = -0.9;
        let dag = graph::Dag::new(adj.clone()).unwrap();
        let x = crate::sim::sem::sample_from_dag(&dag, crate::sim::Noise::Uniform01, 10_000, &mut rng);
        let fit = DirectLingam::new().fit(&x, &VectorizedEngine).unwrap();
        assert_eq!(fit.order, vec![0, 1, 2, 3]);
        let m = graph_metrics(&adj, &fit.adjacency, 0.1);
        assert_eq!(m.f1, 1.0, "adjacency: {:?}", fit.adjacency);
    }

    #[test]
    fn paper_sim_design_recovered() {
        // the paper's §3.1 configuration at small scale
        let mut rng = Pcg64::seed_from_u64(2);
        let ds = simulate_sem(&SemSpec::layered(10, 2, 0.5), 10_000, &mut rng);
        let fit = DirectLingam::new().fit(&ds.data, &VectorizedEngine).unwrap();
        assert!(graph::order_consistent(&ds.adjacency, &fit.order), "order {:?}", fit.order);
        // weights are θ ~ N(0,1): edges with |θ| below the metric
        // threshold are unrecoverable in principle, so demand a strong
        // but not perfect F1 here (the Fig-3 bench reports the sweep)
        let m = graph_metrics(&ds.adjacency, &fit.adjacency, 0.1);
        assert!(m.f1 > 0.75, "f1={}", m.f1);
    }

    #[test]
    fn engines_produce_identical_orders() {
        let mut rng = Pcg64::seed_from_u64(3);
        let ds = simulate_sem(&SemSpec::layered(8, 2, 0.5), 3_000, &mut rng);
        let seq = DirectLingam::new().fit(&ds.data, &SequentialEngine).unwrap();
        let vec = DirectLingam::new().fit(&ds.data, &VectorizedEngine).unwrap();
        let par = DirectLingam::new()
            .fit(&ds.data, &ParallelEngine::new(4).force_parallel())
            .unwrap();
        assert_eq!(seq.order, vec.order);
        assert_eq!(vec.order, par.order, "parallel engine diverged from vectorized");
        assert!(crate::metrics::adjacency_max_diff(&seq.adjacency, &vec.adjacency) < 1e-8);
        assert!(crate::metrics::adjacency_max_diff(&vec.adjacency, &par.adjacency) < 1e-8);
    }

    #[test]
    fn constant_column_rejected_not_panicking() {
        let mut rng = Pcg64::seed_from_u64(6);
        let ds = simulate_sem(&SemSpec::layered(5, 2, 0.5), 500, &mut rng);
        let mut x = ds.data.clone();
        // non-dyadic constant: repeated float sums leave ~1e-17 of
        // rounding variance, which an exact-zero variance test missed
        let constant = vec![0.1; x.rows()];
        x.set_col(2, &constant);
        for eng in [
            &SequentialEngine as &dyn crate::lingam::OrderingEngine,
            &VectorizedEngine,
            &ParallelEngine::new(2),
        ] {
            let res = DirectLingam::new().fit(&x, eng);
            assert!(
                matches!(res, Err(Error::InvalidArgument(_))),
                "{}: constant column must be InvalidArgument",
                eng.name()
            );
        }
    }

    #[test]
    fn profile_dominated_by_ordering() {
        let mut rng = Pcg64::seed_from_u64(4);
        let ds = simulate_sem(&SemSpec::layered(10, 2, 0.5), 4_000, &mut rng);
        let fit = DirectLingam::new().fit(&ds.data, &SequentialEngine).unwrap();
        // the Figure-2 claim: ordering dominates. The 96% figure is at
        // paper scale; at this tiny test size regression overhead is
        // proportionally larger, so assert dominance, not the asymptote.
        assert!(
            fit.profile.fraction("ordering") > 0.5,
            "ordering fraction = {}",
            fit.profile.fraction("ordering")
        );
    }

    #[test]
    fn rejects_degenerate_inputs() {
        let x1 = Mat::zeros(100, 1);
        assert!(DirectLingam::new().fit(&x1, &VectorizedEngine).is_err());
        let x2 = Mat::zeros(4, 3);
        assert!(DirectLingam::new().fit(&x2, &VectorizedEngine).is_err());
        let mut x3 = Mat::zeros(100, 3);
        x3[(0, 0)] = f64::NAN;
        assert!(DirectLingam::new().fit(&x3, &VectorizedEngine).is_err());
    }

    #[test]
    fn order_is_permutation() {
        let mut rng = Pcg64::seed_from_u64(5);
        let ds = simulate_sem(&SemSpec::erdos_renyi(7, 1.5), 2_000, &mut rng);
        let fit = DirectLingam::new().fit(&ds.data, &VectorizedEngine).unwrap();
        let mut o = fit.order.clone();
        o.sort_unstable();
        assert_eq!(o, (0..7).collect::<Vec<_>>());
        assert_eq!(fit.step_scores.len(), 6);
    }
}
