//! DirectLiNGAM (Shimizu et al. 2011).
//!
//! Repeats: find the most-exogenous variable (Algorithm 1, delegated to
//! an [`OrderingEngine`]) → append it to the causal order → remove its
//! effect from the remaining variables by least-squares residualization.
//! After the full order is known, the weighted adjacency is estimated by
//! regressing each variable on its predecessors ([`prune`]).
//!
//! [`DirectLingam::fit`] opens **one ordering session per fit**
//! ([`OrderingEngine::session`]) and drives it through all d−1 search
//! steps, so the workspace — standardized cache, correlation matrix,
//! scratch — is built once and updated incrementally in place (see
//! [`super::session`]). [`DirectLingam::fit_stateless`] keeps the legacy
//! clone-and-`order_step` loop as the comparison baseline, and
//! [`DirectLingam::fit_session`] drives a caller-provided (pooled,
//! reset) session so the bootstrap can reuse workspaces across
//! resamples. [`DirectLingam::fit_plan`] generalizes the driver from
//! "drive one session" to "execute an [`OrderingPlan`]" — the seam the
//! partitioned ordering layer ([`super::partition`]) plugs into.
//!
//! The per-stage timing profile this driver collects is what the
//! Figure-2 reproduction reports (ordering is ~96% of total runtime).

use super::engine::{OrderingEngine, OrderStep};
use super::prune::{estimate_adjacency, PruneMethod};
use super::session::{FnObserver, NullObserver, OrderingSession, StatelessSession, StepObserver};
use super::sweep::SweepCounters;
use crate::linalg::Mat;
use crate::util::timer::StageProfile;
use crate::util::{Error, Result};

/// DirectLiNGAM configuration.
#[derive(Clone, Debug, Default)]
pub struct DirectLingam {
    /// Adjacency pruning method (default: adaptive lasso).
    pub prune: PruneMethod,
}

/// A fitted model.
#[derive(Clone, Debug)]
pub struct LingamFit {
    /// Estimated causal order, causes first.
    pub order: Vec<usize>,
    /// Estimated weighted adjacency (`adj[(i,j)] = β_ij`, j → i).
    pub adjacency: Mat,
    /// k_list of every search step (step s has scores over the variables
    /// still active at step s) — kept for the engine-agreement tests.
    pub step_scores: Vec<Vec<f64>>,
    /// Wall-clock per stage: "ordering" vs "regression".
    pub profile: StageProfile,
}

/// A strategy for producing the full causal order of a panel — the seam
/// between [`DirectLingam`] and *how* the ordering work is decomposed.
///
/// [`DirectLingam::fit`] is the monolithic case: one session over the
/// whole panel. A plan generalizes that to "execute a set of sessions
/// and merge their orders" — the whole-panel fit is the trivial
/// single-block plan ([`super::partition::SingleBlockPlan`]), and the
/// partitioned plan ([`super::partition::PartitionedPlan`]) decomposes
/// the panel into correlation-connected blocks. The driver keeps sole
/// ownership of validation and adjacency regression, so every plan
/// rejects exactly the panels `fit` rejects and prices the regression
/// stage identically.
pub trait OrderingPlan {
    /// Short name for logs and profiles.
    fn name(&self) -> &'static str;
    /// Produce the full causal order (causes first) for `data`, plus the
    /// instrumentation the serve layer books into its metrics.
    fn order(&self, data: &Mat) -> Result<PlanOrdering>;
}

/// What a plan returns: the order itself plus the decomposition
/// instrumentation ([`DirectLingam::fit_plan`] turns this into a
/// [`PlanFit`] by adding the adjacency regression).
#[derive(Clone, Debug)]
pub struct PlanOrdering {
    /// Full causal order — must be a permutation of `0..d`.
    pub order: Vec<usize>,
    /// Per-step score vectors where the plan defines them (the exact
    /// merge tier reports the same d−1 vectors as the unpartitioned
    /// fit; the approx tier's block-local scores are not comparable
    /// across blocks, so it reports none).
    pub step_scores: Vec<Vec<f64>>,
    /// Sweep work accumulated across every session the plan drove.
    pub counters: SweepCounters,
    /// Number of column blocks the plan decomposed the panel into
    /// (1 for the single-block plan).
    pub blocks_formed: u64,
    /// Cross-block candidate pairs the merge visited (0 for the
    /// single-block plan — there is nothing to reconcile).
    pub boundary_pairs: u64,
}

/// A fitted model produced through a plan: the ordinary [`LingamFit`]
/// plus the plan's decomposition instrumentation.
#[derive(Clone, Debug)]
pub struct PlanFit {
    /// The fit itself (order, adjacency, step scores, stage profile).
    pub fit: LingamFit,
    /// Sweep work accumulated across every session the plan drove.
    pub counters: SweepCounters,
    /// Blocks the plan formed.
    pub blocks_formed: u64,
    /// Cross-block candidate pairs the merge visited.
    pub boundary_pairs: u64,
}

impl DirectLingam {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn with_prune(prune: PruneMethod) -> Self {
        DirectLingam { prune }
    }

    /// Fit on a data panel `[n, d]` using the given ordering engine.
    ///
    /// Opens one [`OrderingSession`] for the whole d−1-step loop; session
    /// creation (the one-time standardize + correlation build) is timed
    /// under the "ordering" stage, since it is ordering work the
    /// stateless path pays again on every step.
    pub fn fit(&self, data: &Mat, engine: &dyn OrderingEngine) -> Result<LingamFit> {
        self.validate(data)?;
        let mut profile = StageProfile::new();
        let mut session = profile.time("ordering", || engine.session(data))?;
        self.drive(data, session.as_mut(), profile, &mut NullObserver)
    }

    /// Fit by driving a caller-provided session that has already been
    /// seeded with `data` (via [`OrderingEngine::session`] or
    /// [`OrderingSession::reset`]) — the buffer-reuse entry point the
    /// bootstrap's session pool goes through.
    ///
    /// Shape and freshness are checked; that the session was seeded with
    /// *this* panel (not a different one of the same shape) cannot be
    /// verified here and is the caller's contract — a mismatch would mix
    /// one panel's causal order with the other's adjacency regression.
    pub fn fit_session(
        &self,
        data: &Mat,
        session: &mut dyn OrderingSession,
    ) -> Result<LingamFit> {
        self.fit_session_stepped(data, session, &mut NullObserver)
    }

    /// [`fit_session`](DirectLingam::fit_session) with a per-step
    /// observer closure: `observer(completed, total)` runs after every
    /// search step, and an `Err` aborts the fit — kept as the ergonomic
    /// closure form over [`fit_session_stepped`]
    /// (DirectLingam::fit_session_stepped).
    pub fn fit_session_observed(
        &self,
        data: &Mat,
        session: &mut dyn OrderingSession,
        observer: &mut dyn FnMut(usize, usize) -> Result<()>,
    ) -> Result<LingamFit> {
        self.fit_session_stepped(data, session, &mut FnObserver(observer))
    }

    /// [`fit_session`](DirectLingam::fit_session) with a typed
    /// [`StepObserver`]: `step_done(completed, total, elapsed)` runs
    /// after every search step with that step's measured wall clock, and
    /// an `Err` aborts the fit; `sweep_done` fires once after the last
    /// step with the session's accumulated [`SweepCounters`]. The seam
    /// the serve layer uses to stream per-step progress, honor
    /// cancellation at step boundaries, and book per-step latency into
    /// its histograms/traces without duplicating the drive loop.
    pub fn fit_session_stepped(
        &self,
        data: &Mat,
        session: &mut dyn OrderingSession,
        observer: &mut dyn StepObserver,
    ) -> Result<LingamFit> {
        self.validate(data)?;
        if session.active().len() != data.cols()
            || session.rows() != data.rows()
            || session.remaining() != data.cols()
        {
            return Err(Error::InvalidArgument(
                "session does not match the panel (wrong shape, or already stepped — \
                 reset it first)"
                    .into(),
            ));
        }
        self.drive(data, session, StageProfile::new(), observer)
    }

    /// The legacy stateless path: clone the panel and call
    /// [`OrderingEngine::order_step`] once per iteration, re-deriving
    /// every statistic from the residual panel each time. Kept as the
    /// baseline the session path is measured against (`fig2_speedup`)
    /// and as the reference the per-step agreement tests recompute from.
    /// Implemented as the same internal drive loop over the stateless
    /// shim, so there is exactly one copy of the d−1-step logic.
    pub fn fit_stateless(&self, data: &Mat, engine: &dyn OrderingEngine) -> Result<LingamFit> {
        self.validate(data)?;
        // panel clone (inside the shim) deliberately untimed, matching
        // the legacy loop's untimed `data.clone()`
        let mut shim = StatelessSession::new(engine, data);
        self.drive(data, &mut shim, StageProfile::new(), &mut NullObserver)
    }

    /// Fit by executing an [`OrderingPlan`] instead of driving one
    /// session directly — the entry point the `partition[:B]` engine
    /// spec routes through. Validation and the adjacency regression are
    /// identical to [`fit`](DirectLingam::fit): the plan only supplies
    /// the causal order, so the partition path rejects exactly the
    /// panels the monolithic path rejects.
    pub fn fit_plan(&self, data: &Mat, plan: &dyn OrderingPlan) -> Result<PlanFit> {
        self.validate(data)?;
        let mut profile = StageProfile::new();
        let plan_out = profile.time("ordering", || plan.order(data))?;
        let d = data.cols();
        let mut seen = vec![false; d];
        let valid = plan_out.order.len() == d
            && plan_out.order.iter().all(|&v| v < d && !std::mem::replace(&mut seen[v], true));
        if !valid {
            return Err(Error::Numerical(format!(
                "plan {:?} returned an invalid order (not a permutation of 0..{d})",
                plan.name()
            )));
        }
        let fit = self.finish(data, plan_out.order, plan_out.step_scores, profile)?;
        Ok(PlanFit {
            fit,
            counters: plan_out.counters,
            blocks_formed: plan_out.blocks_formed,
            boundary_pairs: plan_out.boundary_pairs,
        })
    }

    /// Drive a session through the d−1 search steps and estimate the
    /// adjacency over the original (un-residualized) data. The one copy
    /// of the step loop behind every fit entry point; `observer` runs
    /// after each step (progress/cancellation/timing hooks — see
    /// [`fit_session_stepped`](DirectLingam::fit_session_stepped)).
    fn drive(
        &self,
        data: &Mat,
        session: &mut dyn OrderingSession,
        mut profile: StageProfile,
        observer: &mut dyn StepObserver,
    ) -> Result<LingamFit> {
        let d = data.cols();
        let steps = d - 1;
        let mut order = Vec::with_capacity(d);
        let mut step_scores = Vec::with_capacity(d);
        // causal ordering: d−1 search steps; the last variable is forced.
        // Each step is timed individually so the observer sees per-step
        // wall clock (the serve tier's step histogram) and the profile
        // still books the same "ordering" total.
        for k in 0..steps {
            let t0 = std::time::Instant::now();
            let step: OrderStep = session.step()?;
            let dt = t0.elapsed();
            profile.add("ordering", dt);
            order.push(step.chosen);
            step_scores.push(step.scores);
            observer.step_done(k + 1, steps, dt)?;
        }
        observer.sweep_done(&session.sweep_counters());
        let last = session
            .active()
            .iter()
            .position(|&a| a)
            .expect("exactly one variable remains");
        order.push(last);
        self.finish(data, order, step_scores, profile)
    }

    fn finish(
        &self,
        data: &Mat,
        order: Vec<usize>,
        step_scores: Vec<Vec<f64>>,
        mut profile: StageProfile,
    ) -> Result<LingamFit> {
        // adjacency over the original (un-residualized) data
        let adjacency =
            profile.time("regression", || estimate_adjacency(data, &order, self.prune))?;
        Ok(LingamFit { order, adjacency, step_scores, profile })
    }

    fn validate(&self, data: &Mat) -> Result<()> {
        validate_panel(data)
    }
}

/// The panel preconditions every DirectLiNGAM entry point enforces —
/// shared as a free function so callers that drive sessions themselves
/// (the serve workers, which need per-step progress hooks `fit` does not
/// expose) reject exactly the panels `DirectLingam::fit` would.
pub(crate) fn validate_panel(data: &Mat) -> Result<()> {
    let (n, d) = (data.rows(), data.cols());
    if d < 2 {
        return Err(Error::InvalidArgument(format!("need ≥ 2 variables, got {d}")));
    }
    if n < 8 {
        return Err(Error::InvalidArgument(format!("need ≥ 8 samples, got {n}")));
    }
    if !data.is_finite() {
        return Err(Error::InvalidArgument("data contains NaN/inf".into()));
    }
    // a (near-)constant column has no causal direction to estimate
    // (its correlation with everything is 0/0); reject it up front
    // instead of letting degenerate scores reach the engines. The
    // threshold is relative to the column's scale: an exact-zero test
    // would miss constants like 0.1 whose float sums leave ~1e-17 of
    // rounding variance, and std below the standardize() floor means
    // the column is constant to working precision anyway
    for c in 0..d {
        let col = data.col(c);
        if crate::stats::std(&col) <= 1e-12 * (1.0 + crate::stats::mean(&col).abs()) {
            return Err(Error::InvalidArgument(format!(
                "column {c} is constant (zero variance): causal order undefined"
            )));
        }
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph;
    use crate::lingam::{ParallelEngine, SequentialEngine, VectorizedEngine};
    use crate::metrics::graph_metrics;
    use crate::sim::{simulate_sem, SemSpec};
    use crate::util::rng::Pcg64;

    #[test]
    fn recovers_chain() {
        let mut rng = Pcg64::seed_from_u64(1);
        let mut adj = Mat::zeros(4, 4);
        adj[(1, 0)] = 1.0;
        adj[(2, 1)] = 1.3;
        adj[(3, 2)] = -0.9;
        let dag = graph::Dag::new(adj.clone()).unwrap();
        let x = crate::sim::sem::sample_from_dag(&dag, crate::sim::Noise::Uniform01, 10_000, &mut rng);
        let fit = DirectLingam::new().fit(&x, &VectorizedEngine).unwrap();
        assert_eq!(fit.order, vec![0, 1, 2, 3]);
        let m = graph_metrics(&adj, &fit.adjacency, 0.1);
        assert_eq!(m.f1, 1.0, "adjacency: {:?}", fit.adjacency);
    }

    #[test]
    fn paper_sim_design_recovered() {
        // the paper's §3.1 configuration at small scale
        let mut rng = Pcg64::seed_from_u64(2);
        let ds = simulate_sem(&SemSpec::layered(10, 2, 0.5), 10_000, &mut rng);
        let fit = DirectLingam::new().fit(&ds.data, &VectorizedEngine).unwrap();
        assert!(graph::order_consistent(&ds.adjacency, &fit.order), "order {:?}", fit.order);
        // weights are θ ~ N(0,1): edges with |θ| below the metric
        // threshold are unrecoverable in principle, so demand a strong
        // but not perfect F1 here (the Fig-3 bench reports the sweep)
        let m = graph_metrics(&ds.adjacency, &fit.adjacency, 0.1);
        assert!(m.f1 > 0.75, "f1={}", m.f1);
    }

    #[test]
    fn engines_produce_identical_orders() {
        let mut rng = Pcg64::seed_from_u64(3);
        let ds = simulate_sem(&SemSpec::layered(8, 2, 0.5), 3_000, &mut rng);
        let seq = DirectLingam::new().fit(&ds.data, &SequentialEngine).unwrap();
        let vec = DirectLingam::new().fit(&ds.data, &VectorizedEngine).unwrap();
        let par = DirectLingam::new()
            .fit(&ds.data, &ParallelEngine::new(4).force_parallel())
            .unwrap();
        assert_eq!(seq.order, vec.order);
        assert_eq!(vec.order, par.order, "parallel engine diverged from vectorized");
        assert!(crate::metrics::adjacency_max_diff(&seq.adjacency, &vec.adjacency) < 1e-8);
        assert!(crate::metrics::adjacency_max_diff(&vec.adjacency, &par.adjacency) < 1e-8);
    }

    #[test]
    fn session_and_stateless_fits_agree() {
        let mut rng = Pcg64::seed_from_u64(11);
        let ds = simulate_sem(&SemSpec::layered(8, 2, 0.5), 3_000, &mut rng);
        for eng in [&VectorizedEngine as &dyn crate::lingam::OrderingEngine, &SequentialEngine] {
            let s = DirectLingam::new().fit(&ds.data, eng).unwrap();
            let l = DirectLingam::new().fit_stateless(&ds.data, eng).unwrap();
            assert_eq!(s.order, l.order, "{}: session order diverged", eng.name());
            assert!(
                crate::metrics::adjacency_max_diff(&s.adjacency, &l.adjacency) < 1e-10,
                "{}: adjacency diverged",
                eng.name()
            );
        }
    }

    #[test]
    fn observed_fit_reports_every_step_and_can_abort() {
        let mut rng = Pcg64::seed_from_u64(13);
        let ds = simulate_sem(&SemSpec::layered(6, 2, 0.5), 800, &mut rng);
        let engine = VectorizedEngine;
        let mut session = engine.session(&ds.data).unwrap();
        let mut seen = Vec::new();
        let fit = DirectLingam::new()
            .fit_session_observed(&ds.data, session.as_mut(), &mut |k, total| {
                seen.push((k, total));
                Ok(())
            })
            .unwrap();
        assert_eq!(seen, (1..=5).map(|k| (k, 5)).collect::<Vec<_>>());
        let plain = DirectLingam::new().fit(&ds.data, &engine).unwrap();
        assert_eq!(fit.order, plain.order, "observer must not change the fit");
        // an observer error aborts the drive and surfaces unchanged
        session.reset(&ds.data).unwrap();
        let res = DirectLingam::new().fit_session_observed(
            &ds.data,
            session.as_mut(),
            &mut |k, _| {
                if k == 2 {
                    Err(Error::Canceled("stop".into()))
                } else {
                    Ok(())
                }
            },
        );
        assert!(matches!(res, Err(Error::Canceled(_))), "got {res:?}");
    }

    #[test]
    fn fit_session_requires_fresh_session() {
        let mut rng = Pcg64::seed_from_u64(12);
        let ds = simulate_sem(&SemSpec::layered(5, 2, 0.5), 800, &mut rng);
        let engine = VectorizedEngine;
        let mut session = engine.session(&ds.data).unwrap();
        let fit = DirectLingam::new().fit_session(&ds.data, session.as_mut()).unwrap();
        assert_eq!(fit.order.len(), 5);
        // exhausted session must be rejected until reset
        assert!(DirectLingam::new().fit_session(&ds.data, session.as_mut()).is_err());
        session.reset(&ds.data).unwrap();
        let again = DirectLingam::new().fit_session(&ds.data, session.as_mut()).unwrap();
        assert_eq!(fit.order, again.order);
    }

    #[test]
    fn constant_column_rejected_not_panicking() {
        let mut rng = Pcg64::seed_from_u64(6);
        let ds = simulate_sem(&SemSpec::layered(5, 2, 0.5), 500, &mut rng);
        let mut x = ds.data.clone();
        // non-dyadic constant: repeated float sums leave ~1e-17 of
        // rounding variance, which an exact-zero variance test missed
        let constant = vec![0.1; x.rows()];
        x.set_col(2, &constant);
        for eng in [
            &SequentialEngine as &dyn crate::lingam::OrderingEngine,
            &VectorizedEngine,
            &ParallelEngine::new(2),
        ] {
            let res = DirectLingam::new().fit(&x, eng);
            assert!(
                matches!(res, Err(Error::InvalidArgument(_))),
                "{}: constant column must be InvalidArgument",
                eng.name()
            );
        }
    }

    #[test]
    fn profile_dominated_by_ordering() {
        let mut rng = Pcg64::seed_from_u64(4);
        let ds = simulate_sem(&SemSpec::layered(10, 2, 0.5), 4_000, &mut rng);
        let fit = DirectLingam::new().fit(&ds.data, &SequentialEngine).unwrap();
        // the Figure-2 claim: ordering dominates. The 96% figure is at
        // paper scale; at this tiny test size regression overhead is
        // proportionally larger, so assert dominance, not the asymptote.
        assert!(
            fit.profile.fraction("ordering") > 0.5,
            "ordering fraction = {}",
            fit.profile.fraction("ordering")
        );
    }

    #[test]
    fn rejects_degenerate_inputs() {
        let x1 = Mat::zeros(100, 1);
        assert!(DirectLingam::new().fit(&x1, &VectorizedEngine).is_err());
        let x2 = Mat::zeros(4, 3);
        assert!(DirectLingam::new().fit(&x2, &VectorizedEngine).is_err());
        let mut x3 = Mat::zeros(100, 3);
        x3[(0, 0)] = f64::NAN;
        assert!(DirectLingam::new().fit(&x3, &VectorizedEngine).is_err());
    }

    #[test]
    fn order_is_permutation() {
        let mut rng = Pcg64::seed_from_u64(5);
        let ds = simulate_sem(&SemSpec::erdos_renyi(7, 1.5), 2_000, &mut rng);
        let fit = DirectLingam::new().fit(&ds.data, &VectorizedEngine).unwrap();
        let mut o = fit.order.clone();
        o.sort_unstable();
        assert_eq!(o, (0..7).collect::<Vec<_>>());
        assert_eq!(fit.step_scores.len(), 6);
    }
}
