//! `ParallelEngine` — the restructured ordering computation tiled across
//! a bounded CPU worker pool.
//!
//! ParaLiNGAM (Shahbazinia et al. 2023) observes that DirectLiNGAM's
//! O(d²)-pair scoring loop scales near-linearly across CPU threads; this
//! engine applies the same idea to the repo's restructured pair kernel.
//! The upper triangle of the pair matrix is tiled by *row* over
//! [`crate::util::pool::parallel_indexed`] — the same
//! work-stealing-by-atomic-counter pool behind
//! [`crate::coordinator::sweep::parallel_map`] — with each task computing
//! every pair `(a, b)` with `b > a`, reusing the cached standardized
//! column `a` across the whole row. Row contributions come back in row
//! order and are merged on the calling thread, so the result is
//! **deterministic** regardless of which worker processed which row, and
//! agrees with [`VectorizedEngine`](super::VectorizedEngine) to well
//! under 1e-9 (the two differ only in summation association). Small
//! panels (below a pair-work cutoff, ~1 ms of compute) fall back to the
//! identical serial kernel, so the default engine never pays thread
//! spawn/join overhead on problems that finish faster than a spawn.
//!
//! `order_step` additionally residualizes the remaining active columns in
//! parallel: each column's least-squares update is independent, so the
//! columns are split across the same pool and written back serially (the
//! row-major panel interleaves columns, so in-place parallel writes would
//! need aliasing unsafety for no measurable gain).
//!
//! The engine's session
//! ([`OrderingEngine::session`](super::engine::OrderingEngine::session))
//! is the incremental workspace of [`super::session`] with the same
//! worker pool driving its sweeps: the row-tiled pair loop
//! ([`tiled_pair_sweep`], shared between the stateless path here and the
//! session's cached-ρ sweep), the per-column entropy refresh, and the
//! in-place cache residualization (workers own disjoint column buffers
//! taken out of the shared session cache, so no aliasing unsafety is
//! needed there either).
//!
//! [`ParallelEngine::with_pruning`] switches the engine (and its
//! sessions) from the exact row tiles to the **bound-pruned** sweep of
//! [`super::sweep`]: candidates become the dynamic tiles, a shared
//! atomic carries the best completed penalty, and dominated candidates
//! stop mid-row — the same root sequence as the exact sweep, provably,
//! with the per-pair work avoided instead of merely parallelized.

use super::engine::{
    accumulate_pairs, argmax_active, column_entropies, pair_diff, residualize_in_place,
    scatter_scores, standardized_active_columns, OrderStep, OrderingEngine,
};
use super::session::{IncrementalSession, OrderingSession};
use super::sweep::{pair_work, pruned_sweep, pruned_sweep_parallel, SweepCounters, SweepStrategy};
use crate::linalg::Mat;
use crate::stats;
use crate::util::pool::parallel_indexed;
use crate::util::Result;

pub(crate) use super::sweep::tiled_pair_sweep;

/// Worker count to use when the caller passes 0: one per available core.
pub fn default_workers() -> usize {
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Below this many fused pair-element operations (pairs × n) the scoped
/// thread spawn/join overhead outweighs the pair work; fall back to the
/// serial kernel. ~1 ms of work at a few ns per element.
const MIN_PARALLEL_PAIR_WORK: usize = 1 << 18;

/// Column-elements threshold below which residualization stays serial.
const MIN_PARALLEL_RESID_WORK: usize = 1 << 16;

/// Multi-threaded CPU ordering engine (see module docs).
#[derive(Clone, Debug)]
pub struct ParallelEngine {
    workers: usize,
    /// Skip the small-problem serial fallback (tests/benches that need
    /// the threaded path exercised regardless of problem size).
    force_parallel: bool,
    /// How the pair space is visited: exact (default) or bound-pruned
    /// (ParaLiNGAM early termination, [`super::sweep`]).
    strategy: SweepStrategy,
}

impl ParallelEngine {
    /// `workers == 0` means auto (one worker per available core).
    pub fn new(workers: usize) -> ParallelEngine {
        let workers = if workers == 0 { default_workers() } else { workers };
        ParallelEngine { workers, force_parallel: false, strategy: SweepStrategy::Exact }
    }

    /// The resolved worker count.
    pub fn workers(&self) -> usize {
        self.workers
    }

    /// Disable the small-problem serial fallback, so even tiny panels go
    /// through the thread pool (for tests and scaling benches; the
    /// fallback is the right default for real workloads).
    pub fn force_parallel(mut self) -> ParallelEngine {
        self.force_parallel = true;
        self
    }

    /// Switch the engine — and every session it opens — to the
    /// bound-pruned sweep: provably the identical root sequence as the
    /// exact sweep (dominated candidates report partial, strictly losing
    /// scores; see [`super::sweep`] for the argument). `workers == 1`
    /// gives the serial memoized pruned sweep — the single-threaded
    /// pruned counterpart of
    /// [`VectorizedEngine`](super::engine::VectorizedEngine).
    pub fn with_pruning(mut self) -> ParallelEngine {
        self.strategy = SweepStrategy::Pruned;
        self
    }

    /// The engine's sweep strategy.
    pub fn strategy(&self) -> SweepStrategy {
        self.strategy
    }
}

impl Default for ParallelEngine {
    /// Auto-sized pool — the default CPU engine for the apps.
    fn default() -> ParallelEngine {
        ParallelEngine::new(0)
    }
}

impl OrderingEngine for ParallelEngine {
    fn name(&self) -> &'static str {
        match self.strategy {
            SweepStrategy::Exact => "parallel",
            SweepStrategy::Pruned => "pruned",
        }
    }

    fn scores(&self, x: &Mat, active: &[bool]) -> Result<Vec<f64>> {
        let (idx, cols) = standardized_active_columns(x, active);
        let m = idx.len();
        let h = column_entropies(&cols);
        let work = pair_work(m, x.rows());
        let serial =
            m < 2 || self.workers == 1 || (!self.force_parallel && work < MIN_PARALLEL_PAIR_WORK);
        let k = match self.strategy {
            SweepStrategy::Exact => {
                if serial {
                    accumulate_pairs(&cols, &h)
                } else {
                    pair_sweep(&cols, &h, self.workers)
                }
            }
            SweepStrategy::Pruned => {
                // the stateless path has no previous-step scores to seed
                // the schedule and no session to surface counters into
                let mut counters = SweepCounters::default();
                let diff = |a: usize, b: usize| pair_diff(&cols[a], &cols[b], h[a], h[b]);
                if serial {
                    pruned_sweep(m, &diff, None, x.rows(), &mut counters)
                } else {
                    pruned_sweep_parallel(m, self.workers, &diff, None, x.rows(), &mut counters)
                }
            }
        };
        Ok(scatter_scores(x.cols(), &idx, &k))
    }

    fn order_step(&self, x: &mut Mat, active: &mut [bool]) -> Result<OrderStep> {
        let scores = self.scores(x, active)?;
        let chosen = argmax_active(&scores, active)?;
        let resid_work = active.iter().filter(|&&a| a).count().saturating_sub(1) * x.rows();
        if self.workers == 1 || (!self.force_parallel && resid_work < MIN_PARALLEL_RESID_WORK) {
            residualize_in_place(x, active, chosen);
        } else {
            residualize_in_place_parallel(x, active, chosen, self.workers);
        }
        active[chosen] = false;
        Ok(OrderStep { chosen, scores })
    }

    /// Incremental workspace session with this engine's worker pool
    /// tiling the sweeps (and the same small-problem serial fallback /
    /// `force_parallel` override — and sweep strategy — as the
    /// stateless path).
    fn session<'a>(&'a self, data: &Mat) -> Result<Box<dyn OrderingSession + 'a>> {
        Ok(Box::new(IncrementalSession::with_strategy(
            data,
            self.workers,
            self.force_parallel,
            self.strategy,
        )?))
    }

    fn sweep_strategy(&self) -> SweepStrategy {
        self.strategy
    }

    /// Pooled incremental workspace — batchable with this exact pool
    /// configuration and sweep strategy.
    fn incremental_config(&self) -> Option<(usize, bool, SweepStrategy)> {
        Some((self.workers, self.force_parallel, self.strategy))
    }
}

/// The stateless pair sweep: row-tiled [`pair_diff`] over freshly
/// standardized columns (each row task reuses its cached column `a`).
fn pair_sweep(cols: &[Vec<f64>], h: &[f64], workers: usize) -> Vec<f64> {
    tiled_pair_sweep(cols.len(), workers, |a, b| pair_diff(&cols[a], &cols[b], h[a], h[b]))
}

/// Parallel counterpart of
/// [`residualize_in_place`](super::engine::residualize_in_place): the
/// per-column updates are independent, so columns are split across the
/// pool (same atomic-counter stealing) and the results written back on
/// the calling thread. Bitwise-identical to the serial version.
pub fn residualize_in_place_parallel(x: &mut Mat, active: &[bool], m: usize, workers: usize) {
    let xm = x.col(m);
    let var_m = stats::var(&xm).max(1e-300);
    let mean_m = stats::mean(&xm);
    let n = x.rows();
    let targets: Vec<usize> = (0..x.cols()).filter(|&j| j != m && active[j]).collect();
    if targets.is_empty() {
        return;
    }
    let panel: &Mat = x;
    let new_cols = parallel_indexed(targets.len(), workers, |t| {
        let xj = panel.col(targets[t]);
        let beta = stats::cov(&xj, &xm) / var_m;
        let mean_j = stats::mean(&xj);
        (0..n).map(|r| (xj[r] - mean_j) - beta * (xm[r] - mean_m)).collect::<Vec<f64>>()
    });
    for (t, col) in new_cols.into_iter().enumerate() {
        x.set_col(targets[t], &col);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lingam::engine::{residualize_in_place, VectorizedEngine, INACTIVE_SCORE};
    use crate::sim::{simulate_sem, SemSpec};
    use crate::util::rng::Pcg64;

    fn toy_panel(n: usize, d: usize, seed: u64) -> Mat {
        let mut rng = Pcg64::seed_from_u64(seed);
        simulate_sem(&SemSpec::layered(d, 2, 0.6), n, &mut rng).data
    }

    #[test]
    fn matches_vectorized_scores() {
        let x = toy_panel(1_500, 8, 1);
        let active = vec![true; 8];
        let kv = VectorizedEngine.scores(&x, &active).unwrap();
        for workers in [1, 2, 3, 8] {
            // force_parallel: the toy panel is below the serial-fallback
            // cutoff, and the threaded path is what's under test
            let kp =
                ParallelEngine::new(workers).force_parallel().scores(&x, &active).unwrap();
            for i in 0..8 {
                assert!(
                    (kv[i] - kp[i]).abs() < 1e-9 * (1.0 + kv[i].abs()),
                    "workers={workers} i={i}: vec={} par={}",
                    kv[i],
                    kp[i]
                );
            }
        }
    }

    #[test]
    fn small_problem_fallback_is_exact() {
        // below the cutoff the engine runs the identical serial kernel,
        // so scores must match the vectorized engine bitwise
        let x = toy_panel(300, 6, 9);
        let active = vec![true; 6];
        let kv = VectorizedEngine.scores(&x, &active).unwrap();
        let kp = ParallelEngine::new(4).scores(&x, &active).unwrap();
        assert_eq!(kv, kp);
    }

    #[test]
    fn respects_active_mask() {
        let x = toy_panel(400, 6, 2);
        let mut active = vec![true; 6];
        active[1] = false;
        active[5] = false;
        let k = ParallelEngine::new(3).scores(&x, &active).unwrap();
        assert_eq!(k[1], INACTIVE_SCORE);
        assert_eq!(k[5], INACTIVE_SCORE);
        assert!(k[0].is_finite());
    }

    #[test]
    fn deterministic_across_runs() {
        // row-ordered merging makes the sum independent of scheduling
        let x = toy_panel(800, 7, 3);
        let active = vec![true; 7];
        let engine = ParallelEngine::new(4).force_parallel();
        let k1 = engine.scores(&x, &active).unwrap();
        for _ in 0..5 {
            let k2 = engine.scores(&x, &active).unwrap();
            assert_eq!(k1, k2, "parallel scores varied across runs");
        }
    }

    #[test]
    fn parallel_residualize_matches_serial() {
        let mut a = toy_panel(600, 6, 4);
        let mut b = a.clone();
        let active = vec![true; 6];
        residualize_in_place(&mut a, &active, 2);
        residualize_in_place_parallel(&mut b, &active, 2, 3);
        assert_eq!(a, b, "parallel residualize diverged from serial");
    }

    #[test]
    fn order_step_deactivates_chosen() {
        let mut x = toy_panel(500, 5, 5);
        let mut active = vec![true; 5];
        let step = ParallelEngine::new(2)
            .force_parallel()
            .order_step(&mut x, &mut active)
            .unwrap();
        assert!(!active[step.chosen]);
        assert_eq!(active.iter().filter(|&&a| a).count(), 4);
    }

    #[test]
    fn tiny_active_sets() {
        let x = toy_panel(100, 4, 6);
        // one active variable: nothing to compare, score must be -0.0
        let mut active = vec![false; 4];
        active[2] = true;
        let k = ParallelEngine::new(4).scores(&x, &active).unwrap();
        assert_eq!(k[2], 0.0);
        assert_eq!(k[0], INACTIVE_SCORE);
        // zero active variables: all inactive
        let k0 = ParallelEngine::new(4).scores(&x, &[false; 4]).unwrap();
        assert!(k0.iter().all(|&v| v == INACTIVE_SCORE));
    }

    #[test]
    fn worker_auto_sizing() {
        assert!(ParallelEngine::new(0).workers() >= 1);
        assert_eq!(ParallelEngine::new(3).workers(), 3);
        assert!(ParallelEngine::default().workers() >= 1);
    }
}
