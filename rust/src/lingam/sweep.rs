//! The pair-sweep subsystem: every CPU ordering path's O(d²·n) hot loop,
//! in one place — with an exact mode and a **bound-pruned, scheduled**
//! mode (ParaLiNGAM-style early termination, Shahbazinia et al. 2023).
//!
//! # Why pruning is exact
//!
//! Algorithm 1 scores candidate root `i` as `−k_i` with
//! `k_i = Σ_{j≠i} min(0, diff_mi(i, j))²` — a sum of **non-negative**
//! penalty terms, so a candidate's running penalty only grows as its
//! pairs are visited. The next root is the candidate with the *smallest*
//! total penalty. Therefore a candidate whose running penalty already
//! exceeds the total penalty of any *completed* candidate can be dropped
//! mid-sweep: its final score is certain to lose the argmax. Three
//! details make the pruned sweep's choice provably identical to the
//! exact sweep's, not just approximately so:
//!
//! 1. **Per-candidate accumulation order is preserved.** A candidate's
//!    penalty is accumulated over `j` in ascending index order — the
//!    same order [`accumulate_pair_diffs`] uses — so a candidate that is
//!    never pruned ends with the *bitwise identical* float total. Pair
//!    antisymmetry is exploited by always evaluating the kernel in the
//!    canonical `(min, max)` direction and negating (IEEE negation of a
//!    subtraction is exact), matching the exact sweep's shared-pair
//!    arithmetic.
//! 2. **Pruning is strict.** A candidate is dropped only when
//!    `running > bound`; exact ties keep sweeping, complete exactly, and
//!    fall through to the same lowest-index argmax tie-break.
//! 3. **Partial scores stay below the winner.** At prune time
//!    `running > bound ≥ (eventual minimum total)`, so the partial score
//!    `−running` is *strictly below* the winner's exact score and can
//!    never steal the argmax — and since penalties are non-negative and
//!    IEEE addition of a non-negative term is monotone, `−running` is
//!    also an upper bound on the candidate's true score. The winner
//!    itself is never pruned (its running penalty can never exceed a
//!    completed total without exceeding its own minimal total).
//!
//! NaN penalties (overflowed entropies on wildly degenerate panels)
//! never satisfy the strict comparisons, so NaN candidates are neither
//! pruned nor allowed to tighten the bound — degenerate-panel behavior
//! is byte-for-byte the exact sweep's.
//!
//! # Scheduling
//!
//! Candidates are visited in a priority order seeded by the *previous*
//! step's scores (likely roots first): the eventual winner then tends to
//! complete first, the bound tightens immediately, and the remaining
//! candidates prune after a handful of pairs. The serial sweep memoizes
//! each unordered pair so no kernel evaluation is ever repeated; the
//! parallel sweep shares the memo across workers through a lock-free
//! atomic table (ParaLiNGAM's "messaging") and the bound through a
//! single atomic word, with candidates handed to the work-stealing pool
//! in priority order as dynamic tiles.
//!
//! Pruned sweeps report what they did through [`SweepCounters`]
//! (pairs visited / skipped, elements touched), which the
//! [`IncrementalSession`](super::session::IncrementalSession) surfaces
//! via [`OrderingSession::sweep_counters`](super::session::OrderingSession::sweep_counters).
//! The `sweep_pruning` bench records pruned-vs-exact wall-clock and the
//! counters across favorable (chain) and adversarial (tie-heavy,
//! near-Gaussian) panels.
//!
//! # The chunked kernel
//!
//! Underneath both modes, the inner pair kernel is restructured into
//! fixed-width chunked buffers: the two standardized regression
//! residuals are materialized `CHUNK` samples at a time in a tight
//! mul/div loop LLVM can autovectorize, and the transcendental
//! `log_cosh`/`gauss_score` reductions then run over the chunk. Each
//! accumulator still sees its terms in sample order, so the chunked
//! kernel is bitwise-identical to the scalar loop it replaces. With the
//! optional `fastmath` feature an accuracy-bounded polynomial `exp`
//! (relative error ≤ 2e-7, see [`fastmath`]) can be swapped into the
//! transcendental pass — off by default, opt-in per session.

use super::entropy::{entropy_from_moments, gauss_score, log_cosh, order_penalty};
use crate::util::pool::parallel_indexed;
use std::sync::atomic::{AtomicU64, Ordering};

// ---------------------------------------------------------------------
// Strategy + instrumentation surface.
// ---------------------------------------------------------------------

/// How a pair sweep visits the O(d²) candidate/pair space.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum SweepStrategy {
    /// Visit every pair (the measured baseline and the mode every
    /// agreement suite runs): scores are fully computed for every
    /// candidate.
    #[default]
    Exact,
    /// Bound-pruned scheduled sweep: identical root choice and identical
    /// winning score, but dominated candidates stop early and report
    /// only their partial (strictly losing) scores.
    Pruned,
}

/// Instrumentation counters threaded through the ordering sessions:
/// what a sweep actually touched, accumulated across the steps of a fit
/// (reset together with the workspace).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct SweepCounters {
    /// Unordered pairs the exact sweep would evaluate (Σ m(m−1)/2 over
    /// steps).
    pub pairs_total: u64,
    /// Unique pair-kernel evaluations actually performed.
    pub pairs_visited: u64,
    /// Candidate-side comparisons skipped by the bound (a skipped
    /// comparison may still be evaluated later from the other
    /// endpoint's row; `pairs_total − pairs_visited` is the kernel-call
    /// saving, this is ParaLiNGAM's per-candidate saving).
    pub pairs_skipped: u64,
    /// Candidates dropped mid-sweep.
    pub candidates_pruned: u64,
    /// Samples streamed through the pair kernel (`pairs_visited × n`).
    pub elements_touched: u64,
}

impl SweepCounters {
    /// Accumulate another sweep's counters (saturating).
    pub fn merge(&mut self, o: &SweepCounters) {
        self.pairs_total = self.pairs_total.saturating_add(o.pairs_total);
        self.pairs_visited = self.pairs_visited.saturating_add(o.pairs_visited);
        self.pairs_skipped = self.pairs_skipped.saturating_add(o.pairs_skipped);
        self.candidates_pruned = self.candidates_pruned.saturating_add(o.candidates_pruned);
        self.elements_touched = self.elements_touched.saturating_add(o.elements_touched);
    }

    /// Fraction of the exact sweep's kernel evaluations that actually
    /// ran (1.0 when nothing was pruned or nothing was swept).
    pub fn visited_fraction(&self) -> f64 {
        if self.pairs_total == 0 {
            1.0
        } else {
            self.pairs_visited as f64 / self.pairs_total as f64
        }
    }

    /// Book an exact sweep: every pair evaluated, nothing skipped.
    pub(crate) fn record_exact(&mut self, m: usize, n: usize) {
        let pairs = pair_count(m);
        self.pairs_total = self.pairs_total.saturating_add(pairs);
        self.pairs_visited = self.pairs_visited.saturating_add(pairs);
        self.elements_touched =
            self.elements_touched.saturating_add(pairs.saturating_mul(n as u64));
    }
}

/// Unordered pair count m(m−1)/2 as u64 (no overflow for any usize m
/// that can index memory).
fn pair_count(m: usize) -> u64 {
    let m = m as u64;
    if m % 2 == 0 {
        (m / 2).saturating_mul(m.saturating_sub(1))
    } else {
        m.saturating_mul(m.saturating_sub(1) / 2)
    }
}

/// Pair-work heuristic `m(m−1)/2 · n` with saturating arithmetic, so a
/// huge n·d panel can never overflow the pool-cutoff comparison (it
/// saturates to `usize::MAX`, which correctly selects the pooled path).
/// Shares [`pair_count`] so the cutoff heuristic and the counters can
/// never disagree about the same quantity.
pub fn pair_work(m: usize, n: usize) -> usize {
    usize::try_from(pair_count(m)).unwrap_or(usize::MAX).saturating_mul(n)
}

// ---------------------------------------------------------------------
// The chunked fused kernel.
// ---------------------------------------------------------------------

/// Chunk width of the residual buffers: small enough to stay in L1
/// alongside the two source columns, wide enough that the fill loop
/// amortizes across full vector registers.
const CHUNK: usize = 64;

/// The one chunked residual/reduction loop, generic over the
/// transcendental pair so the precise and `fastmath` kernels share it
/// (monomorphized: the function items inline to the same code the
/// hand-specialized loops would be). Returns
/// `(Σ lc(u), Σ gs(u), Σ lc(v), Σ gs(v))` for
/// `u = (ca − r·cb)/denom`, `v = (cb − r·ca)/denom`. Each accumulator
/// sees its terms in sample order, so the result is bitwise-identical to
/// the scalar interleaved loop.
#[inline]
fn pair_moments_with(
    ca: &[f64],
    cb: &[f64],
    r: f64,
    denom: f64,
    lc: impl Fn(f64) -> f64,
    gs: impl Fn(f64) -> f64,
) -> (f64, f64, f64, f64) {
    let n = ca.len();
    let mut u = [0.0f64; CHUNK];
    let mut v = [0.0f64; CHUNK];
    let (mut lc_ab, mut gs_ab, mut lc_ba, mut gs_ba) = (0.0, 0.0, 0.0, 0.0);
    let mut t = 0;
    while t < n {
        let len = CHUNK.min(n - t);
        let (caw, cbw) = (&ca[t..t + len], &cb[t..t + len]);
        // residual fill: pure mul/sub/div, autovectorizable
        for (((uo, vo), &av), &bv) in u.iter_mut().zip(v.iter_mut()).zip(caw).zip(cbw) {
            *uo = (av - r * bv) / denom;
            *vo = (bv - r * av) / denom;
        }
        // transcendental reduction over the chunk
        for &x in &u[..len] {
            lc_ab += lc(x);
            gs_ab += gs(x);
        }
        for &x in &v[..len] {
            lc_ba += lc(x);
            gs_ba += gs(x);
        }
        t += len;
    }
    (lc_ab, gs_ab, lc_ba, gs_ba)
}

/// [`pair_moments_with`] on the precise transcendentals.
#[inline]
fn pair_moments(ca: &[f64], cb: &[f64], r: f64, denom: f64) -> (f64, f64, f64, f64) {
    pair_moments_with(ca, cb, r, denom, log_cosh, gauss_score)
}

/// The shared ρ²-clamped residual denominator (see [`pair_diff`] docs
/// for the degeneracy story behind the clamp and the 1e-12 floor).
#[inline]
pub(crate) fn residual_denom(r: f64) -> f64 {
    (1.0 - (r * r).min(1.0)).sqrt().max(1e-12)
}

/// The fused pair kernel: correlation ρ of two standardized columns, both
/// standardized regression residuals, their entropies via the chunked
/// fused log-cosh / gauss-score pass, and the MI difference for candidate
/// a against b (negate for the b-against-a direction).
///
/// ρ² is clamped to ≤ 1 before the sqrt: collinear or duplicated columns
/// push the float ρ² past 1, and the old `sqrt(1−ρ²).max(1e-150)` then
/// floored the resulting NaN to 1e-150 (`f64::max` ignores NaN) — which
/// blew the standardized residuals up to ~1e150, overflowed the entropy
/// penalty to +∞ and drove every affected score to −∞, tripping the old
/// argmax panic. The clamp plus the saner 1e-12 floor keeps degenerate
/// pairs finite: a huge-but-finite penalty deprioritizes them instead of
/// wiping out the k_list.
pub fn pair_diff(ca: &[f64], cb: &[f64], h_a: f64, h_b: f64) -> f64 {
    let n = ca.len();
    let r = dot(ca, cb) / n as f64;
    pair_diff_with_rho(ca, cb, r, h_a, h_b)
}

/// [`pair_diff`] with the correlation supplied by the caller instead of
/// recomputed with an O(n) dot — the form the incremental
/// [`OrderingSession`](super::session::OrderingSession) runs against its
/// persistent correlation matrix. `pair_diff` delegates here, so the two
/// paths share every numeric detail (including the ρ²-clamp).
pub fn pair_diff_with_rho(ca: &[f64], cb: &[f64], r: f64, h_a: f64, h_b: f64) -> f64 {
    let denom = residual_denom(r);
    let (lc_ab, gs_ab, lc_ba, gs_ba) = pair_moments(ca, cb, r, denom);
    diff_from_moments(ca.len(), h_a, h_b, lc_ab, gs_ab, lc_ba, gs_ba)
}

/// Final reduction shared by the precise and `fastmath` kernels.
#[inline]
fn diff_from_moments(
    n: usize,
    h_a: f64,
    h_b: f64,
    lc_ab: f64,
    gs_ab: f64,
    lc_ba: f64,
    gs_ba: f64,
) -> f64 {
    let inv_n = 1.0 / n as f64;
    let h_rab = entropy_from_moments(lc_ab * inv_n, gs_ab * inv_n);
    let h_rba = entropy_from_moments(lc_ba * inv_n, gs_ba * inv_n);
    super::entropy::diff_mi(h_a, h_b, h_rab, h_rba)
}

/// Shared fused entropy loop, generic over the transcendental pair
/// (precise and `fastmath` instantiations).
#[inline]
fn entropy_with(u: &[f64], lc_f: impl Fn(f64) -> f64, gs_f: impl Fn(f64) -> f64) -> f64 {
    let n = u.len() as f64;
    let (mut lc, mut gs) = (0.0, 0.0);
    for &v in u {
        lc += lc_f(v);
        gs += gs_f(v);
    }
    entropy_from_moments(lc / n, gs / n)
}

/// Fused entropy over an already-standardized column (one log-cosh /
/// gauss-score pass). The one copy of the fused entropy loop in the
/// crate: `entropy::entropy` and the engines' `entropy_fused` re-export
/// both resolve here, next to the chunked pair kernel, so every entropy
/// pass shares code.
pub fn entropy_fused(u: &[f64]) -> f64 {
    entropy_with(u, log_cosh, gauss_score)
}

/// Kernel dispatch used by the session sweeps: the precise kernel, or —
/// when the `fastmath` feature is compiled in *and* the session opted in
/// — the polynomial-exp fast path. Without the feature `fast` is
/// ignored and the precise kernel always runs.
#[inline]
pub(crate) fn pair_diff_with_rho_kernel(
    fast: bool,
    ca: &[f64],
    cb: &[f64],
    r: f64,
    h_a: f64,
    h_b: f64,
) -> f64 {
    #[cfg(feature = "fastmath")]
    if fast {
        return fastmath::pair_diff_with_rho_fast(ca, cb, r, h_a, h_b);
    }
    pair_diff_with_rho(ca, cb, r, h_a, h_b)
}

/// Entropy-kernel dispatch, mirroring [`pair_diff_with_rho_kernel`].
#[inline]
pub(crate) fn entropy_fused_kernel(fast: bool, u: &[f64]) -> f64 {
    #[cfg(feature = "fastmath")]
    if fast {
        return fastmath::entropy_fused_fast(u);
    }
    entropy_fused(u)
}

/// Plain dot product (shared with the session's one-time correlation
/// build so its ρ values are bitwise-identical to the stateless path's).
#[inline]
pub(crate) fn dot(a: &[f64], b: &[f64]) -> f64 {
    a.iter().zip(b).map(|(&x, &y)| x * y).sum()
}

// ---------------------------------------------------------------------
// Exact sweeps (the flat loops, now living next to their pruned
// replacements).
// ---------------------------------------------------------------------

/// Serial upper-triangle accumulation of an antisymmetric pair statistic
/// `diff(a, b)` over positions `0..m`: each unordered pair is computed
/// once and contributes to both i=a and i=b (the GPU kernel computes
/// ordered pairs redundantly; same numbers either way). The one serial
/// copy of the `order_penalty` bookkeeping, and the accumulation order
/// the pruned sweep reproduces per candidate.
pub fn accumulate_pair_diffs<F: Fn(usize, usize) -> f64>(m: usize, diff: F) -> Vec<f64> {
    let mut k = vec![0.0; m];
    for a in 0..m {
        for b in (a + 1)..m {
            // candidate i=a against j=b; i=b against j=a is the
            // antisymmetric direction of the same pair
            let diff_a = diff(a, b);
            k[a] += order_penalty(diff_a);
            k[b] += order_penalty(-diff_a);
        }
    }
    k
}

/// One row of the pair triangle: the candidate's own accumulated penalty
/// plus its antisymmetric contributions to every later candidate.
struct RowContrib {
    /// Σ_{b>a} penalty(diff(a, b)) — row a's own k-accumulator.
    own: f64,
    /// penalty(−diff(a, b)) for b = a+1..m (contribution to k[b]).
    cross: Vec<f64>,
}

/// Tile the upper-triangle pair loop across the worker pool: `diff(a, b)`
/// is the antisymmetric pair statistic over positions `0..m`. Each pool
/// task is one whole *row* (candidate `a` against every `b > a`);
/// [`parallel_indexed`] returns the rows in index order, so the merge
/// below — and therefore the final sum — is deterministic regardless of
/// which worker processed which row. Shared between the stateless
/// parallel engine path and the incremental session's sweep over the
/// shared workspace cache (where `diff` reads the persistent correlation
/// matrix instead of re-doing the dot).
pub fn tiled_pair_sweep<F>(m: usize, workers: usize, diff: F) -> Vec<f64>
where
    F: Fn(usize, usize) -> f64 + Sync,
{
    // the last row has no b > a pairs, so m−1 workers suffice (and an
    // empty or single-element sweep degrades to one no-op worker)
    let rows = parallel_indexed(m, workers.clamp(1, m.saturating_sub(1).max(1)), |a| {
        let mut own = 0.0;
        let mut cross = vec![0.0; m - a - 1];
        for b in (a + 1)..m {
            let diff_a = diff(a, b);
            own += order_penalty(diff_a);
            cross[b - a - 1] = order_penalty(-diff_a);
        }
        RowContrib { own, cross }
    });
    let mut k = vec![0.0; m];
    for (a, row) in rows.into_iter().enumerate() {
        k[a] += row.own;
        for (off, v) in row.cross.into_iter().enumerate() {
            k[a + 1 + off] += v;
        }
    }
    k
}

// ---------------------------------------------------------------------
// Bound-pruned scheduled sweeps.
// ---------------------------------------------------------------------

/// Candidate visit order: descending priority (previous-step scores —
/// likely roots first), ties and the no-priority case falling back to
/// ascending index. NaN priorities sort via the IEEE total order, which
/// only affects scheduling, never correctness.
fn candidate_order(m: usize, priority: Option<&[f64]>) -> Vec<usize> {
    let mut order: Vec<usize> = (0..m).collect();
    if let Some(p) = priority {
        if p.len() == m {
            order.sort_by(|&x, &y| p[y].total_cmp(&p[x]).then(x.cmp(&y)));
        }
    }
    order
}

/// Oriented comparisons remaining for candidate `i` after pair `j` was
/// just processed (used to book skipped comparisons at prune time).
#[inline]
fn remaining_after(m: usize, i: usize, j: usize) -> u64 {
    let rest = (m - 1 - j) as u64;
    if i > j {
        rest - 1
    } else {
        rest
    }
}

/// Serial bound-pruned sweep (see module docs for the exactness
/// argument). `diff(a, b)` must be evaluated with `a < b`; the sweep
/// memoizes each unordered pair so no kernel evaluation is repeated,
/// which makes its kernel-call count ≤ the exact sweep's even before any
/// pruning. `elems_per_pair` is the sample count a single kernel call
/// streams (for the `elements_touched` counter).
///
/// Returns the per-candidate penalty vector `k` (negate for scores):
/// completed candidates carry the bitwise-exact total, pruned candidates
/// their partial running penalty, which is strictly above the winning
/// total — the argmax over `−k` is identical to the exact sweep's.
pub fn pruned_sweep<F>(
    m: usize,
    diff: &F,
    priority: Option<&[f64]>,
    elems_per_pair: usize,
    counters: &mut SweepCounters,
) -> Vec<f64>
where
    F: Fn(usize, usize) -> f64,
{
    counters.pairs_total = counters.pairs_total.saturating_add(pair_count(m));
    let mut k = vec![0.0; m];
    if m < 2 {
        return k;
    }
    let order = candidate_order(m, priority);
    let mut memo = vec![0.0f64; m * m];
    let mut have = vec![false; m * m];
    let mut bound = f64::INFINITY;
    let mut visited: u64 = 0;
    for &i in &order {
        let mut running = 0.0f64;
        let mut pruned = false;
        for j in 0..m {
            if j == i {
                continue;
            }
            let (a, b) = if i < j { (i, j) } else { (j, i) };
            let p = a * m + b;
            let d_ab = if have[p] {
                memo[p]
            } else {
                let v = diff(a, b);
                memo[p] = v;
                have[p] = true;
                visited += 1;
                v
            };
            let oriented = if i < j { d_ab } else { -d_ab };
            running += order_penalty(oriented);
            // strict: exact ties keep sweeping and complete exactly
            if running > bound {
                pruned = true;
                counters.pairs_skipped =
                    counters.pairs_skipped.saturating_add(remaining_after(m, i, j));
                counters.candidates_pruned += 1;
                break;
            }
        }
        k[i] = running;
        // NaN totals never tighten the bound (comparison is false)
        if !pruned && running < bound {
            bound = running;
        }
    }
    counters.pairs_visited = counters.pairs_visited.saturating_add(visited);
    counters.elements_touched =
        counters.elements_touched.saturating_add(visited.saturating_mul(elems_per_pair as u64));
    k
}

/// Sentinel for "pair not yet computed" in the shared memo: a negative
/// all-ones NaN bit pattern no IEEE arithmetic result ever carries
/// (hardware produces the canonical quiet NaN). A false positive would
/// only cost a redundant recompute of the same deterministic value.
const MEMO_EMPTY: u64 = u64::MAX;

/// Parallel bound-pruned sweep: candidates are handed to the
/// work-stealing pool in priority order (one candidate per dynamic
/// tile), the bound lives in one shared atomic word that only ever
/// decreases, and computed pair diffs are published through a lock-free
/// atomic memo so another worker's row reuses them instead of
/// re-evaluating (the messaging that keeps total kernel calls ≤ the
/// exact sweep's up to rare benign races).
///
/// The *choice* is deterministic and identical to the exact sweep's —
/// completed candidates carry bitwise-exact totals and pruned ones sit
/// strictly below the winner (module docs) — but *which* losing
/// candidates get pruned, and therefore their reported partial scores
/// and the counters, may vary run to run with thread timing.
pub fn pruned_sweep_parallel<F>(
    m: usize,
    workers: usize,
    diff: &F,
    priority: Option<&[f64]>,
    elems_per_pair: usize,
    counters: &mut SweepCounters,
) -> Vec<f64>
where
    F: Fn(usize, usize) -> f64 + Sync,
{
    counters.pairs_total = counters.pairs_total.saturating_add(pair_count(m));
    let mut k = vec![0.0; m];
    if m < 2 {
        return k;
    }
    let order = candidate_order(m, priority);
    let memo: Vec<AtomicU64> = (0..m * m).map(|_| AtomicU64::new(MEMO_EMPTY)).collect();
    let bound = AtomicU64::new(f64::INFINITY.to_bits());
    let visited = AtomicU64::new(0);
    let rows = parallel_indexed(m, workers.clamp(1, m), |t| {
        let i = order[t];
        let mut running = 0.0f64;
        let mut skipped: u64 = 0;
        let mut pruned = false;
        for j in 0..m {
            if j == i {
                continue;
            }
            let (a, b) = if i < j { (i, j) } else { (j, i) };
            let p = a * m + b;
            let bits = memo[p].load(Ordering::Relaxed);
            let d_ab = if bits != MEMO_EMPTY {
                f64::from_bits(bits)
            } else {
                let v = diff(a, b);
                // count only the winning publish: two workers racing on
                // the same fresh pair both do the work (same
                // deterministic value), but `pairs_visited` keeps its
                // documented "unique evaluations" meaning and can never
                // exceed pairs_total
                if memo[p]
                    .compare_exchange(
                        MEMO_EMPTY,
                        v.to_bits(),
                        Ordering::Relaxed,
                        Ordering::Relaxed,
                    )
                    .is_ok()
                {
                    visited.fetch_add(1, Ordering::Relaxed);
                }
                v
            };
            let oriented = if i < j { d_ab } else { -d_ab };
            running += order_penalty(oriented);
            // a stale bound is always ≥ the current one, so pruning on
            // it is still exact — one relaxed load per pair keeps it
            // fresh at negligible cost next to the O(n) kernel
            if running > f64::from_bits(bound.load(Ordering::Relaxed)) {
                pruned = true;
                skipped = remaining_after(m, i, j);
                break;
            }
        }
        if !pruned {
            // lock-free fetch-min: penalties are ≥ 0 (or NaN, which
            // never passes the `<` and is correctly ignored)
            let mut cur = bound.load(Ordering::Relaxed);
            while running < f64::from_bits(cur) {
                match bound.compare_exchange_weak(
                    cur,
                    running.to_bits(),
                    Ordering::Relaxed,
                    Ordering::Relaxed,
                ) {
                    Ok(_) => break,
                    Err(c) => cur = c,
                }
            }
        }
        (i, running, pruned, skipped)
    });
    for (i, running, pruned, skipped) in rows {
        k[i] = running;
        if pruned {
            counters.candidates_pruned += 1;
            counters.pairs_skipped = counters.pairs_skipped.saturating_add(skipped);
        }
    }
    let visited = visited.load(Ordering::Relaxed);
    counters.pairs_visited = counters.pairs_visited.saturating_add(visited);
    counters.elements_touched =
        counters.elements_touched.saturating_add(visited.saturating_mul(elems_per_pair as u64));
    k
}

// ---------------------------------------------------------------------
// fastmath: accuracy-bounded polynomial exp fast path.
// ---------------------------------------------------------------------

/// Accuracy-bounded fast transcendentals, compiled only with the
/// `fastmath` feature and opted into per session
/// ([`IncrementalSession::with_fast_kernel`](super::session::IncrementalSession::with_fast_kernel)) —
/// never silently swapped into a default build, because the agreement
/// suites pin the precise kernel bitwise.
///
/// [`fast_exp`](fastmath::fast_exp) does standard range reduction
/// `x = k·ln2 + r` with `|r| ≤ ln2/2` and a degree-6 Taylor polynomial,
/// giving relative error ≤ 2e-7 (truncation `r⁷/5040 ≈ 1.2e-7` plus
/// rounding) — comfortably inside the ~1e-5 score tolerance the
/// engine-agreement suites run at, but **not** bitwise, hence the
/// opt-in.
#[cfg(feature = "fastmath")]
pub mod fastmath {
    use super::{diff_from_moments, residual_denom};

    /// Polynomial `exp` with relative error ≤ 2e-7 on the normal range.
    /// Inputs below −708 flush to 0 (the true value is ≤ 3.3e-308, at
    /// the subnormal boundary — an absolute error far below any moment
    /// this kernel accumulates); above +709 it returns ∞; NaN
    /// propagates.
    #[inline]
    pub fn fast_exp(x: f64) -> f64 {
        if x < -708.0 {
            return 0.0;
        }
        if x > 709.0 {
            return f64::INFINITY;
        }
        const LN_2_HI: f64 = 6.93147180369123816490e-01;
        const LN_2_LO: f64 = 1.90821492927058770002e-10;
        let k = (x * std::f64::consts::LOG2_E).round();
        let r = (x - k * LN_2_HI) - k * LN_2_LO;
        // degree-6 Taylor on |r| ≤ ln2/2 (Horner)
        let p = 1.0
            + r * (1.0
                + r * (0.5
                    + r * (1.0 / 6.0
                        + r * (1.0 / 24.0 + r * (1.0 / 120.0 + r * (1.0 / 720.0))))));
        // 2^k via the exponent field: k ∈ [−1021, 1023] after the clamps
        let scale = f64::from_bits(((1023 + k as i64) as u64) << 52);
        p * scale
    }

    /// [`log_cosh`](super::super::entropy::log_cosh) with [`fast_exp`].
    #[inline]
    pub fn log_cosh_fast(u: f64) -> f64 {
        let a = u.abs();
        a + fast_exp(-2.0 * a).ln_1p() - std::f64::consts::LN_2
    }

    /// [`gauss_score`](super::super::entropy::gauss_score) with
    /// [`fast_exp`].
    #[inline]
    pub fn gauss_score_fast(u: f64) -> f64 {
        u * fast_exp(-0.5 * u * u)
    }

    /// [`entropy_fused`](super::entropy_fused) on the fast
    /// transcendentals (the same shared loop, instantiated with
    /// [`log_cosh_fast`]/[`gauss_score_fast`]).
    pub fn entropy_fused_fast(u: &[f64]) -> f64 {
        super::entropy_with(u, log_cosh_fast, gauss_score_fast)
    }

    /// [`pair_diff_with_rho`](super::pair_diff_with_rho) with the fast
    /// transcendental pass — the identical chunked loop
    /// ([`pair_moments_with`](super::pair_moments_with) is generic over
    /// the transcendental pair, so there is exactly one copy to keep
    /// correct), same ρ²-clamp.
    pub fn pair_diff_with_rho_fast(ca: &[f64], cb: &[f64], r: f64, h_a: f64, h_b: f64) -> f64 {
        let denom = residual_denom(r);
        let (lc_ab, gs_ab, lc_ba, gs_ba) =
            super::pair_moments_with(ca, cb, r, denom, log_cosh_fast, gauss_score_fast);
        diff_from_moments(ca.len(), h_a, h_b, lc_ab, gs_ab, lc_ba, gs_ba)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lingam::engine::{argmax_active, scatter_scores};
    use crate::util::rng::Pcg64;

    /// Synthetic antisymmetric pair statistic backed by a dense matrix.
    fn random_diff_matrix(m: usize, seed: u64) -> Vec<f64> {
        let mut rng = Pcg64::seed_from_u64(seed);
        let mut d = vec![0.0; m * m];
        for a in 0..m {
            for b in (a + 1)..m {
                let v = rng.normal();
                d[a * m + b] = v;
                d[b * m + a] = -v;
            }
        }
        d
    }

    fn winner(k: &[f64]) -> usize {
        let idx: Vec<usize> = (0..k.len()).collect();
        let scores = scatter_scores(k.len(), &idx, k);
        let active = vec![true; k.len()];
        argmax_active(&scores, &active).unwrap()
    }

    #[test]
    fn pruned_matches_exact_winner_and_winning_total() {
        for seed in 0..20 {
            let m = 3 + (seed as usize % 10);
            let d = random_diff_matrix(m, seed);
            let diff = |a: usize, b: usize| d[a * m + b];
            let exact = accumulate_pair_diffs(m, diff);
            let mut c = SweepCounters::default();
            let pruned = pruned_sweep(m, &diff, None, 100, &mut c);
            let (we, wp) = (winner(&exact), winner(&pruned));
            assert_eq!(we, wp, "seed {seed}: winners diverged");
            assert_eq!(exact[we], pruned[wp], "seed {seed}: winning total not bitwise-equal");
            // partial penalties are prefixes of the exact accumulation:
            // never above the exact total, and the winner's is exact
            for i in 0..m {
                assert!(
                    pruned[i] <= exact[i],
                    "seed {seed} cand {i}: partial {} > exact {}",
                    pruned[i],
                    exact[i]
                );
            }
            assert!(c.pairs_visited <= c.pairs_total);
        }
    }

    #[test]
    fn pruned_priority_order_does_not_change_the_choice() {
        let m = 9;
        let d = random_diff_matrix(m, 42);
        let diff = |a: usize, b: usize| d[a * m + b];
        let exact = accumulate_pair_diffs(m, diff);
        let w = winner(&exact);
        // adversarial priority: visit the true winner last
        let mut prio = vec![0.0f64; m];
        prio[w] = f64::NEG_INFINITY;
        let mut c = SweepCounters::default();
        let pruned = pruned_sweep(m, &diff, Some(&prio), 10, &mut c);
        assert_eq!(winner(&pruned), w);
        assert_eq!(pruned[w], exact[w]);
    }

    #[test]
    fn parallel_pruned_matches_serial_choice_across_workers_and_runs() {
        let m = 12;
        let d = random_diff_matrix(m, 7);
        let diff = |a: usize, b: usize| d[a * m + b];
        let exact = accumulate_pair_diffs(m, diff);
        let w = winner(&exact);
        for workers in [1usize, 2, 3, 8] {
            for _ in 0..3 {
                let mut c = SweepCounters::default();
                let k = pruned_sweep_parallel(m, workers, &diff, None, 10, &mut c);
                assert_eq!(winner(&k), w, "workers={workers}");
                assert_eq!(k[w], exact[w], "workers={workers}: winning total drifted");
                // CAS-counted publishes: unique evaluations only, even
                // when two workers race on the same fresh pair
                assert!(c.pairs_visited <= c.pairs_total, "visited exceeded total");
                assert!(c.visited_fraction() <= 1.0);
            }
        }
    }

    #[test]
    fn pruned_counters_report_skips_on_separated_candidates() {
        // one dominant candidate (all diffs in its favor) and many
        // heavily-penalized ones: everything but the winner should prune
        let m = 16;
        let diff = |a: usize, b: usize| {
            if a == 0 {
                2.0 // candidate 0 always looks exogenous
            } else if (a + b) % 2 == 0 {
                1.5 // strong mutual evidence against both others
            } else {
                -1.5
            }
        };
        let mut c = SweepCounters::default();
        let k = pruned_sweep(m, &diff, None, 50, &mut c);
        assert_eq!(winner(&k), 0);
        assert!(c.candidates_pruned > 0, "no candidate pruned: {c:?}");
        assert!(c.pairs_skipped > 0, "no pair skipped: {c:?}");
        assert!(c.pairs_visited < c.pairs_total, "no kernel call saved: {c:?}");
        assert_eq!(c.elements_touched, c.pairs_visited * 50);
    }

    #[test]
    fn exact_mode_counters_visit_everything() {
        let mut c = SweepCounters::default();
        c.record_exact(10, 100);
        assert_eq!(c.pairs_total, 45);
        assert_eq!(c.pairs_visited, 45);
        assert_eq!(c.pairs_skipped, 0);
        assert_eq!(c.elements_touched, 4500);
        assert!((c.visited_fraction() - 1.0).abs() < 1e-15);
    }

    #[test]
    fn counters_merge_saturates() {
        let mut a = SweepCounters {
            pairs_total: u64::MAX - 1,
            pairs_visited: 1,
            pairs_skipped: 0,
            candidates_pruned: 0,
            elements_touched: u64::MAX,
        };
        let b = a;
        a.merge(&b);
        assert_eq!(a.pairs_total, u64::MAX);
        assert_eq!(a.elements_touched, u64::MAX);
    }

    #[test]
    fn pair_work_saturates_instead_of_overflowing() {
        assert_eq!(pair_work(4, 10), 60);
        assert_eq!(pair_work(5, 10), 100);
        assert_eq!(pair_work(0, 10), 0);
        assert_eq!(pair_work(1, 10), 0);
        // the overflow case the cutoff heuristic must survive: saturates
        // high (which selects the pooled path) rather than wrapping low
        assert_eq!(pair_work(usize::MAX, usize::MAX), usize::MAX);
        assert_eq!(pair_work(1 << 33, 1 << 33), usize::MAX);
    }

    #[test]
    fn candidate_order_sorts_descending_with_index_ties() {
        assert_eq!(candidate_order(4, None), vec![0, 1, 2, 3]);
        let p = [1.0, 3.0, 3.0, -1.0];
        assert_eq!(candidate_order(4, Some(&p)), vec![1, 2, 0, 3]);
        // NaN priorities must not panic (total order)
        let pn = [f64::NAN, 1.0, f64::NEG_INFINITY];
        let o = candidate_order(3, Some(&pn));
        assert_eq!(o.len(), 3);
    }

    #[test]
    fn chunked_kernel_is_bitwise_identical_to_scalar_loop() {
        let mut rng = Pcg64::seed_from_u64(3);
        for &n in &[1usize, 5, 63, 64, 65, 257, 1000] {
            let ca: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let cb: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let r = 0.3;
            let denom = residual_denom(r);
            // scalar reference: the pre-chunking interleaved loop
            let (mut lc_ab, mut gs_ab, mut lc_ba, mut gs_ba) = (0.0, 0.0, 0.0, 0.0);
            for t in 0..n {
                let u = (ca[t] - r * cb[t]) / denom;
                let v = (cb[t] - r * ca[t]) / denom;
                lc_ab += log_cosh(u);
                gs_ab += gauss_score(u);
                lc_ba += log_cosh(v);
                gs_ba += gauss_score(v);
            }
            let got = pair_moments(&ca, &cb, r, denom);
            assert_eq!(got, (lc_ab, gs_ab, lc_ba, gs_ba), "n={n}");
        }
    }

    #[test]
    fn nan_diffs_never_prune_or_tighten() {
        // a NaN-poisoned pair statistic: every candidate completes (no
        // bound exists), exactly like the exact sweep
        let m = 5;
        let diff = |_a: usize, _b: usize| f64::NAN;
        let mut c = SweepCounters::default();
        let k = pruned_sweep(m, &diff, None, 10, &mut c);
        assert!(k.iter().all(|v| v.is_nan()));
        assert_eq!(c.candidates_pruned, 0);
        assert_eq!(c.pairs_visited, c.pairs_total);
    }

    #[cfg(feature = "fastmath")]
    mod fast {
        use super::super::fastmath::*;
        use super::super::{entropy_fused, pair_diff_with_rho};
        use crate::util::rng::Pcg64;

        #[test]
        fn fast_exp_relative_error_within_bound() {
            let mut worst: f64 = 0.0;
            let mut x = -700.0;
            while x <= 5.0 {
                let (f, e) = (fast_exp(x), x.exp());
                if e > 0.0 {
                    worst = worst.max(((f - e) / e).abs());
                }
                x += 0.0137;
            }
            assert!(worst < 5e-7, "fast_exp worst relative error {worst}");
            assert_eq!(fast_exp(-1000.0), 0.0);
            assert_eq!(fast_exp(800.0), f64::INFINITY);
            assert!(fast_exp(f64::NAN).is_nan());
        }

        #[test]
        fn fast_kernels_track_precise_kernels() {
            let mut rng = Pcg64::seed_from_u64(9);
            let n = 4_000;
            let mut ca: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
            let mut cb: Vec<f64> = ca.iter().map(|&v| 0.6 * v + rng.normal()).collect();
            crate::stats::standardize(&mut ca);
            crate::stats::standardize(&mut cb);
            let (ha, hb) = (entropy_fused(&ca), entropy_fused(&cb));
            let (ha_f, hb_f) = (entropy_fused_fast(&ca), entropy_fused_fast(&cb));
            assert!((ha - ha_f).abs() < 1e-5, "entropy drift {} vs {}", ha, ha_f);
            assert!((hb - hb_f).abs() < 1e-5);
            let r = super::super::dot(&ca, &cb) / n as f64;
            let precise = pair_diff_with_rho(&ca, &cb, r, ha, hb);
            let fast = pair_diff_with_rho_fast(&ca, &cb, r, ha_f, hb_f);
            assert!(
                (precise - fast).abs() < 1e-4,
                "pair diff drift: precise {precise} fast {fast}"
            );
        }
    }
}
