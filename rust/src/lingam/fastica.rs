//! FastICA (Hyvärinen 1999) with the log-cosh contrast and symmetric
//! decorrelation — the estimation core of ICA-LiNGAM (Shimizu et al.
//! 2006), the original LiNGAM algorithm the paper's §2.2 describes.

use crate::linalg::{eigh::whitening_matrix, Mat};
use crate::stats;
use crate::util::rng::Pcg64;
use crate::util::{Error, Result};

/// FastICA options.
#[derive(Clone, Debug)]
pub struct FastIcaOpts {
    pub max_iter: usize,
    pub tol: f64,
    pub seed: u64,
}

impl Default for FastIcaOpts {
    fn default() -> Self {
        FastIcaOpts { max_iter: 400, tol: 1e-6, seed: 0 }
    }
}

/// Result: the unmixing matrix in the *original* (unwhitened) space:
/// `S = W X_centeredᵀ` recovers the sources.
pub struct FastIcaFit {
    /// Unmixing matrix `[d, d]`.
    pub w: Mat,
    pub iterations: usize,
    pub converged: bool,
}

/// Run FastICA on a data panel `[n, d]` (full-rank, d components).
pub fn fastica(x: &Mat, opts: &FastIcaOpts) -> Result<FastIcaFit> {
    let (n, d) = (x.rows(), x.cols());
    if n < d * 4 {
        return Err(Error::InvalidArgument(format!("need n ≫ d, got {n} × {d}")));
    }
    // center
    let mut xc = x.clone();
    for c in 0..d {
        let m = stats::mean(&x.col(c));
        for r in 0..n {
            xc[(r, c)] -= m;
        }
    }
    // whiten: Z = Xc Kᵀ with K Σ Kᵀ = I
    let cov = xc.t().matmul(&xc).scale(1.0 / n as f64);
    let k = whitening_matrix(&cov, 1e-10)?;
    if k.rows() != d {
        return Err(Error::Numerical(format!(
            "rank-deficient data: {} of {d} components",
            k.rows()
        )));
    }
    let z = xc.matmul(&k.t()); // [n, d]

    // symmetric FastICA on whitened data
    let mut rng = Pcg64::seed_from_u64(opts.seed);
    let mut w = Mat::from_fn(d, d, |_, _| rng.normal());
    w = sym_decorrelate(&w)?;
    let mut converged = false;
    let mut it = 0;
    while it < opts.max_iter {
        it += 1;
        // g = tanh(w z), g' = 1 - tanh²
        let wz = z.matmul(&w.t()); // [n, d] projections
        let g = wz.map(|v| v.tanh());
        let g_prime_mean: Vec<f64> = (0..d)
            .map(|c| {
                (0..n).map(|r| 1.0 - g[(r, c)] * g[(r, c)]).sum::<f64>() / n as f64
            })
            .collect();
        // w_new_i = E[z g(w_i z)] − E[g'] w_i
        let ezg = g.t().matmul(&z).scale(1.0 / n as f64); // [d, d]
        let mut w_new = Mat::zeros(d, d);
        for i in 0..d {
            for j in 0..d {
                w_new[(i, j)] = ezg[(i, j)] - g_prime_mean[i] * w[(i, j)];
            }
        }
        let w_new = sym_decorrelate(&w_new)?;
        // convergence: |diag(W_new Wᵀ)| → 1
        let delta = (0..d)
            .map(|i| {
                let dot: f64 = (0..d).map(|j| w_new[(i, j)] * w[(i, j)]).sum();
                (dot.abs() - 1.0).abs()
            })
            .fold(0.0, f64::max);
        w = w_new;
        if delta < opts.tol {
            converged = true;
            break;
        }
    }
    // back to original space: W_full = W K
    Ok(FastIcaFit { w: w.matmul(&k), iterations: it, converged })
}

/// Symmetric decorrelation: W ← (W Wᵀ)^{-1/2} W via the eigensystem.
fn sym_decorrelate(w: &Mat) -> Result<Mat> {
    let wwt = w.matmul(&w.t());
    let (evals, v) = crate::linalg::eigh::eigh(&wwt)?;
    let d = w.rows();
    let inv_sqrt = Mat::from_fn(d, d, |r, c| {
        if r == c {
            1.0 / evals[r].max(1e-30).sqrt()
        } else {
            0.0
        }
    });
    Ok(v.matmul(&inv_sqrt).matmul(&v.t()).matmul(w))
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Mix independent non-Gaussian sources and check recovery up to
    /// permutation/scale (the ICA identifiability class).
    #[test]
    fn separates_two_uniform_sources() {
        let mut rng = Pcg64::seed_from_u64(1);
        let n = 8_000;
        let s = Mat::from_fn(n, 2, |_, _| rng.f64() - 0.5);
        let mixing = Mat::from_rows(&[&[2.0, 1.0], &[1.0, 1.5]]);
        let x = s.matmul(&mixing.t());
        let fit = fastica(&x, &FastIcaOpts::default()).unwrap();
        assert!(fit.converged, "no convergence in {} iters", fit.iterations);
        // W · A should be a scaled permutation: each row has exactly one
        // dominant entry
        let wa = fit.w.matmul(&mixing);
        for i in 0..2 {
            let row: Vec<f64> = (0..2).map(|j| wa[(i, j)].abs()).collect();
            let (mx, mn) = (row[0].max(row[1]), row[0].min(row[1]));
            assert!(mx > 5.0 * mn, "row {i} not dominated: {row:?}");
        }
    }

    #[test]
    fn recovered_sources_are_uncorrelated() {
        let mut rng = Pcg64::seed_from_u64(2);
        let n = 6_000;
        let s = Mat::from_fn(n, 3, |_, c| match c {
            0 => rng.f64() - 0.5,
            1 => rng.laplace(1.0),
            _ => rng.exponential(1.0) - 1.0,
        });
        let mixing = Mat::from_fn(3, 3, |r, c| if r == c { 1.0 } else { 0.4 + 0.1 * r as f64 });
        let x = s.matmul(&mixing.t());
        let fit = fastica(&x, &FastIcaOpts::default()).unwrap();
        // recovered sources: S_hat = Xc Wᵀ
        let mut xc = x.clone();
        for c in 0..3 {
            let m = stats::mean(&x.col(c));
            for r in 0..n {
                xc[(r, c)] -= m;
            }
        }
        let s_hat = xc.matmul(&fit.w.t());
        for a in 0..3 {
            for b in (a + 1)..3 {
                let rho = stats::cov(&s_hat.col(a), &s_hat.col(b))
                    / (stats::std(&s_hat.col(a)) * stats::std(&s_hat.col(b)));
                assert!(rho.abs() < 0.05, "components {a},{b} correlated: {rho}");
            }
        }
    }

    #[test]
    fn rejects_underdetermined() {
        let x = Mat::zeros(10, 5);
        assert!(fastica(&x, &FastIcaOpts::default()).is_err());
    }
}
