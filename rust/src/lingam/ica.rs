//! ICA-LiNGAM (Shimizu et al. 2006) — the original LiNGAM estimator the
//! paper's §2.2 presents, implemented as a baseline/extension:
//!
//! 1. FastICA unmixing `W` of the data,
//! 2. row permutation of `W` minimizing Σ 1/|W_ii| (Hungarian) so the
//!    diagonal is nonzero,
//! 3. scale rows to unit diagonal; `B̂ = I − W'`,
//! 4. find the causal order as the permutation making B̂ closest to
//!    strictly lower-triangular, then prune with the same adjacency
//!    estimation DirectLiNGAM uses.
//!
//! DirectLiNGAM supersedes this method (no local optima, convergence
//! guarantee) — having both lets the test suite cross-validate two
//! independent estimators of the same model class.

use super::fastica::{fastica, FastIcaOpts};
use super::prune::{estimate_adjacency, PruneMethod};
use crate::linalg::{assignment::hungarian, Mat};
use crate::util::{Error, Result};

/// ICA-LiNGAM configuration.
#[derive(Clone, Debug, Default)]
pub struct IcaLingam {
    pub ica: FastIcaOpts,
    pub prune: PruneMethod,
}

/// Fitted ICA-LiNGAM model.
#[derive(Clone, Debug)]
pub struct IcaLingamFit {
    /// Estimated causal order (causes first).
    pub order: Vec<usize>,
    /// Pruned weighted adjacency (same convention as DirectLiNGAM).
    pub adjacency: Mat,
    /// Raw (unpruned) B̂ = I − W' from the ICA step.
    pub b_raw: Mat,
}

impl IcaLingam {
    pub fn new() -> Self {
        Self::default()
    }

    /// Fit on a data panel `[n, d]`.
    pub fn fit(&self, data: &Mat) -> Result<IcaLingamFit> {
        let d = data.cols();
        if d < 2 {
            return Err(Error::InvalidArgument("need ≥ 2 variables".into()));
        }
        let ica = fastica(data, &self.ica)?;
        let w = ica.w;

        // 2) permute rows so the diagonal carries the dominant entries:
        // minimize Σ 1/|W_{perm(i), i}|
        let big = 1e12;
        let cost = Mat::from_fn(d, d, |r, c| {
            let v = w[(r, c)].abs();
            if v < 1e-12 {
                big
            } else {
                1.0 / v
            }
        });
        let perm = hungarian(&cost); // perm[row] = col the row should own
        // build W' with row r placed at position perm[r]
        let mut w_p = Mat::zeros(d, d);
        for r in 0..d {
            for c in 0..d {
                w_p[(perm[r], c)] = w[(r, c)];
            }
        }

        // 3) unit diagonal, B = I − W'
        for i in 0..d {
            let diag = w_p[(i, i)];
            if diag.abs() < 1e-12 {
                return Err(Error::Numerical("zero diagonal after permutation".into()));
            }
            for j in 0..d {
                w_p[(i, j)] /= diag;
            }
        }
        let b_raw = Mat::eye(d).sub(&w_p);

        // 4) causal order: permutation P minimizing the mass above the
        // diagonal of P B Pᵀ (exhaustive for small d, greedy otherwise —
        // the reference package does the same style of search)
        let order = best_causal_order(&b_raw);

        let adjacency = estimate_adjacency(data, &order, self.prune)?;
        Ok(IcaLingamFit { order, adjacency, b_raw })
    }
}

/// Find the order minimizing the squared mass above the diagonal.
fn best_causal_order(b: &Mat) -> Vec<usize> {
    let d = b.rows();
    if d <= 8 {
        // exhaustive
        let mut best: (f64, Vec<usize>) = (f64::INFINITY, (0..d).collect());
        let mut perm: Vec<usize> = (0..d).collect();
        permute_visit(&mut perm, 0, &mut |p| {
            let m = upper_mass(b, p);
            if m < best.0 {
                best = (m, p.to_vec());
            }
        });
        best.1
    } else {
        // greedy: repeatedly pick the variable with least dependence on
        // the remaining ones (smallest row mass over remaining columns)
        let mut remaining: Vec<usize> = (0..d).collect();
        let mut order = Vec::with_capacity(d);
        while !remaining.is_empty() {
            let (pos, _) = remaining
                .iter()
                .enumerate()
                .map(|(pos, &i)| {
                    let mass: f64 = remaining
                        .iter()
                        .filter(|&&j| j != i)
                        .map(|&j| b[(i, j)] * b[(i, j)])
                        .sum();
                    (pos, mass)
                })
                .min_by(|a, b| a.1.partial_cmp(&b.1).unwrap())
                .unwrap();
            order.push(remaining.remove(pos));
        }
        order
    }
}

/// Squared mass of entries inconsistent with the order (effects before
/// causes).
fn upper_mass(b: &Mat, order: &[usize]) -> f64 {
    let mut pos = vec![0usize; order.len()];
    for (p, &v) in order.iter().enumerate() {
        pos[v] = p;
    }
    let mut m = 0.0;
    for i in 0..b.rows() {
        for j in 0..b.cols() {
            if i != j && pos[j] > pos[i] {
                m += b[(i, j)] * b[(i, j)];
            }
        }
    }
    m
}

fn permute_visit(xs: &mut Vec<usize>, k: usize, f: &mut impl FnMut(&[usize])) {
    if k == xs.len() {
        f(xs);
        return;
    }
    for i in k..xs.len() {
        xs.swap(k, i);
        permute_visit(xs, k + 1, f);
        xs.swap(k, i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph;
    use crate::sim::{simulate_sem, SemSpec};
    use crate::util::rng::Pcg64;

    #[test]
    fn recovers_chain_order() {
        let mut rng = Pcg64::seed_from_u64(1);
        let mut adj = Mat::zeros(3, 3);
        adj[(1, 0)] = 1.4;
        adj[(2, 1)] = -1.1;
        let dag = graph::Dag::new(adj.clone()).unwrap();
        let x = crate::sim::sample_from_dag(&dag, crate::sim::Noise::Uniform01, 12_000, &mut rng);
        let fit = IcaLingam::new().fit(&x).unwrap();
        assert!(graph::order_consistent(&adj, &fit.order), "order {:?}", fit.order);
        let m = crate::metrics::graph_metrics(&adj, &fit.adjacency, 0.1);
        assert!(m.f1 > 0.9, "f1 {}", m.f1);
    }

    #[test]
    fn agrees_with_direct_lingam_on_easy_data() {
        // two independent estimators of the same identifiable model
        // should find the same structure on well-separated data
        let mut rng = Pcg64::seed_from_u64(2);
        let ds = simulate_sem(&SemSpec::layered(5, 2, 0.7), 12_000, &mut rng);
        let ica_fit = IcaLingam::new().fit(&ds.data).unwrap();
        let direct = super::super::DirectLingam::new()
            .fit(&ds.data, &super::super::VectorizedEngine)
            .unwrap();
        let m_ica = crate::metrics::graph_metrics(&ds.adjacency, &ica_fit.adjacency, 0.1);
        let m_dir = crate::metrics::graph_metrics(&ds.adjacency, &direct.adjacency, 0.1);
        assert!(
            (m_ica.f1 - m_dir.f1).abs() < 0.3,
            "ica f1 {} vs direct f1 {}",
            m_ica.f1,
            m_dir.f1
        );
        assert!(m_ica.f1 > 0.6);
    }

    #[test]
    fn upper_mass_zero_for_true_order() {
        let mut b = Mat::zeros(3, 3);
        b[(1, 0)] = 0.5;
        b[(2, 0)] = 0.3;
        assert_eq!(upper_mass(&b, &[0, 1, 2]), 0.0);
        assert!(upper_mass(&b, &[2, 1, 0]) > 0.0);
    }

    #[test]
    fn greedy_path_used_for_large_d() {
        // d = 9 exercises the greedy branch; just verify a permutation
        let b = Mat::from_fn(9, 9, |r, c| if r > c { 0.2 } else { 0.0 });
        let order = best_causal_order(&b);
        let mut o = order.clone();
        o.sort_unstable();
        assert_eq!(o, (0..9).collect::<Vec<_>>());
        assert_eq!(upper_mass(&b, &order), 0.0);
    }
}
