//! The maximum-entropy approximation of differential entropy (Hyvärinen
//! 1998) used by DirectLiNGAM's pairwise independence measure, and the
//! mutual-information difference from Algorithm 1.
//!
//! For a standardized variable u:
//!
//!   H(u) ≈ H(ν) − k₁·(E[log cosh u] − γ)² − k₂·(E[u·exp(−u²/2)])²
//!
//! with H(ν) = (1 + log 2π)/2 the entropy of a standard Gaussian,
//! k₁ = 79.047, k₂ = 7.4129, γ = 0.37457 (the constants the reference
//! `lingam` package uses).
//!
//! The pairwise measure for candidate root i against j:
//!
//!   diff_mi(i, j) = [H(x_j) + H(r_i→j)] − [H(x_i) + H(r_j→i)]
//!
//! where r_i→j = (x_i − ρ x_j)/√(1−ρ²) is the standardized residual of
//! regressing x_i on x_j. diff_mi > 0 is evidence that i is more
//! plausibly the cause.

/// Entropy of a standard Gaussian: (1 + log 2π)/2.
pub const H_NU: f64 = 1.418_938_533_204_672_7;
/// Max-ent constant k₁.
pub const K1: f64 = 79.047;
/// Max-ent constant k₂.
pub const K2: f64 = 7.4129;
/// Max-ent constant γ = E[log cosh ν].
pub const GAMMA: f64 = 0.37457;

/// Numerically-stable log cosh: |u| + log1p(exp(−2|u|)) − log 2.
#[inline]
pub fn log_cosh(u: f64) -> f64 {
    let a = u.abs();
    a + (-2.0 * a).exp().ln_1p() - std::f64::consts::LN_2
}

/// The score nonlinearity u·exp(−u²/2).
#[inline]
pub fn gauss_score(u: f64) -> f64 {
    u * (-0.5 * u * u).exp()
}

/// Max-ent entropy approximation of an (assumed standardized) sample.
///
/// Delegates to [`super::sweep::entropy_fused`] — the one fused
/// log-cosh/gauss-score loop in the crate, which lives next to the
/// chunked pair kernel so every entropy pass shares code (this module
/// and `engine` used to carry an identical copy each).
pub fn entropy(u: &[f64]) -> f64 {
    super::sweep::entropy_fused(u)
}

/// Entropy from the two precomputed expectations (the form both the
/// Pallas kernel and the vectorized engine use).
#[inline]
pub fn entropy_from_moments(e_log_cosh: f64, e_gauss_score: f64) -> f64 {
    H_NU - K1 * (e_log_cosh - GAMMA).powi(2) - K2 * e_gauss_score.powi(2)
}

/// Mutual-information difference between directions for a standardized
/// pair with correlation `rho` and the four entropy terms precomputed.
///
/// Residual entropies must be of the *standardized* residuals.
#[inline]
pub fn diff_mi(h_xi: f64, h_xj: f64, h_ri_j: f64, h_rj_i: f64) -> f64 {
    (h_xj + h_ri_j) - (h_xi + h_rj_i)
}

/// Accumulate Algorithm 1's per-candidate statistic: `min(0, diff)²`.
/// (Candidates are penalized only by evidence *against* their exogeneity.)
#[inline]
pub fn order_penalty(diff: f64) -> f64 {
    let m = diff.min(0.0);
    m * m
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn log_cosh_matches_naive_in_safe_range() {
        for &u in &[-3.0, -0.5, 0.0, 0.1, 2.7] {
            let naive = (u as f64).cosh().ln();
            assert!((log_cosh(u) - naive).abs() < 1e-12, "u={u}");
        }
    }

    #[test]
    fn log_cosh_stable_for_huge_inputs() {
        // naive cosh overflows near 710; ours must not
        let v = log_cosh(1e6);
        assert!(v.is_finite());
        assert!((v - (1e6 - std::f64::consts::LN_2)).abs() < 1e-6);
    }

    #[test]
    fn gaussian_entropy_is_maximal() {
        let mut rng = Pcg64::seed_from_u64(1);
        let n = 100_000;
        let gauss: Vec<f64> = (0..n).map(|_| rng.normal()).collect();
        let mut unif: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let mut lap: Vec<f64> = (0..n).map(|_| rng.laplace(1.0)).collect();
        crate::stats::standardize(&mut unif);
        crate::stats::standardize(&mut lap);
        let hg = entropy(&gauss);
        let hu = entropy(&unif);
        let hl = entropy(&lap);
        assert!((hg - H_NU).abs() < 0.01, "gaussian ≈ H_NU, got {hg}");
        assert!(hu < hg, "uniform {hu} < gaussian {hg}");
        assert!(hl < hg, "laplace {hl} < gaussian {hg}");
    }

    #[test]
    fn diff_mi_detects_causal_direction_uniform_noise() {
        // x → y with uniform noise: diff_mi computed for i=x must be > 0
        let mut rng = Pcg64::seed_from_u64(2);
        let n = 50_000;
        let mut x: Vec<f64> = (0..n).map(|_| rng.f64()).collect();
        let mut y: Vec<f64> = x.iter().map(|&v| 1.5 * v + rng.f64()).collect();
        crate::stats::standardize(&mut x);
        crate::stats::standardize(&mut y);
        let rho = crate::stats::cov(&x, &y);
        let denom = (1.0 - rho * rho).sqrt();
        let rx_y: Vec<f64> = x.iter().zip(&y).map(|(&a, &b)| (a - rho * b) / denom).collect();
        let ry_x: Vec<f64> = y.iter().zip(&x).map(|(&a, &b)| (a - rho * b) / denom).collect();
        let d = diff_mi(entropy(&x), entropy(&y), entropy(&rx_y), entropy(&ry_x));
        assert!(d > 0.0, "x should look exogenous, diff={d}");
    }

    #[test]
    fn order_penalty_only_negative_evidence() {
        assert_eq!(order_penalty(0.5), 0.0);
        assert_eq!(order_penalty(0.0), 0.0);
        assert!((order_penalty(-0.3) - 0.09).abs() < 1e-15);
    }

    #[test]
    fn entropy_from_moments_consistent() {
        let mut rng = Pcg64::seed_from_u64(3);
        let u: Vec<f64> = (0..10_000).map(|_| rng.normal()).collect();
        let n = u.len() as f64;
        let lc = u.iter().map(|&v| log_cosh(v)).sum::<f64>() / n;
        let gs = u.iter().map(|&v| gauss_score(v)).sum::<f64>() / n;
        assert!((entropy(&u) - entropy_from_moments(lc, gs)).abs() < 1e-12);
    }
}
