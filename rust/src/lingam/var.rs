//! VarLiNGAM (Hyvärinen, Zhang, Shimizu & Hoyer 2010): causal discovery
//! for multivariate time series combining a VAR model with LiNGAM.
//!
//!   x(t) = Σ_{τ=0..k} B_τ x(t−τ) + ε(t)
//!
//! 1. estimate the reduced-form VAR(k) coefficients M_τ by least squares,
//! 2. run DirectLiNGAM on the VAR residuals → instantaneous B̂₀,
//! 3. transform every lag: B̂_τ = (I − B̂₀) M̂_τ,
//! 4. rank total causal influence exerted/received (the paper's Table 2).
//!
//! The paper's stock experiment uses k = 1 (the default); the general-k
//! form is the paper's Eqn. for VarLiNGAM and exercised by tests.

use super::direct::{DirectLingam, LingamFit};
use super::engine::OrderingEngine;
use super::prune::PruneMethod;
use crate::linalg::{lstsq, Mat};
use crate::util::timer::StageProfile;
use crate::util::{Error, Result};

/// VarLiNGAM configuration.
#[derive(Clone, Debug)]
pub struct VarLingam {
    pub prune: PruneMethod,
    /// VAR order k ≥ 1 (paper's stock run: 1).
    pub lags: usize,
}

impl Default for VarLingam {
    fn default() -> Self {
        VarLingam { prune: PruneMethod::default(), lags: 1 }
    }
}

/// A fitted VarLiNGAM model.
#[derive(Clone, Debug)]
pub struct VarLingamFit {
    /// Reduced-form VAR matrices M̂_τ, τ = 1..=k.
    pub m_tau: Vec<Mat>,
    /// Instantaneous causal adjacency B̂₀ (acyclic).
    pub b0: Mat,
    /// Lagged causal matrices B̂_τ = (I − B̂₀) M̂_τ, τ = 1..=k.
    pub b_tau: Vec<Mat>,
    /// Causal order of the innovations.
    pub order: Vec<usize>,
    /// Stage timings ("var_fit", "ordering", "regression").
    pub profile: StageProfile,
}

impl VarLingamFit {
    /// Lag-1 reduced-form matrix (always present).
    pub fn m1(&self) -> &Mat {
        &self.m_tau[0]
    }

    /// Lag-1 causal matrix (always present).
    pub fn b1(&self) -> &Mat {
        &self.b_tau[0]
    }
}

impl VarLingam {
    pub fn new() -> Self {
        Self::default()
    }

    /// VAR order k.
    pub fn with_lags(mut self, lags: usize) -> Self {
        assert!(lags >= 1);
        self.lags = lags;
        self
    }

    /// Fit on a time-series panel `[T, d]` (row t = x(t)).
    pub fn fit(&self, series: &Mat, engine: &dyn OrderingEngine) -> Result<VarLingamFit> {
        let (t_len, d) = (series.rows(), series.cols());
        if t_len < self.lags * d + 2 {
            return Err(Error::InvalidArgument(format!(
                "series too short: T={t_len} for d={d}, k={}",
                self.lags
            )));
        }
        let mut profile = StageProfile::new();

        // 1) VAR(k) by least squares (centered = implicit intercept)
        let (m_tau, resid) = profile.time("var_fit", || var_fit(series, self.lags))?;

        // 2) DirectLiNGAM on the innovations
        let direct = DirectLingam::with_prune(self.prune);
        let lingam: LingamFit = direct.fit(&resid, engine)?;
        profile.merge(&lingam.profile);

        // 3) lag-matrix transformation for every lag
        let b0 = lingam.adjacency.clone();
        let i_minus_b0 = Mat::eye(d).sub(&b0);
        let b_tau: Vec<Mat> = m_tau.iter().map(|m| i_minus_b0.matmul(m)).collect();

        Ok(VarLingamFit { m_tau, b0, b_tau, order: lingam.order, profile })
    }
}

/// Least-squares VAR(k): regress x(t) on [x(t−1), ..., x(t−k)].
/// Returns (M̂_1..M̂_k, residuals `[T−k, d]`).
pub fn var_fit(series: &Mat, lags: usize) -> Result<(Vec<Mat>, Mat)> {
    let (t_len, d) = (series.rows(), series.cols());
    let rows = t_len - lags;
    // design: row t = [x(t+k−1), x(t+k−2), ..., x(t)]  (lag 1 first)
    let design = Mat::from_fn(rows, lags * d, |t, c| {
        let tau = c / d + 1; // 1..=k
        let var = c % d;
        series[(t + lags - tau, var)]
    });
    let future = series.select_rows(&((lags..t_len).collect::<Vec<_>>()));
    let center = |m: &Mat| {
        let mut out = m.clone();
        for c in 0..m.cols() {
            let mu = crate::stats::mean(&m.col(c));
            for r in 0..m.rows() {
                out[(r, c)] -= mu;
            }
        }
        out
    };
    let pc = center(&design);
    let fc = center(&future);
    let coef = lstsq(&pc, &fc)?; // [k·d, d] — stacked M_τᵀ
    let pred = pc.matmul(&coef);
    let resid = fc.sub(&pred);
    let m_tau: Vec<Mat> = (0..lags)
        .map(|tau| Mat::from_fn(d, d, |i, j| coef[(tau * d + j, i)]))
        .collect();
    Ok((m_tau, resid))
}

/// Backwards-compatible lag-1 helper used by the runtime cross-check.
pub fn var1_fit(series: &Mat) -> Result<(Mat, Mat)> {
    let (mut m, r) = var_fit(series, 1)?;
    Ok((m.remove(0), r))
}

/// Total causal influence rankings (paper Table 2): for each variable and
/// lag τ (0 = instantaneous), the influence it exerts is the column
/// abs-sum of B̂_τ and the influence it receives is the row abs-sum.
#[derive(Clone, Debug)]
pub struct TotalEffects {
    /// `exerted[τ][j]` — Σ_i |B̂_τ[i,j]|, τ = 0..=k.
    pub exerted: Vec<Vec<f64>>,
    /// `received[τ][i]` — Σ_j |B̂_τ[i,j]|.
    pub received: Vec<Vec<f64>>,
}

/// Compute exerted/received total effects from a fit.
pub fn total_effects(fit: &VarLingamFit) -> TotalEffects {
    let d = fit.b0.rows();
    let col_sum = |m: &Mat, j: usize| (0..d).map(|i| m[(i, j)].abs()).sum::<f64>();
    let row_sum = |m: &Mat, i: usize| (0..d).map(|j| m[(i, j)].abs()).sum::<f64>();
    let mats: Vec<&Mat> = std::iter::once(&fit.b0).chain(fit.b_tau.iter()).collect();
    TotalEffects {
        exerted: mats.iter().map(|m| (0..d).map(|j| col_sum(m, j)).collect()).collect(),
        received: mats.iter().map(|m| (0..d).map(|i| row_sum(m, i)).collect()).collect(),
    }
}

/// Top-k (node, lag, score) triples by exerted or received influence.
pub fn top_influence(scores: &[Vec<f64>], k: usize) -> Vec<(usize, usize, f64)> {
    let mut all: Vec<(usize, usize, f64)> = Vec::new();
    for (tau, s) in scores.iter().enumerate() {
        for (node, &v) in s.iter().enumerate() {
            all.push((node, tau, v));
        }
    }
    all.sort_by(|a, b| b.2.partial_cmp(&a.2).unwrap());
    all.truncate(k);
    all
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::graph;
    use crate::lingam::VectorizedEngine;
    use crate::sim::{simulate_var, VarSpec};
    use crate::util::rng::Pcg64;

    #[test]
    fn var1_fit_recovers_reduced_form() {
        // pure VAR without instantaneous effects: M1 should match truth
        let spec = VarSpec {
            dim: 5,
            instant_edges_per_node: 0.0,
            lag_scale: 0.4,
            lag_density: 0.5,
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from_u64(1);
        let ds = simulate_var(&spec, 20_000, &mut rng);
        let (m1, resid) = var1_fit(&ds.data).unwrap();
        // reduced form here equals B1 (since B0 = 0)
        let err = m1.sub(&ds.b1).max_abs();
        assert!(err < 0.05, "M1 error {err}");
        assert_eq!(resid.rows(), ds.data.rows() - 1);
    }

    #[test]
    fn recovers_instantaneous_structure() {
        let spec = VarSpec { dim: 6, ..Default::default() };
        let mut rng = Pcg64::seed_from_u64(2);
        let ds = simulate_var(&spec, 30_000, &mut rng);
        let fit = VarLingam::new().fit(&ds.data, &VectorizedEngine).unwrap();
        assert!(graph::order_consistent(&ds.b0, &fit.order), "order {:?}", fit.order);
        let m = crate::metrics::graph_metrics(&ds.b0, &fit.b0, 0.1);
        assert!(m.f1 > 0.7, "f1 = {}", m.f1);
    }

    #[test]
    fn b1_transformation_identity_when_b0_zero() {
        let spec = VarSpec {
            dim: 4,
            instant_edges_per_node: 0.0,
            lag_density: 0.5,
            ..Default::default()
        };
        let mut rng = Pcg64::seed_from_u64(3);
        let ds = simulate_var(&spec, 10_000, &mut rng);
        let fit = VarLingam::new().fit(&ds.data, &VectorizedEngine).unwrap();
        // with B0 ≈ 0, B1 ≈ M1
        let diff = fit.b1().sub(fit.m1()).max_abs();
        assert!(
            diff < 0.3 * (1.0 + fit.m1().max_abs()),
            "B1 vs M1 diff {diff} (b0 max {})",
            fit.b0.max_abs()
        );
    }

    #[test]
    fn lag2_fit_beats_lag1_on_lag2_process() {
        // pure AR(2) process: x(t) = A₂ x(t−2) + ε(t), no lag-1 term
        let d = 4;
        let mut rng = Pcg64::seed_from_u64(4);
        let a2 = Mat::from_fn(d, d, |r, c| if r == c { 0.6 } else if (r + 1) % d == c { 0.2 } else { 0.0 });
        let t_len = 12_000;
        let mut x = Mat::zeros(t_len, d);
        for t in 0..t_len {
            for i in 0..d {
                let mut v = rng.laplace(1.0);
                if t >= 2 {
                    for j in 0..d {
                        v += a2[(i, j)] * x[(t - 2, j)];
                    }
                }
                x[(t, i)] = v;
            }
        }
        let (m_k2, resid2) = var_fit(&x, 2).unwrap();
        let (_m_k1, resid1) = var_fit(&x, 1).unwrap();
        let var_of = |m: &Mat| {
            m.as_slice().iter().map(|v| v * v).sum::<f64>() / m.as_slice().len() as f64
        };
        // lag-2 fit explains the process; lag-1 cannot
        assert!(
            var_of(&resid2) < 0.8 * var_of(&resid1),
            "lag-2 {} vs lag-1 {}",
            var_of(&resid2),
            var_of(&resid1)
        );
        // M₂ carries the structure, M₁ ≈ 0
        assert!(m_k2[1].sub(&a2).max_abs() < 0.1, "M2 error {}", m_k2[1].sub(&a2).max_abs());
        assert!(m_k2[0].max_abs() < 0.1, "M1 should vanish: {}", m_k2[0].max_abs());
    }

    #[test]
    fn total_effects_rankings() {
        let mut b0 = Mat::zeros(3, 3);
        b0[(1, 0)] = 2.0; // 0 exerts strongly
        b0[(2, 0)] = 1.0;
        let fit = VarLingamFit {
            m_tau: vec![Mat::zeros(3, 3)],
            b0,
            b_tau: vec![Mat::zeros(3, 3)],
            order: vec![0, 1, 2],
            profile: StageProfile::new(),
        };
        let te = total_effects(&fit);
        assert_eq!(te.exerted[0][0], 3.0);
        assert_eq!(te.received[0][1], 2.0);
        let top = top_influence(&te.exerted, 2);
        assert_eq!(top[0], (0, 0, 3.0));
    }

    #[test]
    fn profile_includes_all_stages() {
        let spec = VarSpec { dim: 5, ..Default::default() };
        let mut rng = Pcg64::seed_from_u64(4);
        let ds = simulate_var(&spec, 2_000, &mut rng);
        let fit = VarLingam::new().fit(&ds.data, &VectorizedEngine).unwrap();
        assert!(fit.profile.secs("var_fit") > 0.0);
        assert!(fit.profile.secs("ordering") > 0.0);
        assert!(fit.profile.secs("regression") > 0.0);
    }

    #[test]
    fn too_short_series_rejected() {
        let m = Mat::zeros(5, 10);
        assert!(VarLingam::new().fit(&m, &VectorizedEngine).is_err());
        let m2 = Mat::zeros(25, 10);
        assert!(VarLingam::new().with_lags(3).fit(&m2, &VectorizedEngine).is_err());
    }
}
