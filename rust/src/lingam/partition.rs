//! Partitioned causal ordering: the plan layer that scales DirectLiNGAM
//! past d≈1000 by decomposing the panel into correlation-connected
//! column blocks before paying the per-step O(d²·n) pair sweeps.
//!
//! The decomposition is a thresholded correlation graph: columns `a` and
//! `b` are linked when `|ρ_ab| > threshold`, and each connected
//! component becomes a block ([`partition_columns`], built from the
//! correlation matrix the [`IncrementalSession`] has already computed —
//! the partition costs no statistics of its own). A
//! [`PartitionedPlan`] then orders the blocks' variables and merges
//! them back into one global causal order through the
//! [`OrderingPlan`] seam in [`super::direct`].
//!
//! # Merge exactness
//!
//! The pruned sweep (see [`super::sweep`]) can be exact because every
//! skipped pair comes with a per-candidate certificate: a running
//! penalty already above a completed total cannot win the argmax.
//! Partitioning has no analogous certificate. The idealized lemma *does*
//! hold: if every cross-block correlation were exactly zero, the
//! cross-block regression coefficient would be zero, residualization
//! would be the identity on the other blocks' columns, the closed-form
//! correlation update would preserve the zeros, and every cross-block
//! `pair_diff` would contribute zero penalty — the blockwise fit would
//! *be* the global fit. But sample correlations are never exactly zero
//! (they concentrate at O(n^{-1/2})), and a near-zero cross-block pair
//! has no bound that proves it cannot flip an argmax. Exactness
//! therefore cannot come from omitting boundary work, and the plan
//! tiers the same way the sweep does:
//!
//! 1. **[`MergeMode::Exact`] evaluates everything.** It drives a single
//!    session over the whole panel through the same step loop as the
//!    unpartitioned fit — same workers ⇒ bitwise-identical scores,
//!    identical order and adjacency *by construction* (pinned by
//!    `tests/partition_exactness.rs`). The partition is used purely for
//!    instrumentation: at each step it counts how many of the active
//!    pairs straddle blocks, i.e. exactly the work a lossy
//!    decomposition would have skipped. This is the measured baseline,
//!    playing the role `SweepStrategy::Exact` plays for the sweep.
//! 2. **[`MergeMode::Approx`] actually skips it.** Each block is
//!    ordered by an independent session over its column subpanel
//!    (O(Σ_b d_b²·n) per step instead of O(d²·n)), and the block
//!    orders are reconciled by a k-way tournament restricted to
//!    boundary pairs: at every merge step the blocks' current heads are
//!    scored with the exact pair kernel ([`pair_diff_with_rho`]) on the
//!    initial standardized statistics, under the same bound-pruned
//!    machinery ([`pruned_sweep`]) scheduled by the blocks' own head
//!    scores. Every head pair is cross-block, so the sweep's visited
//!    count *is* the boundary-pair count. The SHD this tier trades for
//!    speed is measured, not promised away — the `partition_scaling`
//!    bench reports the SHD-vs-speed table alongside the counters.
//!
//! [`PartitionWorkspace`] is the exact tier packaged as an
//! [`OrderingSession`], so the bootstrap pools it across resamples
//! exactly like any other session workspace ([`OrderingSession::reset`]
//! re-seeds the inner workspace *and* re-partitions against the
//! resample's own correlation graph).

use super::direct::{OrderingPlan, PlanOrdering};
use super::engine::{argmax_active, scatter_scores, OrderStep};
use super::parallel::default_workers;
use super::session::{IncrementalSession, OrderingSession};
use super::sweep::{entropy_fused, pair_diff_with_rho, pruned_sweep, SweepCounters};
use crate::linalg::Mat;
use crate::util::pool::parallel_indexed;
use crate::util::Result;
use std::collections::BTreeMap;

/// Correlation-graph edge threshold the `partition[:B]` engine spec
/// uses: |ρ| above this links two columns into one block.
pub const DEFAULT_THRESHOLD: f64 = 0.05;

/// How block orders are merged back into one global order (see the
/// module essay for why these tier like the sweep strategies).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum MergeMode {
    /// Provably identical to the unpartitioned fit: one global session,
    /// the partition only instruments boundary-pair work.
    #[default]
    Exact,
    /// Independent per-block sessions + boundary-pair tournament merge:
    /// real asymptotic saving, measured SHD cost.
    Approx,
}

/// Configuration of a [`PartitionedPlan`].
#[derive(Clone, Copy, Debug)]
pub struct PartitionSpec {
    /// Upper bound on the number of blocks (0 = uncapped): smallest
    /// components are merged until the cap holds, so `partition:1`
    /// degenerates to the whole-panel fit.
    pub max_blocks: usize,
    /// Correlation-graph edge threshold ([`DEFAULT_THRESHOLD`]).
    pub threshold: f64,
    /// Merge tier ([`MergeMode::Exact`] by default).
    pub merge: MergeMode,
    /// Worker threads for sessions and block-level parallelism
    /// (0 = size to the machine).
    pub workers: usize,
}

impl Default for PartitionSpec {
    fn default() -> Self {
        PartitionSpec {
            max_blocks: 0,
            threshold: DEFAULT_THRESHOLD,
            merge: MergeMode::Exact,
            workers: 0,
        }
    }
}

impl PartitionSpec {
    fn resolved_workers(&self) -> usize {
        if self.workers == 0 {
            default_workers()
        } else {
            self.workers
        }
    }
}

/// Connected components of the thresholded correlation graph: columns
/// `a`, `b` are linked when `|corr[(a,b)]| > threshold` (strict, so a
/// threshold of 0 still separates exactly-orthogonal columns). Blocks
/// come out sorted by smallest member with members ascending; when
/// `max_blocks > 0`, the smallest components (ties: lowest first
/// member) are merged pairwise until the cap holds.
pub fn partition_columns(corr: &Mat, threshold: f64, max_blocks: usize) -> Vec<Vec<usize>> {
    let d = corr.rows();
    // union-find with union-by-minimum, so each root is its component's
    // smallest member and the BTreeMap below yields blocks pre-sorted
    let mut parent: Vec<usize> = (0..d).collect();
    fn find(parent: &mut [usize], mut i: usize) -> usize {
        while parent[i] != i {
            parent[i] = parent[parent[i]];
            i = parent[i];
        }
        i
    }
    for a in 0..d {
        for b in (a + 1)..d {
            if corr[(a, b)].abs() > threshold {
                let (ra, rb) = (find(&mut parent, a), find(&mut parent, b));
                if ra != rb {
                    parent[ra.max(rb)] = ra.min(rb);
                }
            }
        }
    }
    let mut by_root: BTreeMap<usize, Vec<usize>> = BTreeMap::new();
    for i in 0..d {
        let r = find(&mut parent, i);
        by_root.entry(r).or_default().push(i);
    }
    let mut blocks: Vec<Vec<usize>> = by_root.into_values().collect();
    if max_blocks > 0 {
        while blocks.len() > max_blocks {
            blocks.sort_by_key(|b| (b.len(), b[0]));
            let small = blocks.remove(0);
            blocks[0].extend(small);
            blocks[0].sort_unstable();
        }
        blocks.sort_by_key(|b| b[0]);
    }
    blocks
}

/// Unordered active pairs that straddle blocks: C(m,2) minus the
/// within-block pair counts — the exact tier's per-step boundary-pair
/// instrumentation.
fn cross_block_pairs(active: &[bool], labels: &[usize], num_blocks: usize) -> u64 {
    let choose2 = |k: u64| k * k.saturating_sub(1) / 2;
    let mut per = vec![0u64; num_blocks];
    let mut m = 0u64;
    for (i, &a) in active.iter().enumerate() {
        if a {
            per[labels[i]] += 1;
            m += 1;
        }
    }
    choose2(m) - per.iter().map(|&k| choose2(k)).sum::<u64>()
}

// ---------------------------------------------------------------------
// The exact tier as a poolable session.
// ---------------------------------------------------------------------

/// The exact merge tier packaged as an [`OrderingSession`]: a global
/// [`IncrementalSession`] plus per-column block labels. Every step
/// first books the active cross-block pair count, then delegates to the
/// inner session — so the fit it produces is the inner session's fit,
/// bit for bit. `reset` re-seeds the inner workspace and re-partitions
/// against the fresh panel's correlation graph, which is what lets the
/// bootstrap pool these across resamples like any other session.
pub struct PartitionWorkspace {
    inner: IncrementalSession,
    labels: Vec<usize>,
    num_blocks: usize,
    threshold: f64,
    max_blocks: usize,
    boundary_pairs: u64,
}

impl PartitionWorkspace {
    /// Seed a workspace for `data` (`spec.merge` is ignored — the
    /// workspace *is* the exact tier).
    pub fn new(data: &Mat, spec: &PartitionSpec) -> Result<PartitionWorkspace> {
        let inner = IncrementalSession::new(data, spec.resolved_workers(), false)?;
        let mut ws = PartitionWorkspace {
            inner,
            labels: vec![0; data.cols()],
            num_blocks: 0,
            threshold: spec.threshold,
            max_blocks: spec.max_blocks,
            boundary_pairs: 0,
        };
        ws.relabel();
        Ok(ws)
    }

    fn relabel(&mut self) {
        let blocks = partition_columns(self.inner.corr(), self.threshold, self.max_blocks);
        for (b, block) in blocks.iter().enumerate() {
            for &c in block {
                self.labels[c] = b;
            }
        }
        self.num_blocks = blocks.len();
        self.boundary_pairs = 0;
    }

    /// Blocks the current panel decomposed into.
    pub fn blocks_formed(&self) -> u64 {
        self.num_blocks as u64
    }

    /// Cross-block pairs the steps so far have visited.
    pub fn boundary_pairs(&self) -> u64 {
        self.boundary_pairs
    }
}

impl OrderingSession for PartitionWorkspace {
    fn remaining(&self) -> usize {
        self.inner.remaining()
    }

    fn rows(&self) -> usize {
        self.inner.rows()
    }

    fn active(&self) -> &[bool] {
        self.inner.active()
    }

    fn step(&mut self) -> Result<OrderStep> {
        self.boundary_pairs +=
            cross_block_pairs(self.inner.active(), &self.labels, self.num_blocks);
        self.inner.step()
    }

    fn reset(&mut self, data: &Mat) -> Result<()> {
        self.inner.reset(data)?;
        self.relabel();
        Ok(())
    }

    fn sweep_counters(&self) -> SweepCounters {
        self.inner.counters()
    }
}

// ---------------------------------------------------------------------
// Plans.
// ---------------------------------------------------------------------

/// The trivial plan: the whole panel is one block, ordered by one
/// [`IncrementalSession`] — [`DirectLingam::fit`](super::direct::DirectLingam::fit)
/// expressed through the plan seam, so plan-driven callers have a
/// baseline with identical semantics.
#[derive(Clone, Copy, Debug, Default)]
pub struct SingleBlockPlan {
    /// Worker threads for the session's sweeps (0 = machine-sized).
    pub workers: usize,
}

impl SingleBlockPlan {
    pub fn new(workers: usize) -> SingleBlockPlan {
        SingleBlockPlan { workers }
    }
}

impl OrderingPlan for SingleBlockPlan {
    fn name(&self) -> &'static str {
        "single-block"
    }

    fn order(&self, data: &Mat) -> Result<PlanOrdering> {
        let workers = if self.workers == 0 { default_workers() } else { self.workers };
        let mut session = IncrementalSession::new(data, workers, false)?;
        let (order, step_scores) = drive_session(&mut session, data.cols())?;
        Ok(PlanOrdering {
            order,
            step_scores,
            counters: session.counters(),
            blocks_formed: 1,
            boundary_pairs: 0,
        })
    }
}

/// The partitioned plan: decompose, order per block, merge — with the
/// tier split described in the module essay.
#[derive(Clone, Copy, Debug, Default)]
pub struct PartitionedPlan {
    pub spec: PartitionSpec,
}

impl PartitionedPlan {
    pub fn new(spec: PartitionSpec) -> PartitionedPlan {
        PartitionedPlan { spec }
    }

    /// The CLI/serve constructor: block cap straight from the
    /// `partition[:B]` engine spec, workers from the caller's
    /// normalization, defaults elsewhere (exact merge).
    pub fn with_blocks(max_blocks: usize, workers: usize) -> PartitionedPlan {
        PartitionedPlan {
            spec: PartitionSpec { max_blocks, workers, ..PartitionSpec::default() },
        }
    }
}

impl OrderingPlan for PartitionedPlan {
    fn name(&self) -> &'static str {
        "partition"
    }

    fn order(&self, data: &Mat) -> Result<PlanOrdering> {
        match self.spec.merge {
            MergeMode::Exact => exact_order(data, &self.spec),
            MergeMode::Approx => approx_order(data, &self.spec),
        }
    }
}

/// Shared d−1-step drive loop over a session (the plan-layer twin of
/// `DirectLingam::drive`, minus profiling/observer concerns).
fn drive_session(
    session: &mut dyn OrderingSession,
    d: usize,
) -> Result<(Vec<usize>, Vec<Vec<f64>>)> {
    let mut order = Vec::with_capacity(d);
    let mut step_scores = Vec::with_capacity(d.saturating_sub(1));
    for _ in 1..d {
        let step = session.step()?;
        order.push(step.chosen);
        step_scores.push(step.scores);
    }
    let last = session
        .active()
        .iter()
        .position(|&a| a)
        .expect("exactly one variable remains");
    order.push(last);
    Ok((order, step_scores))
}

fn exact_order(data: &Mat, spec: &PartitionSpec) -> Result<PlanOrdering> {
    let mut ws = PartitionWorkspace::new(data, spec)?;
    let (order, step_scores) = drive_session(&mut ws, data.cols())?;
    Ok(PlanOrdering {
        order,
        step_scores,
        counters: ws.sweep_counters(),
        blocks_formed: ws.blocks_formed(),
        boundary_pairs: ws.boundary_pairs(),
    })
}

/// One block's independent fit: local order mapped to global column
/// indices, plus each entry's block-local score at the step it was
/// chosen (the merge's scheduling priority; the forced last entry gets
/// −∞ so it is scheduled last among heads).
struct BlockFit {
    order: Vec<usize>,
    scores: Vec<f64>,
    counters: SweepCounters,
}

fn fit_block(data: &Mat, cols: &[usize]) -> Result<BlockFit> {
    if cols.len() == 1 {
        return Ok(BlockFit {
            order: vec![cols[0]],
            scores: vec![f64::NEG_INFINITY],
            counters: SweepCounters::default(),
        });
    }
    // per-block sessions are serial: parallelism lives at block level
    let sub = data.select_cols(cols);
    let mut session = IncrementalSession::new(&sub, 1, false)?;
    let mut order = Vec::with_capacity(cols.len());
    let mut scores = Vec::with_capacity(cols.len());
    for _ in 1..cols.len() {
        let step = session.step()?;
        order.push(cols[step.chosen]);
        scores.push(step.scores[step.chosen]);
    }
    let last = session
        .active()
        .iter()
        .position(|&a| a)
        .expect("exactly one variable remains");
    order.push(cols[last]);
    scores.push(f64::NEG_INFINITY);
    Ok(BlockFit { order, scores, counters: session.counters() })
}

fn approx_order(data: &Mat, spec: &PartitionSpec) -> Result<PlanOrdering> {
    let (n, d) = (data.rows(), data.cols());
    // Seed statistics: standardized columns + full correlation matrix,
    // computed once. The seed session is never stepped, so its cache
    // stays the *initial* panel statistics the merge scores heads with.
    let seed = IncrementalSession::new(data, spec.resolved_workers(), false)?;
    let blocks = partition_columns(seed.corr(), spec.threshold, spec.max_blocks);
    let workers = spec.resolved_workers();

    // independent per-block fits over column subpanels
    let fits: Vec<Result<BlockFit>> =
        parallel_indexed(blocks.len(), workers, |b| fit_block(data, &blocks[b]));
    let mut block_orders = Vec::with_capacity(blocks.len());
    let mut head_scores = Vec::with_capacity(blocks.len());
    let mut counters = SweepCounters::default();
    for fit in fits {
        let fit = fit?;
        counters.merge(&fit.counters);
        block_orders.push(fit.order);
        head_scores.push(fit.scores);
    }

    // Cross-block reconciliation: k-way tournament over the blocks'
    // current heads, scored by the exact pair kernel on the initial
    // statistics under the bound-pruned sweep, scheduled by the blocks'
    // own head scores. Every head pair straddles blocks, so the sweep's
    // visited count is exactly the boundary-pair count.
    let h: Vec<f64> = (0..d).map(|i| entropy_fused(seed.cached_column(i))).collect();
    let corr = seed.corr();
    let mut heads = vec![0usize; blocks.len()];
    let mut order = Vec::with_capacity(d);
    let mut boundary_pairs = 0u64;
    loop {
        let live: Vec<usize> =
            (0..blocks.len()).filter(|&b| heads[b] < block_orders[b].len()).collect();
        if live.is_empty() {
            break;
        }
        if live.len() == 1 {
            // one block left: its internal order is already decided
            let b = live[0];
            order.extend_from_slice(&block_orders[b][heads[b]..]);
            break;
        }
        let cand: Vec<usize> = live.iter().map(|&b| block_orders[b][heads[b]]).collect();
        let m = cand.len();
        let diff = |a: usize, b: usize| {
            let (ca, cb) = (cand[a], cand[b]);
            pair_diff_with_rho(
                seed.cached_column(ca),
                seed.cached_column(cb),
                corr[(ca, cb)],
                h[ca],
                h[cb],
            )
        };
        let priority: Vec<f64> = live.iter().map(|&b| head_scores[b][heads[b]]).collect();
        let mut call = SweepCounters::default();
        let k = pruned_sweep(m, &diff, Some(&priority), n, &mut call);
        boundary_pairs += call.pairs_visited;
        counters.merge(&call);
        let idx: Vec<usize> = (0..m).collect();
        let scores = scatter_scores(m, &idx, &k);
        let winner = argmax_active(&scores, &vec![true; m])?;
        order.push(cand[winner]);
        heads[live[winner]] += 1;
    }
    Ok(PlanOrdering {
        // block-local scores are not comparable across blocks, so the
        // approx tier reports no global step scores
        order,
        step_scores: Vec::new(),
        counters,
        blocks_formed: blocks.len() as u64,
        boundary_pairs,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::lingam::{DirectLingam, VectorizedEngine};
    use crate::sim::{simulate_sem, SemSpec};
    use crate::util::rng::Pcg64;

    fn corr_from(pairs: &[(usize, usize)], d: usize) -> Mat {
        let mut c = Mat::eye(d);
        for &(a, b) in pairs {
            c[(a, b)] = 0.9;
            c[(b, a)] = 0.9;
        }
        c
    }

    #[test]
    fn components_split_and_threshold_is_strict() {
        let c = corr_from(&[(0, 1), (2, 3)], 4);
        assert_eq!(partition_columns(&c, 0.05, 0), vec![vec![0, 1], vec![2, 3]]);
        // |ρ| exactly at the threshold does not link
        let mut at = Mat::eye(2);
        at[(0, 1)] = 0.05;
        at[(1, 0)] = 0.05;
        assert_eq!(partition_columns(&at, 0.05, 0), vec![vec![0], vec![1]]);
    }

    #[test]
    fn fully_connected_is_one_block() {
        let c = corr_from(&[(0, 1), (1, 2), (2, 3)], 4);
        assert_eq!(partition_columns(&c, 0.05, 0), vec![vec![0, 1, 2, 3]]);
    }

    #[test]
    fn block_cap_merges_smallest_components_and_keeps_every_column() {
        let blocks = partition_columns(&Mat::eye(5), 0.05, 2);
        assert_eq!(blocks.len(), 2);
        let mut all = blocks.concat();
        all.sort_unstable();
        assert_eq!(all, vec![0, 1, 2, 3, 4]);
        // cap of 1 degenerates to the whole panel
        assert_eq!(partition_columns(&Mat::eye(5), 0.05, 1), vec![vec![0, 1, 2, 3, 4]]);
    }

    #[test]
    fn cross_block_pair_count_is_combinatorial() {
        // blocks {0,1}, {2,3}: 4 active → C(4,2)=6 pairs, 2 within
        let labels = vec![0, 0, 1, 1];
        assert_eq!(cross_block_pairs(&[true; 4], &labels, 2), 4);
        // deactivate one: C(3,2)=3 pairs, 1 within
        assert_eq!(cross_block_pairs(&[true, false, true, true], &labels, 2), 2);
        assert_eq!(cross_block_pairs(&[false; 4], &labels, 2), 0);
    }

    #[test]
    fn single_block_plan_is_the_unpartitioned_fit() {
        let mut rng = Pcg64::seed_from_u64(21);
        let ds = simulate_sem(&SemSpec::layered(6, 2, 0.5), 1_500, &mut rng);
        let direct = DirectLingam::new().fit(&ds.data, &VectorizedEngine).unwrap();
        let pf =
            DirectLingam::new().fit_plan(&ds.data, &SingleBlockPlan::new(1)).unwrap();
        assert_eq!(pf.fit.order, direct.order);
        assert_eq!(pf.fit.step_scores, direct.step_scores);
        assert_eq!(pf.blocks_formed, 1);
        assert_eq!(pf.boundary_pairs, 0);
    }

    #[test]
    fn workspace_reset_reseeds_and_repartitions() {
        let mut rng = Pcg64::seed_from_u64(22);
        let a = simulate_sem(&SemSpec::layered(6, 2, 0.5), 900, &mut rng).data;
        let b = simulate_sem(&SemSpec::layered(6, 2, 0.5), 900, &mut rng).data;
        let spec = PartitionSpec { workers: 1, ..PartitionSpec::default() };
        let mut pooled = PartitionWorkspace::new(&a, &spec).unwrap();
        let fit_a = DirectLingam::new().fit_session(&a, &mut pooled).unwrap();
        pooled.reset(&b).unwrap();
        assert_eq!(pooled.boundary_pairs(), 0, "reset must clear instrumentation");
        let fit_b = DirectLingam::new().fit_session(&b, &mut pooled).unwrap();
        let fresh = DirectLingam::new()
            .fit_session(&b, &mut PartitionWorkspace::new(&b, &spec).unwrap())
            .unwrap();
        assert_eq!(fit_b.order, fresh.order, "pooled reset diverged from fresh");
        assert_eq!(fit_b.step_scores, fresh.step_scores);
        assert_eq!(fit_a.order.len(), 6);
    }
}
